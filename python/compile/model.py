"""L2: batched (MeshBlockPack) hydro compute graph.

Builds the jitted, AOT-lowerable functions for every artifact kind listed in
DESIGN.md.  Each function is shaped for a static MeshBlockPack: a leading
``nb`` dimension over blocks of one fixed block size — the paper's
MeshBlockPack/"fill-in-one" machinery made concrete as one XLA executable
per (kind, shape, nb) variant.

All functions take/return f32 and are pure; the Rust coordinator owns all
state and sequencing.
"""

import jax
import jax.numpy as jnp

from . import bufspec
from .bufspec import NVAR
from .kernels import ref
from .kernels.hydro_pallas import stage_pallas

F32 = jnp.float32


def _shape_zyx(n, dim):
    return bufspec.total_shape(n, dim)


def make_stage(nb, dim, n, impl="jnp"):
    """(u [nb,NVAR,Z,Y,X], u0, scal f32[8]) -> u_new."""
    zyx = _shape_zyx(n, dim)
    if impl == "pallas":
        inner = stage_pallas(nb, dim, zyx)

        def fn(u, u0, scal):
            return (inner(u, u0, scal),)

        return fn

    def fn(u, u0, scal):
        return (jax.vmap(lambda a, b: ref.stage(a, b, scal, dim))(u, u0),)

    return fn


def make_dt(nb, dim, n):
    """(u, scal) -> per-block CFL dt, f32[nb]."""

    def fn(u, scal):
        return (jax.vmap(lambda a: ref.min_dt(a, scal, dim))(u),)

    return fn


def make_pack(nb, dim, n):
    """(u) -> bufs f32[nb, BUFLEN]: every boundary buffer in one launch."""

    def fn(u):
        return (jax.vmap(lambda a: ref.pack_buffers(a, dim, n))(u),)

    return fn


def make_pack1(nb, dim, n, nbr_idx):
    """(u) -> one neighbor's buffer (the per-buffer-kernel baseline)."""

    def fn(u):
        return (jax.vmap(lambda a: ref.pack_one_buffer(a, dim, n, nbr_idx))(u),)

    return fn


def make_unpack1(nb, dim, n, nbr_idx):
    """(u, seg) -> u with one neighbor's ghost region applied."""

    def fn(u, seg):
        return (
            jax.vmap(lambda a, s: ref.unpack_one_buffer(a, s, dim, n, nbr_idx))(
                u, seg
            ),
        )

    return fn


def make_unpack(nb, dim, n):
    """(u, bufs) -> u with all ghost regions filled, one launch."""

    def fn(u, bufs):
        return (jax.vmap(lambda a, b: ref.unpack_buffers(a, b, dim, n))(u, bufs),)

    return fn


def make_fused(nb, dim, n, impl="jnp"):
    """(u, u0, bufs_in, scal) -> (u_new, bufs_out, dt[nb]).

    unpack -> stage -> pack -> dt in ONE executable: the steady-state cycle
    needs exactly one launch per stage per pack.
    """
    if impl == "pallas":
        zyx = _shape_zyx(n, dim)
        pstage = stage_pallas(nb, dim, zyx)

        def fn(u, u0, bufs_in, scal):
            u = jax.vmap(lambda a, b: ref.unpack_buffers(a, b, dim, n))(u, bufs_in)
            u_new = pstage(u, u0, scal)
            bufs_out = jax.vmap(lambda a: ref.pack_buffers(a, dim, n))(u_new)
            dt = jax.vmap(lambda a: ref.min_dt(a, scal, dim))(u_new)
            return u_new, bufs_out, dt

        return fn

    def fn(u, u0, bufs_in, scal):
        def one(a, b, c):
            return ref.fused_step(a, b, c, scal, dim, n)

        return jax.vmap(one)(u, u0, bufs_in)

    return fn


# ---------------------------------------------------------------------------
# Multilevel boundary kernels (fine<->coarse exchange + flux correction).
# The `prolong` variant index packs (neighbor, child parity) into one int —
# `nbr_idx * 8 + child` — so the manifest keeps a single `nbr` field.
# ---------------------------------------------------------------------------


def pack_prolong_nbr(nbr_idx, child):
    """Encode a prolong variant's (neighbor index, child-parity bits)."""
    return nbr_idx * 8 + child


def unpack_prolong_nbr(code):
    return code // 8, code % 8


def prolong_seg_len(dim, n, nbr_idx, child):
    """Payload length of the coarse->fine prolongation source box."""
    o = bufspec.neighbors(dim)[nbr_idx]
    flx = [(child >> d) & 1 for d in range(3)]
    _, _, cdims = bufspec.coarse_prolong_box(o, flx, n, dim)
    return NVAR * cdims[0] * cdims[1] * cdims[2]


def fluxcorr_face_shape(dim, n, d):
    """(NVAR, T2, T1) fine-face plane shape for flux direction d."""
    t = [a for a in range(dim) if a != d]
    t1 = n[t[0]] if len(t) >= 1 else 1
    t2 = n[t[1]] if len(t) >= 2 else 1
    return (NVAR, t2, t1)


def make_restrict(nb, dim, n, nbr_idx):
    """(u) -> restricted fine->coarse boundary payload for one neighbor."""

    def fn(u):
        return (jax.vmap(lambda a: ref.restrict_send_segment(a, dim, n, nbr_idx))(u),)

    return fn


def make_prolong(nb, dim, n, code):
    """(u, seg) -> u with one coarse neighbor's ghost region prolongated."""
    nbr_idx, child = unpack_prolong_nbr(code)

    def fn(u, seg):
        return (
            jax.vmap(
                lambda a, s: ref.prolong_ghost_segment(a, s, dim, n, nbr_idx, child)
            )(u, seg),
        )

    return fn


def make_fluxcorr(nb, dim, n, d):
    """(face plane) -> tangentially restricted coarse-face flux payload."""

    def fn(face):
        return (jax.vmap(lambda a: ref.fluxcorr_face_restrict(a, dim))(face),)

    return fn


def arg_specs(kind, nb, dim, n, nbr_idx=None):
    """ShapeDtypeStructs for jax.jit(...).lower of an artifact kind."""
    zyx = _shape_zyx(n, dim)
    u = jax.ShapeDtypeStruct((nb, NVAR) + zyx, F32)
    scal = jax.ShapeDtypeStruct((8,), F32)
    bl = bufspec.buflen(n, dim)
    bufs = jax.ShapeDtypeStruct((nb, bl), F32)
    if kind == "stage":
        return (u, u, scal)
    if kind == "dt":
        return (u, scal)
    if kind == "pack" or kind == "pack1" or kind == "restrict":
        return (u,)
    if kind == "unpack":
        return (u, bufs)
    if kind == "unpack1":
        seg_len = bufspec.segment_lengths(n, dim)[nbr_idx]
        seg = jax.ShapeDtypeStruct((nb, seg_len), F32)
        return (u, seg)
    if kind == "prolong":
        ni, child = unpack_prolong_nbr(nbr_idx)
        seg_len = prolong_seg_len(dim, n, ni, child)
        seg = jax.ShapeDtypeStruct((nb, seg_len), F32)
        return (u, seg)
    if kind == "fluxcorr":
        face = jax.ShapeDtypeStruct((nb,) + fluxcorr_face_shape(dim, n, nbr_idx), F32)
        return (face,)
    if kind == "fused":
        return (u, u, bufs, scal)
    raise ValueError(f"unknown artifact kind {kind!r}")


def build(kind, nb, dim, n, impl="jnp", nbr_idx=None):
    """Return the python callable for an artifact variant."""
    if kind == "stage":
        return make_stage(nb, dim, n, impl)
    if kind == "dt":
        return make_dt(nb, dim, n)
    if kind == "pack":
        return make_pack(nb, dim, n)
    if kind == "pack1":
        return make_pack1(nb, dim, n, nbr_idx)
    if kind == "unpack":
        return make_unpack(nb, dim, n)
    if kind == "unpack1":
        return make_unpack1(nb, dim, n, nbr_idx)
    if kind == "restrict":
        return make_restrict(nb, dim, n, nbr_idx)
    if kind == "prolong":
        return make_prolong(nb, dim, n, nbr_idx)
    if kind == "fluxcorr":
        return make_fluxcorr(nb, dim, n, nbr_idx)
    if kind == "fused":
        return make_fused(nb, dim, n, impl)
    raise ValueError(f"unknown artifact kind {kind!r}")
