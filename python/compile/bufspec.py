"""Boundary-buffer layout contract, mirrored by rust/src/bvals/bufspec.rs.

Same-level ghost-zone exchange between MeshBlocks works on flat, per-block
buffer vectors.  For a block of interior size (nx, ny, nz) with NGHOST ghost
cells in every *active* dimension, the buffer vector concatenates one segment
per neighbor, in the canonical neighbor order defined by :func:`neighbors`.

* The *send* segment for neighbor offset ``o`` holds the interior cells
  adjacent to that boundary (width NGHOST in each pinched axis, full interior
  extent in tangential axes), laid out ``[v, z, y, x]`` row-major.
* The *recv* segment for neighbor offset ``o`` is written into the ghost
  region on the ``o`` side of the block.
* Routing (done by the Rust coordinator): block A's send segment for offset
  ``o`` becomes block B's recv segment for offset ``-o`` where B is A's
  neighbor in direction ``o``.

This module is authoritative: aot.py embeds the segment table into
artifacts/manifest.json and the Rust side cross-checks its own table
against it at startup.
"""

NGHOST = 2
NVAR = 5  # rho, mx, my, mz, E


def neighbors(dim):
    """Canonical neighbor offsets (ox1, ox2, ox3), x-fastest lexicographic.

    3D: 26 offsets; 2D: 8 offsets (ox3 == 0); 1D: 2 offsets.
    """
    r1 = (-1, 0, 1)
    r2 = r1 if dim >= 2 else (0,)
    r3 = r1 if dim >= 3 else (0,)
    out = []
    for o3 in r3:
        for o2 in r2:
            for o1 in r1:
                if (o1, o2, o3) != (0, 0, 0):
                    out.append((o1, o2, o3))
    return out


def _axis_send_range(o, n, active, g=NGHOST):
    """Index range [lo, hi) along one axis of the full (ghosted) array for
    the send slab of a neighbor with per-axis offset ``o``."""
    if not active:
        return (0, 1)
    if o == -1:
        return (g, 2 * g)
    if o == 1:
        return (n, n + g)
    return (g, g + n)


def _axis_recv_range(o, n, active, g=NGHOST):
    """Ghost-region range [lo, hi) along one axis for neighbor offset ``o``."""
    if not active:
        return (0, 1)
    if o == -1:
        return (0, g)
    if o == 1:
        return (g + n, g + n + g)
    return (g, g + n)


def send_slab(offset, n, dim, g=NGHOST):
    """((xlo,xhi),(ylo,yhi),(zlo,zhi)) send ranges for neighbor ``offset``.

    ``n`` = (nx, ny, nz) interior sizes (nz/ny may be 1 for lower dims).
    """
    o1, o2, o3 = offset
    nx, ny, nz = n
    return (
        _axis_send_range(o1, nx, True, g),
        _axis_send_range(o2, ny, dim >= 2, g),
        _axis_send_range(o3, nz, dim >= 3, g),
    )


def recv_slab(offset, n, dim, g=NGHOST):
    """Ghost-region ranges for neighbor ``offset`` (same shape as its
    opposite send slab)."""
    o1, o2, o3 = offset
    nx, ny, nz = n
    return (
        _axis_recv_range(o1, nx, True, g),
        _axis_recv_range(o2, ny, dim >= 2, g),
        _axis_recv_range(o3, nz, dim >= 3, g),
    )


def slab_len(slab):
    (x0, x1), (y0, y1), (z0, z1) = slab
    return (x1 - x0) * (y1 - y0) * (z1 - z0)


def segment_lengths(n, dim, nvar=NVAR, g=NGHOST):
    """Per-neighbor segment lengths (in f32 elements, including nvar)."""
    return [nvar * slab_len(send_slab(o, n, dim, g)) for o in neighbors(dim)]


def buflen(n, dim, nvar=NVAR, g=NGHOST):
    """Total flat buffer length per block."""
    return sum(segment_lengths(n, dim, nvar, g))


def opposite_index(dim):
    """Mapping i -> j such that neighbors(dim)[j] == -neighbors(dim)[i]."""
    ns = neighbors(dim)
    idx = {o: i for i, o in enumerate(ns)}
    return [idx[(-o[0], -o[1], -o[2])] for o in ns]


def total_shape(n, dim, g=NGHOST):
    """Full array shape (Z, Y, X) including ghosts in active dims."""
    nx, ny, nz = n
    zt = nz + 2 * g if dim >= 3 else 1
    yt = ny + 2 * g if dim >= 2 else 1
    xt = nx + 2 * g
    return (zt, yt, xt)


# ---------------------------------------------------------------------------
# Multilevel (fine <-> coarse) boundary geometry, mirrored by
# rust/src/bvals/exchange.rs.  A fine block restricts its boundary data
# before sending toward a coarser neighbor; a coarse block sends a
# prolongation source box (its own cells plus one coarse cell of padding,
# clamped to the block) toward each finer neighbor.  All index math uses
# floor division, matching Rust's div_euclid on the non-negative logical
# coordinates of a valid tree.
# ---------------------------------------------------------------------------


def _axis_fine_send_range(o, n, active, g=NGHOST):
    """Send range toward a COARSER neighbor: 2g deep (restricts to g)."""
    if not active:
        return (0, 1)
    if o == -1:
        return (g, g + 2 * g)
    if o == 1:
        return (g + n - 2 * g, g + n)
    return (g, g + n)


def fine_send_slab(offset, n, dim, g=NGHOST):
    """Slab a fine block restricts-and-sends toward a coarser neighbor."""
    o1, o2, o3 = offset
    nx, ny, nz = n
    return (
        _axis_fine_send_range(o1, nx, True, g),
        _axis_fine_send_range(o2, ny, dim >= 2, g),
        _axis_fine_send_range(o3, nz, dim >= 3, g),
    )


def restrict_seg_lens(n, dim, nvar=NVAR, g=NGHOST):
    """Per-neighbor payload lengths of the restricted fine->coarse sends
    (each active axis of the fine send slab halves: 2g -> g, n -> n//2)."""
    nx, ny, nz = n
    out = []
    for o1, o2, o3 in neighbors(dim):
        ln = nvar
        for o, nd, active in ((o1, nx, True), (o2, ny, dim >= 2), (o3, nz, dim >= 3)):
            if active:
                ln *= g if o != 0 else nd // 2
        out.append(ln)
    return out


def coarse_geom_lx(offset, lx):
    """Logical location of the coarser neighbor at `offset` of a fine block
    at `lx` (one level up): floor((lx + o) / 2) per axis."""
    return [(lx[d] + offset[d]) // 2 for d in range(3)]


def coarse_prolong_box(offset, flx, n, dim, g=NGHOST):
    """Geometry of the prolongation source a coarse block sends toward the
    fine block at `flx` across `offset` (the fine block's offset toward the
    coarse neighbor).

    Returns ``(local, clo, cdims)``: the slab in the coarse block's local
    (ghosted) indices, the global coarse index of its origin, and its dims.
    The box covers every coarse cell owning or adjacent to the fine ghost
    region (one cell of slope padding), clamped to the coarse interior.
    """
    clx = coarse_geom_lx(offset, flx)
    local = [(0, 1), (0, 1), (0, 1)]
    clo = [0, 0, 0]
    cdims = [1, 1, 1]
    for d in range(dim):
        nd = n[d]
        b_lo = flx[d] * nd
        b_hi = b_lo + nd
        if offset[d] == -1:
            flo, fhi = b_lo - g, b_lo
        elif offset[d] == 1:
            flo, fhi = b_hi, b_hi + g
        else:
            flo, fhi = b_lo, b_hi
        c0 = flo // 2 - 1
        c1 = (fhi - 1) // 2 + 2
        cs = clx[d] * nd
        ce = cs + nd
        c0 = max(c0, cs)
        c1 = min(c1, ce)
        local[d] = (c0 - cs + g, c1 - cs + g)
        clo[d] = c0
        cdims[d] = c1 - c0
    return tuple(local), clo, cdims


def coarse_recv_restriction_box(offset, flx, n, dim, g=NGHOST):
    """Slab (in the coarse block's local ghosted indices) where a coarse
    block lands the restricted payload from the fine block at `flx` across
    `offset` (the fine block's offset toward the coarse neighbor)."""
    clx = coarse_geom_lx(offset, flx)
    local = [(0, 1), (0, 1), (0, 1)]
    for d in range(dim):
        nd = n[d]
        b_lo = flx[d] * nd
        b_hi = b_lo + nd
        if offset[d] == -1:
            c0, c1 = b_lo // 2, b_lo // 2 + g
        elif offset[d] == 1:
            c0, c1 = b_hi // 2 - g, b_hi // 2
        else:
            c0, c1 = b_lo // 2, b_hi // 2
        cs = clx[d] * nd
        local[d] = (c0 - cs + g, c1 - cs + g)
    return tuple(local)
