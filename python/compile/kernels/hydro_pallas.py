"""L1: the hydro RK stage as a Pallas kernel.

The kernel body operates on ONE MeshBlock resident in "VMEM" (the Pallas
block); the pallas grid iterates over the MeshBlockPack dimension ``nb`` —
exactly the paper's MeshBlockPack picture: one kernel launch covers every
block in the pack, with the per-block work expressed once.

HARDWARE ADAPTATION (paper targets GPUs; we think in TPU terms per the
DESIGN.md §Hardware-Adaptation): a whole 16^3 block of 5 conserved variables
is 5*20^3*4 B ≈ 160 KB — it fits VMEM comfortably, so the natural TPU
schedule is "one block per grid step, whole-block vector ops", not a
threadblock tiling.  BlockSpec expresses the HBM->VMEM schedule; the stencil
arithmetic is plain VPU-style vector work (the Euler update has no matmul,
so the MXU is idle — the algorithm is bandwidth-bound, matching the paper's
roofline argument).

Must be lowered with ``interpret=True``: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.  Correctness is pinned to
``ref.py`` by pytest (see python/tests/test_kernel.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..bufspec import NVAR
from . import ref


def _stage_kernel(dim):
    """Kernel body: one RK stage on one block held in the Pallas block."""

    def kernel(u_ref, u0_ref, scal_ref, o_ref):
        u = u_ref[0]        # [NVAR, Z, Y, X] block, resident in "VMEM"
        u0 = u0_ref[0]
        scal = scal_ref[...]
        o_ref[0] = ref.stage(u, u0, scal, dim)

    return kernel


def stage_pallas(nb, dim, shape_zyx):
    """Build the batched stage function backed by the Pallas kernel.

    Returns ``f(u, u0, scal) -> u_new`` for u of shape [nb, NVAR, Z, Y, X].
    """
    z, y, x = shape_zyx
    blk = (1, NVAR, z, y, x)
    bspec = pl.BlockSpec(blk, lambda b: (b, 0, 0, 0, 0))
    sspec = pl.BlockSpec((8,), lambda b: (0,))

    def fn(u, u0, scal):
        return pl.pallas_call(
            _stage_kernel(dim),
            grid=(nb,),
            in_specs=[bspec, bspec, sspec],
            out_specs=bspec,
            out_shape=jax.ShapeDtypeStruct((nb, NVAR, z, y, x), jnp.float32),
            interpret=True,
        )(u, u0, scal)

    return fn
