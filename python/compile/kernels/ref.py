"""Pure-jnp single-block hydro oracle (L2 reference and production compute).

All functions operate on ONE block array ``u`` of shape ``[NVAR, Z, Y, X]``
(f32, ghosts included in active dims, NGHOST = 2).  ``model.py`` batches them
over the MeshBlockPack dimension with ``jax.vmap``.

The scheme mirrors PARTHENON-HYDRO (paper Sec. 4.1): ideal-gas Euler
equations, piecewise-linear reconstruction (MC limiter) on primitive
variables, HLLE Riemann solver, unsplit flux-divergence update, used inside
a two-stage RK2 integrator.  A stage computes

    u_new = g0 * u0 + g1 * u + beta * dt * L(u)

on interior cells (ghosts are passed through from ``u``; they are refilled
by boundary communication before the next stage).
"""

import jax.numpy as jnp

from .. import bufspec
from ..bufspec import NGHOST, NVAR

IDN, IM1, IM2, IM3, IEN = 0, 1, 2, 3, 4
# Primitive variable slots (same indexing): rho, vx, vy, vz, p.
IVX, IVY, IVZ, IPR = 1, 2, 3, 4

PRESSURE_FLOOR = 1.0e-10
DENSITY_FLOOR = 1.0e-10

# Axis index within a [NVAR, Z, Y, X] array for each direction d=0(x),1(y),2(z)
_AXIS = {0: 3, 1: 2, 2: 1}


def primitives(u, gamma):
    """Conserved -> primitive: w = [rho, vx, vy, vz, p], with floors."""
    rho = jnp.maximum(u[IDN], DENSITY_FLOOR)
    vx = u[IM1] / rho
    vy = u[IM2] / rho
    vz = u[IM3] / rho
    ke = 0.5 * rho * (vx * vx + vy * vy + vz * vz)
    p = jnp.maximum((gamma - 1.0) * (u[IEN] - ke), PRESSURE_FLOOR)
    return jnp.stack([rho, vx, vy, vz, p])


def conserved(w, gamma):
    """Primitive -> conserved (used by problem generators / tests)."""
    rho, vx, vy, vz, p = w[IDN], w[IVX], w[IVY], w[IVZ], w[IPR]
    e = p / (gamma - 1.0) + 0.5 * rho * (vx * vx + vy * vy + vz * vz)
    return jnp.stack([rho, rho * vx, rho * vy, rho * vz, e])


def sound_speed(w, gamma):
    return jnp.sqrt(gamma * w[IPR] / w[IDN])


def _shift(q, d, s):
    """q shifted by s cells along direction d: result[..., i] = q[..., i+s].

    Uses roll; the wrapped edge entries are never consumed (stencil stays
    NGHOST-deep inside the array bounds).
    """
    ax = _AXIS[d]
    return jnp.roll(q, -s, axis=ax)


def mc_slopes(w, d):
    """Monotonized-central limited slopes of primitives along direction d."""
    dqm = w - _shift(w, d, -1)  # q_i - q_{i-1}
    dqp = _shift(w, d, 1) - w   # q_{i+1} - q_i
    prod = dqm * dqp
    avg = 0.5 * (dqm + dqp)
    lim = jnp.sign(avg) * jnp.minimum(
        2.0 * jnp.minimum(jnp.abs(dqm), jnp.abs(dqp)), jnp.abs(avg)
    )
    return jnp.where(prod > 0.0, lim, 0.0).astype(w.dtype)


def _face_slice(q, d, lo, n_faces):
    """Cells [lo, lo+n_faces) along direction d of a [NVAR,Z,Y,X] array."""
    ax = _AXIS[d]
    idx = [slice(None)] * q.ndim
    idx[ax] = slice(lo, lo + n_faces)
    return q[tuple(idx)]


def reconstruct(w, d, n_int):
    """PLM interface states along d.

    Returns (wL, wR) at the n_int+1 faces bounding the interior cells:
    face f (f = 0..n_int) sits between cells (g-1+f) and (g+f).
    """
    g = NGHOST
    dq = mc_slopes(w, d)
    nf = n_int + 1
    w_l = _face_slice(w, d, g - 1, nf) + 0.5 * _face_slice(dq, d, g - 1, nf)
    w_r = _face_slice(w, d, g, nf) - 0.5 * _face_slice(dq, d, g, nf)
    return w_l, w_r


def euler_flux(w, d, gamma):
    """Analytic Euler flux of primitive state w along direction d."""
    rho, p = w[IDN], w[IPR]
    vn = w[1 + d]
    e = p / (gamma - 1.0) + 0.5 * rho * (
        w[IVX] * w[IVX] + w[IVY] * w[IVY] + w[IVZ] * w[IVZ]
    )
    f = [rho * vn]
    for comp in (IVX, IVY, IVZ):
        mom = rho * w[comp] * vn
        if comp == 1 + d:
            mom = mom + p
        f.append(mom)
    f.append((e + p) * vn)
    return jnp.stack(f)


def hlle_flux(w_l, w_r, d, gamma):
    """HLLE flux from left/right primitive interface states along d."""
    c_l = sound_speed(w_l, gamma)
    c_r = sound_speed(w_r, gamma)
    vn_l = w_l[1 + d]
    vn_r = w_r[1 + d]
    s_l = jnp.minimum(jnp.minimum(vn_l - c_l, vn_r - c_r), 0.0)
    s_r = jnp.maximum(jnp.maximum(vn_l + c_l, vn_r + c_r), 0.0)
    u_l = conserved(w_l, gamma)
    u_r = conserved(w_r, gamma)
    f_l = euler_flux(w_l, d, gamma)
    f_r = euler_flux(w_r, d, gamma)
    denom = s_r - s_l
    # s_r >= 0 >= s_l and s_r - s_l >= c_l + c_r > 0: no division hazard.
    return (s_r * f_l - s_l * f_r + s_l * s_r * (u_r - u_l)) / denom


def _interior(shape_zyx, dim, g=NGHOST):
    """Slices of the interior box for a [NVAR, Z, Y, X] array."""
    z, y, x = shape_zyx
    sz = slice(g, z - g) if dim >= 3 else slice(0, 1)
    sy = slice(g, y - g) if dim >= 2 else slice(0, 1)
    sx = slice(g, x - g)
    return (slice(None), sz, sy, sx)


def _n_int(shape_zyx, dim, g=NGHOST):
    zt, yt, xt = shape_zyx
    return {
        0: xt - 2 * g,
        1: (yt - 2 * g) if dim >= 2 else 1,
        2: (zt - 2 * g) if dim >= 3 else 1,
    }


def rhs(u, dim, dx, dy, dz, gamma):
    """-div(F) on the interior box. Returns [NVAR, nz, ny, nx]."""
    w = primitives(u, gamma)
    g = NGHOST
    n_int = _n_int(u.shape[1:], dim)
    inv_d = {0: 1.0 / dx, 1: 1.0 / dy, 2: 1.0 / dz}

    out = None
    for d in range(dim):
        w_l, w_r = reconstruct(w, d, n_int[d])
        f = hlle_flux(w_l, w_r, d, gamma)
        # f has n_int[d]+1 entries along direction d and FULL (ghosted)
        # extent along the other directions; restrict those to interior.
        idx = [slice(None)] * 4
        for dd in range(dim):
            if dd != d:
                idx[_AXIS[dd]] = slice(g, g + n_int[dd])
        f = f[tuple(idx)]
        ax = _AXIS[d]
        lo = [slice(None)] * 4
        hi = [slice(None)] * 4
        lo[ax] = slice(0, n_int[d])
        hi[ax] = slice(1, n_int[d] + 1)
        div = (f[tuple(hi)] - f[tuple(lo)]) * inv_d[d]
        out = div if out is None else out + div
    return -out


def stage(u, u0, scal, dim):
    """One RK stage. scal = [g0, g1, beta, dt, dx, dy, dz, gamma] (f32[8])."""
    g0, g1, beta, dt = scal[0], scal[1], scal[2], scal[3]
    dx, dy, dz, gamma = scal[4], scal[5], scal[6], scal[7]
    dudt = rhs(u, dim, dx, dy, dz, gamma)
    box = _interior(u.shape[1:], dim)
    u_new_int = g0 * u0[box] + g1 * u[box] + beta * dt * dudt
    return u.at[box].set(u_new_int)


def min_dt(u, scal, dim):
    """Per-block CFL limit min_d(dx_d / (|v_d| + c)) over interior cells.

    (The CFL safety factor is applied by the Rust coordinator.)
    """
    dx, dy, dz, gamma = scal[4], scal[5], scal[6], scal[7]
    box = _interior(u.shape[1:], dim)
    w = primitives(u[box], gamma)
    c = sound_speed(w, gamma)
    dt = dx / (jnp.abs(w[IVX]) + c)
    if dim >= 2:
        dt = jnp.minimum(dt, dy / (jnp.abs(w[IVY]) + c))
    if dim >= 3:
        dt = jnp.minimum(dt, dz / (jnp.abs(w[IVZ]) + c))
    return jnp.min(dt)


# ---------------------------------------------------------------------------
# Boundary-buffer pack / unpack ("fill-in-one": every segment in one kernel).
# ---------------------------------------------------------------------------

def _slab_slices(slab):
    (x0, x1), (y0, y1), (z0, z1) = slab
    return (slice(None), slice(z0, z1), slice(y0, y1), slice(x0, x1))


def pack_buffers(u, dim, n):
    """Extract all same-level send segments into one flat f32[BUFLEN]."""
    segs = []
    for o in bufspec.neighbors(dim):
        sl = _slab_slices(bufspec.send_slab(o, n, dim))
        segs.append(u[sl].reshape(-1))
    return jnp.concatenate(segs)


def pack_one_buffer(u, dim, n, nbr_idx):
    """Extract a single neighbor's send segment (the "original" per-buffer
    kernel regime of Fig. 8)."""
    o = bufspec.neighbors(dim)[nbr_idx]
    sl = _slab_slices(bufspec.send_slab(o, n, dim))
    return u[sl].reshape(-1)


def unpack_one_buffer(u, seg, dim, n, nbr_idx):
    """Apply a single neighbor's recv segment into its ghost region (the
    per-buffer unpack regime of Fig. 8)."""
    o = bufspec.neighbors(dim)[nbr_idx]
    slab = bufspec.recv_slab(o, n, dim)
    (x0, x1), (y0, y1), (z0, z1) = slab
    shp = (NVAR, z1 - z0, y1 - y0, x1 - x0)
    return u.at[_slab_slices(slab)].set(seg.reshape(shp))


def unpack_buffers(u, bufs, dim, n):
    """Write every recv segment of ``bufs`` into the ghost regions of u."""
    offset = 0
    for o in bufspec.neighbors(dim):
        slab = bufspec.recv_slab(o, n, dim)
        ln = NVAR * bufspec.slab_len(slab)
        seg = bufs[offset:offset + ln]
        offset += ln
        (x0, x1), (y0, y1), (z0, z1) = slab
        shp = (NVAR, z1 - z0, y1 - y0, x1 - x0)
        u = u.at[_slab_slices(slab)].set(seg.reshape(shp))
    return u


def fused_step(u, u0, bufs_in, scal, dim, n):
    """unpack -> stage -> pack -> dt, one executable (peak launch fusion).

    Returns (u_new, bufs_out, dt_min).
    """
    u = unpack_buffers(u, bufs_in, dim, n)
    u_new = stage(u, u0, scal, dim)
    bufs_out = pack_buffers(u_new, dim, n)
    dt = min_dt(u_new, scal, dim)
    return u_new, bufs_out, dt


# ---------------------------------------------------------------------------
# Multilevel boundary kernels (paper Sec. 3.7/3.8): restriction of
# fine->coarse boundary sends, slope-limited prolongation of coarse->fine
# ghost receipts, and tangential face-flux restriction for flux correction.
# Geometry comes from bufspec, which rust/src/bvals/exchange.rs mirrors.
# ---------------------------------------------------------------------------


def _halve(box, ax):
    """Average adjacent index pairs along axis `ax` (factor-2 restriction)."""
    shp = list(box.shape)
    shp[ax] //= 2
    shp.insert(ax + 1, 2)
    return box.reshape(shp).mean(axis=ax + 1)


def _minmod(a, b):
    return jnp.where(a * b > 0.0, jnp.where(jnp.abs(a) < jnp.abs(b), a, b), 0.0)


def restrict_send_segment(u, dim, n, nbr_idx):
    """Restrict the fine-send slab toward coarser neighbor `nbr_idx` into a
    flat [v, z, y, x] payload (conservative 2^dim averaging)."""
    o = bufspec.neighbors(dim)[nbr_idx]
    box = u[_slab_slices(bufspec.fine_send_slab(o, n, dim))]
    box = _halve(box, 3)
    if dim >= 2:
        box = _halve(box, 2)
    if dim >= 3:
        box = _halve(box, 1)
    return box.reshape(-1)


def _axis_slopes(c, ax):
    """Minmod-limited slopes along `ax`, zero at the array edges."""
    d = jnp.diff(c, axis=ax)
    zshape = list(c.shape)
    zshape[ax] = 1
    z = jnp.zeros(zshape, dtype=c.dtype)
    dm = jnp.concatenate([z, d], axis=ax)  # c[i] - c[i-1], 0 at lo edge
    dp = jnp.concatenate([d, z], axis=ax)  # c[i+1] - c[i], 0 at hi edge
    return _minmod(dm, dp)


def prolong_ghost_segment(u, seg, dim, n, nbr_idx, child, g=NGHOST):
    """Fill the ghost region on side `nbr_idx` from a coarse neighbor's
    prolongation payload `seg` (slope-limited linear interpolation at fine
    cell centers, slopes clamped at payload edges).

    `child` packs the fine block's per-axis logical-coordinate parity bits
    (bit0 = x, bit1 = y, bit2 = z) — the only part of the location the
    geometry depends on.  Returns the updated u.
    """
    o = bufspec.neighbors(dim)[nbr_idx]
    flx = [(child >> d) & 1 for d in range(3)]
    _, clo, cdims = bufspec.coarse_prolong_box(o, flx, n, dim, g)
    cx, cy, cz = cdims
    coarse = seg.reshape((NVAR, cz, cy, cx))
    ghost = bufspec.recv_slab(o, n, dim, g)

    # Static per-axis gather indices and fine-center offsets.
    owner, tsign = [], []
    for d in range(3):
        (lo, hi) = ghost[d]
        active = d == 0 or dim >= d + 1
        fine_lo = flx[d] * n[d] if active else 0
        gshift = g if active else 0
        idx, ts = [], []
        for i in range(lo, hi):
            gf = fine_lo + i - gshift
            idx.append(gf // 2 - clo[d] if active else 0)
            ts.append(-0.25 if gf % 2 == 0 else 0.25)
        owner.append(jnp.asarray(idx))
        tsign.append(jnp.asarray(ts, dtype=u.dtype))

    def gather(c):
        b = jnp.take(c, owner[2], axis=1)
        b = jnp.take(b, owner[1], axis=2)
        return jnp.take(b, owner[0], axis=3)

    val = gather(coarse)
    fz, fy, fx = val.shape[1:]
    val = val + tsign[0].reshape(1, 1, 1, fx) * gather(_axis_slopes(coarse, 3))
    if dim >= 2:
        val = val + tsign[1].reshape(1, 1, fy, 1) * gather(_axis_slopes(coarse, 2))
    if dim >= 3:
        val = val + tsign[2].reshape(1, fz, 1, 1) * gather(_axis_slopes(coarse, 1))
    return u.at[_slab_slices(ghost)].set(val)


def fluxcorr_face_restrict(face, dim):
    """Restrict one fine boundary-face flux plane (NVAR, T2, T1) onto the
    coarse face: mean of the 2x2 tangential fine faces (2 in 2D, identity
    in 1D), flattened [v, t2, t1]."""
    if dim >= 2:
        face = _halve(face, 2)
    if dim >= 3:
        face = _halve(face, 1)
    return face.reshape(-1)
