"""AOT pipeline: lower every artifact variant to HLO *text* + manifest.json.

HLO text (NOT ``lowered.compiler_ir().serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Run as ``python -m compile.aot --out ../artifacts`` (the Makefile
``artifacts`` target).  Python never runs again after this: the Rust
coordinator loads the manifest and compiles executables per rank at startup.
"""

import argparse
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import bufspec, model

# ---------------------------------------------------------------------------
# Variant table.  (kind, dim, n, nb, impl, nbr_idx)
# ---------------------------------------------------------------------------

KINDS = ("stage", "dt", "pack", "unpack", "fused")

# (dim, block interior size, pack sizes)
SHAPES_FULL = [
    # 3D cubes: Fig 8 sweep + Table 1/2 + weak/strong scaling blocks
    (3, (8, 8, 8), (1, 2, 4, 8, 16)),
    (3, (16, 16, 16), (1, 2, 4, 8, 16)),
    (3, (32, 32, 32), (1, 2, 4, 8, 16)),
    (3, (64, 64, 64), (1, 2, 4)),
    # 2D squares: quickstart / KH / e2e driver
    (2, (32, 32, 1), (1, 2, 4, 8, 16)),
    (2, (64, 64, 1), (1, 2, 4, 8, 16)),
    (2, (128, 128, 1), (1, 2, 4)),
    (2, (256, 256, 1), (1, 2)),
]

SHAPES_QUICK = [
    (3, (16, 16, 16), (1, 4)),
    (2, (32, 32, 1), (1, 4)),
]

# Per-neighbor pack/unpack kernels ("original" one-kernel-per-buffer regime,
# Fig 8): one launch per buffer per block for both fill and apply.
PACK1_SHAPES = [(3, (8, 8, 8)), (3, (16, 16, 16)), (3, (32, 32, 32)),
                (3, (64, 64, 64))]
PACK1_QUICK = [(3, (16, 16, 16))]

# Pallas-kernel variants (validation + Table 2 device row).
PALLAS_VARIANTS = [
    ("stage", 3, (16, 16, 16), 1),
    ("stage", 3, (16, 16, 16), 4),
    ("stage", 2, (64, 64, 1), 1),
    ("fused", 2, (64, 64, 1), 4),
]
PALLAS_QUICK = [("stage", 3, (16, 16, 16), 1)]

# Multilevel boundary kernels (restrict / prolong / fluxcorr): per-neighbor
# variants like pack1; prolong additionally varies with the fine block's
# child-parity bits, packed as nbr_idx * 8 + child (model.pack_prolong_nbr).
REFINE_SHAPES = [(2, (32, 32, 1)), (3, (16, 16, 16))]
REFINE_QUICK = [(2, (32, 32, 1))]


def variant_name(kind, dim, n, nb, impl, nbr_idx=None):
    nx, ny, nz = n
    s = f"{kind}_d{dim}_b{nx}x{ny}x{nz}_nb{nb}_{impl}"
    if nbr_idx is not None:
        s += f"_n{nbr_idx:02d}"
    return s


def variants(quick=False):
    shapes = SHAPES_QUICK if quick else SHAPES_FULL
    out = []
    for dim, n, nbs in shapes:
        for nb in nbs:
            for kind in KINDS:
                out.append((kind, dim, n, nb, "jnp", None))
    for dim, n in (PACK1_QUICK if quick else PACK1_SHAPES):
        for i in range(len(bufspec.neighbors(dim))):
            out.append(("pack1", dim, n, 1, "jnp", i))
            out.append(("unpack1", dim, n, 1, "jnp", i))
    for dim, n in (REFINE_QUICK if quick else REFINE_SHAPES):
        for i in range(len(bufspec.neighbors(dim))):
            out.append(("restrict", dim, n, 1, "jnp", i))
            for child in range(1 << dim):
                out.append(
                    ("prolong", dim, n, 1, "jnp", model.pack_prolong_nbr(i, child))
                )
        for d in range(dim):
            out.append(("fluxcorr", dim, n, 1, "jnp", d))
    for kind, dim, n, nb in (PALLAS_QUICK if quick else PALLAS_VARIANTS):
        out.append((kind, dim, n, nb, "pallas", None))
    return out


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(kind, dim, n, nb, impl, nbr_idx):
    fn = model.build(kind, nb, dim, n, impl=impl, nbr_idx=nbr_idx)
    specs = model.arg_specs(kind, nb, dim, n, nbr_idx=nbr_idx)
    return to_hlo_text(jax.jit(fn).lower(*specs))


def bufspec_tables(quick=False):
    """Segment tables for every distinct (dim, n), cross-checked by Rust."""
    seen = {}
    for kind, dim, n, nb, impl, nbr in variants(quick):
        key = (dim, n)
        if key in seen:
            continue
        seen[key] = {
            "dim": dim,
            "n": list(n),
            "neighbors": [list(o) for o in bufspec.neighbors(dim)],
            "seg_lens": bufspec.segment_lengths(n, dim),
            "buflen": bufspec.buflen(n, dim),
            "opposite": bufspec.opposite_index(dim),
            "total_shape": list(bufspec.total_shape(n, dim)),
            # fine->coarse restricted send lengths (multilevel exchange);
            # the Rust parser tolerates and cross-checks this table too.
            "restrict_seg_lens": bufspec.restrict_seg_lens(n, dim),
        }
    return list(seen.values())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="small variant subset (CI)")
    ap.add_argument("--only", default=None,
                    help="only lower variants whose name starts with this")
    args = ap.parse_args()

    quick = args.quick or os.environ.get("PARTHENON_AOT_QUICK") == "1"
    skip_existing = os.environ.get("PARTHENON_AOT_SKIP_EXISTING") == "1"

    os.makedirs(args.out, exist_ok=True)
    entries = []
    t0 = time.time()
    vs = variants(quick)
    for i, (kind, dim, n, nb, impl, nbr) in enumerate(vs):
        name = variant_name(kind, dim, n, nb, impl, nbr)
        fname = name + ".hlo.txt"
        path = os.path.join(args.out, fname)
        entry = {
            "name": name,
            "kind": kind,
            "dim": dim,
            "n": list(n),
            "nb": nb,
            "impl": impl,
            "file": fname,
            "buflen": bufspec.buflen(n, dim),
        }
        if nbr is not None:
            entry["nbr"] = nbr
        entries.append(entry)
        if args.only and not name.startswith(args.only):
            continue
        if skip_existing and os.path.exists(path):
            continue
        text = lower_variant(kind, dim, n, nb, impl, nbr)
        with open(path, "w") as f:
            f.write(text)
        if (i + 1) % 25 == 0 or i + 1 == len(vs):
            print(f"[aot] {i + 1}/{len(vs)} ({time.time() - t0:.1f}s) {name}",
                  flush=True)

    manifest = {
        "version": 1,
        "nghost": bufspec.NGHOST,
        "nvar": bufspec.NVAR,
        "scal_layout": ["g0", "g1", "beta", "dt", "dx", "dy", "dz", "gamma"],
        "quick": quick,
        "artifacts": entries,
        "bufspec": bufspec_tables(quick),
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(entries)} artifact entries "
          f"in {time.time() - t0:.1f}s -> {args.out}/manifest.json")
    return 0


if __name__ == "__main__":
    sys.exit(main())
