"""Analysis-side reader for parthenon-rs `pbin` snapshots.

The paper ships xdmf/yt frontends so external tools can read outputs
(Sec. 3.9); this module is the analog for the pbin format: it loads a
snapshot into numpy arrays and can assemble blocks into a single uniform
array (uniform meshes) or per-level collections (multilevel).

Usage:
    from tools.pbin_reader import Snapshot
    snap = Snapshot("out_quickstart/parthenon.00002.pbin")
    rho = snap.assemble_uniform("cons", component=0)

CLI: python -m tools.pbin_reader FILE [--var cons] [--comp 0] [--stats]
"""

import argparse
import json
import struct
import sys

import numpy as np

MAGIC = b"PBIN1\n"


class Snapshot:
    def __init__(self, path):
        with open(path, "rb") as f:
            data = f.read()
        if not data.startswith(MAGIC):
            raise ValueError(f"{path}: not a pbin file")
        (hlen,) = struct.unpack_from("<Q", data, len(MAGIC))
        off = len(MAGIC) + 8
        self.header = json.loads(data[off:off + hlen].decode())
        off += hlen

        self.time = self.header["time"]
        if "time_bits" in self.header:
            self.time = struct.unpack(
                ">d", bytes.fromhex(self.header["time_bits"])
            )[0]
        self.cycle = self.header["cycle"]
        self.dim = self.header["dim"]
        self.block_nx = self.header["block_nx"]
        self.leaves = [tuple(l) for l in self.header["leaves"]]  # (level,lx1,lx2,lx3)
        self.vars = [(v["name"], v["ncomp"]) for v in self.header["vars"]]

        zone = 1
        for d in range(3):
            n = self.block_nx[d] if (d == 0 or self.dim > d) else 1
            zone *= max(n, 1)
        self.zone = zone
        self._blocks = {}
        rec = 8 + 4 * sum(nc * zone for _, nc in self.vars)
        for gid in range(len(self.leaves)):
            base = off + gid * rec
            (stored,) = struct.unpack_from("<Q", data, base)
            if stored != gid:
                raise ValueError(f"gid mismatch at record {gid}")
            self._blocks[gid] = base + 8
        self._data = data

    def block_var(self, gid, var):
        """[ncomp, nz, ny, nx] interior array of one block."""
        off = self._blocks[gid]
        for name, nc in self.vars:
            nbytes = 4 * nc * self.zone
            if name == var:
                arr = np.frombuffer(self._data, dtype="<f4", count=nc * self.zone,
                                    offset=off)
                nx = self.block_nx[0]
                ny = self.block_nx[1] if self.dim >= 2 else 1
                nz = self.block_nx[2] if self.dim >= 3 else 1
                return arr.reshape(nc, nz, ny, nx)
            off += nbytes
        raise KeyError(var)

    def max_level(self):
        return max(l[0] for l in self.leaves)

    def assemble_uniform(self, var, component=0):
        """Stitch a uniform (single-level) mesh into one global array."""
        if self.max_level() != 0:
            raise ValueError("mesh is multilevel; use per-block access")
        nx, ny, nz = self.block_nx
        lx_max = [max(l[1 + d] for l in self.leaves) + 1 for d in range(3)]
        gz = max(nz, 1) * (lx_max[2] if self.dim >= 3 else 1)
        gy = max(ny, 1) * (lx_max[1] if self.dim >= 2 else 1)
        gx = nx * lx_max[0]
        out = np.zeros((gz, gy, gx), dtype=np.float32)
        for gid, (lev, l1, l2, l3) in enumerate(self.leaves):
            assert lev == 0
            blk = self.block_var(gid, var)[component]
            z0 = l3 * max(nz, 1) if self.dim >= 3 else 0
            y0 = l2 * max(ny, 1) if self.dim >= 2 else 0
            x0 = l1 * nx
            out[z0:z0 + blk.shape[0], y0:y0 + blk.shape[1], x0:x0 + blk.shape[2]] = blk
        return out

    def conserved_totals(self, var="cons"):
        """Per-component sums over all blocks, volume-weighted per level."""
        ncomp = dict(self.vars)[var]
        totals = np.zeros(ncomp, dtype=np.float64)
        for gid, (lev, *_rest) in enumerate(self.leaves):
            w = 0.5 ** (self.dim * lev)  # relative cell volume
            blk = self.block_var(gid, var)
            totals += blk.reshape(ncomp, -1).sum(axis=1, dtype=np.float64) * w
        return totals


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("file")
    ap.add_argument("--var", default="cons")
    ap.add_argument("--comp", type=int, default=0)
    ap.add_argument("--stats", action="store_true")
    args = ap.parse_args()
    snap = Snapshot(args.file)
    print(f"time {snap.time:.6e}  cycle {snap.cycle}  dim {snap.dim}  "
          f"blocks {len(snap.leaves)}  max level {snap.max_level()}")
    if args.stats:
        vals = [snap.block_var(g, args.var)[args.comp] for g in range(len(snap.leaves))]
        allv = np.concatenate([v.ravel() for v in vals])
        print(f"{args.var}[{args.comp}]: min {allv.min():.6e}  max {allv.max():.6e}  "
              f"mean {allv.mean():.6e}")
        print("conserved totals:", snap.conserved_totals(args.var))
    return 0


if __name__ == "__main__":
    sys.exit(main())
