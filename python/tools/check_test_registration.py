#!/usr/bin/env python3
"""CI guard: Rust integration tests must be fully registered.

Because Cargo.toml uses explicit ``[[test]]`` sections (the test sources
live under ``rust/tests/``, not the default ``tests/``), a new test file
that is never registered silently never runs.  Likewise a rank-guarded
test (one calling ``multi_rank_enabled``) that is missing from the
ci.yml multi-rank ``cargo test`` step silently runs single-rank only.

Rules enforced:

1. every ``rust/tests/*.rs`` file has a ``[[test]]`` entry in Cargo.toml
   whose ``name`` is the file stem and whose ``path`` points at the file;
2. every ``[[test]]`` entry's path exists (no stale registrations);
3. every test file whose source mentions ``multi_rank_enabled`` appears
   as a ``--test <name>`` token in .github/workflows/ci.yml;
4. every ``--test <name>`` token in ci.yml names a registered test.

stdlib-only on purpose: the Rust CI job has no pip dependencies.
"""

import argparse
import os
import re
import sys

RANK_GUARD = "multi_rank_enabled"


def cargo_test_entries(text):
    """Parse ``[[test]]`` sections out of Cargo.toml -> {name: path}."""
    entries = {}
    section = None  # fields of the [[test]] section being read, else None
    for raw in text.splitlines():
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        if line.startswith("["):
            if section is not None and "name" in section:
                entries[section["name"]] = section.get("path", "")
            section = {} if line == "[[test]]" else None
            continue
        if section is not None and "=" in line:
            key, _, val = line.partition("=")
            section[key.strip()] = val.strip().strip('"')
    if section is not None and "name" in section:
        entries[section["name"]] = section.get("path", "")
    return entries


def ci_test_tokens(text):
    """Every ``--test <name>`` token appearing in the workflow file."""
    return set(re.findall(r"--test\s+([A-Za-z0-9_-]+)", text))


def is_rank_guarded(path):
    with open(path) as f:
        return RANK_GUARD in f.read()


def check(repo_root):
    """Return a list of violation messages (empty == all registered)."""
    problems = []
    cargo_path = os.path.join(repo_root, "Cargo.toml")
    ci_path = os.path.join(repo_root, ".github", "workflows", "ci.yml")
    tests_dir = os.path.join(repo_root, "rust", "tests")

    with open(cargo_path) as f:
        entries = cargo_test_entries(f.read())
    with open(ci_path) as f:
        ci_tokens = ci_test_tokens(f.read())

    by_path = {p: n for n, p in entries.items()}
    for fname in sorted(os.listdir(tests_dir)):
        if not fname.endswith(".rs"):
            continue
        stem = fname[: -len(".rs")]
        rel = f"rust/tests/{fname}"
        if rel not in by_path:
            problems.append(
                f"{rel}: no [[test]] entry in Cargo.toml (add name = "
                f'"{stem}", path = "{rel}")'
            )
            continue
        if by_path[rel] != stem:
            problems.append(
                f"{rel}: [[test]] name {by_path[rel]!r} != file stem {stem!r}"
            )
        if is_rank_guarded(os.path.join(tests_dir, fname)) and stem not in ci_tokens:
            problems.append(
                f"{rel}: calls {RANK_GUARD} but is missing from the ci.yml "
                f"multi-rank step (add --test {stem})"
            )

    for name, path in sorted(entries.items()):
        if not os.path.exists(os.path.join(repo_root, path)):
            problems.append(f"Cargo.toml [[test]] {name}: path {path!r} not found")

    for tok in sorted(ci_tokens):
        if tok not in entries:
            problems.append(f"ci.yml: --test {tok} is not a registered [[test]]")

    return problems


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "root",
        nargs="?",
        default=os.path.join(os.path.dirname(__file__), "..", ".."),
        help="repository root (default: inferred from this file)",
    )
    args = ap.parse_args(argv)
    problems = check(os.path.abspath(args.root))
    if problems:
        print("test registration check FAILED:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print("test registration check OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
