"""Performance-regression comparator — the analog of the paper's Parthenon
Performance Metrics App (PPMA, Sec. 6.2.3): compare a fresh
`bench_results/` directory against a stored baseline and flag regressions.

Usage:
    python -m tools.perf_compare baseline_dir current_dir [--tol 0.15]
    python -m tools.perf_compare --snapshot bench_results baselines/$(git id)

Exit code 1 if any sample regressed beyond tolerance.
"""

import argparse
import json
import os
import shutil
import sys


def load(dirpath):
    out = {}
    for fn in sorted(os.listdir(dirpath)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(dirpath, fn)) as f:
            doc = json.load(f)
        for s in doc.get("samples", []):
            out[f"{doc['name']}/{s['label']}"] = s["throughput"]
    return out


def compare(baseline, current, tol):
    base = load(baseline)
    cur = load(current)
    regressions = []
    improvements = []
    for key in sorted(base):
        if key not in cur:
            print(f"  MISSING {key}")
            continue
        b, c = base[key], cur[key]
        if b <= 0:
            continue
        ratio = c / b
        marker = ""
        if ratio < 1.0 - tol:
            marker = "  <-- REGRESSION"
            regressions.append((key, ratio))
        elif ratio > 1.0 + tol:
            marker = "  (improved)"
            improvements.append((key, ratio))
        print(f"  {key:55} {b:10.3e} -> {c:10.3e}  ({ratio:5.2f}x){marker}")
    return regressions, improvements


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("current", nargs="?")
    ap.add_argument("--tol", type=float, default=0.15,
                    help="relative slowdown tolerated before flagging")
    ap.add_argument("--snapshot", action="store_true",
                    help="copy baseline(arg1=src) to current(arg2=dst) and exit")
    args = ap.parse_args()

    if args.snapshot:
        if args.current is None:
            ap.error("--snapshot needs src and dst")
        os.makedirs(args.current, exist_ok=True)
        for fn in os.listdir(args.baseline):
            if fn.endswith(".json"):
                shutil.copy(os.path.join(args.baseline, fn), args.current)
        print(f"snapshotted {args.baseline} -> {args.current}")
        return 0

    if args.current is None:
        ap.error("need baseline and current directories")
    regressions, improvements = compare(args.baseline, args.current, args.tol)
    print(f"\n{len(regressions)} regressions, {len(improvements)} improvements "
          f"(tol {args.tol:.0%})")
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
