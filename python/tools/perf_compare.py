"""Performance-regression comparator — the analog of the paper's Parthenon
Performance Metrics App (PPMA, Sec. 6.2.3): compare a fresh
`bench_results/` directory against a stored baseline and flag regressions.

Usage:
    python -m tools.perf_compare baseline_dir current_dir [--tol 0.15]
    python -m tools.perf_compare --snapshot bench_results baselines/$(git id)

Exit code 1 if any sample regressed beyond tolerance. When running under
GitHub Actions (``$GITHUB_STEP_SUMMARY`` set) the comparison is also
appended to the job's step summary as a markdown table.
"""

import argparse
import json
import os
import shutil
import sys


def load(dirpath):
    out = {}
    for fn in sorted(os.listdir(dirpath)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(dirpath, fn)) as f:
            doc = json.load(f)
        for s in doc.get("samples", []):
            out[f"{doc['name']}/{s['label']}"] = s["throughput"]
    return out


def compare(baseline, current, tol):
    """Returns (rows, regressions, improvements); rows are
    (key, base, cur, ratio, status). cur/ratio are None for samples
    missing from the current run or with an unusable (non-positive)
    baseline — both get their own status so they never vanish silently."""
    base = load(baseline)
    cur = load(current)
    rows = []
    regressions = []
    improvements = []
    for key in sorted(base):
        if key not in cur:
            print(f"  MISSING {key}")
            rows.append((key, base[key], None, None, "missing"))
            continue
        b, c = base[key], cur[key]
        if b <= 0:
            print(f"  BAD-BASELINE {key} ({b!r})")
            rows.append((key, b, c, None, "bad-baseline"))
            continue
        ratio = c / b
        marker = ""
        status = "ok"
        if ratio < 1.0 - tol:
            marker = "  <-- REGRESSION"
            status = "regression"
            regressions.append((key, ratio))
        elif ratio > 1.0 + tol:
            marker = "  (improved)"
            status = "improved"
            improvements.append((key, ratio))
        print(f"  {key:55} {b:10.3e} -> {c:10.3e}  ({ratio:5.2f}x){marker}")
        rows.append((key, b, c, ratio, status))
    return rows, regressions, improvements


STATUS_MARK = {
    "ok": "✅ ok",
    "improved": "🚀 improved",
    "regression": "❌ regression",
    "missing": "⚠️ missing",
    "bad-baseline": "⚠️ bad baseline",
}


def write_step_summary(rows, tol, regressions, improvements):
    """Append a markdown table to $GITHUB_STEP_SUMMARY (no-op outside CI)."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    with open(path, "a") as f:
        f.write("## Perf baseline comparison\n\n")
        f.write(
            f"{len(regressions)} regressions, {len(improvements)} improvements "
            f"across {len(rows)} samples (tolerance ±{tol:.0%})\n\n"
        )
        f.write("| sample | baseline | current | ratio | status |\n")
        f.write("|---|---:|---:|---:|---|\n")
        for key, b, c, ratio, status in rows:
            cur_s = f"{c:.3e}" if c is not None else "—"
            ratio_s = f"{ratio:.2f}x" if ratio is not None else "—"
            f.write(
                f"| `{key}` | {b:.3e} | {cur_s} | {ratio_s} "
                f"| {STATUS_MARK.get(status, status)} |\n"
            )
        f.write("\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("current", nargs="?")
    ap.add_argument("--tol", type=float, default=0.15,
                    help="relative slowdown tolerated before flagging")
    ap.add_argument("--snapshot", action="store_true",
                    help="copy baseline(arg1=src) to current(arg2=dst) and exit")
    args = ap.parse_args()

    if args.snapshot:
        if args.current is None:
            ap.error("--snapshot needs src and dst")
        os.makedirs(args.current, exist_ok=True)
        for fn in os.listdir(args.baseline):
            if fn.endswith(".json"):
                shutil.copy(os.path.join(args.baseline, fn), args.current)
        print(f"snapshotted {args.baseline} -> {args.current}")
        return 0

    if args.current is None:
        ap.error("need baseline and current directories")
    rows, regressions, improvements = compare(args.baseline, args.current, args.tol)
    print(f"\n{len(regressions)} regressions, {len(improvements)} improvements "
          f"(tol {args.tol:.0%})")
    write_step_summary(rows, args.tol, regressions, improvements)
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
