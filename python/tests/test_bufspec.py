"""Invariants of the boundary-buffer layout contract (bufspec)."""

import numpy as np
import pytest

from compile import bufspec


@pytest.mark.parametrize("dim,count", [(1, 2), (2, 8), (3, 26)])
def test_neighbor_count(dim, count):
    ns = bufspec.neighbors(dim)
    assert len(ns) == count
    assert len(set(ns)) == count
    for o in ns:
        assert o != (0, 0, 0)
        if dim < 3:
            assert o[2] == 0
        if dim < 2:
            assert o[1] == 0


@pytest.mark.parametrize("dim", [1, 2, 3])
def test_opposite_is_involution(dim):
    ns = bufspec.neighbors(dim)
    opp = bufspec.opposite_index(dim)
    for i, o in enumerate(ns):
        assert ns[opp[i]] == (-o[0], -o[1], -o[2])
        assert opp[opp[i]] == i


@pytest.mark.parametrize("dim,n", [(2, (8, 8, 1)), (2, (16, 8, 1)),
                                   (3, (8, 8, 8)), (3, (16, 8, 4))])
def test_send_recv_shapes_match(dim, n):
    """A's send slab for o must be congruent to B's recv slab for -o."""
    for o in bufspec.neighbors(dim):
        s = bufspec.send_slab(o, n, dim)
        r = bufspec.recv_slab((-o[0], -o[1], -o[2]), n, dim)
        sdims = [hi - lo for lo, hi in s]
        rdims = [hi - lo for lo, hi in r]
        assert sdims == rdims, (o, s, r)


@pytest.mark.parametrize("dim,n", [(2, (8, 8, 1)), (3, (8, 8, 8)),
                                   (3, (16, 8, 4))])
def test_recv_slabs_tile_ghost_shell_exactly(dim, n):
    """The recv slabs cover every ghost cell exactly once, no interior."""
    zt, yt, xt = bufspec.total_shape(n, dim)
    cover = np.zeros((zt, yt, xt), dtype=int)
    for o in bufspec.neighbors(dim):
        (x0, x1), (y0, y1), (z0, z1) = bufspec.recv_slab(o, n, dim)
        cover[z0:z1, y0:y1, x0:x1] += 1
    g = bufspec.NGHOST
    # interior must be untouched, ghosts exactly once
    izlo = g if dim >= 3 else 0
    izhi = zt - g if dim >= 3 else zt
    iylo = g if dim >= 2 else 0
    iyhi = yt - g if dim >= 2 else yt
    inner = cover[izlo:izhi, iylo:iyhi, g:xt - g]
    assert (inner == 0).all()
    total_ghost = zt * yt * xt - inner.size
    assert int(cover.sum()) == total_ghost
    assert cover.max() == 1


@pytest.mark.parametrize("dim,n", [(2, (8, 8, 1)), (3, (8, 8, 8))])
def test_buflen_consistency(dim, n):
    lens = bufspec.segment_lengths(n, dim)
    assert sum(lens) == bufspec.buflen(n, dim)
    assert all(l > 0 for l in lens)


def test_buflen_known_value():
    # 3D 16^3, g=2: faces 6*(2*16*16), edges 12*(2*2*16), corners 8*(2*2*2)
    per_var = 6 * 2 * 16 * 16 + 12 * 4 * 16 + 8 * 8
    assert bufspec.buflen((16, 16, 16), 3) == 5 * per_var
