"""AOT pipeline: variant table sanity and a real lowering round-trip."""

import json
import os

import pytest

from compile import aot, bufspec


def test_variant_names_unique():
    vs = aot.variants(quick=False)
    names = [aot.variant_name(k, d, n, nb, impl, nbr)
             for (k, d, n, nb, impl, nbr) in vs]
    assert len(names) == len(set(names))
    assert len(names) > 200  # full set is a real sweep


def test_quick_subset_is_subset_shapes():
    vq = aot.variants(quick=True)
    assert 0 < len(vq) < len(aot.variants(quick=False))


def test_bufspec_tables_complete():
    tables = aot.bufspec_tables(quick=False)
    keys = {(t["dim"], tuple(t["n"])) for t in tables}
    for (k, d, n, nb, impl, nbr) in aot.variants(quick=False):
        assert (d, tuple(n)) in keys
    for t in tables:
        assert t["buflen"] == sum(t["seg_lens"])
        assert len(t["neighbors"]) == len(t["seg_lens"])
        assert t["total_shape"] == list(
            bufspec.total_shape(tuple(t["n"]), t["dim"]))


def test_lower_one_variant_produces_hlo_text():
    text = aot.lower_variant("dt", 3, (8, 8, 8), 1, "jnp", None)
    assert "ENTRY" in text and "HloModule" in text


def test_manifest_on_disk_if_built():
    """If `make artifacts` has run, the manifest must be self-consistent."""
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                        "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        m = json.load(f)
    assert m["nghost"] == bufspec.NGHOST
    assert m["nvar"] == bufspec.NVAR
    names = [a["name"] for a in m["artifacts"]]
    assert len(names) == len(set(names))
    adir = os.path.dirname(path)
    for a in m["artifacts"]:
        assert os.path.exists(os.path.join(adir, a["file"])), a["name"]
