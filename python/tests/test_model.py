"""L2 model assembly: fused executable equivalence, batching, conservation."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import bufspec, model
from compile.kernels import ref

GAMMA = 5.0 / 3.0


def random_state(rng, dim, n, nb=1, amp=0.05):
    zyx = bufspec.total_shape(n, dim)
    u = np.zeros((nb, 5) + zyx, np.float32)
    u[:, 0] = 1.0
    u[:, 4] = 1.0 / (GAMMA - 1.0)
    u += rng.normal(0.0, amp, u.shape).astype(np.float32)
    u[:, 0] = np.maximum(u[:, 0], 0.2)
    u[:, 4] = np.maximum(u[:, 4], 0.5)
    return u


def scal_vec(**kw):
    d = dict(g0=0.5, g1=0.5, beta=0.5, dt=1e-3, dx=0.05, dy=0.05, dz=0.05,
             gamma=GAMMA)
    d.update(kw)
    return np.array([d["g0"], d["g1"], d["beta"], d["dt"], d["dx"], d["dy"],
                     d["dz"], d["gamma"]], np.float32)


@pytest.mark.parametrize("dim,n,nb", [(3, (8, 8, 8), 3), (2, (16, 16, 1), 2)])
def test_fused_equals_composition(dim, n, nb):
    rng = np.random.default_rng(42)
    u = random_state(rng, dim, n, nb)
    bufs = rng.normal(1.0, 0.02,
                      (nb, bufspec.buflen(n, dim))).astype(np.float32)
    scal = scal_vec()

    u_unp = np.asarray(model.build("unpack", nb, dim, n)(u, bufs)[0])
    u_stg = np.asarray(model.build("stage", nb, dim, n)(u_unp, u, scal)[0])
    b_out = np.asarray(model.build("pack", nb, dim, n)(u_stg)[0])
    dts = np.asarray(model.build("dt", nb, dim, n)(u_stg, scal)[0])

    fu, fb, fdt = model.build("fused", nb, dim, n)(u, u, bufs, scal)
    np.testing.assert_allclose(np.asarray(fu), u_stg, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(fb), b_out, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(fdt), dts, rtol=1e-6, atol=1e-7)


def test_batching_matches_per_block():
    """A pack of nb blocks gives bit-identical results to nb separate calls."""
    rng = np.random.default_rng(9)
    dim, n, nb = 3, (8, 8, 8), 4
    u = random_state(rng, dim, n, nb)
    scal = scal_vec()
    batched = np.asarray(model.build("stage", nb, dim, n)(u, u, scal)[0])
    single = model.build("stage", 1, dim, n)
    for b in range(nb):
        one = np.asarray(single(u[b:b + 1], u[b:b + 1], scal)[0])
        np.testing.assert_array_equal(batched[b:b + 1], one)


def test_pack1_segments_concatenate_to_pack():
    rng = np.random.default_rng(13)
    dim, n = 3, (8, 8, 8)
    u = random_state(rng, dim, n, 1)
    full = np.asarray(model.build("pack", 1, dim, n)(u)[0])[0]
    segs = []
    for i in range(len(bufspec.neighbors(dim))):
        segs.append(np.asarray(model.build("pack1", 1, dim, n,
                                           nbr_idx=i)(u)[0])[0])
    np.testing.assert_array_equal(np.concatenate(segs), full)


def test_interior_conservation_with_periodic_ghosts():
    """With consistent periodic ghosts, a stage conserves total interior
    mass/momentum/energy to f32 roundoff (flux-divergence telescopes)."""
    rng = np.random.default_rng(21)
    dim, n = 2, (16, 16, 1)
    u = random_state(rng, dim, n, 1)[0]
    g = bufspec.NGHOST
    nx, ny, _ = n

    def wrap_axis(a, axis, n_int):
        idx = np.r_[np.arange(n_int, n_int + g),
                    np.arange(g, g + n_int),
                    np.arange(g, 2 * g)]
        return np.take(a, idx, axis=axis)

    u = wrap_axis(wrap_axis(u, 3, nx), 2, ny)
    scal = scal_vec(g0=0.0, g1=1.0, beta=1.0)
    out = np.asarray(ref.stage(jnp.asarray(u), jnp.asarray(u),
                               jnp.asarray(scal), dim))
    box = (slice(None), slice(0, 1), slice(g, g + ny), slice(g, g + nx))
    before = u[box].astype(np.float64).sum(axis=(1, 2, 3))
    after = out[box].astype(np.float64).sum(axis=(1, 2, 3))
    np.testing.assert_allclose(after, before, rtol=2e-5)


def test_arg_specs_cover_all_kinds():
    for kind in ("stage", "dt", "pack", "unpack", "fused", "pack1"):
        specs = model.arg_specs(kind, 2, 3, (8, 8, 8))
        assert all(s.dtype == np.float32 for s in specs)
    with pytest.raises(ValueError):
        model.arg_specs("nope", 1, 3, (8, 8, 8))
