"""Multilevel boundary kernels (restrict / prolong / fluxcorr) vs numpy
references, plus invariants of the fine<->coarse bufspec geometry that
rust/src/bvals/exchange.rs mirrors."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import bufspec, model
from compile.bufspec import NGHOST, NVAR
from compile.kernels import ref

G = NGHOST


def random_u(rng, dim, n):
    zyx = bufspec.total_shape(n, dim)
    return rng.normal(0.0, 1.0, (NVAR,) + zyx).astype(np.float32)


def children(dim):
    return range(1 << dim)


# ---------------------------------------------------------------------------
# Geometry invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dim,n", [(1, (8, 1, 1)), (2, (8, 8, 1)), (3, (8, 8, 8))])
def test_fine_send_slab_even_in_active_axes(dim, n):
    for o in bufspec.neighbors(dim):
        slab = bufspec.fine_send_slab(o, n, dim)
        for d in range(dim):
            lo, hi = slab[d]
            assert (hi - lo) % 2 == 0, (o, d)


@pytest.mark.parametrize("dim,n", [(2, (8, 8, 1)), (3, (8, 8, 8))])
def test_restrict_seg_lens_match_recv_boxes(dim, n):
    """The restricted fine->coarse payload must exactly fill the coarse
    receive box, for every neighbor offset and child parity."""
    lens = bufspec.restrict_seg_lens(n, dim)
    for i, o in enumerate(bufspec.neighbors(dim)):
        for child in children(dim):
            flx = [(child >> d) & 1 for d in range(3)]
            box = bufspec.coarse_recv_restriction_box(o, flx, n, dim)
            assert NVAR * bufspec.slab_len(box) == lens[i], (o, child)


@pytest.mark.parametrize("dim,n", [(2, (8, 8, 1)), (3, (8, 8, 8))])
def test_prolong_box_consistency(dim, n):
    """The coarse sender's local slab and the advertised (clo, cdims) agree,
    the box stays inside the coarse block, and it covers every coarse cell
    owning a fine ghost cell."""
    for i, o in enumerate(bufspec.neighbors(dim)):
        for child in children(dim):
            flx = [(child >> d) & 1 for d in range(3)]
            local, clo, cdims = bufspec.coarse_prolong_box(o, flx, n, dim)
            assert NVAR * bufspec.slab_len(local) == model.prolong_seg_len(
                dim, n, i, child
            )
            ghost = bufspec.recv_slab(o, n, dim)
            for d in range(dim):
                lo, hi = local[d]
                assert hi - lo == cdims[d]
                # within the coarse block's ghosted array
                assert G <= lo and hi <= G + n[d]
                # every fine ghost cell's owner is inside the box
                for f in range(ghost[d][0], ghost[d][1]):
                    gf = flx[d] * n[d] + f - G
                    c = gf // 2 - clo[d]
                    assert 0 <= c < cdims[d], (o, child, d, f)


# ---------------------------------------------------------------------------
# Restriction kernel
# ---------------------------------------------------------------------------

def np_restrict(u, dim, n, nbr_idx):
    o = bufspec.neighbors(dim)[nbr_idx]
    (x0, x1), (y0, y1), (z0, z1) = bufspec.fine_send_slab(o, n, dim)
    box = u[:, z0:z1, y0:y1, x0:x1].astype(np.float64)
    v, z, y, x = box.shape
    box = box.reshape(v, z, y, x // 2, 2).mean(-1)
    if dim >= 2:
        box = box.reshape(v, z, y // 2, 2, x // 2).mean(3)
    if dim >= 3:
        box = box.reshape(v, z // 2, 2, box.shape[2], box.shape[3]).mean(2)
    return box.reshape(-1)


@pytest.mark.parametrize("dim,n", [(2, (8, 8, 1)), (3, (8, 8, 8))])
def test_restrict_matches_numpy(dim, n):
    rng = np.random.default_rng(7)
    u = random_u(rng, dim, n)
    lens = bufspec.restrict_seg_lens(n, dim)
    for i in range(len(bufspec.neighbors(dim))):
        got = np.asarray(ref.restrict_send_segment(u, dim, n, i))
        assert got.shape == (lens[i],)
        np.testing.assert_allclose(
            got, np_restrict(u, dim, n, i), rtol=1e-5, atol=1e-6
        )


def test_restrict_constant_preserved():
    dim, n = 2, (8, 8, 1)
    zyx = bufspec.total_shape(n, dim)
    u = np.full((NVAR,) + zyx, 3.25, np.float32)
    for i in range(len(bufspec.neighbors(dim))):
        got = np.asarray(ref.restrict_send_segment(u, dim, n, i))
        np.testing.assert_allclose(got, 3.25, rtol=1e-6)


# ---------------------------------------------------------------------------
# Prolongation kernel
# ---------------------------------------------------------------------------

def np_prolong(u, seg, dim, n, nbr_idx, child):
    """Scalar-loop reference of prolongate_ghost_slab (exchange.rs)."""
    o = bufspec.neighbors(dim)[nbr_idx]
    flx = [(child >> d) & 1 for d in range(3)]
    _, clo, cdims = bufspec.coarse_prolong_box(o, flx, n, dim)
    cx, cy, cz = cdims
    coarse = np.asarray(seg, np.float64).reshape(NVAR, cz, cy, cx)
    (x0, x1), (y0, y1), (z0, z1) = bufspec.recv_slab(o, n, dim)
    out = u.astype(np.float64).copy()

    def minmod(a, b):
        if a * b > 0:
            return a if abs(a) < abs(b) else b
        return 0.0

    for v in range(NVAR):
        for k in range(z0, z1):
            for j in range(y0, y1):
                for i in range(x0, x1):
                    gf = [
                        flx[0] * n[0] + i - G,
                        flx[1] * n[1] + j - (G if dim >= 2 else 0),
                        flx[2] * n[2] + k - (G if dim >= 3 else 0),
                    ]
                    c = [
                        gf[0] // 2 - clo[0],
                        gf[1] // 2 - clo[1] if dim >= 2 else 0,
                        gf[2] // 2 - clo[2] if dim >= 3 else 0,
                    ]
                    center = coarse[v, c[2], c[1], c[0]]
                    val = center
                    for d in range(dim):
                        ext, cc = cdims[d], c[d]
                        slope = 0.0
                        if 0 < cc < ext - 1:
                            idx_m = list(c)
                            idx_p = list(c)
                            idx_m[d] -= 1
                            idx_p[d] += 1
                            dm = center - coarse[v, idx_m[2], idx_m[1], idx_m[0]]
                            dp = coarse[v, idx_p[2], idx_p[1], idx_p[0]] - center
                            slope = minmod(dm, dp)
                        t = -0.25 if gf[d] % 2 == 0 else 0.25
                        val += slope * t
                    out[v, k, j, i] = val
    return out


@pytest.mark.parametrize("dim,n", [(2, (8, 8, 1)), (3, (4, 4, 4))])
def test_prolong_matches_numpy(dim, n):
    rng = np.random.default_rng(11)
    for i in range(len(bufspec.neighbors(dim))):
        for child in children(dim):
            u = random_u(rng, dim, n)
            seg_len = model.prolong_seg_len(dim, n, i, child)
            seg = rng.normal(0.0, 1.0, seg_len).astype(np.float32)
            got = np.asarray(
                ref.prolong_ghost_segment(jnp.asarray(u), seg, dim, n, i, child)
            )
            want = np_prolong(u, seg, dim, n, i, child)
            np.testing.assert_allclose(got, want, rtol=3e-6, atol=1e-6)


def test_prolong_constant_exact():
    dim, n = 2, (8, 8, 1)
    rng = np.random.default_rng(3)
    for i in range(len(bufspec.neighbors(dim))):
        u = random_u(rng, dim, n)
        seg_len = model.prolong_seg_len(dim, n, i, 0)
        seg = np.full(seg_len, 1.75, np.float32)
        got = np.asarray(ref.prolong_ghost_segment(jnp.asarray(u), seg, dim, n, i, 0))
        o = bufspec.neighbors(dim)[i]
        (x0, x1), (y0, y1), (z0, z1) = bufspec.recv_slab(o, n, dim)
        np.testing.assert_allclose(got[:, z0:z1, y0:y1, x0:x1], 1.75, rtol=1e-6)
        # cells outside the ghost slab are untouched
        mask = np.ones(got.shape, bool)
        mask[:, z0:z1, y0:y1, x0:x1] = False
        np.testing.assert_array_equal(got[mask], u[mask])


# ---------------------------------------------------------------------------
# Flux-correction face restriction
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dim,n", [(1, (8, 1, 1)), (2, (8, 8, 1)), (3, (8, 8, 8))])
def test_fluxcorr_matches_numpy(dim, n):
    rng = np.random.default_rng(5)
    for d in range(dim):
        shape = model.fluxcorr_face_shape(dim, n, d)
        face = rng.normal(0.0, 1.0, shape).astype(np.float32)
        got = np.asarray(ref.fluxcorr_face_restrict(face, dim))
        want = face.astype(np.float64)
        v, t2, t1 = want.shape
        if dim >= 2:
            want = want.reshape(v, t2, t1 // 2, 2).mean(-1)
        if dim >= 3:
            want = want.reshape(v, t2 // 2, 2, want.shape[2]).mean(2)
        np.testing.assert_allclose(got, want.reshape(-1), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# model-layer plumbing (batched builders + specs)
# ---------------------------------------------------------------------------

def test_model_builders_run_batched():
    dim, n, nb = 2, (8, 8, 1), 3
    rng = np.random.default_rng(13)
    u = np.stack([random_u(rng, dim, n) for _ in range(nb)])

    i = 0  # neighbor (-1, 0, 0)
    restrict = model.build("restrict", nb, dim, n, nbr_idx=i)
    (seg,) = restrict(u)
    assert seg.shape == (nb, bufspec.restrict_seg_lens(n, dim)[i])

    code = model.pack_prolong_nbr(i, 1)
    prolong = model.build("prolong", nb, dim, n, nbr_idx=code)
    seg_len = model.prolong_seg_len(dim, n, i, 1)
    segs = rng.normal(0.0, 1.0, (nb, seg_len)).astype(np.float32)
    (u2,) = prolong(u, segs)
    assert u2.shape == u.shape

    fluxcorr = model.build("fluxcorr", nb, dim, n, nbr_idx=0)
    shape = model.fluxcorr_face_shape(dim, n, 0)
    face = rng.normal(0.0, 1.0, (nb,) + shape).astype(np.float32)
    (out,) = fluxcorr(face)
    assert out.shape == (nb, NVAR * (n[1] // 2))


def test_arg_specs_cover_new_kinds():
    dim, n, nb = 2, (8, 8, 1), 2
    assert len(model.arg_specs("restrict", nb, dim, n, nbr_idx=0)) == 1
    code = model.pack_prolong_nbr(3, 2)
    u, seg = model.arg_specs("prolong", nb, dim, n, nbr_idx=code)
    assert seg.shape == (nb, model.prolong_seg_len(dim, n, 3, 2))
    (face,) = model.arg_specs("fluxcorr", nb, dim, n, nbr_idx=1)
    assert face.shape == (nb,) + model.fluxcorr_face_shape(dim, n, 1)


def test_aot_variant_table_includes_refine_kinds():
    from compile import aot

    vs = aot.variants(quick=True)
    kinds = {v[0] for v in vs}
    assert {"restrict", "prolong", "fluxcorr"} <= kinds
    tables = aot.bufspec_tables(quick=True)
    for t in tables:
        assert t["restrict_seg_lens"] == bufspec.restrict_seg_lens(
            tuple(t["n"]), t["dim"]
        )
