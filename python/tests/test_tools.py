"""Tests for the analysis tools: pbin reader + perf comparator."""

import json
import os
import struct
import subprocess
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from tools import check_test_registration as reg  # noqa: E402
from tools import perf_compare  # noqa: E402
from tools.pbin_reader import MAGIC, Snapshot  # noqa: E402


def make_pbin(path, dim=2, block_nx=(4, 4, 1), leaves=None, time=0.5, cycle=7):
    """Hand-roll a pbin file matching rust/src/io/mod.rs."""
    if leaves is None:
        leaves = [(0, 0, 0, 0), (0, 1, 0, 0)]
    header = {
        "time": time,
        "time_bits": struct.pack(">d", time).hex(),
        "dt_bits": struct.pack(">d", 1e-3).hex(),
        "cycle": cycle,
        "dim": dim,
        "block_nx": list(block_nx),
        "leaves": [list(l) for l in leaves],
        "vars": [{"name": "cons", "ncomp": 5}],
        "nblocks": len(leaves),
    }
    zone = block_nx[0] * (block_nx[1] if dim >= 2 else 1) * (
        block_nx[2] if dim >= 3 else 1
    )
    h = json.dumps(header).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<Q", len(h)))
        f.write(h)
        for gid in range(len(leaves)):
            f.write(struct.pack("<Q", gid))
            data = np.arange(5 * zone, dtype="<f4") + gid * 1000
            f.write(data.tobytes())
    return header


def test_reader_roundtrip(tmp_path):
    path = str(tmp_path / "t.pbin")
    make_pbin(path)
    snap = Snapshot(path)
    assert snap.cycle == 7
    assert abs(snap.time - 0.5) < 1e-15
    assert snap.max_level() == 0
    blk = snap.block_var(1, "cons")
    assert blk.shape == (5, 1, 4, 4)
    assert blk[0, 0, 0, 0] == 1000.0


def test_assemble_uniform(tmp_path):
    path = str(tmp_path / "t.pbin")
    make_pbin(path)
    snap = Snapshot(path)
    rho = snap.assemble_uniform("cons", component=0)
    assert rho.shape == (1, 4, 8)
    # block 1 occupies x in [4, 8)
    assert rho[0, 0, 4] == 1000.0
    assert rho[0, 0, 0] == 0.0


def test_conserved_totals_weighting(tmp_path):
    path = str(tmp_path / "t.pbin")
    make_pbin(path, leaves=[(0, 0, 0, 0), (1, 2, 0, 0)])
    snap = Snapshot(path)
    tot = snap.conserved_totals()
    # level-1 block contributes 1/4 the volume weight in 2D
    zone = 16
    b0 = np.arange(5 * zone, dtype=np.float64).reshape(5, -1).sum(1)
    b1 = (np.arange(5 * zone, dtype=np.float64) + 1000).reshape(5, -1).sum(1)
    np.testing.assert_allclose(tot, b0 + 0.25 * b1)


def test_reader_rejects_garbage(tmp_path):
    path = str(tmp_path / "bad.pbin")
    with open(path, "wb") as f:
        f.write(b"NOTPBIN")
    with pytest.raises(ValueError):
        Snapshot(path)


def test_reader_reads_real_output(tmp_path):
    """If the quickstart example has run, its outputs must parse."""
    cand = "../out_quickstart"
    if not os.path.isdir(cand):
        pytest.skip("quickstart output not present")
    files = [f for f in os.listdir(cand) if f.endswith(".pbin")]
    if not files:
        pytest.skip("no pbin files")
    snap = Snapshot(os.path.join(cand, sorted(files)[0]))
    assert len(snap.leaves) > 0
    tot = snap.conserved_totals()
    assert np.isfinite(tot).all() and tot[0] > 0


def write_results(dirpath, name, labels_tp):
    os.makedirs(dirpath, exist_ok=True)
    doc = {
        "name": name,
        "samples": [{"label": l, "throughput": t, "median_secs": 1.0,
                     "mad_secs": 0.0, "work": t, "reps": 3} for l, t in labels_tp],
    }
    with open(os.path.join(dirpath, f"{name}.json"), "w") as f:
        json.dump(doc, f)


def test_perf_compare_flags_regressions(tmp_path):
    base = str(tmp_path / "base")
    cur = str(tmp_path / "cur")
    write_results(base, "bench", [("a", 100.0), ("b", 100.0)])
    write_results(cur, "bench", [("a", 95.0), ("b", 50.0)])
    rows, regs, imps = perf_compare.compare(base, cur, tol=0.15)
    assert [k for k, _ in regs] == ["bench/b"]
    assert not imps
    assert [r[0] for r in rows] == ["bench/a", "bench/b"]
    assert [r[4] for r in rows] == ["ok", "regression"]


def test_perf_compare_cli(tmp_path):
    base = str(tmp_path / "base")
    cur = str(tmp_path / "cur")
    write_results(base, "bench", [("a", 100.0)])
    write_results(cur, "bench", [("a", 100.0)])
    r = subprocess.run(
        [sys.executable, "-m", "tools.perf_compare", base, cur],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True,
    )
    assert r.returncode == 0, r.stdout + r.stderr


def test_perf_compare_writes_step_summary(tmp_path, monkeypatch):
    """Under GitHub Actions the comparison lands in $GITHUB_STEP_SUMMARY as
    a markdown table (one row per sample, regressions flagged)."""
    base = str(tmp_path / "base")
    cur = str(tmp_path / "cur")
    write_results(base, "bench", [("a", 100.0), ("b", 100.0), ("c", 100.0)])
    write_results(cur, "bench", [("a", 100.0), ("b", 50.0)])
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    rows, regs, imps = perf_compare.compare(base, cur, tol=0.15)
    perf_compare.write_step_summary(rows, 0.15, regs, imps)
    text = summary.read_text()
    assert "| sample | baseline | current | ratio | status |" in text
    assert "`bench/b`" in text and "regression" in text
    assert "`bench/c`" in text and "missing" in text
    # no env -> no-op
    monkeypatch.delenv("GITHUB_STEP_SUMMARY")
    perf_compare.write_step_summary(rows, 0.15, regs, imps)


# ---------------------------------------------------------------------------
# check_test_registration: the CI guard for rust/tests registration
# ---------------------------------------------------------------------------


def make_repo(tmp_path, tests, cargo_entries, ci_tests):
    """Build a fake repo tree.

    ``tests``: {stem: source}; ``cargo_entries``: [(name, path)];
    ``ci_tests``: stems listed in the multi-rank cargo test step.
    """
    root = tmp_path / "repo"
    (root / "rust" / "tests").mkdir(parents=True)
    (root / ".github" / "workflows").mkdir(parents=True)
    for stem, src in tests.items():
        (root / "rust" / "tests" / f"{stem}.rs").write_text(src)
    cargo = "[package]\nname = \"x\"\n"
    for name, path in cargo_entries:
        cargo += f'\n[[test]]\nname = "{name}"\npath = "{path}"\n'
    (root / "Cargo.toml").write_text(cargo)
    run = " ".join(f"--test {s}" for s in ci_tests)
    (root / ".github" / "workflows" / "ci.yml").write_text(
        f"jobs:\n  rust:\n    steps:\n      - run: cargo test -q {run}\n"
    )
    return str(root)


def test_registration_parses_cargo_and_ci():
    entries = reg.cargo_test_entries(
        '[package]\nname = "x"\n\n[[test]]\nname = "a"  # comment\n'
        'path = "rust/tests/a.rs"\n\n[[bench]]\nname = "nope"\n'
        'path = "b.rs"\n\n[[test]]\nname = "b"\npath = "rust/tests/b.rs"\n'
    )
    assert entries == {"a": "rust/tests/a.rs", "b": "rust/tests/b.rs"}
    toks = reg.ci_test_tokens("run: cargo test --test a --test b_c\n")
    assert toks == {"a", "b_c"}


def test_registration_ok(tmp_path):
    root = make_repo(
        tmp_path,
        {"a": "fn main() {}", "b": "use common::multi_rank_enabled;"},
        [("a", "rust/tests/a.rs"), ("b", "rust/tests/b.rs")],
        ["b"],
    )
    assert reg.check(root) == []


def test_registration_flags_unregistered_file(tmp_path):
    root = make_repo(tmp_path, {"new_test": "x"}, [], [])
    problems = reg.check(root)
    assert len(problems) == 1 and "no [[test]] entry" in problems[0]


def test_registration_flags_guarded_test_missing_from_ci(tmp_path):
    root = make_repo(
        tmp_path,
        {"ranked": "if multi_rank_enabled() {}"},
        [("ranked", "rust/tests/ranked.rs")],
        [],
    )
    problems = reg.check(root)
    assert len(problems) == 1 and "multi-rank" in problems[0]


def test_registration_flags_stale_entries(tmp_path):
    root = make_repo(
        tmp_path,
        {"a": "x"},
        [("a", "rust/tests/a.rs"), ("gone", "rust/tests/gone.rs")],
        ["a", "ghost"],
    )
    problems = reg.check(root)
    assert any("not found" in p for p in problems)
    assert any("--test ghost" in p for p in problems)


def test_registration_cli_on_real_repo():
    """The actual repository must satisfy its own guard."""
    root = os.path.join(os.path.dirname(__file__), "..", "..")
    r = subprocess.run(
        [sys.executable, os.path.join("python", "tools",
                                      "check_test_registration.py"), "."],
        cwd=root,
        capture_output=True,
    )
    assert r.returncode == 0, r.stdout + r.stderr
