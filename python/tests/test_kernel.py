"""L1 Pallas kernel vs pure-jnp oracle — the core correctness signal —
plus physical invariants of the reference scheme itself."""

import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment"
)
from hypothesis import given, settings, strategies as st

from compile import bufspec, model
from compile.kernels import ref

GAMMA = 1.4


def random_state(rng, dim, n, nb=1, amp=0.1):
    zyx = bufspec.total_shape(n, dim)
    u = np.zeros((nb, 5) + zyx, np.float32)
    u[:, 0] = 1.0
    u[:, 4] = 1.0 / (GAMMA - 1.0)
    u += rng.normal(0.0, amp, u.shape).astype(np.float32)
    u[:, 0] = np.maximum(u[:, 0], 0.2)
    u[:, 4] = np.maximum(u[:, 4], 0.5)
    return u


def scal_vec(dt=1e-3, dx=0.1, g0=0.0, g1=1.0, beta=1.0):
    return np.array([g0, g1, beta, dt, dx, dx, dx, GAMMA], np.float32)


# ---------------------------------------------------------------------------
# Pallas vs ref
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    nx=st.sampled_from([4, 6, 8]),
    ny=st.sampled_from([4, 8]),
    nz=st.sampled_from([4, 8]),
    nb=st.sampled_from([1, 2, 3]),
)
def test_pallas_stage_matches_ref_3d(seed, nx, ny, nz, nb):
    rng = np.random.default_rng(seed)
    n = (nx, ny, nz)
    u = random_state(rng, 3, n, nb)
    scal = scal_vec()
    f_ref = model.build("stage", nb, 3, n, "jnp")
    f_pal = model.build("stage", nb, 3, n, "pallas")
    a = np.asarray(f_ref(u, u, scal)[0])
    b = np.asarray(f_pal(u, u, scal)[0])
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    nx=st.sampled_from([8, 16]),
    nb=st.sampled_from([1, 2]),
)
def test_pallas_stage_matches_ref_2d(seed, nx, nb):
    rng = np.random.default_rng(seed)
    n = (nx, nx, 1)
    u = random_state(rng, 2, n, nb)
    scal = scal_vec()
    a = np.asarray(model.build("stage", nb, 2, n, "jnp")(u, u, scal)[0])
    b = np.asarray(model.build("stage", nb, 2, n, "pallas")(u, u, scal)[0])
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_pallas_fused_matches_ref_2d():
    rng = np.random.default_rng(7)
    n, nb = (64, 64, 1), 4
    u = random_state(rng, 2, n, nb)
    bufs = rng.normal(1.0, 0.05, (nb, bufspec.buflen(n, 2))).astype(np.float32)
    scal = scal_vec()
    ra = model.build("fused", nb, 2, n, "jnp")(u, u, bufs, scal)
    rb = model.build("fused", nb, 2, n, "pallas")(u, u, bufs, scal)
    for a, b in zip(ra, rb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Physical invariants of the scheme
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dim,n", [(2, (16, 16, 1)), (3, (8, 8, 8))])
def test_uniform_state_is_stationary(dim, n):
    zyx = bufspec.total_shape(n, dim)
    u = np.zeros((5,) + zyx, np.float32)
    u[0] = 1.3
    u[1] = 1.3 * 0.5  # uniform velocity is also stationary
    u[4] = 2.0 + 0.5 * 1.3 * 0.25
    out = np.asarray(ref.stage(jnp.asarray(u), jnp.asarray(u),
                               jnp.asarray(scal_vec(dt=1e-2)), dim))
    np.testing.assert_allclose(out, u, rtol=1e-5, atol=1e-6)


def test_identity_when_beta_zero():
    rng = np.random.default_rng(3)
    u = random_state(rng, 3, (8, 8, 8))[0]
    scal = scal_vec(beta=0.0)
    out = np.asarray(ref.stage(jnp.asarray(u), jnp.asarray(u),
                               jnp.asarray(scal), 3))
    np.testing.assert_allclose(out, u, rtol=0, atol=0)


def test_mirror_symmetry_x():
    """Mirroring the state in x and flipping vx must commute with a stage."""
    rng = np.random.default_rng(11)
    n = (16, 8, 1)
    u = random_state(rng, 2, n)[0]
    scal = scal_vec()
    out = np.asarray(ref.stage(jnp.asarray(u), jnp.asarray(u),
                               jnp.asarray(scal), 2))
    um = u[:, :, :, ::-1].copy()
    um[1] = -um[1]
    outm = np.asarray(ref.stage(jnp.asarray(um), jnp.asarray(um),
                                jnp.asarray(scal), 2))
    outm_back = outm[:, :, :, ::-1].copy()
    outm_back[1] = -outm_back[1]
    np.testing.assert_allclose(out, outm_back, rtol=1e-5, atol=1e-6)


def test_dt_positive_and_decreases_with_velocity():
    rng = np.random.default_rng(5)
    n = (8, 8, 8)
    u = random_state(rng, 3, n)[0]
    scal = scal_vec()
    dt0 = float(ref.min_dt(jnp.asarray(u), jnp.asarray(scal), 3))
    assert dt0 > 0
    u_fast = u.copy()
    u_fast[1] += 5.0 * u_fast[0]  # add big vx
    u_fast[4] += 0.5 * 25.0 * u_fast[0]
    dt1 = float(ref.min_dt(jnp.asarray(u_fast), jnp.asarray(scal), 3))
    assert dt1 < dt0


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), dim=st.sampled_from([2, 3]))
def test_pack_unpack_roundtrip(seed, dim):
    """unpack(pack-permuted periodic self-buffers) == periodic ghost fill."""
    rng = np.random.default_rng(seed)
    n = (8, 8, 8) if dim == 3 else (8, 8, 1)
    u = random_state(rng, dim, n)[0]
    bufs = np.asarray(ref.pack_buffers(jnp.asarray(u), dim, n))
    # Route: a single periodic block is its own neighbor in every direction;
    # the send segment for o lands in the recv slot for o of the same block
    # (A=B, recv index = index(o) since send o -> B recv at -o, and the
    # neighbor at o of A is A itself; A receives FROM neighbor at o the data
    # that neighbor sent towards -o... which is A's own send segment for -o).
    ns = bufspec.neighbors(dim)
    opp = bufspec.opposite_index(dim)
    lens = bufspec.segment_lengths(n, dim)
    starts = np.concatenate([[0], np.cumsum(lens)]).astype(int)
    routed = np.zeros_like(bufs)
    for i in range(len(ns)):
        j = opp[i]
        routed[starts[i]:starts[i] + lens[i]] = bufs[starts[j]:starts[j] + lens[j]]
    out = np.asarray(ref.unpack_buffers(jnp.asarray(u), jnp.asarray(routed),
                                        dim, n))
    # Compare against numpy periodic fill of ghost zones
    g = bufspec.NGHOST
    nx, ny, nz = n
    expected = u.copy()

    # periodic wrap via np.take along each active axis
    def wrap_axis(a, axis, n_int):
        idx = np.r_[np.arange(n_int, n_int + g),
                    np.arange(g, g + n_int),
                    np.arange(g, 2 * g)]
        return np.take(a, idx, axis=axis)
    expected = wrap_axis(expected, 3, nx)
    if dim >= 2:
        expected = wrap_axis(expected, 2, ny)
    if dim >= 3:
        expected = wrap_axis(expected, 1, nz)
    np.testing.assert_allclose(out, expected, rtol=0, atol=0)
