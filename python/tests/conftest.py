"""Make the `compile`/`tools` packages importable regardless of where
pytest is invoked from (repo root CI runs `python -m pytest python/tests`)."""

import os
import sys

_PYTHON_DIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _PYTHON_DIR not in sys.path:
    sys.path.insert(0, _PYTHON_DIR)
