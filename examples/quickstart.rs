//! Quickstart: a 2D spherical blast wave on a uniform mesh, run on the
//! Device (PJRT) execution space with the fused per-pack strategy, writing
//! snapshots and a history file.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use parthenon::config::ParameterInput;
use parthenon::driver::{Driver, SimBuilder};

const INPUT: &str = r#"
<parthenon/job>
problem = blast
out_dir = out_quickstart

<parthenon/mesh>
nx1 = 128
nx2 = 128
x1min = 0.0
x1max = 1.0
x2min = 0.0
x2max = 1.0

<parthenon/meshblock>
nx1 = 32
nx2 = 32

<parthenon/time>
tlim = 0.08
nlim = 200

<parthenon/exec>
space = device
strategy = perpack
pack_size = 16

<parthenon/output0>
dt = 0.02

<parthenon/history>
dt = 0.005

<hydro>
gamma = 1.6666667
cfl = 0.3

<problem>
p_in = 10.0
p_out = 0.1
radius = 0.1
"#;

fn main() {
    let nranks = 2;
    let t0 = std::time::Instant::now();
    // CI smoke mode (PARTHENON_BENCH_QUICK=1): a handful of cycles, no
    // snapshot/history output — enough to catch API rot and runtime panics.
    let quick = parthenon::util::benchkit::quick_mode();
    parthenon::comm::World::launch(nranks, move |rank, world| {
        let mut pin = ParameterInput::from_str(INPUT).expect("parse input");
        if quick {
            pin.apply_override("parthenon/time/nlim=5").expect("override");
            pin.apply_override("parthenon/output0/dt=-1.0").expect("override");
            pin.apply_override("parthenon/history/dt=-1.0").expect("override");
        }
        let mut sim = SimBuilder::new(pin)
            .rank(rank)
            .world(world)
            .build()
            .expect("construct");
        sim.execute().expect("run");
        if rank == 0 {
            println!(
                "rank 0: {} cycles to t = {:.4}, {:.3e} zone-cycles/s, {} launches",
                sim.cycle,
                sim.time,
                sim.zc.zcps(),
                sim.device.as_ref().map(|d| d.rt.launches()).unwrap_or(0),
            );
        }
    });
    println!(
        "quickstart done in {:.2}s — snapshots in out_quickstart/",
        t0.elapsed().as_secs_f64()
    );
}
