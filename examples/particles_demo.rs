//! Particle swarms (paper Sec. 3.5): tracer particles advected through a
//! rotating velocity field across MeshBlocks, ranks and periodic
//! boundaries, with iterative transport and on-demand defragmentation.

use parthenon::comm::{tags, ReduceOp, World};
use parthenon::config::ParameterInput;
use parthenon::driver::SimBuilder;
use parthenon::particles::{transport_until_done, Swarm, SwarmField};
use parthenon::Real;

fn main() {
    World::launch(4, |rank, world| {
        let pin = ParameterInput::from_str(
            "<parthenon/job>\nproblem = uniform\nquiet = true\n\
             <parthenon/mesh>\nnx1 = 32\nnx2 = 32\n\
             <parthenon/meshblock>\nnx1 = 8\nnx2 = 8\n\
             <parthenon/time>\ntlim = 1\n<hydro>\ngamma = 1.4\n",
        )
        .unwrap();
        let mut sim =
            SimBuilder::new(pin).rank(rank).world(world.clone()).build().unwrap();

        // seed tracers on a ring
        let mut seeded = 0usize;
        for b in &mut sim.mesh.blocks {
            let mut sw = Swarm::new(
                "tracers",
                &[SwarmField::Real("angle0".into()), SwarmField::Int("id".into())],
            );
            let mut pts = Vec::new();
            for n in 0..64 {
                let th = 2.0 * std::f64::consts::PI * n as f64 / 64.0;
                let (x, y) = (0.5 + 0.3 * th.cos(), 0.5 + 0.3 * th.sin());
                if b.coords.contains([x, y, 0.0]) {
                    pts.push((x, y, th, n));
                }
            }
            let idx = sw.add_particles(pts.len());
            for (&i, (x, y, th, n)) in idx.iter().zip(pts.iter()) {
                sw.real_field_mut("x").unwrap()[i] = *x as Real;
                sw.real_field_mut("y").unwrap()[i] = *y as Real;
                sw.real_field_mut("angle0").unwrap()[i] = *th as Real;
                sw.int_field_mut("id").unwrap()[i] = *n as i64;
            }
            seeded += pts.len();
            b.swarms.insert("tracers".into(), sw);
        }
        let comm = world.comm(rank, tags::COMM_PARTICLES_BASE);
        let coll = world.comm(rank, 0);
        let total0 = coll.allreduce(seeded as f64, ReduceOp::Sum);

        // rigid-body rotation around the domain center
        let omega = 2.0 * std::f64::consts::PI; // one revolution per unit time
        let dt = 0.002;
        let nsteps = 500; // one full revolution
        let mut moved_total = 0usize;
        for _ in 0..nsteps {
            for b in &mut sim.mesh.blocks {
                if let Some(sw) = b.swarms.get_mut("tracers") {
                    for i in sw.active_indices() {
                        let x = sw.real_field("x").unwrap()[i] as f64 - 0.5;
                        let y = sw.real_field("y").unwrap()[i] as f64 - 0.5;
                        sw.real_field_mut("x").unwrap()[i] -= (omega * y * dt) as Real;
                        sw.real_field_mut("y").unwrap()[i] += (omega * x * dt) as Real;
                    }
                }
            }
            moved_total +=
                transport_until_done(&mut sim.mesh, &comm, "tracers", 8).unwrap();
            // periodic defrag keeps storage compact under churn
            for b in &mut sim.mesh.blocks {
                if let Some(sw) = b.swarms.get_mut("tracers") {
                    if !sw.is_contiguous() {
                        sw.defrag();
                    }
                }
            }
        }

        let total1 = coll.allreduce(
            sim.mesh
                .blocks
                .iter()
                .map(|b| b.swarms["tracers"].num_active() as f64)
                .sum(),
            ReduceOp::Sum,
        );

        // after one revolution each tracer should be near its start angle
        let mut max_err = 0.0f64;
        for b in &sim.mesh.blocks {
            let sw = &b.swarms["tracers"];
            for i in sw.active_indices() {
                let x = sw.real_field("x").unwrap()[i] as f64 - 0.5;
                let y = sw.real_field("y").unwrap()[i] as f64 - 0.5;
                let th = y.atan2(x).rem_euclid(2.0 * std::f64::consts::PI);
                let th0 =
                    (sw.real_field("angle0").unwrap()[i] as f64).rem_euclid(2.0 * std::f64::consts::PI);
                let mut d = (th - th0).abs();
                d = d.min(2.0 * std::f64::consts::PI - d);
                max_err = max_err.max(d);
            }
        }
        let max_err = coll.allreduce(max_err, ReduceOp::Max);

        if rank == 0 {
            println!(
                "tracers: {total0} seeded, {total1} after one revolution, \
                 {moved_total} block-crossings on rank 0, max angle error {max_err:.3} rad"
            );
            assert_eq!(total0, total1, "tracers lost");
            assert!(max_err < 0.15, "forward-Euler rotation drift too large");
        }
    });
}
