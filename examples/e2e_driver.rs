//! END-TO-END VALIDATION DRIVER (DESIGN.md §6 / EXPERIMENTS.md §E2E).
//!
//! Two legs prove all layers compose on a real workload:
//!  1. Device leg — 2D Kelvin-Helmholtz, 256² zones in 32² blocks on 4
//!     simulated ranks, PJRT execution (fused per-pack artifacts, L1 Pallas
//!     semantics validated against the jnp oracle at build time), a few
//!     hundred cycles, conservation + throughput logged.
//!  2. Host AMR leg — the same problem with 2-level adaptive refinement and
//!     flux correction on 4 ranks.

use parthenon::comm::{ReduceOp, World};
use parthenon::config::ParameterInput;
use parthenon::driver::{EvolutionDriver, SimBuilder};

fn deck(extra: &str) -> String {
    format!(
        "<parthenon/job>\nproblem = kh\nquiet = true\nout_dir = out_e2e\n\
         <parthenon/mesh>\nnx1 = 256\nnx2 = 256\n\
         <parthenon/meshblock>\nnx1 = 32\nnx2 = 32\n\
         <parthenon/time>\ntlim = 10.0\nnlim = 200\n\
         <parthenon/history>\ndt = 0.01\n\
         <hydro>\ngamma = 1.4\ncfl = 0.3\n\
         <problem>\nvflow = 0.5\ndrho = 1.0\namp = 0.02\n{extra}"
    )
}

fn run_leg(name: &str, input: String, nranks: usize) {
    use std::sync::{Arc, Mutex};
    let stats: Arc<Mutex<(u64, f64, f64, f64, u64)>> = Arc::new(Mutex::new((0, 0.0, 0.0, 0.0, 0)));
    let s2 = stats.clone();
    let t0 = std::time::Instant::now();
    World::launch(nranks, move |rank, world| {
        let pin = ParameterInput::from_str(&input).expect("parse");
        let mut sim = SimBuilder::new(pin)
            .rank(rank)
            .world(world.clone())
            .build()
            .expect("construct");
        let coll = world.comm(rank, 0);
        let before = coll.allreduce_vec(&sim.history_sums(), ReduceOp::Sum);
        while sim.cycle < 200 {
            sim.step().expect("step");
        }
        let after = coll.allreduce_vec(&sim.history_sums(), ReduceOp::Sum);
        if rank == 0 {
            let mut s = s2.lock().unwrap();
            *s = (
                sim.cycle,
                sim.zc.zcps(),
                ((after[0] - before[0]) / before[0]).abs(),
                ((after[3] - before[3]) / before[3]).abs(),
                sim.device.as_ref().map(|d| d.rt.launches()).unwrap_or(0),
            );
        }
    });
    let (cycles, zcps, mdrift, edrift, launches) = *stats.lock().unwrap();
    println!(
        "[{name}] {cycles} cycles in {:.1}s | {:.3e} zone-cycles/s | \
         mass drift {mdrift:.2e} | energy drift {edrift:.2e} | {launches} launches",
        t0.elapsed().as_secs_f64(),
        zcps,
    );
    assert!(mdrift < 1e-5, "{name}: mass must be conserved");
    assert!(edrift < 1e-5, "{name}: energy must be conserved");
}

fn main() {
    println!("== E2E leg 1: Device (PJRT, fused per-pack), 256^2 KH, 4 ranks ==");
    run_leg(
        "device",
        deck("<parthenon/exec>\nspace = device\nstrategy = perpack\npack_size = 16\n"),
        4,
    );

    println!("== E2E leg 2: Host AMR (2 levels + flux correction), 4 ranks ==");
    run_leg(
        "host-amr",
        deck(
            "<parthenon/exec>\nspace = host\n",
        )
        .replace(
            "<parthenon/mesh>\n",
            "<parthenon/mesh>\nrefinement = adaptive\nnumlevel = 2\n\
             check_refine_interval = 5\n",
        )
        .replace(
            "<hydro>\ngamma = 1.4\ncfl = 0.3\n",
            "<hydro>\ngamma = 1.4\ncfl = 0.3\nrefine_criterion = density_gradient\n\
             refine_tol = 0.04\nderefine_tol = 0.01\n",
        ),
        4,
    );
    println!("e2e_driver: both legs PASSED");
}
