//! The paper's `calculate_pi` example (Sec. 3.11): approximate π by
//! integrating the indicator function of the unit circle on an adaptively
//! refined mesh — AMR machinery with no hydrodynamics at all, driven by the
//! base `Driver` abstraction and a task-based global reduction.
//!
//! The "physics package" registers one cell-centered variable `in_circle`;
//! the refinement criterion refines any block crossed by the circle
//! boundary. Each refinement level halves the error of the area estimate.

use std::collections::HashMap;

use parthenon::comm::{ReduceOp, World};
use parthenon::config::ParameterInput;
use parthenon::mesh::{AmrFlag, Mesh, MeshConfig};
use parthenon::tasks::{TaskRegion, TaskStatus, NONE};
use parthenon::vars::{FieldDef, Metadata, MetadataFlag};

const RADIUS: f64 = 1.0;

fn fill_in_circle(mesh: &mut Mesh) {
    let shape = mesh.cfg.index_shape();
    for b in &mut mesh.blocks {
        let coords = b.coords;
        let arr = b.data.get_mut("in_circle").unwrap();
        for j in shape.is_(1)..shape.ie(1) {
            for i in shape.is_(0)..shape.ie(0) {
                let x = coords.center(0, i);
                let y = coords.center(1, j);
                let v = if x * x + y * y <= RADIUS * RADIUS { 1.0 } else { 0.0 };
                arr.set(0, 0, j, i, v);
            }
        }
    }
}

/// Refine blocks crossed by the circle boundary (mixed 0/1 cells).
fn refinement_flags(mesh: &Mesh) -> HashMap<parthenon::mesh::LogicalLocation, AmrFlag> {
    let shape = mesh.cfg.index_shape();
    let mut flags = HashMap::new();
    for b in &mesh.blocks {
        let arr = b.data.get("in_circle").unwrap();
        let mut any0 = false;
        let mut any1 = false;
        for j in shape.is_(1)..shape.ie(1) {
            for i in shape.is_(0)..shape.ie(0) {
                if arr.get(0, 0, j, i) > 0.5 {
                    any1 = true;
                } else {
                    any0 = true;
                }
            }
        }
        let flag = if any0 && any1 { AmrFlag::Refine } else { AmrFlag::Same };
        flags.insert(b.loc, flag);
    }
    flags
}

fn main() {
    let nranks = 2;
    let max_level = 6u8;
    World::launch(nranks, move |rank, world| {
        let mut pin = ParameterInput::from_str(
            "<parthenon/mesh>\nnx1 = 64\nnx2 = 64\nx1min = -1.5\nx1max = 1.5\n\
             x2min = -1.5\nx2max = 1.5\n<parthenon/meshblock>\nnx1 = 16\nnx2 = 16\n",
        )
        .unwrap();
        let cfg = MeshConfig::from_params(&mut pin).unwrap();
        let fields = vec![FieldDef {
            name: "in_circle".into(),
            metadata: Metadata::new(&[MetadataFlag::Cell, MetadataFlag::Derived]),
        }];
        let mut mesh = Mesh::build(cfg, fields, rank, world.size());
        fill_in_circle(&mut mesh);

        // AMR loop: refine boundary blocks until max_level
        for _ in 0..max_level {
            // allgather flags so every rank rebuilds the same tree
            let comm = world.comm(rank, 3);
            let mut payload = Vec::new();
            for (loc, flag) in refinement_flags(&mesh) {
                let gid = mesh.tree.gid_of(&loc).unwrap() as u64;
                payload.extend_from_slice(&gid.to_le_bytes());
                payload.push(matches!(flag, AmrFlag::Refine) as u8);
            }
            let gathered = comm.allgather(payload);
            let mut flags = HashMap::new();
            for blob in &gathered {
                for chunk in blob.chunks_exact(9) {
                    let gid = u64::from_le_bytes(chunk[..8].try_into().unwrap()) as usize;
                    let loc = mesh.tree.leaves()[gid];
                    if chunk[8] == 1 {
                        flags.insert(loc, AmrFlag::Refine);
                    }
                }
            }
            if flags.is_empty() {
                break;
            }
            let new_tree = mesh.tree.regrid(&flags, max_level);
            if new_tree.leaves() == mesh.tree.leaves() {
                break;
            }
            mesh.tree = new_tree;
            let costs = vec![1.0; mesh.tree.nblocks()];
            mesh.ranks = parthenon::balance::assign_blocks(&costs, world.size());
            mesh.rebuild_local_blocks();
            fill_in_circle(&mut mesh); // data is analytic: regenerate
        }

        // task-based area integration with a regional reduction (Sec. 3.10)
        let shape = mesh.cfg.index_shape();
        let nblocks = mesh.blocks.len();
        struct Ctx {
            mesh: Mesh,
            partial: f64,
            total: f64,
            world: World,
            rank: usize,
        }
        let mut region: TaskRegion<Ctx> = TaskRegion::new(nblocks.max(1));
        let mut marks = Vec::new();
        for bi in 0..nblocks {
            let id = region.list(bi).add(NONE, move |c: &mut Ctx| {
                let b = &c.mesh.blocks[bi];
                let arr = b.data.get("in_circle").unwrap();
                let da = b.coords.cell_volume();
                let mut s = 0.0;
                for j in shape.is_(1)..shape.ie(1) {
                    for i in shape.is_(0)..shape.ie(0) {
                        s += arr.get(0, 0, j, i) as f64 * da;
                    }
                }
                c.partial += s;
                TaskStatus::Complete
            });
            marks.push((bi, id));
        }
        region.add_regional(marks, |c: &mut Ctx| {
            let comm = c.world.comm(c.rank, 0);
            c.total = comm.allreduce(c.partial, ReduceOp::Sum);
            TaskStatus::Complete
        });
        let mut ctx = Ctx { mesh, partial: 0.0, total: 0.0, world: world.clone(), rank };
        region.execute(&mut ctx, 1000).unwrap();

        if rank == 0 {
            let pi = ctx.total / (RADIUS * RADIUS);
            println!(
                "blocks {} (max level {}), area = {:.8} -> pi ≈ {:.8} (err {:.2e})",
                ctx.mesh.tree.nblocks(),
                ctx.mesh.tree.max_level(),
                ctx.total,
                pi,
                (pi - std::f64::consts::PI).abs()
            );
            assert!((pi - std::f64::consts::PI).abs() < 5e-3);
        }
    });
}
