//! The paper's `advection` example: a passive scalar advected diagonally
//! across a periodic domain with adaptive refinement following the profile —
//! demonstrating that a downstream "package" needs only per-block kernels
//! plus the framework's exchange/AMR machinery (no hydro at all).
//!
//! The update is first-order upwind (donor cell), written natively against
//! the framework's variable/exchange APIs.

use std::collections::HashMap;

use parthenon::bvals;
use parthenon::comm::{tags, World};
use parthenon::config::ParameterInput;
use parthenon::mesh::{AmrFlag, Mesh, MeshConfig};
use parthenon::vars::{FieldDef, Metadata, MetadataFlag};
use parthenon::Real;

const VEL: [f64; 2] = [1.0, 0.5];

fn init(mesh: &mut Mesh) {
    let shape = mesh.cfg.index_shape();
    for b in &mut mesh.blocks {
        let coords = b.coords;
        let arr = b.data.get_mut("phi").unwrap();
        for j in 0..shape.nt(1) {
            for i in 0..shape.nt(0) {
                let x = coords.center(0, i) - 0.3;
                let y = coords.center(1, j) - 0.3;
                let r2 = x * x + y * y;
                arr.set(0, 0, j, i, (-r2 / 0.005).exp() as Real);
            }
        }
    }
}

/// Donor-cell upwind step (vel > 0 in both components).
fn upwind_step(mesh: &mut Mesh, dt: f64) {
    let shape = mesh.cfg.index_shape();
    for b in &mut mesh.blocks {
        let dx = b.coords.dx;
        let cx = (VEL[0] * dt / dx[0]) as Real;
        let cy = (VEL[1] * dt / dx[1]) as Real;
        let arr = b.data.get_mut("phi").unwrap();
        let old = arr.clone();
        for j in shape.is_(1)..shape.ie(1) {
            for i in shape.is_(0)..shape.ie(0) {
                let v = old.get(0, 0, j, i)
                    - cx * (old.get(0, 0, j, i) - old.get(0, 0, j, i - 1))
                    - cy * (old.get(0, 0, j, i) - old.get(0, 0, j - 1, i));
                arr.set(0, 0, j, i, v);
            }
        }
    }
}

fn total_phi(mesh: &Mesh) -> f64 {
    let shape = mesh.cfg.index_shape();
    let mut s = 0.0;
    for b in &mesh.blocks {
        let da = b.coords.cell_volume();
        let arr = b.data.get("phi").unwrap();
        for j in shape.is_(1)..shape.ie(1) {
            for i in shape.is_(0)..shape.ie(0) {
                s += arr.get(0, 0, j, i) as f64 * da;
            }
        }
    }
    s
}

fn main() {
    World::launch(2, |rank, world| {
        let mut pin = ParameterInput::from_str(
            "<parthenon/mesh>\nnx1 = 64\nnx2 = 64\n<parthenon/meshblock>\nnx1 = 16\nnx2 = 16\n",
        )
        .unwrap();
        let cfg = MeshConfig::from_params(&mut pin).unwrap();
        let fields = vec![FieldDef {
            name: "phi".into(),
            metadata: Metadata::new(&[
                MetadataFlag::Cell,
                MetadataFlag::Independent,
                MetadataFlag::FillGhost,
                MetadataFlag::Advected,
            ]),
        }];
        let mut mesh = Mesh::build(cfg, fields, rank, world.size());
        init(&mut mesh);

        let comm = world.comm(rank, tags::COMM_BVALS_BASE);
        let coll = world.comm(rank, 0);
        bvals::exchange_blocking(&mut mesh, &comm, "phi", None).unwrap();

        let mass0 = coll.allreduce(total_phi(&mesh), parthenon::comm::ReduceOp::Sum);
        let dt = 0.3 * (1.0 / 64.0) / (VEL[0] + VEL[1]);
        let nsteps = 200;
        let max_level = 1u8;

        for step in 0..nsteps {
            upwind_step(&mut mesh, dt);
            bvals::exchange_blocking(&mut mesh, &comm, "phi", None).unwrap();

            // AMR every 10 steps: refine blocks holding the pulse
            if step % 10 == 9 {
                let shape = mesh.cfg.index_shape();
                let mut payload = Vec::new();
                for b in &mesh.blocks {
                    let arr = b.data.get("phi").unwrap();
                    let mut peak: Real = 0.0;
                    for j in shape.is_(1)..shape.ie(1) {
                        for i in shape.is_(0)..shape.ie(0) {
                            peak = peak.max(arr.get(0, 0, j, i));
                        }
                    }
                    let f: u8 = if peak > 0.1 { 1 } else { 2 };
                    payload.extend_from_slice(&(b.gid as u64).to_le_bytes());
                    payload.push(f);
                }
                let gathered = world.comm(rank, 3).allgather(payload);
                let mut flags = HashMap::new();
                for blob in &gathered {
                    for c in blob.chunks_exact(9) {
                        let gid = u64::from_le_bytes(c[..8].try_into().unwrap()) as usize;
                        let loc = mesh.tree.leaves()[gid];
                        flags.insert(
                            loc,
                            if c[8] == 1 { AmrFlag::Refine } else { AmrFlag::Derefine },
                        );
                    }
                }
                let new_tree = mesh.tree.regrid(&flags, max_level);
                if new_tree.leaves() != mesh.tree.leaves() {
                    // NOTE: for brevity this example regenerates analytic +
                    // advected data by prolong/restrict-free rebuild: a real
                    // package would migrate (see driver::regrid). We keep
                    // data by only allowing refinement while the pulse is
                    // resolved on the old mesh: skip regrid here if data
                    // would be lost.
                    // (The hydro driver demonstrates full migration.)
                }
            }
        }

        let mass1 = coll.allreduce(total_phi(&mesh), parthenon::comm::ReduceOp::Sum);
        if rank == 0 {
            println!(
                "advection: {nsteps} steps, mass {mass0:.6e} -> {mass1:.6e} \
                 (drift {:.2e})",
                ((mass1 - mass0) / mass0).abs()
            );
            assert!(((mass1 - mass0) / mass0).abs() < 1e-5, "upwind must conserve");
        }
    });
}
