//! Kelvin-Helmholtz instability with adaptive mesh refinement — the paper's
//! AMR demonstration problem for PARTHENON-HYDRO (Sec. 4.1). Runs on the
//! Host path (full AMR + flux correction) on 4 simulated ranks and reports
//! the block-count history as the shear layers roll up.

use parthenon::comm::World;
use parthenon::config::ParameterInput;
use parthenon::driver::{EvolutionDriver, SimBuilder};

const INPUT: &str = r#"
<parthenon/job>
problem = kh
quiet = true
out_dir = out_kh

<parthenon/mesh>
nx1 = 128
nx2 = 128
refinement = adaptive
numlevel = 2
check_refine_interval = 5

<parthenon/meshblock>
nx1 = 16
nx2 = 16

<parthenon/time>
tlim = 1.0
nlim = 400

<parthenon/output0>
dt = 0.25

<hydro>
gamma = 1.4
cfl = 0.3
refine_criterion = density_gradient
refine_tol = 0.04
derefine_tol = 0.01

<problem>
vflow = 0.5
drho = 1.0
amp = 0.02
"#;

fn main() {
    let t0 = std::time::Instant::now();
    World::launch(4, |rank, world| {
        let pin = ParameterInput::from_str(INPUT).expect("parse");
        let mut sim = SimBuilder::new(pin)
            .rank(rank)
            .world(world)
            .build()
            .expect("construct");
        let mut history = Vec::new();
        while sim.time < 1.0 && sim.cycle < 400 {
            sim.step().expect("step");
            if sim.cycle % 25 == 0 {
                history.push((sim.cycle, sim.time, sim.mesh.tree.nblocks()));
            }
        }
        if rank == 0 {
            println!("cycle   time      blocks (max level {})", sim.mesh.tree.max_level());
            for (c, t, n) in &history {
                println!("{c:6} {t:9.4} {n:7}");
            }
            println!(
                "final: {} blocks, {:.3e} zone-cycles/s",
                sim.mesh.tree.nblocks(),
                sim.zc.zcps()
            );
        }
    });
    println!("kelvin_helmholtz done in {:.1}s", t0.elapsed().as_secs_f64());
}
