//! 3D spherical blast ("Sedov-like") on a statically refined mesh (SMR):
//! the center of the domain is refined one level; the shock crosses the
//! fine-coarse boundary, exercising prolongation/restriction and flux
//! correction in 3D. Host path, 4 ranks.

use parthenon::comm::{ReduceOp, World};
use parthenon::config::ParameterInput;
use parthenon::driver::{EvolutionDriver, SimBuilder};

const INPUT: &str = r#"
<parthenon/job>
problem = blast
quiet = true

<parthenon/mesh>
nx1 = 32
nx2 = 32
nx3 = 32
refinement = static

<parthenon/meshblock>
nx1 = 8
nx2 = 8
nx3 = 8

<parthenon/static_refinement0>
level = 1
x1min = 0.3
x1max = 0.7
x2min = 0.3
x2max = 0.7
x3min = 0.3
x3max = 0.7

<parthenon/time>
tlim = 0.05
nlim = 60

<hydro>
gamma = 1.6666667
cfl = 0.3

<problem>
p_in = 100.0
p_out = 0.1
radius = 0.12
"#;

fn main() {
    let t0 = std::time::Instant::now();
    // CI smoke mode (PARTHENON_BENCH_QUICK=1): a few cycles through the
    // full SMR + flux-correction machinery instead of the whole run.
    let ncycles: u64 = if parthenon::util::benchkit::quick_mode() { 5 } else { 60 };
    World::launch(4, move |rank, world| {
        let pin = ParameterInput::from_str(INPUT).expect("parse");
        let mut sim = SimBuilder::new(pin)
            .rank(rank)
            .world(world.clone())
            .build()
            .expect("construct");
        let coll = world.comm(rank, 0);
        let before = coll.allreduce_vec(&sim.history_sums(), ReduceOp::Sum);
        while sim.time < 0.05 && sim.cycle < ncycles {
            sim.step().expect("step");
        }
        let after = coll.allreduce_vec(&sim.history_sums(), ReduceOp::Sum);
        if rank == 0 {
            println!(
                "sedov: {} cycles, {} blocks ({} at level 1), mass drift {:.2e}, \
                 energy drift {:.2e}, {:.3e} zone-cycles/s",
                sim.cycle,
                sim.mesh.tree.nblocks(),
                sim.mesh.tree.leaves().iter().filter(|l| l.level == 1).count(),
                ((after[0] - before[0]) / before[0]).abs(),
                ((after[3] - before[3]) / before[3]).abs(),
                sim.zc.zcps()
            );
            assert!(((after[0] - before[0]) / before[0]).abs() < 1e-4);
        }
    });
    println!("sedov done in {:.1}s", t0.elapsed().as_secs_f64());
}
