//! Linear-wave convergence study — the automated convergence test the paper
//! mentions for PARTHENON-HYDRO (Sec. 4.1). Propagates an acoustic wave for
//! one period at several resolutions and prints the L1 error + measured
//! convergence order.

use parthenon::comm::World;
use parthenon::config::ParameterInput;
use parthenon::driver::{EvolutionDriver, SimBuilder};
use parthenon::hydro::problems::linear_wave_exact;
use parthenon::hydro::CONS;

fn l1_error(nx: usize) -> f64 {
    let input = format!(
        "<parthenon/job>\nproblem = linear_wave\nquiet = true\n\
         <parthenon/mesh>\nnx1 = {nx}\n<parthenon/meshblock>\nnx1 = {}\n\
         <parthenon/time>\ntlim = 1.0\nnlim = -1\n\
         <hydro>\ngamma = 1.4\ncfl = 0.3\n",
        nx / 2
    );
    let err = std::sync::Arc::new(std::sync::Mutex::new(0.0f64));
    let e2 = err.clone();
    World::launch(1, move |rank, world| {
        let pin = ParameterInput::from_str(&input).unwrap();
        let mut sim =
            SimBuilder::new(pin).rank(rank).world(world).build().unwrap();
        let t_end = 1.0;
        while sim.time < t_end {
            if sim.time + sim.dt > t_end {
                sim.dt = t_end - sim.time;
            }
            sim.step().unwrap();
        }
        let shape = sim.mesh.cfg.index_shape();
        let mut e = 0.0f64;
        let mut cells = 0usize;
        for b in &sim.mesh.blocks {
            let arr = b.data.get(CONS).unwrap();
            for i in shape.is_(0)..shape.ie(0) {
                let x = b.coords.center(0, i);
                let exact = linear_wave_exact(x, t_end, 1.4, 1e-3, 1.0, 1.0 / 1.4, 1.0);
                e += (arr.get(0, 0, 0, i) - exact[0]).abs() as f64;
                cells += 1;
            }
        }
        *e2.lock().unwrap() = e / cells as f64;
    });
    let v = *err.lock().unwrap();
    v
}

fn main() {
    println!("{:>6} {:>12} {:>8}", "nx", "L1(rho)", "order");
    let mut prev: Option<f64> = None;
    for nx in [16usize, 32, 64, 128, 256] {
        let e = l1_error(nx);
        let order = prev.map(|p| (p / e).log2());
        match order {
            Some(o) => println!("{nx:6} {e:12.4e} {o:8.2}"),
            None => println!("{nx:6} {e:12.4e} {:>8}", "-"),
        }
        prev = Some(e);
    }
    println!(
        "\nNOTE: the hot path is f32 (artifact dtype); the error floor near\n\
         ~2e-6 is amplitude^2 nonlinearity + f32 roundoff, so the measured\n\
         order falls off at the highest resolutions (see DESIGN.md)."
    );
}
