//! Deterministic fault injection for the simulated-MPI fabric.
//!
//! A seed-driven fault plan (parsed from `parthenon/fault`) perturbs the
//! mailbox send path — delaying, duplicating, reordering, or bit-flipping
//! payloads, and simulating rank death — while checksum framing turns every
//! corruption into a structured [`Error::CorruptMessage`] instead of silent
//! wrong answers. Duplicates and reordering are absorbed transparently by
//! per-(source, tag) sequence numbers: the receiver delivers frames in send
//! order no matter how the fabric scrambled them, so a faulty run must be
//! bitwise identical to a fault-free one (pinned by `rust/tests/chaos.rs`).
//!
//! The module also owns the World-level cooperative-abort cell: any rank
//! hitting a timeout, corruption, or simulated death posts an abort on the
//! reserved [`ABORT_KEY`] tag (waking every blocked receiver), and all
//! pending waits drain with [`Error::Aborted`] within one watchdog period.
//!
//! Framing invariant: a sender frames messages iff the World's fault plan
//! is installed at send time, and a receiver decodes iff it is installed at
//! receive time. Installation therefore must happen on every rank *before*
//! that rank's first send or receive (`HydroSim::new` installs before any
//! communication); a message framed under one regime and read under the
//! other is reported as corrupt rather than silently mis-parsed.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use super::simmpi::Payload;
use crate::config::ParameterInput;
use crate::error::Error;
use crate::metrics::FaultStats;
use crate::util::rng::XorShift;

/// Reserved mailbox key for abort postings: bit 46 of the 48-bit tag space,
/// outside every application key (`comm_id << 48 | tag`, application tags
/// stay far below bit 46) and below the tree-collective bit (47).
pub(crate) const ABORT_KEY: u64 = 1 << 46;

/// `parthenon/fault` parameters — the seed-driven fault plan.
#[derive(Debug, Clone)]
pub struct FaultConfig {
    /// RNG seed for every injection decision.
    pub seed: u64,
    /// Probability a sent frame is parked in the receiver's limbo buffer
    /// and only released on a later poll miss (arrives late, after
    /// younger messages).
    pub delay_prob: f64,
    /// Probability a sent frame is enqueued twice (same sequence number;
    /// the receiver must drop the duplicate).
    pub dup_prob: f64,
    /// Probability a sent frame jumps the queue (delivered before older
    /// undelivered frames of the same (source, tag)).
    pub reorder_prob: f64,
    /// Probability one bit of a sent frame is flipped after checksumming.
    pub corrupt_prob: f64,
    /// Rank to kill (-1 = none)...
    pub kill_rank: i64,
    /// ...at the start of this cycle (-1 = never).
    pub kill_cycle: i64,
    /// Watchdog budget (ms) for every communication/task wait before it
    /// escalates to [`Error::Timeout`].
    pub watchdog_ms: u64,
}

impl Default for FaultConfig {
    fn default() -> FaultConfig {
        FaultConfig {
            seed: 0,
            delay_prob: 0.0,
            dup_prob: 0.0,
            reorder_prob: 0.0,
            corrupt_prob: 0.0,
            kill_rank: -1,
            kill_cycle: -1,
            watchdog_ms: 60_000,
        }
    }
}

impl FaultConfig {
    /// Parse the `parthenon/fault` block (all fields optional; the default
    /// plan injects nothing and keeps the 60 s watchdog).
    pub fn from_input(pin: &mut ParameterInput) -> FaultConfig {
        let d = FaultConfig::default();
        FaultConfig {
            seed: pin.int_or("parthenon/fault", "seed", 0).max(0) as u64,
            delay_prob: pin.real_or("parthenon/fault", "delay_prob", 0.0),
            dup_prob: pin.real_or("parthenon/fault", "dup_prob", 0.0),
            reorder_prob: pin.real_or("parthenon/fault", "reorder_prob", 0.0),
            corrupt_prob: pin.real_or("parthenon/fault", "corrupt_prob", 0.0),
            kill_rank: pin.int_or("parthenon/fault", "kill_rank", -1),
            kill_cycle: pin.int_or("parthenon/fault", "kill_cycle", -1),
            watchdog_ms: pin
                .int_or("parthenon/fault", "watchdog_ms", d.watchdog_ms as i64)
                .max(1) as u64,
        }
    }

    /// True when the message path must be framed (any message-perturbing
    /// probability armed). Kill scheduling and the watchdog work without
    /// framing, so they don't force the framing overhead on.
    pub fn framing(&self) -> bool {
        self.delay_prob > 0.0
            || self.dup_prob > 0.0
            || self.reorder_prob > 0.0
            || self.corrupt_prob > 0.0
    }

    /// True when the plan injects anything at all.
    pub fn injecting(&self) -> bool {
        self.framing() || (self.kill_rank >= 0 && self.kill_cycle >= 0)
    }
}

/// Injection/escalation counters (atomics; snapshot via
/// [`FaultCounters::snapshot`] into [`crate::metrics::FaultStats`]).
#[derive(Debug, Default)]
pub struct FaultCounters {
    pub delayed: AtomicU64,
    pub duplicated: AtomicU64,
    pub reordered: AtomicU64,
    pub corrupted_injected: AtomicU64,
    pub corruption_detected: AtomicU64,
    pub duplicates_dropped: AtomicU64,
    pub dead_sends_dropped: AtomicU64,
    pub kills: AtomicU64,
    pub aborts_posted: AtomicU64,
    pub timeouts: AtomicU64,
}

impl FaultCounters {
    pub fn snapshot(&self) -> FaultStats {
        let g = |a: &AtomicU64| a.load(Ordering::Relaxed);
        FaultStats {
            delayed: g(&self.delayed),
            duplicated: g(&self.duplicated),
            reordered: g(&self.reordered),
            corrupted_injected: g(&self.corrupted_injected),
            corruption_detected: g(&self.corruption_detected),
            duplicates_dropped: g(&self.duplicates_dropped),
            dead_sends_dropped: g(&self.dead_sends_dropped),
            kills: g(&self.kills),
            aborts_posted: g(&self.aborts_posted),
            timeouts: g(&self.timeouts),
        }
    }
}

/// World-level cooperative-abort cell: first poster wins; every later
/// waiter reads the origin/reason back as [`Error::Aborted`].
#[derive(Debug, Default)]
pub(crate) struct AbortCell {
    flag: AtomicBool,
    info: Mutex<Option<(usize, String)>>,
}

impl AbortCell {
    /// Record an abort; returns true only for the first poster (callers
    /// broadcast the reserved-tag wakeup exactly once).
    pub(crate) fn post(&self, origin: usize, reason: &str) -> bool {
        let mut info = self.info.lock().unwrap_or_else(|e| e.into_inner());
        if info.is_some() {
            return false;
        }
        *info = Some((origin, reason.to_string()));
        self.flag.store(true, Ordering::SeqCst);
        true
    }

    pub(crate) fn is_set(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }

    pub(crate) fn error_for(&self, rank: usize) -> Error {
        let info = self.info.lock().unwrap_or_else(|e| e.into_inner());
        let (origin, reason) = info
            .clone()
            .unwrap_or((rank, "abort flag set with no info".to_string()));
        Error::Aborted { rank, origin, reason }
    }
}

// -- checksum framing ---------------------------------------------------------
//
// Frame layout: [seq u64 LE][kind u8][body][fnv1a(seq..body) u64 LE].
// `kind` preserves the payload variant across the byte round-trip; the
// checksum covers everything before it, so a flipped bit anywhere in the
// frame (except the checksum itself, which then mismatches the recomputed
// value) is detected.

const KIND_BYTES: u8 = 0;
const KIND_F32: u8 = 1;
const KIND_F64: u8 = 2;

/// FNV-1a 64-bit.
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Frame a payload for the faulty fabric.
pub(crate) fn encode_frame(seq: u64, payload: &Payload) -> Vec<u8> {
    let (kind, body_len) = match payload {
        Payload::Bytes(b) => (KIND_BYTES, b.len()),
        Payload::F32(v) => (KIND_F32, v.len() * 4),
        Payload::F64(v) => (KIND_F64, v.len() * 8),
    };
    let mut out = Vec::with_capacity(8 + 1 + body_len + 8);
    out.extend_from_slice(&seq.to_le_bytes());
    out.push(kind);
    match payload {
        Payload::Bytes(b) => out.extend_from_slice(b),
        Payload::F32(v) => {
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        Payload::F64(v) => {
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
    let csum = fnv1a(&out);
    out.extend_from_slice(&csum.to_le_bytes());
    out
}

/// Verify and unpack a frame. `None` means the checksum (or the shape)
/// doesn't hold — the caller reports [`Error::CorruptMessage`].
pub(crate) fn decode_frame(bytes: &[u8]) -> Option<(u64, Payload)> {
    if bytes.len() < 8 + 1 + 8 {
        return None;
    }
    let (covered, csum_b) = bytes.split_at(bytes.len() - 8);
    let csum = u64::from_le_bytes(csum_b.try_into().ok()?);
    if fnv1a(covered) != csum {
        return None;
    }
    let seq = u64::from_le_bytes(covered[..8].try_into().ok()?);
    let kind = covered[8];
    let body = &covered[9..];
    let payload = match kind {
        KIND_BYTES => Payload::Bytes(body.to_vec()),
        KIND_F32 => {
            if body.len() % 4 != 0 {
                return None;
            }
            Payload::F32(
                body.chunks_exact(4)
                    .map(|c| crate::Real::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            )
        }
        KIND_F64 => {
            if body.len() % 8 != 0 {
                return None;
            }
            Payload::F64(
                body.chunks_exact(8)
                    .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            )
        }
        _ => return None,
    };
    Some((seq, payload))
}

/// Flip one random bit in the checksum-covered region of a frame (never
/// the trailing checksum itself, so detection is guaranteed rather than
/// relying on the flip not colliding with a recomputed sum).
pub(crate) fn flip_random_bit(frame: &mut [u8], rng: &mut XorShift) {
    debug_assert!(frame.len() > 8);
    let covered = frame.len() - 8;
    let bit = rng.below(covered * 8);
    frame[bit / 8] ^= 1 << (bit % 8);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_all_kinds() {
        for p in [
            Payload::Bytes(vec![1, 2, 3]),
            Payload::Bytes(Vec::new()),
            Payload::F32(vec![1.5, -2.25]),
            Payload::F64(vec![3.141592653589793]),
        ] {
            let f = encode_frame(42, &p);
            let (seq, back) = decode_frame(&f).expect("decodes");
            assert_eq!(seq, 42);
            match (&p, &back) {
                (Payload::Bytes(a), Payload::Bytes(b)) => assert_eq!(a, b),
                (Payload::F32(a), Payload::F32(b)) => assert_eq!(a, b),
                (Payload::F64(a), Payload::F64(b)) => assert_eq!(a, b),
                _ => panic!("payload kind changed in flight"),
            }
        }
    }

    #[test]
    fn every_single_bit_flip_is_detected() {
        let f0 = encode_frame(7, &Payload::F32(vec![1.0, 2.0, 3.0]));
        for bit in 0..(f0.len() - 8) * 8 {
            let mut f = f0.clone();
            f[bit / 8] ^= 1 << (bit % 8);
            assert!(
                decode_frame(&f).is_none(),
                "flip of covered bit {bit} must fail the checksum"
            );
        }
    }

    #[test]
    fn flip_random_bit_corrupts() {
        let mut rng = XorShift::new(9);
        for _ in 0..50 {
            let mut f = encode_frame(0, &Payload::Bytes(vec![0u8; 16]));
            flip_random_bit(&mut f, &mut rng);
            assert!(decode_frame(&f).is_none());
        }
    }

    #[test]
    fn default_config_injects_nothing() {
        let cfg = FaultConfig::default();
        assert!(!cfg.framing());
        assert!(!cfg.injecting());
        assert_eq!(cfg.watchdog_ms, 60_000);
    }

    #[test]
    fn config_parses_from_input() {
        let mut pin = ParameterInput::from_str(
            "<parthenon/fault>\nseed = 11\ndelay_prob = 0.2\nkill_rank = 1\n\
             kill_cycle = 5\nwatchdog_ms = 250\n",
        )
        .unwrap();
        let cfg = FaultConfig::from_input(&mut pin);
        assert_eq!(cfg.seed, 11);
        assert_eq!(cfg.delay_prob, 0.2);
        assert_eq!(cfg.kill_rank, 1);
        assert_eq!(cfg.kill_cycle, 5);
        assert_eq!(cfg.watchdog_ms, 250);
        assert!(cfg.framing() && cfg.injecting());
    }

    #[test]
    fn abort_cell_first_poster_wins() {
        let c = AbortCell::default();
        assert!(!c.is_set());
        assert!(c.post(3, "first"));
        assert!(!c.post(4, "second"));
        assert!(c.is_set());
        match c.error_for(1) {
            Error::Aborted { rank, origin, reason } => {
                assert_eq!((rank, origin), (1, 3));
                assert!(reason.contains("first"));
            }
            e => panic!("wrong error {e}"),
        }
    }
}
