//! Nonblocking tree-structured collectives over the simmpi mailboxes.
//!
//! The paper's scaling rests on keeping communication off the critical
//! path (Sec. 3.8): collectives must neither serialize all ranks through
//! one lock nor barrier the task graph. This module implements
//! `iallreduce` / `iallreduce_vec` / `iallreduce_u64` / `iallgather` /
//! `ibarrier` as pollable [`CollHandle`] state machines built ONLY on the
//! existing point-to-point mailboxes:
//!
//! * **Reductions and gathers** run a binomial tree: rank `r > 0` reduces
//!   its subtree and sends one message to parent `r & (r-1)`; the root
//!   folds in fixed child order (own value first, then children by
//!   ascending round), then the result is broadcast down the same tree.
//!   Per-rank cost is O(log P) message hops and NO global lock — the
//!   flat generation-counted path ([`super::simmpi::Comm::allreduce`]
//!   with `coll = flat`) serializes O(P) acquisitions of one mutex per
//!   call. The fixed fold order makes `Sum` deterministic (the flat
//!   oracle folds in nondeterministic arrival order); `Min`/`Max` on
//!   f64 are order-insensitive, so tree ≡ flat bitwise always.
//! * **Barrier** runs dissemination: round `k` sends to `(r + 2^k) % P`
//!   and waits on `(r + P - 2^k) % P`, `ceil(log2 P)` rounds total — no
//!   reduction payload rides along (the old barrier piggybacked on a
//!   Sum allreduce).
//!
//! Collective messages reserve tag bit 47 ([`COLL_TAG_BIT`]) so they can
//! never collide with user point-to-point tags on the same communicator
//! (`bval_tag` would need a gid ≥ 2^36 to reach it), and carry a
//! per-(rank, comm) sequence number so back-to-back collectives on one
//! communicator stay separated without any synchronization. Every
//! message carries a (kind, op, len) header; a receiver that finds a
//! mismatched header panics with both ranks named instead of
//! deadlocking — the tree-path half of the collective-mismatch guard.

use super::simmpi::{Comm, Payload, ReduceOp};
use crate::error::{Error, Result};
use crate::util::backoff::ProgressWait;

/// Which collective algorithm a [`Comm`]'s blocking calls use
/// (`parthenon/comm coll`, default `tree`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollMode {
    /// Bulk-synchronous generation-counted path — O(P) serialized lock
    /// acquisitions; kept as the bitwise oracle.
    Flat,
    /// Tree-structured exchanges over the pt2pt mailboxes — O(log P)
    /// hops per rank, no global lock (default).
    Tree,
}

impl CollMode {
    /// Parse the `parthenon/comm coll` input value.
    pub fn parse(s: &str) -> Option<CollMode> {
        match s {
            "flat" | "sync" => Some(CollMode::Flat),
            "tree" | "async" => Some(CollMode::Tree),
            _ => None,
        }
    }
}

/// Reserved tag bit for collective traffic: user pt2pt tags stay below it
/// by construction (see `comm::tags`), so tree collectives share every
/// communicator with user messages without collision.
pub(crate) const COLL_TAG_BIT: u64 = 1 << 47;
/// Sequence bits in the tag (tag layout: bit 47 | seq << 8 | code).
const SEQ_MASK: u64 = (1 << 39) - 1;
/// Tag codes: 0 = reduce (child -> parent), 1 = broadcast (parent ->
/// child), 2+k = dissemination-barrier round k.
const CODE_REDUCE: u64 = 0;
const CODE_BCAST: u64 = 1;
const CODE_BARRIER0: u64 = 2;

/// Collective kinds, shared by the tree headers and the flat path's
/// mismatch guard.
pub(crate) const KIND_REDUCE: u8 = 1;
pub(crate) const KIND_GATHER: u8 = 2;
pub(crate) const KIND_BARRIER: u8 = 3;
pub(crate) const KIND_REDUCE_U64: u8 = 4;

pub(crate) fn kind_name(kind: u8) -> &'static str {
    match kind {
        KIND_REDUCE => "allreduce",
        KIND_GATHER => "allgather",
        KIND_BARRIER => "barrier",
        KIND_REDUCE_U64 => "allreduce_u64",
        _ => "unknown-collective",
    }
}

pub(crate) fn op_code(op: ReduceOp) -> u8 {
    match op {
        ReduceOp::Min => 0,
        ReduceOp::Max => 1,
        ReduceOp::Sum => 2,
    }
}

/// ceil(log2(p)) for p >= 1 (0 for p == 1): the round count of both the
/// binomial tree and the dissemination barrier.
pub(crate) fn ceil_log2(p: usize) -> u32 {
    if p <= 1 {
        0
    } else {
        usize::BITS - (p - 1).leading_zeros()
    }
}

/// Binomial-tree children of `rank` in a `size`-rank world, ascending.
fn children(rank: usize, size: usize) -> Vec<usize> {
    let limit = if rank == 0 { ceil_log2(size) } else { rank.trailing_zeros() };
    (0..limit)
        .map(|k| rank + (1usize << k))
        .filter(|&c| c < size)
        .collect()
}

/// Binomial-tree parent of `rank` (rank > 0): clear the lowest set bit.
fn parent(rank: usize) -> usize {
    rank & (rank - 1)
}

fn tag(seq: u64, code: u64) -> u64 {
    COLL_TAG_BIT | ((seq & SEQ_MASK) << 8) | code
}

// -- wire format -------------------------------------------------------------
//
// Every collective message is Payload::Bytes with a 10-byte header
// [kind u8][op u8][len u64 LE] followed by the body:
//   KIND_REDUCE      body = len f64 (LE)           len = vector length
//   KIND_REDUCE_U64  body = 1 u64 (LE)             len = 1
//   KIND_GATHER      body = entries, each          len = entry count
//                    [rank u32][blen u64][bytes]
//   KIND_BARRIER     no body                       len = round

fn encode_reduce(kind: u8, op: u8, acc_f64: &[f64], acc_u64: u64) -> Vec<u8> {
    let len = if kind == KIND_REDUCE_U64 { 1 } else { acc_f64.len() };
    let mut out = Vec::with_capacity(10 + 8 * len);
    out.push(kind);
    out.push(op);
    out.extend_from_slice(&(len as u64).to_le_bytes());
    if kind == KIND_REDUCE_U64 {
        out.extend_from_slice(&acc_u64.to_le_bytes());
    } else {
        for v in acc_f64 {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

fn encode_gather(entries: &[(u32, Vec<u8>)]) -> Vec<u8> {
    let mut out = Vec::new();
    out.push(KIND_GATHER);
    out.push(0);
    out.extend_from_slice(&(entries.len() as u64).to_le_bytes());
    for (r, b) in entries {
        out.extend_from_slice(&r.to_le_bytes());
        out.extend_from_slice(&(b.len() as u64).to_le_bytes());
        out.extend_from_slice(b);
    }
    out
}

fn encode_barrier(round: u32) -> Vec<u8> {
    let mut out = Vec::with_capacity(10);
    out.push(KIND_BARRIER);
    out.push(0);
    out.extend_from_slice(&(round as u64).to_le_bytes());
    out
}

struct Header {
    kind: u8,
    op: u8,
    len: u64,
}

fn decode_header(bytes: &[u8]) -> Header {
    assert!(bytes.len() >= 10, "collective message shorter than its header");
    Header {
        kind: bytes[0],
        op: bytes[1],
        len: u64::from_le_bytes(bytes[2..10].try_into().unwrap()),
    }
}

fn decode_gather(bytes: &[u8]) -> Vec<(u32, Vec<u8>)> {
    let h = decode_header(bytes);
    let mut entries = Vec::with_capacity(h.len as usize);
    let mut at = 10usize;
    for _ in 0..h.len {
        let r = u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap());
        let bl = u64::from_le_bytes(bytes[at + 4..at + 12].try_into().unwrap()) as usize;
        entries.push((r, bytes[at + 12..at + 12 + bl].to_vec()));
        at += 12 + bl;
    }
    entries
}

// -- handle ------------------------------------------------------------------

/// Accumulating payload of one in-flight collective.
enum CollData {
    /// f64 reduction (scalar = len 1). Fold order is fixed: own value
    /// first, then children by ascending round — so Sum is deterministic.
    Reduce { op: ReduceOp, acc: Vec<f64> },
    /// Exact integer sum (u64-in-f64 is exact only below 2^53; this is
    /// exact by construction — the particle-count reduction).
    ReduceU64 { acc: u64 },
    /// Allgatherv: (rank, blob) entries, sorted by rank at completion.
    Gather { entries: Vec<(u32, Vec<u8>)> },
    Barrier,
}

impl CollData {
    fn kind(&self) -> u8 {
        match self {
            CollData::Reduce { .. } => KIND_REDUCE,
            CollData::ReduceU64 { .. } => KIND_REDUCE_U64,
            CollData::Gather { .. } => KIND_GATHER,
            CollData::Barrier => KIND_BARRIER,
        }
    }

    fn op_code(&self) -> u8 {
        match self {
            CollData::Reduce { op, .. } => op_code(*op),
            _ => 0,
        }
    }

    fn len(&self) -> u64 {
        match self {
            CollData::Reduce { acc, .. } => acc.len() as u64,
            CollData::ReduceU64 { .. } => 1,
            // gather entry counts legitimately differ per subtree
            CollData::Gather { entries } => entries.len() as u64,
            CollData::Barrier => 0,
        }
    }
}

/// Where a handle is in its exchange.
enum Phase {
    /// Waiting on `children[next_child..]`, then sends to the parent.
    Reduce { next_child: usize },
    /// Sent to the parent; waiting for the broadcast back down.
    AwaitBcast,
    /// Dissemination barrier at `round` (`sent` = this round's message
    /// is on the wire).
    Dissem { round: u32, sent: bool },
    Done,
}

/// A pollable in-flight collective (MPI_Iallreduce analog): drive it with
/// [`CollHandle::test`] from any task/poll loop, or block on
/// [`CollHandle::wait`]. Created by [`Comm::iallreduce`] and friends; the
/// contribution message toward the parent is posted as early as possible
/// (leaf ranks send at creation), so the exchange makes progress while
/// the caller computes.
pub struct CollHandle {
    comm: Comm,
    seq: u64,
    children: Vec<usize>,
    data: CollData,
    phase: Phase,
}

impl CollHandle {
    fn post(comm: &Comm, data: CollData) -> CollHandle {
        let (rank, size) = (comm.rank(), comm.size());
        let seq = comm.next_coll_seq();
        let phase = match data {
            _ if size == 1 => Phase::Done,
            CollData::Barrier => Phase::Dissem { round: 0, sent: false },
            _ => Phase::Reduce { next_child: 0 },
        };
        let mut h = CollHandle {
            comm: comm.clone(),
            seq,
            children: children(rank, size),
            data,
            phase,
        };
        if size == 1 {
            h.finalize();
        } else {
            // push the contribution toward the parent (or the round-0
            // barrier message) onto the wire immediately; an abort at post
            // time is sticky, so test()/wait() re-report it
            let _ = h.advance();
        }
        h
    }

    /// Sort gather entries into rank order once the exchange completes.
    fn finalize(&mut self) {
        if let CollData::Gather { entries } = &mut self.data {
            entries.sort_by_key(|(r, _)| *r);
        }
        self.phase = Phase::Done;
    }

    fn expect_bytes(&self, src: usize, p: Payload) -> Vec<u8> {
        match p {
            Payload::Bytes(b) => b,
            _ => {
                self.comm.abort_collectives();
                panic!(
                    "collective mismatch on rank {}: non-collective payload from rank \
                     {src} on a reserved collective tag",
                    self.comm.rank()
                )
            }
        }
    }

    /// Validate a reduce/bcast header against this rank's entry; panic
    /// with both ranks named on mismatch (fail fast instead of folding
    /// garbage or deadlocking).
    fn check_header(&self, src: usize, bytes: &[u8]) -> Header {
        let h = decode_header(bytes);
        let (kind, op, len) = (self.data.kind(), self.data.op_code(), self.data.len());
        if h.kind != kind || (kind == KIND_REDUCE && (h.op != op || h.len != len)) {
            // poison the world's collectives so peers waiting on their
            // own handles fail fast instead of spinning out the stall
            // limit
            self.comm.abort_collectives();
            panic!(
                "collective mismatch: rank {} entered {}(op={}, len={}) but rank {src} \
                 sent {}(op={}, len={})",
                self.comm.rank(),
                kind_name(kind),
                op,
                len,
                kind_name(h.kind),
                h.op,
                h.len
            );
        }
        h
    }

    /// Fold one child's contribution into the accumulator.
    fn fold(&mut self, src: usize, bytes: Vec<u8>) {
        self.check_header(src, &bytes);
        match &mut self.data {
            CollData::Reduce { op, acc } => {
                for (i, a) in acc.iter_mut().enumerate() {
                    let at = 10 + 8 * i;
                    let v = f64::from_le_bytes(bytes[at..at + 8].try_into().unwrap());
                    *a = op.apply(*a, v);
                }
            }
            CollData::ReduceU64 { acc } => {
                *acc = acc
                    .checked_add(u64::from_le_bytes(bytes[10..18].try_into().unwrap()))
                    .expect("u64 allreduce overflow");
            }
            CollData::Gather { entries } => {
                entries.extend(decode_gather(&bytes));
            }
            CollData::Barrier => unreachable!("barrier runs dissemination"),
        }
    }

    fn encoded(&self) -> Vec<u8> {
        match &self.data {
            CollData::Reduce { op, acc } => {
                encode_reduce(KIND_REDUCE, op_code(*op), acc, 0)
            }
            CollData::ReduceU64 { acc } => encode_reduce(KIND_REDUCE_U64, 0, &[], *acc),
            CollData::Gather { entries } => encode_gather(entries),
            CollData::Barrier => unreachable!("barrier runs dissemination"),
        }
    }

    /// Replace the accumulator with the broadcast result.
    fn adopt(&mut self, src: usize, bytes: &[u8]) {
        self.check_header(src, bytes);
        match &mut self.data {
            CollData::Reduce { acc, .. } => {
                for (i, a) in acc.iter_mut().enumerate() {
                    let at = 10 + 8 * i;
                    *a = f64::from_le_bytes(bytes[at..at + 8].try_into().unwrap());
                }
            }
            CollData::ReduceU64 { acc } => {
                *acc = u64::from_le_bytes(bytes[10..18].try_into().unwrap());
            }
            CollData::Gather { entries } => *entries = decode_gather(bytes),
            CollData::Barrier => unreachable!("barrier runs dissemination"),
        }
    }

    /// Drive the state machine as far as it goes without blocking.
    /// Returns true if any state advanced (progress, for backoff resets);
    /// fails when the World has aborted (poll drains with `Aborted`).
    fn advance(&mut self) -> Result<bool> {
        let rank = self.comm.rank();
        let size = self.comm.size();
        let mut progressed = false;
        loop {
            match self.phase {
                Phase::Reduce { next_child } => {
                    let mut next = next_child;
                    // poll children in fixed ascending order: the fold
                    // order (and thus Sum) is deterministic even when a
                    // later child's message arrives first
                    while next < self.children.len() {
                        let src = self.children[next];
                        match self.comm.try_recv(src, tag(self.seq, CODE_REDUCE))? {
                            Some(p) => {
                                let b = self.expect_bytes(src, p);
                                self.fold(src, b);
                                next += 1;
                                progressed = true;
                            }
                            None => break,
                        }
                    }
                    if next < self.children.len() {
                        self.phase = Phase::Reduce { next_child: next };
                        return Ok(progressed);
                    }
                    // subtree complete
                    if rank == 0 {
                        let msg = self.encoded();
                        for &c in self.children.iter().rev() {
                            self.comm.isend(
                                c,
                                tag(self.seq, CODE_BCAST),
                                Payload::Bytes(msg.clone()),
                            );
                        }
                        self.finalize();
                        return Ok(true);
                    }
                    self.comm.isend(
                        parent(rank),
                        tag(self.seq, CODE_REDUCE),
                        Payload::Bytes(self.encoded()),
                    );
                    self.phase = Phase::AwaitBcast;
                    progressed = true;
                }
                Phase::AwaitBcast => {
                    let src = parent(rank);
                    match self.comm.try_recv(src, tag(self.seq, CODE_BCAST))? {
                        Some(p) => {
                            let bytes = self.expect_bytes(src, p);
                            self.adopt(src, &bytes);
                            for &c in self.children.iter().rev() {
                                self.comm.isend(
                                    c,
                                    tag(self.seq, CODE_BCAST),
                                    Payload::Bytes(bytes.clone()),
                                );
                            }
                            self.finalize();
                            return Ok(true);
                        }
                        None => return Ok(progressed),
                    }
                }
                Phase::Dissem { round, sent } => {
                    let nrounds = ceil_log2(size);
                    if round >= nrounds {
                        self.phase = Phase::Done;
                        return Ok(true);
                    }
                    let stride = 1usize << round;
                    if !sent {
                        let dst = (rank + stride) % size;
                        self.comm.isend(
                            dst,
                            tag(self.seq, CODE_BARRIER0 + round as u64),
                            Payload::Bytes(encode_barrier(round)),
                        );
                        self.phase = Phase::Dissem { round, sent: true };
                        progressed = true;
                    }
                    let src = (rank + size - stride) % size;
                    match self
                        .comm
                        .try_recv(src, tag(self.seq, CODE_BARRIER0 + round as u64))?
                    {
                        Some(p) => {
                            let b = self.expect_bytes(src, p);
                            self.check_header(src, &b);
                            self.phase = Phase::Dissem { round: round + 1, sent: false };
                            progressed = true;
                        }
                        None => return Ok(progressed),
                    }
                }
                Phase::Done => return Ok(progressed),
            }
        }
    }

    /// Poll once (MPI_Test): `Ok(true)` when the collective has completed.
    /// Fails fast (with the abort's origin) once the World has aborted —
    /// no spin to the stall limit when a peer already died.
    pub fn test(&mut self) -> Result<bool> {
        if !matches!(self.phase, Phase::Done) {
            self.comm.abort_check()?;
            self.advance()?;
        }
        Ok(matches!(self.phase, Phase::Done))
    }

    /// True without polling (no mailbox access).
    pub fn is_done(&self) -> bool {
        matches!(self.phase, Phase::Done)
    }

    /// Block (bounded spin-then-backoff) until the collective completes.
    /// A wait with zero progress for the watchdog budget escalates to a
    /// rank-annotated [`Error::Timeout`] and posts the World abort (a
    /// stalled collective means a peer never entered it); once completed,
    /// a handle always drains Ok even if the World aborts afterwards.
    pub fn wait(&mut self) -> Result<()> {
        let mut pw = ProgressWait::new(self.comm.stall_limit());
        loop {
            if matches!(self.phase, Phase::Done) {
                return Ok(());
            }
            self.comm.abort_check()?;
            let progressed = self.advance()?;
            if matches!(self.phase, Phase::Done) {
                return Ok(());
            }
            if !pw.step(progressed) {
                let e = Error::Timeout {
                    what: format!(
                        "tree {} (did every rank enter the same collective?)",
                        kind_name(self.data.kind())
                    ),
                    rank: Some(self.comm.rank()),
                    peer: None,
                    tag: None,
                    elapsed: pw.idle_elapsed(),
                };
                self.comm.world().escalate(self.comm.rank(), &e);
                return Err(e);
            }
        }
    }

    /// Completed scalar allreduce result.
    pub fn into_f64(mut self) -> Result<f64> {
        self.wait()?;
        match self.data {
            CollData::Reduce { ref acc, .. } if acc.len() == 1 => Ok(acc[0]),
            _ => Err(Error::Comm("collective handle is not a scalar allreduce".into())),
        }
    }

    /// Completed vector allreduce result.
    pub fn into_vec(mut self) -> Result<Vec<f64>> {
        self.wait()?;
        match self.data {
            CollData::Reduce { acc, .. } => Ok(acc),
            _ => Err(Error::Comm("collective handle is not an allreduce_vec".into())),
        }
    }

    /// Completed exact integer sum.
    pub fn into_u64(mut self) -> Result<u64> {
        self.wait()?;
        match self.data {
            CollData::ReduceU64 { acc } => Ok(acc),
            _ => Err(Error::Comm("collective handle is not an allreduce_u64".into())),
        }
    }

    /// Completed allgather result, one blob per rank in rank order.
    pub fn into_gathered(mut self) -> Result<Vec<Vec<u8>>> {
        self.wait()?;
        match self.data {
            CollData::Gather { entries } => {
                Ok(entries.into_iter().map(|(_, b)| b).collect())
            }
            _ => Err(Error::Comm("collective handle is not an allgather".into())),
        }
    }
}

impl Comm {
    /// Nonblocking tree allreduce of a scalar (MPI_Iallreduce): returns a
    /// pollable handle; drain with [`CollHandle::into_f64`].
    pub fn iallreduce(&self, value: f64, op: ReduceOp) -> CollHandle {
        CollHandle::post(self, CollData::Reduce { op, acc: vec![value] })
    }

    /// Nonblocking tree allreduce of a vector (element-wise; all ranks
    /// pass equal lengths — a length mismatch panics with both ranks).
    pub fn iallreduce_vec(&self, values: &[f64], op: ReduceOp) -> CollHandle {
        CollHandle::post(self, CollData::Reduce { op, acc: values.to_vec() })
    }

    /// Nonblocking exact integer sum-allreduce (u64 end to end — never
    /// routed through f64, so counts above 2^53 stay exact).
    pub fn iallreduce_u64(&self, value: u64) -> CollHandle {
        CollHandle::post(self, CollData::ReduceU64 { acc: value })
    }

    /// Nonblocking tree allgatherv of one byte blob per rank.
    pub fn iallgather(&self, bytes: Vec<u8>) -> CollHandle {
        CollHandle::post(
            self,
            CollData::Gather { entries: vec![(self.rank() as u32, bytes)] },
        )
    }

    /// Nonblocking dissemination barrier (always tree-structured: a
    /// barrier has no result to need the flat oracle for).
    pub fn ibarrier(&self) -> CollHandle {
        CollHandle::post(self, CollData::Barrier)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::World;

    #[test]
    fn tree_shape_helpers() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(4), 2);
        assert_eq!(ceil_log2(5), 3);
        assert_eq!(ceil_log2(64), 6);
        // size 6: edges cover every rank exactly once
        let mut covered = vec![false; 6];
        covered[0] = true;
        for r in 0..6 {
            for c in children(r, 6) {
                assert!(!covered[c], "child {c} claimed twice");
                covered[c] = true;
                assert_eq!(parent(c), r);
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn iallreduce_matches_ops_across_sizes() {
        for size in [1usize, 2, 3, 4, 5, 7, 8] {
            World::launch(size, move |rank, world| {
                let comm = world.comm(rank, 0);
                let v = (rank + 1) as f64;
                let n = size as f64;
                assert_eq!(
                    comm.iallreduce(v, ReduceOp::Sum).into_f64().unwrap(),
                    n * (n + 1.0) / 2.0
                );
                assert_eq!(comm.iallreduce(v, ReduceOp::Min).into_f64().unwrap(), 1.0);
                assert_eq!(comm.iallreduce(v, ReduceOp::Max).into_f64().unwrap(), n);
            });
        }
    }

    #[test]
    fn iallreduce_vec_elementwise() {
        World::launch(5, |rank, world| {
            let comm = world.comm(rank, 0);
            let v = vec![rank as f64, 10.0 * rank as f64, 1.0];
            let r = comm.iallreduce_vec(&v, ReduceOp::Sum).into_vec().unwrap();
            assert_eq!(r, vec![10.0, 100.0, 5.0]);
        });
    }

    #[test]
    fn iallreduce_u64_exact_above_2_53() {
        // each rank contributes a value that f64 cannot represent exactly;
        // the u64 path must sum them exactly
        World::launch(3, |rank, world| {
            let comm = world.comm(rank, 0);
            let v = (1u64 << 53) + 1 + rank as u64;
            let got = comm.iallreduce_u64(v).into_u64().unwrap();
            let want = 3 * ((1u64 << 53) + 1) + 3;
            assert_eq!(got, want);
            assert_ne!(got as f64 as u64, got, "test value must exceed f64 precision");
        });
    }

    #[test]
    fn iallgather_rank_order() {
        World::launch(6, |rank, world| {
            let comm = world.comm(rank, 0);
            let got = comm.iallgather(vec![rank as u8; rank]).into_gathered().unwrap();
            assert_eq!(got.len(), 6);
            for (r, blob) in got.iter().enumerate() {
                assert_eq!(blob, &vec![r as u8; r]);
            }
        });
    }

    #[test]
    fn ibarrier_separates_phases() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static BEFORE: AtomicUsize = AtomicUsize::new(0);
        World::launch(5, |_rank, world| {
            let comm = world.comm(_rank, 0);
            BEFORE.fetch_add(1, Ordering::SeqCst);
            let mut h = comm.ibarrier();
            h.wait().unwrap();
            // every rank must have incremented before any rank passes
            assert_eq!(BEFORE.load(Ordering::SeqCst), 5);
        });
    }

    #[test]
    fn repeated_mixed_tree_collectives_stay_in_sync() {
        World::launch(4, |rank, world| {
            let comm = world.comm(rank, 0);
            for i in 0..50u64 {
                let s = comm.iallreduce(i as f64, ReduceOp::Sum).into_f64().unwrap();
                assert_eq!(s, 4.0 * i as f64);
                let g = comm
                    .iallgather(vec![(rank as u64 + i) as u8])
                    .into_gathered()
                    .unwrap();
                assert_eq!(g.len(), 4);
                assert_eq!(g[rank][0], (rank as u64 + i) as u8);
                let u = comm.iallreduce_u64(i).into_u64().unwrap();
                assert_eq!(u, 4 * i);
            }
        });
    }

    #[test]
    fn overlapping_handles_on_one_comm() {
        // two collectives in flight at once, drained out of post order —
        // the per-(rank, comm) sequence numbers keep them separated
        World::launch(4, |rank, world| {
            let comm = world.comm(rank, 0);
            let h1 = comm.iallreduce(rank as f64, ReduceOp::Sum);
            let h2 = comm.iallreduce(1.0, ReduceOp::Sum);
            assert_eq!(h2.into_f64().unwrap(), 4.0);
            assert_eq!(h1.into_f64().unwrap(), 6.0);
        });
    }

    #[test]
    #[should_panic(expected = "collective mismatch")]
    fn mismatched_kinds_panic_not_deadlock() {
        World::launch(2, |rank, world| {
            let comm = world.comm(rank, 0);
            if rank == 0 {
                let _ = comm.iallreduce(1.0, ReduceOp::Sum).into_f64();
            } else {
                let _ = comm.iallgather(vec![1, 2, 3]).into_gathered();
            }
        });
    }

    #[test]
    #[should_panic(expected = "collective mismatch")]
    fn mismatched_vec_lengths_panic_not_deadlock() {
        World::launch(2, |rank, world| {
            let comm = world.comm(rank, 0);
            let v = vec![1.0; 2 + rank];
            let _ = comm.iallreduce_vec(&v, ReduceOp::Sum).into_vec();
        });
    }
}
