//! Simulated MPI: threads-as-ranks message passing with MPI-flavored
//! semantics (nonblocking pt2pt, communicators, collectives).

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};

use crate::error::{Error, Result};
use crate::Real;

/// Message payloads. `F32` covers field data (zero-conversion), `Bytes`
/// covers particles/serialized structures.
#[derive(Debug, Clone)]
pub enum Payload {
    F32(Vec<Real>),
    F64(Vec<f64>),
    Bytes(Vec<u8>),
}

impl Payload {
    pub fn into_f32(self) -> Result<Vec<Real>> {
        match self {
            Payload::F32(v) => Ok(v),
            _ => Err(Error::Comm("payload is not f32".into())),
        }
    }

    pub fn into_bytes(self) -> Result<Vec<u8>> {
        match self {
            Payload::Bytes(v) => Ok(v),
            _ => Err(Error::Comm("payload is not bytes".into())),
        }
    }
}

type Key = (usize, u64); // (source rank, tag)

#[derive(Default)]
struct MailboxInner {
    queues: HashMap<Key, VecDeque<Payload>>,
}

struct Mailbox {
    inner: Mutex<MailboxInner>,
    cv: Condvar,
}

/// Reduction operators for collectives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    Min,
    Max,
    Sum,
}

impl ReduceOp {
    fn apply(self, a: f64, b: f64) -> f64 {
        match self {
            ReduceOp::Min => a.min(b),
            ReduceOp::Max => a.max(b),
            ReduceOp::Sum => a + b,
        }
    }

    fn identity(self) -> f64 {
        match self {
            ReduceOp::Min => f64::INFINITY,
            ReduceOp::Max => f64::NEG_INFINITY,
            ReduceOp::Sum => 0.0,
        }
    }
}

/// Generation-counted state for bulk-synchronous collectives.
struct CollectiveState {
    generation: u64,
    arrived: usize,
    acc: f64,
    acc_vec: Vec<f64>,
    gathered: Vec<Option<Vec<u8>>>,
    /// snapshot of the finished generation's results
    done_acc: f64,
    done_acc_vec: Vec<f64>,
    done_gathered: Vec<Vec<u8>>,
}

struct WorldInner {
    size: usize,
    mailboxes: Vec<Mailbox>,
    collective: Mutex<CollectiveState>,
    collective_cv: Condvar,
}

/// The "MPI_COMM_WORLD" of one simulation: create once, then derive one
/// [`Comm`] per rank thread.
#[derive(Clone)]
pub struct World {
    inner: Arc<WorldInner>,
}

impl World {
    pub fn new(size: usize) -> World {
        assert!(size > 0);
        let mailboxes = (0..size)
            .map(|_| Mailbox { inner: Mutex::new(MailboxInner::default()), cv: Condvar::new() })
            .collect();
        World {
            inner: Arc::new(WorldInner {
                size,
                mailboxes,
                collective: Mutex::new(CollectiveState {
                    generation: 0,
                    arrived: 0,
                    acc: 0.0,
                    acc_vec: Vec::new(),
                    gathered: vec![None; size],
                    done_acc: 0.0,
                    done_acc_vec: Vec::new(),
                    done_gathered: Vec::new(),
                }),
                collective_cv: Condvar::new(),
            }),
        }
    }

    pub fn size(&self) -> usize {
        self.inner.size
    }

    /// The communication endpoint for `rank`. `comm_id` namespaces tags —
    /// one id per Variable, mirroring the paper's per-variable
    /// communicators.
    pub fn comm(&self, rank: usize, comm_id: u32) -> Comm {
        assert!(rank < self.inner.size);
        Comm { world: self.clone(), rank, comm_id }
    }

    /// Run `f(rank, world)` on `size` threads and join them, propagating
    /// panics. The standard launcher for multi-rank simulations and tests.
    pub fn launch<F>(size: usize, f: F) -> World
    where
        F: Fn(usize, World) + Send + Sync + 'static,
    {
        let world = World::new(size);
        let f = Arc::new(f);
        let mut handles = Vec::new();
        for rank in 0..size {
            let w = world.clone();
            let f = f.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("rank{rank}"))
                    .stack_size(32 * 1024 * 1024)
                    .spawn(move || f(rank, w))
                    .expect("spawn rank thread"),
            );
        }
        for h in handles {
            if let Err(e) = h.join() {
                std::panic::resume_unwind(e);
            }
        }
        world
    }
}

/// A rank's endpoint within one communicator.
#[derive(Clone)]
pub struct Comm {
    world: World,
    rank: usize,
    comm_id: u32,
}

/// Nonblocking receive handle (MPI_Irecv analog).
pub struct RecvHandle {
    comm: Comm,
    src: usize,
    tag: u64,
}

impl Comm {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.world.inner.size
    }

    #[inline]
    fn key(&self, tag: u64) -> u64 {
        // namespace the tag with the communicator id
        ((self.comm_id as u64) << 48) | (tag & 0xFFFF_FFFF_FFFF)
    }

    /// Nonblocking, eager send (MPI_Isend with buffered completion — the
    /// "one-sided" flavor of the paper: the sender never blocks).
    pub fn isend(&self, dst: usize, tag: u64, payload: Payload) {
        let mb = &self.world.inner.mailboxes[dst];
        let mut inner = mb.inner.lock().unwrap();
        inner
            .queues
            .entry((self.rank, self.key(tag)))
            .or_default()
            .push_back(payload);
        mb.cv.notify_all();
    }

    /// Nonblocking receive: returns a handle to poll.
    pub fn irecv(&self, src: usize, tag: u64) -> RecvHandle {
        RecvHandle { comm: self.clone(), src, tag: self.key(tag) }
    }

    /// Immediate poll (MPI_Test + receive).
    pub fn try_recv(&self, src: usize, tag: u64) -> Option<Payload> {
        let mb = &self.world.inner.mailboxes[self.rank];
        let mut inner = mb.inner.lock().unwrap();
        inner
            .queues
            .get_mut(&(src, self.key(tag)))
            .and_then(|q| q.pop_front())
    }

    /// Blocking receive (MPI_Recv).
    pub fn recv(&self, src: usize, tag: u64) -> Payload {
        let key = (src, self.key(tag));
        let mb = &self.world.inner.mailboxes[self.rank];
        let mut inner = mb.inner.lock().unwrap();
        loop {
            if let Some(p) = inner.queues.get_mut(&key).and_then(|q| q.pop_front()) {
                return p;
            }
            inner = mb.cv.wait(inner).unwrap();
        }
    }

    // -- collectives (bulk-synchronous, generation-counted) -----------------

    fn collective<FEnter, FSnap, T>(&self, enter: FEnter, snap: FSnap) -> T
    where
        FEnter: FnOnce(&mut CollectiveState),
        FSnap: FnOnce(&CollectiveState) -> T,
    {
        let w = &self.world.inner;
        let mut st = w.collective.lock().unwrap();
        let my_gen = st.generation;
        enter(&mut st);
        st.arrived += 1;
        if st.arrived == w.size {
            // last arrival publishes results and advances the generation
            st.done_acc = st.acc;
            st.done_acc_vec = std::mem::take(&mut st.acc_vec);
            st.done_gathered = st
                .gathered
                .iter_mut()
                .map(|g| g.take().unwrap_or_default())
                .collect();
            st.arrived = 0;
            st.generation += 1;
            w.collective_cv.notify_all();
        } else {
            while st.generation == my_gen {
                st = w.collective_cv.wait(st).unwrap();
            }
        }
        snap(&st)
    }

    /// All-reduce a scalar.
    pub fn allreduce(&self, value: f64, op: ReduceOp) -> f64 {
        self.collective(
            |st| {
                if st.arrived == 0 {
                    st.acc = op.identity();
                }
                st.acc = op.apply(st.acc, value);
            },
            |st| st.done_acc,
        )
    }

    /// Element-wise all-reduce of a vector (all ranks pass equal lengths).
    pub fn allreduce_vec(&self, values: &[f64], op: ReduceOp) -> Vec<f64> {
        let vals = values.to_vec();
        self.collective(
            move |st| {
                if st.arrived == 0 {
                    st.acc_vec = vec![op.identity(); vals.len()];
                }
                assert_eq!(st.acc_vec.len(), vals.len(), "allreduce_vec length mismatch");
                for (a, v) in st.acc_vec.iter_mut().zip(&vals) {
                    *a = op.apply(*a, *v);
                }
            },
            |st| st.done_acc_vec.clone(),
        )
    }

    /// Gather one byte blob from every rank, delivered to all (MPI_Allgatherv).
    pub fn allgather(&self, bytes: Vec<u8>) -> Vec<Vec<u8>> {
        let rank = self.rank;
        self.collective(
            move |st| {
                st.gathered[rank] = Some(bytes);
            },
            |st| st.done_gathered.clone(),
        )
    }

    /// Allgather a list of u64 ids (e.g. block gids), returned per rank.
    /// Used by the incremental rebalance to agree on the global set of
    /// blocks whose boundary data needs refreshing — each rank contributes
    /// its dirty-pack gids, every rank sees the union.
    pub fn allgather_u64s(&self, vals: &[u64]) -> Vec<Vec<u64>> {
        let mut bytes = Vec::with_capacity(vals.len() * 8);
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        self.allgather(bytes)
            .into_iter()
            .map(|blob| {
                blob.chunks_exact(8)
                    .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                    .collect()
            })
            .collect()
    }

    /// Barrier.
    pub fn barrier(&self) {
        let _ = self.allreduce(0.0, ReduceOp::Sum);
    }
}

impl RecvHandle {
    /// Poll for completion; consumes the message when available.
    pub fn test(&self) -> Option<Payload> {
        let mb = &self.comm.world.inner.mailboxes[self.comm.rank];
        let mut inner = mb.inner.lock().unwrap();
        inner
            .queues
            .get_mut(&(self.src, self.tag))
            .and_then(|q| q.pop_front())
    }

    /// Block until the message arrives.
    pub fn wait(&self) -> Payload {
        let key = (self.src, self.tag);
        let mb = &self.comm.world.inner.mailboxes[self.comm.rank];
        let mut inner = mb.inner.lock().unwrap();
        loop {
            if let Some(p) = inner.queues.get_mut(&key).and_then(|q| q.pop_front()) {
                return p;
            }
            inner = mb.cv.wait(inner).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pt2pt_roundtrip() {
        World::launch(2, |rank, world| {
            let comm = world.comm(rank, 0);
            if rank == 0 {
                comm.isend(1, 7, Payload::F32(vec![1.0, 2.0]));
                let back = comm.recv(1, 8).into_f32().unwrap();
                assert_eq!(back, vec![3.0]);
            } else {
                let got = comm.recv(0, 7).into_f32().unwrap();
                assert_eq!(got, vec![1.0, 2.0]);
                comm.isend(0, 8, Payload::F32(vec![3.0]));
            }
        });
    }

    #[test]
    fn fifo_per_source_tag() {
        World::launch(2, |rank, world| {
            let comm = world.comm(rank, 0);
            if rank == 0 {
                for i in 0..50 {
                    comm.isend(1, 1, Payload::F32(vec![i as f32]));
                }
            } else {
                for i in 0..50 {
                    let v = comm.recv(0, 1).into_f32().unwrap();
                    assert_eq!(v[0], i as f32, "messages must stay ordered");
                }
            }
        });
    }

    #[test]
    fn communicators_do_not_collide() {
        World::launch(2, |rank, world| {
            let a = world.comm(rank, 1);
            let b = world.comm(rank, 2);
            if rank == 0 {
                b.isend(1, 5, Payload::F32(vec![2.0]));
                a.isend(1, 5, Payload::F32(vec![1.0]));
            } else {
                // same tag, different communicator: no cross-talk
                let va = a.recv(0, 5).into_f32().unwrap();
                let vb = b.recv(0, 5).into_f32().unwrap();
                assert_eq!(va, vec![1.0]);
                assert_eq!(vb, vec![2.0]);
            }
        });
    }

    #[test]
    fn irecv_poll() {
        World::launch(2, |rank, world| {
            let comm = world.comm(rank, 0);
            if rank == 0 {
                std::thread::sleep(std::time::Duration::from_millis(10));
                comm.isend(1, 3, Payload::Bytes(vec![9u8]));
            } else {
                let h = comm.irecv(0, 3);
                let mut polls = 0;
                let payload = loop {
                    if let Some(p) = h.test() {
                        break p;
                    }
                    polls += 1;
                    std::thread::yield_now();
                };
                assert_eq!(payload.into_bytes().unwrap(), vec![9u8]);
                assert!(polls > 0 || true);
            }
        });
    }

    #[test]
    fn allreduce_ops() {
        World::launch(4, |rank, world| {
            let comm = world.comm(rank, 0);
            let v = (rank + 1) as f64;
            assert_eq!(comm.allreduce(v, ReduceOp::Sum), 10.0);
            assert_eq!(comm.allreduce(v, ReduceOp::Min), 1.0);
            assert_eq!(comm.allreduce(v, ReduceOp::Max), 4.0);
        });
    }

    #[test]
    fn allreduce_vec_elementwise() {
        World::launch(3, |rank, world| {
            let comm = world.comm(rank, 0);
            let v = vec![rank as f64, 10.0 * rank as f64];
            let r = comm.allreduce_vec(&v, ReduceOp::Sum);
            assert_eq!(r, vec![3.0, 30.0]);
        });
    }

    #[test]
    fn allgather_delivers_everyone() {
        World::launch(3, |rank, world| {
            let comm = world.comm(rank, 0);
            let got = comm.allgather(vec![rank as u8; rank + 1]);
            assert_eq!(got.len(), 3);
            for (r, blob) in got.iter().enumerate() {
                assert_eq!(blob, &vec![r as u8; r + 1]);
            }
        });
    }

    #[test]
    fn allgather_u64s_roundtrip() {
        World::launch(3, |rank, world| {
            let comm = world.comm(rank, 0);
            let mine: Vec<u64> = (0..rank as u64).map(|i| 100 * rank as u64 + i).collect();
            let got = comm.allgather_u64s(&mine);
            assert_eq!(got.len(), 3);
            assert_eq!(got[0], Vec::<u64>::new());
            assert_eq!(got[1], vec![100]);
            assert_eq!(got[2], vec![200, 201]);
        });
    }

    #[test]
    fn repeated_collectives_stay_in_sync() {
        static COUNT: AtomicUsize = AtomicUsize::new(0);
        World::launch(4, |rank, world| {
            let comm = world.comm(rank, 0);
            for i in 0..100 {
                let s = comm.allreduce(i as f64, ReduceOp::Sum);
                assert_eq!(s, 4.0 * i as f64);
            }
            COUNT.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(COUNT.load(Ordering::SeqCst), 4);
    }

    #[test]
    #[should_panic]
    fn rank_panic_propagates() {
        World::launch(2, |rank, _| {
            if rank == 1 {
                panic!("boom");
            }
        });
    }
}
