//! Simulated MPI: threads-as-ranks message passing with MPI-flavored
//! semantics (nonblocking pt2pt, communicators, collectives).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use super::coll::{self, CollMode};
use crate::error::{Error, Result};
use crate::Real;

/// Message payloads. `F32` covers field data (zero-conversion), `Bytes`
/// covers particles/serialized structures.
#[derive(Debug, Clone)]
pub enum Payload {
    F32(Vec<Real>),
    F64(Vec<f64>),
    Bytes(Vec<u8>),
}

impl Payload {
    pub fn into_f32(self) -> Result<Vec<Real>> {
        match self {
            Payload::F32(v) => Ok(v),
            _ => Err(Error::Comm("payload is not f32".into())),
        }
    }

    pub fn into_bytes(self) -> Result<Vec<u8>> {
        match self {
            Payload::Bytes(v) => Ok(v),
            _ => Err(Error::Comm("payload is not bytes".into())),
        }
    }
}

type Key = (usize, u64); // (source rank, tag)

#[derive(Default)]
struct MailboxInner {
    queues: HashMap<Key, VecDeque<Payload>>,
}

struct Mailbox {
    inner: Mutex<MailboxInner>,
    cv: Condvar,
}

/// Reduction operators for collectives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    Min,
    Max,
    Sum,
}

impl ReduceOp {
    pub(crate) fn apply(self, a: f64, b: f64) -> f64 {
        match self {
            ReduceOp::Min => a.min(b),
            ReduceOp::Max => a.max(b),
            ReduceOp::Sum => a + b,
        }
    }

    pub(crate) fn identity(self) -> f64 {
        match self {
            ReduceOp::Min => f64::INFINITY,
            ReduceOp::Max => f64::NEG_INFINITY,
            ReduceOp::Sum => 0.0,
        }
    }
}

/// Generation-counted state for bulk-synchronous collectives.
struct CollectiveState {
    generation: u64,
    arrived: usize,
    /// (kind, op, len) the first arrival of this generation declared —
    /// the flat half of the collective-mismatch guard: a later rank
    /// entering a different collective panics instead of deadlocking or
    /// folding garbage.
    entered: (u8, u8, u64),
    acc: f64,
    acc_u64: u64,
    acc_vec: Vec<f64>,
    gathered: Vec<Option<Vec<u8>>>,
    /// snapshot of the finished generation's results
    done_acc: f64,
    done_acc_u64: u64,
    done_acc_vec: Vec<f64>,
    done_gathered: Vec<Vec<u8>>,
}

struct WorldInner {
    size: usize,
    mailboxes: Vec<Mailbox>,
    collective: Mutex<CollectiveState>,
    collective_cv: Condvar,
    /// Per-rank tree-collective sequence counters, keyed by comm_id.
    /// World-owned (NOT per-`Comm`): several `Comm` handles for the same
    /// (rank, comm_id) coexist, and all must draw from one sequence so
    /// their collective tags line up across ranks.
    coll_seqs: Vec<Mutex<HashMap<u32, u64>>>,
    /// Set when a rank dies inside a tree collective (e.g. mismatch
    /// panic) so peers polling their handles fail fast instead of
    /// spinning out the full stall limit.
    coll_abort: AtomicBool,
}

/// The "MPI_COMM_WORLD" of one simulation: create once, then derive one
/// [`Comm`] per rank thread.
#[derive(Clone)]
pub struct World {
    inner: Arc<WorldInner>,
}

impl World {
    pub fn new(size: usize) -> World {
        assert!(size > 0);
        let mailboxes = (0..size)
            .map(|_| Mailbox { inner: Mutex::new(MailboxInner::default()), cv: Condvar::new() })
            .collect();
        World {
            inner: Arc::new(WorldInner {
                size,
                mailboxes,
                collective: Mutex::new(CollectiveState {
                    generation: 0,
                    arrived: 0,
                    entered: (0, 0, 0),
                    acc: 0.0,
                    acc_u64: 0,
                    acc_vec: Vec::new(),
                    gathered: vec![None; size],
                    done_acc: 0.0,
                    done_acc_u64: 0,
                    done_acc_vec: Vec::new(),
                    done_gathered: Vec::new(),
                }),
                collective_cv: Condvar::new(),
                coll_seqs: (0..size).map(|_| Mutex::new(HashMap::new())).collect(),
                coll_abort: AtomicBool::new(false),
            }),
        }
    }

    /// Next tree-collective sequence number for (rank, comm_id).
    pub(crate) fn next_coll_seq(&self, rank: usize, comm_id: u32) -> u64 {
        let mut seqs = self.inner.coll_seqs[rank].lock().unwrap();
        let s = seqs.entry(comm_id).or_insert(0);
        let out = *s;
        *s += 1;
        out
    }

    pub fn size(&self) -> usize {
        self.inner.size
    }

    /// The communication endpoint for `rank`. `comm_id` namespaces tags —
    /// one id per Variable, mirroring the paper's per-variable
    /// communicators.
    pub fn comm(&self, rank: usize, comm_id: u32) -> Comm {
        assert!(rank < self.inner.size);
        Comm { world: self.clone(), rank, comm_id, coll: CollMode::Tree }
    }

    /// Run `f(rank, world)` on `size` threads and join them, propagating
    /// panics. The standard launcher for multi-rank simulations and tests.
    pub fn launch<F>(size: usize, f: F) -> World
    where
        F: Fn(usize, World) + Send + Sync + 'static,
    {
        let world = World::new(size);
        let f = Arc::new(f);
        let mut handles = Vec::new();
        for rank in 0..size {
            let w = world.clone();
            let f = f.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("rank{rank}"))
                    .stack_size(32 * 1024 * 1024)
                    .spawn(move || f(rank, w))
                    .expect("spawn rank thread"),
            );
        }
        for h in handles {
            if let Err(e) = h.join() {
                std::panic::resume_unwind(e);
            }
        }
        world
    }
}

/// A rank's endpoint within one communicator.
#[derive(Clone)]
pub struct Comm {
    world: World,
    rank: usize,
    comm_id: u32,
    /// Algorithm for the blocking collective calls (tree by default; the
    /// flat generation-counted path is kept as the bitwise oracle).
    coll: CollMode,
}

/// Nonblocking receive handle (MPI_Irecv analog).
pub struct RecvHandle {
    comm: Comm,
    src: usize,
    tag: u64,
}

impl Comm {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.world.inner.size
    }

    /// Select the collective algorithm (builder-style; see [`CollMode`]).
    pub fn with_coll(mut self, coll: CollMode) -> Comm {
        self.coll = coll;
        self
    }

    /// The collective algorithm this endpoint's blocking calls use.
    pub fn coll_mode(&self) -> CollMode {
        self.coll
    }

    /// Draw the next tree-collective sequence number for this endpoint.
    pub(crate) fn next_coll_seq(&self) -> u64 {
        self.world.next_coll_seq(self.rank, self.comm_id)
    }

    /// Mark every tree collective in this world as doomed (called on the
    /// way into a mismatch panic so peers fail fast).
    pub(crate) fn abort_collectives(&self) {
        self.world.inner.coll_abort.store(true, Ordering::SeqCst);
    }

    /// Panic promptly if a peer rank died inside a collective.
    pub(crate) fn check_coll_abort(&self) {
        if self.world.inner.coll_abort.load(Ordering::SeqCst) {
            panic!(
                "collective aborted on rank {}: a peer rank failed a collective",
                self.rank
            );
        }
    }

    #[inline]
    fn key(&self, tag: u64) -> u64 {
        // namespace the tag with the communicator id
        ((self.comm_id as u64) << 48) | (tag & 0xFFFF_FFFF_FFFF)
    }

    /// Nonblocking, eager send (MPI_Isend with buffered completion — the
    /// "one-sided" flavor of the paper: the sender never blocks).
    pub fn isend(&self, dst: usize, tag: u64, payload: Payload) {
        let mb = &self.world.inner.mailboxes[dst];
        let mut inner = mb.inner.lock().unwrap();
        inner
            .queues
            .entry((self.rank, self.key(tag)))
            .or_default()
            .push_back(payload);
        mb.cv.notify_all();
    }

    /// Nonblocking receive: returns a handle to poll.
    pub fn irecv(&self, src: usize, tag: u64) -> RecvHandle {
        RecvHandle { comm: self.clone(), src, tag: self.key(tag) }
    }

    /// Immediate poll (MPI_Test + receive).
    pub fn try_recv(&self, src: usize, tag: u64) -> Option<Payload> {
        let mb = &self.world.inner.mailboxes[self.rank];
        let mut inner = mb.inner.lock().unwrap();
        inner
            .queues
            .get_mut(&(src, self.key(tag)))
            .and_then(|q| q.pop_front())
    }

    /// Blocking receive (MPI_Recv).
    pub fn recv(&self, src: usize, tag: u64) -> Payload {
        let key = (src, self.key(tag));
        let mb = &self.world.inner.mailboxes[self.rank];
        let mut inner = mb.inner.lock().unwrap();
        loop {
            if let Some(p) = inner.queues.get_mut(&key).and_then(|q| q.pop_front()) {
                return p;
            }
            inner = mb.cv.wait(inner).unwrap();
        }
    }

    // -- collectives --------------------------------------------------------
    //
    // The blocking entry points dispatch on `self.coll`: Tree posts a
    // nonblocking tree handle (see `comm::coll`) and drains it; Flat runs
    // the original bulk-synchronous generation-counted exchange below,
    // kept as the bitwise oracle.

    fn collective<FEnter, FSnap, T>(
        &self,
        kind: u8,
        op: u8,
        len: u64,
        enter: FEnter,
        snap: FSnap,
    ) -> T
    where
        FEnter: FnOnce(&mut CollectiveState),
        FSnap: FnOnce(&CollectiveState) -> T,
    {
        let w = &self.world.inner;
        let mut st = match w.collective.lock() {
            Ok(g) => g,
            Err(_) => panic!(
                "collective state poisoned on rank {}: a peer rank failed a collective",
                self.rank
            ),
        };
        let my_gen = st.generation;
        if st.arrived == 0 {
            st.entered = (kind, op, len);
        } else if st.entered != (kind, op, len) {
            // fail fast with both entries named instead of deadlocking
            // (the lock poisons on the way out, waking blocked peers)
            self.abort_collectives();
            let (k0, o0, l0) = st.entered;
            panic!(
                "collective mismatch: rank {} entered {}(op={op}, len={len}) but an \
                 earlier rank entered {}(op={o0}, len={l0})",
                self.rank,
                coll::kind_name(kind),
                coll::kind_name(k0),
            );
        }
        enter(&mut st);
        st.arrived += 1;
        if st.arrived == w.size {
            // last arrival publishes results and advances the generation
            st.done_acc = st.acc;
            st.done_acc_u64 = st.acc_u64;
            st.done_acc_vec = std::mem::take(&mut st.acc_vec);
            st.done_gathered = st
                .gathered
                .iter_mut()
                .map(|g| g.take().unwrap_or_default())
                .collect();
            st.arrived = 0;
            st.generation += 1;
            w.collective_cv.notify_all();
        } else {
            while st.generation == my_gen {
                st = match w.collective_cv.wait(st) {
                    Ok(g) => g,
                    Err(_) => panic!(
                        "collective state poisoned on rank {}: a peer rank failed a \
                         collective",
                        self.rank
                    ),
                };
            }
        }
        snap(&st)
    }

    /// All-reduce a scalar.
    pub fn allreduce(&self, value: f64, op: ReduceOp) -> f64 {
        match self.coll {
            CollMode::Tree => self.iallreduce(value, op).into_f64(),
            CollMode::Flat => self.allreduce_flat(value, op),
        }
    }

    fn allreduce_flat(&self, value: f64, op: ReduceOp) -> f64 {
        self.collective(
            coll::KIND_REDUCE,
            coll::op_code(op),
            1,
            |st| {
                if st.arrived == 0 {
                    st.acc = op.identity();
                }
                st.acc = op.apply(st.acc, value);
            },
            |st| st.done_acc,
        )
    }

    /// Exact integer sum-allreduce: u64 end to end, never routed through
    /// f64 (u64-in-f64 is exact only below 2^53).
    pub fn allreduce_u64(&self, value: u64) -> u64 {
        match self.coll {
            CollMode::Tree => self.iallreduce_u64(value).into_u64(),
            CollMode::Flat => self.collective(
                coll::KIND_REDUCE_U64,
                0,
                1,
                |st| {
                    if st.arrived == 0 {
                        st.acc_u64 = 0;
                    }
                    st.acc_u64 = st
                        .acc_u64
                        .checked_add(value)
                        .expect("u64 allreduce overflow");
                },
                |st| st.done_acc_u64,
            ),
        }
    }

    /// Element-wise all-reduce of a vector (all ranks pass equal lengths).
    pub fn allreduce_vec(&self, values: &[f64], op: ReduceOp) -> Vec<f64> {
        match self.coll {
            CollMode::Tree => self.iallreduce_vec(values, op).into_vec(),
            CollMode::Flat => {
                let vals = values.to_vec();
                self.collective(
                    coll::KIND_REDUCE,
                    coll::op_code(op),
                    vals.len() as u64,
                    move |st| {
                        if st.arrived == 0 {
                            st.acc_vec = vec![op.identity(); vals.len()];
                        }
                        for (a, v) in st.acc_vec.iter_mut().zip(&vals) {
                            *a = op.apply(*a, *v);
                        }
                    },
                    |st| st.done_acc_vec.clone(),
                )
            }
        }
    }

    /// Gather one byte blob from every rank, delivered to all (MPI_Allgatherv).
    pub fn allgather(&self, bytes: Vec<u8>) -> Vec<Vec<u8>> {
        match self.coll {
            CollMode::Tree => self.iallgather(bytes).into_gathered(),
            CollMode::Flat => {
                let rank = self.rank;
                // blob lengths legitimately differ per rank: len is not
                // part of the gather guard
                self.collective(
                    coll::KIND_GATHER,
                    0,
                    0,
                    move |st| {
                        st.gathered[rank] = Some(bytes);
                    },
                    |st| st.done_gathered.clone(),
                )
            }
        }
    }

    /// Allgather a list of u64 ids (e.g. block gids), returned per rank.
    /// Used by the incremental rebalance to agree on the global set of
    /// blocks whose boundary data needs refreshing — each rank contributes
    /// its dirty-pack gids, every rank sees the union.
    pub fn allgather_u64s(&self, vals: &[u64]) -> Vec<Vec<u64>> {
        let mut bytes = Vec::with_capacity(vals.len() * 8);
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        self.allgather(bytes)
            .into_iter()
            .map(|blob| {
                blob.chunks_exact(8)
                    .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                    .collect()
            })
            .collect()
    }

    /// Barrier. Tree mode runs a dedicated dissemination barrier (no
    /// reduction payload rides along); flat mode synchronizes through the
    /// generation counter with its own kind tag, so a barrier meeting a
    /// reduction trips the mismatch guard instead of silently pairing.
    pub fn barrier(&self) {
        match self.coll {
            CollMode::Tree => self.ibarrier().wait(),
            CollMode::Flat => self.collective(coll::KIND_BARRIER, 0, 0, |_| (), |_| ()),
        }
    }
}

impl RecvHandle {
    /// Poll for completion; consumes the message when available.
    pub fn test(&self) -> Option<Payload> {
        let mb = &self.comm.world.inner.mailboxes[self.comm.rank];
        let mut inner = mb.inner.lock().unwrap();
        inner
            .queues
            .get_mut(&(self.src, self.tag))
            .and_then(|q| q.pop_front())
    }

    /// Block until the message arrives.
    pub fn wait(&self) -> Payload {
        let key = (self.src, self.tag);
        let mb = &self.comm.world.inner.mailboxes[self.comm.rank];
        let mut inner = mb.inner.lock().unwrap();
        loop {
            if let Some(p) = inner.queues.get_mut(&key).and_then(|q| q.pop_front()) {
                return p;
            }
            inner = mb.cv.wait(inner).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pt2pt_roundtrip() {
        World::launch(2, |rank, world| {
            let comm = world.comm(rank, 0);
            if rank == 0 {
                comm.isend(1, 7, Payload::F32(vec![1.0, 2.0]));
                let back = comm.recv(1, 8).into_f32().unwrap();
                assert_eq!(back, vec![3.0]);
            } else {
                let got = comm.recv(0, 7).into_f32().unwrap();
                assert_eq!(got, vec![1.0, 2.0]);
                comm.isend(0, 8, Payload::F32(vec![3.0]));
            }
        });
    }

    #[test]
    fn fifo_per_source_tag() {
        World::launch(2, |rank, world| {
            let comm = world.comm(rank, 0);
            if rank == 0 {
                for i in 0..50 {
                    comm.isend(1, 1, Payload::F32(vec![i as f32]));
                }
            } else {
                for i in 0..50 {
                    let v = comm.recv(0, 1).into_f32().unwrap();
                    assert_eq!(v[0], i as f32, "messages must stay ordered");
                }
            }
        });
    }

    #[test]
    fn communicators_do_not_collide() {
        World::launch(2, |rank, world| {
            let a = world.comm(rank, 1);
            let b = world.comm(rank, 2);
            if rank == 0 {
                b.isend(1, 5, Payload::F32(vec![2.0]));
                a.isend(1, 5, Payload::F32(vec![1.0]));
            } else {
                // same tag, different communicator: no cross-talk
                let va = a.recv(0, 5).into_f32().unwrap();
                let vb = b.recv(0, 5).into_f32().unwrap();
                assert_eq!(va, vec![1.0]);
                assert_eq!(vb, vec![2.0]);
            }
        });
    }

    #[test]
    fn irecv_poll() {
        World::launch(2, |rank, world| {
            let comm = world.comm(rank, 0);
            if rank == 0 {
                std::thread::sleep(std::time::Duration::from_millis(10));
                comm.isend(1, 3, Payload::Bytes(vec![9u8]));
            } else {
                let h = comm.irecv(0, 3);
                let mut polls = 0;
                let payload = loop {
                    if let Some(p) = h.test() {
                        break p;
                    }
                    polls += 1;
                    std::thread::yield_now();
                };
                assert_eq!(payload.into_bytes().unwrap(), vec![9u8]);
                assert!(polls > 0 || true);
            }
        });
    }

    /// Every blocking collective, on both algorithms: the flat oracle and
    /// the default tree path must agree exactly.
    fn both_modes(f: impl Fn(CollMode) + Copy) {
        f(CollMode::Flat);
        f(CollMode::Tree);
    }

    #[test]
    fn allreduce_ops() {
        both_modes(|mode| {
            World::launch(4, move |rank, world| {
                let comm = world.comm(rank, 0).with_coll(mode);
                let v = (rank + 1) as f64;
                assert_eq!(comm.allreduce(v, ReduceOp::Sum), 10.0);
                assert_eq!(comm.allreduce(v, ReduceOp::Min), 1.0);
                assert_eq!(comm.allreduce(v, ReduceOp::Max), 4.0);
            });
        });
    }

    #[test]
    fn allreduce_u64_exact() {
        both_modes(|mode| {
            World::launch(3, move |rank, world| {
                let comm = world.comm(rank, 0).with_coll(mode);
                let v = (1u64 << 53) + rank as u64;
                assert_eq!(comm.allreduce_u64(v), 3 * (1u64 << 53) + 3);
                assert_eq!(comm.allreduce_u64(0), 0);
            });
        });
    }

    #[test]
    fn allreduce_vec_elementwise() {
        both_modes(|mode| {
            World::launch(3, move |rank, world| {
                let comm = world.comm(rank, 0).with_coll(mode);
                let v = vec![rank as f64, 10.0 * rank as f64];
                let r = comm.allreduce_vec(&v, ReduceOp::Sum);
                assert_eq!(r, vec![3.0, 30.0]);
            });
        });
    }

    #[test]
    fn allgather_delivers_everyone() {
        both_modes(|mode| {
            World::launch(3, move |rank, world| {
                let comm = world.comm(rank, 0).with_coll(mode);
                let got = comm.allgather(vec![rank as u8; rank + 1]);
                assert_eq!(got.len(), 3);
                for (r, blob) in got.iter().enumerate() {
                    assert_eq!(blob, &vec![r as u8; r + 1]);
                }
            });
        });
    }

    #[test]
    fn allgather_u64s_roundtrip() {
        both_modes(|mode| {
            World::launch(3, move |rank, world| {
                let comm = world.comm(rank, 0).with_coll(mode);
                let mine: Vec<u64> =
                    (0..rank as u64).map(|i| 100 * rank as u64 + i).collect();
                let got = comm.allgather_u64s(&mine);
                assert_eq!(got.len(), 3);
                assert_eq!(got[0], Vec::<u64>::new());
                assert_eq!(got[1], vec![100]);
                assert_eq!(got[2], vec![200, 201]);
            });
        });
    }

    #[test]
    fn barrier_runs_on_both_modes() {
        both_modes(|mode| {
            World::launch(3, move |rank, world| {
                let comm = world.comm(rank, 0).with_coll(mode);
                for _ in 0..5 {
                    comm.barrier();
                }
                // and interleaves cleanly with reductions
                assert_eq!(comm.allreduce(1.0, ReduceOp::Sum), 3.0);
            });
        });
    }

    #[test]
    fn repeated_collectives_stay_in_sync() {
        static COUNT: AtomicUsize = AtomicUsize::new(0);
        World::launch(4, |rank, world| {
            let comm = world.comm(rank, 0).with_coll(CollMode::Flat);
            for i in 0..100 {
                let s = comm.allreduce(i as f64, ReduceOp::Sum);
                assert_eq!(s, 4.0 * i as f64);
            }
            COUNT.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(COUNT.load(Ordering::SeqCst), 4);
    }

    #[test]
    #[should_panic(expected = "collective")]
    fn flat_mismatched_kinds_panic_not_deadlock() {
        World::launch(2, |rank, world| {
            let comm = world.comm(rank, 0).with_coll(CollMode::Flat);
            if rank == 0 {
                let _ = comm.allreduce(1.0, ReduceOp::Sum);
            } else {
                let _ = comm.allgather(vec![1, 2, 3]);
            }
        });
    }

    #[test]
    #[should_panic(expected = "collective")]
    fn flat_mismatched_vec_lengths_panic_not_deadlock() {
        World::launch(2, |rank, world| {
            let comm = world.comm(rank, 0).with_coll(CollMode::Flat);
            let v = vec![1.0; 2 + rank];
            let _ = comm.allreduce_vec(&v, ReduceOp::Sum);
        });
    }

    #[test]
    #[should_panic(expected = "collective")]
    fn flat_mismatched_ops_panic_not_deadlock() {
        World::launch(2, |rank, world| {
            let comm = world.comm(rank, 0).with_coll(CollMode::Flat);
            let op = if rank == 0 { ReduceOp::Min } else { ReduceOp::Max };
            let _ = comm.allreduce(1.0, op);
        });
    }

    #[test]
    #[should_panic]
    fn rank_panic_propagates() {
        World::launch(2, |rank, _| {
            if rank == 1 {
                panic!("boom");
            }
        });
    }
}
