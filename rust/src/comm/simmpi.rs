//! Simulated MPI: threads-as-ranks message passing with MPI-flavored
//! semantics (nonblocking pt2pt, communicators, collectives).

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::{Duration, Instant};

use super::coll::{self, CollMode};
use super::fault::{self, FaultConfig, FaultCounters};
use crate::error::{Error, Result};
use crate::metrics::FaultStats;
use crate::util::rng::XorShift;
use crate::Real;

/// Message payloads. `F32` covers field data (zero-conversion), `Bytes`
/// covers particles/serialized structures.
#[derive(Debug, Clone)]
pub enum Payload {
    F32(Vec<Real>),
    F64(Vec<f64>),
    Bytes(Vec<u8>),
}

impl Payload {
    pub fn into_f32(self) -> Result<Vec<Real>> {
        match self {
            Payload::F32(v) => Ok(v),
            _ => Err(Error::Comm("payload is not f32".into())),
        }
    }

    pub fn into_bytes(self) -> Result<Vec<u8>> {
        match self {
            Payload::Bytes(v) => Ok(v),
            _ => Err(Error::Comm("payload is not bytes".into())),
        }
    }
}

type Key = (usize, u64); // (source rank, tag)

#[derive(Default)]
struct MailboxInner {
    queues: HashMap<Key, VecDeque<Payload>>,
    // -- fault-framing state (touched only when a framing fault plan is
    //    installed; all under the one mailbox lock) -------------------------
    /// Next sequence number to stamp on a frame arriving from `Key`
    /// (sender-side counter, but owned by the *destination* mailbox so all
    /// sends to it serialize on one lock).
    send_next: HashMap<Key, u64>,
    /// Next sequence number this rank will deliver for `Key`.
    recv_next: HashMap<Key, u64>,
    /// Out-of-order frames parked until their sequence number comes up.
    stash: HashMap<Key, BTreeMap<u64, Payload>>,
    /// Delay-injected frames, released into the queues on a poll miss (so
    /// they genuinely arrive after younger messages).
    limbo: Vec<(Key, Payload)>,
    /// Injection RNG (seeded per mailbox at fault-plan install).
    rng: Option<XorShift>,
}

struct Mailbox {
    inner: Mutex<MailboxInner>,
    cv: Condvar,
}

/// Reduction operators for collectives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    Min,
    Max,
    Sum,
}

impl ReduceOp {
    pub(crate) fn apply(self, a: f64, b: f64) -> f64 {
        match self {
            ReduceOp::Min => a.min(b),
            ReduceOp::Max => a.max(b),
            ReduceOp::Sum => a + b,
        }
    }

    pub(crate) fn identity(self) -> f64 {
        match self {
            ReduceOp::Min => f64::INFINITY,
            ReduceOp::Max => f64::NEG_INFINITY,
            ReduceOp::Sum => 0.0,
        }
    }
}

/// Generation-counted state for bulk-synchronous collectives.
struct CollectiveState {
    generation: u64,
    arrived: usize,
    /// (kind, op, len) the first arrival of this generation declared —
    /// the flat half of the collective-mismatch guard: a later rank
    /// entering a different collective panics instead of deadlocking or
    /// folding garbage.
    entered: (u8, u8, u64),
    acc: f64,
    acc_u64: u64,
    acc_vec: Vec<f64>,
    gathered: Vec<Option<Vec<u8>>>,
    /// snapshot of the finished generation's results
    done_acc: f64,
    done_acc_u64: u64,
    done_acc_vec: Vec<f64>,
    done_gathered: Vec<Vec<u8>>,
}

struct WorldInner {
    size: usize,
    mailboxes: Vec<Mailbox>,
    collective: Mutex<CollectiveState>,
    collective_cv: Condvar,
    /// Per-rank tree-collective sequence counters, keyed by comm_id.
    /// World-owned (NOT per-`Comm`): several `Comm` handles for the same
    /// (rank, comm_id) coexist, and all must draw from one sequence so
    /// their collective tags line up across ranks.
    coll_seqs: Vec<Mutex<HashMap<u32, u64>>>,
    /// Installed fault plan (install-once; `None` = clean fabric).
    fault_cfg: OnceLock<FaultConfig>,
    /// Injection/escalation counters (always allocated; cheap atomics).
    counters: FaultCounters,
    /// Cooperative-abort cell: any rank hitting timeout/corruption/death
    /// posts here; every pending wait drains with `Error::Aborted`.
    abort: fault::AbortCell,
    /// Watchdog budget (ms) for communication waits — `parthenon/fault
    /// watchdog_ms`, adjustable at runtime for tests.
    watchdog_ms: AtomicU64,
    /// Ranks the fault plan has killed; their sends are dropped.
    dead: Vec<AtomicBool>,
}

/// The "MPI_COMM_WORLD" of one simulation: create once, then derive one
/// [`Comm`] per rank thread.
#[derive(Clone)]
pub struct World {
    inner: Arc<WorldInner>,
}

impl World {
    pub fn new(size: usize) -> World {
        assert!(size > 0);
        let mailboxes = (0..size)
            .map(|_| Mailbox { inner: Mutex::new(MailboxInner::default()), cv: Condvar::new() })
            .collect();
        World {
            inner: Arc::new(WorldInner {
                size,
                mailboxes,
                collective: Mutex::new(CollectiveState {
                    generation: 0,
                    arrived: 0,
                    entered: (0, 0, 0),
                    acc: 0.0,
                    acc_u64: 0,
                    acc_vec: Vec::new(),
                    gathered: vec![None; size],
                    done_acc: 0.0,
                    done_acc_u64: 0,
                    done_acc_vec: Vec::new(),
                    done_gathered: Vec::new(),
                }),
                collective_cv: Condvar::new(),
                coll_seqs: (0..size).map(|_| Mutex::new(HashMap::new())).collect(),
                fault_cfg: OnceLock::new(),
                counters: FaultCounters::default(),
                abort: fault::AbortCell::default(),
                watchdog_ms: AtomicU64::new(FaultConfig::default().watchdog_ms),
                dead: (0..size).map(|_| AtomicBool::new(false)).collect(),
            }),
        }
    }

    /// Next tree-collective sequence number for (rank, comm_id). Counter
    /// maps stay structurally sound across a peer's panic, so a poisoned
    /// lock is recovered rather than cascaded.
    pub(crate) fn next_coll_seq(&self, rank: usize, comm_id: u32) -> u64 {
        let mut seqs = self.inner.coll_seqs[rank]
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        let s = seqs.entry(comm_id).or_insert(0);
        let out = *s;
        *s += 1;
        out
    }

    /// Install the fault plan (first installer wins; later calls with the
    /// same deterministic config are no-ops). Must run on every rank
    /// before that rank's first send or receive — see `comm::fault`.
    pub fn install_faults(&self, cfg: FaultConfig) {
        let w = &self.inner;
        let cfg = w.fault_cfg.get_or_init(|| cfg);
        w.watchdog_ms.store(cfg.watchdog_ms, Ordering::SeqCst);
        for (i, mb) in w.mailboxes.iter().enumerate() {
            let mut inner = mb.inner.lock().unwrap_or_else(PoisonError::into_inner);
            if inner.rng.is_none() {
                inner.rng =
                    Some(XorShift::new(cfg.seed ^ (i as u64).wrapping_mul(0x9E3779B9)));
            }
        }
    }

    /// The installed fault plan, if any.
    pub fn fault_cfg(&self) -> Option<&FaultConfig> {
        self.inner.fault_cfg.get()
    }

    /// Snapshot of the injection/escalation counters.
    pub fn fault_stats(&self) -> FaultStats {
        self.inner.counters.snapshot()
    }

    /// Override the communication watchdog budget (tests shrink it to
    /// milliseconds to pin deadlock escalation without 60 s waits).
    pub fn set_watchdog(&self, d: Duration) {
        self.inner
            .watchdog_ms
            .store((d.as_millis() as u64).max(1), Ordering::SeqCst);
    }

    /// Current watchdog budget for communication/task waits.
    pub fn stall_limit(&self) -> Duration {
        Duration::from_millis(self.inner.watchdog_ms.load(Ordering::SeqCst))
    }

    /// Post a World-level abort: set the cell, then wake every rank by
    /// pushing a message on the reserved tag so blocked receivers drain
    /// promptly with `Error::Aborted`.
    pub fn post_abort(&self, origin: usize, reason: &str) {
        let w = &self.inner;
        if !w.abort.post(origin, reason) {
            return; // already aborted — the wakeup was broadcast once
        }
        w.counters.aborts_posted.fetch_add(1, Ordering::Relaxed);
        for mb in &w.mailboxes {
            let mut inner = mb.inner.lock().unwrap_or_else(PoisonError::into_inner);
            inner
                .queues
                .entry((origin, fault::ABORT_KEY))
                .or_default()
                .push_back(Payload::Bytes(Vec::new()));
            mb.cv.notify_all();
        }
    }

    /// True once any rank has posted an abort.
    pub fn aborted(&self) -> bool {
        self.inner.abort.is_set()
    }

    /// The abort as seen from `rank` (who aborted, and why).
    pub fn abort_error(&self, rank: usize) -> Error {
        self.inner.abort.error_for(rank)
    }

    /// Escalate a timeout/corruption into the World-level abort protocol
    /// (no-op for other errors — `Aborted` itself must not re-post).
    pub(crate) fn escalate(&self, rank: usize, e: &Error) {
        match e {
            Error::CorruptMessage { src, tag, .. } => {
                self.post_abort(
                    rank,
                    &format!("corrupt message from rank {src} tag {tag:#x}"),
                );
            }
            Error::Timeout { what, .. } => {
                self.inner.counters.timeouts.fetch_add(1, Ordering::Relaxed);
                self.post_abort(rank, &format!("timeout: {what}"));
            }
            _ => {}
        }
    }

    /// Consult the fault plan's kill schedule at the top of a cycle: when
    /// it fires, the rank is marked dead (its sends drop), the abort is
    /// posted, and the caller unwinds with the returned error.
    pub fn check_kill(&self, rank: usize, cycle: u64) -> Result<()> {
        let w = &self.inner;
        if let Some(cfg) = w.fault_cfg.get() {
            if cfg.kill_rank == rank as i64
                && cfg.kill_cycle >= 0
                && cycle == cfg.kill_cycle as u64
            {
                w.dead[rank].store(true, Ordering::SeqCst);
                w.counters.kills.fetch_add(1, Ordering::Relaxed);
                let reason = format!("simulated death of rank {rank} at cycle {cycle}");
                self.post_abort(rank, &reason);
                return Err(Error::Aborted { rank, origin: rank, reason });
            }
        }
        Ok(())
    }

    pub fn size(&self) -> usize {
        self.inner.size
    }

    /// The communication endpoint for `rank`. `comm_id` namespaces tags —
    /// one id per Variable, mirroring the paper's per-variable
    /// communicators.
    pub fn comm(&self, rank: usize, comm_id: u32) -> Comm {
        assert!(rank < self.inner.size);
        Comm { world: self.clone(), rank, comm_id, coll: CollMode::Tree }
    }

    /// Run `f(rank, world)` on `size` threads and join them, propagating
    /// panics. The standard launcher for multi-rank simulations and tests.
    pub fn launch<F>(size: usize, f: F) -> World
    where
        F: Fn(usize, World) + Send + Sync + 'static,
    {
        let world = World::new(size);
        let f = Arc::new(f);
        let mut handles = Vec::new();
        for rank in 0..size {
            let w = world.clone();
            let f = f.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("rank{rank}"))
                    .stack_size(32 * 1024 * 1024)
                    .spawn(move || f(rank, w))
                    .expect("spawn rank thread"),
            );
        }
        for h in handles {
            if let Err(e) = h.join() {
                std::panic::resume_unwind(e);
            }
        }
        world
    }
}

/// A rank's endpoint within one communicator.
#[derive(Clone)]
pub struct Comm {
    world: World,
    rank: usize,
    comm_id: u32,
    /// Algorithm for the blocking collective calls (tree by default; the
    /// flat generation-counted path is kept as the bitwise oracle).
    coll: CollMode,
}

/// Nonblocking receive handle (MPI_Irecv analog).
pub struct RecvHandle {
    comm: Comm,
    src: usize,
    tag: u64,
}

impl Comm {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.world.inner.size
    }

    /// Select the collective algorithm (builder-style; see [`CollMode`]).
    pub fn with_coll(mut self, coll: CollMode) -> Comm {
        self.coll = coll;
        self
    }

    /// The collective algorithm this endpoint's blocking calls use.
    pub fn coll_mode(&self) -> CollMode {
        self.coll
    }

    /// Draw the next tree-collective sequence number for this endpoint.
    pub(crate) fn next_coll_seq(&self) -> u64 {
        self.world.next_coll_seq(self.rank, self.comm_id)
    }

    /// The world this endpoint belongs to (watchdog budget, abort cell,
    /// fault counters).
    pub fn world(&self) -> &World {
        &self.world
    }

    /// Current watchdog budget for waits through this endpoint.
    pub fn stall_limit(&self) -> Duration {
        self.world.stall_limit()
    }

    /// Mark every pending wait in this world as doomed (called on the way
    /// into a collective-mismatch panic so peers fail fast) — now a thin
    /// wrapper over the World-level abort protocol.
    pub(crate) fn abort_collectives(&self) {
        self.world
            .post_abort(self.rank, "a peer rank failed a collective");
    }

    /// Fail promptly (with the abort's origin and reason) if any rank has
    /// posted a World-level abort.
    pub(crate) fn abort_check(&self) -> Result<()> {
        if self.world.aborted() {
            Err(self.world.abort_error(self.rank))
        } else {
            Ok(())
        }
    }

    #[inline]
    fn key(&self, tag: u64) -> u64 {
        // namespace the tag with the communicator id
        ((self.comm_id as u64) << 48) | (tag & 0xFFFF_FFFF_FFFF)
    }

    /// Lock a mailbox, mapping a poisoned lock (a peer panicked mid-send)
    /// to a rank-annotated error instead of a poison cascade.
    fn lock_mb<'a>(&self, mb: &'a Mailbox) -> Result<MutexGuard<'a, MailboxInner>> {
        mb.inner.lock().map_err(|_| {
            Error::Comm(format!(
                "mailbox lock poisoned on rank {}: a peer rank panicked mid-send",
                self.rank
            ))
        })
    }

    /// Nonblocking, eager send (MPI_Isend with buffered completion — the
    /// "one-sided" flavor of the paper: the sender never blocks). Under an
    /// installed framing fault plan the payload is checksum-framed and may
    /// be delayed, duplicated, reordered, or bit-flipped.
    pub fn isend(&self, dst: usize, tag: u64, payload: Payload) {
        let w = &self.world.inner;
        if w.dead[self.rank].load(Ordering::SeqCst) {
            w.counters.dead_sends_dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let key = (self.rank, self.key(tag));
        let mb = &w.mailboxes[dst];
        // A send must not fail in the eager/buffered model: recover the
        // (structurally sound) queues from a poisoned lock.
        let mut inner = mb.inner.lock().unwrap_or_else(PoisonError::into_inner);
        match w.fault_cfg.get().filter(|c| c.framing()) {
            Some(cfg) => {
                let seq = {
                    let s = inner.send_next.entry(key).or_insert(0);
                    let out = *s;
                    *s += 1;
                    out
                };
                let mut frame = fault::encode_frame(seq, &payload);
                let (dup, delay, reorder);
                {
                    let rng = inner.rng.as_mut().expect("fault rng installed");
                    if cfg.corrupt_prob > 0.0 && rng.chance(cfg.corrupt_prob) {
                        fault::flip_random_bit(&mut frame, rng);
                        w.counters.corrupted_injected.fetch_add(1, Ordering::Relaxed);
                    }
                    dup = cfg.dup_prob > 0.0 && rng.chance(cfg.dup_prob);
                    delay = cfg.delay_prob > 0.0 && rng.chance(cfg.delay_prob);
                    reorder = cfg.reorder_prob > 0.0 && rng.chance(cfg.reorder_prob);
                }
                if dup {
                    w.counters.duplicated.fetch_add(1, Ordering::Relaxed);
                    inner
                        .queues
                        .entry(key)
                        .or_default()
                        .push_back(Payload::Bytes(frame.clone()));
                }
                let q = if delay {
                    w.counters.delayed.fetch_add(1, Ordering::Relaxed);
                    inner.limbo.push((key, Payload::Bytes(frame)));
                    None
                } else {
                    Some(inner.queues.entry(key).or_default())
                };
                if let Some(q) = q {
                    if reorder {
                        w.counters.reordered.fetch_add(1, Ordering::Relaxed);
                        q.push_front(Payload::Bytes(frame));
                    } else {
                        q.push_back(Payload::Bytes(frame));
                    }
                }
            }
            None => {
                inner.queues.entry(key).or_default().push_back(payload);
            }
        }
        mb.cv.notify_all();
    }

    /// Nonblocking receive: returns a handle to poll.
    pub fn irecv(&self, src: usize, tag: u64) -> RecvHandle {
        RecvHandle { comm: self.clone(), src, tag: self.key(tag) }
    }

    /// Pop the next deliverable payload for `(src, key)` from a locked
    /// mailbox. With a framing fault plan installed this decodes frames,
    /// drops duplicates, reassembles send order through the sequence
    /// stash, and releases limbo'd (delayed) frames on a miss — so the
    /// caller sees exactly the sent sequence or `Error::CorruptMessage`.
    fn pop_locked(
        &self,
        inner: &mut MailboxInner,
        src: usize,
        key: u64,
    ) -> Result<Option<Payload>> {
        let w = &self.world.inner;
        if w.fault_cfg.get().filter(|c| c.framing()).is_none() {
            return Ok(inner.queues.get_mut(&(src, key)).and_then(|q| q.pop_front()));
        }
        let k = (src, key);
        loop {
            let next = *inner.recv_next.entry(k).or_insert(0);
            if let Some(p) = inner.stash.get_mut(&k).and_then(|s| s.remove(&next)) {
                inner.recv_next.insert(k, next + 1);
                return Ok(Some(p));
            }
            match inner.queues.get_mut(&k).and_then(|q| q.pop_front()) {
                Some(Payload::Bytes(frame)) => match fault::decode_frame(&frame) {
                    Some((seq, payload)) => {
                        if seq < next
                            || inner
                                .stash
                                .entry(k)
                                .or_default()
                                .insert(seq, payload)
                                .is_some()
                        {
                            // duplicate (already delivered or already
                            // stashed) — absorbed transparently
                            w.counters
                                .duplicates_dropped
                                .fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    None => {
                        w.counters
                            .corruption_detected
                            .fetch_add(1, Ordering::Relaxed);
                        return Err(Error::CorruptMessage {
                            src,
                            dst: self.rank,
                            tag: key,
                        });
                    }
                },
                Some(_) => {
                    // unframed payload under a framing plan: the install
                    // invariant was violated — report, don't mis-parse
                    return Err(Error::CorruptMessage { src, dst: self.rank, tag: key });
                }
                None => {
                    if inner.limbo.is_empty() {
                        return Ok(None);
                    }
                    // release delayed frames (they now arrive after every
                    // younger undelayed message) and retry
                    let limbo = std::mem::take(&mut inner.limbo);
                    for (lk, p) in limbo {
                        inner.queues.entry(lk).or_default().push_back(p);
                    }
                }
            }
        }
    }

    /// Immediate poll (MPI_Test + receive). Fails fast once a World-level
    /// abort is posted, so pending poll loops drain with `Error::Aborted`.
    pub fn try_recv(&self, src: usize, tag: u64) -> Result<Option<Payload>> {
        self.try_recv_key(src, self.key(tag))
    }

    fn try_recv_key(&self, src: usize, key: u64) -> Result<Option<Payload>> {
        if self.world.aborted() {
            return Err(self.world.abort_error(self.rank));
        }
        let mb = &self.world.inner.mailboxes[self.rank];
        let mut inner = self.lock_mb(mb)?;
        let r = self.pop_locked(&mut inner, src, key);
        drop(inner);
        if let Err(e) = &r {
            self.world.escalate(self.rank, e);
        }
        r
    }

    /// Blocking receive (MPI_Recv) with the watchdog: waits escalate to a
    /// rank/peer/tag-annotated `Error::Timeout` after the configured
    /// budget (posting the World abort so peers drain too), and drain with
    /// `Error::Aborted` when any rank has already aborted.
    pub fn recv(&self, src: usize, tag: u64) -> Result<Payload> {
        self.recv_key(src, self.key(tag), tag)
    }

    fn recv_key(&self, src: usize, key: u64, tag_for_err: u64) -> Result<Payload> {
        let limit = self.world.stall_limit();
        let start = Instant::now();
        let mb = &self.world.inner.mailboxes[self.rank];
        let mut inner = self.lock_mb(mb)?;
        loop {
            if self.world.aborted() {
                return Err(self.world.abort_error(self.rank));
            }
            match self.pop_locked(&mut inner, src, key) {
                Ok(Some(p)) => return Ok(p),
                Ok(None) => {}
                Err(e) => {
                    drop(inner);
                    self.world.escalate(self.rank, &e);
                    return Err(e);
                }
            }
            if start.elapsed() >= limit {
                drop(inner);
                let e = Error::Timeout {
                    what: "blocking recv".into(),
                    rank: Some(self.rank),
                    peer: Some(src),
                    tag: Some(tag_for_err),
                    elapsed: start.elapsed(),
                };
                self.world.escalate(self.rank, &e);
                return Err(e);
            }
            // bounded waits so the watchdog and the abort flag are
            // re-checked even if no wakeup ever arrives
            let step = limit
                .saturating_sub(start.elapsed())
                .min(Duration::from_millis(20));
            inner = match mb.cv.wait_timeout(inner, step) {
                Ok((g, _)) => g,
                Err(_) => {
                    return Err(Error::Comm(format!(
                        "mailbox lock poisoned on rank {}: a peer rank panicked \
                         mid-send",
                        self.rank
                    )))
                }
            };
        }
    }

    // -- collectives --------------------------------------------------------
    //
    // The blocking entry points dispatch on `self.coll`: Tree posts a
    // nonblocking tree handle (see `comm::coll`) and drains it; Flat runs
    // the original bulk-synchronous generation-counted exchange below,
    // kept as the bitwise oracle.

    fn collective<FEnter, FSnap, T>(
        &self,
        kind: u8,
        op: u8,
        len: u64,
        enter: FEnter,
        snap: FSnap,
    ) -> T
    where
        FEnter: FnOnce(&mut CollectiveState),
        FSnap: FnOnce(&CollectiveState) -> T,
    {
        let w = &self.world.inner;
        let mut st = match w.collective.lock() {
            Ok(g) => g,
            Err(_) => panic!(
                "collective state poisoned on rank {}: a peer rank failed a collective",
                self.rank
            ),
        };
        let my_gen = st.generation;
        if st.arrived == 0 {
            st.entered = (kind, op, len);
        } else if st.entered != (kind, op, len) {
            // fail fast with both entries named instead of deadlocking
            // (the lock poisons on the way out, waking blocked peers)
            self.abort_collectives();
            let (k0, o0, l0) = st.entered;
            panic!(
                "collective mismatch: rank {} entered {}(op={op}, len={len}) but an \
                 earlier rank entered {}(op={o0}, len={l0})",
                self.rank,
                coll::kind_name(kind),
                coll::kind_name(k0),
            );
        }
        enter(&mut st);
        st.arrived += 1;
        if st.arrived == w.size {
            // last arrival publishes results and advances the generation
            st.done_acc = st.acc;
            st.done_acc_u64 = st.acc_u64;
            st.done_acc_vec = std::mem::take(&mut st.acc_vec);
            st.done_gathered = st
                .gathered
                .iter_mut()
                .map(|g| g.take().unwrap_or_default())
                .collect();
            st.arrived = 0;
            st.generation += 1;
            w.collective_cv.notify_all();
        } else {
            while st.generation == my_gen {
                st = match w.collective_cv.wait(st) {
                    Ok(g) => g,
                    Err(_) => panic!(
                        "collective state poisoned on rank {}: a peer rank failed a \
                         collective",
                        self.rank
                    ),
                };
            }
        }
        snap(&st)
    }

    /// Unwrap a tree-collective result inside the infallible blocking
    /// wrappers: a timeout/abort/corruption here has no recovery at this
    /// level, so it surfaces as a panic carrying the rank-annotated error
    /// (absorbed by the recovery harness's per-rank catch).
    fn unwrap_coll<T>(r: Result<T>) -> T {
        r.unwrap_or_else(|e| panic!("collective failed: {e}"))
    }

    /// All-reduce a scalar.
    pub fn allreduce(&self, value: f64, op: ReduceOp) -> f64 {
        match self.coll {
            CollMode::Tree => Self::unwrap_coll(self.iallreduce(value, op).into_f64()),
            CollMode::Flat => self.allreduce_flat(value, op),
        }
    }

    fn allreduce_flat(&self, value: f64, op: ReduceOp) -> f64 {
        self.collective(
            coll::KIND_REDUCE,
            coll::op_code(op),
            1,
            |st| {
                if st.arrived == 0 {
                    st.acc = op.identity();
                }
                st.acc = op.apply(st.acc, value);
            },
            |st| st.done_acc,
        )
    }

    /// Exact integer sum-allreduce: u64 end to end, never routed through
    /// f64 (u64-in-f64 is exact only below 2^53).
    pub fn allreduce_u64(&self, value: u64) -> u64 {
        match self.coll {
            CollMode::Tree => Self::unwrap_coll(self.iallreduce_u64(value).into_u64()),
            CollMode::Flat => self.collective(
                coll::KIND_REDUCE_U64,
                0,
                1,
                |st| {
                    if st.arrived == 0 {
                        st.acc_u64 = 0;
                    }
                    st.acc_u64 = st
                        .acc_u64
                        .checked_add(value)
                        .expect("u64 allreduce overflow");
                },
                |st| st.done_acc_u64,
            ),
        }
    }

    /// Element-wise all-reduce of a vector (all ranks pass equal lengths).
    pub fn allreduce_vec(&self, values: &[f64], op: ReduceOp) -> Vec<f64> {
        match self.coll {
            CollMode::Tree => Self::unwrap_coll(self.iallreduce_vec(values, op).into_vec()),
            CollMode::Flat => {
                let vals = values.to_vec();
                self.collective(
                    coll::KIND_REDUCE,
                    coll::op_code(op),
                    vals.len() as u64,
                    move |st| {
                        if st.arrived == 0 {
                            st.acc_vec = vec![op.identity(); vals.len()];
                        }
                        for (a, v) in st.acc_vec.iter_mut().zip(&vals) {
                            *a = op.apply(*a, *v);
                        }
                    },
                    |st| st.done_acc_vec.clone(),
                )
            }
        }
    }

    /// Gather one byte blob from every rank, delivered to all (MPI_Allgatherv).
    pub fn allgather(&self, bytes: Vec<u8>) -> Vec<Vec<u8>> {
        match self.coll {
            CollMode::Tree => Self::unwrap_coll(self.iallgather(bytes).into_gathered()),
            CollMode::Flat => {
                let rank = self.rank;
                // blob lengths legitimately differ per rank: len is not
                // part of the gather guard
                self.collective(
                    coll::KIND_GATHER,
                    0,
                    0,
                    move |st| {
                        st.gathered[rank] = Some(bytes);
                    },
                    |st| st.done_gathered.clone(),
                )
            }
        }
    }

    /// Allgather a list of u64 ids (e.g. block gids), returned per rank.
    /// Used by the incremental rebalance to agree on the global set of
    /// blocks whose boundary data needs refreshing — each rank contributes
    /// its dirty-pack gids, every rank sees the union.
    pub fn allgather_u64s(&self, vals: &[u64]) -> Vec<Vec<u64>> {
        let mut bytes = Vec::with_capacity(vals.len() * 8);
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        self.allgather(bytes)
            .into_iter()
            .map(|blob| {
                blob.chunks_exact(8)
                    .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                    .collect()
            })
            .collect()
    }

    /// Barrier. Tree mode runs a dedicated dissemination barrier (no
    /// reduction payload rides along); flat mode synchronizes through the
    /// generation counter with its own kind tag, so a barrier meeting a
    /// reduction trips the mismatch guard instead of silently pairing.
    pub fn barrier(&self) {
        match self.coll {
            CollMode::Tree => Self::unwrap_coll(self.ibarrier().wait()),
            CollMode::Flat => self.collective(coll::KIND_BARRIER, 0, 0, |_| (), |_| ()),
        }
    }
}

impl RecvHandle {
    /// Poll for completion; consumes the message when available. Fails
    /// fast on a World-level abort or a poisoned mailbox.
    pub fn test(&self) -> Result<Option<Payload>> {
        self.comm.try_recv_key(self.src, self.tag)
    }

    /// Block until the message arrives (same watchdog/abort escalation as
    /// [`Comm::recv`]).
    pub fn wait(&self) -> Result<Payload> {
        self.comm.recv_key(self.src, self.tag, self.tag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pt2pt_roundtrip() {
        World::launch(2, |rank, world| {
            let comm = world.comm(rank, 0);
            if rank == 0 {
                comm.isend(1, 7, Payload::F32(vec![1.0, 2.0]));
                let back = comm.recv(1, 8).unwrap().into_f32().unwrap();
                assert_eq!(back, vec![3.0]);
            } else {
                let got = comm.recv(0, 7).unwrap().into_f32().unwrap();
                assert_eq!(got, vec![1.0, 2.0]);
                comm.isend(0, 8, Payload::F32(vec![3.0]));
            }
        });
    }

    #[test]
    fn fifo_per_source_tag() {
        World::launch(2, |rank, world| {
            let comm = world.comm(rank, 0);
            if rank == 0 {
                for i in 0..50 {
                    comm.isend(1, 1, Payload::F32(vec![i as f32]));
                }
            } else {
                for i in 0..50 {
                    let v = comm.recv(0, 1).unwrap().into_f32().unwrap();
                    assert_eq!(v[0], i as f32, "messages must stay ordered");
                }
            }
        });
    }

    #[test]
    fn communicators_do_not_collide() {
        World::launch(2, |rank, world| {
            let a = world.comm(rank, 1);
            let b = world.comm(rank, 2);
            if rank == 0 {
                b.isend(1, 5, Payload::F32(vec![2.0]));
                a.isend(1, 5, Payload::F32(vec![1.0]));
            } else {
                // same tag, different communicator: no cross-talk
                let va = a.recv(0, 5).unwrap().into_f32().unwrap();
                let vb = b.recv(0, 5).unwrap().into_f32().unwrap();
                assert_eq!(va, vec![1.0]);
                assert_eq!(vb, vec![2.0]);
            }
        });
    }

    #[test]
    fn irecv_poll() {
        World::launch(2, |rank, world| {
            let comm = world.comm(rank, 0);
            if rank == 0 {
                std::thread::sleep(std::time::Duration::from_millis(10));
                comm.isend(1, 3, Payload::Bytes(vec![9u8]));
            } else {
                let h = comm.irecv(0, 3);
                let mut polls = 0;
                let payload = loop {
                    if let Some(p) = h.test().unwrap() {
                        break p;
                    }
                    polls += 1;
                    std::thread::yield_now();
                };
                assert_eq!(payload.into_bytes().unwrap(), vec![9u8]);
                assert!(polls > 0 || true);
            }
        });
    }

    /// Every blocking collective, on both algorithms: the flat oracle and
    /// the default tree path must agree exactly.
    fn both_modes(f: impl Fn(CollMode) + Copy) {
        f(CollMode::Flat);
        f(CollMode::Tree);
    }

    #[test]
    fn allreduce_ops() {
        both_modes(|mode| {
            World::launch(4, move |rank, world| {
                let comm = world.comm(rank, 0).with_coll(mode);
                let v = (rank + 1) as f64;
                assert_eq!(comm.allreduce(v, ReduceOp::Sum), 10.0);
                assert_eq!(comm.allreduce(v, ReduceOp::Min), 1.0);
                assert_eq!(comm.allreduce(v, ReduceOp::Max), 4.0);
            });
        });
    }

    #[test]
    fn allreduce_u64_exact() {
        both_modes(|mode| {
            World::launch(3, move |rank, world| {
                let comm = world.comm(rank, 0).with_coll(mode);
                let v = (1u64 << 53) + rank as u64;
                assert_eq!(comm.allreduce_u64(v), 3 * (1u64 << 53) + 3);
                assert_eq!(comm.allreduce_u64(0), 0);
            });
        });
    }

    #[test]
    fn allreduce_vec_elementwise() {
        both_modes(|mode| {
            World::launch(3, move |rank, world| {
                let comm = world.comm(rank, 0).with_coll(mode);
                let v = vec![rank as f64, 10.0 * rank as f64];
                let r = comm.allreduce_vec(&v, ReduceOp::Sum);
                assert_eq!(r, vec![3.0, 30.0]);
            });
        });
    }

    #[test]
    fn allgather_delivers_everyone() {
        both_modes(|mode| {
            World::launch(3, move |rank, world| {
                let comm = world.comm(rank, 0).with_coll(mode);
                let got = comm.allgather(vec![rank as u8; rank + 1]);
                assert_eq!(got.len(), 3);
                for (r, blob) in got.iter().enumerate() {
                    assert_eq!(blob, &vec![r as u8; r + 1]);
                }
            });
        });
    }

    #[test]
    fn allgather_u64s_roundtrip() {
        both_modes(|mode| {
            World::launch(3, move |rank, world| {
                let comm = world.comm(rank, 0).with_coll(mode);
                let mine: Vec<u64> =
                    (0..rank as u64).map(|i| 100 * rank as u64 + i).collect();
                let got = comm.allgather_u64s(&mine);
                assert_eq!(got.len(), 3);
                assert_eq!(got[0], Vec::<u64>::new());
                assert_eq!(got[1], vec![100]);
                assert_eq!(got[2], vec![200, 201]);
            });
        });
    }

    #[test]
    fn barrier_runs_on_both_modes() {
        both_modes(|mode| {
            World::launch(3, move |rank, world| {
                let comm = world.comm(rank, 0).with_coll(mode);
                for _ in 0..5 {
                    comm.barrier();
                }
                // and interleaves cleanly with reductions
                assert_eq!(comm.allreduce(1.0, ReduceOp::Sum), 3.0);
            });
        });
    }

    #[test]
    fn repeated_collectives_stay_in_sync() {
        static COUNT: AtomicUsize = AtomicUsize::new(0);
        World::launch(4, |rank, world| {
            let comm = world.comm(rank, 0).with_coll(CollMode::Flat);
            for i in 0..100 {
                let s = comm.allreduce(i as f64, ReduceOp::Sum);
                assert_eq!(s, 4.0 * i as f64);
            }
            COUNT.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(COUNT.load(Ordering::SeqCst), 4);
    }

    #[test]
    #[should_panic(expected = "collective")]
    fn flat_mismatched_kinds_panic_not_deadlock() {
        World::launch(2, |rank, world| {
            let comm = world.comm(rank, 0).with_coll(CollMode::Flat);
            if rank == 0 {
                let _ = comm.allreduce(1.0, ReduceOp::Sum);
            } else {
                let _ = comm.allgather(vec![1, 2, 3]);
            }
        });
    }

    #[test]
    #[should_panic(expected = "collective")]
    fn flat_mismatched_vec_lengths_panic_not_deadlock() {
        World::launch(2, |rank, world| {
            let comm = world.comm(rank, 0).with_coll(CollMode::Flat);
            let v = vec![1.0; 2 + rank];
            let _ = comm.allreduce_vec(&v, ReduceOp::Sum);
        });
    }

    #[test]
    #[should_panic(expected = "collective")]
    fn flat_mismatched_ops_panic_not_deadlock() {
        World::launch(2, |rank, world| {
            let comm = world.comm(rank, 0).with_coll(CollMode::Flat);
            let op = if rank == 0 { ReduceOp::Min } else { ReduceOp::Max };
            let _ = comm.allreduce(1.0, op);
        });
    }

    #[test]
    #[should_panic]
    fn rank_panic_propagates() {
        World::launch(2, |rank, _| {
            if rank == 1 {
                panic!("boom");
            }
        });
    }

    // -- fault injection -----------------------------------------------------

    fn faulty(delay: f64, dup: f64, reorder: f64) -> FaultConfig {
        FaultConfig {
            seed: 42,
            delay_prob: delay,
            dup_prob: dup,
            reorder_prob: reorder,
            ..FaultConfig::default()
        }
    }

    /// Delay/dup/reorder must be absorbed transparently by the framing
    /// sequence machinery: the receiver sees the exact sent order.
    #[test]
    fn faulty_fabric_preserves_send_order() {
        let plans = [
            faulty(0.5, 0.0, 0.0),
            faulty(0.0, 0.5, 0.0),
            faulty(0.0, 0.0, 0.5),
            faulty(0.3, 0.3, 0.3),
        ];
        for cfg in plans {
            World::launch(2, move |rank, world| {
                world.install_faults(cfg.clone());
                let comm = world.comm(rank, 0);
                if rank == 0 {
                    for i in 0..200 {
                        comm.isend(1, 1, Payload::F32(vec![i as f32]));
                    }
                } else {
                    for i in 0..200 {
                        let v = comm.recv(0, 1).unwrap().into_f32().unwrap();
                        assert_eq!(v[0], i as f32, "frame order must survive faults");
                    }
                }
            });
        }
    }

    /// Corruption is detected by the checksum, never silently absorbed.
    #[test]
    fn corruption_surfaces_as_error() {
        World::launch(2, |rank, world| {
            world.install_faults(FaultConfig {
                seed: 7,
                corrupt_prob: 1.0,
                watchdog_ms: 5_000,
                ..FaultConfig::default()
            });
            let comm = world.comm(rank, 0);
            if rank == 0 {
                comm.isend(1, 9, Payload::F32(vec![1.0, 2.0, 3.0]));
                // rank 1's detection posts the world abort; don't hang here
            } else {
                match comm.recv(0, 9) {
                    Err(Error::CorruptMessage { src, dst, .. }) => {
                        assert_eq!((src, dst), (0, 1));
                        assert!(world.aborted(), "corruption must post the abort");
                    }
                    other => panic!("expected CorruptMessage, got {other:?}"),
                }
                assert!(world.fault_stats().corruption_detected >= 1);
            }
        });
    }

    /// A blocking recv with no sender escalates to a rank/peer-annotated
    /// timeout within the watchdog budget, and the posted abort drains the
    /// OTHER rank's unrelated recv with `Aborted` (no hang anywhere).
    #[test]
    fn recv_timeout_escalates_and_peers_drain() {
        let t0 = std::time::Instant::now();
        World::launch(2, |rank, world| {
            world.set_watchdog(Duration::from_millis(200));
            let comm = world.comm(rank, 0);
            if rank == 0 {
                match comm.recv(1, 77) {
                    Err(Error::Timeout { rank, peer, .. }) => {
                        assert_eq!((rank, peer), (Some(0), Some(1)));
                    }
                    Err(Error::Aborted { .. }) => {} // rank 1 timed out first
                    other => panic!("expected Timeout/Aborted, got {other:?}"),
                }
            } else {
                match comm.recv(0, 78) {
                    Err(Error::Timeout { .. }) | Err(Error::Aborted { .. }) => {}
                    other => panic!("expected Timeout/Aborted, got {other:?}"),
                }
            }
        });
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "escalation must beat the old 60s stall limit by far"
        );
    }

    /// The kill schedule fires exactly at (rank, cycle), marks the rank
    /// dead, and posts the abort.
    #[test]
    fn kill_schedule_fires_once() {
        World::launch(2, |rank, world| {
            world.install_faults(FaultConfig {
                kill_rank: 1,
                kill_cycle: 3,
                ..FaultConfig::default()
            });
            for cycle in 0..3 {
                assert!(world.check_kill(rank, cycle).is_ok());
            }
            if rank == 1 {
                let e = world.check_kill(1, 3).unwrap_err();
                assert!(matches!(e, Error::Aborted { origin: 1, .. }));
                assert!(world.aborted());
                assert_eq!(world.fault_stats().kills, 1);
            }
        });
    }
}
