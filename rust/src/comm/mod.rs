//! Communication substrate.
//!
//! The paper runs on MPI with one-sided, asynchronous, GPU-aware calls and
//! per-variable communicators. This machine has no MPI, so `simmpi`
//! implements the same *structure* in-process: rank = OS thread, mailbox =
//! lock-protected queues keyed by (source, tag), nonblocking send/recv
//! handles, per-communicator id spaces (so per-variable communicators work
//! exactly as in Sec. 3.7 — no 32,767 tag-bound problem, but we keep the
//! same tag-encoding discipline).
//!
//! Collectives come in two algorithms, selected per endpoint by
//! [`CollMode`] (`parthenon/comm coll`, default `tree`):
//!
//! * `coll` — nonblocking tree-structured exchanges over the pt2pt
//!   mailboxes (binomial reduce+broadcast, dissemination barrier):
//!   O(log P) hops per rank, pollable [`CollHandle`]s that sit on the
//!   task graph (the overlapped dt reduction).
//! * `simmpi`'s generation-counted bulk-synchronous path — O(P)
//!   serialized lock acquisitions, kept as the bitwise oracle the tree
//!   path is tested against.
//!
//! `fault` adds a deterministic, seed-driven fault-injection plan
//! (`parthenon/fault`) over the mailbox path — delay/duplicate/reorder/
//! corrupt plus simulated rank death — with checksum framing, a World-level
//! cooperative-abort protocol, and the configurable communication watchdog
//! that every wait in the crate escalates through.

pub mod coll;
pub mod fault;
mod simmpi;
pub mod tags;

pub use coll::{CollHandle, CollMode};
pub use fault::{FaultConfig, FaultCounters};
pub use simmpi::{Comm, Payload, RecvHandle, ReduceOp, World};
