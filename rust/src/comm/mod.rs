//! Communication substrate.
//!
//! The paper runs on MPI with one-sided, asynchronous, GPU-aware calls and
//! per-variable communicators. This machine has no MPI, so `simmpi`
//! implements the same *structure* in-process: rank = OS thread, mailbox =
//! lock-protected queues keyed by (source, tag), nonblocking send/recv
//! handles, per-communicator id spaces (so per-variable communicators work
//! exactly as in Sec. 3.7 — no 32,767 tag-bound problem, but we keep the
//! same tag-encoding discipline), tree-free allgather and generation-counted
//! allreduce/barrier collectives.

mod simmpi;
pub mod tags;

pub use simmpi::{Comm, Payload, RecvHandle, ReduceOp, World};
