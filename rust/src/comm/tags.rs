//! Tag encoding for boundary/particle/migration messages.
//!
//! The paper (Sec. 3.7) avoids the 32,767 MPI tag bound by giving every
//! Variable its own communicator and creating buffer tags sequentially.  We
//! keep the same discipline: the communicator id carries the variable, the
//! tag carries (receiving block gid, neighbor slot on the receiving side).
//! Tags are unique per (comm, src, dst, cycle-phase) by construction because
//! each (recv block, neighbor slot) pair receives at most one message per
//! communication phase.

/// Communicator ids (one "MPI communicator" per logical channel).
pub const COMM_FLUX: u32 = 1;
pub const COMM_BVALS_BASE: u32 = 8; // + variable index
pub const COMM_PARTICLES_BASE: u32 = 4096; // + swarm index
pub const COMM_MIGRATE: u32 = 2;

/// Boundary-buffer tag: the receiving block's gid and an 11-bit sub-id
/// (message class << 8 | neighbor slot << 3 | sending child code).
#[inline]
pub fn bval_tag(recv_gid: usize, sub: usize) -> u64 {
    debug_assert!(sub < 2048);
    ((recv_gid as u64) << 11) | (sub as u64 & 0x7FF)
}

/// Flux-correction tag: receiving (coarse) block gid + face index (0..6).
#[inline]
pub fn flux_tag(recv_gid: usize, face: usize, child_slot: usize) -> u64 {
    ((recv_gid as u64) << 6) | ((face as u64) << 3) | (child_slot as u64 & 0x7)
}

/// Particle-migration tag: receiving block gid + sending neighbor slot.
#[inline]
pub fn particle_tag(recv_gid: usize, recv_nbr_index: usize) -> u64 {
    ((recv_gid as u64) << 6) | (recv_nbr_index as u64 & 0x3F)
}

/// Block-migration tag (regrid/load balance): the new gid being filled.
#[inline]
pub fn migrate_tag(new_gid: usize, piece: usize) -> u64 {
    ((new_gid as u64) << 4) | (piece as u64 & 0xF)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn bval_tags_unique_per_block_slot_child() {
        let mut seen = HashSet::new();
        for gid in 0..200 {
            for slot in 0..26 {
                for child in 0..8 {
                    assert!(seen.insert(bval_tag(gid, (slot << 3) | child)));
                }
            }
        }
    }

    #[test]
    fn flux_tags_unique() {
        let mut seen = HashSet::new();
        for gid in 0..100 {
            for face in 0..6 {
                for child in 0..4 {
                    assert!(seen.insert(flux_tag(gid, face, child)));
                }
            }
        }
    }

    #[test]
    fn tag_spaces_scale_past_mpi_bound() {
        // the paper's problem: >32767 buffers per rank — our tags stay unique
        let t1 = bval_tag(40_000, 25);
        let t2 = bval_tag(40_001, 0);
        assert_ne!(t1, t2);
        assert!(t1 > 32_767);
    }
}
