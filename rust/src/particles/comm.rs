//! Particle communication (paper Sec. 3.5): after a position update,
//! particles that left their MeshBlock are sent to the owning neighbor
//! (periodic boundaries wrap coordinates, outflow boundaries absorb).
//!
//! One transport *round* moves particles by at most one block; algorithms
//! whose particles cross several blocks per step call [`transport_round`]
//! repeatedly until the globally-reduced moved-count reaches zero — the
//! paper's "blocking TaskRegion repeatedly called until a global stop
//! criterion is met".
//!
//! Like Parthenon ("only communication to neighboring meshblocks is
//! supported"), transport is supported on uniform meshes; every (block,
//! neighbor-slot) edge carries exactly one message per round, so the
//! receive set is deterministic and deadlock-free even under periodic
//! self-adjacency.

use crate::comm::{tags, Comm, Payload};
use crate::error::{Error, Result};
use crate::mesh::{Mesh, NeighborKind};

/// One transport round for `swarm` on every local block. Returns the number
/// of particles this rank sent (reduce across ranks to detect completion).
pub fn transport_round(mesh: &mut Mesh, comm: &Comm, swarm: &str) -> Result<usize> {
    if mesh.tree.max_level() != 0 {
        return Err(Error::Comm(
            "particle transport requires a uniform mesh".into(),
        ));
    }
    let dim = mesh.cfg.dim;
    let domain = mesh.cfg.domain;
    let _bcs = mesh.cfg.bcs;
    let periodic = mesh.cfg.periodic_flags();
    let opp = crate::bvals::bufspec::opposite_index(dim);

    let mut moved = 0usize;

    // -- classify & send: one message per (block, slot) edge -------------------
    for bi in 0..mesh.blocks.len() {
        let loc = mesh.blocks[bi].loc;
        let coords = mesh.blocks[bi].coords;
        let neighbors = mesh.tree.find_neighbors(&loc);
        let nslots = neighbors.len();
        let mut outbound: Vec<Vec<usize>> = vec![Vec::new(); nslots];

        if let Some(sw) = mesh.blocks[bi].swarms.get_mut(swarm) {
            for idx in sw.active_indices() {
                let mut off = [0i32; 3];
                let mut gone = false;
                let pos = [
                    sw.real_field("x")?[idx] as f64,
                    sw.real_field("y")?[idx] as f64,
                    sw.real_field("z")?[idx] as f64,
                ];
                for d in 0..dim {
                    if pos[d] < coords.xmin[d] {
                        off[d] = -1;
                    } else if pos[d] >= coords.xmax(d) {
                        off[d] = 1;
                    }
                    if off[d] != 0 {
                        let below = pos[d] < domain.xmin[d];
                        let above = pos[d] >= domain.xmax[d];
                        if below || above {
                            if periodic[d] {
                                let w = domain.width(d) as f32;
                                let name = ["x", "y", "z"][d];
                                let f = sw.real_field_mut(name)?;
                                if below {
                                    f[idx] += w;
                                } else {
                                    f[idx] -= w;
                                }
                            } else {
                                // outflow/reflect domain edges absorb

                                gone = true;
                            }
                        }
                    }
                }
                if gone {
                    sw.remove(idx);
                    continue;
                }
                if off != [0, 0, 0] {
                    let slot = neighbors
                        .iter()
                        .position(|n| n.offset == off)
                        .expect("offset must be a neighbor slot");
                    outbound[slot].push(idx);
                }
            }
        } else {
            continue;
        }

        for (slot, idxs) in outbound.iter().enumerate() {
            let nloc = match &neighbors[slot].kind {
                NeighborKind::SameLevel(l) => l,
                NeighborKind::Physical => {
                    debug_assert!(idxs.is_empty(), "physical-slot particles must be absorbed");
                    continue;
                }
                _ => unreachable!("uniform mesh"),
            };
            let sw = mesh.blocks[bi].swarms.get_mut(swarm).unwrap();
            let bytes = sw.extract(idxs);
            moved += idxs.len();
            let ngid = mesh.tree.gid_of(nloc).unwrap();
            // the receiver's slot is the opposite offset of ours
            comm.isend(
                mesh.ranks[ngid],
                tags::particle_tag(ngid, opp[slot]),
                Payload::Bytes(bytes),
            );
        }
    }

    // -- receive: exactly one message per (block, slot) edge -------------------
    for bi in 0..mesh.blocks.len() {
        let loc = mesh.blocks[bi].loc;
        let gid = mesh.blocks[bi].gid;
        let neighbors = mesh.tree.find_neighbors(&loc);
        for (slot, nb) in neighbors.iter().enumerate() {
            let NeighborKind::SameLevel(nloc) = &nb.kind else { continue };
            let sgid = mesh.tree.gid_of(nloc).unwrap();
            let payload = comm
                .recv(mesh.ranks[sgid], tags::particle_tag(gid, slot))?
                .into_bytes()?;
            if payload.is_empty() {
                continue;
            }
            if let Some(sw) = mesh.blocks[bi].swarms.get_mut(swarm) {
                sw.insert_bytes(&payload)?;
            }
        }
    }

    Ok(moved)
}

/// Transport until globally quiescent (max `max_rounds` to bound runaways).
///
/// The stop criterion counts moved particles *exactly*: the per-round
/// reduction is an integer-safe `iallreduce_u64` (a f64 Sum would silently
/// lose counts past 2^53 and can't be trusted as an == 0 test under
/// reassociation), and on the tree path the handle is polled while this
/// rank keeps draining its own inbound particle messages.
pub fn transport_until_done(
    mesh: &mut Mesh,
    comm: &Comm,
    swarm: &str,
    max_rounds: usize,
) -> Result<usize> {
    let mut total = 0usize;
    for _ in 0..max_rounds {
        let moved = transport_round(mesh, comm, swarm)?;
        total += moved;
        // The round's sends/receives are fully drained by
        // `transport_round` (one message per edge), so the collective is
        // the only outstanding traffic; on the tree path this posts an
        // `iallreduce_u64` whose handle is polled right here, and ranks
        // that finish their local round early progress the tree while
        // stragglers are still mid-round. Flat keeps the blocking oracle.
        let global = comm.allreduce_u64(moved as u64);
        if global == 0 {
            return Ok(total);
        }
    }
    Ok(total)
}
