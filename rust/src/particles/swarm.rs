//! The Swarm: SoA particle storage with a x2-growing memory pool, a free
//! list, masked validity, Defrag, and byte (de)serialization for migration.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::Real;

/// One particle field: real- or integer-valued, one entry per pool slot.
#[derive(Debug, Clone)]
pub enum ParticleData {
    Real(Vec<Real>),
    Int(Vec<i64>),
}

impl ParticleData {
    fn resize(&mut self, n: usize) {
        match self {
            ParticleData::Real(v) => v.resize(n, 0.0),
            ParticleData::Int(v) => v.resize(n, 0),
        }
    }

    fn copy_within(&mut self, from: usize, to: usize) {
        match self {
            ParticleData::Real(v) => v[to] = v[from],
            ParticleData::Int(v) => v[to] = v[from],
        }
    }

    pub fn len(&self) -> usize {
        match self {
            ParticleData::Real(v) => v.len(),
            ParticleData::Int(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Field registration: name + kind.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SwarmField {
    Real(String),
    Int(String),
}

/// A swarm of particles on one MeshBlock.
///
/// Always carries the real-valued fields `x`, `y`, `z`; packages enroll
/// additional fields at creation. Slots are reused through a free list; the
/// pool grows by factors of two; `defrag` compacts storage on demand.
#[derive(Debug, Clone)]
pub struct Swarm {
    pub name: String,
    fields: BTreeMap<String, ParticleData>,
    mask: Vec<bool>,
    free: Vec<usize>,
    nactive: usize,
}

pub const INITIAL_POOL: usize = 16;

impl Swarm {
    pub fn new(name: &str, extra_fields: &[SwarmField]) -> Self {
        let mut fields = BTreeMap::new();
        for coord in ["x", "y", "z"] {
            fields.insert(coord.to_string(), ParticleData::Real(vec![0.0; INITIAL_POOL]));
        }
        for f in extra_fields {
            match f {
                SwarmField::Real(n) => {
                    fields.insert(n.clone(), ParticleData::Real(vec![0.0; INITIAL_POOL]));
                }
                SwarmField::Int(n) => {
                    fields.insert(n.clone(), ParticleData::Int(vec![0; INITIAL_POOL]));
                }
            }
        }
        Swarm {
            name: name.to_string(),
            fields,
            mask: vec![false; INITIAL_POOL],
            free: (0..INITIAL_POOL).rev().collect(),
            nactive: 0,
        }
    }

    pub fn pool_size(&self) -> usize {
        self.mask.len()
    }

    pub fn num_active(&self) -> usize {
        self.nactive
    }

    pub fn field_names(&self) -> impl Iterator<Item = &str> {
        self.fields.keys().map(|s| s.as_str())
    }

    /// Request `n` new particles; returns their slot indices. Free slots are
    /// consumed first, then the pool doubles until it fits (paper Sec. 3.5).
    pub fn add_particles(&mut self, n: usize) -> Vec<usize> {
        while self.free.len() < n {
            let old = self.pool_size();
            let new = (old * 2).max(INITIAL_POOL);
            for f in self.fields.values_mut() {
                f.resize(new);
            }
            self.mask.resize(new, false);
            for idx in (old..new).rev() {
                self.free.push(idx);
            }
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let idx = self.free.pop().unwrap();
            self.mask[idx] = true;
            out.push(idx);
        }
        self.nactive += n;
        out
    }

    /// Remove one particle (slot becomes reusable).
    pub fn remove(&mut self, idx: usize) {
        if self.mask[idx] {
            self.mask[idx] = false;
            self.free.push(idx);
            self.nactive -= 1;
        }
    }

    pub fn is_active(&self, idx: usize) -> bool {
        self.mask[idx]
    }

    /// Iterate active slot indices.
    pub fn active_indices(&self) -> Vec<usize> {
        (0..self.pool_size()).filter(|&i| self.mask[i]).collect()
    }

    pub fn real_field(&self, name: &str) -> Result<&[Real]> {
        match self.fields.get(name) {
            Some(ParticleData::Real(v)) => Ok(v),
            Some(_) => Err(Error::Variable(format!("swarm field {name:?} is not real"))),
            None => Err(Error::Variable(format!("no swarm field {name:?}"))),
        }
    }

    pub fn real_field_mut(&mut self, name: &str) -> Result<&mut [Real]> {
        match self.fields.get_mut(name) {
            Some(ParticleData::Real(v)) => Ok(v),
            Some(_) => Err(Error::Variable(format!("swarm field {name:?} is not real"))),
            None => Err(Error::Variable(format!("no swarm field {name:?}"))),
        }
    }

    pub fn int_field_mut(&mut self, name: &str) -> Result<&mut [i64]> {
        match self.fields.get_mut(name) {
            Some(ParticleData::Int(v)) => Ok(v),
            Some(_) => Err(Error::Variable(format!("swarm field {name:?} is not int"))),
            None => Err(Error::Variable(format!("no swarm field {name:?}"))),
        }
    }

    pub fn int_field(&self, name: &str) -> Result<&[i64]> {
        match self.fields.get(name) {
            Some(ParticleData::Int(v)) => Ok(v),
            Some(_) => Err(Error::Variable(format!("swarm field {name:?} is not int"))),
            None => Err(Error::Variable(format!("no swarm field {name:?}"))),
        }
    }

    /// Compact storage: move every active particle into the leading slots
    /// (deep per-field copies, as in the paper's Defrag).
    pub fn defrag(&mut self) {
        let mut dst = 0usize;
        for src in 0..self.pool_size() {
            if self.mask[src] {
                if src != dst {
                    for f in self.fields.values_mut() {
                        f.copy_within(src, dst);
                    }
                    self.mask[dst] = true;
                    self.mask[src] = false;
                }
                dst += 1;
            }
        }
        self.free = (dst..self.pool_size()).rev().collect();
        debug_assert_eq!(self.nactive, dst);
    }

    /// True if the active particles occupy a contiguous prefix.
    pub fn is_contiguous(&self) -> bool {
        let mut seen_hole = false;
        for &m in &self.mask {
            if !m {
                seen_hole = true;
            } else if seen_hole {
                return false;
            }
        }
        true
    }

    // -- migration ----------------------------------------------------------

    /// Serialize the given particles into bytes (field order = BTreeMap
    /// order, so both sides agree) and remove them from this swarm.
    pub fn extract(&mut self, indices: &[usize]) -> Vec<u8> {
        let mut out = Vec::with_capacity(indices.len() * self.fields.len() * 8);
        for &idx in indices {
            debug_assert!(self.mask[idx]);
            for f in self.fields.values() {
                match f {
                    ParticleData::Real(v) => out.extend_from_slice(&v[idx].to_le_bytes()),
                    ParticleData::Int(v) => out.extend_from_slice(&v[idx].to_le_bytes()),
                }
            }
        }
        for &idx in indices {
            self.remove(idx);
        }
        out
    }

    /// Bytes per particle in the wire format.
    pub fn particle_wire_size(&self) -> usize {
        self.fields
            .values()
            .map(|f| match f {
                ParticleData::Real(_) => std::mem::size_of::<Real>(),
                ParticleData::Int(_) => 8,
            })
            .sum()
    }

    /// Deserialize particles received from a neighbor into this swarm.
    pub fn insert_bytes(&mut self, bytes: &[u8]) -> Result<Vec<usize>> {
        let psize = self.particle_wire_size();
        if psize == 0 || bytes.len() % psize != 0 {
            return Err(Error::Comm(format!(
                "swarm {}: bad particle payload size {} (particle = {psize}B)",
                self.name,
                bytes.len()
            )));
        }
        let n = bytes.len() / psize;
        let slots = self.add_particles(n);
        let mut off = 0usize;
        for &slot in &slots {
            for f in self.fields.values_mut() {
                match f {
                    ParticleData::Real(v) => {
                        let sz = std::mem::size_of::<Real>();
                        let mut b = [0u8; 4];
                        b.copy_from_slice(&bytes[off..off + sz]);
                        v[slot] = Real::from_le_bytes(b);
                        off += sz;
                    }
                    ParticleData::Int(v) => {
                        let mut b = [0u8; 8];
                        b.copy_from_slice(&bytes[off..off + 8]);
                        v[slot] = i64::from_le_bytes(b);
                        off += 8;
                    }
                }
            }
        }
        Ok(slots)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn swarm() -> Swarm {
        Swarm::new("tracers", &[SwarmField::Real("w".into()), SwarmField::Int("id".into())])
    }

    #[test]
    fn pool_grows_by_doubling() {
        let mut s = swarm();
        assert_eq!(s.pool_size(), INITIAL_POOL);
        s.add_particles(INITIAL_POOL + 1);
        assert_eq!(s.pool_size(), 2 * INITIAL_POOL);
        assert_eq!(s.num_active(), INITIAL_POOL + 1);
        s.add_particles(2 * INITIAL_POOL);
        assert_eq!(s.pool_size(), 4 * INITIAL_POOL, "doubles until it fits");
    }

    #[test]
    fn free_slots_reused_before_growth() {
        let mut s = swarm();
        let idx = s.add_particles(4);
        s.remove(idx[1]);
        s.remove(idx[2]);
        let idx2 = s.add_particles(2);
        assert_eq!(s.pool_size(), INITIAL_POOL);
        assert!(idx2.contains(&idx[1]) && idx2.contains(&idx[2]));
    }

    #[test]
    fn defrag_compacts() {
        let mut s = swarm();
        let idx = s.add_particles(6);
        let xs = s.real_field_mut("x").unwrap();
        for (n, &i) in idx.iter().enumerate() {
            xs[i] = n as Real;
        }
        s.remove(idx[0]);
        s.remove(idx[2]);
        s.remove(idx[4]);
        assert!(!s.is_contiguous() || s.num_active() == 0);
        s.defrag();
        assert!(s.is_contiguous());
        assert_eq!(s.num_active(), 3);
        let survivors: Vec<Real> = s
            .active_indices()
            .iter()
            .map(|&i| s.real_field("x").unwrap()[i])
            .collect();
        let mut sorted = survivors.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(sorted, vec![1.0, 3.0, 5.0]);
    }

    #[test]
    fn extract_insert_roundtrip() {
        let mut a = swarm();
        let idx = a.add_particles(3);
        for (n, &i) in idx.iter().enumerate() {
            a.real_field_mut("x").unwrap()[i] = 0.5 + n as Real;
            a.real_field_mut("w").unwrap()[i] = 10.0 * n as Real;
            a.int_field_mut("id").unwrap()[i] = 100 + n as i64;
        }
        let bytes = a.extract(&[idx[0], idx[2]]);
        assert_eq!(a.num_active(), 1);

        let mut b = swarm();
        let got = b.insert_bytes(&bytes).unwrap();
        assert_eq!(got.len(), 2);
        let xs: Vec<Real> = got.iter().map(|&i| b.real_field("x").unwrap()[i]).collect();
        assert_eq!(xs, vec![0.5, 2.5]);
        let ids: Vec<i64> = got.iter().map(|&i| b.int_field("id").unwrap()[i]).collect();
        assert_eq!(ids, vec![100, 102]);
    }

    #[test]
    fn insert_rejects_ragged_payload() {
        let mut s = swarm();
        assert!(s.insert_bytes(&[0u8; 7]).is_err());
    }
}
