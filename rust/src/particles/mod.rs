//! Particles: Swarms (paper Sec. 3.5) — struct-of-arrays particle storage
//! per MeshBlock with dynamic pools, defragmentation, and neighbor-block
//! communication.

pub mod comm;
mod swarm;

pub use comm::{transport_round, transport_until_done};
pub use swarm::{ParticleData, Swarm, SwarmField};
