//! PJRT client wrapper: compile HLO-text artifacts once per rank, execute
//! them with flat f32 staging buffers.

use std::collections::HashMap;
use std::sync::Arc;

use super::manifest::{ArtifactKey, Manifest};
use crate::bvals::bufspec;
use crate::error::Result;
use crate::mesh::IndexShape;
use crate::{Real, NHYDRO};

/// Scalar argument vector of the artifacts:
/// [g0, g1, beta, dt, dx, dy, dz, gamma].
#[derive(Debug, Clone, Copy)]
pub struct ScalArgs {
    pub g0: Real,
    pub g1: Real,
    pub beta: Real,
    pub dt: Real,
    pub dx: [Real; 3],
    pub gamma: Real,
}

impl ScalArgs {
    pub fn to_vec(self) -> Vec<Real> {
        vec![
            self.g0, self.g1, self.beta, self.dt, self.dx[0], self.dx[1], self.dx[2],
            self.gamma,
        ]
    }
}

/// Per-rank device runtime: PJRT CPU client + lazily compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Arc<Manifest>,
    cache: HashMap<ArtifactKey, xla::PjRtLoadedExecutable>,
    /// Number of executable invocations ("kernel launches") so far.
    pub launches: u64,
}

impl Runtime {
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        Self::with_manifest(Arc::new(Manifest::load(dir)?))
    }

    pub fn with_manifest(manifest: Arc<Manifest>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, manifest, cache: HashMap::new(), launches: 0 })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch cached) the executable for `key`.
    fn exe(&mut self, key: &ArtifactKey) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(key) {
            let path = self.manifest.path(key)?;
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(key.clone(), exe);
        }
        Ok(self.cache.get(key).unwrap())
    }

    /// Eagerly compile an artifact (startup warmup, outside timed regions).
    pub fn warmup(&mut self, key: &ArtifactKey) -> Result<()> {
        self.exe(key).map(|_| ())
    }

    pub fn num_compiled(&self) -> usize {
        self.cache.len()
    }

    // -- shape helpers -------------------------------------------------------

    fn u_dims(key: &ArtifactKey) -> [usize; 5] {
        let shape = IndexShape::new(key.dim, key.n);
        let (zt, yt, xt) = shape.total_zyx();
        [key.nb, NHYDRO, zt, yt, xt]
    }

    /// Elements in one block's [NVAR, Z, Y, X] slab.
    pub fn block_elems(key: &ArtifactKey) -> usize {
        let shape = IndexShape::new(key.dim, key.n);
        NHYDRO * shape.ncells_total()
    }

    /// Flat boundary-buffer length per block.
    pub fn buflen(key: &ArtifactKey) -> usize {
        let shape = IndexShape::new(key.dim, key.n);
        bufspec::buflen(&shape, NHYDRO)
    }

    /// Upload a host slice directly to a device buffer (single copy; the
    /// Literal::vec1 + reshape route costs two — see EXPERIMENTS.md §Perf).
    fn buf(&self, data: &[Real], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    fn run_b(
        &mut self,
        key: &ArtifactKey,
        inputs: &[xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        self.launches += 1;
        let exe = self.exe(key)?;
        let result = exe.execute_b::<xla::PjRtBuffer>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    // -- artifact entry points ------------------------------------------------

    /// `stage`: (u, u0, scal) -> u_new (written into `out`).
    pub fn stage(
        &mut self,
        key: &ArtifactKey,
        u: &[Real],
        u0: &[Real],
        scal: ScalArgs,
        out: &mut [Real],
    ) -> Result<()> {
        let dims = Self::u_dims(key);
        let inputs = [
            self.buf(u, &dims)?,
            self.buf(u0, &dims)?,
            self.buf(&scal.to_vec(), &[8])?,
        ];
        let outs = self.run_b(key, &inputs)?;
        outs[0].copy_raw_to(out)?;
        Ok(())
    }

    /// `dt`: (u, scal) -> per-block CFL dt [nb].
    pub fn dt(&mut self, key: &ArtifactKey, u: &[Real], scal: ScalArgs) -> Result<Vec<Real>> {
        let dims = Self::u_dims(key);
        let inputs = [self.buf(u, &dims)?, self.buf(&scal.to_vec(), &[8])?];
        let outs = self.run_b(key, &inputs)?;
        Ok(outs[0].to_vec::<Real>()?)
    }

    /// `pack`: u -> all boundary buffers [nb, BUFLEN] (into `bufs`).
    pub fn pack(&mut self, key: &ArtifactKey, u: &[Real], bufs: &mut [Real]) -> Result<()> {
        let dims = Self::u_dims(key);
        let inputs = [self.buf(u, &dims)?];
        let outs = self.run_b(key, &inputs)?;
        outs[0].copy_raw_to(bufs)?;
        Ok(())
    }

    /// `pack1` (per-neighbor): u -> one buffer segment.
    pub fn pack1(&mut self, key: &ArtifactKey, u: &[Real]) -> Result<Vec<Real>> {
        let dims = Self::u_dims(key);
        let inputs = [self.buf(u, &dims)?];
        let outs = self.run_b(key, &inputs)?;
        Ok(outs[0].to_vec::<Real>()?)
    }

    /// `unpack1` (per-neighbor): (u, seg) -> u with one ghost region applied.
    pub fn unpack1(
        &mut self,
        key: &ArtifactKey,
        u: &[Real],
        seg: &[Real],
        out: &mut [Real],
    ) -> Result<()> {
        let dims = Self::u_dims(key);
        let sdims = [key.nb, seg.len() / key.nb];
        let inputs = [self.buf(u, &dims)?, self.buf(seg, &sdims)?];
        let outs = self.run_b(key, &inputs)?;
        outs[0].copy_raw_to(out)?;
        Ok(())
    }

    /// `unpack`: (u, bufs) -> u with ghosts filled (written into `out`).
    pub fn unpack(
        &mut self,
        key: &ArtifactKey,
        u: &[Real],
        bufs: &[Real],
        out: &mut [Real],
    ) -> Result<()> {
        let dims = Self::u_dims(key);
        let bdims = [key.nb, Self::buflen(key)];
        let inputs = [self.buf(u, &dims)?, self.buf(bufs, &bdims)?];
        let outs = self.run_b(key, &inputs)?;
        outs[0].copy_raw_to(out)?;
        Ok(())
    }

    /// `fused`: (u, u0, bufs_in, scal) -> (u_new, bufs_out, dt[nb]).
    /// u is updated in place; bufs_out overwritten; returns per-block dts.
    pub fn fused(
        &mut self,
        key: &ArtifactKey,
        u: &mut [Real],
        u0: &[Real],
        bufs_in: &[Real],
        scal: ScalArgs,
        bufs_out: &mut [Real],
    ) -> Result<Vec<Real>> {
        let dims = Self::u_dims(key);
        let bdims = [key.nb, Self::buflen(key)];
        let inputs = [
            self.buf(u, &dims)?,
            self.buf(u0, &dims)?,
            self.buf(bufs_in, &bdims)?,
            self.buf(&scal.to_vec(), &[8])?,
        ];
        let outs = self.run_b(key, &inputs)?;
        outs[0].copy_raw_to(u)?;
        outs[1].copy_raw_to(bufs_out)?;
        Ok(outs[2].to_vec::<Real>()?)
    }
}

/// Decompose `nblocks` into pack sizes drawn from `available` (ascending),
/// capped at `desired`: greedy largest-first. The compiled variants always
/// include nb = 1, so this cannot fail.
pub fn plan_packs(nblocks: usize, available: &[usize], desired: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut left = nblocks;
    while left > 0 {
        let pick = available
            .iter()
            .rev()
            .find(|&&s| s <= left && s <= desired)
            .copied()
            .unwrap_or(1);
        out.push(pick);
        left -= pick;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifact_dir;

    fn runtime() -> Option<Runtime> {
        let dir = default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Runtime::new(dir).unwrap())
    }

    #[test]
    fn plan_packs_decomposes() {
        let avail = vec![1, 2, 4, 8, 16];
        assert_eq!(plan_packs(16, &avail, 16), vec![16]);
        assert_eq!(plan_packs(7, &avail, 16), vec![4, 2, 1]);
        assert_eq!(plan_packs(9, &avail, 4), vec![4, 4, 1]);
        assert_eq!(plan_packs(3, &avail, 1), vec![1, 1, 1]);
        assert!(plan_packs(0, &avail, 4).is_empty());
        assert_eq!(plan_packs(5, &avail, 16).iter().sum::<usize>(), 5);
    }

    #[test]
    fn stage_uniform_is_stationary_on_device() {
        let Some(mut rt) = runtime() else { return };
        let key = ArtifactKey::new("stage", 3, [8, 8, 8], 1);
        let nelem = Runtime::block_elems(&key);
        let ncell = nelem / NHYDRO;
        let mut u = vec![0.0f32; nelem];
        for c in 0..ncell {
            u[c] = 1.0; // rho
            u[4 * ncell + c] = 2.5; // E
        }
        let scal = ScalArgs {
            g0: 0.0,
            g1: 1.0,
            beta: 1.0,
            dt: 1e-3,
            dx: [0.1; 3],
            gamma: 1.4,
        };
        let mut out = vec![0.0f32; nelem];
        rt.stage(&key, &u, &u, scal, &mut out).unwrap();
        for (a, b) in u.iter().zip(out.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
        assert_eq!(rt.launches, 1);
        assert_eq!(rt.num_compiled(), 1);
    }

    #[test]
    fn device_matches_native_stage() {
        let Some(mut rt) = runtime() else { return };
        use crate::hydro::native;
        use crate::util::rng::XorShift;
        let shape = IndexShape::new(3, [8, 8, 8]);
        let key = ArtifactKey::new("stage", 3, [8, 8, 8], 1);
        let nelem = Runtime::block_elems(&key);
        let ncell = shape.ncells_total();
        let mut rng = XorShift::new(42);
        let mut u = vec![0.0f32; nelem];
        for c in 0..ncell {
            u[c] = 1.0 + 0.1 * (rng.next_f32() - 0.5);
            u[ncell + c] = 0.1 * (rng.next_f32() - 0.5);
            u[4 * ncell + c] = 2.5 + 0.1 * rng.next_f32();
        }
        let scal = ScalArgs {
            g0: 0.5,
            g1: 0.5,
            beta: 0.5,
            dt: 1e-3,
            dx: [0.05; 3],
            gamma: 1.4,
        };
        let mut dev = vec![0.0f32; nelem];
        rt.stage(&key, &u, &u, scal, &mut dev).unwrap();

        let mut fx = native::FluxArrays::new(&shape);
        let mut sc = native::Scratch::default();
        let mut nat = vec![0.0f32; nelem];
        native::stage(
            &u,
            &u,
            &shape,
            native::StageCoeffs { g0: 0.5, g1: 0.5, beta: 0.5 },
            1e-3,
            [0.05; 3],
            1.4,
            &mut fx,
            &mut sc,
            &mut nat,
        );
        crate::util::testutil::assert_allclose(&dev, &nat, 2e-4, 2e-5);
    }

    #[test]
    fn device_pack_matches_native_pack() {
        let Some(mut rt) = runtime() else { return };
        let shape = IndexShape::new(3, [8, 8, 8]);
        let key = ArtifactKey::new("pack", 3, [8, 8, 8], 1);
        let nelem = Runtime::block_elems(&key);
        let u: Vec<f32> = (0..nelem).map(|i| (i % 9973) as f32).collect();
        let mut dev = vec![0.0f32; Runtime::buflen(&key)];
        rt.pack(&key, &u, &mut dev).unwrap();
        let mut nat = vec![0.0f32; dev.len()];
        bufspec::pack_all(&u, &shape, NHYDRO, &mut nat);
        assert_eq!(dev, nat, "device and native pack layouts must be identical");
    }

    #[test]
    fn device_unpack_roundtrip() {
        let Some(mut rt) = runtime() else { return };
        let shape = IndexShape::new(3, [8, 8, 8]);
        let key = ArtifactKey::new("unpack", 3, [8, 8, 8], 1);
        let nelem = Runtime::block_elems(&key);
        let u: Vec<f32> = vec![1.0; nelem];
        let bufs: Vec<f32> = (0..Runtime::buflen(&key)).map(|i| i as f32).collect();
        let mut dev = vec![0.0f32; nelem];
        rt.unpack(&key, &u, &bufs, &mut dev).unwrap();
        let mut nat = u.clone();
        bufspec::unpack_all(&mut nat, &shape, NHYDRO, &bufs);
        assert_eq!(dev, nat);
    }
}
