//! Device-runtime executor: loads the AOT artifact manifest and executes
//! artifact entry points with flat f32 staging buffers.
//!
//! In this offline build the executables are *interpreted natively*: every
//! entry point reproduces its artifact's semantics operation-for-operation
//! (`python/compile/kernels/ref.py`) on the same flat buffers, and every
//! call still counts as one "kernel launch" — so launch-count accounting
//! (Fig. 8) and buffer layouts stay faithful. A real xla/PJRT client can be
//! slotted back in behind the same `Runtime` API without touching callers.
//!
//! One [`Runtime`] per rank; every entry point takes `&self`, so pack
//! launches from concurrent worker threads share one runtime without a
//! coarse lock on the launch path (the fused Device stage drives per-pack
//! task lists on the work-stealing pool). Shared state is split by access
//! pattern:
//!
//! * the **compile-once map** (key → [`Executable`]) sits behind an
//!   `RwLock`: launches take the read lock on the hot path; only the first
//!   launch of a new (kind, shape, pack-size) variant takes the write lock,
//!   and the `entry` insert under it guarantees each artifact is compiled
//!   exactly once even when many workers race on a cold key;
//! * **launch scratch** (flux arrays, reconstruction scratch, staging tmp)
//!   is never shared between in-flight launches: each launch pops a scratch
//!   from the executable's bounded pool (or builds a fresh one when all are
//!   in flight — at most one per concurrent worker) and pushes it back when
//!   the launch retires.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use super::manifest::{ArtifactKey, Manifest};
use crate::bvals::bufspec;
use crate::error::{Error, Result};
use crate::hydro::native;
use crate::mesh::IndexShape;
use crate::{Real, NHYDRO};

/// Scalar argument vector of the artifacts:
/// [g0, g1, beta, dt, dx, dy, dz, gamma].
#[derive(Debug, Clone, Copy)]
pub struct ScalArgs {
    pub g0: Real,
    pub g1: Real,
    pub beta: Real,
    pub dt: Real,
    pub dx: [Real; 3],
    pub gamma: Real,
}

impl ScalArgs {
    pub fn to_vec(self) -> Vec<Real> {
        vec![
            self.g0, self.g1, self.beta, self.dt, self.dx[0], self.dx[1], self.dx[2],
            self.gamma,
        ]
    }

    fn coeffs(&self) -> native::StageCoeffs {
        native::StageCoeffs { g0: self.g0, g1: self.g1, beta: self.beta }
    }
}

/// Per-launch work buffers of the interpreter. Popped from the owning
/// [`Executable`]'s pool for the duration of one launch; contents carry no
/// state between launches (every kernel fully overwrites what it reads).
struct LaunchScratch {
    fx: native::FluxArrays,
    sc: native::Scratch,
    tmp: Vec<Real>,
}

impl LaunchScratch {
    fn new(shape: &IndexShape) -> LaunchScratch {
        LaunchScratch {
            fx: native::FluxArrays::new(shape),
            sc: native::Scratch::default(),
            tmp: vec![0.0; NHYDRO * shape.ncells_total()],
        }
    }
}

/// One compiled executable: immutable shape metadata plus a bounded pool
/// of per-launch scratch (at most one scratch per concurrent launch).
struct Executable {
    shape: IndexShape,
    scratch: Mutex<Vec<LaunchScratch>>,
}

impl Executable {
    fn new(shape: IndexShape) -> Executable {
        Executable { shape, scratch: Mutex::new(Vec::new()) }
    }

    /// Run `f` with a pooled scratch: pop (or build) one, restore after.
    fn with_scratch<R>(&self, f: impl FnOnce(&mut LaunchScratch) -> R) -> R {
        let mut s = self
            .scratch
            .lock()
            .unwrap()
            .pop()
            .unwrap_or_else(|| LaunchScratch::new(&self.shape));
        let r = f(&mut s);
        self.scratch.lock().unwrap().push(s);
        r
    }
}

/// Per-rank device runtime: artifact manifest + lazily prepared executables.
/// Shareable across worker threads — see the module docs for the lock
/// granularity.
pub struct Runtime {
    manifest: Arc<Manifest>,
    cache: RwLock<HashMap<ArtifactKey, Arc<Executable>>>,
    /// Number of executable invocations ("kernel launches") so far.
    launches: AtomicU64,
}

/// Process-global count of [`Runtime`] constructions. The service layer's
/// one-runtime-per-process invariant is asserted against the delta of this
/// counter: an `Engine` serving N sessions must construct exactly one.
static RUNTIMES_CONSTRUCTED: AtomicU64 = AtomicU64::new(0);

/// One tenant's contribution to a batched `fused` launch: the same buffers
/// [`Runtime::fused`] takes, with a per-part [`ScalArgs`] (each simulation
/// carries its own dt/dx/gamma). All parts of one batch share an
/// [`ArtifactKey`], so their buffer geometry is identical.
pub struct FusedPart<'a> {
    pub u: &'a mut [Real],
    pub u0: &'a [Real],
    pub bufs_in: &'a [Real],
    pub scal: ScalArgs,
    pub bufs_out: &'a mut [Real],
}

impl Runtime {
    /// Open the runtime for an artifact directory. A *missing* manifest
    /// falls back to the native interpreter's synthetic manifest (every
    /// variant available) so the Device execution space works out of the
    /// box; a manifest that exists but fails to parse or fails the bufspec
    /// cross-check is a real error, never a silent fallback.
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let dir = dir.as_ref();
        let manifest = if dir.join("manifest.json").exists() {
            Manifest::load(dir)?
        } else {
            Manifest::native()
        };
        Self::with_manifest(Arc::new(manifest))
    }

    pub fn with_manifest(manifest: Arc<Manifest>) -> Result<Runtime> {
        RUNTIMES_CONSTRUCTED.fetch_add(1, Ordering::SeqCst);
        Ok(Runtime {
            manifest,
            cache: RwLock::new(HashMap::new()),
            launches: AtomicU64::new(0),
        })
    }

    /// Process-global number of `Runtime` constructions so far (see
    /// [`RUNTIMES_CONSTRUCTED`]).
    pub fn constructed_count() -> u64 {
        RUNTIMES_CONSTRUCTED.load(Ordering::SeqCst)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Total executable invocations ("kernel launches") so far.
    pub fn launches(&self) -> u64 {
        self.launches.load(Ordering::Relaxed)
    }

    fn count_launch(&self) {
        self.launches.fetch_add(1, Ordering::Relaxed);
    }

    /// Fetch (or compile-once) the executable for `key`. Hot path is one
    /// read-lock; a cold key upgrades to the write lock, where the `entry`
    /// insert makes the compile unique even under a thundering herd.
    fn exe(&self, key: &ArtifactKey) -> Arc<Executable> {
        if let Some(e) = self.cache.read().unwrap().get(key) {
            return e.clone();
        }
        let mut w = self.cache.write().unwrap();
        w.entry(key.clone())
            .or_insert_with(|| {
                Arc::new(Executable::new(IndexShape::new(key.dim, key.n)))
            })
            .clone()
    }

    /// Eagerly prepare an artifact (startup warmup, outside timed regions).
    pub fn warmup(&self, key: &ArtifactKey) -> Result<()> {
        self.exe(key);
        Ok(())
    }

    pub fn num_compiled(&self) -> usize {
        self.cache.read().unwrap().len()
    }

    // -- shape helpers -------------------------------------------------------

    /// Elements in one block's [NVAR, Z, Y, X] slab.
    pub fn block_elems(key: &ArtifactKey) -> usize {
        let shape = IndexShape::new(key.dim, key.n);
        NHYDRO * shape.ncells_total()
    }

    /// Flat boundary-buffer length per block.
    pub fn buflen(key: &ArtifactKey) -> usize {
        let shape = IndexShape::new(key.dim, key.n);
        bufspec::buflen(&shape, NHYDRO)
    }

    /// Error unless `len >= need` (`what` names the offending buffer).
    fn check_len(key: &ArtifactKey, what: &str, len: usize, need: usize) -> Result<()> {
        if len < need {
            return Err(Error::Runtime(format!(
                "{} buffer too short for {:?}: {} < {} elements",
                what, key, len, need
            )));
        }
        Ok(())
    }

    // -- artifact entry points ------------------------------------------------

    /// `stage`: (u, u0, scal) -> u_new (written into `out`).
    pub fn stage(
        &self,
        key: &ArtifactKey,
        u: &[Real],
        u0: &[Real],
        scal: ScalArgs,
        out: &mut [Real],
    ) -> Result<()> {
        self.count_launch();
        let shape = IndexShape::new(key.dim, key.n);
        let ne = Self::block_elems(key);
        let exe = self.exe(key);
        exe.with_scratch(|c| {
            for b in 0..key.nb {
                native::stage(
                    &u[b * ne..(b + 1) * ne],
                    &u0[b * ne..(b + 1) * ne],
                    &shape,
                    scal.coeffs(),
                    scal.dt,
                    scal.dx,
                    scal.gamma,
                    &mut c.fx,
                    &mut c.sc,
                    &mut out[b * ne..(b + 1) * ne],
                );
            }
        });
        Ok(())
    }

    /// `dt`: (u, scal) -> per-block CFL dt [nb].
    pub fn dt(&self, key: &ArtifactKey, u: &[Real], scal: ScalArgs) -> Result<Vec<Real>> {
        self.count_launch();
        let shape = IndexShape::new(key.dim, key.n);
        let ne = Self::block_elems(key);
        let mut dts = Vec::with_capacity(key.nb);
        for b in 0..key.nb {
            dts.push(native::min_dt(
                &u[b * ne..(b + 1) * ne],
                &shape,
                scal.dx,
                scal.gamma,
            ));
        }
        Ok(dts)
    }

    /// `pack`: u -> all boundary buffers [nb, BUFLEN] (into `bufs`).
    pub fn pack(&self, key: &ArtifactKey, u: &[Real], bufs: &mut [Real]) -> Result<()> {
        self.count_launch();
        let shape = IndexShape::new(key.dim, key.n);
        let ne = Self::block_elems(key);
        let bl = Self::buflen(key);
        for b in 0..key.nb {
            bufspec::pack_all(
                &u[b * ne..(b + 1) * ne],
                &shape,
                NHYDRO,
                &mut bufs[b * bl..(b + 1) * bl],
            );
        }
        Ok(())
    }

    /// `pack1` (per-neighbor): u -> one buffer segment.
    pub fn pack1(&self, key: &ArtifactKey, u: &[Real]) -> Result<Vec<Real>> {
        self.count_launch();
        let shape = IndexShape::new(key.dim, key.n);
        let ne = Self::block_elems(key);
        let slot = key.nbr.unwrap_or(0);
        let offset = crate::mesh::tree::neighbor_offsets(key.dim)[slot];
        let slab = bufspec::send_slab(offset, &shape);
        let seg_len = NHYDRO * slab.ncells();
        let mut out = vec![0.0; key.nb * seg_len];
        for b in 0..key.nb {
            let mut w = b * seg_len;
            for v in 0..NHYDRO {
                w += bufspec::copy_slab_out(
                    &u[b * ne..(b + 1) * ne],
                    &shape,
                    v,
                    &slab,
                    &mut out[w..],
                );
            }
        }
        Ok(out)
    }

    /// `unpack1` (per-neighbor): (u, seg) -> u with one ghost region applied.
    /// Lengths are validated against the key's shape — a short device
    /// buffer is an error, not a panic.
    pub fn unpack1(
        &self,
        key: &ArtifactKey,
        u: &[Real],
        seg: &[Real],
        out: &mut [Real],
    ) -> Result<()> {
        self.count_launch();
        let shape = IndexShape::new(key.dim, key.n);
        let ne = Self::block_elems(key);
        let slot = key.nbr.unwrap_or(0);
        let offsets = crate::mesh::tree::neighbor_offsets(key.dim);
        if slot >= offsets.len() {
            return Err(Error::Runtime(format!(
                "unpack1 neighbor slot {} out of range for {:?}",
                slot, key
            )));
        }
        let slab = bufspec::recv_slab(offsets[slot], &shape);
        let seg_len = NHYDRO * slab.ncells();
        Self::check_len(key, "unpack1 state", u.len(), key.nb * ne)?;
        Self::check_len(key, "unpack1 output", out.len(), key.nb * ne)?;
        Self::check_len(key, "unpack1 segment", seg.len(), key.nb * seg_len)?;
        out[..key.nb * ne].copy_from_slice(&u[..key.nb * ne]);
        for b in 0..key.nb {
            let mut r = b * seg_len;
            for v in 0..NHYDRO {
                r += bufspec::copy_slab_in(
                    &mut out[b * ne..(b + 1) * ne],
                    &shape,
                    v,
                    &slab,
                    &seg[r..],
                );
            }
        }
        Ok(())
    }

    /// `unpack`: (u, bufs) -> u with ghosts filled (written into `out`).
    /// Lengths are validated against `buflen(key)` — a short device buffer
    /// is an error, not a panic.
    pub fn unpack(
        &self,
        key: &ArtifactKey,
        u: &[Real],
        bufs: &[Real],
        out: &mut [Real],
    ) -> Result<()> {
        self.count_launch();
        let shape = IndexShape::new(key.dim, key.n);
        let ne = Self::block_elems(key);
        let bl = Self::buflen(key);
        Self::check_len(key, "unpack state", u.len(), key.nb * ne)?;
        Self::check_len(key, "unpack output", out.len(), key.nb * ne)?;
        Self::check_len(key, "unpack boundary", bufs.len(), key.nb * bl)?;
        out[..key.nb * ne].copy_from_slice(&u[..key.nb * ne]);
        for b in 0..key.nb {
            bufspec::unpack_all(
                &mut out[b * ne..(b + 1) * ne],
                &shape,
                NHYDRO,
                &bufs[b * bl..(b + 1) * bl],
            );
        }
        Ok(())
    }

    /// `flux`: u -> face fluxes of ONE block (the caller owns the
    /// [`native::FluxArrays`] so the raw fluxes survive the launch — the
    /// multilevel Device path patches them with flux corrections before
    /// the combine launch).
    pub(crate) fn flux(
        &self,
        key: &ArtifactKey,
        u: &[Real],
        scal: ScalArgs,
        fx: &mut native::FluxArrays,
    ) -> Result<()> {
        self.count_launch();
        let shape = IndexShape::new(key.dim, key.n);
        Self::check_len(key, "flux state", u.len(), Self::block_elems(key))?;
        let exe = self.exe(key);
        exe.with_scratch(|c| {
            native::compute_fluxes(u, &shape, scal.gamma, fx, &mut c.sc);
        });
        Ok(())
    }

    /// `combine`: (u, u0, fluxes, scal) -> u updated in place for ONE
    /// block. Together with [`Runtime::flux`] this splits the `stage`
    /// artifact at the flux/update seam (identical arithmetic, so
    /// flux-then-combine is bitwise `stage`) — the split the multilevel
    /// Device task list needs to interleave flux correction.
    pub(crate) fn combine(
        &self,
        key: &ArtifactKey,
        u: &mut [Real],
        u0: &[Real],
        fx: &native::FluxArrays,
        scal: ScalArgs,
    ) -> Result<()> {
        self.count_launch();
        let shape = IndexShape::new(key.dim, key.n);
        let ne = Self::block_elems(key);
        Self::check_len(key, "combine state", u.len(), ne)?;
        Self::check_len(key, "combine u0", u0.len(), ne)?;
        let exe = self.exe(key);
        exe.with_scratch(|c| {
            native::apply_stage(
                &u[..ne],
                &u0[..ne],
                fx,
                &shape,
                scal.coeffs(),
                scal.dt,
                scal.dx,
                &mut c.tmp,
            );
            u[..ne].copy_from_slice(&c.tmp[..ne]);
        });
        Ok(())
    }

    /// `payload`: extract ONE outbound boundary segment from a block's
    /// state — same-level slab copy, fine→coarse restriction, or the
    /// coarse→fine prolongation source box, selected by the
    /// [`crate::bvals::SendOp`] the routing snapshot carries. Shares the
    /// spec layer with the host exchange, so the bytes on the wire are
    /// identical by construction.
    pub(crate) fn boundary_payload(
        &self,
        key: &ArtifactKey,
        u: &[Real],
        op: &crate::bvals::SendOp,
    ) -> Result<Vec<Real>> {
        self.count_launch();
        let shape = IndexShape::new(key.dim, key.n);
        Self::check_len(key, "payload state", u.len(), Self::block_elems(key))?;
        Ok(crate::bvals::send_payload(u, &shape, NHYDRO, op))
    }

    /// Apply ONE inbound boundary segment to a block's state — dense ghost
    /// insert or coarse→fine prolongation, selected by the
    /// [`crate::bvals::RecvOp`] the routing snapshot carries (the
    /// receive-side mirror of [`Runtime::boundary_payload`]).
    pub(crate) fn apply_boundary(
        &self,
        key: &ArtifactKey,
        u: &mut [Real],
        op: &crate::bvals::RecvOp,
        data: &[Real],
    ) -> Result<()> {
        self.count_launch();
        let shape = IndexShape::new(key.dim, key.n);
        Self::check_len(key, "apply state", u.len(), Self::block_elems(key))?;
        crate::bvals::apply_recv_op(u, &shape, NHYDRO, op, data);
        Ok(())
    }

    /// `fused`: (u, u0, bufs_in, scal) -> (u_new, bufs_out, dt[nb]).
    /// u is updated in place; bufs_out overwritten; returns per-block dts.
    /// Semantics: unpack -> stage -> pack -> dt, one launch per pack
    /// (`ref.py::fused_step`). Implemented as a one-part
    /// [`Runtime::fused_batch`] so batched and solo launches run literally
    /// the same per-block code (bitwise-identical results by construction).
    pub fn fused(
        &self,
        key: &ArtifactKey,
        u: &mut [Real],
        u0: &[Real],
        bufs_in: &[Real],
        scal: ScalArgs,
        bufs_out: &mut [Real],
    ) -> Result<Vec<Real>> {
        let mut parts = [FusedPart { u, u0, bufs_in, scal, bufs_out }];
        let mut out = self.fused_batch(key, &mut parts)?;
        Ok(out.pop().expect("one part in, one result out").0)
    }

    /// Cross-simulation batched `fused`: run every part's
    /// unpack→stage→pack→dt sweep under ONE launch (one `count_launch`, one
    /// pooled scratch). Parts are independent — each touches only its own
    /// buffers with its own `scal` — so the batch order never changes any
    /// part's bits, only how many launches the work costs. Returns, per
    /// part in order, (per-block dts, wall seconds of that part's sweep);
    /// the per-part seconds keep the cost EWMAs attributable per tenant.
    pub fn fused_batch(
        &self,
        key: &ArtifactKey,
        parts: &mut [FusedPart<'_>],
    ) -> Result<Vec<(Vec<Real>, f64)>> {
        self.count_launch();
        let shape = IndexShape::new(key.dim, key.n);
        let ne = Self::block_elems(key);
        let bl = Self::buflen(key);
        for p in parts.iter() {
            Self::check_len(key, "fused state", p.u.len(), key.nb * ne)?;
            Self::check_len(key, "fused u0", p.u0.len(), key.nb * ne)?;
            Self::check_len(key, "fused boundary-in", p.bufs_in.len(), key.nb * bl)?;
            Self::check_len(key, "fused boundary-out", p.bufs_out.len(), key.nb * bl)?;
        }
        let exe = self.exe(key);
        exe.with_scratch(|c| {
            let mut out = Vec::with_capacity(parts.len());
            for p in parts.iter_mut() {
                let t0 = std::time::Instant::now();
                let scal = p.scal;
                let mut dts = Vec::with_capacity(key.nb);
                for b in 0..key.nb {
                    let ub = &mut p.u[b * ne..(b + 1) * ne];
                    bufspec::unpack_all(ub, &shape, NHYDRO, &p.bufs_in[b * bl..(b + 1) * bl]);
                    native::stage(
                        ub,
                        &p.u0[b * ne..(b + 1) * ne],
                        &shape,
                        scal.coeffs(),
                        scal.dt,
                        scal.dx,
                        scal.gamma,
                        &mut c.fx,
                        &mut c.sc,
                        &mut c.tmp,
                    );
                    ub.copy_from_slice(&c.tmp);
                    bufspec::pack_all(
                        ub,
                        &shape,
                        NHYDRO,
                        &mut p.bufs_out[b * bl..(b + 1) * bl],
                    );
                    dts.push(native::min_dt(ub, &shape, scal.dx, scal.gamma));
                }
                out.push((dts, t0.elapsed().as_secs_f64()));
            }
            Ok(out)
        })
    }
}

/// Decompose `nblocks` into pack sizes drawn from `available` (ascending),
/// capped at `desired`: greedy largest-first. The compiled variants always
/// include nb = 1, so this cannot fail.
pub fn plan_packs(nblocks: usize, available: &[usize], desired: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut left = nblocks;
    while left > 0 {
        let pick = available
            .iter()
            .rev()
            .find(|&&s| s <= left && s <= desired)
            .copied()
            .unwrap_or(1);
        out.push(pick);
        left -= pick;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifact_dir;

    fn runtime() -> Runtime {
        // Runtime::new always succeeds: a missing manifest selects the
        // native interpreter's synthetic manifest.
        Runtime::new(default_artifact_dir()).unwrap()
    }

    #[test]
    fn plan_packs_decomposes() {
        let avail = vec![1, 2, 4, 8, 16];
        assert_eq!(plan_packs(16, &avail, 16), vec![16]);
        assert_eq!(plan_packs(7, &avail, 16), vec![4, 2, 1]);
        assert_eq!(plan_packs(9, &avail, 4), vec![4, 4, 1]);
        assert_eq!(plan_packs(3, &avail, 1), vec![1, 1, 1]);
        assert!(plan_packs(0, &avail, 4).is_empty());
        assert_eq!(plan_packs(5, &avail, 16).iter().sum::<usize>(), 5);
    }

    #[test]
    fn fused_batch_bitwise_matches_solo_with_one_launch() {
        use crate::util::rng::XorShift;
        let rt = runtime();
        let key = ArtifactKey::new("fused", 2, [8, 8, 1], 2);
        let ne = Runtime::block_elems(&key);
        let bl = Runtime::buflen(&key);
        // three tenants, each with its own state, ghosts, and scal
        let mut rng = XorShift::new(7);
        let mk = |rng: &mut XorShift, dt: f32| {
            let ncell = ne / NHYDRO;
            let mut u = vec![0.0f32; key.nb * ne];
            for b in 0..key.nb {
                for c in 0..ncell {
                    u[b * ne + c] = 1.0 + 0.1 * (rng.next_f32() - 0.5);
                    u[b * ne + 4 * ncell + c] = 2.5 + 0.1 * rng.next_f32();
                }
            }
            let bufs_in: Vec<f32> =
                (0..key.nb * bl).map(|_| 1.0 + 0.01 * rng.next_f32()).collect();
            let scal = ScalArgs {
                g0: 0.5,
                g1: 0.5,
                beta: 0.5,
                dt,
                dx: [0.05; 3],
                gamma: 1.4,
            };
            (u.clone(), u, bufs_in, scal)
        };
        let tenants: Vec<_> =
            (0..3).map(|i| mk(&mut rng, 1e-3 * (i + 1) as f32)).collect();

        // solo: one fused launch per tenant
        let mut solo = Vec::new();
        for (u, u0, bufs_in, scal) in &tenants {
            let mut u = u.clone();
            let mut bufs_out = vec![0.0f32; key.nb * bl];
            let dts = rt.fused(&key, &mut u, u0, bufs_in, *scal, &mut bufs_out).unwrap();
            solo.push((u, bufs_out, dts));
        }

        // batched: all three under one launch
        let l0 = rt.launches();
        let mut us: Vec<Vec<f32>> = tenants.iter().map(|t| t.0.clone()).collect();
        let mut outs: Vec<Vec<f32>> = (0..3).map(|_| vec![0.0f32; key.nb * bl]).collect();
        let mut parts: Vec<FusedPart<'_>> = us
            .iter_mut()
            .zip(outs.iter_mut())
            .zip(tenants.iter())
            .map(|((u, bufs_out), (_, u0, bufs_in, scal))| FusedPart {
                u,
                u0,
                bufs_in,
                scal: *scal,
                bufs_out,
            })
            .collect();
        let batched = rt.fused_batch(&key, &mut parts).unwrap();
        drop(parts);
        assert_eq!(rt.launches() - l0, 1, "one launch for the whole batch");
        for i in 0..3 {
            assert_eq!(us[i], solo[i].0, "tenant {i} state bits");
            assert_eq!(outs[i], solo[i].1, "tenant {i} boundary bits");
            assert_eq!(batched[i].0, solo[i].2, "tenant {i} dt bits");
        }
    }

    #[test]
    fn constructed_count_monotonic() {
        let c0 = Runtime::constructed_count();
        let _rt = runtime();
        let _rt2 = runtime();
        assert!(Runtime::constructed_count() >= c0 + 2);
    }

    #[test]
    fn stage_uniform_is_stationary_on_device() {
        let rt = runtime();
        let key = ArtifactKey::new("stage", 3, [8, 8, 8], 1);
        let nelem = Runtime::block_elems(&key);
        let ncell = nelem / NHYDRO;
        let mut u = vec![0.0f32; nelem];
        for c in 0..ncell {
            u[c] = 1.0; // rho
            u[4 * ncell + c] = 2.5; // E
        }
        let scal = ScalArgs {
            g0: 0.0,
            g1: 1.0,
            beta: 1.0,
            dt: 1e-3,
            dx: [0.1; 3],
            gamma: 1.4,
        };
        let mut out = vec![0.0f32; nelem];
        rt.stage(&key, &u, &u, scal, &mut out).unwrap();
        for (a, b) in u.iter().zip(out.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
        assert_eq!(rt.launches(), 1);
        assert_eq!(rt.num_compiled(), 1);
    }

    #[test]
    fn device_matches_native_stage() {
        let rt = runtime();
        use crate::util::rng::XorShift;
        let shape = IndexShape::new(3, [8, 8, 8]);
        let key = ArtifactKey::new("stage", 3, [8, 8, 8], 1);
        let nelem = Runtime::block_elems(&key);
        let ncell = shape.ncells_total();
        let mut rng = XorShift::new(42);
        let mut u = vec![0.0f32; nelem];
        for c in 0..ncell {
            u[c] = 1.0 + 0.1 * (rng.next_f32() - 0.5);
            u[ncell + c] = 0.1 * (rng.next_f32() - 0.5);
            u[4 * ncell + c] = 2.5 + 0.1 * rng.next_f32();
        }
        let scal = ScalArgs {
            g0: 0.5,
            g1: 0.5,
            beta: 0.5,
            dt: 1e-3,
            dx: [0.05; 3],
            gamma: 1.4,
        };
        let mut dev = vec![0.0f32; nelem];
        rt.stage(&key, &u, &u, scal, &mut dev).unwrap();

        let mut fx = native::FluxArrays::new(&shape);
        let mut sc = native::Scratch::default();
        let mut nat = vec![0.0f32; nelem];
        native::stage(
            &u,
            &u,
            &shape,
            native::StageCoeffs { g0: 0.5, g1: 0.5, beta: 0.5 },
            1e-3,
            [0.05; 3],
            1.4,
            &mut fx,
            &mut sc,
            &mut nat,
        );
        crate::util::testutil::assert_allclose(&dev, &nat, 2e-4, 2e-5);
    }

    #[test]
    fn device_pack_matches_native_pack() {
        let rt = runtime();
        let shape = IndexShape::new(3, [8, 8, 8]);
        let key = ArtifactKey::new("pack", 3, [8, 8, 8], 1);
        let nelem = Runtime::block_elems(&key);
        let u: Vec<f32> = (0..nelem).map(|i| (i % 9973) as f32).collect();
        let mut dev = vec![0.0f32; Runtime::buflen(&key)];
        rt.pack(&key, &u, &mut dev).unwrap();
        let mut nat = vec![0.0f32; dev.len()];
        bufspec::pack_all(&u, &shape, NHYDRO, &mut nat);
        assert_eq!(dev, nat, "device and native pack layouts must be identical");
    }

    #[test]
    fn device_unpack_roundtrip() {
        let rt = runtime();
        let shape = IndexShape::new(3, [8, 8, 8]);
        let key = ArtifactKey::new("unpack", 3, [8, 8, 8], 1);
        let nelem = Runtime::block_elems(&key);
        let u: Vec<f32> = vec![1.0; nelem];
        let bufs: Vec<f32> = (0..Runtime::buflen(&key)).map(|i| i as f32).collect();
        let mut dev = vec![0.0f32; nelem];
        rt.unpack(&key, &u, &bufs, &mut dev).unwrap();
        let mut nat = u.clone();
        bufspec::unpack_all(&mut nat, &shape, NHYDRO, &bufs);
        assert_eq!(dev, nat);
    }

    #[test]
    fn unpack_short_buffers_error_not_panic() {
        let rt = runtime();
        let key = ArtifactKey::new("unpack", 2, [8, 8, 1], 2);
        let ne = Runtime::block_elems(&key);
        let bl = Runtime::buflen(&key);
        let u = vec![1.0f32; 2 * ne];
        let mut out = vec![0.0f32; 2 * ne];
        // boundary buffer one element short of nb * buflen
        let short = vec![0.0f32; 2 * bl - 1];
        assert!(rt.unpack(&key, &u, &short, &mut out).is_err());
        // short output slab
        let mut short_out = vec![0.0f32; ne];
        let bufs = vec![0.0f32; 2 * bl];
        assert!(rt.unpack(&key, &u, &bufs, &mut short_out).is_err());
        // unpack1: segment shorter than nb * seg_len
        let k1 = ArtifactKey::new("unpack1", 2, [8, 8, 1], 2).with_nbr(0);
        assert!(rt.unpack1(&k1, &u, &[0.0f32; 1], &mut out).is_err());
        // well-formed lengths still succeed
        assert!(rt.unpack(&key, &u, &bufs, &mut out).is_ok());
    }

    #[test]
    fn pack1_matches_full_pack_segment() {
        let rt = runtime();
        let shape = IndexShape::new(2, [8, 8, 1]);
        let key = ArtifactKey::new("pack", 2, [8, 8, 1], 1);
        let nelem = Runtime::block_elems(&key);
        let u: Vec<f32> = (0..nelem).map(|i| (i % 997) as f32).collect();
        let mut full = vec![0.0f32; Runtime::buflen(&key)];
        rt.pack(&key, &u, &mut full).unwrap();
        let (offs, _) = bufspec::segment_offsets(&shape, NHYDRO);
        let lens = bufspec::segment_lengths(&shape, NHYDRO);
        for slot in 0..lens.len() {
            let k1 = ArtifactKey::new("pack1", 2, [8, 8, 1], 1).with_nbr(slot);
            let seg = rt.pack1(&k1, &u).unwrap();
            assert_eq!(seg, full[offs[slot]..offs[slot] + lens[slot]].to_vec());
        }
    }

    #[test]
    fn flux_then_combine_is_bitwise_stage() {
        // the general (multilevel) Device list splits the stage launch at
        // the flux/update seam; the split must be bitwise neutral
        let rt = runtime();
        use crate::util::rng::XorShift;
        let key = ArtifactKey::new("flux", 2, [8, 8, 1], 1);
        let kst = ArtifactKey::new("stage", 2, [8, 8, 1], 1);
        let ne = Runtime::block_elems(&key);
        let ncell = ne / NHYDRO;
        let mut rng = XorShift::new(11);
        let mut u = vec![0.0f32; ne];
        for c in 0..ncell {
            u[c] = 1.0 + 0.1 * (rng.next_f32() - 0.5);
            u[ncell + c] = 0.1 * (rng.next_f32() - 0.5);
            u[4 * ncell + c] = 2.5 + 0.1 * rng.next_f32();
        }
        let u0 = u.clone();
        let scal = ScalArgs {
            g0: 0.5,
            g1: 0.5,
            beta: 0.5,
            dt: 1e-3,
            dx: [0.1; 3],
            gamma: 1.4,
        };
        let mut expect = vec![0.0f32; ne];
        rt.stage(&kst, &u, &u0, scal, &mut expect).unwrap();

        let shape = IndexShape::new(2, [8, 8, 1]);
        let mut fx = native::FluxArrays::new(&shape);
        rt.flux(&key, &u, scal, &mut fx).unwrap();
        let mut got = u.clone();
        rt.combine(&key, &mut got, &u0, &fx, scal).unwrap();
        assert_eq!(got, expect, "flux+combine must equal the fused stage bitwise");
    }

    #[test]
    fn boundary_payload_same_matches_pack1() {
        let rt = runtime();
        let shape = IndexShape::new(2, [8, 8, 1]);
        let key = ArtifactKey::new("payload", 2, [8, 8, 1], 1);
        let ne = Runtime::block_elems(&key);
        let u: Vec<f32> = (0..ne).map(|i| (i % 613) as f32).collect();
        for (slot, o) in crate::mesh::tree::neighbor_offsets(2).iter().enumerate() {
            let op = crate::bvals::SendOp::Same(bufspec::send_slab(*o, &shape));
            let seg = rt.boundary_payload(&key, &u, &op).unwrap();
            let k1 = ArtifactKey::new("pack1", 2, [8, 8, 1], 1).with_nbr(slot);
            assert_eq!(seg, rt.pack1(&k1, &u).unwrap(), "offset {o:?}");
        }
    }

    #[test]
    fn boundary_restrict_payload_lengths_and_values() {
        let rt = runtime();
        let shape = IndexShape::new(2, [8, 8, 1]);
        let key = ArtifactKey::new("payload", 2, [8, 8, 1], 1);
        let ne = Runtime::block_elems(&key);
        let u: Vec<f32> = (0..ne).map(|i| (i % 769) as f32 * 0.5).collect();
        // 2g-deep fine send slab toward -x: pinched axis (g, 3g), full
        // interior tangentially — the fine→coarse restriction source
        let g = crate::NGHOST;
        let slab = bufspec::Slab { x: (g, 3 * g), y: (g, g + 8), z: (0, 1) };
        let op = crate::bvals::SendOp::Restrict(slab);
        let seg = rt.boundary_payload(&key, &u, &op).unwrap();
        let lens = bufspec::restrict_segment_lengths(&shape, NHYDRO);
        let slot = crate::mesh::tree::neighbor_offsets(2)
            .iter()
            .position(|o| *o == [-1, 0, 0])
            .unwrap();
        assert_eq!(seg.len(), lens[slot]);
        let mut expect = Vec::new();
        crate::bvals::restrict_slab(&u, &shape, NHYDRO, &slab, &mut expect);
        assert_eq!(seg, expect);
    }

    #[test]
    fn apply_boundary_insert_matches_unpack1() {
        let rt = runtime();
        let shape = IndexShape::new(2, [8, 8, 1]);
        let key = ArtifactKey::new("apply", 2, [8, 8, 1], 1);
        let ne = Runtime::block_elems(&key);
        let u: Vec<f32> = vec![3.0; ne];
        for (slot, o) in crate::mesh::tree::neighbor_offsets(2).iter().enumerate() {
            let slab = bufspec::recv_slab(*o, &shape);
            let data: Vec<f32> =
                (0..NHYDRO * slab.ncells()).map(|i| (i % 89) as f32).collect();
            let op = crate::bvals::RecvOp::Insert(slab);
            let mut got = u.clone();
            rt.apply_boundary(&key, &mut got, &op, &data).unwrap();
            let k1 = ArtifactKey::new("unpack1", 2, [8, 8, 1], 1).with_nbr(slot);
            let mut expect = vec![0.0f32; ne];
            rt.unpack1(&k1, &u, &data, &mut expect).unwrap();
            assert_eq!(got, expect, "offset {o:?}");
        }
    }

    #[test]
    fn fused_matches_unpack_stage_pack_dt() {
        let rt = runtime();
        use crate::util::rng::XorShift;
        let key = ArtifactKey::new("fused", 2, [8, 8, 1], 2);
        let k1 = ArtifactKey::new("x", 2, [8, 8, 1], 2);
        let ne = Runtime::block_elems(&k1);
        let bl = Runtime::buflen(&k1);
        let mut rng = XorShift::new(7);
        let ncell = ne / NHYDRO;
        let mut u = vec![0.0f32; 2 * ne];
        for b in 0..2 {
            for c in 0..ncell {
                u[b * ne + c] = 1.0 + 0.1 * (rng.next_f32() - 0.5);
                u[b * ne + 4 * ncell + c] = 2.5 + 0.1 * rng.next_f32();
            }
        }
        let u0 = u.clone();
        let bufs_in: Vec<f32> = (0..2 * bl).map(|i| 1.0 + (i % 13) as f32 * 1e-3).collect();
        let scal = ScalArgs {
            g0: 0.0,
            g1: 1.0,
            beta: 1.0,
            dt: 1e-3,
            dx: [0.1; 3],
            gamma: 1.4,
        };
        // composed reference via the separate entry points
        let kun = ArtifactKey::new("unpack", 2, [8, 8, 1], 2);
        let kst = ArtifactKey::new("stage", 2, [8, 8, 1], 2);
        let kpk = ArtifactKey::new("pack", 2, [8, 8, 1], 2);
        let kdt = ArtifactKey::new("dt", 2, [8, 8, 1], 2);
        let mut ref_u = vec![0.0f32; 2 * ne];
        rt.unpack(&kun, &u, &bufs_in, &mut ref_u).unwrap();
        let mut ref_new = vec![0.0f32; 2 * ne];
        rt.stage(&kst, &ref_u, &u0, scal, &mut ref_new).unwrap();
        let mut ref_bufs = vec![0.0f32; 2 * bl];
        rt.pack(&kpk, &ref_new, &mut ref_bufs).unwrap();
        let ref_dts = rt.dt(&kdt, &ref_new, scal).unwrap();

        let mut fu = u.clone();
        let mut bufs_out = vec![0.0f32; 2 * bl];
        let dts = rt.fused(&key, &mut fu, &u0, &bufs_in, scal, &mut bufs_out).unwrap();
        assert_eq!(fu, ref_new);
        assert_eq!(bufs_out, ref_bufs);
        assert_eq!(dts, ref_dts);
    }
}
