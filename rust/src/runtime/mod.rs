//! Device execution space: the PJRT runtime that loads AOT artifacts
//! (HLO text lowered by python/compile/aot.py) and executes them from the
//! coordinator hot path.
//!
//! One [`Runtime`] per rank thread (the `xla` crate's client is not `Send`);
//! executables are compiled lazily per (kind, shape, pack-size) key and
//! cached — mirroring "one compiled kernel per MeshBlockPack variant".

mod manifest;
mod pjrt;

pub use manifest::{default_artifact_dir, ArtifactKey, Manifest};
pub use pjrt::{plan_packs, FusedPart, Runtime, ScalArgs};

/// Whether the Device execution space can run at all. With the native
/// artifact interpreter this is always true; real AOT artifacts (when
/// present under the artifact dir) are still validated against the native
/// bufspec tables at load time.
pub fn device_available() -> bool {
    true
}
