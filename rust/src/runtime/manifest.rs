//! Artifact manifest: what python/compile/aot.py produced.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::bvals::bufspec;
use crate::error::{Error, Result};
use crate::mesh::IndexShape;
use crate::util::json::Json;

/// Identity of one compiled artifact variant.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ArtifactKey {
    /// stage | dt | pack | unpack | fused | pack1
    pub kind: String,
    pub dim: usize,
    /// Block interior size (nx, ny, nz).
    pub n: [usize; 3],
    /// Pack size (leading batch dimension).
    pub nb: usize,
    /// jnp | pallas
    pub impl_: String,
    /// Neighbor index for `pack1` variants.
    pub nbr: Option<usize>,
}

impl ArtifactKey {
    pub fn new(kind: &str, dim: usize, n: [usize; 3], nb: usize) -> Self {
        ArtifactKey {
            kind: kind.to_string(),
            dim,
            n,
            nb,
            impl_: "jnp".to_string(),
            nbr: None,
        }
    }

    pub fn with_impl(mut self, impl_: &str) -> Self {
        self.impl_ = impl_.to_string();
        self
    }

    pub fn with_nbr(mut self, nbr: usize) -> Self {
        self.nbr = Some(nbr);
        self
    }
}

/// Parsed manifest.json — or the native interpreter's synthetic manifest,
/// which advertises every variant (the interpreter specializes on demand).
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub nghost: usize,
    pub nvar: usize,
    files: HashMap<ArtifactKey, String>,
    native: bool,
}

/// Pack sizes the native interpreter advertises for fused/stage variants.
const NATIVE_PACK_SIZES: &[usize] = &[1, 2, 4, 8, 16, 32, 64];

impl Manifest {
    /// The synthetic manifest of the native artifact interpreter: no files
    /// on disk, every (kind, dim, n, nb) variant available.
    pub fn native() -> Manifest {
        Manifest {
            dir: PathBuf::new(),
            nghost: crate::NGHOST,
            nvar: crate::NHYDRO,
            files: HashMap::new(),
            native: true,
        }
    }

    pub fn is_native(&self) -> bool {
        self.native
    }

    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {path:?} (run `make artifacts` first): {e}"
            ))
        })?;
        let doc = Json::parse(&text)?;
        let nghost = doc.req("nghost")?.as_usize().unwrap_or(0);
        let nvar = doc.req("nvar")?.as_usize().unwrap_or(0);
        if nghost != crate::NGHOST || nvar != crate::NHYDRO {
            return Err(Error::Artifact(format!(
                "manifest nghost/nvar = {nghost}/{nvar} do not match build \
                 ({}/{})",
                crate::NGHOST,
                crate::NHYDRO
            )));
        }

        let mut files = HashMap::new();
        for a in doc.req("artifacts")?.as_arr().unwrap_or(&[]) {
            let kind = a.req("kind")?.as_str().unwrap_or("").to_string();
            let narr = a.req("n")?.as_arr().unwrap_or(&[]);
            let n = [
                narr[0].as_usize().unwrap_or(1),
                narr[1].as_usize().unwrap_or(1),
                narr[2].as_usize().unwrap_or(1),
            ];
            let key = ArtifactKey {
                kind,
                dim: a.req("dim")?.as_usize().unwrap_or(0),
                n,
                nb: a.req("nb")?.as_usize().unwrap_or(1),
                impl_: a.req("impl")?.as_str().unwrap_or("jnp").to_string(),
                nbr: a.get("nbr").and_then(|v| v.as_usize()),
            };
            files.insert(key, a.req("file")?.as_str().unwrap_or("").to_string());
        }

        let m = Manifest { dir, nghost, nvar, files, native: false };
        m.cross_check_bufspec(&doc)?;
        Ok(m)
    }

    /// Verify the python bufspec tables embedded in the manifest agree with
    /// the native implementation (segment lengths, opposite map, shapes).
    fn cross_check_bufspec(&self, doc: &Json) -> Result<()> {
        for t in doc.req("bufspec")?.as_arr().unwrap_or(&[]) {
            let dim = t.req("dim")?.as_usize().unwrap_or(0);
            let narr = t.req("n")?.as_arr().unwrap_or(&[]);
            let n = [
                narr[0].as_usize().unwrap_or(1),
                narr[1].as_usize().unwrap_or(1),
                narr[2].as_usize().unwrap_or(1),
            ];
            let shape = IndexShape::new(dim, n);
            let ours: Vec<usize> = bufspec::segment_lengths(&shape, self.nvar);
            let theirs: Vec<usize> = t
                .req("seg_lens")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect();
            if ours != theirs {
                return Err(Error::Artifact(format!(
                    "bufspec mismatch for dim={dim} n={n:?}: rust {ours:?} vs \
                     python {theirs:?}"
                )));
            }
            let opp: Vec<usize> = t
                .req("opposite")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect();
            if opp != bufspec::opposite_index(dim) {
                return Err(Error::Artifact(format!(
                    "opposite-index mismatch for dim={dim}"
                )));
            }
            // Optional (manifests predating the multilevel Device path
            // lack it): restricted fine->coarse payload lengths.
            if let Some(arr) = t.get("restrict_seg_lens").and_then(|v| v.as_arr()) {
                let theirs: Vec<usize> =
                    arr.iter().map(|v| v.as_usize().unwrap_or(0)).collect();
                let ours = bufspec::restrict_segment_lengths(&shape, self.nvar);
                if ours != theirs {
                    return Err(Error::Artifact(format!(
                        "restrict_seg_lens mismatch for dim={dim} n={n:?}: \
                         rust {ours:?} vs python {theirs:?}"
                    )));
                }
            }
        }
        Ok(())
    }

    pub fn has(&self, key: &ArtifactKey) -> bool {
        if self.native {
            return true;
        }
        self.files.contains_key(key)
    }

    pub fn path(&self, key: &ArtifactKey) -> Result<PathBuf> {
        if self.native {
            return Err(Error::Artifact(
                "native interpreter manifest has no artifact files".into(),
            ));
        }
        self.files
            .get(key)
            .map(|f| self.dir.join(f))
            .ok_or_else(|| Error::Artifact(format!("no artifact for {key:?}")))
    }

    /// Available pack sizes for a (kind, dim, n, impl), ascending.
    pub fn pack_sizes(&self, kind: &str, dim: usize, n: [usize; 3], impl_: &str) -> Vec<usize> {
        if self.native {
            return NATIVE_PACK_SIZES.to_vec();
        }
        let mut v: Vec<usize> = self
            .files
            .keys()
            .filter(|k| k.kind == kind && k.dim == dim && k.n == n && k.impl_ == impl_)
            .map(|k| k.nb)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    pub fn keys(&self) -> impl Iterator<Item = &ArtifactKey> {
        self.files.keys()
    }
}

/// Locate the artifacts directory: $PARTHENON_ARTIFACTS or ./artifacts
/// (walking up from cwd so tests/benches work from target dirs).
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(d) = std::env::var("PARTHENON_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        default_artifact_dir().join("manifest.json").exists()
    }

    #[test]
    fn load_real_manifest_and_cross_check() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(default_artifact_dir()).unwrap();
        assert_eq!(m.nghost, crate::NGHOST);
        // the canonical Table-1 variants exist
        let key = ArtifactKey::new("stage", 3, [16, 16, 16], 1);
        assert!(m.has(&key), "stage 16^3 nb=1 must exist");
        assert!(m.path(&key).unwrap().exists());
        let sizes = m.pack_sizes("stage", 3, [16, 16, 16], "jnp");
        assert!(sizes.contains(&1) && sizes.contains(&16), "{sizes:?}");
        // pack1 per-neighbor variants
        let k1 = ArtifactKey::new("pack1", 3, [16, 16, 16], 1).with_nbr(0);
        assert!(m.has(&k1));
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = Manifest::load("/nonexistent/path").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
