//! Simulation-as-a-service: N independent [`HydroSim`] tenants in ONE
//! process, sharing ONE compiled-artifact [`Runtime`] and ONE worker pool.
//!
//! The paper's central throughput lever is packing — batching blocks into
//! one kernel launch so the launch overhead amortizes (Sec. 3.6 / Fig. 8).
//! This module generalizes that across *tenants*: many small concurrent
//! simulations are exactly the regime where launch overhead, not FLOPs,
//! bounds throughput, so the [`Engine`]
//!
//! * constructs the process's single [`Runtime`] once (the `&self`
//!   compile-once executable cache is already thread-shareable) and
//!   injects it into every session via [`SimBuilder::runtime`] — a corrupt
//!   artifact dir surfaces once, at engine build, not once per session;
//! * multiplexes every live session's per-pack task lists into ONE merged
//!   [`TaskRegion`] per RK stage ([`run_cycle_multi`]), executed on one
//!   shared cost-weighted stealing pool, so idle workers drain whichever
//!   tenant has work (cross-tenant steals are counted);
//! * fuses same-shape device packs of DIFFERENT sessions into one batched
//!   launch ([`BatchRegistry`] → [`Runtime::fused_batch`]) with per-tenant
//!   result scatter.
//!
//! Every optimization is pinned: N concurrent sessions are bitwise
//! identical (state, dt bits, checkpoint bytes) to the same N sims run
//! sequentially, with multiplexing ([`EngineConfig::multiplex`]) and
//! batching ([`EngineConfig::batching`]) each independently toggleable as
//! oracles (`rust/tests/service_equivalence.rs`).
//!
//! [`TaskRegion`]: crate::tasks::TaskRegion
//! [`run_cycle_multi`]: crate::driver::run_cycle_multi

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::ParameterInput;
use crate::driver::{EvolutionDriver, HydroSim, SimBuilder};
use crate::error::{Error, Result};
use crate::metrics::ServiceStats;
use crate::runtime::{ArtifactKey, FusedPart, Runtime, ScalArgs};
use crate::util::stealing::StealPolicy;
use crate::Real;

// ---------------------------------------------------------------------------
// Cross-simulation pack batching
// ---------------------------------------------------------------------------

/// One tenant's donated staging buffers for a batched `fused` launch: the
/// exact per-pack arrays the solo launch would hand to
/// [`Runtime::fused`], moved (not copied) into the rendezvous and moved
/// back with the results.
pub(crate) struct FusedParcel {
    pub u: Vec<Real>,
    pub u0: Vec<Real>,
    pub bufs_in: Vec<Real>,
    pub bufs_out: Vec<Real>,
    pub scal: ScalArgs,
}

/// Per-slot rendezvous state of one [`BatchGroup`].
#[derive(Default)]
struct GroupState {
    /// Enlisting sim (slot-indexed) — a group must span ≥ 2 distinct sims
    /// to stay active past [`BatchRegistry::seal`].
    sims: Vec<u32>,
    parcels: Vec<Option<FusedParcel>>,
    results: Vec<Option<(FusedParcel, Vec<Real>, f64)>>,
    arrived: usize,
    launched: bool,
    /// Launcher-observed failure, re-surfaced to every other participant
    /// (the stage aborts; nobody waits on a launch that never completed).
    error: Option<String>,
}

/// Rendezvous for ONE batched launch: every same-[`ArtifactKey`] device
/// pack enlisted this stage posts its staging parcel, and whichever
/// participant polls last runs ONE [`Runtime::fused_batch`] over the whole
/// group, scattering per-slot results.
pub(crate) struct BatchGroup {
    key: ArtifactKey,
    /// Number of enlisted slots, fixed at [`BatchRegistry::seal`].
    need: AtomicUsize,
    /// False until sealed, and forever for groups that did not span ≥ 2
    /// distinct sims (their tickets are inert — the pack launches solo, so
    /// a single-session engine is bitwise the plain run by construction).
    active: AtomicBool,
    state: Mutex<GroupState>,
}

impl BatchGroup {
    /// Whether tickets into this group route through the rendezvous at
    /// all. Checked per launch task; false before seal and for dissolved
    /// (single-sim) groups.
    pub(crate) fn is_active(&self) -> bool {
        self.active.load(Ordering::SeqCst)
    }

    /// Donate one slot's staging buffers. Called exactly once per ticket
    /// (the launch task tracks `posted`).
    pub(crate) fn post(&self, slot: usize, parcel: FusedParcel) {
        let mut st = self.state.lock().unwrap();
        debug_assert!(st.parcels[slot].is_none(), "double post on batch slot");
        st.parcels[slot] = Some(parcel);
        st.arrived += 1;
    }

    /// One poll of the rendezvous: `Ok(None)` while co-batched packs are
    /// still arriving (the task returns `Incomplete` and the worker sweeps
    /// on), the poll that finds everyone arrived runs the single fused
    /// launch, and every participant then reclaims its own
    /// (parcel, per-block dts, per-part seconds).
    pub(crate) fn try_collect(
        &self,
        rt: &Runtime,
        slot: usize,
    ) -> Result<Option<(FusedParcel, Vec<Real>, f64)>> {
        let mut st = self.state.lock().unwrap();
        if let Some(msg) = &st.error {
            return Err(Error::Runtime(format!("batched launch failed: {msg}")));
        }
        if !st.launched {
            if st.arrived < self.need.load(Ordering::SeqCst) {
                return Ok(None);
            }
            // Everyone arrived: take the parcels in slot order and run the
            // whole group under ONE launch. Holding the group lock briefly
            // blocks the other participants' polls — they would only spin
            // Incomplete anyway until the results land.
            let mut parcels: Vec<FusedParcel> = st
                .parcels
                .iter_mut()
                .map(|p| p.take().expect("all slots posted"))
                .collect();
            let mut parts: Vec<FusedPart<'_>> = parcels
                .iter_mut()
                .map(|p| FusedPart {
                    u: &mut p.u,
                    u0: &p.u0,
                    bufs_in: &p.bufs_in,
                    scal: p.scal,
                    bufs_out: &mut p.bufs_out,
                })
                .collect();
            match rt.fused_batch(&self.key, &mut parts) {
                Ok(out) => {
                    drop(parts);
                    for (res, (parcel, (dts, secs))) in
                        st.results.iter_mut().zip(parcels.into_iter().zip(out))
                    {
                        *res = Some((parcel, dts, secs));
                    }
                    st.launched = true;
                }
                Err(e) => {
                    st.error = Some(e.to_string());
                    return Err(e);
                }
            }
        }
        Ok(st.results[slot].take())
    }
}

/// One pack's membership in a [`BatchGroup`], handed to the device launch
/// task via `DevPackCtx::batch`.
pub(crate) struct BatchTicket {
    pub(crate) group: Arc<BatchGroup>,
    pub(crate) slot: usize,
    /// Whether this ticket's parcel was already donated (the launch task
    /// polls repeatedly; the donation happens on the first poll only).
    pub(crate) posted: bool,
}

/// Per-stage registry of batch groups, keyed by [`ArtifactKey`] (kind +
/// block geometry + pack size + kernel impl — parts of one batch are
/// buffer-layout identical by construction, and `pallas`/`jnp` tenants
/// never mix). Built during stage pass 1, sealed before any task runs.
pub(crate) struct BatchRegistry {
    groups: HashMap<ArtifactKey, Arc<BatchGroup>>,
}

impl BatchRegistry {
    pub(crate) fn new() -> BatchRegistry {
        BatchRegistry { groups: HashMap::new() }
    }

    /// Enlist one device pack of simulation `sim` into the group for
    /// `key`, creating the group on first sight. The returned ticket is
    /// inert until [`BatchRegistry::seal`] activates its group.
    pub(crate) fn enlist(&mut self, key: ArtifactKey, sim: u32) -> BatchTicket {
        let group = self
            .groups
            .entry(key.clone())
            .or_insert_with(|| {
                Arc::new(BatchGroup {
                    key,
                    need: AtomicUsize::new(0),
                    active: AtomicBool::new(false),
                    state: Mutex::new(GroupState::default()),
                })
            });
        let mut st = group.state.lock().unwrap();
        let slot = st.sims.len();
        st.sims.push(sim);
        st.parcels.push(None);
        st.results.push(None);
        drop(st);
        BatchTicket { group: Arc::clone(group), slot, posted: false }
    }

    /// Fix every group's membership: `need` = enlisted slots, and only
    /// groups spanning ≥ 2 DISTINCT sims activate — a single-sim group
    /// dissolves (tickets stay inert, packs launch solo), so every
    /// surviving batch is genuinely cross-tenant and a one-session engine
    /// runs bit-for-bit like a plain sim.
    pub(crate) fn seal(&mut self) {
        for g in self.groups.values() {
            let st = g.state.lock().unwrap();
            let n = st.sims.len();
            let mut distinct = st.sims.clone();
            distinct.sort_unstable();
            distinct.dedup();
            g.need.store(n, Ordering::SeqCst);
            g.active.store(n >= 2 && distinct.len() >= 2, Ordering::SeqCst);
        }
    }

    /// (batched launches, launches saved) across every group that actually
    /// ran: each batch of `need` packs cost ONE launch instead of `need`.
    pub(crate) fn harvest(&self) -> (u64, u64) {
        let (mut batched, mut saved) = (0u64, 0u64);
        for g in self.groups.values() {
            if !g.is_active() {
                continue;
            }
            let st = g.state.lock().unwrap();
            if st.launched {
                batched += 1;
                saved += (g.need.load(Ordering::SeqCst) as u64).saturating_sub(1);
            }
        }
        (batched, saved)
    }
}

// ---------------------------------------------------------------------------
// Engine / Session
// ---------------------------------------------------------------------------

/// Cross-tenant counters harvested by the stage multiplexer
/// ([`crate::driver::run_stage_multi`]) and folded into
/// [`ServiceStats`] by [`Engine::stats`].
#[derive(Default)]
pub struct ServiceCounters {
    pub batched_launches: AtomicU64,
    pub launches_saved: AtomicU64,
    pub cross_sim_steals: AtomicU64,
}

/// The engine's global worker-pool shape, injected into every session via
/// [`SimBuilder::pool`] (so solo-stepped sessions schedule identically)
/// and passed to the merged stage region as the worker override.
pub struct SharedPool {
    pub nworkers: usize,
    pub policy: StealPolicy,
}

impl SharedPool {
    /// `nworkers = 0` resolves to the machine's parallelism exactly like
    /// `parthenon/exec nworkers = 0` does for a solo run.
    pub fn new(nworkers: usize, policy: StealPolicy) -> SharedPool {
        let nworkers = if nworkers > 0 {
            nworkers
        } else {
            crate::util::num_workers(usize::MAX, 1)
        };
        SharedPool { nworkers, policy }
    }
}

/// Engine construction knobs. The two `bool`s are the oracle toggles of
/// the service equivalence suite: with both off, [`Engine::run`] is
/// N sequential solo runs that merely share the runtime.
pub struct EngineConfig {
    /// Shared pool width (0 = auto, like `parthenon/exec nworkers`).
    pub nworkers: usize,
    /// Shared pool schedule (overrides every session's deck).
    pub sched: StealPolicy,
    /// Run every live session's cycle through ONE merged task region
    /// (false = step sessions one at a time, the sequential oracle).
    pub multiplex: bool,
    /// Fuse same-shape device packs of different sessions into one launch
    /// (requires `multiplex`; false = every pack launches solo).
    pub batching: bool,
    /// Artifact directory for the single shared [`Runtime`] (`None` =
    /// [`crate::runtime::default_artifact_dir`]).
    pub artifact_dir: Option<std::path::PathBuf>,
}

impl Default for EngineConfig {
    fn default() -> EngineConfig {
        EngineConfig {
            nworkers: 0,
            sched: StealPolicy::Heaviest,
            multiplex: true,
            batching: true,
            artifact_dir: None,
        }
    }
}

/// One tenant: a [`HydroSim`] built against the engine's shared runtime
/// and pool. Public so tests and benches can inspect the final state.
pub struct Session {
    pub sim: HydroSim,
}

/// The multi-tenant simulation service: one process, one [`Runtime`], one
/// worker pool, N sessions. See the module docs for the ownership story.
pub struct Engine {
    rt: Arc<Runtime>,
    pool: SharedPool,
    counters: ServiceCounters,
    sessions: Vec<Session>,
    multiplex: bool,
    batching: bool,
}

/// Per-session engine take-out for one multiplexed cycle (the same
/// host/device take-dance `HydroSim::step` performs, held across all
/// sessions at once so the merged region can borrow every sim).
struct TakenEngines {
    host: Option<crate::driver::HostExec>,
    dev: Option<crate::driver::DeviceState>,
    dt: Real,
    live: bool,
}

impl Engine {
    /// Build the engine — and with it the process's ONE [`Runtime`]. A
    /// corrupt artifact dir fails here, once, before any session exists.
    pub fn new(cfg: EngineConfig) -> Result<Engine> {
        let dir = cfg
            .artifact_dir
            .clone()
            .unwrap_or_else(crate::runtime::default_artifact_dir);
        let rt = Arc::new(Runtime::new(dir)?);
        Ok(Engine {
            rt,
            pool: SharedPool::new(cfg.nworkers, cfg.sched),
            counters: ServiceCounters::default(),
            sessions: Vec::new(),
            multiplex: cfg.multiplex,
            batching: cfg.batching,
        })
    }

    /// Attach a tenant: build its sim with the shared runtime and pool
    /// injected ([`SimBuilder`]); returns the session index.
    pub fn add_session(&mut self, pin: ParameterInput) -> Result<usize> {
        let sim = SimBuilder::new(pin)
            .runtime(Arc::clone(&self.rt))
            .pool(&self.pool)
            .build()?;
        self.sessions.push(Session { sim });
        Ok(self.sessions.len() - 1)
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    pub fn pool(&self) -> &SharedPool {
        &self.pool
    }

    pub fn sessions(&self) -> &[Session] {
        &self.sessions
    }

    pub fn sessions_mut(&mut self) -> &mut [Session] {
        &mut self.sessions
    }

    pub fn session(&self, i: usize) -> &Session {
        &self.sessions[i]
    }

    /// Cross-tenant accounting so far (sessions attached, batched
    /// launches, launches saved, cross-sim steals).
    pub fn stats(&self) -> ServiceStats {
        ServiceStats {
            sessions_live: self.sessions.len() as u64,
            batched_launches: self.counters.batched_launches.load(Ordering::SeqCst),
            launches_saved: self.counters.launches_saved.load(Ordering::SeqCst),
            cross_sim_steals: self.counters.cross_sim_steals.load(Ordering::SeqCst),
        }
    }

    /// Advance every still-running session by one cycle. Multiplexed mode
    /// runs them all through ONE merged region ([`run_cycle_multi`]);
    /// otherwise each steps solo (the sequential oracle — identical code
    /// path to a plain `sim.step()`). Returns false once every session
    /// has finished.
    ///
    /// [`run_cycle_multi`]: crate::driver::run_cycle_multi
    pub fn step(&mut self) -> Result<bool> {
        if !self.sessions.iter().any(|s| s.sim.running()) {
            return Ok(false);
        }
        if !self.multiplex {
            for sess in &mut self.sessions {
                if sess.sim.running() {
                    sess.sim.step()?;
                    sess.sim.maybe_output(false)?;
                }
            }
            return Ok(true);
        }
        let t0 = std::time::Instant::now();
        // Take every live session's engines out (exactly the solo step's
        // take-dance, across all sessions) so the merged region's contexts
        // can borrow each sim alongside its engines.
        let mut first_err: Option<Error> = None;
        let mut taken: Vec<TakenEngines> = Vec::with_capacity(self.sessions.len());
        for sess in &mut self.sessions {
            let mut live = first_err.is_none() && sess.sim.running();
            let mut dt: Real = 0.0;
            if live {
                match sess.sim.pre_step() {
                    Ok(v) => dt = v,
                    Err(e) => {
                        first_err = Some(e);
                        live = false;
                    }
                }
            }
            taken.push(TakenEngines {
                host: if live { sess.sim.host.take() } else { None },
                dev: if live { sess.sim.device.take() } else { None },
                dt,
                live,
            });
        }
        let result = if first_err.is_none() {
            let shared = crate::driver::StageShared {
                workers: Some((self.pool.nworkers, self.pool.policy)),
                batching: self.batching,
                svc: Some(&self.counters),
            };
            let mut slots: Vec<crate::driver::SimSlot<'_>> =
                Vec::with_capacity(taken.len());
            for (sess, tk) in self.sessions.iter_mut().zip(taken.iter_mut()) {
                if tk.live {
                    slots.push(crate::driver::SimSlot {
                        sim: &mut sess.sim,
                        host: tk.host.as_mut(),
                        dev: tk.dev.as_mut(),
                        dt: tk.dt,
                    });
                }
            }
            crate::driver::run_cycle_multi(&mut slots, &shared)
        } else {
            Ok(())
        };
        // Restore engines on every path, including errors.
        for (sess, tk) in self.sessions.iter_mut().zip(taken.iter_mut()) {
            if tk.live {
                sess.sim.host = tk.host.take();
                sess.sim.device = tk.dev.take();
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        result?;
        let elapsed = t0.elapsed().as_secs_f64();
        for (sess, tk) in self.sessions.iter_mut().zip(taken.iter()) {
            if tk.live {
                sess.sim.post_step(elapsed)?;
                sess.sim.maybe_output(false)?;
            }
        }
        Ok(true)
    }

    /// Run every session to completion (the service analog of
    /// [`crate::driver::Driver::execute`], outputs included).
    pub fn run(&mut self) -> Result<()> {
        for sess in &mut self.sessions {
            sess.sim.maybe_output(true)?;
        }
        while self.step()? {}
        for sess in &mut self.sessions {
            sess.sim.maybe_output(true)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::default_artifact_dir;
    use crate::NHYDRO;

    fn key() -> ArtifactKey {
        ArtifactKey::new("fused", 2, [8, 8, 1], 2)
    }

    #[test]
    fn single_sim_group_dissolves_at_seal() {
        let mut reg = BatchRegistry::new();
        let t0 = reg.enlist(key(), 0);
        let t1 = reg.enlist(key(), 0);
        assert!(!t0.group.is_active(), "inert before seal");
        reg.seal();
        assert!(!t0.group.is_active(), "one sim, two packs: dissolved");
        assert!(!t1.group.is_active());
        assert_eq!(reg.harvest(), (0, 0));
    }

    #[test]
    fn cross_sim_group_activates_and_slots_are_ordered() {
        let mut reg = BatchRegistry::new();
        let t0 = reg.enlist(key(), 0);
        let t1 = reg.enlist(key(), 1);
        let t2 = reg.enlist(key(), 1);
        assert_eq!((t0.slot, t1.slot, t2.slot), (0, 1, 2));
        assert!(Arc::ptr_eq(&t0.group, &t1.group), "same key, same group");
        reg.seal();
        assert!(t0.group.is_active(), "two sims: active");
        // not launched yet: nothing harvested
        assert_eq!(reg.harvest(), (0, 0));
    }

    #[test]
    fn distinct_keys_never_share_a_group() {
        let mut reg = BatchRegistry::new();
        let other = ArtifactKey::new("fused", 2, [8, 8, 1], 4); // nb differs
        let t0 = reg.enlist(key(), 0);
        let t1 = reg.enlist(other, 1);
        assert!(!Arc::ptr_eq(&t0.group, &t1.group));
        reg.seal();
        assert!(!t0.group.is_active(), "each group is single-sim");
        assert!(!t1.group.is_active());
    }

    #[test]
    fn rendezvous_launches_once_and_matches_solo_bits() {
        let rt = Runtime::new(default_artifact_dir()).unwrap();
        let k = key();
        let ne = Runtime::block_elems(&k);
        let bl = Runtime::buflen(&k);
        let mk = |seed: f32| {
            let ncell = ne / NHYDRO;
            let mut u = vec![0.0f32; k.nb * ne];
            for b in 0..k.nb {
                for c in 0..ncell {
                    u[b * ne + c] = 1.0 + 0.01 * seed * (c % 7) as f32;
                    u[b * ne + 4 * ncell + c] = 2.5 + 0.001 * seed;
                }
            }
            let bufs_in = vec![1.0f32; k.nb * bl];
            let scal = ScalArgs {
                g0: 0.5,
                g1: 0.5,
                beta: 0.5,
                dt: 1e-3 * seed,
                dx: [0.05; 3],
                gamma: 1.4,
            };
            FusedParcel {
                u: u.clone(),
                u0: u,
                bufs_in,
                bufs_out: vec![0.0f32; k.nb * bl],
                scal,
            }
        };

        // solo reference for both tenants
        let solo: Vec<_> = [1.0f32, 2.0]
            .iter()
            .map(|&s| {
                let mut p = mk(s);
                let dts = rt
                    .fused(&k, &mut p.u, &p.u0, &p.bufs_in, p.scal, &mut p.bufs_out)
                    .unwrap();
                (p, dts)
            })
            .collect();

        let mut reg = BatchRegistry::new();
        let mut t0 = reg.enlist(k.clone(), 0);
        let mut t1 = reg.enlist(k.clone(), 1);
        reg.seal();
        assert!(t0.group.is_active());

        t0.group.post(t0.slot, mk(1.0));
        assert!(
            t0.group.try_collect(&rt, t0.slot).unwrap().is_none(),
            "waits for the co-batched tenant"
        );
        t1.group.post(t1.slot, mk(2.0));
        let l0 = rt.launches();
        let (p0, d0, _) = t0.group.try_collect(&rt, t0.slot).unwrap().unwrap();
        assert_eq!(rt.launches() - l0, 1, "one launch for the whole batch");
        let (p1, d1, _) = t1.group.try_collect(&rt, t1.slot).unwrap().unwrap();
        assert_eq!(rt.launches() - l0, 1, "collect does not relaunch");
        t0.posted = true;
        t1.posted = true;

        assert_eq!(p0.u, solo[0].0.u, "tenant 0 state bits");
        assert_eq!(p0.bufs_out, solo[0].0.bufs_out, "tenant 0 boundary bits");
        assert_eq!(d0, solo[0].1, "tenant 0 dt bits");
        assert_eq!(p1.u, solo[1].0.u, "tenant 1 state bits");
        assert_eq!(d1, solo[1].1, "tenant 1 dt bits");
        assert_eq!(reg.harvest(), (1, 1), "one batch of two: one launch saved");
    }

    #[test]
    fn shared_pool_resolves_auto_width() {
        let p = SharedPool::new(0, StealPolicy::Heaviest);
        assert!(p.nworkers >= 1);
        let p4 = SharedPool::new(4, StealPolicy::NoSteal);
        assert_eq!(p4.nworkers, 4);
    }
}
