//! `MeshData` — the cached pack-centric view of one rank's blocks
//! (paper Sec. 3.6: logical packing of variables *and mesh blocks*).
//!
//! The local blocks are partitioned once into contiguous MeshBlockPacks
//! honoring `parthenon/exec pack_size`; the partition plus its per-pack
//! gather/scatter staging buffers are cached here and invalidated only when
//! the mesh changes (regrid / load balance / restart) — not rebuilt per
//! stage. Both execution spaces consume this one structure:
//!
//! * **Host** — packs are the unit of work for the scoped-thread worker
//!   pool: each pack is a contiguous `first..first+nb` block range, so
//!   per-block work arrays split into disjoint `&mut` chunks per worker.
//! * **Device** — packs are the unit of launch: staging buffers hold the
//!   flat `[nb, NVAR, Z, Y, X]` slabs and `[nb, BUFLEN]` boundary buffers
//!   the artifacts consume.
//!
//! Staleness safety: a `MeshData` pins the [`Mesh::version`] it was built
//! against. Every stage entry point calls [`MeshData::validate`] first, so
//! running on a pack plan that no longer matches the block set is an error,
//! never silent corruption. The single driver-side rebuild hook is
//! `HydroSim::rebuild_work_buffers` (which goes through
//! [`MeshData::ensure_current`]); on Device runs the DeviceState is torn
//! down first and recreated after, so the plan is re-drawn from the
//! artifact pack sizes and staging re-gathered.

use std::collections::HashMap;
use std::ops::Range;

use crate::bvals::bufspec;
use crate::error::{Error, Result};
use crate::mesh::{Mesh, LogicalLocation};
use crate::runtime::plan_packs;
use crate::{Real, NHYDRO};

/// Which execution space currently owns a pack (hybrid co-execution).
/// Host-owned packs keep their block containers authoritative (staging
/// dirty); Device-owned packs keep their staging authoritative (clean).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PackSpace {
    Host,
    Device,
}

/// One MeshBlockPack: a contiguous run of local block indices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PackDesc {
    /// Pack index within the plan.
    pub index: usize,
    /// First local block index (order of `mesh.blocks`).
    pub first: usize,
    /// Number of blocks in the pack.
    pub nb: usize,
}

impl PackDesc {
    pub fn block_range(&self) -> Range<usize> {
        self.first..self.first + self.nb
    }
}

/// The predicted effect of a rebalance on the pack plan, computed by
/// [`MeshData::plan_delta`] BEFORE the mesh is touched. The incremental
/// rebalance uses it to scatter exactly the packs whose staging will not
/// survive the re-plan (so their containers are authoritative before
/// blocks migrate or staging is re-gathered) — and nothing else.
#[derive(Debug, Clone, Default)]
pub struct PlanDelta {
    /// CURRENT clean pack indices whose staging will NOT be preserved by
    /// [`MeshData::rebuild_preserving`] — the packs to scatter before the
    /// rebuild. Dirty packs are excluded (their containers are already
    /// authoritative), as is everything when no staging is resident.
    pub stale_old: Vec<usize>,
    /// New packs that will start dirty (each pays one re-gather).
    pub dirty_new: usize,
    /// New packs whose staging stays resident.
    pub preserved_new: usize,
}

/// Per-pack staging storage for the device path (and any consumer that
/// wants the packed flat layout). Allocated lazily by
/// [`MeshData::ensure_staging`]; the host path never pays for it.
#[derive(Debug, Default)]
pub struct PackStaging {
    /// `[nb, NVAR, Z, Y, X]` conserved state.
    pub u: Vec<Real>,
    /// Cycle-start state for the RK combine.
    pub u0: Vec<Real>,
    /// `[nb, BUFLEN]` inbound boundary buffers.
    pub bufs_in: Vec<Real>,
    /// `[nb, BUFLEN]` outbound boundary buffers.
    pub bufs_out: Vec<Real>,
}

/// THE staging-survival matcher: for each new pack loc-set, the old CLEAN
/// pack index whose staging survives into it (`None` otherwise). Both
/// [`MeshData::plan_delta`] (prediction) and
/// [`MeshData::rebuild_preserving`] (commit) go through this one function,
/// so the prediction can never drift from what the rebuild actually does.
fn match_survivors(
    old_locs: &[Vec<LogicalLocation>],
    old_dirty: &[bool],
    new_sets: &[&[LogicalLocation]],
) -> Vec<Option<usize>> {
    let by_locs: HashMap<&[LogicalLocation], usize> = old_locs
        .iter()
        .enumerate()
        .map(|(i, l)| (l.as_slice(), i))
        .collect();
    new_sets
        .iter()
        .map(|set| by_locs.get(*set).copied().filter(|&oi| !old_dirty[oi]))
        .collect()
}

/// The cached pack partition of one rank's local blocks.
#[derive(Debug)]
pub struct MeshData {
    pack_size: usize,
    /// `Mesh::version` this plan was built against (0 = invalidated).
    mesh_version: u64,
    nblocks: usize,
    block_elems: usize,
    buflen: usize,
    descs: Vec<PackDesc>,
    staging: Vec<PackStaging>,
    staged: bool,
    /// Per-pack block identities (LogicalLocations are stable across gid
    /// renumbering) — the key for preserving staging across rebuilds.
    locs: Vec<Vec<LogicalLocation>>,
    /// Per-pack: staging does not reflect the block containers and must be
    /// re-gathered before use.
    dirty: Vec<bool>,
    /// Per-pack owning execution space (hybrid co-execution). A rebuild
    /// resets everything to Host; the hybrid partitioner re-assigns via
    /// [`MeshData::set_pack_spaces`].
    spaces: Vec<PackSpace>,
    /// Cumulative count of packs gathered (instrumentation: tests assert
    /// that clean packs are NOT re-gathered after a rebalance).
    gathered_packs: u64,
}

impl MeshData {
    /// Partition `mesh`'s local blocks into packs of at most `pack_size`
    /// blocks. `avail` restricts pack sizes to the given ascending set
    /// (device artifact variants); `None` allows any size up to
    /// `pack_size` (host path).
    pub fn build(mesh: &Mesh, pack_size: usize, avail: Option<&[usize]>) -> MeshData {
        let shape = mesh.cfg.index_shape();
        let mut md = MeshData {
            pack_size: pack_size.max(1),
            mesh_version: 0,
            nblocks: 0,
            block_elems: NHYDRO * shape.ncells_total(),
            buflen: bufspec::buflen(&shape, NHYDRO),
            descs: Vec::new(),
            staging: Vec::new(),
            staged: false,
            locs: Vec::new(),
            dirty: Vec::new(),
            spaces: Vec::new(),
            gathered_packs: 0,
        };
        md.rebuild(mesh, avail);
        md
    }

    /// The pack-size menu a (re)build draws from: the device artifact
    /// variants when given, any size up to `pack_size` otherwise. Shared
    /// by [`MeshData::rebuild`] and [`MeshData::plan_delta`] so the delta
    /// predicts exactly the plan a rebuild will draw.
    fn size_menu(&self, avail: Option<&[usize]>) -> Vec<usize> {
        match avail {
            Some(a) if !a.is_empty() => a.to_vec(),
            _ => (1..=self.pack_size).collect(),
        }
    }

    /// Recompute the plan for the mesh's current block set (drops staging;
    /// it is re-allocated on demand).
    pub fn rebuild(&mut self, mesh: &Mesh, avail: Option<&[usize]>) {
        let sizes = self.size_menu(avail);
        let plan = plan_packs(mesh.blocks.len(), &sizes, self.pack_size);
        self.descs.clear();
        let mut first = 0usize;
        for (index, nb) in plan.into_iter().enumerate() {
            self.descs.push(PackDesc { index, first, nb });
            first += nb;
        }
        self.nblocks = first;
        debug_assert_eq!(self.nblocks, mesh.blocks.len());
        self.staging.clear();
        self.staged = false;
        self.locs = self
            .descs
            .iter()
            .map(|d| mesh.blocks[d.block_range()].iter().map(|b| b.loc).collect())
            .collect();
        self.dirty = vec![true; self.descs.len()];
        self.spaces = vec![PackSpace::Host; self.descs.len()];
        self.mesh_version = mesh.version;
    }

    /// Re-plan against the mesh's current block set, preserving the staging
    /// buffers (and clean status) of every pack whose block identity set is
    /// unchanged — the persistent-staging path for load balance: only
    /// migrated packs become dirty and pay a re-gather. Runs even when the
    /// plan is current (the pack-size menu may have changed, e.g. Host plan
    /// -> Device artifact sizes). Returns the number of packs preserved.
    pub fn rebuild_preserving(&mut self, mesh: &Mesh, avail: Option<&[usize]>) -> usize {
        let old_locs = std::mem::take(&mut self.locs);
        let old_dirty = std::mem::take(&mut self.dirty);
        let mut old_staging: Vec<Option<PackStaging>> =
            std::mem::take(&mut self.staging).into_iter().map(Some).collect();
        let was_staged = self.staged;
        self.rebuild(mesh, avail);
        if !was_staged {
            return 0;
        }
        let new_sets: Vec<&[LogicalLocation]> =
            self.locs.iter().map(|l| l.as_slice()).collect();
        let survivors = match_survivors(&old_locs, &old_dirty, &new_sets);
        drop(new_sets);
        self.ensure_staging();
        let mut kept = 0usize;
        for (pi, oi) in survivors.into_iter().enumerate() {
            let Some(oi) = oi else { continue };
            if let Some(s) = old_staging[oi].take() {
                self.staging[pi] = s;
                self.dirty[pi] = false;
                kept += 1;
            }
        }
        kept
    }

    /// Predict, WITHOUT touching anything, which packs a coming
    /// [`MeshData::rebuild_preserving`] against `new_locs` (the locations
    /// this rank will own, in gid order) would preserve, mirroring its
    /// loc-set matching exactly: a new pack keeps resident staging iff its
    /// location set equals a current CLEAN pack's. Everything else lands
    /// in [`PlanDelta::stale_old`] / counts as dirty.
    pub fn plan_delta(&self, new_locs: &[LogicalLocation], avail: Option<&[usize]>) -> PlanDelta {
        let sizes = self.size_menu(avail);
        let plan = plan_packs(new_locs.len(), &sizes, self.pack_size);
        let mut new_sets: Vec<&[LogicalLocation]> = Vec::with_capacity(plan.len());
        let mut first = 0usize;
        for nb in plan {
            new_sets.push(&new_locs[first..first + nb]);
            first += nb;
        }
        debug_assert_eq!(first, new_locs.len());
        if !self.staged {
            // nothing resident: every new pack starts dirty, and there is
            // no staging to scatter back
            return PlanDelta {
                stale_old: Vec::new(),
                dirty_new: new_sets.len(),
                preserved_new: 0,
            };
        }
        let mut survives = vec![false; self.descs.len()];
        let mut preserved_new = 0usize;
        for oi in match_survivors(&self.locs, &self.dirty, &new_sets)
            .into_iter()
            .flatten()
        {
            survives[oi] = true;
            preserved_new += 1;
        }
        PlanDelta {
            // dirty old packs are excluded: their containers are already
            // authoritative (that is what dirty MEANS), so there is no
            // resident state to scatter back before it is dropped
            stale_old: survives
                .iter()
                .enumerate()
                .filter_map(|(i, s)| (!s && !self.dirty[i]).then_some(i))
                .collect(),
            dirty_new: new_sets.len() - preserved_new,
            preserved_new,
        }
    }

    /// Rebuild only if stale. Returns true when a rebuild happened.
    pub fn ensure_current(&mut self, mesh: &Mesh, avail: Option<&[usize]>) -> bool {
        if self.is_current(mesh) {
            return false;
        }
        self.rebuild(mesh, avail);
        true
    }

    /// Mark the plan unusable until the next rebuild.
    pub fn invalidate(&mut self) {
        // Mesh versions start at 1 (build bumps from 0), so 0 never matches.
        self.mesh_version = 0;
    }

    pub fn is_current(&self, mesh: &Mesh) -> bool {
        self.mesh_version != 0 && self.mesh_version == mesh.version
    }

    /// Error unless the plan matches the mesh's current block set. Every
    /// stage entry point calls this — stale packs cannot be executed.
    pub fn validate(&self, mesh: &Mesh) -> Result<()> {
        if self.is_current(mesh) {
            return Ok(());
        }
        Err(Error::Mesh(format!(
            "stale MeshData: pack plan built for mesh version {} but mesh is \
             at version {} (regrid/load-balance without pack-cache rebuild?)",
            self.mesh_version, mesh.version
        )))
    }

    pub fn pack_size(&self) -> usize {
        self.pack_size
    }

    /// `Mesh::version` the current plan was built against (0 = invalid).
    pub fn built_version(&self) -> u64 {
        self.mesh_version
    }

    pub fn npacks(&self) -> usize {
        self.descs.len()
    }

    pub fn nblocks(&self) -> usize {
        self.nblocks
    }

    pub fn packs(&self) -> &[PackDesc] {
        &self.descs
    }

    /// Elements in one block's `[NVAR, Z, Y, X]` slab.
    pub fn block_elems(&self) -> usize {
        self.block_elems
    }

    /// Flat boundary-buffer length per block.
    pub fn buflen(&self) -> usize {
        self.buflen
    }

    /// Per-pack local block ranges (for per-pack boundary task lists).
    pub fn block_ranges(&self) -> Vec<Range<usize>> {
        self.descs.iter().map(|d| d.block_range()).collect()
    }

    /// Summed [`crate::mesh::MeshBlock::cost`] per pack — the seed weights
    /// for the work-stealing scheduler and the cost-weighted partition.
    pub fn pack_costs(&self, mesh: &Mesh) -> Vec<f64> {
        self.descs
            .iter()
            .map(|d| {
                mesh.blocks[d.block_range()]
                    .iter()
                    .map(|b| b.cost)
                    .sum::<f64>()
                    .max(f64::MIN_POSITIVE)
            })
            .collect()
    }

    /// Pack-aligned contiguous block ranges for `nworkers` parallel
    /// workers, balanced by cumulative BLOCK count (uniform per-block
    /// cost). See [`MeshData::worker_block_ranges_weighted`].
    pub fn worker_block_ranges(&self, nworkers: usize) -> Vec<Range<usize>> {
        let uniform: Vec<f64> = self.descs.iter().map(|d| d.nb as f64).collect();
        self.worker_block_ranges_weighted(nworkers, &uniform)
    }

    /// Pack-aligned contiguous block ranges for `nworkers` parallel
    /// workers: packs are dealt out in contiguous groups balanced by
    /// cumulative per-pack COST (`pack_costs`; uniform costs reduce to
    /// block-count balance — pack sizes can be very uneven, e.g. a [64, 1]
    /// plan), and worker chunks never split a pack.
    pub fn worker_block_ranges_weighted(
        &self,
        nworkers: usize,
        pack_costs: &[f64],
    ) -> Vec<Range<usize>> {
        let npacks = self.descs.len();
        debug_assert_eq!(pack_costs.len(), npacks);
        if npacks == 0 {
            return Vec::new();
        }
        let nw = nworkers.max(1).min(npacks);
        let mut out = Vec::with_capacity(nw);
        let mut p = 0usize;
        let mut remaining: f64 = pack_costs.iter().sum();
        for w in 0..nw {
            let workers_left = nw - w;
            // even split of the remaining cost
            let target = remaining / workers_left as f64;
            let start = self.descs[p].first;
            let mut got_blocks = 0usize;
            let mut got_cost = 0.0f64;
            loop {
                got_blocks += self.descs[p].nb;
                got_cost += pack_costs[p];
                p += 1;
                if p >= npacks {
                    break;
                }
                // leave at least one pack for every later worker
                if npacks - p <= workers_left - 1 {
                    break;
                }
                if got_cost >= target {
                    break;
                }
            }
            out.push(start..start + got_blocks);
            remaining -= got_cost;
        }
        debug_assert_eq!(p, npacks);
        out
    }

    /// Whether staging buffers are allocated.
    pub fn has_staging(&self) -> bool {
        self.staged
    }

    /// Allocate (or keep) per-pack staging buffers sized for the current
    /// plan. Idempotent. Fresh buffers start dirty (zeros, not block data).
    pub fn ensure_staging(&mut self) {
        if self.staged {
            return;
        }
        self.staging = self
            .descs
            .iter()
            .map(|d| PackStaging {
                u: vec![0.0; d.nb * self.block_elems],
                u0: vec![0.0; d.nb * self.block_elems],
                bufs_in: vec![0.0; d.nb * self.buflen],
                bufs_out: vec![0.0; d.nb * self.buflen],
            })
            .collect();
        self.dirty = vec![true; self.descs.len()];
        self.staged = true;
    }

    /// Mark every pack's staging as out of sync with the block containers
    /// (e.g. after a restart wrote new data into the containers).
    pub fn mark_all_dirty(&mut self) {
        for d in &mut self.dirty {
            *d = true;
        }
    }

    /// Pack indices currently marked dirty.
    pub fn dirty_packs(&self) -> Vec<usize> {
        self.dirty
            .iter()
            .enumerate()
            .filter_map(|(i, d)| d.then_some(i))
            .collect()
    }

    /// Cumulative packs gathered since construction (instrumentation).
    pub fn gathered_packs(&self) -> u64 {
        self.gathered_packs
    }

    /// Per-pack owning execution space (all Host until the hybrid
    /// partitioner assigns otherwise).
    pub fn pack_spaces(&self) -> &[PackSpace] {
        &self.spaces
    }

    /// Record the hybrid partitioner's pack→space assignment. Does NOT
    /// touch dirty flags — migration restaging is the driver's job (a
    /// migrating pack pays exactly one restage, counted in HybridStats).
    pub fn set_pack_spaces(&mut self, spaces: Vec<PackSpace>) {
        debug_assert_eq!(spaces.len(), self.descs.len());
        self.spaces = spaces;
    }

    /// Mark the given packs' staging as out of sync with the block
    /// containers (a host-space pack's cycle wrote the containers).
    pub fn mark_packs_dirty(&mut self, packs: &[usize]) {
        for &pi in packs {
            self.dirty[pi] = true;
        }
    }

    /// Pack plan + staging, borrowed together (device stage loops).
    /// Requires [`MeshData::ensure_staging`] to have run.
    pub fn parts_mut(&mut self) -> (&[PackDesc], &mut [PackStaging]) {
        debug_assert!(self.staged, "ensure_staging before parts_mut");
        (&self.descs, &mut self.staging)
    }

    pub fn staging(&self) -> &[PackStaging] {
        &self.staging
    }

    /// Gather `var` from the authoritative block containers into the
    /// per-pack `u` staging slabs (all packs; marks everything dirty
    /// first, so the whole rank pays the copy — initialization/restart).
    pub fn gather(&mut self, mesh: &Mesh, var: &str) -> Result<()> {
        self.mark_all_dirty();
        self.gather_dirty(mesh, var)
    }

    /// Gather only the packs marked dirty, clearing their dirty flags —
    /// the persistent-staging fast path: after a load balance only
    /// migrated packs are dirty, so untouched packs are not re-gathered.
    pub fn gather_dirty(&mut self, mesh: &Mesh, var: &str) -> Result<()> {
        self.validate(mesh)?;
        self.ensure_staging();
        let ne = self.block_elems;
        let mut gathered = 0u64;
        for ((d, p), dirty) in self
            .descs
            .iter()
            .zip(self.staging.iter_mut())
            .zip(self.dirty.iter_mut())
        {
            if !*dirty {
                continue;
            }
            for bi in 0..d.nb {
                let arr = mesh.blocks[d.first + bi].data.get(var)?;
                p.u[bi * ne..(bi + 1) * ne].copy_from_slice(arr.as_slice());
            }
            *dirty = false;
            gathered += 1;
        }
        self.gathered_packs += gathered;
        Ok(())
    }

    /// Scatter the per-pack `u` staging slabs back into the block
    /// containers (IO / regrid / equivalence checks).
    pub fn scatter(&self, mesh: &mut Mesh, var: &str) -> Result<()> {
        let all: Vec<usize> = (0..self.descs.len()).collect();
        self.scatter_packs(mesh, var, &all)
    }

    /// Scatter only the given packs' `u` slabs into the block containers
    /// (partial sync: e.g. only the packs whose blocks are about to
    /// migrate off-rank need authoritative containers).
    pub fn scatter_packs(&self, mesh: &mut Mesh, var: &str, packs: &[usize]) -> Result<()> {
        self.validate(mesh)?;
        if !self.staged {
            return Err(Error::Mesh("MeshData scatter without staging".into()));
        }
        let ne = self.block_elems;
        for &pi in packs {
            let (d, p) = (&self.descs[pi], &self.staging[pi]);
            for bi in 0..d.nb {
                let arr = mesh.blocks[d.first + bi].data.get_mut(var)?;
                arr.as_mut_slice()
                    .copy_from_slice(&p.u[bi * ne..(bi + 1) * ne]);
            }
        }
        Ok(())
    }

    /// Scatter only the CLEAN packs' `u` slabs into the block containers —
    /// the residency-aware full sync: dirty packs' containers are already
    /// authoritative (that is what dirty MEANS), so copying staging over
    /// them would clobber newer data. On a pure-device run mid-cycle every
    /// pack is clean, so this is identical to [`MeshData::scatter`].
    pub fn scatter_resident(&self, mesh: &mut Mesh, var: &str) -> Result<()> {
        let clean: Vec<usize> = self
            .dirty
            .iter()
            .enumerate()
            .filter_map(|(i, d)| (!d).then_some(i))
            .collect();
        self.scatter_packs(mesh, var, &clean)
    }

    /// Gather only the GIVEN packs from their authoritative block
    /// containers into staging `u`, clearing their dirty flags — the
    /// host→device migration restage (one pack, one copy). Packs already
    /// clean are gathered anyway (callers pass exactly the migrating set).
    pub fn gather_packs(&mut self, mesh: &Mesh, var: &str, packs: &[usize]) -> Result<()> {
        self.validate(mesh)?;
        self.ensure_staging();
        let ne = self.block_elems;
        for &pi in packs {
            let d = &self.descs[pi];
            let p = &mut self.staging[pi];
            for bi in 0..d.nb {
                let arr = mesh.blocks[d.first + bi].data.get(var)?;
                p.u[bi * ne..(bi + 1) * ne].copy_from_slice(arr.as_slice());
            }
            self.dirty[pi] = false;
        }
        self.gathered_packs += packs.len() as u64;
        Ok(())
    }

    /// Scatter only the boundary-adjacent slabs (the interior shells
    /// neighbors read during a ghost exchange) of every CLEAN pack into
    /// the block containers — enough to make a container-side exchange
    /// correct without paying the full interior copy. Dirty packs are
    /// skipped (their containers are already authoritative).
    pub fn scatter_boundary(&self, mesh: &mut Mesh, var: &str) -> Result<()> {
        let all: Vec<usize> = (0..self.descs.len()).collect();
        self.scatter_boundary_packs(mesh, var, &all)
    }

    /// [`MeshData::scatter_boundary`] restricted to the given packs — the
    /// incremental-rebalance path syncs only the packs whose blocks border
    /// a migrating block (the only containers the subset ghost refresh
    /// reads). Dirty packs in the list are skipped, as in the full sweep.
    pub fn scatter_boundary_packs(
        &self,
        mesh: &mut Mesh,
        var: &str,
        packs: &[usize],
    ) -> Result<()> {
        self.validate(mesh)?;
        if !self.staged {
            return Err(Error::Mesh("MeshData scatter without staging".into()));
        }
        let shape = mesh.cfg.index_shape();
        let dim = shape.dim;
        let ne = self.block_elems;
        let n = shape.ncells_total();
        let (nt0, nt1) = (shape.nt(0), shape.nt(1));
        for &pi in packs {
            let (d, p, dirty) = (&self.descs[pi], &self.staging[pi], self.dirty[pi]);
            if dirty {
                continue;
            }
            for bi in 0..d.nb {
                let src = &p.u[bi * ne..(bi + 1) * ne];
                let arr = mesh.blocks[d.first + bi].data.get_mut(var)?;
                let dst = arr.as_mut_slice();
                for off in crate::mesh::neighbor_offsets(dim) {
                    let slab = bufspec::send_slab(off, &shape);
                    for v in 0..NHYDRO {
                        for k in slab.z.0..slab.z.1 {
                            for j in slab.y.0..slab.y.1 {
                                let row = v * n + (k * nt1 + j) * nt0;
                                dst[row + slab.x.0..row + slab.x.1]
                                    .copy_from_slice(&src[row + slab.x.0..row + slab.x.1]);
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ParameterInput;
    use crate::mesh::MeshConfig;

    fn mesh_2d(nblocks_side: usize) -> Mesh {
        let nx = 8 * nblocks_side;
        let deck = format!(
            "<parthenon/mesh>\nnx1 = {nx}\nnx2 = {nx}\n\
             <parthenon/meshblock>\nnx1 = 8\nnx2 = 8\n"
        );
        let mut pin = ParameterInput::from_str(&deck).unwrap();
        let cfg = MeshConfig::from_params(&mut pin).unwrap();
        Mesh::build(cfg, vec![], 0, 1)
    }

    /// Like [`mesh_2d`] but with a CONS field so gather/scatter work.
    fn mesh_2d_cons(nblocks_side: usize) -> Mesh {
        use crate::vars::{FieldDef, Metadata, MetadataFlag};
        let nx = 8 * nblocks_side;
        let deck = format!(
            "<parthenon/mesh>\nnx1 = {nx}\nnx2 = {nx}\n\
             <parthenon/meshblock>\nnx1 = 8\nnx2 = 8\n"
        );
        let mut pin = ParameterInput::from_str(&deck).unwrap();
        let cfg = MeshConfig::from_params(&mut pin).unwrap();
        let fields = vec![FieldDef {
            name: crate::hydro::CONS.into(),
            metadata: Metadata::new(&[
                MetadataFlag::Cell,
                MetadataFlag::Independent,
                MetadataFlag::FillGhost,
            ])
            .with_shape(vec![NHYDRO]),
        }];
        Mesh::build(cfg, fields, 0, 1)
    }

    #[test]
    fn plan_covers_blocks_contiguously() {
        let mesh = mesh_2d(4); // 16 blocks
        for ps in [1usize, 3, 4, 16, 64] {
            let md = MeshData::build(&mesh, ps, None);
            assert_eq!(md.nblocks(), 16);
            let mut next = 0usize;
            for d in md.packs() {
                assert_eq!(d.first, next, "packs must be contiguous");
                assert!(d.nb >= 1 && d.nb <= ps.max(1));
                next += d.nb;
            }
            assert_eq!(next, 16);
        }
        let md = MeshData::build(&mesh, 4, None);
        assert_eq!(md.npacks(), 4);
    }

    #[test]
    fn device_plan_respects_available_sizes() {
        let mesh = mesh_2d(4); // 16 blocks
        let md = MeshData::build(&mesh, 16, Some(&[1, 2, 4]));
        for d in md.packs() {
            assert!([1, 2, 4].contains(&d.nb));
        }
        assert_eq!(md.packs().iter().map(|d| d.nb).sum::<usize>(), 16);
    }

    #[test]
    fn stale_after_mesh_rebuild() {
        let mut mesh = mesh_2d(2);
        let mut md = MeshData::build(&mesh, 4, None);
        assert!(md.is_current(&mesh));
        assert!(md.validate(&mesh).is_ok());
        mesh.rebuild_local_blocks(); // load-balance / regrid analog
        assert!(!md.is_current(&mesh));
        assert!(md.validate(&mesh).is_err(), "stale packs must be unusable");
        assert!(md.ensure_current(&mesh, None));
        assert!(md.validate(&mesh).is_ok());
        assert!(!md.ensure_current(&mesh, None), "no rebuild when current");
    }

    #[test]
    fn invalidate_forces_rebuild() {
        let mesh = mesh_2d(2);
        let mut md = MeshData::build(&mesh, 4, None);
        md.invalidate();
        assert!(md.validate(&mesh).is_err());
        assert!(md.ensure_current(&mesh, None));
        assert!(md.validate(&mesh).is_ok());
    }

    #[test]
    fn worker_ranges_are_pack_aligned_and_cover() {
        let mesh = mesh_2d(4); // 16 blocks
        let md = MeshData::build(&mesh, 3, None); // packs 3,3,3,3,3,1
        for nw in [1usize, 2, 3, 5, 99] {
            let ranges = md.worker_block_ranges(nw);
            assert!(ranges.len() <= nw.max(1));
            let mut next = 0usize;
            for r in &ranges {
                assert_eq!(r.start, next);
                next = r.end;
                // pack alignment: every range boundary is a pack boundary
                assert!(
                    md.packs().iter().any(|d| d.first == r.start),
                    "range start {} not a pack boundary",
                    r.start
                );
            }
            assert_eq!(next, 16);
        }
    }

    #[test]
    fn worker_ranges_balance_blocks_not_packs() {
        // 9 blocks with plan [4,1,1,1,1,1]: pack-count dealing would give
        // a worker 6 blocks and the other 3; block-count dealing gives 5/4.
        let nx = 8 * 3;
        let deck = format!(
            "<parthenon/mesh>\nnx1 = {nx}\nnx2 = {nx}\n\
             <parthenon/meshblock>\nnx1 = 8\nnx2 = 8\n"
        );
        let mut pin = ParameterInput::from_str(&deck).unwrap();
        let cfg = MeshConfig::from_params(&mut pin).unwrap();
        let mesh = Mesh::build(cfg, vec![], 0, 1);
        let md = MeshData::build(&mesh, 4, Some(&[1, 4]));
        let sizes: Vec<usize> = md.packs().iter().map(|d| d.nb).collect();
        assert_eq!(sizes, vec![4, 1, 1, 1, 1, 1]);
        let ranges = md.worker_block_ranges(2);
        assert_eq!(ranges.len(), 2);
        let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
        assert_eq!(lens, vec![5, 4], "block-balanced, pack-aligned split");
    }

    #[test]
    fn plan_delta_mirrors_rebuild_preserving() {
        use crate::hydro::CONS;
        let mut mesh = mesh_2d_cons(4); // 16 blocks
        let mut md = MeshData::build(&mesh, 4, None); // packs of 4
        let locs: Vec<LogicalLocation> = mesh.blocks.iter().map(|b| b.loc).collect();

        // not staged: nothing to scatter, every new pack starts dirty
        let d0 = md.plan_delta(&locs, None);
        assert!(d0.stale_old.is_empty());
        assert_eq!((d0.dirty_new, d0.preserved_new), (4, 0));

        md.gather(&mesh, CONS).unwrap(); // stage + clean everything

        // identical block set: everything survives
        let d1 = md.plan_delta(&locs, None);
        assert!(d1.stale_old.is_empty());
        assert_eq!((d1.dirty_new, d1.preserved_new), (0, 4));

        // tail block leaves the rank: only the tail pack dies
        // (new plan for 15 blocks is [4, 4, 4, 3])
        let d2 = md.plan_delta(&locs[..15], None);
        assert_eq!(d2.stale_old, vec![3]);
        assert_eq!((d2.dirty_new, d2.preserved_new), (1, 3));

        // head block leaves: every pack boundary shifts, nothing survives
        let d3 = md.plan_delta(&locs[1..], None);
        assert_eq!(d3.stale_old, vec![0, 1, 2, 3]);
        assert_eq!((d3.dirty_new, d3.preserved_new), (4, 0));

        // prediction matches what rebuild_preserving actually does for the
        // same-set case
        mesh.rebuild_local_blocks();
        let kept = md.rebuild_preserving(&mesh, None);
        assert_eq!(kept, d1.preserved_new);
    }

    #[test]
    fn residency_tracking_scatter_and_gather_subsets() {
        use crate::hydro::CONS;
        let mut mesh = mesh_2d_cons(2); // 4 blocks
        let mut md = MeshData::build(&mesh, 1, None); // 4 packs of 1
        assert_eq!(md.pack_spaces(), &[PackSpace::Host; 4]);
        md.gather(&mesh, CONS).unwrap(); // everything staged + clean
        let base = md.gathered_packs();

        // Simulate: pack 1 ran on host (containers newer), rest on device.
        md.set_pack_spaces(vec![
            PackSpace::Device,
            PackSpace::Host,
            PackSpace::Device,
            PackSpace::Device,
        ]);
        md.mark_packs_dirty(&[1]);
        let ne = md.block_elems();
        // Poison every staging slab; scatter_resident must push only the
        // clean packs (0, 2, 3) back into containers.
        for p in &mut md.staging {
            for x in &mut p.u {
                *x = 7.0;
            }
        }
        md.scatter_resident(&mut mesh, CONS).unwrap();
        for (bi, b) in mesh.blocks.iter().enumerate() {
            let arr = b.data.get(CONS).unwrap();
            let v = arr.as_slice()[0];
            if bi == 1 {
                assert_ne!(v, 7.0, "dirty pack's container must survive");
            } else {
                assert_eq!(v, 7.0, "clean packs scatter back");
            }
        }
        // Migrate pack 1 host→device: one subset gather clears its dirty
        // flag and costs exactly one gathered pack.
        md.gather_packs(&mesh, CONS, &[1]).unwrap();
        assert!(md.dirty_packs().is_empty());
        assert_eq!(md.gathered_packs(), base + 1);
        assert_eq!(md.staging()[1].u[0..ne].iter().position(|&x| x == 7.0), None);
    }

    #[test]
    fn staging_sizes_match_plan() {
        let mesh = mesh_2d(2); // 4 blocks
        let mut md = MeshData::build(&mesh, 4, None);
        md.ensure_staging();
        let (descs, staging) = md.parts_mut();
        assert_eq!(descs.len(), staging.len());
        for (d, p) in descs.iter().zip(staging.iter()) {
            assert_eq!(p.u.len(), d.nb * NHYDRO * 12 * 12);
            assert_eq!(p.u0.len(), p.u.len());
            assert_eq!(p.bufs_in.len(), p.bufs_out.len());
        }
    }
}
