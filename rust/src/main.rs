//! The `parthenon` CLI: run simulations from Athena-style input files.
//!
//! ```text
//! parthenon run -i input.in [-n NRANKS] [block/key=value ...]
//! parthenon info                      # artifact inventory
//! parthenon pgen-list                 # problem generators
//! ```

// Same crate-wide allowances as the library (see rust/src/lib.rs): the CI
// clippy gate denies warnings, and these stylistic lints fight the
// numeric-kernel idiom.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity
)]

use parthenon::config::{Override, ParameterInput};
use parthenon::driver::{Driver, SimBuilder};
use parthenon::runtime::{default_artifact_dir, Manifest};

fn usage() -> ! {
    eprintln!(
        "usage:\n  parthenon run -i <input.in> [-n <nranks>] [block/key=value ...]\n  \
         parthenon info\n  parthenon pgen-list"
    );
    std::process::exit(2);
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(&args[1..]),
        Some("info") => cmd_info(),
        Some("pgen-list") => {
            println!("linear_wave  blast  kh  uniform");
        }
        _ => usage(),
    }
}

fn cmd_run(args: &[String]) {
    let mut input: Option<String> = None;
    let mut nranks = 1usize;
    let mut overrides: Vec<Override> = Vec::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "-i" => input = it.next().cloned(),
            "-n" => {
                nranks = it
                    .next()
                    .and_then(|s| s.parse().ok())
                    .unwrap_or_else(|| usage())
            }
            // Parse overrides at the program edge: a malformed spec is a
            // config error here, before any rank thread launches.
            ov if ov.contains('=') && ov.contains('/') => {
                overrides.push(ov.parse::<Override>().unwrap_or_else(|e| {
                    eprintln!("{e}");
                    std::process::exit(2);
                }))
            }
            _ => usage(),
        }
    }
    let Some(input) = input else { usage() };
    let text = std::fs::read_to_string(&input).unwrap_or_else(|e| {
        eprintln!("cannot read {input}: {e}");
        std::process::exit(1);
    });

    let t0 = std::time::Instant::now();
    use std::sync::{Arc, Mutex};
    let stats: Arc<Mutex<Vec<(u64, f64, u64)>>> =
        Arc::new(Mutex::new(vec![(0, 0.0, 0); nranks]));
    let stats2 = stats.clone();
    let overrides2 = overrides.clone();
    parthenon::comm::World::launch(nranks, move |rank, world| {
        let mut pin = ParameterInput::from_str(&text).expect("parse input");
        for ov in &overrides2 {
            pin.apply(ov);
        }
        let mut sim = SimBuilder::new(pin)
            .rank(rank)
            .world(world)
            .build()
            .expect("construct sim");
        sim.execute().expect("execute");
        let launches = sim.device.as_ref().map(|d| d.rt.launches()).unwrap_or(0);
        stats2.lock().unwrap()[rank] = (sim.cycle, sim.zc.zcps(), launches);
    });
    let stats = stats.lock().unwrap();
    // every rank measures the same global zone-cycles; report the mean
    let total_zcps: f64 =
        stats.iter().map(|s| s.1).sum::<f64>() / stats.len().max(1) as f64;
    let launches: u64 = stats.iter().map(|s| s.2).sum();
    println!(
        "done: {} cycles, {:.3}s wall, {:.3e} zone-cycles/s total ({} ranks, {} launches)",
        stats[0].0,
        t0.elapsed().as_secs_f64(),
        total_zcps,
        stats.len(),
        launches
    );
}

fn cmd_info() {
    let dir = default_artifact_dir();
    match Manifest::load(&dir) {
        Ok(m) => {
            let mut kinds: std::collections::BTreeMap<String, usize> =
                std::collections::BTreeMap::new();
            for k in m.keys() {
                *kinds.entry(k.kind.clone()).or_default() += 1;
            }
            println!("artifacts at {dir:?}:");
            for (k, c) in kinds {
                println!("  {k:10} {c} variants");
            }
        }
        Err(e) => {
            eprintln!("no artifacts: {e}");
            std::process::exit(1);
        }
    }
}
