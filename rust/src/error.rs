//! Crate-wide error type (hand-rolled `Display`/`Error` impls — external
//! derive crates are unavailable in this offline build).

use std::fmt;

/// Errors produced by the framework.
#[derive(Debug)]
pub enum Error {
    Config(String),
    Mesh(String),
    Package(String),
    Variable(String),
    Comm(String),
    Task(String),
    Runtime(String),
    Artifact(String),
    Io(std::io::Error),
    Json(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Mesh(m) => write!(f, "mesh error: {m}"),
            Error::Package(m) => write!(f, "package error: {m}"),
            Error::Variable(m) => write!(f, "variable error: {m}"),
            Error::Comm(m) => write!(f, "communication error: {m}"),
            Error::Task(m) => write!(f, "task error: {m}"),
            Error::Runtime(m) => write!(f, "runtime (PJRT) error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Json(m) => write!(f, "json error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Shorthand constructor helpers.
impl Error {
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn mesh(msg: impl Into<String>) -> Self {
        Error::Mesh(msg.into())
    }
}
