//! Crate-wide error type (hand-rolled `Display`/`Error` impls — external
//! derive crates are unavailable in this offline build).

use std::fmt;
use std::time::Duration;

/// Errors produced by the framework.
#[derive(Debug)]
pub enum Error {
    Config(String),
    Mesh(String),
    Package(String),
    Variable(String),
    Comm(String),
    Task(String),
    Runtime(String),
    Artifact(String),
    Io(std::io::Error),
    Json(String),
    /// A communication or task wait made zero progress for longer than the
    /// watchdog budget. `rank`/`peer`/`tag` are filled where the waiting
    /// layer knows them (task-pool stalls have no rank).
    Timeout {
        what: String,
        rank: Option<usize>,
        peer: Option<usize>,
        tag: Option<u64>,
        elapsed: Duration,
    },
    /// A peer rank posted a World-level abort (after its own timeout,
    /// corruption, or simulated death); this rank drained cooperatively.
    Aborted { rank: usize, origin: usize, reason: String },
    /// Checksum mismatch on a framed message (fault injection or a real
    /// corruption) — surfaced instead of silently computing wrong bits.
    CorruptMessage { src: usize, dst: usize, tag: u64 },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Mesh(m) => write!(f, "mesh error: {m}"),
            Error::Package(m) => write!(f, "package error: {m}"),
            Error::Variable(m) => write!(f, "variable error: {m}"),
            Error::Comm(m) => write!(f, "communication error: {m}"),
            Error::Task(m) => write!(f, "task error: {m}"),
            Error::Runtime(m) => write!(f, "runtime (PJRT) error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Timeout { what, rank, peer, tag, elapsed } => {
                write!(f, "timeout: {what} stalled for {elapsed:?}")?;
                if let Some(r) = rank {
                    write!(f, " on rank {r}")?;
                }
                if let Some(p) = peer {
                    write!(f, " waiting on peer {p}")?;
                }
                if let Some(t) = tag {
                    write!(f, " tag {t:#x}")?;
                }
                Ok(())
            }
            Error::Aborted { rank, origin, reason } => {
                write!(f, "aborted on rank {rank}: rank {origin} posted abort ({reason})")
            }
            Error::CorruptMessage { src, dst, tag } => {
                write!(
                    f,
                    "corrupt message: checksum mismatch on rank {dst} for message from rank {src} tag {tag:#x}"
                )
            }
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Shorthand constructor helpers.
impl Error {
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn mesh(msg: impl Into<String>) -> Self {
        Error::Mesh(msg.into())
    }
}
