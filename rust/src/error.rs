//! Crate-wide error type.

use thiserror::Error;

/// Errors produced by the framework.
#[derive(Error, Debug)]
pub enum Error {
    #[error("config error: {0}")]
    Config(String),

    #[error("mesh error: {0}")]
    Mesh(String),

    #[error("package error: {0}")]
    Package(String),

    #[error("variable error: {0}")]
    Variable(String),

    #[error("communication error: {0}")]
    Comm(String),

    #[error("task error: {0}")]
    Task(String),

    #[error("runtime (PJRT) error: {0}")]
    Runtime(String),

    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("json error: {0}")]
    Json(String),

    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Shorthand constructor helpers.
impl Error {
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn mesh(msg: impl Into<String>) -> Self {
        Error::Mesh(msg.into())
    }
}
