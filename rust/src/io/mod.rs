//! IO (paper Sec. 3.9): pbin snapshot/restart files and history output.
//!
//! The paper uses parallel HDF5 with per-block chunking; this environment
//! has no HDF5, so `pbin` keeps the same *structure*: a self-describing
//! header (JSON) listing the mesh leaves and variables, followed by one
//! chunk per (block, variable) of raw little-endian f32 interior data, in
//! gid (Z-)order.  Restarts are bitwise exact (state is f32 on disk and in
//! memory; time/dt are stored as f64 bit patterns) and may be read back on
//! a different rank count — the load balancer redistributes on load, just
//! like the paper's restart path.

use std::io::Write;
use std::path::Path;

use crate::comm::Comm;
use crate::error::{Error, Result};
use crate::hydro::CONS;
use crate::mesh::{LogicalLocation, Mesh};
use crate::util::json::{obj, Json};
use crate::Real;

const MAGIC: &[u8] = b"PBIN1\n";

/// Write a snapshot: every rank contributes its blocks (interior of each
/// listed variable); rank 0 assembles in gid order and writes one file.
pub fn write_snapshot(
    mesh: &Mesh,
    comm: &Comm,
    time: f64,
    cycle: u64,
    dt: f64,
    vars: &[String],
    path: &str,
) -> Result<()> {
    let shape = mesh.cfg.index_shape();
    // serialize local contribution: [gid u64][var data...] per block
    let mut local = Vec::new();
    for b in &mesh.blocks {
        local.extend_from_slice(&(b.gid as u64).to_le_bytes());
        for var in vars {
            let arr = b.data.get(var)?;
            let ncomp = arr.dims()[0];
            let n = shape.ncells_total();
            for v in 0..ncomp {
                for k in shape.is_(2)..shape.ie(2) {
                    for j in shape.is_(1)..shape.ie(1) {
                        for i in shape.is_(0)..shape.ie(0) {
                            let val = arr.as_slice()[v * n + shape.idx3(k, j, i)];
                            local.extend_from_slice(&val.to_le_bytes());
                        }
                    }
                }
            }
        }
    }
    let gathered = comm.allgather(local);
    if mesh.my_rank != 0 {
        return Ok(());
    }

    // header
    let leaves: Vec<Json> = mesh
        .tree
        .leaves()
        .iter()
        .map(|l| {
            Json::Arr(vec![
                (l.level as i64).into(),
                l.lx[0].into(),
                l.lx[1].into(),
                l.lx[2].into(),
            ])
        })
        .collect();
    let var_descs: Vec<Json> = vars
        .iter()
        .map(|v| {
            let ncomp = mesh
                .blocks
                .first()
                .and_then(|b| b.data.get(v).ok())
                .map(|a| a.dims()[0])
                .unwrap_or(crate::NHYDRO);
            obj(vec![("name", v.as_str().into()), ("ncomp", ncomp.into())])
        })
        .collect();
    let header = obj(vec![
        ("time", time.into()),
        ("time_bits", format!("{:016x}", time.to_bits()).into()),
        ("dt_bits", format!("{:016x}", dt.to_bits()).into()),
        ("cycle", (cycle as i64).into()),
        ("dim", mesh.cfg.dim.into()),
        (
            "block_nx",
            Json::Arr(vec![
                mesh.cfg.block_nx[0].into(),
                mesh.cfg.block_nx[1].into(),
                mesh.cfg.block_nx[2].into(),
            ]),
        ),
        ("leaves", Json::Arr(leaves)),
        ("vars", Json::Arr(var_descs)),
        ("nblocks", mesh.tree.nblocks().into()),
    ]);

    // per-block payload size
    let zone = shape.ncells_interior();
    let var_elems: usize = vars
        .iter()
        .map(|v| {
            mesh.blocks
                .first()
                .and_then(|b| b.data.get(v).ok())
                .map(|a| a.dims()[0])
                .unwrap_or(crate::NHYDRO)
                * zone
        })
        .sum();
    let rec = 8 + 4 * var_elems;

    // assemble blocks in gid order
    let mut by_gid: Vec<Option<&[u8]>> = vec![None; mesh.tree.nblocks()];
    for blob in &gathered {
        let mut off = 0usize;
        while off + rec <= blob.len() {
            let gid =
                u64::from_le_bytes(blob[off..off + 8].try_into().unwrap()) as usize;
            by_gid[gid] = Some(&blob[off + 8..off + rec]);
            off += rec;
        }
    }

    if let Some(dir) = Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    let h = header.dump();
    f.write_all(&(h.len() as u64).to_le_bytes())?;
    f.write_all(h.as_bytes())?;
    for (gid, blob) in by_gid.iter().enumerate() {
        let blob = blob.ok_or_else(|| {
            Error::Io(std::io::Error::other(format!("missing block {gid}")))
        })?;
        f.write_all(&(gid as u64).to_le_bytes())?;
        f.write_all(blob)?;
    }
    f.flush()?;
    Ok(())
}

/// Parsed snapshot/restart file.
pub struct Snapshot {
    pub time: f64,
    pub dt: f64,
    pub cycle: u64,
    pub dim: usize,
    pub block_nx: [usize; 3],
    pub leaves: Vec<LogicalLocation>,
    pub vars: Vec<(String, usize)>,
    data: Vec<u8>,
    data_start: usize,
    rec: usize,
    zone: usize,
}

impl Snapshot {
    pub fn read(path: &str) -> Result<Snapshot> {
        let data = std::fs::read(path)?;
        if !data.starts_with(MAGIC) {
            return Err(Error::Io(std::io::Error::other("bad pbin magic")));
        }
        let hlen = u64::from_le_bytes(data[6..14].try_into().unwrap()) as usize;
        let header = Json::parse(std::str::from_utf8(&data[14..14 + hlen]).map_err(
            |e| Error::Io(std::io::Error::other(format!("bad header utf8: {e}"))),
        )?)?;
        let time = match header.get("time_bits").and_then(|v| v.as_str()) {
            Some(hex) => f64::from_bits(u64::from_str_radix(hex, 16).unwrap_or(0)),
            None => header.req("time")?.as_f64().unwrap_or(0.0),
        };
        let dt = match header.get("dt_bits").and_then(|v| v.as_str()) {
            Some(hex) => f64::from_bits(u64::from_str_radix(hex, 16).unwrap_or(0)),
            None => 0.0,
        };
        let cycle = header.req("cycle")?.as_i64().unwrap_or(0) as u64;
        let dim = header.req("dim")?.as_usize().unwrap_or(1);
        let bn = header.req("block_nx")?.as_arr().unwrap_or(&[]);
        let block_nx = [
            bn[0].as_usize().unwrap_or(1),
            bn[1].as_usize().unwrap_or(1),
            bn[2].as_usize().unwrap_or(1),
        ];
        let leaves: Vec<LogicalLocation> = header
            .req("leaves")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|l| {
                let a = l.as_arr().unwrap();
                LogicalLocation::new(
                    a[0].as_i64().unwrap_or(0) as u8,
                    a[1].as_i64().unwrap_or(0),
                    a[2].as_i64().unwrap_or(0),
                    a[3].as_i64().unwrap_or(0),
                )
            })
            .collect();
        let vars: Vec<(String, usize)> = header
            .req("vars")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|v| {
                (
                    v.req("name").unwrap().as_str().unwrap_or("").to_string(),
                    v.req("ncomp").unwrap().as_usize().unwrap_or(1),
                )
            })
            .collect();
        let shape = crate::mesh::IndexShape::new(dim, block_nx);
        let zone = shape.ncells_interior();
        let var_elems: usize = vars.iter().map(|(_, nc)| nc * zone).sum();
        let rec = 8 + 4 * var_elems;
        Ok(Snapshot {
            time,
            dt,
            cycle,
            dim,
            block_nx,
            leaves,
            vars,
            data,
            data_start: 14 + hlen,
            rec,
            zone,
        })
    }

    /// Interior data of (gid, var) as f32s (components fused).
    pub fn block_var(&self, gid: usize, var: &str) -> Result<Vec<Real>> {
        let mut off = self.data_start + gid * self.rec;
        let stored_gid =
            u64::from_le_bytes(self.data[off..off + 8].try_into().unwrap()) as usize;
        if stored_gid != gid {
            return Err(Error::Io(std::io::Error::other(format!(
                "gid mismatch: {stored_gid} != {gid}"
            ))));
        }
        off += 8;
        for (name, ncomp) in &self.vars {
            let elems = ncomp * self.zone;
            if name == var {
                let mut out = Vec::with_capacity(elems);
                for e in 0..elems {
                    let b = &self.data[off + 4 * e..off + 4 * e + 4];
                    out.push(Real::from_le_bytes(b.try_into().unwrap()));
                }
                return Ok(out);
            }
            off += 4 * elems;
        }
        Err(Error::Variable(format!("var {var:?} not in snapshot")))
    }

    /// Load a snapshot's CONS data into a freshly built mesh (restart).
    /// Ghosts must be refilled by the caller via exchange.
    pub fn restore_into(&self, mesh: &mut Mesh) -> Result<()> {
        let shape = mesh.cfg.index_shape();
        let n = shape.ncells_total();
        for bi in 0..mesh.blocks.len() {
            let gid = mesh.blocks[bi].gid;
            let data = self.block_var(gid, CONS)?;
            let arr = mesh.blocks[bi].data.get_mut(CONS)?;
            let ncomp = arr.dims()[0];
            let s = arr.as_mut_slice();
            let mut r = 0usize;
            for v in 0..ncomp {
                for k in shape.is_(2)..shape.ie(2) {
                    for j in shape.is_(1)..shape.ie(1) {
                        for i in shape.is_(0)..shape.ie(0) {
                            s[v * n + shape.idx3(k, j, i)] = data[r];
                            r += 1;
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

/// Append one history line (rank 0 only).
pub fn append_history(path: &str, time: f64, cycle: u64, sums: &[f64]) -> Result<()> {
    if let Some(dir) = Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let exists = Path::new(path).exists();
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    if !exists {
        writeln!(f, "# time cycle mass mom_x kinetic_e total_e")?;
    }
    let cols: Vec<String> = sums.iter().map(|s| format!("{s:.10e}")).collect();
    writeln!(f, "{time:.10e} {cycle} {}", cols.join(" "))?;
    Ok(())
}
