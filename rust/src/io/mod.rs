//! IO (paper Sec. 3.9): pbin snapshot/restart files and history output.
//!
//! The paper uses parallel HDF5 with per-block chunking; this environment
//! has no HDF5, so `pbin` keeps the same *structure*: a self-describing
//! header (JSON) listing the mesh leaves and variables, followed by one
//! chunk per (block, variable) of raw little-endian f32 interior data, in
//! gid (Z-)order.  Restarts are bitwise exact (state is f32 on disk and in
//! memory; time/dt are stored as f64 bit patterns) and may be read back on
//! a different rank count — the load balancer redistributes on load, just
//! like the paper's restart path.

use std::io::Write;
use std::path::Path;

use crate::comm::Comm;
use crate::error::{Error, Result};
use crate::hydro::CONS;
use crate::mesh::{LogicalLocation, Mesh};
use crate::util::json::{obj, Json};
use crate::Real;

const MAGIC: &[u8] = b"PBIN1\n";

/// Write a snapshot: every rank contributes its blocks (interior of each
/// listed variable); rank 0 assembles in gid order and writes one file.
pub fn write_snapshot(
    mesh: &Mesh,
    comm: &Comm,
    time: f64,
    cycle: u64,
    dt: f64,
    vars: &[String],
    path: &str,
) -> Result<()> {
    let shape = mesh.cfg.index_shape();
    // serialize local contribution: [gid u64][var data...] per block
    let mut local = Vec::new();
    for b in &mesh.blocks {
        local.extend_from_slice(&(b.gid as u64).to_le_bytes());
        for var in vars {
            let arr = b.data.get(var)?;
            let ncomp = arr.dims()[0];
            let n = shape.ncells_total();
            for v in 0..ncomp {
                for k in shape.is_(2)..shape.ie(2) {
                    for j in shape.is_(1)..shape.ie(1) {
                        for i in shape.is_(0)..shape.ie(0) {
                            let val = arr.as_slice()[v * n + shape.idx3(k, j, i)];
                            local.extend_from_slice(&val.to_le_bytes());
                        }
                    }
                }
            }
        }
    }
    let gathered = comm.allgather(local);
    if mesh.my_rank != 0 {
        return Ok(());
    }

    // header
    let leaves: Vec<Json> = mesh
        .tree
        .leaves()
        .iter()
        .map(|l| {
            Json::Arr(vec![
                (l.level as i64).into(),
                l.lx[0].into(),
                l.lx[1].into(),
                l.lx[2].into(),
            ])
        })
        .collect();
    let var_descs: Vec<Json> = vars
        .iter()
        .map(|v| {
            let ncomp = mesh
                .blocks
                .first()
                .and_then(|b| b.data.get(v).ok())
                .map(|a| a.dims()[0])
                .unwrap_or(crate::NHYDRO);
            obj(vec![("name", v.as_str().into()), ("ncomp", ncomp.into())])
        })
        .collect();
    let header = obj(vec![
        ("time", time.into()),
        ("time_bits", format!("{:016x}", time.to_bits()).into()),
        ("dt_bits", format!("{:016x}", dt.to_bits()).into()),
        ("cycle", (cycle as i64).into()),
        ("dim", mesh.cfg.dim.into()),
        (
            "block_nx",
            Json::Arr(vec![
                mesh.cfg.block_nx[0].into(),
                mesh.cfg.block_nx[1].into(),
                mesh.cfg.block_nx[2].into(),
            ]),
        ),
        ("leaves", Json::Arr(leaves)),
        ("vars", Json::Arr(var_descs)),
        ("nblocks", mesh.tree.nblocks().into()),
    ]);

    // per-block payload size
    let zone = shape.ncells_interior();
    let var_elems: usize = vars
        .iter()
        .map(|v| {
            mesh.blocks
                .first()
                .and_then(|b| b.data.get(v).ok())
                .map(|a| a.dims()[0])
                .unwrap_or(crate::NHYDRO)
                * zone
        })
        .sum();
    let rec = 8 + 4 * var_elems;

    // assemble blocks in gid order
    let mut by_gid: Vec<Option<&[u8]>> = vec![None; mesh.tree.nblocks()];
    for blob in &gathered {
        let mut off = 0usize;
        while off + rec <= blob.len() {
            let gid =
                u64::from_le_bytes(blob[off..off + 8].try_into().unwrap()) as usize;
            by_gid[gid] = Some(&blob[off + 8..off + rec]);
            off += rec;
        }
    }

    if let Some(dir) = Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    // Atomic publish: write the full file to `<path>.tmp`, then rename over
    // the destination. A crash mid-write (or a kill_rank firing during a
    // checkpoint) leaves at worst a truncated .tmp; the previously durable
    // snapshot at `path` stays restorable.
    let tmp = format!("{path}.tmp");
    {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        f.write_all(MAGIC)?;
        let h = header.dump();
        f.write_all(&(h.len() as u64).to_le_bytes())?;
        f.write_all(h.as_bytes())?;
        for (gid, blob) in by_gid.iter().enumerate() {
            let blob = blob.ok_or_else(|| {
                Error::Io(std::io::Error::other(format!("missing block {gid}")))
            })?;
            f.write_all(&(gid as u64).to_le_bytes())?;
            f.write_all(blob)?;
        }
        f.flush()?;
        f.into_inner()
            .map_err(|e| Error::Io(e.into_error()))?
            .sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Parsed snapshot/restart file.
pub struct Snapshot {
    pub time: f64,
    pub dt: f64,
    pub cycle: u64,
    pub dim: usize,
    pub block_nx: [usize; 3],
    pub leaves: Vec<LogicalLocation>,
    pub vars: Vec<(String, usize)>,
    data: Vec<u8>,
    data_start: usize,
    rec: usize,
    zone: usize,
}

impl Snapshot {
    pub fn read(path: &str) -> Result<Snapshot> {
        let data = std::fs::read(path)?;
        if !data.starts_with(MAGIC) {
            return Err(Error::Io(std::io::Error::other("bad pbin magic")));
        }
        if data.len() < 14 {
            return Err(Error::Io(std::io::Error::other("truncated pbin header")));
        }
        let hlen = u64::from_le_bytes(data[6..14].try_into().unwrap()) as usize;
        // saturating: a crafted header length near usize::MAX must not
        // overflow the bound check into a slice panic
        if data.len().saturating_sub(14) < hlen {
            return Err(Error::Io(std::io::Error::other(
                "pbin header length exceeds file size",
            )));
        }
        let header = Json::parse(std::str::from_utf8(&data[14..14 + hlen]).map_err(
            |e| Error::Io(std::io::Error::other(format!("bad header utf8: {e}"))),
        )?)?;
        let time = match header.get("time_bits").and_then(|v| v.as_str()) {
            Some(hex) => f64::from_bits(u64::from_str_radix(hex, 16).unwrap_or(0)),
            None => header.req("time")?.as_f64().unwrap_or(0.0),
        };
        let dt = match header.get("dt_bits").and_then(|v| v.as_str()) {
            Some(hex) => f64::from_bits(u64::from_str_radix(hex, 16).unwrap_or(0)),
            None => 0.0,
        };
        let cycle = header.req("cycle")?.as_i64().unwrap_or(0) as u64;
        let dim = header.req("dim")?.as_usize().unwrap_or(1);
        let bn = header.req("block_nx")?.as_arr().unwrap_or(&[]);
        if bn.len() < 3 {
            return Err(Error::Json(format!(
                "snapshot manifest: block_nx needs 3 entries, got {}",
                bn.len()
            )));
        }
        let block_nx = [
            bn[0].as_usize().unwrap_or(1),
            bn[1].as_usize().unwrap_or(1),
            bn[2].as_usize().unwrap_or(1),
        ];
        // A malformed manifest must surface as Err, never a panic: every
        // required field propagates through the crate error type.
        let mut leaves: Vec<LogicalLocation> = Vec::new();
        for l in header.req("leaves")?.as_arr().unwrap_or(&[]) {
            let a = l.as_arr().ok_or_else(|| {
                Error::Json("snapshot manifest: leaf entry must be an array".into())
            })?;
            if a.len() < 4 {
                return Err(Error::Json(
                    "snapshot manifest: leaf entry needs [level, lx1, lx2, lx3]".into(),
                ));
            }
            leaves.push(LogicalLocation::new(
                a[0].as_i64().unwrap_or(0) as u8,
                a[1].as_i64().unwrap_or(0),
                a[2].as_i64().unwrap_or(0),
                a[3].as_i64().unwrap_or(0),
            ));
        }
        let mut vars: Vec<(String, usize)> = Vec::new();
        for v in header.req("vars")?.as_arr().unwrap_or(&[]) {
            let name = v
                .req("name")?
                .as_str()
                .ok_or_else(|| {
                    Error::Json("snapshot manifest: var name must be a string".into())
                })?
                .to_string();
            let ncomp = v.req("ncomp")?.as_usize().ok_or_else(|| {
                Error::Json(format!(
                    "snapshot manifest: var {name:?} ncomp must be a non-negative integer"
                ))
            })?;
            vars.push((name, ncomp));
        }
        // Sanity-bound the block extents before any size arithmetic: an
        // absurd manifest must error, not overflow (debug panic / release
        // wrap) downstream.
        if block_nx.iter().any(|&n| n == 0 || n > (1 << 20)) {
            return Err(Error::Json(format!(
                "snapshot manifest: implausible block_nx {block_nx:?}"
            )));
        }
        let shape = crate::mesh::IndexShape::new(dim, block_nx);
        let zone = shape.ncells_interior();
        let mut var_elems: usize = 0;
        for (name, nc) in &vars {
            var_elems = nc
                .checked_mul(zone)
                .and_then(|e| var_elems.checked_add(e))
                .ok_or_else(|| {
                    Error::Json(format!(
                        "snapshot manifest: var {name:?} ncomp {nc} overflows the \
                         record size"
                    ))
                })?;
        }
        let rec = var_elems
            .checked_mul(4)
            .and_then(|b| b.checked_add(8))
            .ok_or_else(|| Error::Json("snapshot manifest: record size overflows".into()))?;
        Ok(Snapshot {
            time,
            dt,
            cycle,
            dim,
            block_nx,
            leaves,
            vars,
            data,
            data_start: 14 + hlen,
            rec,
            zone,
        })
    }

    /// Interior data of (gid, var) as f32s (components fused).
    pub fn block_var(&self, gid: usize, var: &str) -> Result<Vec<Real>> {
        let in_bounds = gid
            .checked_mul(self.rec)
            .and_then(|o| o.checked_add(self.data_start))
            .and_then(|start| start.checked_add(self.rec))
            .is_some_and(|end| end <= self.data.len());
        if !in_bounds {
            return Err(Error::Io(std::io::Error::other(format!(
                "snapshot truncated: block {gid} record past end of file"
            ))));
        }
        let mut off = self.data_start + gid * self.rec;
        let stored_gid =
            u64::from_le_bytes(self.data[off..off + 8].try_into().unwrap()) as usize;
        if stored_gid != gid {
            return Err(Error::Io(std::io::Error::other(format!(
                "gid mismatch: {stored_gid} != {gid}"
            ))));
        }
        off += 8;
        for (name, ncomp) in &self.vars {
            let elems = ncomp * self.zone;
            if name == var {
                let mut out = Vec::with_capacity(elems);
                for e in 0..elems {
                    let b = &self.data[off + 4 * e..off + 4 * e + 4];
                    out.push(Real::from_le_bytes(b.try_into().unwrap()));
                }
                return Ok(out);
            }
            off += 4 * elems;
        }
        Err(Error::Variable(format!("var {var:?} not in snapshot")))
    }

    /// Load a snapshot's CONS data into a freshly built mesh (restart).
    /// Ghosts must be refilled by the caller via exchange.
    pub fn restore_into(&self, mesh: &mut Mesh) -> Result<()> {
        let shape = mesh.cfg.index_shape();
        let n = shape.ncells_total();
        for bi in 0..mesh.blocks.len() {
            let gid = mesh.blocks[bi].gid;
            let data = self.block_var(gid, CONS)?;
            let arr = mesh.blocks[bi].data.get_mut(CONS)?;
            let ncomp = arr.dims()[0];
            let s = arr.as_mut_slice();
            let mut r = 0usize;
            for v in 0..ncomp {
                for k in shape.is_(2)..shape.ie(2) {
                    for j in shape.is_(1)..shape.ie(1) {
                        for i in shape.is_(0)..shape.ie(0) {
                            s[v * n + shape.idx3(k, j, i)] = data[r];
                            r += 1;
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

/// Append one history line (rank 0 only). Failures carry the path and
/// cycle so a full disk or bad out_dir is diagnosable from the error alone.
pub fn append_history(path: &str, time: f64, cycle: u64, sums: &[f64]) -> Result<()> {
    let ctx = |e: std::io::Error| {
        Error::Io(std::io::Error::new(
            e.kind(),
            format!("history append to {path:?} at cycle {cycle}: {e}"),
        ))
    };
    if let Some(dir) = Path::new(path).parent() {
        std::fs::create_dir_all(dir).map_err(ctx)?;
    }
    let exists = Path::new(path).exists();
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .map_err(ctx)?;
    if !exists {
        writeln!(f, "# time cycle mass mom_x kinetic_e total_e").map_err(ctx)?;
    }
    let cols: Vec<String> = sums.iter().map(|s| format!("{s:.10e}")).collect();
    writeln!(f, "{time:.10e} {cycle} {}", cols.join(" ")).map_err(ctx)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Write a pbin file with the given header (no block records) and
    /// return its path.
    fn write_header_pbin(tag: &str, header: &str) -> String {
        let path = std::env::temp_dir().join(format!(
            "parthenon_manifest_{}_{}.pbin",
            tag,
            std::process::id()
        ));
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&(header.len() as u64).to_le_bytes());
        buf.extend_from_slice(header.as_bytes());
        std::fs::write(&path, buf).unwrap();
        path.to_string_lossy().into_owned()
    }

    fn header_with(vars: &str, block_nx: &str, leaves: &str) -> String {
        format!(
            "{{\"time\": 0.0, \"cycle\": 0, \"dim\": 2, \"block_nx\": {block_nx}, \
             \"leaves\": {leaves}, \"vars\": {vars}, \"nblocks\": 1}}"
        )
    }

    #[test]
    fn malformed_manifest_is_an_error_not_a_panic() {
        // var entry missing "name"
        let p = write_header_pbin(
            "noname",
            &header_with("[{\"ncomp\": 5}]", "[8, 8, 1]", "[[0, 0, 0, 0]]"),
        );
        assert!(Snapshot::read(&p).is_err(), "missing var name must be Err");
        // var ncomp of the wrong type
        let p = write_header_pbin(
            "badncomp",
            &header_with(
                "[{\"name\": \"cons\", \"ncomp\": \"five\"}]",
                "[8, 8, 1]",
                "[[0, 0, 0, 0]]",
            ),
        );
        assert!(Snapshot::read(&p).is_err(), "non-integer ncomp must be Err");
        // short block_nx
        let p = write_header_pbin(
            "shortnx",
            &header_with(
                "[{\"name\": \"cons\", \"ncomp\": 5}]",
                "[8]",
                "[[0, 0, 0, 0]]",
            ),
        );
        assert!(Snapshot::read(&p).is_err(), "short block_nx must be Err");
        // malformed leaf entry
        let p = write_header_pbin(
            "badleaf",
            &header_with("[{\"name\": \"cons\", \"ncomp\": 5}]", "[8, 8, 1]", "[7]"),
        );
        assert!(Snapshot::read(&p).is_err(), "non-array leaf must be Err");
        // header length pointing past the end of the file
        let path = std::env::temp_dir().join(format!(
            "parthenon_manifest_truncated_{}.pbin",
            std::process::id()
        ));
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&(1_000_000u64).to_le_bytes());
        buf.extend_from_slice(b"{}");
        std::fs::write(&path, buf).unwrap();
        assert!(Snapshot::read(&path.to_string_lossy()).is_err());
        // header length near u64::MAX must not overflow the bound check
        let path = std::env::temp_dir().join(format!(
            "parthenon_manifest_hugelen_{}.pbin",
            std::process::id()
        ));
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&u64::MAX.to_le_bytes());
        buf.extend_from_slice(b"{}");
        std::fs::write(&path, buf).unwrap();
        assert!(Snapshot::read(&path.to_string_lossy()).is_err());
        // absurd ncomp must not overflow the record-size arithmetic
        let p = write_header_pbin(
            "hugencomp",
            &header_with(
                "[{\"name\": \"cons\", \"ncomp\": 4611686018427387904}]",
                "[8, 8, 1]",
                "[[0, 0, 0, 0]]",
            ),
        );
        assert!(Snapshot::read(&p).is_err(), "overflowing ncomp must be Err");
        // absurd block extents must be rejected before size arithmetic
        let p = write_header_pbin(
            "hugenx",
            &header_with(
                "[{\"name\": \"cons\", \"ncomp\": 5}]",
                "[8388608, 8388608, 8388608]",
                "[[0, 0, 0, 0]]",
            ),
        );
        assert!(Snapshot::read(&p).is_err(), "implausible block_nx must be Err");
    }

    #[test]
    fn truncated_tmp_leaves_prior_snapshot_restorable() {
        // A durable snapshot exists; a later checkpoint attempt crashed
        // mid-write, leaving a torn `<path>.tmp` beside it. The durable
        // file must still parse (rename-based publish never tears it),
        // and the torn temp itself must be rejected, not half-read.
        let p = write_header_pbin(
            "durable",
            &header_with(
                "[{\"name\": \"cons\", \"ncomp\": 5}]",
                "[8, 8, 1]",
                "[[0, 0, 0, 0]]",
            ),
        );
        std::fs::write(format!("{p}.tmp"), &MAGIC[..3]).unwrap();
        let snap = Snapshot::read(&p).expect("durable snapshot must survive a torn .tmp");
        assert_eq!(snap.leaves.len(), 1);
        assert!(Snapshot::read(&format!("{p}.tmp")).is_err(), "torn temp must be Err");
    }

    #[test]
    fn wellformed_manifest_still_parses() {
        let p = write_header_pbin(
            "ok",
            &header_with(
                "[{\"name\": \"cons\", \"ncomp\": 5}]",
                "[8, 8, 1]",
                "[[0, 0, 0, 0]]",
            ),
        );
        let snap = Snapshot::read(&p).unwrap();
        assert_eq!(snap.vars, vec![("cons".to_string(), 5)]);
        assert_eq!(snap.block_nx, [8, 8, 1]);
        assert_eq!(snap.leaves.len(), 1);
        // truncated data section: reading a block errors instead of
        // panicking on a short slice
        assert!(snap.block_var(0, "cons").is_err());
    }
}
