//! Ghost-zone exchange engine (native path) — paper Sec. 3.7.
//!
//! Every FillGhost variable communicates on its own communicator id; each
//! message is one boundary segment, tagged by (receiving gid, receiving
//! neighbor slot, sending child code). Same-level segments are raw slabs;
//! fine->coarse segments are restricted before sending; coarse->fine
//! segments carry an expanded coarse box that the receiver prolongates.
//!
//! The engine is split into post_sends / post_receives / poll so drivers can
//! express it as tasks and overlap communication with compute; the blocking
//! wrapper `exchange_blocking` composes the three.

use std::ops::Range;

use super::bufspec::{self, Slab};
use super::prolong;
use crate::comm::{tags, Comm, Payload};
use crate::mesh::{
    BlockTree, BoundaryCondition, IndexShape, LogicalLocation, Mesh, MeshBlock,
    NeighborKind,
};
use crate::tasks::{TaskRegion, TaskStatus, NONE};
use crate::util::backoff::ProgressWait;
use crate::util::stealing::StealPolicy;
use crate::Real;

/// The immutable mesh topology the exchange engine reads: shared by every
/// per-pack context, so block slices can be handed to worker threads while
/// the tree/rank tables stay borrowed once (`Send`-splittable contexts).
#[derive(Clone, Copy)]
pub struct ExchTopo<'a> {
    pub shape: IndexShape,
    pub dim: usize,
    pub tree: &'a BlockTree,
    pub ranks: &'a [usize],
}

impl<'a> ExchTopo<'a> {
    pub fn of(mesh: &'a Mesh) -> ExchTopo<'a> {
        ExchTopo {
            shape: mesh.cfg.index_shape(),
            dim: mesh.cfg.dim,
            tree: &mesh.tree,
            ranks: &mesh.ranks,
        }
    }

    fn rank_of(&self, gid: usize) -> usize {
        self.ranks[gid]
    }
}

/// Device-path buffer packing strategies (paper Fig. 8). `Native` is the
/// CPU/host path where packing happens in plain copies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PackStrategy {
    /// One kernel launch per buffer per block (the "original" regime).
    PerBuffer,
    /// All buffers of one block in one launch.
    PerBlock,
    /// All buffers of all blocks of a pack in one launch.
    PerPack,
    /// Host path: plain memcpy packing (no launches).
    Native,
}

impl PackStrategy {
    pub fn parse(s: &str) -> Option<PackStrategy> {
        match s {
            "perbuffer" | "per_buffer" => Some(PackStrategy::PerBuffer),
            "perblock" | "per_block" => Some(PackStrategy::PerBlock),
            "perpack" | "per_pack" => Some(PackStrategy::PerPack),
            "native" => Some(PackStrategy::Native),
            _ => None,
        }
    }
}

/// Child code of a location: packed per-axis parity bits.
fn child_code(loc: &LogicalLocation) -> usize {
    ((loc.lx[0] & 1) | ((loc.lx[1] & 1) << 1) | ((loc.lx[2] & 1) << 2)) as usize
}

/// Fine-side send slab towards a coarser neighbor: depth 2g (restricts to
/// g coarse), full interior tangentially.
fn fine_send_slab(offset: [i32; 3], shape: &IndexShape) -> Slab {
    let g = crate::NGHOST;
    let axis = |o: i32, n: usize, active: bool| -> (usize, usize) {
        if !active {
            return (0, 1);
        }
        match o {
            -1 => (g, g + 2 * g),
            1 => (g + n - 2 * g, g + n),
            _ => (g, g + n),
        }
    };
    Slab {
        x: axis(offset[0], shape.n[0], true),
        y: axis(offset[1], shape.n[1], shape.dim >= 2),
        z: axis(offset[2], shape.n[2], shape.dim >= 3),
    }
}

/// The unwrapped virtual coarse-block position covering the fine block
/// `floc`'s neighbor region at `offset` (per-axis parent of floc+offset).
/// Geometry is always computed in this unwrapped frame so both sides agree
/// across periodic wraps.
fn coarse_geom_lx(offset: [i32; 3], floc: &LogicalLocation) -> [i64; 3] {
    [
        (floc.lx[0] + offset[0] as i64).div_euclid(2),
        (floc.lx[1] + offset[1] as i64).div_euclid(2),
        (floc.lx[2] + offset[2] as i64).div_euclid(2),
    ]
}

/// The coarse box sent from a coarse block to fine block `floc` whose
/// neighbor slot `offset` points at (a region covered by) the coarse block.
///
/// Per axis: the fine ghost range in global coarse cells, expanded by one
/// cell for prolongation slopes, clamped to the coarse block's interior.
/// Handles faces, edges and corners — including the case where the coarse
/// block's span *contains* the fine ghost range along an axis (corner
/// adjacency through the same coarse leaf).
///
/// Returns (coarse-local slab in the SENDER's ghosted index space,
/// global coarse origin `clo` in the RECEIVER's frame, dims). Both sides
/// compute this identically from (offset, floc, shape).
fn coarse_prolong_box(
    offset: [i32; 3],
    floc: &LogicalLocation,
    shape: &IndexShape,
) -> (Slab, [i64; 3], [usize; 3]) {
    let g = crate::NGHOST as i64;
    let cg = coarse_geom_lx(offset, floc);
    let mut local = [(0usize, 1usize); 3];
    let mut clo = [0i64; 3];
    let mut dims = [1usize; 3];
    for d in 0..3 {
        if d >= shape.dim {
            continue;
        }
        let n = shape.n[d] as i64;
        let b_lo = floc.lx[d] * n; // fine-global start of the block
        let b_hi = b_lo + n;
        // fine ghost range along this axis for `offset`
        let (flo, fhi) = match offset[d] {
            -1 => (b_lo - g, b_lo),
            1 => (b_hi, b_hi + g),
            _ => (b_lo, b_hi),
        };
        // owning coarse cells, expanded for slopes
        let mut c0 = flo.div_euclid(2) - 1;
        let mut c1 = (fhi - 1).div_euclid(2) + 2; // exclusive
        // clamp to the coarse block's interior span
        let cs = cg[d] * n;
        let ce = cs + n;
        c0 = c0.max(cs);
        c1 = c1.min(ce);
        debug_assert!(c0 < c1, "empty coarse box along axis {d}");
        local[d] = (
            (c0 - cs + g) as usize,
            (c1 - cs + g) as usize,
        );
        clo[d] = c0;
        dims[d] = (c1 - c0) as usize;
    }
    (
        Slab { x: local[0], y: local[1], z: local[2] },
        clo,
        dims,
    )
}

/// The sub-box of the coarse block's ghost shell written by fine block
/// `floc`'s restricted send for `offset` (in the coarse block's ghosted
/// local index space). Mirrors [`fine_send_slab`] restricted to coarse
/// resolution.
fn coarse_recv_restriction_box(
    offset: [i32; 3],
    floc: &LogicalLocation,
    shape: &IndexShape,
) -> Slab {
    let g = crate::NGHOST as i64;
    let cg = coarse_geom_lx(offset, floc);
    let mut local = [(0usize, 1usize); 3];
    for d in 0..3 {
        if d >= shape.dim {
            continue;
        }
        let n = shape.n[d] as i64;
        let b_lo = floc.lx[d] * n;
        let b_hi = b_lo + n;
        // restricted region in global coarse cells (fine_send_slab / 2)
        let (c0, c1) = match offset[d] {
            -1 => (b_lo / 2, b_lo / 2 + g),
            1 => (b_hi / 2 - g, b_hi / 2),
            _ => (b_lo.div_euclid(2), b_hi.div_euclid(2)),
        };
        let cs = cg[d] * n;
        // offset into the coarse block's ghosted array (+g in active dims)
        local[d] = ((c0 - cs + g) as usize, (c1 - cs + g) as usize);
    }
    Slab { x: local[0], y: local[1], z: local[2] }
}

/// Extract a dense [nvar, ...] box from an array (row-major v,z,y,x).
fn extract_box(arr: &[Real], shape: &IndexShape, nvar: usize, slab: &Slab) -> Vec<Real> {
    let mut out = Vec::with_capacity(nvar * slab.ncells());
    let n = shape.ncells_total();
    let (nt0, nt1) = (shape.nt(0), shape.nt(1));
    for v in 0..nvar {
        for k in slab.z.0..slab.z.1 {
            for j in slab.y.0..slab.y.1 {
                let row = v * n + (k * nt1 + j) * nt0;
                out.extend_from_slice(&arr[row + slab.x.0..row + slab.x.1]);
            }
        }
    }
    out
}

/// Write a dense box into an array.
fn insert_box(arr: &mut [Real], shape: &IndexShape, nvar: usize, slab: &Slab, src: &[Real]) {
    let n = shape.ncells_total();
    let (nt0, nt1) = (shape.nt(0), shape.nt(1));
    let mut r = 0usize;
    for v in 0..nvar {
        for k in slab.z.0..slab.z.1 {
            for j in slab.y.0..slab.y.1 {
                let row = v * n + (k * nt1 + j) * nt0;
                let w = slab.x.1 - slab.x.0;
                arr[row + slab.x.0..row + slab.x.1].copy_from_slice(&src[r..r + w]);
                r += w;
            }
        }
    }
    debug_assert_eq!(r, src.len());
}

/// How to build the payload of one outbound boundary segment from a
/// block's [nvar, Z, Y, X] array. Shared by the host send path and the
/// Device boundary tasks so both produce byte-identical messages.
pub(crate) enum SendOp {
    /// Same-level slab copied verbatim.
    Same(Slab),
    /// Fine->coarse: restrict the 2g-deep boundary slab before sending.
    Restrict(Slab),
    /// Coarse->fine prolongation source box copied verbatim.
    Prolong(Slab),
}

/// One outbound boundary segment: destination block, wire tag, payload op.
pub(crate) struct SendSpec {
    pub ngid: usize,
    pub tag: u64,
    pub op: SendOp,
}

/// Enumerate every outbound segment of the block at `loc` — the single
/// source of truth for send geometry + tags (the host path iterates it
/// inline; the Device path snapshots it per block into its routes).
pub(crate) fn send_specs_for(t: &ExchTopo, loc: &LogicalLocation) -> Vec<SendSpec> {
    let shape = t.shape;
    let mut out = Vec::new();
    let mut toward_finer = false;
    for nb in t.tree.find_neighbors(loc) {
        let opp = opposite_offset(nb.offset);
        match &nb.kind {
            NeighborKind::Physical => {}
            NeighborKind::SameLevel(nloc) => {
                let ngid = t.tree.gid_of(nloc).unwrap();
                let slot = offset_index(t.dim, opp);
                out.push(SendSpec {
                    ngid,
                    tag: tags::bval_tag(
                        ngid,
                        CLASS_SAME | (slot << 3) | child_code(loc),
                    ),
                    op: SendOp::Same(bufspec::send_slab(nb.offset, &shape)),
                });
            }
            NeighborKind::Coarser(cloc) => {
                // restrict and send; tagged by the direction we sent
                // through (= -our offset) + our child code
                let ngid = t.tree.gid_of(cloc).unwrap();
                let slot = offset_index(t.dim, opp);
                out.push(SendSpec {
                    ngid,
                    tag: tags::bval_tag(
                        ngid,
                        CLASS_RESTRICT | (slot << 3) | child_code(loc),
                    ),
                    op: SendOp::Restrict(fine_send_slab(nb.offset, &shape)),
                });
            }
            NeighborKind::Finer(_) => {
                toward_finer = true;
            }
        }
    }
    if toward_finer {
        // prolongation boxes: one per (fine block, fine offset) pair
        for (floc, off, fslot) in pairs_toward_coarse(t, loc) {
            let ngid = t.tree.gid_of(&floc).unwrap();
            let (local, _clo, _dims) = coarse_prolong_box(off, &floc, &shape);
            out.push(SendSpec {
                ngid,
                tag: tags::bval_tag(
                    ngid,
                    CLASS_PROLONG | (fslot << 3) | child_code(loc),
                ),
                op: SendOp::Prolong(local),
            });
        }
    }
    out
}

/// Build the wire payload of one outbound segment from a block's array.
pub(crate) fn send_payload(
    data: &[Real],
    shape: &IndexShape,
    nvar: usize,
    op: &SendOp,
) -> Vec<Real> {
    match op {
        SendOp::Same(slab) | SendOp::Prolong(slab) => {
            extract_box(data, shape, nvar, slab)
        }
        SendOp::Restrict(slab) => {
            let mut payload = Vec::new();
            prolong::restrict_slab(data, shape, nvar, slab, &mut payload);
            payload
        }
    }
}

/// How to land one inbound boundary segment in a block's array.
pub(crate) enum RecvOp {
    /// Dense slab written verbatim (same-level ghost or restricted
    /// fine->coarse sub-box).
    Insert(Slab),
    /// Coarse source box to prolongate into a ghost slab.
    Prolong {
        ghost: Slab,
        clo: [i64; 3],
        cdims: [usize; 3],
        fine_lo: [i64; 3],
    },
}

/// One inbound segment: source rank, wire tag, landing op.
pub(crate) struct RecvSpec {
    pub src_rank: usize,
    pub tag: u64,
    pub op: RecvOp,
}

/// Enumerate every inbound segment the block `(gid, loc)` expects — the
/// receive-side mirror of [`send_specs_for`].
pub(crate) fn recv_specs_for(
    t: &ExchTopo,
    gid: usize,
    loc: &LogicalLocation,
) -> Vec<RecvSpec> {
    let shape = t.shape;
    let mut out = Vec::new();
    let mut has_finer = false;
    for nb in t.tree.find_neighbors(loc) {
        let my_slot = nb.nbr_index;
        match &nb.kind {
            NeighborKind::Physical => {}
            NeighborKind::SameLevel(nloc) => {
                let ngid = t.tree.gid_of(nloc).unwrap();
                out.push(RecvSpec {
                    src_rank: t.rank_of(ngid),
                    tag: tags::bval_tag(
                        gid,
                        CLASS_SAME | (my_slot << 3) | child_code(nloc),
                    ),
                    op: RecvOp::Insert(bufspec::recv_slab(nb.offset, &shape)),
                });
            }
            NeighborKind::Coarser(cloc) => {
                // we are the fine side: expect a prolongation box
                let (_local, clo, cdims) = coarse_prolong_box(nb.offset, loc, &shape);
                let fine_lo = [
                    loc.lx[0] * shape.n[0] as i64,
                    loc.lx[1] * shape.n[1] as i64,
                    loc.lx[2] * shape.n[2] as i64,
                ];
                let ngid = t.tree.gid_of(cloc).unwrap();
                out.push(RecvSpec {
                    src_rank: t.rank_of(ngid),
                    tag: tags::bval_tag(
                        gid,
                        CLASS_PROLONG | (my_slot << 3) | child_code(cloc),
                    ),
                    op: RecvOp::Prolong {
                        ghost: bufspec::recv_slab(nb.offset, &shape),
                        clo,
                        cdims,
                        fine_lo,
                    },
                });
            }
            NeighborKind::Finer(_) => {
                has_finer = true;
            }
        }
    }
    if has_finer {
        // we are the coarse side: expect one restricted box per
        // (fine block, fine offset) pair pointing at us
        for (floc, off, _fslot) in pairs_toward_coarse(t, loc) {
            let slab = coarse_recv_restriction_box(off, &floc, &shape);
            // sender tags with the direction it sent through = -off
            let send_dir = offset_index(t.dim, opposite_offset(off));
            let ngid = t.tree.gid_of(&floc).unwrap();
            out.push(RecvSpec {
                src_rank: t.rank_of(ngid),
                tag: tags::bval_tag(
                    gid,
                    CLASS_RESTRICT | (send_dir << 3) | child_code(&floc),
                ),
                op: RecvOp::Insert(slab),
            });
        }
    }
    out
}

/// Land one received segment in a block's array.
pub(crate) fn apply_recv_op(
    arr: &mut [Real],
    shape: &IndexShape,
    nvar: usize,
    op: &RecvOp,
    data: &[Real],
) {
    match op {
        RecvOp::Insert(slab) => insert_box(arr, shape, nvar, slab, data),
        RecvOp::Prolong { ghost, clo, cdims, fine_lo } => {
            prolong::prolongate_ghost_slab(
                arr, shape, nvar, ghost, *fine_lo, data, *clo, *cdims,
            );
        }
    }
}

/// A receive we are waiting for.
struct Pending {
    block: usize,
    op: RecvOp,
}

/// Outstanding receives for one exchange phase of one variable.
pub struct ExchangeState {
    items: Vec<(Pending, usize, u64)>, // (what, src rank, tag)
    done: Vec<bool>,
}

impl ExchangeState {
    pub fn remaining(&self) -> usize {
        self.done.iter().filter(|d| !**d).count()
    }
}

/// Message classes namespacing the tag space (same tag slot numbers are
/// reused across classes).
const CLASS_SAME: usize = 0 << 8;
const CLASS_RESTRICT: usize = 1 << 8;
const CLASS_PROLONG: usize = 2 << 8;

/// Every (fine block F, offset o_F) pair whose neighbor region resolves to
/// the coarse leaf `cloc`. Enumerated identically by the fine side (its own
/// neighbor list) and the coarse side (this function) so message sets match
/// exactly — including corner adjacency through the same coarse leaf.
fn pairs_toward_coarse(
    t: &ExchTopo,
    cloc: &LogicalLocation,
) -> Vec<(LogicalLocation, [i32; 3], usize)> {
    use std::collections::HashSet;
    let mut fines: HashSet<LogicalLocation> = HashSet::new();
    for nb in t.tree.find_neighbors(cloc) {
        if let NeighborKind::Finer(fs) = nb.kind {
            fines.extend(fs);
        }
    }
    let mut out = Vec::new();
    for f in fines {
        for (slot, off) in crate::mesh::neighbor_offsets(t.dim)
            .into_iter()
            .enumerate()
        {
            if let NeighborKind::Coarser(c) = t.tree.resolve_neighbor(&f, off) {
                if c == *cloc {
                    out.push((f, off, slot));
                }
            }
        }
    }
    out
}

/// Post every outbound boundary segment of `var` for all local blocks.
/// Returns the number of segments posted.
pub fn post_sends(mesh: &Mesh, comm: &Comm, var: &str) -> crate::error::Result<usize> {
    post_sends_blocks(&ExchTopo::of(mesh), &mesh.blocks, comm, var)
}

/// Post outbound boundary segments for one pack's blocks
/// (`blocks[range]`) — the per-pack send task of the stage task collection.
pub fn post_sends_range(
    mesh: &Mesh,
    comm: &Comm,
    var: &str,
    range: Range<usize>,
) -> crate::error::Result<usize> {
    post_sends_blocks(&ExchTopo::of(mesh), &mesh.blocks[range], comm, var)
}

/// Slice-based core of the send side: posts the outbound segments of the
/// given blocks against the shared topology (callable from any worker with
/// a disjoint block slice). Returns the number of segments posted — the
/// overlap instrumentation the fused stage pipeline asserts against.
pub fn post_sends_blocks(
    t: &ExchTopo,
    blocks: &[MeshBlock],
    comm: &Comm,
    var: &str,
) -> crate::error::Result<usize> {
    post_sends_filtered(t, blocks, comm, var, None)
}

/// The ONE send path: posts the outbound segments of the given blocks,
/// optionally restricted to segments whose DESTINATION block gid is in
/// `targets`. Both the full exchange and the incremental rebalance's
/// subset refresh go through this function, so a subset refresh is
/// byte-identical (same tags, same payloads) to the slabs a full exchange
/// would deliver — by construction, not by parallel maintenance.
fn post_sends_filtered(
    t: &ExchTopo,
    blocks: &[MeshBlock],
    comm: &Comm,
    var: &str,
    targets: Option<&std::collections::HashSet<usize>>,
) -> crate::error::Result<usize> {
    let shape = t.shape;
    let wanted = |gid: usize| targets.map_or(true, |s| s.contains(&gid));
    let mut nsent = 0usize;
    for b in blocks {
        let arr = b.data.get(var)?;
        let nvar = arr.dims()[0];
        let data = arr.as_slice();
        for spec in send_specs_for(t, &b.loc) {
            if !wanted(spec.ngid) {
                continue;
            }
            let payload = send_payload(data, &shape, nvar, &spec.op);
            comm.isend(t.rank_of(spec.ngid), spec.tag, Payload::F32(payload));
            nsent += 1;
        }
    }
    Ok(nsent)
}

fn opposite_offset(o: [i32; 3]) -> [i32; 3] {
    [-o[0], -o[1], -o[2]]
}

fn offset_index(dim: usize, o: [i32; 3]) -> usize {
    crate::mesh::neighbor_offsets(dim)
        .iter()
        .position(|x| *x == o)
        .expect("offset in canonical set")
}

/// Register every inbound segment we expect for `var`.
pub fn post_receives(mesh: &Mesh, comm: &Comm, var: &str) -> ExchangeState {
    post_receives_range(mesh, comm, var, 0..mesh.blocks.len())
}

/// Register the inbound segments expected by one pack's blocks
/// (`blocks[range]`) — the per-pack receive registration of the stage task
/// collection. Block indices in the returned state are mesh-global (poll
/// with the full block list, or a slice whose base matches `range.start`).
pub fn post_receives_range(
    mesh: &Mesh,
    _comm: &Comm,
    _var: &str,
    range: Range<usize>,
) -> ExchangeState {
    let base = range.start;
    post_receives_blocks(&ExchTopo::of(mesh), &mesh.blocks[range], base)
}

/// Slice-based core of the receive side: registers the inbound segments of
/// the given blocks. `Pending::block` indices are `base + slice index`, so
/// the state must be polled against a slice whose first block sits at
/// local index `base` (the whole block list for `base == 0` plus the full
/// slice, or a pack slice with `base == 0` in the per-pack contexts).
pub fn post_receives_blocks(
    t: &ExchTopo,
    blocks: &[MeshBlock],
    base: usize,
) -> ExchangeState {
    let mut items = Vec::new();
    for (i, b) in blocks.iter().enumerate() {
        let bi = base + i;
        for spec in recv_specs_for(t, b.gid, &b.loc) {
            items.push((
                Pending { block: bi, op: spec.op },
                spec.src_rank,
                spec.tag,
            ));
        }
    }
    let done = vec![false; items.len()];
    ExchangeState { items, done }
}

/// Poll inbound segments, applying any that arrived. Returns true when all
/// are in.
pub fn poll_receives(
    mesh: &mut Mesh,
    comm: &Comm,
    var: &str,
    state: &mut ExchangeState,
) -> crate::error::Result<bool> {
    let shape = mesh.cfg.index_shape();
    poll_receives_blocks(&shape, &mut mesh.blocks, 0, comm, var, state)
}

/// Slice-based core of the poll: `blocks[0]` must sit at the local index
/// `base` the state was registered with, so a per-pack context can poll
/// its own disjoint block slice from a worker thread.
pub fn poll_receives_blocks(
    shape: &IndexShape,
    blocks: &mut [MeshBlock],
    base: usize,
    comm: &Comm,
    var: &str,
    state: &mut ExchangeState,
) -> crate::error::Result<bool> {
    let mut all = true;
    for (idx, (pending, src, tag)) in state.items.iter().enumerate() {
        if state.done[idx] {
            continue;
        }
        let Some(payload) = comm.try_recv(*src, *tag)? else {
            all = false;
            continue;
        };
        let data = payload.into_f32()?;
        let arr = blocks[pending.block - base].data.get_mut(var)?;
        let nvar = arr.dims()[0];
        apply_recv_op(arr.as_mut_slice(), shape, nvar, &pending.op, &data);
        state.done[idx] = true;
    }
    Ok(all)
}

/// The non-periodic physical boundaries touching the block at `loc`, as
/// the per-side table [`physical::apply_physical_bcs`] consumes; `None`
/// when the block touches no physical boundary. Shared by the host BC
/// sweep and the Device routes so both fill the same ghost cells.
pub(crate) fn block_bc_table(
    cfg_bcs: [[BoundaryCondition; 2]; 3],
    nrb: [i64; 3],
    dim: usize,
    loc: &LogicalLocation,
) -> Option<[[Option<BoundaryCondition>; 2]; 3]> {
    let mut bcs: [[Option<BoundaryCondition>; 2]; 3] = [[None; 2]; 3];
    let mut any = false;
    for d in 0..dim {
        let w = nrb[d] << loc.level;
        if loc.lx[d] == 0 && cfg_bcs[d][0] != BoundaryCondition::Periodic {
            bcs[d][0] = Some(cfg_bcs[d][0]);
            any = true;
        }
        if loc.lx[d] == w - 1 && cfg_bcs[d][1] != BoundaryCondition::Periodic {
            bcs[d][1] = Some(cfg_bcs[d][1]);
            any = true;
        }
    }
    any.then_some(bcs)
}

/// Apply physical BCs on domain edges (after all receives landed).
pub fn apply_block_physical_bcs(
    mesh: &mut Mesh,
    var: &str,
    vector_comps: Option<[usize; 3]>,
) -> crate::error::Result<()> {
    let shape = mesh.cfg.index_shape();
    let cfg_bcs = mesh.cfg.bcs;
    let dim = mesh.cfg.dim;
    let nrb = mesh.cfg.nrb;
    let locs: Vec<(usize, LogicalLocation)> =
        mesh.blocks.iter().enumerate().map(|(i, b)| (i, b.loc)).collect();
    for (bi, loc) in locs {
        let Some(bcs) = block_bc_table(cfg_bcs, nrb, dim, &loc) else {
            continue;
        };
        let arr = mesh.blocks[bi].data.get_mut(var)?;
        let nvar = arr.dims()[0];
        super::physical::apply_physical_bcs(
            arr.as_mut_slice(),
            &shape,
            &bcs,
            nvar,
            vector_comps,
        );
    }
    Ok(())
}

/// Complete blocking exchange of one variable (sends + receives + BCs).
/// Waits with bounded spin-then-backoff instead of pegging a core.
pub fn exchange_blocking(
    mesh: &mut Mesh,
    comm: &Comm,
    var: &str,
    vector_comps: Option<[usize; 3]>,
) -> crate::error::Result<()> {
    post_sends(mesh, comm, var)?;
    let mut state = post_receives(mesh, comm, var);
    let mut wait = ProgressWait::new(comm.stall_limit());
    let mut remaining = state.remaining();
    while !poll_receives(mesh, comm, var, &mut state)? {
        let now = state.remaining();
        let progressed = now < remaining;
        remaining = now;
        if !wait.step(progressed) {
            let e = crate::error::Error::Timeout {
                what: format!(
                    "exchange of {var:?} ({} segments missing)",
                    state.remaining()
                ),
                rank: Some(comm.rank()),
                peer: None,
                tag: None,
                elapsed: wait.idle_elapsed(),
            };
            comm.world().escalate(comm.rank(), &e);
            return Err(e);
        }
    }
    apply_block_physical_bcs(mesh, var, vector_comps)?;
    Ok(())
}

/// Post every outbound segment of the given blocks whose DESTINATION block
/// gid is in `targets` — the send half of the subset ghost refresh the
/// incremental rebalance runs: only migrated blocks receive fresh ghosts,
/// so every rank sends only the segments some migrated block needs. Shares
/// [`post_sends_filtered`] with the full send path, so the subset refresh
/// is bitwise identical to the slabs a full exchange would deliver.
/// Returns the number of segments posted.
pub fn post_sends_toward(
    t: &ExchTopo,
    blocks: &[MeshBlock],
    comm: &Comm,
    var: &str,
    targets: &std::collections::HashSet<usize>,
) -> crate::error::Result<usize> {
    post_sends_filtered(t, blocks, comm, var, Some(targets))
}

/// Ghost refresh limited to a subset of blocks (by gid): every block in
/// `targets` receives its FULL inbound segment set; every rank posts only
/// the segments addressed at a target. `targets` must be identical on all
/// ranks (the incremental rebalance derives it from the shared migration
/// plan), or matched sends/receives would not pair up. Blocking, with the
/// same stall watchdog as [`exchange_blocking`]; physical BCs are applied
/// to the target blocks once their receives have landed. Returns the
/// number of segments this rank sent.
pub fn exchange_blocking_subset(
    mesh: &mut Mesh,
    comm: &Comm,
    var: &str,
    vector_comps: Option<[usize; 3]>,
    targets: &std::collections::HashSet<usize>,
) -> crate::error::Result<usize> {
    let nsent = post_sends_toward(&ExchTopo::of(mesh), &mesh.blocks, comm, var, targets)?;
    // register the full receive set of each LOCAL target block; indices in
    // the merged state are mesh-global, so the normal poll applies
    let mut state = ExchangeState { items: Vec::new(), done: Vec::new() };
    {
        let t = ExchTopo::of(mesh);
        for (bi, b) in mesh.blocks.iter().enumerate() {
            if !targets.contains(&b.gid) {
                continue;
            }
            let s = post_receives_blocks(&t, &mesh.blocks[bi..bi + 1], bi);
            state.items.extend(s.items);
            state.done.extend(s.done);
        }
    }
    let mut wait = ProgressWait::new(comm.stall_limit());
    let mut remaining = state.remaining();
    while !poll_receives(mesh, comm, var, &mut state)? {
        let now = state.remaining();
        let progressed = now < remaining;
        remaining = now;
        if !wait.step(progressed) {
            let e = crate::error::Error::Timeout {
                what: format!(
                    "subset exchange of {var:?} ({} segments missing)",
                    state.remaining()
                ),
                rank: Some(comm.rank()),
                peer: None,
                tag: None,
                elapsed: wait.idle_elapsed(),
            };
            comm.world().escalate(comm.rank(), &e);
            return Err(e);
        }
    }
    apply_block_physical_bcs(mesh, var, vector_comps)?;
    Ok(nsent)
}

/// Context threaded through the per-pack exchange task lists.
struct ExchCtx<'a> {
    mesh: &'a mut Mesh,
    comm: &'a Comm,
    var: &'a str,
    /// One registered receive set per pack (filled by the post task).
    states: Vec<Option<ExchangeState>>,
    /// First real error hit by any task. Tasks record it and complete
    /// (never retry — a retried post would duplicate isends); the region
    /// drains fast and the error is returned to the caller.
    error: Option<crate::error::Error>,
}

/// Pack-tasked exchange of one variable: one task list per MeshBlockPack
/// (post sends + receives, then poll), so boundary communication of one
/// pack hides behind the polls of the others — the paper's interleaved
/// tasking, with pack identity threaded through the engine.
pub fn exchange_tasked(
    mesh: &mut Mesh,
    comm: &Comm,
    var: &str,
    vector_comps: Option<[usize; 3]>,
    pack_ranges: &[Range<usize>],
) -> crate::error::Result<()> {
    if pack_ranges.is_empty() {
        return apply_block_physical_bcs(mesh, var, vector_comps);
    }
    let npacks = pack_ranges.len();
    let mut region: TaskRegion<ExchCtx> = TaskRegion::new(npacks);
    for (pi, range) in pack_ranges.iter().enumerate() {
        let post_range = range.clone();
        let list = region.list(pi);
        let t_post = list.add(NONE, move |c: &mut ExchCtx| {
            let ExchCtx { mesh, comm, var, states, error } = c;
            match post_sends_range(mesh, comm, var, post_range.clone()) {
                Ok(_) => {
                    states[pi] =
                        Some(post_receives_range(mesh, comm, var, post_range.clone()));
                }
                Err(e) => {
                    if error.is_none() {
                        *error = Some(e);
                    }
                }
            }
            TaskStatus::Complete
        });
        let _t_poll = list.add(&[t_post], move |c: &mut ExchCtx| {
            let ExchCtx { mesh, comm, var, states, error } = c;
            if error.is_some() {
                return TaskStatus::Complete; // abort: drain the region fast
            }
            let Some(state) = states[pi].as_mut() else {
                return TaskStatus::Complete; // post failed; error is recorded
            };
            match poll_receives(mesh, comm, var, state) {
                Ok(true) => TaskStatus::Complete,
                Ok(false) => TaskStatus::Incomplete,
                Err(e) => {
                    *error = Some(e);
                    TaskStatus::Complete
                }
            }
        });
    }
    let mut ctx = ExchCtx {
        mesh,
        comm,
        var,
        states: (0..npacks).map(|_| None).collect(),
        error: None,
    };
    if let Err(e) = region.execute(&mut ctx, 200_000) {
        comm.world().escalate(comm.rank(), &e);
        return Err(e);
    }
    let ExchCtx { mesh, error, .. } = ctx; // recover borrows from the ctx
    if let Some(e) = error {
        return Err(e);
    }
    apply_block_physical_bcs(mesh, var, vector_comps)?;
    Ok(())
}

/// The send and receive halves of ONE pack's ghost exchange, decoupled so
/// a driver can schedule them as separate tasks interleaved with compute
/// (the fused stage pipeline): sends are posted as soon as the pack's
/// blocks are updated, receives are registered and polled from later tasks
/// while other packs are still computing. The halves share the topology
/// and communicator; block slices are passed per call so the owner keeps
/// the `&mut` borrow.
///
/// Instrumentation: [`PackExchange::sends_posted`] and
/// [`PackExchange::segments_sent`] pin the overlap contract — a pack's
/// sends must be on the wire before its poll first comes up empty.
pub struct PackExchange<'a> {
    topo: ExchTopo<'a>,
    comm: &'a Comm,
    var: &'a str,
    state: Option<ExchangeState>,
    sends_posted: bool,
    segments_sent: usize,
}

impl<'a> PackExchange<'a> {
    pub fn new(topo: ExchTopo<'a>, comm: &'a Comm, var: &'a str) -> PackExchange<'a> {
        PackExchange {
            topo,
            comm,
            var,
            state: None,
            sends_posted: false,
            segments_sent: 0,
        }
    }

    /// Send half: post every outbound boundary segment of the pack's
    /// blocks (a disjoint slice of the rank's blocks).
    pub fn post_sends(&mut self, blocks: &[MeshBlock]) -> crate::error::Result<()> {
        self.segments_sent +=
            post_sends_blocks(&self.topo, blocks, self.comm, self.var)?;
        self.sends_posted = true;
        Ok(())
    }

    /// Receive half, part 1: register the inbound segments the pack's
    /// blocks expect (local bookkeeping only — no waiting).
    pub fn register_receives(&mut self, blocks: &[MeshBlock]) {
        self.state = Some(post_receives_blocks(&self.topo, blocks, 0));
    }

    /// Receive half, part 2: poll registered receives, applying arrivals
    /// into the pack's blocks. `Ok(true)` once every segment has landed.
    pub fn poll(&mut self, blocks: &mut [MeshBlock]) -> crate::error::Result<bool> {
        let Some(state) = self.state.as_mut() else {
            return Err(crate::error::Error::Task(
                "PackExchange::poll before register_receives".into(),
            ));
        };
        poll_receives_blocks(&self.topo.shape, blocks, 0, self.comm, self.var, state)
    }

    /// The shared exchange topology this pack communicates over (also
    /// serves flux-correction tasks riding the same task list, so the
    /// topology lives in exactly one place per pack).
    pub fn topo(&self) -> ExchTopo<'a> {
        self.topo
    }

    /// Whether the send half has run.
    pub fn sends_posted(&self) -> bool {
        self.sends_posted
    }

    /// Outbound segments posted so far.
    pub fn segments_sent(&self) -> usize {
        self.segments_sent
    }

    /// Registered receives still outstanding (0 before registration).
    pub fn remaining(&self) -> usize {
        self.state.as_ref().map_or(0, |s| s.remaining())
    }
}

/// Per-pack exchange context for the parallel task-region executor: owns a
/// disjoint `&mut` slice of the rank's blocks plus the send/receive halves
/// ([`PackExchange`]), so the whole context is `Send` and its task list can
/// be swept from any worker thread while other packs' lists run
/// concurrently.
struct PackExchCtx<'a> {
    exch: PackExchange<'a>,
    blocks: &'a mut [MeshBlock],
    error: Option<crate::error::Error>,
    /// Shared across all packs: set on the first error so every other
    /// pack's poll list drains immediately instead of waiting out the
    /// stall watchdog for segments that were never sent.
    abort: &'a std::sync::atomic::AtomicBool,
}

/// [`exchange_tasked`] with the per-pack task lists executed on the
/// work-stealing worker pool instead of being polled on one thread: each
/// pack's post/poll list is an independent pool item, so boundary
/// communication of slow packs is polled by whichever worker is idle
/// (stealing), not serialized behind every other pack's sweep. Physical
/// BCs run on the caller once all receives have landed.
///
/// Results are bitwise identical to the serial path: every received
/// segment is written to a disjoint ghost slab exactly once, so arrival
/// and polling order cannot change the final state.
pub fn exchange_tasked_parallel(
    mesh: &mut Mesh,
    comm: &Comm,
    var: &str,
    vector_comps: Option<[usize; 3]>,
    pack_ranges: &[Range<usize>],
    nworkers: usize,
    policy: StealPolicy,
) -> crate::error::Result<()> {
    if pack_ranges.is_empty() {
        return apply_block_physical_bcs(mesh, var, vector_comps);
    }
    if nworkers <= 1 || policy == StealPolicy::NoSteal {
        return exchange_tasked(mesh, comm, var, vector_comps, pack_ranges);
    }
    let npacks = pack_ranges.len();
    let mut first_error = None;
    {
        use std::sync::atomic::{AtomicBool, Ordering};
        let abort = AtomicBool::new(false);
        let topo = ExchTopo {
            shape: mesh.cfg.index_shape(),
            dim: mesh.cfg.dim,
            tree: &mesh.tree,
            ranks: &mesh.ranks,
        };
        // split the rank's blocks into disjoint per-pack slices
        let mut rest: &mut [MeshBlock] = &mut mesh.blocks;
        let mut cursor = 0usize;
        let mut ctxs = Vec::with_capacity(npacks);
        for r in pack_ranges {
            debug_assert_eq!(r.start, cursor, "pack ranges must tile the blocks");
            let (head, tail) = rest.split_at_mut(r.len());
            rest = tail;
            cursor = r.end;
            ctxs.push(PackExchCtx {
                exch: PackExchange::new(topo, comm, var),
                blocks: head,
                error: None,
                abort: &abort,
            });
        }
        let mut region: TaskRegion<PackExchCtx> = TaskRegion::new(npacks);
        for pi in 0..npacks {
            let list = region.list(pi);
            let t_post = list.add(NONE, |c: &mut PackExchCtx| {
                match c.exch.post_sends(c.blocks) {
                    Ok(()) => c.exch.register_receives(c.blocks),
                    Err(e) => {
                        if c.error.is_none() {
                            c.error = Some(e);
                        }
                        c.abort.store(true, Ordering::SeqCst);
                    }
                }
                TaskStatus::Complete
            });
            let _t_poll = list.add(&[t_post], |c: &mut PackExchCtx| {
                if c.error.is_some() || c.abort.load(Ordering::SeqCst) {
                    // a pack errored: every list drains fast so the real
                    // error surfaces instead of a watchdog stall
                    return TaskStatus::Complete;
                }
                let PackExchCtx { exch, blocks, error, abort } = c;
                match exch.poll(blocks) {
                    Ok(true) => TaskStatus::Complete,
                    Ok(false) => TaskStatus::Incomplete,
                    Err(e) => {
                        *error = Some(e);
                        abort.store(true, Ordering::SeqCst);
                        TaskStatus::Complete
                    }
                }
            });
        }
        let ctxs =
            match region.execute_parallel(ctxs, nworkers, policy, comm.stall_limit()) {
                Ok(c) => c,
                Err(e) => {
                    comm.world().escalate(comm.rank(), &e);
                    return Err(e);
                }
            };
        for c in ctxs {
            if let Some(e) = c.error {
                first_error = Some(e);
                break;
            }
        }
    }
    if let Some(e) = first_error {
        return Err(e);
    }
    apply_block_physical_bcs(mesh, var, vector_comps)
}
