//! Boundary-buffer layout — the Rust mirror of `python/compile/bufspec.py`.
//!
//! The two implementations must agree bit-for-bit: the runtime cross-checks
//! this table against the one embedded in `artifacts/manifest.json` at
//! startup, and integration tests round-trip device-packed buffers through
//! the native unpack (and vice versa).

use crate::mesh::IndexShape;
use crate::NGHOST;

/// Per-axis index range [lo, hi) into the ghosted array.
pub type AxisRange = (usize, usize);

/// A box (x, y, z ranges) in the ghosted index space of a block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Slab {
    pub x: AxisRange,
    pub y: AxisRange,
    pub z: AxisRange,
}

impl Slab {
    pub fn ncells(&self) -> usize {
        (self.x.1 - self.x.0) * (self.y.1 - self.y.0) * (self.z.1 - self.z.0)
    }

    pub fn dims_zyx(&self) -> (usize, usize, usize) {
        (self.z.1 - self.z.0, self.y.1 - self.y.0, self.x.1 - self.x.0)
    }
}

fn axis_send(o: i32, n: usize, active: bool, g: usize) -> AxisRange {
    if !active {
        return (0, 1);
    }
    match o {
        -1 => (g, 2 * g),
        1 => (n, n + g),
        _ => (g, g + n),
    }
}

fn axis_recv(o: i32, n: usize, active: bool, g: usize) -> AxisRange {
    if !active {
        return (0, 1);
    }
    match o {
        -1 => (0, g),
        1 => (g + n, 2 * g + n),
        _ => (g, g + n),
    }
}

/// Send slab (interior cells adjacent to the `offset` boundary).
pub fn send_slab(offset: [i32; 3], shape: &IndexShape) -> Slab {
    let g = NGHOST;
    Slab {
        x: axis_send(offset[0], shape.n[0], true, g),
        y: axis_send(offset[1], shape.n[1], shape.dim >= 2, g),
        z: axis_send(offset[2], shape.n[2], shape.dim >= 3, g),
    }
}

/// Recv slab (ghost region on the `offset` side).
pub fn recv_slab(offset: [i32; 3], shape: &IndexShape) -> Slab {
    let g = NGHOST;
    Slab {
        x: axis_recv(offset[0], shape.n[0], true, g),
        y: axis_recv(offset[1], shape.n[1], shape.dim >= 2, g),
        z: axis_recv(offset[2], shape.n[2], shape.dim >= 3, g),
    }
}

/// Per-neighbor segment lengths (elements, including `nvar` components).
pub fn segment_lengths(shape: &IndexShape, nvar: usize) -> Vec<usize> {
    crate::mesh::tree::neighbor_offsets(shape.dim)
        .into_iter()
        .map(|o| nvar * send_slab(o, shape).ncells())
        .collect()
}

/// Per-neighbor payload lengths of the restricted fine->coarse sends
/// (mirror of python bufspec.restrict_seg_lens): each active axis of the
/// 2g-deep fine send slab halves under restriction, so pinched axes carry
/// g coarse cells and tangential axes n/2.
pub fn restrict_segment_lengths(shape: &IndexShape, nvar: usize) -> Vec<usize> {
    let g = NGHOST;
    crate::mesh::tree::neighbor_offsets(shape.dim)
        .into_iter()
        .map(|o| {
            let mut ln = nvar;
            for d in 0..3 {
                let active = d == 0 || shape.dim >= d + 1;
                if active {
                    ln *= if o[d] != 0 { g } else { shape.n[d] / 2 };
                }
            }
            ln
        })
        .collect()
}

/// Offsets of each segment in the flat per-block buffer, plus total length.
pub fn segment_offsets(shape: &IndexShape, nvar: usize) -> (Vec<usize>, usize) {
    let lens = segment_lengths(shape, nvar);
    let mut offs = Vec::with_capacity(lens.len());
    let mut acc = 0usize;
    for l in &lens {
        offs.push(acc);
        acc += l;
    }
    (offs, acc)
}

/// Total flat buffer length per block.
pub fn buflen(shape: &IndexShape, nvar: usize) -> usize {
    segment_lengths(shape, nvar).iter().sum()
}

/// Index of the opposite neighbor offset in canonical order.
pub fn opposite_index(dim: usize) -> Vec<usize> {
    let ns = crate::mesh::tree::neighbor_offsets(dim);
    ns.iter()
        .map(|o| {
            let opp = [-o[0], -o[1], -o[2]];
            ns.iter().position(|x| *x == opp).unwrap()
        })
        .collect()
}

/// Copy a slab of component `v` of `arr` (dims [nvar, Z, Y, X]) into `out`
/// in [z, y, x] row-major order. Returns elements written.
pub fn copy_slab_out(
    arr: &[crate::Real],
    shape: &IndexShape,
    v: usize,
    slab: &Slab,
    out: &mut [crate::Real],
) -> usize {
    let (xt, yt) = (shape.nt(0), shape.nt(1));
    let plane = xt * yt * shape.nt(2);
    let base = v * plane;
    let mut w = 0usize;
    for k in slab.z.0..slab.z.1 {
        for j in slab.y.0..slab.y.1 {
            let row = base + (k * yt + j) * xt;
            let n = slab.x.1 - slab.x.0;
            out[w..w + n].copy_from_slice(&arr[row + slab.x.0..row + slab.x.1]);
            w += n;
        }
    }
    w
}

/// Inverse of [`copy_slab_out`].
pub fn copy_slab_in(
    arr: &mut [crate::Real],
    shape: &IndexShape,
    v: usize,
    slab: &Slab,
    src: &[crate::Real],
) -> usize {
    let (xt, yt) = (shape.nt(0), shape.nt(1));
    let plane = xt * yt * shape.nt(2);
    let base = v * plane;
    let mut r = 0usize;
    for k in slab.z.0..slab.z.1 {
        for j in slab.y.0..slab.y.1 {
            let row = base + (k * yt + j) * xt;
            let n = slab.x.1 - slab.x.0;
            arr[row + slab.x.0..row + slab.x.1].copy_from_slice(&src[r..r + n]);
            r += n;
        }
    }
    r
}

/// Pack every send segment of a [nvar, Z, Y, X] array into `out`
/// (native analog of the `pack` artifact; identical layout).
pub fn pack_all(arr: &[crate::Real], shape: &IndexShape, nvar: usize, out: &mut [crate::Real]) {
    let mut w = 0usize;
    for o in crate::mesh::tree::neighbor_offsets(shape.dim) {
        let slab = send_slab(o, shape);
        for v in 0..nvar {
            w += copy_slab_out(arr, shape, v, &slab, &mut out[w..]);
        }
    }
    debug_assert_eq!(w, buflen(shape, nvar));
}

/// Unpack every recv segment of `src` into the ghost regions of `arr`.
pub fn unpack_all(arr: &mut [crate::Real], shape: &IndexShape, nvar: usize, src: &[crate::Real]) {
    let mut r = 0usize;
    for o in crate::mesh::tree::neighbor_offsets(shape.dim) {
        let slab = recv_slab(o, shape);
        for v in 0..nvar {
            r += copy_slab_in(arr, shape, v, &slab, &src[r..]);
        }
    }
    debug_assert_eq!(r, buflen(shape, nvar));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::tree::neighbor_offsets;

    #[test]
    fn buflen_known_value_3d() {
        // matches python/tests/test_bufspec.py::test_buflen_known_value
        let s = IndexShape::new(3, [16, 16, 16]);
        let per_var = 6 * 2 * 16 * 16 + 12 * 4 * 16 + 8 * 8;
        assert_eq!(buflen(&s, 5), 5 * per_var);
    }

    #[test]
    fn send_recv_shapes_congruent() {
        let s = IndexShape::new(3, [16, 8, 4]);
        for o in neighbor_offsets(3) {
            let snd = send_slab(o, &s);
            let rcv = recv_slab([-o[0], -o[1], -o[2]], &s);
            assert_eq!(snd.dims_zyx(), rcv.dims_zyx(), "offset {o:?}");
        }
    }

    #[test]
    fn recv_slabs_tile_ghost_shell() {
        let s = IndexShape::new(2, [8, 8, 1]);
        let mut cover = vec![0u8; s.ncells_total()];
        for o in neighbor_offsets(2) {
            let slab = recv_slab(o, &s);
            for k in slab.z.0..slab.z.1 {
                for j in slab.y.0..slab.y.1 {
                    for i in slab.x.0..slab.x.1 {
                        cover[s.idx3(k, j, i)] += 1;
                    }
                }
            }
        }
        for k in 0..s.nt(2) {
            for j in 0..s.nt(1) {
                for i in 0..s.nt(0) {
                    let interior = (s.is_(0)..s.ie(0)).contains(&i)
                        && (s.is_(1)..s.ie(1)).contains(&j)
                        && (s.is_(2)..s.ie(2)).contains(&k);
                    let expected = if interior { 0 } else { 1 };
                    assert_eq!(cover[s.idx3(k, j, i)], expected, "({k},{j},{i})");
                }
            }
        }
    }

    #[test]
    fn pack_unpack_periodic_self_roundtrip() {
        // single periodic block: routing send segment i to recv slot at
        // opposite(i) must equal a periodic ghost fill
        let s = IndexShape::new(2, [8, 8, 1]);
        let nvar = 2;
        let n = s.ncells_total();
        let mut arr = vec![0.0f32; nvar * n];
        for v in 0..nvar {
            for j in 0..s.nt(1) {
                for i in 0..s.nt(0) {
                    arr[v * n + s.idx3(0, j, i)] =
                        (v * 10_000 + j * 100 + i) as f32;
                }
            }
        }
        let mut bufs = vec![0.0f32; buflen(&s, nvar)];
        pack_all(&arr, &s, nvar, &mut bufs);

        // route
        let (offs, total) = segment_offsets(&s, nvar);
        let lens = segment_lengths(&s, nvar);
        let opp = opposite_index(2);
        let mut routed = vec![0.0f32; total];
        for i in 0..lens.len() {
            let j = opp[i];
            routed[offs[i]..offs[i] + lens[i]]
                .copy_from_slice(&bufs[offs[j]..offs[j] + lens[j]]);
        }
        let mut out = arr.clone();
        unpack_all(&mut out, &s, nvar, &routed);

        // periodic expectation
        let g = crate::NGHOST;
        let wrap = |i: usize, ni: usize| -> usize {
            let v = (i as i64 - g as i64).rem_euclid(ni as i64) as usize;
            v + g
        };
        for v in 0..nvar {
            for j in 0..s.nt(1) {
                for i in 0..s.nt(0) {
                    let src = arr[v * n + s.idx3(0, wrap(j, 8), wrap(i, 8))];
                    assert_eq!(out[v * n + s.idx3(0, j, i)], src, "v{v} j{j} i{i}");
                }
            }
        }
    }

    #[test]
    fn restrict_lengths_known_values() {
        // matches python/tests/test_refine.py geometry invariants
        let s = IndexShape::new(2, [8, 8, 1]);
        for (o, l) in neighbor_offsets(2)
            .iter()
            .zip(restrict_segment_lengths(&s, 5))
        {
            let ex = 5
                * (if o[0] != 0 { 2 } else { 4 })
                * (if o[1] != 0 { 2 } else { 4 });
            assert_eq!(l, ex, "offset {o:?}");
        }
        let s3 = IndexShape::new(3, [16, 16, 16]);
        let lens = restrict_segment_lengths(&s3, 5);
        assert_eq!(lens.len(), 26);
        // x-face: g * (n/2)^2
        assert_eq!(lens[neighbor_offsets(3).iter().position(|o| *o == [-1, 0, 0]).unwrap()], 5 * 2 * 8 * 8);
    }

    #[test]
    fn segment_offsets_sum() {
        let s = IndexShape::new(3, [8, 8, 8]);
        let (offs, total) = segment_offsets(&s, 5);
        assert_eq!(offs.len(), 26);
        assert_eq!(total, buflen(&s, 5));
        assert_eq!(offs[0], 0);
    }
}
