//! Physical (non-periodic) boundary conditions, applied to ghost slabs on
//! domain edges after neighbor exchange: outflow (zero-gradient copy) and
//! reflecting (mirror + sign flip of the normal vector component).
//!
//! Sweeps are applied axis by axis over the FULL extent of the other axes
//! (ghosts included), so edges/corners between a physical boundary and a
//! periodic/internal one are filled correctly — the ATHENA++ ordering.

use crate::mesh::{BoundaryCondition, IndexShape};
use crate::Real;

/// Apply physical BCs to a [nvar, Z, Y, X] array.
///
/// `bcs[d][side]` gives the condition per axis/side; sides on internal or
/// periodic boundaries must be passed as `None`. `vector_comps` names the
/// components that flip sign under reflection along each axis (e.g.
/// `[IM1, IM2, IM3]` for conserved hydro momenta).
pub fn apply_physical_bcs(
    arr: &mut [Real],
    shape: &IndexShape,
    bcs: &[[Option<BoundaryCondition>; 2]; 3],
    nvar: usize,
    vector_comps: Option<[usize; 3]>,
) {
    let g = crate::NGHOST;
    let n = shape.ncells_total();
    let (nt0, nt1, nt2) = (shape.nt(0), shape.nt(1), shape.nt(2));

    for d in 0..shape.dim {
        for side in 0..2 {
            let Some(bc) = bcs[d][side] else { continue };
            if bc == BoundaryCondition::Periodic {
                continue;
            }
            let flip_comp = vector_comps.map(|v| v[d]);
            // ghost index range along d and its mirror/clamp source
            let nd = shape.n[d];
            for v in 0..nvar {
                let flip = bc == BoundaryCondition::Reflect && flip_comp == Some(v);
                for k in 0..nt2 {
                    for j in 0..nt1 {
                        for i in 0..nt0 {
                            let idx_d = match d {
                                0 => i,
                                1 => j,
                                _ => k,
                            };
                            let in_ghost = if side == 0 { idx_d < g } else { idx_d >= g + nd };
                            if !in_ghost {
                                continue;
                            }
                            let src_d = match bc {
                                BoundaryCondition::Outflow => {
                                    if side == 0 {
                                        g
                                    } else {
                                        g + nd - 1
                                    }
                                }
                                BoundaryCondition::Reflect => {
                                    if side == 0 {
                                        2 * g - 1 - idx_d
                                    } else {
                                        2 * (g + nd) - 1 - idx_d
                                    }
                                }
                                BoundaryCondition::Periodic => unreachable!(),
                            };
                            let (si, sj, sk) = match d {
                                0 => (src_d, j, k),
                                1 => (i, src_d, k),
                                _ => (i, j, src_d),
                            };
                            let src = arr[v * n + (sk * nt1 + sj) * nt0 + si];
                            let dst = v * n + (k * nt1 + j) * nt0 + i;
                            arr[dst] = if flip { -src } else { src };
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::BoundaryCondition::{Outflow, Reflect};

    fn shape() -> IndexShape {
        IndexShape::new(1, [6, 1, 1])
    }

    #[test]
    fn outflow_copies_edge_value() {
        let s = shape();
        let mut a: Vec<Real> = (0..s.ncells_total()).map(|i| i as Real).collect();
        // interior is [2..8); a[2] = 2, a[7] = 7
        let bcs = [[Some(Outflow), Some(Outflow)], [None, None], [None, None]];
        apply_physical_bcs(&mut a, &s, &bcs, 1, None);
        assert_eq!(&a[0..2], &[2.0, 2.0]);
        assert_eq!(&a[8..10], &[7.0, 7.0]);
    }

    #[test]
    fn reflect_mirrors_and_flips_normal_component() {
        let s = shape();
        let n = s.ncells_total();
        let mut a = vec![0.0; 2 * n];
        for i in 2..8 {
            a[i] = i as Real; // scalar comp 0
            a[n + i] = 10.0 + i as Real; // "momentum" comp 1
        }
        let bcs = [[Some(Reflect), None], [None, None], [None, None]];
        apply_physical_bcs(&mut a, &s, &bcs, 2, Some([1, usize::MAX, usize::MAX]));
        // ghost 1 mirrors interior 2, ghost 0 mirrors interior 3
        assert_eq!(a[1], 2.0);
        assert_eq!(a[0], 3.0);
        assert_eq!(a[n + 1], -12.0);
        assert_eq!(a[n], -13.0);
    }

    #[test]
    fn corners_filled_by_sweep_order_2d() {
        let s = IndexShape::new(2, [4, 4, 1]);
        let n = s.ncells_total();
        let mut a = vec![-1.0; n];
        for j in 2..6 {
            for i in 2..6 {
                a[j * s.nt(0) + i] = 5.0;
            }
        }
        let bcs = [
            [Some(Outflow), Some(Outflow)],
            [Some(Outflow), Some(Outflow)],
            [None, None],
        ];
        apply_physical_bcs(&mut a, &s, &bcs, 1, None);
        assert!(a.iter().all(|&x| x == 5.0), "corner ghosts must be filled");
    }
}
