//! Restriction and prolongation between refinement levels.
//!
//! * Restriction is conservative averaging of 2^dim fine cells.
//! * Prolongation is slope-limited (minmod) linear interpolation, evaluated
//!   at fine cell centers (offsets ±h/4 from the coarse center), with slopes
//!   clamped to zero at the edges of the available coarse data.
//!
//! Used in three places, exactly like the paper (Sec. 3.7/3.8): ghost-zone
//! exchange at level boundaries (fine data is restricted *before* sending;
//! coarse data is prolongated *after* receipt), regridding (blocks are
//! refined/derefined in place), and flux correction (face-flux restriction
//! lives in the exchange engine).

use super::bufspec::Slab;
use crate::mesh::IndexShape;
use crate::Real;

#[inline]
fn minmod(a: Real, b: Real) -> Real {
    if a * b > 0.0 {
        if a.abs() < b.abs() {
            a
        } else {
            b
        }
    } else {
        0.0
    }
}

/// Restrict an even-aligned fine-index box of `fine` ([nvar, Z, Y, X],
/// ghosted) into a dense coarse buffer (dims = box dims halved per active
/// axis), appended to `out` in [v, z, y, x] order.
pub fn restrict_slab(
    fine: &[Real],
    shape: &IndexShape,
    nvar: usize,
    slab: &Slab,
    out: &mut Vec<Real>,
) -> [usize; 3] {
    let dim = shape.dim;
    let (fz, fy, fx) = slab.dims_zyx();
    let cx = fx / 2;
    let cy = if dim >= 2 { fy / 2 } else { fy };
    let cz = if dim >= 3 { fz / 2 } else { fz };
    debug_assert!(fx % 2 == 0);
    debug_assert!(dim < 2 || fy % 2 == 0);
    debug_assert!(dim < 3 || fz % 2 == 0);
    let n = shape.ncells_total();
    let (nt0, nt1) = (shape.nt(0), shape.nt(1));
    let wsum = match dim {
        1 => 0.5,
        2 => 0.25,
        _ => 0.125,
    } as Real;
    for v in 0..nvar {
        for ck in 0..cz {
            for cj in 0..cy {
                for ci in 0..cx {
                    let k0 = slab.z.0 + if dim >= 3 { 2 * ck } else { ck };
                    let j0 = slab.y.0 + if dim >= 2 { 2 * cj } else { cj };
                    let i0 = slab.x.0 + 2 * ci;
                    let mut s = 0.0;
                    let kmax = if dim >= 3 { 2 } else { 1 };
                    let jmax = if dim >= 2 { 2 } else { 1 };
                    for dk in 0..kmax {
                        for dj in 0..jmax {
                            for di in 0..2 {
                                s += fine[v * n + ((k0 + dk) * nt1 + j0 + dj) * nt0 + i0 + di];
                            }
                        }
                    }
                    out.push(s * wsum);
                }
            }
        }
    }
    [cx, cy, cz]
}

/// Prolongate coarse data into a fine ghost slab.
///
/// * `arr`: the fine block's [nvar, Z, Y, X] array (ghosted).
/// * `slab`: the ghost box to fill, in local fine (ghosted) indices.
/// * `fine_lo`: global *fine-cell* index of local cell (is_, is_, is_) — i.e.
///   `loc.lx[d] * n[d]` per axis; converts local indices to global.
/// * `coarse`: dense [nvar, cz, cy, cx] coarse data.
/// * `clo`: global coarse-cell index of coarse[.., 0, 0, 0].
pub fn prolongate_ghost_slab(
    arr: &mut [Real],
    shape: &IndexShape,
    nvar: usize,
    slab: &Slab,
    fine_lo: [i64; 3],
    coarse: &[Real],
    clo: [i64; 3],
    cdims: [usize; 3],
) {
    let dim = shape.dim;
    let n = shape.ncells_total();
    let (nt0, nt1) = (shape.nt(0), shape.nt(1));
    let [cx, cy, cz] = cdims;
    let cplane = cx * cy * cz;
    let g = shape.is_(0) as i64; // NGHOST in active dims

    let cidx = |v: usize, k: usize, j: usize, i: usize| -> usize {
        v * cplane + (k * cy + j) * cx + i
    };

    for v in 0..nvar {
        for k in slab.z.0..slab.z.1 {
            for j in slab.y.0..slab.y.1 {
                for i in slab.x.0..slab.x.1 {
                    // global fine indices
                    let gf = [
                        fine_lo[0] + i as i64 - g,
                        fine_lo[1] + j as i64 - if dim >= 2 { g } else { 0 },
                        fine_lo[2] + k as i64 - if dim >= 3 { g } else { 0 },
                    ];
                    // owning coarse cell (local to the buffer)
                    let c = [
                        (gf[0].div_euclid(2) - clo[0]) as usize,
                        if dim >= 2 { (gf[1].div_euclid(2) - clo[1]) as usize } else { 0 },
                        if dim >= 3 { (gf[2].div_euclid(2) - clo[2]) as usize } else { 0 },
                    ];
                    debug_assert!(c[0] < cx && c[1] < cy && c[2] < cz);
                    let center = coarse[cidx(v, c[2], c[1], c[0])];
                    let mut val = center;
                    // per-axis limited slope, zero at buffer edges
                    for d in 0..dim {
                        let (ext, cc) = match d {
                            0 => (cx, c[0]),
                            1 => (cy, c[1]),
                            _ => (cz, c[2]),
                        };
                        let mut slope = 0.0;
                        if cc > 0 && cc + 1 < ext {
                            let (km, jm, im, kp, jp, ip) = match d {
                                0 => (c[2], c[1], c[0] - 1, c[2], c[1], c[0] + 1),
                                1 => (c[2], c[1] - 1, c[0], c[2], c[1] + 1, c[0]),
                                _ => (c[2] - 1, c[1], c[0], c[2] + 1, c[1], c[0]),
                            };
                            let dm = center - coarse[cidx(v, km, jm, im)];
                            let dp = coarse[cidx(v, kp, jp, ip)] - center;
                            slope = minmod(dm, dp);
                        }
                        let t: Real = if gf[d].rem_euclid(2) == 0 { -0.25 } else { 0.25 };
                        val += slope * t;
                    }
                    arr[v * n + (k * nt1 + j) * nt0 + i] = val;
                }
            }
        }
    }
}

/// On derefinement: restrict a child block's interior into the parent's
/// octant given the child's per-axis bits (0 = lower half).
pub fn restrict_block_into_parent(
    child: &[Real],
    shape: &IndexShape,
    nvar: usize,
    bits: [i64; 3],
    parent: &mut [Real],
) {
    let dim = shape.dim;
    let interior = Slab {
        x: (shape.is_(0), shape.ie(0)),
        y: (shape.is_(1), shape.ie(1)),
        z: (shape.is_(2), shape.ie(2)),
    };
    let mut buf = Vec::with_capacity(nvar * shape.ncells_interior() / (1 << dim));
    let [cx, cy, cz] = restrict_slab(child, shape, nvar, &interior, &mut buf);
    let n = shape.ncells_total();
    let (nt0, nt1) = (shape.nt(0), shape.nt(1));
    // parent octant origin in parent local (ghosted) indices
    let ox = shape.is_(0) + bits[0] as usize * shape.n[0] / 2;
    let oy = shape.is_(1) + if dim >= 2 { bits[1] as usize * shape.n[1] / 2 } else { 0 };
    let oz = shape.is_(2) + if dim >= 3 { bits[2] as usize * shape.n[2] / 2 } else { 0 };
    let mut r = 0usize;
    for v in 0..nvar {
        for k in 0..cz {
            for j in 0..cy {
                for i in 0..cx {
                    parent[v * n + ((oz + k) * nt1 + oy + j) * nt0 + ox + i] = buf[r];
                    r += 1;
                }
            }
        }
    }
}

/// On refinement: fill a child block's interior by prolongating from the
/// parent's interior (slope-limited linear; slopes clamped at parent
/// interior edges).
pub fn prolongate_child_from_parent(
    parent: &[Real],
    shape: &IndexShape,
    nvar: usize,
    bits: [i64; 3],
    child: &mut [Real],
) {
    let dim = shape.dim;
    // Dense copy of parent's interior as the "coarse buffer".
    let n = shape.ncells_total();
    let (nt0, nt1) = (shape.nt(0), shape.nt(1));
    let (px, py, pz) = (shape.n[0], shape.n[1], shape.n[2]);
    let mut coarse = vec![0.0; nvar * px * py * pz];
    let mut w = 0usize;
    for v in 0..nvar {
        for k in shape.is_(2)..shape.ie(2) {
            for j in shape.is_(1)..shape.ie(1) {
                for i in shape.is_(0)..shape.ie(0) {
                    coarse[w] = parent[v * n + (k * nt1 + j) * nt0 + i];
                    w += 1;
                }
            }
        }
    }
    // Child interior slab, with globals chosen so the child's fine cells
    // land inside the parent's coarse box: treat parent interior as coarse
    // cells [0..px) etc., child fine index = bits*n + local.
    let interior = Slab {
        x: (shape.is_(0), shape.ie(0)),
        y: (shape.is_(1), shape.ie(1)),
        z: (shape.is_(2), shape.ie(2)),
    };
    // In the parent's coarse frame, child octant `bits` spans fine cells
    // [bits*n, bits*n + n) per axis.
    let fine_lo = [
        bits[0] * px as i64,
        if dim >= 2 { bits[1] * py as i64 } else { 0 },
        if dim >= 3 { bits[2] * pz as i64 } else { 0 },
    ];
    prolongate_ghost_slab(
        child,
        shape,
        nvar,
        &interior,
        fine_lo,
        &coarse,
        [0, 0, 0],
        [px, py, pz],
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::NGHOST;

    fn fill_linear(shape: &IndexShape, nvar: usize, f: impl Fn(usize, f64, f64, f64) -> f64) -> Vec<Real> {
        let n = shape.ncells_total();
        let mut arr = vec![0.0; nvar * n];
        for v in 0..nvar {
            for k in 0..shape.nt(2) {
                for j in 0..shape.nt(1) {
                    for i in 0..shape.nt(0) {
                        let x = i as f64;
                        let y = j as f64;
                        let z = k as f64;
                        arr[v * n + (k * shape.nt(1) + j) * shape.nt(0) + i] =
                            f(v, x, y, z) as Real;
                    }
                }
            }
        }
        arr
    }

    #[test]
    fn restriction_preserves_constant_and_mean() {
        let shape = IndexShape::new(2, [8, 8, 1]);
        let fine = fill_linear(&shape, 1, |_, _, _, _| 3.5);
        let slab = Slab { x: (2, 10), y: (2, 10), z: (0, 1) };
        let mut out = Vec::new();
        let dims = restrict_slab(&fine, &shape, 1, &slab, &mut out);
        assert_eq!(dims, [4, 4, 1]);
        assert!(out.iter().all(|&x| (x - 3.5).abs() < 1e-6));

        // mean preservation for arbitrary data
        let fine2 = fill_linear(&shape, 1, |_, x, y, _| x * 7.0 + y * 0.5 + 1.0);
        let mut out2 = Vec::new();
        restrict_slab(&fine2, &shape, 1, &slab, &mut out2);
        let fine_sum: f64 = (2..10)
            .flat_map(|j| (2..10).map(move |i| (i as f64 * 7.0 + j as f64 * 0.5 + 1.0)))
            .sum();
        let coarse_sum: f64 = out2.iter().map(|&x| x as f64 * 4.0).sum();
        assert!((fine_sum - coarse_sum).abs() < 1e-3);
    }

    #[test]
    fn prolongation_reproduces_linear_fields() {
        // coarse data linear in x and y -> limited-linear prolongation is
        // exact away from buffer edges
        let shape = IndexShape::new(2, [8, 8, 1]);
        let nvar = 1;
        let (cx, cy, cz) = (6, 6, 1);
        let clo = [-1i64, -1, 0];
        let mut coarse = vec![0.0; cx * cy * cz];
        for j in 0..cy {
            for i in 0..cx {
                let gx = clo[0] + i as i64;
                let gy = clo[1] + j as i64;
                coarse[j * cx + i] = (2.0 * gx as f64 + 0.5 * gy as f64) as Real;
            }
        }
        let mut arr = vec![0.0; nvar * shape.ncells_total()];
        // fill the block interior (fine globals [0,8)x[0,8) = coarse [0,4))
        let slab = Slab {
            x: (NGHOST, NGHOST + 8),
            y: (NGHOST, NGHOST + 8),
            z: (0, 1),
        };
        prolongate_ghost_slab(&mut arr, &shape, nvar, &slab, [0, 0, 0], &coarse, clo, [cx, cy, cz]);
        // fine cell value should equal the linear field at fine centers:
        // coarse cell c center = c + 0.5 (coarse units), fine cell gf sits
        // at (gf + 0.5)/2 coarse units -> field = 2x + 0.5y in coarse coords
        for j in NGHOST..NGHOST + 8 {
            for i in NGHOST..NGHOST + 8 {
                let gfx = (i - NGHOST) as f64;
                let gfy = (j - NGHOST) as f64;
                let xc = (gfx + 0.5) / 2.0 - 0.5; // position in coarse index units
                let yc = (gfy + 0.5) / 2.0 - 0.5;
                let expect = 2.0 * xc + 0.5 * yc;
                let got = arr[(j * shape.nt(0)) + i] as f64;
                assert!(
                    (got - expect).abs() < 1e-5,
                    "({i},{j}): {got} vs {expect}"
                );
            }
        }
    }

    #[test]
    fn refine_then_derefine_roundtrips_constant() {
        let shape = IndexShape::new(2, [8, 8, 1]);
        let nvar = 2;
        let parent = fill_linear(&shape, nvar, |v, _, _, _| v as f64 + 1.0);
        let mut children = Vec::new();
        for bits in [[0i64, 0, 0], [1, 0, 0], [0, 1, 0], [1, 1, 0]] {
            let mut child = vec![0.0; nvar * shape.ncells_total()];
            prolongate_child_from_parent(&parent, &shape, nvar, bits, &mut child);
            children.push((bits, child));
        }
        let mut back = vec![0.0; nvar * shape.ncells_total()];
        for (bits, child) in &children {
            restrict_block_into_parent(child, &shape, nvar, *bits, &mut back);
        }
        // interiors agree exactly for constants
        let n = shape.ncells_total();
        for v in 0..nvar {
            for j in shape.is_(1)..shape.ie(1) {
                for i in shape.is_(0)..shape.ie(0) {
                    let c = v * n + j * shape.nt(0) + i;
                    assert!((back[c] - parent[c]).abs() < 1e-6);
                }
            }
        }
    }

    #[test]
    fn refine_derefine_conserves_totals() {
        use crate::util::rng::XorShift;
        let shape = IndexShape::new(2, [8, 8, 1]);
        let nvar = 1;
        let mut rng = XorShift::new(5);
        let n = shape.ncells_total();
        let mut parent = vec![0.0; n];
        for j in shape.is_(1)..shape.ie(1) {
            for i in shape.is_(0)..shape.ie(0) {
                parent[j * shape.nt(0) + i] = 1.0 + rng.next_f32();
            }
        }
        let mut total_children = 0.0f64;
        let mut back = vec![0.0; n];
        for bits in [[0i64, 0, 0], [1, 0, 0], [0, 1, 0], [1, 1, 0]] {
            let mut child = vec![0.0; n];
            prolongate_child_from_parent(&parent, &shape, nvar, bits, &mut child);
            for j in shape.is_(1)..shape.ie(1) {
                for i in shape.is_(0)..shape.ie(0) {
                    // child cell volume = parent/4
                    total_children += child[j * shape.nt(0) + i] as f64 * 0.25;
                }
            }
            restrict_block_into_parent(&child, &shape, nvar, bits, &mut back);
        }
        let mut total_parent = 0.0f64;
        let mut total_back = 0.0f64;
        for j in shape.is_(1)..shape.ie(1) {
            for i in shape.is_(0)..shape.ie(0) {
                total_parent += parent[j * shape.nt(0) + i] as f64;
                total_back += back[j * shape.nt(0) + i] as f64;
            }
        }
        // limited-linear prolongation is conservative (slopes cancel in the
        // 2x2 average), restriction is exact averaging
        assert!((total_children - total_parent).abs() < 1e-3, "{total_children} vs {total_parent}");
        assert!((total_back - total_parent).abs() < 1e-3);
    }
}
