//! Boundary values: buffer layout, ghost-zone exchange engine with the
//! paper's packing strategies, physical boundary conditions, restriction /
//! prolongation across levels, and flux correction.

pub mod bufspec;
mod exchange;
mod physical;
mod prolong;

pub use exchange::{
    apply_block_physical_bcs, exchange_blocking, exchange_blocking_subset,
    exchange_tasked, exchange_tasked_parallel, poll_receives, poll_receives_blocks,
    post_receives, post_receives_blocks, post_receives_range, post_sends,
    post_sends_blocks, post_sends_range, post_sends_toward, ExchTopo, ExchangeState,
    PackExchange, PackStrategy,
};
// Boundary-segment specs shared with the Device execution space (crate
// internal: the Device routes snapshot them so its messages are
// byte-identical to the host exchange by construction).
pub(crate) use exchange::{
    apply_recv_op, block_bc_table, recv_specs_for, send_payload, send_specs_for,
    RecvOp, RecvSpec, SendOp, SendSpec,
};
pub use physical::apply_physical_bcs;
pub use prolong::{
    prolongate_child_from_parent, prolongate_ghost_slab, restrict_block_into_parent,
    restrict_slab,
};
