//! Runtime configuration: Athena/Parthenon-style input files.
//!
//! ```text
//! <parthenon/mesh>
//! nx1 = 64          # root grid cells
//! x1min = -0.5
//! x1max = 0.5
//!
//! <parthenon/meshblock>
//! nx1 = 16
//! ```
//!
//! Keys can be overridden from the command line as `block/key=value`
//! (see [`ParameterInput::apply_override`]), mirroring Parthenon's CLI.

mod parameter_input;

pub use parameter_input::{Override, ParameterInput};
