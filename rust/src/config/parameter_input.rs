//! The `ParameterInput` store: parsed input blocks with typed getters.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};

/// A typed configuration override: one `<block>` key set to a value.
///
/// The CLI spelling `block/key=value` parses via [`std::str::FromStr`]
/// (parse once, at the program edge — a malformed spec is an
/// [`Error::Config`] before any rank thread launches, never a panic inside
/// one), and [`std::fmt::Display`] round-trips it for logs and decks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Override {
    pub block: String,
    pub key: String,
    pub value: String,
}

impl Override {
    pub fn new(
        block: impl Into<String>,
        key: impl Into<String>,
        value: impl ToString,
    ) -> Self {
        Override {
            block: block.into(),
            key: key.into(),
            value: value.to_string(),
        }
    }
}

impl std::str::FromStr for Override {
    type Err = Error;

    fn from_str(spec: &str) -> Result<Self> {
        let (path, value) = spec
            .split_once('=')
            .ok_or_else(|| Error::config(format!("bad override {spec:?}")))?;
        let (block, key) = path
            .rsplit_once('/')
            .ok_or_else(|| Error::config(format!("bad override path {path:?}")))?;
        if block.is_empty() || key.is_empty() {
            return Err(Error::config(format!("bad override path {path:?}")));
        }
        Ok(Override::new(block, key, value))
    }
}

impl std::fmt::Display for Override {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}={}", self.block, self.key, self.value)
    }
}

/// Parsed input file: `<block>` sections of `key = value` pairs.
///
/// Getter methods with an `_or` suffix record the default into the store so
/// that the effective configuration (including defaulted values) can be
/// dumped into outputs — the same trick Parthenon/Athena++ use to make runs
/// reproducible from their output headers.
#[derive(Debug, Clone, Default)]
pub struct ParameterInput {
    blocks: BTreeMap<String, BTreeMap<String, String>>,
}

impl ParameterInput {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref()).map_err(|e| {
            Error::config(format!("cannot read {:?}: {e}", path.as_ref()))
        })?;
        Self::from_str(&text)
    }

    #[allow(clippy::should_implement_trait)]
    pub fn from_str(text: &str) -> Result<Self> {
        let mut pin = Self::new();
        let mut block = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('<') {
                let name = name.strip_suffix('>').ok_or_else(|| {
                    Error::config(format!("line {}: malformed block header", lineno + 1))
                })?;
                block = name.trim().to_string();
                pin.blocks.entry(block.clone()).or_default();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::config(format!("line {}: expected key = value", lineno + 1))
            })?;
            if block.is_empty() {
                return Err(Error::config(format!(
                    "line {}: key before any <block>",
                    lineno + 1
                )));
            }
            pin.blocks
                .get_mut(&block)
                .unwrap()
                .insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(pin)
    }

    /// Apply a CLI override of the form `block/key=value` (parse + apply in
    /// one step; prefer parsing to [`Override`] once at the program edge and
    /// [`ParameterInput::apply`] thereafter).
    pub fn apply_override(&mut self, spec: &str) -> Result<()> {
        self.apply(&spec.parse::<Override>()?);
        Ok(())
    }

    /// Apply an already-parsed [`Override`]. Infallible: a well-formed
    /// override always lands (unknown keys are simply never read).
    pub fn apply(&mut self, ov: &Override) {
        self.set(&ov.block, &ov.key, &ov.value);
    }

    pub fn set(&mut self, block: &str, key: &str, value: impl ToString) {
        self.blocks
            .entry(block.to_string())
            .or_default()
            .insert(key.to_string(), value.to_string());
    }

    pub fn has(&self, block: &str, key: &str) -> bool {
        self.blocks
            .get(block)
            .map(|b| b.contains_key(key))
            .unwrap_or(false)
    }

    pub fn get_str(&self, block: &str, key: &str) -> Option<&str> {
        self.blocks.get(block)?.get(key).map(|s| s.as_str())
    }

    fn parse<T: std::str::FromStr>(&self, block: &str, key: &str) -> Result<Option<T>> {
        match self.get_str(block, key) {
            None => Ok(None),
            Some(s) => s.parse::<T>().map(Some).map_err(|_| {
                Error::config(format!("cannot parse <{block}> {key} = {s:?}"))
            }),
        }
    }

    pub fn get_real(&self, block: &str, key: &str) -> Result<Option<f64>> {
        self.parse(block, key)
    }

    pub fn get_int(&self, block: &str, key: &str) -> Result<Option<i64>> {
        self.parse(block, key)
    }

    pub fn get_bool(&self, block: &str, key: &str) -> Result<Option<bool>> {
        match self.get_str(block, key) {
            None => Ok(None),
            Some(s) => match s.to_ascii_lowercase().as_str() {
                "true" | "1" | "yes" | "on" => Ok(Some(true)),
                "false" | "0" | "no" | "off" => Ok(Some(false)),
                _ => Err(Error::config(format!("cannot parse bool <{block}> {key} = {s:?}"))),
            },
        }
    }

    // -- getters that record the applied default ----------------------------

    pub fn real_or(&mut self, block: &str, key: &str, default: f64) -> f64 {
        match self.get_real(block, key) {
            Ok(Some(v)) => v,
            _ => {
                self.set(block, key, default);
                default
            }
        }
    }

    pub fn int_or(&mut self, block: &str, key: &str, default: i64) -> i64 {
        match self.get_int(block, key) {
            Ok(Some(v)) => v,
            _ => {
                self.set(block, key, default);
                default
            }
        }
    }

    pub fn bool_or(&mut self, block: &str, key: &str, default: bool) -> bool {
        match self.get_bool(block, key) {
            Ok(Some(v)) => v,
            _ => {
                self.set(block, key, default);
                default
            }
        }
    }

    pub fn str_or(&mut self, block: &str, key: &str, default: &str) -> String {
        match self.get_str(block, key) {
            Some(v) => v.to_string(),
            None => {
                self.set(block, key, default);
                default.to_string()
            }
        }
    }

    /// Dump the effective configuration back to input-file syntax.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        for (block, kv) in &self.blocks {
            s.push_str(&format!("<{block}>\n"));
            for (k, v) in kv {
                s.push_str(&format!("{k} = {v}\n"));
            }
            s.push('\n');
        }
        s
    }

    pub fn block_names(&self) -> impl Iterator<Item = &str> {
        self.blocks.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    match line.find('#') {
        Some(i) => &line[..i],
        None => line,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# a comment
<parthenon/mesh>
nx1 = 64    # trailing comment
x1min = -0.5
x1max = 0.5
periodic = true

<hydro>
gamma = 1.4
eos = adiabatic
"#;

    #[test]
    fn parses_blocks_and_values() {
        let pin = ParameterInput::from_str(SAMPLE).unwrap();
        assert_eq!(pin.get_int("parthenon/mesh", "nx1").unwrap(), Some(64));
        assert_eq!(pin.get_real("parthenon/mesh", "x1min").unwrap(), Some(-0.5));
        assert_eq!(pin.get_bool("parthenon/mesh", "periodic").unwrap(), Some(true));
        assert_eq!(pin.get_str("hydro", "eos"), Some("adiabatic"));
    }

    #[test]
    fn defaults_are_recorded() {
        let mut pin = ParameterInput::from_str(SAMPLE).unwrap();
        assert_eq!(pin.int_or("parthenon/mesh", "nx2", 1), 1);
        // second read sees the recorded default
        assert_eq!(pin.get_int("parthenon/mesh", "nx2").unwrap(), Some(1));
        assert!(pin.dump().contains("nx2 = 1"));
    }

    #[test]
    fn overrides() {
        let mut pin = ParameterInput::from_str(SAMPLE).unwrap();
        pin.apply_override("parthenon/mesh/nx1=128").unwrap();
        assert_eq!(pin.get_int("parthenon/mesh", "nx1").unwrap(), Some(128));
        assert!(pin.apply_override("garbage").is_err());
        assert!(pin.apply_override("noslash=3").is_err());
    }

    #[test]
    fn typed_override_roundtrip() {
        let ov: Override = "parthenon/mesh/nx1=128".parse().unwrap();
        assert_eq!(ov, Override::new("parthenon/mesh", "nx1", 128));
        assert_eq!(ov.to_string(), "parthenon/mesh/nx1=128");
        let mut pin = ParameterInput::from_str(SAMPLE).unwrap();
        pin.apply(&ov);
        assert_eq!(pin.get_int("parthenon/mesh", "nx1").unwrap(), Some(128));
        // malformed specs are Error::Config at parse time, never a panic
        assert!("garbage".parse::<Override>().is_err());
        assert!("noslash=3".parse::<Override>().is_err());
        assert!("/key=3".parse::<Override>().is_err());
        assert!(matches!(
            "garbage".parse::<Override>().unwrap_err(),
            Error::Config(_)
        ));
    }

    #[test]
    fn error_paths() {
        assert!(ParameterInput::from_str("<unclosed\nx=1").is_err());
        assert!(ParameterInput::from_str("x = 1").is_err()); // key before block
        let pin = ParameterInput::from_str("<b>\nx = abc").unwrap();
        assert!(pin.get_int("b", "x").is_err());
    }

    #[test]
    fn roundtrip_dump() {
        let pin = ParameterInput::from_str(SAMPLE).unwrap();
        let pin2 = ParameterInput::from_str(&pin.dump()).unwrap();
        assert_eq!(pin2.get_int("parthenon/mesh", "nx1").unwrap(), Some(64));
    }
}
