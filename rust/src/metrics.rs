//! Performance accounting: zone-cycles/s (the paper's headline metric),
//! per-region timers, and launch counts.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Wall-clock accumulator per named region.
#[derive(Debug, Default)]
pub struct Timers {
    acc: BTreeMap<String, Duration>,
    open: BTreeMap<String, Instant>,
}

impl Timers {
    pub fn start(&mut self, name: &str) {
        self.open.insert(name.to_string(), Instant::now());
    }

    pub fn stop(&mut self, name: &str) {
        if let Some(t0) = self.open.remove(name) {
            *self.acc.entry(name.to_string()).or_default() += t0.elapsed();
        }
    }

    pub fn seconds(&self, name: &str) -> f64 {
        self.acc.get(name).map(|d| d.as_secs_f64()).unwrap_or(0.0)
    }

    pub fn report(&self) -> Vec<(String, f64)> {
        self.acc.iter().map(|(k, v)| (k.clone(), v.as_secs_f64())).collect()
    }
}

/// Exponentially weighted moving average — the per-block cost model
/// (measured cycle seconds folded into [`crate::mesh::MeshBlock::cost`],
/// consumed by the cost-weighted scheduler seed and the load balancer).
#[derive(Debug, Clone, Copy)]
pub struct Ewma {
    /// Weight of the newest sample (0 < alpha <= 1).
    pub alpha: f64,
}

impl Ewma {
    pub fn fold(&self, prev: f64, sample: f64) -> f64 {
        self.alpha * sample + (1.0 - self.alpha) * prev
    }
}

/// Normalize a cost vector to mean 1.0 in place (no-op when the sum is not
/// positive, e.g. before the first measured cycle).
pub fn normalize_mean_one(v: &mut [f64]) {
    let total: f64 = v.iter().sum();
    if v.is_empty() || total <= 0.0 {
        return;
    }
    let mean = total / v.len() as f64;
    for x in v.iter_mut() {
        *x /= mean;
    }
}

/// Migration / re-gather accounting for the load balancer (paper Sec. 3.8
/// overhead): every fixed-tree rebalance records how much actually moved,
/// so tests and the regrid bench lane can assert the incremental path
/// touches only the delta. A no-op rebalance (assignment unchanged) must
/// leave every counter untouched.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RebalanceStats {
    /// Rebalances that moved at least one block.
    pub rebalances: u64,
    /// Of those, how many took the full-rebuild oracle path
    /// (`parthenon/loadbalance mode=full`).
    pub full_rebuilds: u64,
    /// Global blocks that changed owner (summed over rebalances).
    pub blocks_moved: u64,
    /// Local blocks sent to another rank.
    pub blocks_sent: u64,
    /// Local blocks received from another rank.
    pub blocks_received: u64,
    /// Local blocks whose containers survived IN PLACE (incremental path
    /// only — the full oracle tears every container down).
    pub blocks_kept: u64,
    /// Device staging re-gathers paid (dirty packs after the re-plan).
    pub packs_regathered: u64,
    /// Device packs whose staging stayed resident across the re-plan.
    pub packs_preserved: u64,
    /// Device blocks whose boundary routing was rebuilt from the tree
    /// (the rest only re-point ranks on gid-stable entries).
    pub routes_rebuilt: u64,
    /// Boundary segments resent to refresh ghosts / device `bufs_in`
    /// during the rebalance (incremental path; the full oracle re-routes
    /// everything through the blocking exchange instead).
    pub bval_segments_resent: u64,
}

impl RebalanceStats {
    /// True when no rebalance work has been recorded at all — what a
    /// stable-tree, stable-assignment regrid check must leave behind.
    pub fn is_untouched(&self) -> bool {
        *self == RebalanceStats::default()
    }
}

/// Heterogeneous co-execution accounting (`parthenon/exec space=hybrid`):
/// how the cost partitioner split packs across the Host and Device
/// execution spaces, how often idle workers crossed the space boundary,
/// and how many staging re-stagings pack migrations paid. The hybrid perf
/// lane asserts these are non-zero when both spaces are live.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HybridStats {
    /// Pack-stage executions assigned to the Host space (summed over
    /// cycles).
    pub packs_host: u64,
    /// Pack-stage executions assigned to the Device space.
    pub packs_device: u64,
    /// Task lists claimed by a worker whose seeded items belong to the
    /// OTHER execution space (idle worker crossing the boundary).
    pub cross_space_steals: u64,
    /// Staging re-stagings paid when a pack migrated between spaces
    /// (exactly one per migration).
    pub restagings: u64,
    /// Cost-EWMA repartitions performed (at the loadbalance cadence).
    pub repartitions: u64,
}

impl HybridStats {
    /// True when no hybrid scheduling work has been recorded at all —
    /// what a pure single-space run must leave behind.
    pub fn is_untouched(&self) -> bool {
        *self == HybridStats::default()
    }

    /// Fold another rank's counters into this one (bench aggregation).
    pub fn merge(&mut self, other: &HybridStats) {
        self.packs_host += other.packs_host;
        self.packs_device += other.packs_device;
        self.cross_space_steals += other.cross_space_steals;
        self.restagings += other.restagings;
        self.repartitions += other.repartitions;
    }
}

/// Multi-tenant service accounting ([`crate::service::Engine`]): how many
/// sessions share the process, how often same-shape device packs from
/// DIFFERENT sessions were fused into one launch, and how often idle
/// workers drained another tenant's task lists. The service equivalence
/// suite asserts these are non-zero under forced skew — and untouched when
/// batching / multiplexing are toggled off (the sequential oracle).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ServiceStats {
    /// Sessions currently attached to the engine.
    pub sessions_live: u64,
    /// Fused launches that combined packs from >= 2 sessions.
    pub batched_launches: u64,
    /// Kernel launches avoided by batching (sum over batched launches of
    /// participants - 1).
    pub launches_saved: u64,
    /// Task lists claimed by a worker whose seeded items belong to a
    /// DIFFERENT session (idle worker crossing the tenant boundary).
    pub cross_sim_steals: u64,
}

impl ServiceStats {
    /// True when no cross-tenant work has been recorded at all — what a
    /// solo run (or a multiplex/batching-disabled engine) must leave
    /// behind in the batching/steal counters.
    pub fn is_untouched(&self) -> bool {
        *self == ServiceStats::default()
    }

    /// Fold another engine's counters into this one (bench aggregation).
    pub fn merge(&mut self, other: &ServiceStats) {
        self.sessions_live = self.sessions_live.max(other.sessions_live);
        self.batched_launches += other.batched_launches;
        self.launches_saved += other.launches_saved;
        self.cross_sim_steals += other.cross_sim_steals;
    }
}

/// Snapshot of the comm fabric's fault-injection / escalation counters
/// (`World::fault_stats`): what the seeded plan injected, what the framing
/// layer absorbed or detected, and how failures escalated. The chaos suite
/// asserts on these (e.g. injected corruption implies detected corruption —
/// never silently absorbed).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Frames parked in limbo (delivered late).
    pub delayed: u64,
    /// Frames enqueued twice by the injector.
    pub duplicated: u64,
    /// Frames that jumped their queue.
    pub reordered: u64,
    /// Frames bit-flipped by the injector.
    pub corrupted_injected: u64,
    /// Checksum failures surfaced as `Error::CorruptMessage`.
    pub corruption_detected: u64,
    /// Duplicate frames absorbed by the sequence machinery.
    pub duplicates_dropped: u64,
    /// Sends dropped because the sending rank was killed.
    pub dead_sends_dropped: u64,
    /// Ranks killed by the schedule.
    pub kills: u64,
    /// World-level aborts posted (first poster only).
    pub aborts_posted: u64,
    /// Waits escalated to `Error::Timeout`.
    pub timeouts: u64,
}

/// Throughput accounting over a measured window.
#[derive(Debug, Clone, Default)]
pub struct ZoneCycles {
    pub zones_updated: u64,
    pub cycles: u64,
    pub wall_secs: f64,
}

impl ZoneCycles {
    pub fn record_cycle(&mut self, zones: u64, secs: f64) {
        self.zones_updated += zones;
        self.cycles += 1;
        self.wall_secs += secs;
    }

    /// zone-cycles per second (the paper's unit).
    pub fn zcps(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.zones_updated as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    pub fn reset(&mut self) {
        *self = ZoneCycles::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_accumulates() {
        let mut t = Timers::default();
        t.start("a");
        std::thread::sleep(Duration::from_millis(5));
        t.stop("a");
        t.start("a");
        t.stop("a");
        assert!(t.seconds("a") >= 0.005);
        assert_eq!(t.seconds("missing"), 0.0);
    }

    #[test]
    fn ewma_folds_toward_samples() {
        let e = Ewma { alpha: 0.5 };
        let mut c = 1.0;
        for _ in 0..20 {
            c = e.fold(c, 3.0);
        }
        assert!((c - 3.0).abs() < 1e-4, "converges to the steady sample");
        assert_eq!(e.fold(2.0, 2.0), 2.0, "fixed point");
    }

    #[test]
    fn normalize_mean_one_works() {
        let mut v = vec![2.0, 4.0, 6.0];
        normalize_mean_one(&mut v);
        assert!((v.iter().sum::<f64>() - 3.0).abs() < 1e-12);
        assert!((v[1] - 1.0).abs() < 1e-12);
        let mut z = vec![0.0, 0.0];
        normalize_mean_one(&mut z);
        assert_eq!(z, vec![0.0, 0.0], "degenerate input untouched");
    }

    #[test]
    fn rebalance_stats_untouched() {
        let mut s = RebalanceStats::default();
        assert!(s.is_untouched());
        s.blocks_moved += 1;
        assert!(!s.is_untouched());
    }

    #[test]
    fn hybrid_stats_untouched() {
        let mut s = HybridStats::default();
        assert!(s.is_untouched());
        s.cross_space_steals += 1;
        assert!(!s.is_untouched());
    }

    #[test]
    fn service_stats_untouched_and_merge() {
        let mut s = ServiceStats::default();
        assert!(s.is_untouched());
        s.batched_launches += 1;
        s.launches_saved += 3;
        assert!(!s.is_untouched());
        let mut t = ServiceStats { sessions_live: 2, ..Default::default() };
        t.merge(&s);
        assert_eq!(t.sessions_live, 2);
        assert_eq!(t.launches_saved, 3);
    }

    #[test]
    fn zcps_math() {
        let mut z = ZoneCycles::default();
        z.record_cycle(1000, 0.5);
        z.record_cycle(1000, 0.5);
        assert_eq!(z.zcps(), 2000.0);
        assert_eq!(z.cycles, 2);
        z.reset();
        assert_eq!(z.zcps(), 0.0);
    }
}
