//! Performance accounting: zone-cycles/s (the paper's headline metric),
//! per-region timers, and launch counts.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Wall-clock accumulator per named region.
#[derive(Debug, Default)]
pub struct Timers {
    acc: BTreeMap<String, Duration>,
    open: BTreeMap<String, Instant>,
}

impl Timers {
    pub fn start(&mut self, name: &str) {
        self.open.insert(name.to_string(), Instant::now());
    }

    pub fn stop(&mut self, name: &str) {
        if let Some(t0) = self.open.remove(name) {
            *self.acc.entry(name.to_string()).or_default() += t0.elapsed();
        }
    }

    pub fn seconds(&self, name: &str) -> f64 {
        self.acc.get(name).map(|d| d.as_secs_f64()).unwrap_or(0.0)
    }

    pub fn report(&self) -> Vec<(String, f64)> {
        self.acc.iter().map(|(k, v)| (k.clone(), v.as_secs_f64())).collect()
    }
}

/// Throughput accounting over a measured window.
#[derive(Debug, Clone, Default)]
pub struct ZoneCycles {
    pub zones_updated: u64,
    pub cycles: u64,
    pub wall_secs: f64,
}

impl ZoneCycles {
    pub fn record_cycle(&mut self, zones: u64, secs: f64) {
        self.zones_updated += zones;
        self.cycles += 1;
        self.wall_secs += secs;
    }

    /// zone-cycles per second (the paper's unit).
    pub fn zcps(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.zones_updated as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    pub fn reset(&mut self) {
        *self = ZoneCycles::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_accumulates() {
        let mut t = Timers::default();
        t.start("a");
        std::thread::sleep(Duration::from_millis(5));
        t.stop("a");
        t.start("a");
        t.stop("a");
        assert!(t.seconds("a") >= 0.005);
        assert_eq!(t.seconds("missing"), 0.0);
    }

    #[test]
    fn zcps_math() {
        let mut z = ZoneCycles::default();
        z.record_cycle(1000, 0.5);
        z.record_cycle(1000, 0.5);
        assert_eq!(z.zcps(), 2000.0);
        assert_eq!(z.cycles, 2);
        z.reset();
        assert_eq!(z.zcps(), 0.0);
    }
}
