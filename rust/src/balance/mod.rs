//! Load balancing (paper Sec. 3.8): blocks ordered by the tree's Z-order
//! (Morton) are split into contiguous, cost-balanced rank segments. Costs
//! are the measured per-block EWMA weights ([`crate::mesh::MeshBlock::cost`],
//! fed by the host stage timings in `metrics::Ewma`), mapped onto a new
//! tree by [`derive_leaf_costs`].

use std::collections::HashMap;

use crate::mesh::LogicalLocation;

/// Assign each block (in Z-order) to a rank by contiguous cost partition.
///
/// For equal costs this reduces to near-equal counts (within one block);
/// the greedy prefix split keeps segments contiguous in Morton order, which
/// preserves locality — the property the paper relies on for scalable
/// boundary communication.
pub fn assign_blocks(costs: &[f64], nranks: usize) -> Vec<usize> {
    assert!(nranks > 0);
    let n = costs.len();
    let total: f64 = costs.iter().sum();
    let mut out = vec![0usize; n];
    if n == 0 {
        return out;
    }
    let target = (total / nranks as f64).max(f64::MIN_POSITIVE);
    let mut cum = 0.0;
    let mut prev = 0usize;
    for (i, &c) in costs.iter().enumerate() {
        // rank whose cost interval contains this block's midpoint
        let mid = cum + 0.5 * c;
        let mut r = ((mid / target) as usize).min(nranks - 1);
        if n >= nranks {
            // never give a rank its first block too early (r <= i) and
            // never starve trailing ranks (enough blocks must remain)
            r = r.min(i);
            r = r.max(nranks.saturating_sub(n - i));
        }
        r = r.max(prev); // contiguity: non-decreasing in Z-order
        out[i] = r;
        prev = r;
        cum += c;
    }
    out
}

/// Summary statistics of an assignment (used by tests and the CLI).
pub fn assignment_counts(assign: &[usize], nranks: usize) -> Vec<usize> {
    let mut counts = vec![0usize; nranks];
    for &r in assign {
        counts[r] += 1;
    }
    counts
}

/// Per-leaf costs for a (possibly new) leaf set from a map of measured
/// block costs keyed by location: an unchanged leaf keeps its measured
/// cost, a refined child inherits its parent's cost (hot regions stay
/// hot), a derefined parent takes the mean of its measured children, and
/// anything unknown falls back to the nominal 1.0.
pub fn derive_leaf_costs(
    leaves: &[LogicalLocation],
    known: &HashMap<LogicalLocation, f64>,
    dim: usize,
) -> Vec<f64> {
    leaves
        .iter()
        .map(|loc| {
            if let Some(c) = known.get(loc) {
                return *c;
            }
            if loc.level > 0 {
                if let Some(c) = known.get(&loc.parent()) {
                    return *c;
                }
            }
            let vals: Vec<f64> = loc
                .children(dim)
                .iter()
                .filter_map(|ch| known.get(ch).copied())
                .collect();
            if vals.is_empty() {
                1.0
            } else {
                vals.iter().sum::<f64>() / vals.len() as f64
            }
        })
        .collect()
}

/// The migration plan between two assignments of the *same* block list:
/// (gid, from_rank, to_rank) for every block that moves.
pub fn migration_plan(old: &[usize], new: &[usize]) -> Vec<(usize, usize, usize)> {
    debug_assert_eq!(old.len(), new.len());
    old.iter()
        .zip(new.iter())
        .enumerate()
        .filter(|(_, (a, b))| a != b)
        .map(|(gid, (&a, &b))| (gid, a, b))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;
    use crate::util::testutil::check;

    #[test]
    fn equal_costs_near_equal_counts() {
        for (n, r) in [(8, 2), (7, 3), (100, 7), (5, 5), (3, 8)] {
            let costs = vec![1.0; n];
            let a = assign_blocks(&costs, r);
            let counts = assignment_counts(&a, r);
            let max = *counts.iter().max().unwrap();
            let min = *counts.iter().min().unwrap();
            assert!(
                max - min <= 1 || n < r,
                "n={n} r={r} counts={counts:?}"
            );
        }
    }

    #[test]
    fn assignment_is_monotone_contiguous() {
        check("contiguous", 50, |rng: &mut XorShift| {
            let n = 1 + rng.below(200);
            let r = 1 + rng.below(16);
            let costs: Vec<f64> = (0..n).map(|_| 0.5 + rng.next_f64()).collect();
            let a = assign_blocks(&costs, r);
            for w in a.windows(2) {
                assert!(w[1] >= w[0], "ranks must be non-decreasing in Z-order");
            }
            assert!(*a.last().unwrap() < r);
        });
    }

    #[test]
    fn every_block_assigned_exactly_once() {
        check("coverage", 30, |rng: &mut XorShift| {
            let n = 1 + rng.below(64);
            let r = 1 + rng.below(8);
            let costs: Vec<f64> = (0..n).map(|_| 1.0 + rng.next_f64()).collect();
            let a = assign_blocks(&costs, r);
            assert_eq!(a.len(), n);
        });
    }

    #[test]
    fn weighted_split_tracks_cost() {
        // one hot block: it should get its own rank when costs dominate
        let mut costs = vec![1.0; 10];
        costs[0] = 100.0;
        let a = assign_blocks(&costs, 2);
        assert_eq!(a[0], 0);
        assert!(a[1..].iter().all(|&r| r == 1), "{a:?}");
    }

    #[test]
    fn derive_leaf_costs_inherits_across_levels() {
        use crate::mesh::LogicalLocation;
        let mut known = HashMap::new();
        let kept = LogicalLocation::new(0, 0, 0, 0);
        let hot_parent = LogicalLocation::new(0, 1, 0, 0);
        known.insert(kept, 2.0);
        known.insert(hot_parent, 4.0);
        // children of a coarse leaf that will be derefined
        let dpar = LogicalLocation::new(0, 1, 1, 0);
        for (ci, ch) in dpar.children(2).into_iter().enumerate() {
            known.insert(ch, (ci + 1) as f64); // mean = 2.5
        }
        let leaves = vec![
            kept,                                // unchanged -> 2.0
            hot_parent.children(2)[0],           // refined -> parent's 4.0
            dpar,                                // derefined -> mean 2.5
            LogicalLocation::new(0, 0, 1, 0),    // unknown -> 1.0
        ];
        let costs = derive_leaf_costs(&leaves, &known, 2);
        assert_eq!(costs, vec![2.0, 4.0, 2.5, 1.0]);
    }

    #[test]
    fn migration_plan_diffs() {
        let old = vec![0, 0, 1, 1];
        let new = vec![0, 1, 1, 1];
        assert_eq!(migration_plan(&old, &new), vec![(1, 0, 1)]);
    }
}
