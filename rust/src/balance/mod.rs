//! Load balancing (paper Sec. 3.8): blocks ordered by the tree's Z-order
//! (Morton) are split into contiguous, cost-balanced rank segments. Costs
//! are the measured per-block EWMA weights ([`crate::mesh::MeshBlock::cost`],
//! fed by the host stage timings in `metrics::Ewma`), mapped onto a new
//! tree by [`derive_leaf_costs`].

use std::collections::HashMap;

use crate::mesh::LogicalLocation;

/// Assign each block (in Z-order) to a rank by contiguous cost partition.
///
/// For equal costs this reduces to near-equal counts (within one block);
/// the greedy prefix split keeps segments contiguous in Morton order, which
/// preserves locality — the property the paper relies on for scalable
/// boundary communication.
pub fn assign_blocks(costs: &[f64], nranks: usize) -> Vec<usize> {
    assert!(nranks > 0);
    let n = costs.len();
    let total: f64 = costs.iter().sum();
    let mut out = vec![0usize; n];
    if n == 0 {
        return out;
    }
    let target = (total / nranks as f64).max(f64::MIN_POSITIVE);
    let mut cum = 0.0;
    let mut prev = 0usize;
    for (i, &c) in costs.iter().enumerate() {
        // rank whose cost interval contains this block's midpoint
        let mid = cum + 0.5 * c;
        let mut r = ((mid / target) as usize).min(nranks - 1);
        if n >= nranks {
            // never give a rank its first block too early (r <= i) and
            // never starve trailing ranks (enough blocks must remain)
            r = r.min(i);
            r = r.max(nranks.saturating_sub(n - i));
        }
        r = r.max(prev); // contiguity: non-decreasing in Z-order
        out[i] = r;
        prev = r;
        cum += c;
    }
    out
}

/// Summary statistics of an assignment (used by tests and the CLI).
pub fn assignment_counts(assign: &[usize], nranks: usize) -> Vec<usize> {
    let mut counts = vec![0usize; nranks];
    for &r in assign {
        counts[r] += 1;
    }
    counts
}

/// Per-leaf costs for a (possibly new) leaf set from a map of measured
/// block costs keyed by location: an unchanged leaf keeps its measured
/// cost, a refined child inherits its parent's cost (hot regions stay
/// hot), a derefined parent takes the mean of its measured children, and
/// anything unknown falls back to the nominal 1.0.
pub fn derive_leaf_costs(
    leaves: &[LogicalLocation],
    known: &HashMap<LogicalLocation, f64>,
    dim: usize,
) -> Vec<f64> {
    leaves
        .iter()
        .map(|loc| {
            if let Some(c) = known.get(loc) {
                return *c;
            }
            if loc.level > 0 {
                if let Some(c) = known.get(&loc.parent()) {
                    return *c;
                }
            }
            let vals: Vec<f64> = loc
                .children(dim)
                .iter()
                .filter_map(|ch| known.get(ch).copied())
                .collect();
            if vals.is_empty() {
                1.0
            } else {
                vals.iter().sum::<f64>() / vals.len() as f64
            }
        })
        .collect()
}

/// One block changing owner in a fixed-tree rebalance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockMove {
    pub gid: usize,
    pub from: usize,
    pub to: usize,
}

/// The delta between two assignments of the *same* block list: exactly the
/// blocks that change owner, in gid order. This is the unit the incremental
/// rebalance operates on — everything NOT in the plan keeps its container,
/// staging and routing untouched. Every rank derives the identical plan
/// from the shared assignment tables (no communication).
#[derive(Debug, Clone, Default)]
pub struct MigrationPlan {
    pub moves: Vec<BlockMove>,
}

impl MigrationPlan {
    pub fn between(old: &[usize], new: &[usize]) -> MigrationPlan {
        debug_assert_eq!(old.len(), new.len(), "same-tree assignment diff");
        MigrationPlan {
            moves: old
                .iter()
                .zip(new.iter())
                .enumerate()
                .filter(|(_, (a, b))| a != b)
                .map(|(gid, (&from, &to))| BlockMove { gid, from, to })
                .collect(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.moves.is_empty()
    }

    /// Global blocks changing owner.
    pub fn len(&self) -> usize {
        self.moves.len()
    }

    /// Moves leaving `rank` (this rank's point-to-point sends).
    pub fn leaving(&self, rank: usize) -> impl Iterator<Item = &BlockMove> {
        self.moves.iter().filter(move |m| m.from == rank)
    }

    /// Moves arriving at `rank` (this rank's point-to-point receives).
    pub fn arriving(&self, rank: usize) -> impl Iterator<Item = &BlockMove> {
        self.moves.iter().filter(move |m| m.to == rank)
    }

    /// Gids of every block changing owner (any rank) — the ghost-refresh
    /// target set of the incremental rebalance.
    pub fn moved_gids(&self) -> impl Iterator<Item = usize> + '_ {
        self.moves.iter().map(|m| m.gid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;
    use crate::util::testutil::check;

    #[test]
    fn equal_costs_near_equal_counts() {
        for (n, r) in [(8, 2), (7, 3), (100, 7), (5, 5), (3, 8)] {
            let costs = vec![1.0; n];
            let a = assign_blocks(&costs, r);
            let counts = assignment_counts(&a, r);
            let max = *counts.iter().max().unwrap();
            let min = *counts.iter().min().unwrap();
            assert!(
                max - min <= 1 || n < r,
                "n={n} r={r} counts={counts:?}"
            );
        }
    }

    #[test]
    fn assignment_is_monotone_contiguous() {
        check("contiguous", 50, |rng: &mut XorShift| {
            let n = 1 + rng.below(200);
            let r = 1 + rng.below(16);
            let costs: Vec<f64> = (0..n).map(|_| 0.5 + rng.next_f64()).collect();
            let a = assign_blocks(&costs, r);
            for w in a.windows(2) {
                assert!(w[1] >= w[0], "ranks must be non-decreasing in Z-order");
            }
            assert!(*a.last().unwrap() < r);
        });
    }

    #[test]
    fn every_block_assigned_exactly_once() {
        check("coverage", 30, |rng: &mut XorShift| {
            let n = 1 + rng.below(64);
            let r = 1 + rng.below(8);
            let costs: Vec<f64> = (0..n).map(|_| 1.0 + rng.next_f64()).collect();
            let a = assign_blocks(&costs, r);
            assert_eq!(a.len(), n);
        });
    }

    #[test]
    fn weighted_split_tracks_cost() {
        // one hot block: it should get its own rank when costs dominate
        let mut costs = vec![1.0; 10];
        costs[0] = 100.0;
        let a = assign_blocks(&costs, 2);
        assert_eq!(a[0], 0);
        assert!(a[1..].iter().all(|&r| r == 1), "{a:?}");
    }

    #[test]
    fn derive_leaf_costs_inherits_across_levels() {
        use crate::mesh::LogicalLocation;
        let mut known = HashMap::new();
        let kept = LogicalLocation::new(0, 0, 0, 0);
        let hot_parent = LogicalLocation::new(0, 1, 0, 0);
        known.insert(kept, 2.0);
        known.insert(hot_parent, 4.0);
        // children of a coarse leaf that will be derefined
        let dpar = LogicalLocation::new(0, 1, 1, 0);
        for (ci, ch) in dpar.children(2).into_iter().enumerate() {
            known.insert(ch, (ci + 1) as f64); // mean = 2.5
        }
        let leaves = vec![
            kept,                                // unchanged -> 2.0
            hot_parent.children(2)[0],           // refined -> parent's 4.0
            dpar,                                // derefined -> mean 2.5
            LogicalLocation::new(0, 0, 1, 0),    // unknown -> 1.0
        ];
        let costs = derive_leaf_costs(&leaves, &known, 2);
        assert_eq!(costs, vec![2.0, 4.0, 2.5, 1.0]);
    }

    #[test]
    fn migration_plan_diffs() {
        let old = vec![0, 0, 1, 1];
        let new = vec![0, 1, 1, 1];
        assert_eq!(
            MigrationPlan::between(&old, &new).moves,
            vec![BlockMove { gid: 1, from: 0, to: 1 }]
        );
    }

    #[test]
    fn migration_plan_views() {
        let old = vec![0, 0, 1, 1, 2, 2];
        let new = vec![0, 1, 1, 2, 2, 0];
        let plan = MigrationPlan::between(&old, &new);
        assert_eq!(plan.len(), 3);
        assert!(!plan.is_empty());
        assert_eq!(
            plan.moves,
            vec![
                BlockMove { gid: 1, from: 0, to: 1 },
                BlockMove { gid: 3, from: 1, to: 2 },
                BlockMove { gid: 5, from: 2, to: 0 },
            ]
        );
        assert_eq!(plan.leaving(0).count(), 1);
        assert_eq!(plan.arriving(0).map(|m| m.gid).collect::<Vec<_>>(), vec![5]);
        assert_eq!(plan.moved_gids().collect::<Vec<_>>(), vec![1, 3, 5]);
        assert!(MigrationPlan::between(&old, &old).is_empty());
    }
}
