//! Host execution space: the native Rust solver run pack-parallel.
//!
//! The stage operates per MeshBlockPack ([`crate::mesh_data::MeshData`]):
//! packs are the work items of a cost-aware work-stealing pool
//! ([`crate::util::stealing::StealPool`]). Worker deques are seeded by the
//! cost-weighted contiguous partition (per-pack costs = summed
//! `MeshBlock::cost` EWMAs), and a worker whose deque drains steals packs
//! from the heaviest victim — closing the tail that static range-dealing
//! leaves on multilevel meshes with uneven per-block cost. With
//! `parthenon/exec sched = static` the pool never steals and degenerates
//! to the cost-weighted static schedule.
//!
//! Every pack owns a disjoint `&mut` chunk of the per-block work arrays
//! (fluxes, u0, u_new), and reconstruction scratch is bounded by the
//! worker count, so no locking happens inside the kernels and results are
//! bitwise independent of worker count and steal order. Per-block kernel
//! seconds are measured here and folded into `MeshBlock::cost` by
//! `HydroSim::update_block_costs` (EWMA) — the measured costs feed both
//! the next cycle's seed partition and the load balancer.
//!
//! Two stage schedules share the kernels (`parthenon/exec overlap`):
//!
//! * **`fused`** (default) — phases 1–4 are ONE per-pack task list run by
//!   [`crate::tasks::TaskRegion::execute_parallel`] on the steal pool:
//!   fluxes → flux-correction send/poll → stage combine → post boundary
//!   sends, then receives are polled as `Incomplete` tasks. Pack A's
//!   boundary exchange overlaps pack B's compute instead of waiting at a
//!   phase barrier — the paper's comm/compute overlap at task granularity.
//! * **`phased`** — the barrier-phased loop (all fluxes, then flux
//!   correction on the driver thread, then all combines, then the
//!   exchange). Kept as the bitwise-identity oracle; both schedules
//!   produce identical results because every per-block computation reads
//!   exactly the same inputs (pinned by `rust/tests/overlap_fused.rs`).

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::{run_stage_exchange, OverlapMode, StageExecutor};
use crate::bvals::{self, ExchTopo, PackExchange};
use crate::comm::{CollHandle, CollMode, Comm, ReduceOp};
use crate::error::{Error, Result};
use crate::hydro::native::{self, FluxArrays, Scratch, StageCoeffs};
use crate::hydro::{HydroPackage, CONS};
use crate::mesh::{IndexShape, MeshBlock};
use crate::tasks::{TaskRegion, TaskStatus, NONE};
use crate::util::stealing::{run_stealing, StealPolicy, StealPool};
use crate::vars::Package;
use crate::{Real, NHYDRO};

/// Instrumentation counters for the fused overlap pipeline (cumulative
/// over stages/cycles). `early_poll_violations` pins the overlap contract:
/// a pack's exchange sends must be posted before its poll task first
/// returns `Incomplete` — the task graph orders post-sends before the
/// poll, so this must stay 0.
#[derive(Debug, Default)]
pub struct OverlapStats {
    /// Per-pack send tasks that ran (sends posted + receives registered).
    pub packs_posted: AtomicU64,
    /// Boundary segments posted by fused send tasks.
    pub segments_sent: AtomicU64,
    /// Times a fused poll task returned `Incomplete` (receives pending
    /// while other packs keep computing — the overlap actually engaging).
    pub incomplete_polls: AtomicU64,
    /// Poll returned `Incomplete` before the pack's sends were posted.
    pub early_poll_violations: AtomicU64,
}

/// Bounded scratch store for the fused pipeline: at most `nworkers` flux
/// tasks run concurrently, so a stack of `nworkers` scratches serves every
/// pack without per-pack allocations (the fused analog of the phased
/// path's one-scratch-per-worker array).
struct ScratchPool {
    stack: Mutex<Vec<Scratch>>,
}

impl ScratchPool {
    fn new(scratches: Vec<Scratch>) -> ScratchPool {
        ScratchPool { stack: Mutex::new(scratches) }
    }

    fn with<R>(&self, f: impl FnOnce(&mut Scratch) -> R) -> R {
        let mut s = self.stack.lock().unwrap().pop().unwrap_or_default();
        let r = f(&mut s);
        self.stack.lock().unwrap().push(s);
        r
    }

    fn into_inner(self) -> Vec<Scratch> {
        self.stack.into_inner().unwrap()
    }
}

/// Per-rank host executor state: per-block work arrays (same order as
/// `mesh.blocks`) plus one scratch per worker thread.
pub struct HostExec {
    flux: Vec<FluxArrays>,
    u0: Vec<Vec<Real>>,
    unew: Vec<Vec<Real>>,
    scratch: Vec<Scratch>,
    /// Measured kernel seconds per block, accumulated over the cycle's
    /// stages and drained by `HydroSim::update_block_costs`.
    block_secs: Vec<f64>,
    nworkers: usize,
    /// Requested worker count (`parthenon/exec nworkers`, 0 = auto) —
    /// kept so [`HostExec::resize`] re-resolves `nworkers` against a new
    /// pack count exactly like a fresh build.
    nworkers_req: usize,
    /// Ranks sharing this machine's cores (auto worker sizing).
    nranks: usize,
    policy: StealPolicy,
    overlap_stats: OverlapStats,
    /// Local raw CFL dt cached by the fused pipeline's regional reduction
    /// on the final RK stage (the per-pack partial minima folded
    /// cross-list inside the stage region) — so `local_dt` needs no
    /// separate sweep over the blocks in fused mode. `None` until the
    /// first fused cycle completes (and after every rebuild: regrid /
    /// rebalance / restart recreate the executor).
    fused_dt: Option<f64>,
    /// GLOBAL (cross-rank) dt produced by the overlapped collective the
    /// fused final stage posted from inside its task region (tree
    /// collectives only). Taken — consumed once — by
    /// `HydroSim::reduce_dt`, which then skips its blocking allreduce
    /// entirely.
    fused_dt_global: Option<f64>,
}

impl HostExec {
    pub fn new(
        shape: &IndexShape,
        nblocks: usize,
        npacks: usize,
        ranks_sharing: usize,
        nworkers_req: usize,
        policy: StealPolicy,
    ) -> HostExec {
        let nelem = NHYDRO * shape.ncells_total();
        let cap = npacks.max(1);
        let nworkers = if nworkers_req > 0 {
            nworkers_req.min(cap)
        } else {
            crate::util::num_workers(cap, ranks_sharing)
        };
        HostExec {
            flux: (0..nblocks).map(|_| FluxArrays::new(shape)).collect(),
            u0: (0..nblocks).map(|_| vec![0.0; nelem]).collect(),
            unew: (0..nblocks).map(|_| vec![0.0; nelem]).collect(),
            scratch: (0..nworkers).map(|_| Scratch::default()).collect(),
            block_secs: vec![0.0; nblocks],
            nworkers,
            nworkers_req,
            nranks: ranks_sharing,
            policy,
            overlap_stats: OverlapStats::default(),
            fused_dt: None,
            fused_dt_global: None,
        }
    }

    /// Resize the per-block work arrays in place after an incremental
    /// rebalance: allocations for surviving blocks are reused (the arrays
    /// are per-cycle scratch, so contents never carry over anyway), the
    /// worker count is re-resolved against the new pack count exactly like
    /// [`HostExec::new`] would, timing accumulators are zeroed and the
    /// cached fused dt is dropped — leaving the executor in the same state
    /// a fresh build produces, minus the allocations.
    pub fn resize(&mut self, shape: &IndexShape, nblocks: usize, npacks: usize) {
        let nelem = NHYDRO * shape.ncells_total();
        let cap = npacks.max(1);
        self.nworkers = if self.nworkers_req > 0 {
            self.nworkers_req.min(cap)
        } else {
            crate::util::num_workers(cap, self.nranks)
        };
        self.flux.truncate(nblocks);
        while self.flux.len() < nblocks {
            self.flux.push(FluxArrays::new(shape));
        }
        self.u0.resize_with(nblocks, || vec![0.0; nelem]);
        self.unew.resize_with(nblocks, || vec![0.0; nelem]);
        self.scratch.resize_with(self.nworkers, Scratch::default);
        self.block_secs.clear();
        self.block_secs.resize(nblocks, 0.0);
        self.overlap_stats = OverlapStats::default();
        self.fused_dt = None;
        self.fused_dt_global = None;
    }

    /// Consume the overlapped global dt (fused final stage, tree
    /// collectives). `None` when the blocking reduction must run instead.
    pub fn take_global_dt(&mut self) -> Option<f64> {
        self.fused_dt_global.take()
    }

    pub fn nworkers(&self) -> usize {
        self.nworkers
    }

    pub fn policy(&self) -> StealPolicy {
        self.policy
    }

    /// Block `bi`'s flux arrays (flux-correction tests).
    pub fn flux(&self, bi: usize) -> &FluxArrays {
        &self.flux[bi]
    }

    /// Fused-pipeline instrumentation (exchange overlap counters).
    pub fn overlap_stats(&self) -> &OverlapStats {
        &self.overlap_stats
    }

    /// Take (and zero) the per-block kernel seconds measured since the
    /// last drain.
    pub fn drain_block_secs(&mut self) -> Vec<f64> {
        let out = self.block_secs.clone();
        for s in &mut self.block_secs {
            *s = 0.0;
        }
        out
    }
}

/// Split a per-block slice into per-pack chunks matching `ranges`
/// (contiguous ascending block ranges covering the slice).
fn split_chunks<'a, T>(
    mut rest: &'a mut [T],
    ranges: &[std::ops::Range<usize>],
) -> Vec<&'a mut [T]> {
    let mut parts = Vec::with_capacity(ranges.len());
    for r in ranges {
        let (head, tail) = rest.split_at_mut(r.len());
        parts.push(head);
        rest = tail;
    }
    parts
}

/// Shared slot of the overlapped dt collective (fused final stage, tree
/// collectives): the posting task folds the per-pack minima, posts the
/// `iallreduce(Min)` on the driver's collective communicator, and parks
/// the handle here; the draining task polls it to completion while other
/// lists' boundary polls keep running on the same worker pool.
struct DtCollSlot<'a> {
    /// `Some` only when the overlapped reduction is active this stage.
    comm: Option<&'a Comm>,
    handle: Mutex<Option<CollHandle>>,
    /// Global dt bits, stored when the handle completes.
    global: AtomicU64,
}

/// Per-pack context of the fused stage pipeline: one task list per pack
/// runs fluxes → flux-correction → combine → boundary sends → receive
/// polls against this context, which owns a disjoint `&mut` slice of every
/// per-block structure (blocks, fluxes, u_new, timings) plus shared
/// read-only views (topology, u0, scratch pool) — the whole context is
/// `Send`, so its list can be swept by any worker while other packs' lists
/// run concurrently.
struct FusedPackCtx<'a> {
    /// Global index of the pack's first block (u0 is indexed globally).
    start: usize,
    /// Pack index (slot in the regional dt reduction's `minima`).
    pi: usize,
    blocks: &'a mut [MeshBlock],
    flux: &'a mut [FluxArrays],
    unew: &'a mut [Vec<Real>],
    secs: &'a mut [f64],
    u0: &'a [Vec<Real>],
    /// Flux corrections this pack's coarse blocks expect (indices are
    /// global; polled against the pack's flux slice via `start`).
    fpending: Vec<super::FluxRecv>,
    /// Send/receive halves of the pack's ghost exchange; also the single
    /// owner of the shared topology (`PackExchange::topo`).
    exch: PackExchange<'a>,
    fcomm: &'a Comm,
    scratch: &'a ScratchPool,
    stats: &'a OverlapStats,
    /// Package view for the fused dt reduction (`estimate_dt` reads
    /// interior cells only, so it can run right after the combine).
    pkg: &'a HydroPackage,
    /// Per-pack partial CFL minima of the fused dt reduction (one slot
    /// per pack, f64 bit patterns; min is exact, so the regional fold is
    /// bitwise equal to the phased path's block-order sweep).
    minima: &'a [AtomicU64],
    /// Result slot written by the regional cross-list fold.
    dt_result: &'a AtomicU64,
    /// Count of per-pack dt tasks that have stored their minimum — the
    /// overlapped collective posts once this reaches the pack count.
    dt_done: &'a AtomicUsize,
    /// The in-flight global dt collective (see [`DtCollSlot`]).
    coll: &'a DtCollSlot<'a>,
    shape: IndexShape,
    gamma: Real,
    co: StageCoeffs,
    dt: Real,
    error: Option<Error>,
    /// Shared across packs: first error drains every list fast.
    abort: &'a AtomicBool,
}

impl HostExec {
    /// The fused stage: phases 1–4 as ONE per-pack task list executed on
    /// the work-stealing pool, so boundary exchange of one pack overlaps
    /// compute of the others. Bitwise identical to the phased path: every
    /// per-block kernel reads exactly the inputs it reads there (fluxes
    /// from its own block, corrections complete before its combine,
    /// ghost segments written to disjoint slabs), and physical BCs are
    /// applied at the same point, after every receive has landed.
    fn stage_fused(
        &mut self,
        sim: &mut super::HydroSim,
        co: StageCoeffs,
        si: usize,
        dt: Real,
    ) -> Result<()> {
        sim.mesh_data.validate(&sim.mesh)?;
        let shape = sim.mesh.cfg.index_shape();
        let gamma = sim.pkg.gamma;
        let stall = sim.world.stall_limit();
        let multilevel = sim.is_multilevel();
        let pack_ranges = sim.mesh_data.block_ranges();
        let mut pack_costs = sim.mesh_data.pack_costs(&sim.mesh);
        let npacks = pack_ranges.len();
        let nworkers = self.nworkers;
        let policy = self.policy;
        // The fused dt reduction runs on the final RK stage only: t_dt
        // partial minima per pack + one regional cross-list fold.
        let final_stage = si + 1 == native::RK2_STAGES.len();
        // With tree collectives the GLOBAL dt reduction also runs inside
        // the region: an extra task list folds the per-pack minima as soon
        // as the last t_dt lands, posts the iallreduce(Min), and polls the
        // handle — overlapping the cross-rank exchange with the tail
        // packs' boundary-receive polls. Flat mode keeps the blocking
        // post-region allreduce as the oracle.
        let overlap_coll = final_stage && sim.sp.coll == CollMode::Tree;
        // Reduction slots exist only on the final stage (empty slice
        // otherwise — no t_dt task ever reads it).
        let minima: Vec<AtomicU64> = if final_stage {
            (0..npacks).map(|_| AtomicU64::new(f64::INFINITY.to_bits())).collect()
        } else {
            Vec::new()
        };
        let dt_result = AtomicU64::new(f64::INFINITY.to_bits());
        let dt_done = AtomicUsize::new(0);
        let coll_slot = DtCollSlot {
            comm: if overlap_coll && npacks > 0 { Some(&sim.comm_coll) } else { None },
            handle: Mutex::new(None),
            global: AtomicU64::new(f64::INFINITY.to_bits()),
        };

        // Scratch moves into a bounded pool (≤ nworkers concurrent flux
        // tasks) and is restored below, also on error paths.
        let scratch_pool = ScratchPool::new(std::mem::take(&mut self.scratch));
        let mut first_error: Option<Error> = None;
        {
            let stats = &self.overlap_stats;
            let flux_parts = split_chunks(&mut self.flux, &pack_ranges);
            let unew_parts = split_chunks(&mut self.unew, &pack_ranges);
            let secs_parts = split_chunks(&mut self.block_secs, &pack_ranges);
            let u0_all: &[Vec<Real>] = &self.u0;

            let mesh = &mut sim.mesh;
            let topo = ExchTopo {
                shape,
                dim: mesh.cfg.dim,
                tree: &mesh.tree,
                ranks: &mesh.ranks,
            };
            // Flux corrections are registered per pack up front (reads the
            // immutable topology), before the blocks split into disjoint
            // per-pack slices.
            let fpend: Vec<Vec<super::FluxRecv>> = if multilevel {
                pack_ranges
                    .iter()
                    .map(|r| {
                        super::flux_corr_pending_blocks(
                            &topo,
                            &mesh.blocks[r.clone()],
                            r.start,
                        )
                    })
                    .collect()
            } else {
                (0..npacks).map(|_| Vec::new()).collect()
            };
            let block_parts = split_chunks(&mut mesh.blocks, &pack_ranges);
            let comm = &sim.comm_cons;
            let fcomm = &sim.comm_flux;
            let abort = AtomicBool::new(false);

            let mut ctxs: Vec<FusedPackCtx> = Vec::with_capacity(npacks);
            for (pi, ((((range, blocks), flux), (unew, secs)), fpending)) in pack_ranges
                .iter()
                .zip(block_parts)
                .zip(flux_parts)
                .zip(unew_parts.into_iter().zip(secs_parts))
                .zip(fpend)
                .enumerate()
            {
                ctxs.push(FusedPackCtx {
                    start: range.start,
                    pi,
                    blocks,
                    flux,
                    unew,
                    secs,
                    u0: u0_all,
                    fpending,
                    exch: PackExchange::new(topo, comm, CONS),
                    fcomm,
                    scratch: &scratch_pool,
                    stats,
                    pkg: &sim.pkg,
                    minima: &minima,
                    dt_result: &dt_result,
                    dt_done: &dt_done,
                    coll: &coll_slot,
                    shape,
                    gamma,
                    co,
                    dt,
                    error: None,
                    abort: &abort,
                });
            }

            // The overlapped dt collective gets its own (cheap) task list
            // so its Incomplete polls interleave with every pack's
            // boundary polls on the worker pool — regional tasks only run
            // AFTER the pool drains, which would forfeit the overlap.
            let nlists = npacks + usize::from(overlap_coll && npacks > 0);
            let mut region: TaskRegion<FusedPackCtx> = TaskRegion::new(nlists);
            let mut dt_marks = Vec::new();
            for pi in 0..npacks {
                let list = region.list(pi);
                // 1. prim recovery + fluxes for the pack's blocks
                let t_flux = list.add(NONE, |c: &mut FusedPackCtx| {
                    if c.abort.load(Ordering::SeqCst) {
                        return TaskStatus::Complete;
                    }
                    let FusedPackCtx { blocks, flux, secs, scratch, shape, gamma, .. } =
                        c;
                    scratch.with(|scr| {
                        for (off, fx) in flux.iter_mut().enumerate() {
                            let t0 = Instant::now();
                            let arr = blocks[off].data.get(CONS).expect("cons");
                            native::compute_fluxes(
                                arr.as_slice(),
                                shape,
                                *gamma,
                                fx,
                                scr,
                            );
                            secs[off] += t0.elapsed().as_secs_f64();
                        }
                    });
                    TaskStatus::Complete
                });
                // 2. flux correction (multilevel): fine-side sends read the
                // computed fluxes; the coarse-side poll overwrites disjoint
                // face entries and gates the combine.
                let dep_apply = if multilevel {
                    let _t_fcsend = list.add(&[t_flux], |c: &mut FusedPackCtx| {
                        if c.abort.load(Ordering::SeqCst) {
                            return TaskStatus::Complete;
                        }
                        let FusedPackCtx { blocks, flux, exch, fcomm, .. } = c;
                        let topo = exch.topo();
                        for (off, b) in blocks.iter().enumerate() {
                            super::flux_corr_send_block(&topo, fcomm, &b.loc, &flux[off]);
                        }
                        TaskStatus::Complete
                    });
                    list.add(&[t_flux], |c: &mut FusedPackCtx| {
                        if c.abort.load(Ordering::SeqCst) {
                            return TaskStatus::Complete;
                        }
                        let FusedPackCtx {
                            flux, fpending, fcomm, start, exch, error, abort, ..
                        } = c;
                        match super::flux_corr_poll_pending(
                            fcomm,
                            exch.topo().dim,
                            fpending,
                            flux,
                            *start,
                        ) {
                            Ok(true) => TaskStatus::Complete,
                            Ok(false) => TaskStatus::Incomplete,
                            Err(e) => {
                                *error = Some(e);
                                abort.store(true, Ordering::SeqCst);
                                TaskStatus::Complete
                            }
                        }
                    })
                } else {
                    t_flux
                };
                // 3. stage combine (reads u0 globally, writes own blocks)
                let t_apply = list.add(&[dep_apply], |c: &mut FusedPackCtx| {
                    if c.abort.load(Ordering::SeqCst) {
                        return TaskStatus::Complete;
                    }
                    let FusedPackCtx {
                        blocks, flux, unew, secs, u0, start, shape, co, dt, ..
                    } = c;
                    for (off, b) in blocks.iter_mut().enumerate() {
                        let t0 = Instant::now();
                        let dx = [
                            b.coords.dx[0] as Real,
                            b.coords.dx[1] as Real,
                            b.coords.dx[2] as Real,
                        ];
                        let arr = b.data.get_mut(CONS).expect("cons");
                        native::apply_stage(
                            arr.as_slice(),
                            &u0[*start + off],
                            &flux[off],
                            shape,
                            *co,
                            *dt,
                            dx,
                            &mut unew[off],
                        );
                        arr.as_mut_slice().copy_from_slice(&unew[off]);
                        secs[off] += t0.elapsed().as_secs_f64();
                    }
                    TaskStatus::Complete
                });
                // 4a. post the pack's boundary sends + register receives
                let t_send = list.add(&[t_apply], |c: &mut FusedPackCtx| {
                    if c.abort.load(Ordering::SeqCst) {
                        return TaskStatus::Complete;
                    }
                    let FusedPackCtx { blocks, exch, stats, error, abort, .. } = c;
                    match exch.post_sends(blocks) {
                        Ok(()) => {
                            exch.register_receives(blocks);
                            stats.packs_posted.fetch_add(1, Ordering::Relaxed);
                            stats
                                .segments_sent
                                .fetch_add(exch.segments_sent() as u64, Ordering::Relaxed);
                        }
                        Err(e) => {
                            if error.is_none() {
                                *error = Some(e);
                            }
                            abort.store(true, Ordering::SeqCst);
                        }
                    }
                    TaskStatus::Complete
                });
                // 4b. poll receives; Incomplete hands the worker to other
                // packs' lists — this is where the overlap happens.
                let _t_poll = list.add(&[t_send], |c: &mut FusedPackCtx| {
                    if c.error.is_some() || c.abort.load(Ordering::SeqCst) {
                        return TaskStatus::Complete;
                    }
                    let FusedPackCtx { blocks, exch, stats, error, abort, .. } = c;
                    match exch.poll(blocks) {
                        Ok(true) => TaskStatus::Complete,
                        Ok(false) => {
                            stats.incomplete_polls.fetch_add(1, Ordering::Relaxed);
                            if !exch.sends_posted() {
                                stats
                                    .early_poll_violations
                                    .fetch_add(1, Ordering::Relaxed);
                            }
                            TaskStatus::Incomplete
                        }
                        Err(e) => {
                            *error = Some(e);
                            abort.store(true, Ordering::SeqCst);
                            TaskStatus::Complete
                        }
                    }
                });
                // 5. (final stage) per-pack partial CFL min — reads the
                // combined interior state written by t_apply, so it rides
                // the same list without waiting on the ghost exchange.
                if final_stage {
                    let t_dt = list.add(&[t_apply], |c: &mut FusedPackCtx| {
                        if c.abort.load(Ordering::SeqCst) {
                            return TaskStatus::Complete;
                        }
                        let mut m = f64::INFINITY;
                        for b in c.blocks.iter() {
                            m = m.min(c.pkg.estimate_dt(&b.data, &b.coords));
                        }
                        c.minima[c.pi].store(m.to_bits(), Ordering::SeqCst);
                        c.dt_done.fetch_add(1, Ordering::SeqCst);
                        TaskStatus::Complete
                    });
                    dt_marks.push((pi, t_dt));
                }
            }
            if overlap_coll && npacks > 0 {
                // Extra task list: fold the per-pack minima the moment the
                // last t_dt lands, post the global iallreduce(Min), then
                // poll the tree handle to completion. Both tasks return
                // Incomplete while waiting, so workers sweep back to the
                // packs' boundary polls in between — the global dt
                // reduction rides the same overlap the ghost exchange
                // uses.
                let list = region.list(npacks);
                let t_post = list.add(NONE, move |c: &mut FusedPackCtx| {
                    if c.abort.load(Ordering::SeqCst) {
                        return TaskStatus::Complete;
                    }
                    if c.dt_done.load(Ordering::SeqCst) < npacks {
                        return TaskStatus::Incomplete;
                    }
                    let mut m = f64::INFINITY;
                    for a in c.minima {
                        m = m.min(f64::from_bits(a.load(Ordering::SeqCst)));
                    }
                    c.dt_result.store(m.to_bits(), Ordering::SeqCst);
                    let comm = c.coll.comm.expect("overlap collective comm");
                    *c.coll.handle.lock().unwrap() =
                        Some(comm.iallreduce(m, ReduceOp::Min));
                    TaskStatus::Complete
                });
                let _t_drain = list.add(&[t_post], |c: &mut FusedPackCtx| {
                    if c.abort.load(Ordering::SeqCst) {
                        return TaskStatus::Complete;
                    }
                    let mut slot = c.coll.handle.lock().unwrap();
                    match slot.as_mut().map(CollHandle::test) {
                        Some(Ok(true)) => {
                            match slot.take().expect("handle present").into_f64() {
                                Ok(g) => {
                                    c.coll.global.store(g.to_bits(), Ordering::SeqCst);
                                }
                                Err(e) => {
                                    drop(slot);
                                    if c.error.is_none() {
                                        c.error = Some(e);
                                    }
                                    c.abort.store(true, Ordering::SeqCst);
                                }
                            }
                            TaskStatus::Complete
                        }
                        Some(Ok(false)) => TaskStatus::Incomplete,
                        Some(Err(e)) => {
                            *slot = None; // poisoned handle: drop it
                            drop(slot);
                            if c.error.is_none() {
                                c.error = Some(e);
                            }
                            c.abort.store(true, Ordering::SeqCst);
                            TaskStatus::Complete
                        }
                        // aborted before the post ran
                        None => TaskStatus::Complete,
                    }
                });
            } else if final_stage && npacks > 0 {
                // Flat oracle: regional cross-list fold under the same
                // abort-aware region (replaces the whole-rank local_dt
                // sweep that used to run after the cycle); the blocking
                // global allreduce stays in `reduce_dt`.
                region.add_regional(dt_marks, |c: &mut FusedPackCtx| {
                    let mut m = f64::INFINITY;
                    for a in c.minima {
                        m = m.min(f64::from_bits(a.load(Ordering::SeqCst)));
                    }
                    c.dt_result.store(m.to_bits(), Ordering::SeqCst);
                    TaskStatus::Complete
                });
            }
            if overlap_coll && npacks > 0 {
                // one context (and one seed-cost slot) per task list
                ctxs.push(FusedPackCtx {
                    start: 0,
                    pi: npacks,
                    blocks: &mut [],
                    flux: &mut [],
                    unew: &mut [],
                    secs: &mut [],
                    u0: u0_all,
                    fpending: Vec::new(),
                    exch: PackExchange::new(topo, comm, CONS),
                    fcomm,
                    scratch: &scratch_pool,
                    stats,
                    pkg: &sim.pkg,
                    minima: &minima,
                    dt_result: &dt_result,
                    dt_done: &dt_done,
                    coll: &coll_slot,
                    shape,
                    gamma,
                    co,
                    dt,
                    error: None,
                    abort: &abort,
                });
                pack_costs.push(0.0);
            }

            let res = region.execute_parallel_weighted(
                ctxs,
                Some(&pack_costs),
                nworkers,
                policy,
                stall,
            );
            match res {
                Ok(done) => {
                    for c in done {
                        if let Some(e) = c.error {
                            first_error = Some(e);
                            break;
                        }
                    }
                }
                Err(e) => first_error = Some(e),
            }
        }
        self.scratch = scratch_pool.into_inner();
        if let Some(e) = first_error {
            // A stalled task region is this rank's first sight of the
            // failure: escalate so every peer's waits drain with `Aborted`
            // instead of idling out their own watchdogs one by one.
            sim.world.escalate(sim.mesh.my_rank, &e);
            return Err(e);
        }
        if final_stage {
            // Local dt for this cycle, produced inside the region — the
            // post-cycle `local_dt` consults this instead of re-sweeping.
            self.fused_dt = Some(f64::from_bits(dt_result.load(Ordering::SeqCst)));
            if overlap_coll {
                // Every rank posts exactly one dt collective per cycle, so
                // a rank with zero packs (no task region to overlap with)
                // still joins the exchange — here, blocking, with an
                // identity contribution.
                let g = if npacks > 0 {
                    f64::from_bits(coll_slot.global.load(Ordering::SeqCst))
                } else {
                    sim.comm_coll
                        .iallreduce(f64::INFINITY, ReduceOp::Min)
                        .into_f64()?
                };
                self.fused_dt_global = Some(g);
            }
        }
        // Physical BCs once every receive has landed — the same point the
        // phased path applies them.
        bvals::apply_block_physical_bcs(
            &mut sim.mesh,
            CONS,
            Some([native::IM1, native::IM2, native::IM3]),
        )
    }
}

impl StageExecutor for HostExec {
    fn begin_cycle(&mut self, sim: &mut super::HydroSim) -> Result<()> {
        sim.mesh_data.validate(&sim.mesh)?;
        for (bi, b) in sim.mesh.blocks.iter().enumerate() {
            self.u0[bi].copy_from_slice(b.data.get(CONS)?.as_slice());
        }
        Ok(())
    }

    fn stage(
        &mut self,
        sim: &mut super::HydroSim,
        co: StageCoeffs,
        si: usize,
        dt: Real,
    ) -> Result<()> {
        if sim.sp.overlap == OverlapMode::Fused {
            return self.stage_fused(sim, co, si, dt);
        }
        sim.mesh_data.validate(&sim.mesh)?;
        let shape = sim.mesh.cfg.index_shape();
        let gamma = sim.pkg.gamma;
        let multilevel = sim.is_multilevel();
        if multilevel {
            sim.flux_corr_post_recvs();
        }
        // Packs are the unit of stealing; the seed partition is weighted
        // by the measured per-block costs.
        let pack_ranges = sim.mesh_data.block_ranges();
        let pack_costs = sim.mesh_data.pack_costs(&sim.mesh);

        // Phase 1 — fluxes, pack-stealing (reads block state, writes
        // disjoint per-pack flux chunks; each worker owns a scratch).
        {
            let blocks = &sim.mesh.blocks;
            let flux_parts = split_chunks(&mut self.flux, &pack_ranges);
            let secs_parts = split_chunks(&mut self.block_secs, &pack_ranges);
            let items: Vec<(usize, &mut [FluxArrays], &mut [f64])> = pack_ranges
                .iter()
                .zip(flux_parts.into_iter().zip(secs_parts))
                .map(|(r, (fx, sc))| (r.start, fx, sc))
                .collect();
            let pool = StealPool::seed(&pack_costs, self.nworkers, self.policy);
            run_stealing(
                &pool,
                items,
                &mut self.scratch,
                |scr: &mut Scratch, _pi, (start, flux_part, secs_part)| {
                    for (off, fx) in flux_part.iter_mut().enumerate() {
                        let t0 = Instant::now();
                        let arr = blocks[start + off].data.get(CONS).expect("cons");
                        native::compute_fluxes(arr.as_slice(), &shape, gamma, fx, scr);
                        secs_part[off] += t0.elapsed().as_secs_f64();
                    }
                },
            );
        }

        // Phase 2 — flux correction across fine/coarse faces (multilevel
        // only): communication-bound, driver thread, backoff while waiting.
        if multilevel {
            for bi in 0..sim.mesh.blocks.len() {
                sim.flux_corr_send(&self.flux[bi], bi);
            }
            sim.flux_corr_wait(&mut self.flux)?;
        }

        // Phase 3 — stage combine, pack-stealing (disjoint &mut blocks;
        // fluxes and u0 are read by global block index).
        {
            let flux = &self.flux;
            let u0 = &self.u0;
            let block_parts = split_chunks(&mut sim.mesh.blocks, &pack_ranges);
            let unew_parts = split_chunks(&mut self.unew, &pack_ranges);
            let secs_parts = split_chunks(&mut self.block_secs, &pack_ranges);
            let items: Vec<_> = pack_ranges
                .iter()
                .zip(block_parts)
                .zip(unew_parts.into_iter().zip(secs_parts))
                .map(|((r, bp), (up, sp))| (r.start, bp, up, sp))
                .collect();
            let pool = StealPool::seed(&pack_costs, self.nworkers, self.policy);
            run_stealing(
                &pool,
                items,
                &mut self.scratch,
                |_scr: &mut Scratch, _pi, (start, blocks_part, unew_part, secs_part)| {
                    for (off, b) in blocks_part.iter_mut().enumerate() {
                        let t0 = Instant::now();
                        let dx = [
                            b.coords.dx[0] as Real,
                            b.coords.dx[1] as Real,
                            b.coords.dx[2] as Real,
                        ];
                        let arr = b.data.get_mut(CONS).expect("cons");
                        native::apply_stage(
                            arr.as_slice(),
                            &u0[start + off],
                            &flux[start + off],
                            &shape,
                            co,
                            dt,
                            dx,
                            &mut unew_part[off],
                        );
                        arr.as_mut_slice().copy_from_slice(&unew_part[off]);
                        secs_part[off] += t0.elapsed().as_secs_f64();
                    }
                },
            );
        }

        // Phase 4 — ghost exchange as per-pack task lists, run on the same
        // worker-pool shape (parallel polling; serial under sched=static).
        run_stage_exchange(sim, self.nworkers, self.policy)
    }

    /// Local CFL dt. In fused mode this returns the value the stage
    /// region's regional dt reduction already produced (no extra sweep
    /// over the blocks); otherwise it's a parallel min-reduction of the
    /// per-block CFL estimates over the pack items, folded on the driver
    /// thread (f64 min is associative and commutative, so the result is
    /// order-independent — and bitwise equal to the fused reduction).
    fn local_dt(&self, sim: &super::HydroSim) -> f64 {
        let blocks = &sim.mesh.blocks;
        if blocks.is_empty() {
            return f64::INFINITY;
        }
        if sim.sp.overlap == OverlapMode::Fused {
            if let Some(v) = self.fused_dt {
                return v;
            }
        }
        let pkg = &sim.pkg;
        if !sim.mesh_data.is_current(&sim.mesh) || self.nworkers <= 1 {
            return blocks
                .iter()
                .map(|b| pkg.estimate_dt(&b.data, &b.coords))
                .fold(f64::INFINITY, f64::min);
        }
        let pack_ranges = sim.mesh_data.block_ranges();
        let pack_costs = sim.mesh_data.pack_costs(&sim.mesh);
        let pool = StealPool::seed(&pack_costs, self.nworkers, self.policy);
        let mut mins = vec![f64::INFINITY; pool.nworkers()];
        run_stealing(&pool, pack_ranges, &mut mins, |m, _pi, r| {
            for b in &blocks[r] {
                *m = m.min(pkg.estimate_dt(&b.data, &b.coords));
            }
        });
        mins.into_iter().fold(f64::INFINITY, f64::min)
    }
}
