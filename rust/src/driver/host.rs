//! Host execution space: the native Rust solver as a TASK-LIST PRODUCER.
//!
//! The stage operates per MeshBlockPack ([`crate::mesh_data::MeshData`]):
//! [`add_host_pack_list`] emits one task list per pack — fluxes →
//! flux-correction send/poll → stage combine → boundary sends → receive
//! polls (+ the per-pack dt partial on the final RK stage) — and the
//! driver's single merged [`crate::tasks::TaskRegion`]
//! ([`super::run_stage`]) executes those lists on the shared cost-aware
//! work-stealing pool, next to whatever lists the Device space produced
//! for ITS packs. Worker deques are seeded by the cost-weighted contiguous
//! partition (per-pack costs = summed `MeshBlock::cost` EWMAs), and a
//! worker whose deque drains steals from the heaviest victim — including
//! across the execution-space boundary under `space=hybrid`. With
//! `parthenon/exec sched = static` the pool never steals and degenerates
//! to the cost-weighted static schedule; with `overlap = phased` the same
//! lists run serially on one worker (the bitwise oracle over the same task
//! units — every per-block kernel reads exactly the same inputs, pinned by
//! `rust/tests/overlap_fused.rs`).
//!
//! Every pack owns a disjoint `&mut` chunk of the per-block work arrays
//! (fluxes, u0, u_new), and reconstruction scratch is bounded by the
//! worker count, so no locking happens inside the kernels and results are
//! bitwise independent of worker count and steal order. Per-block kernel
//! seconds are measured here and folded into `MeshBlock::cost` by
//! `HydroSim::update_block_costs` (EWMA) — the measured costs feed the
//! next cycle's seed partition, the load balancer, and (hybrid) the
//! per-space cost model of [`super::hybrid::HybridPartition`].
//!
//! Multilevel lists split the combine speculatively: blocks with no
//! pending fine-neighbor flux corrections combine right after their fluxes
//! (their face fluxes can never be overwritten by the correction poll),
//! while the rest stay gated on the poll — shaving the flux-correction
//! tail without changing any block's inputs.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::{DtColl, SpaceCtx};
use crate::bvals::PackExchange;
use crate::comm::Comm;
use crate::error::Error;
use crate::hydro::native::{self, FluxArrays, Scratch, StageCoeffs};
use crate::hydro::{HydroPackage, CONS};
use crate::mesh::{IndexShape, MeshBlock};
use crate::tasks::{TaskId, TaskList, TaskStatus, NONE};
use crate::util::stealing::StealPolicy;
use crate::Real;
use crate::NHYDRO;

/// Instrumentation counters for the fused overlap pipeline (cumulative
/// over stages/cycles). `early_poll_violations` pins the overlap contract:
/// a pack's exchange sends must be posted before its poll task first
/// returns `Incomplete` — the task graph orders post-sends before the
/// poll, so this must stay 0.
#[derive(Debug, Default)]
pub struct OverlapStats {
    /// Per-pack send tasks that ran (sends posted + receives registered).
    pub packs_posted: AtomicU64,
    /// Boundary segments posted by fused send tasks.
    pub segments_sent: AtomicU64,
    /// Times a fused poll task returned `Incomplete` (receives pending
    /// while other packs keep computing — the overlap actually engaging).
    pub incomplete_polls: AtomicU64,
    /// Poll returned `Incomplete` before the pack's sends were posted.
    pub early_poll_violations: AtomicU64,
}

/// Bounded scratch store for the fused pipeline: at most `nworkers` flux
/// tasks run concurrently, so a stack of `nworkers` scratches serves every
/// pack without per-pack allocations.
pub(crate) struct ScratchPool {
    stack: Mutex<Vec<Scratch>>,
}

impl ScratchPool {
    pub(crate) fn new(scratches: Vec<Scratch>) -> ScratchPool {
        ScratchPool { stack: Mutex::new(scratches) }
    }

    fn with<R>(&self, f: impl FnOnce(&mut Scratch) -> R) -> R {
        let mut s = self.stack.lock().unwrap().pop().unwrap_or_default();
        let r = f(&mut s);
        self.stack.lock().unwrap().push(s);
        r
    }

    pub(crate) fn into_inner(self) -> Vec<Scratch> {
        self.stack.into_inner().unwrap()
    }
}

/// Per-rank host executor state: per-block work arrays (same order as
/// `mesh.blocks`) plus one scratch per worker thread. Fields are crate
/// visible so [`super::run_stage`] can split them into disjoint per-pack
/// chunks for the contexts of the merged region.
pub struct HostExec {
    pub(crate) flux: Vec<FluxArrays>,
    pub(crate) u0: Vec<Vec<Real>>,
    pub(crate) unew: Vec<Vec<Real>>,
    pub(crate) scratch: Vec<Scratch>,
    /// Measured kernel seconds per block, accumulated over the cycle's
    /// stages and drained by `HydroSim::update_block_costs`.
    pub(crate) block_secs: Vec<f64>,
    pub(crate) nworkers: usize,
    /// Requested worker count (`parthenon/exec nworkers`, 0 = auto) —
    /// kept so [`HostExec::resize`] re-resolves `nworkers` against a new
    /// pack count exactly like a fresh build.
    nworkers_req: usize,
    /// Ranks sharing this machine's cores (auto worker sizing).
    nranks: usize,
    pub(crate) policy: StealPolicy,
    pub(crate) overlap_stats: OverlapStats,
}

impl HostExec {
    pub fn new(
        shape: &IndexShape,
        nblocks: usize,
        npacks: usize,
        ranks_sharing: usize,
        nworkers_req: usize,
        policy: StealPolicy,
    ) -> HostExec {
        let nelem = NHYDRO * shape.ncells_total();
        let cap = npacks.max(1);
        let nworkers = if nworkers_req > 0 {
            nworkers_req.min(cap)
        } else {
            crate::util::num_workers(cap, ranks_sharing)
        };
        HostExec {
            flux: (0..nblocks).map(|_| FluxArrays::new(shape)).collect(),
            u0: (0..nblocks).map(|_| vec![0.0; nelem]).collect(),
            unew: (0..nblocks).map(|_| vec![0.0; nelem]).collect(),
            scratch: (0..nworkers).map(|_| Scratch::default()).collect(),
            block_secs: vec![0.0; nblocks],
            nworkers,
            nworkers_req,
            nranks: ranks_sharing,
            policy,
            overlap_stats: OverlapStats::default(),
        }
    }

    /// Resize the per-block work arrays in place after an incremental
    /// rebalance (or a device re-plan under hybrid): allocations for
    /// surviving blocks are reused (the arrays are per-cycle scratch, so
    /// contents never carry over anyway), the worker count is re-resolved
    /// against the new pack count exactly like [`HostExec::new`] would,
    /// and timing accumulators are zeroed — leaving the executor in the
    /// same state a fresh build produces, minus the allocations.
    pub fn resize(&mut self, shape: &IndexShape, nblocks: usize, npacks: usize) {
        let nelem = NHYDRO * shape.ncells_total();
        let cap = npacks.max(1);
        self.nworkers = if self.nworkers_req > 0 {
            self.nworkers_req.min(cap)
        } else {
            crate::util::num_workers(cap, self.nranks)
        };
        self.flux.truncate(nblocks);
        while self.flux.len() < nblocks {
            self.flux.push(FluxArrays::new(shape));
        }
        self.u0.resize_with(nblocks, || vec![0.0; nelem]);
        self.unew.resize_with(nblocks, || vec![0.0; nelem]);
        self.scratch.resize_with(self.nworkers, Scratch::default);
        self.block_secs.clear();
        self.block_secs.resize(nblocks, 0.0);
        self.overlap_stats = OverlapStats::default();
    }

    pub fn nworkers(&self) -> usize {
        self.nworkers
    }

    pub fn policy(&self) -> StealPolicy {
        self.policy
    }

    /// Block `bi`'s flux arrays (flux-correction tests).
    pub fn flux(&self, bi: usize) -> &FluxArrays {
        &self.flux[bi]
    }

    /// Fused-pipeline instrumentation (exchange overlap counters).
    pub fn overlap_stats(&self) -> &OverlapStats {
        &self.overlap_stats
    }

    /// Take (and zero) the per-block kernel seconds measured since the
    /// last drain.
    pub fn drain_block_secs(&mut self) -> Vec<f64> {
        let out = self.block_secs.clone();
        for s in &mut self.block_secs {
            *s = 0.0;
        }
        out
    }
}

/// Split a per-block slice into per-pack chunks matching `ranges`
/// (contiguous ascending block ranges covering the slice).
pub(crate) fn split_chunks<'a, T>(
    mut rest: &'a mut [T],
    ranges: &[std::ops::Range<usize>],
) -> Vec<&'a mut [T]> {
    let mut parts = Vec::with_capacity(ranges.len());
    for r in ranges {
        let (head, tail) = rest.split_at_mut(r.len());
        parts.push(head);
        rest = tail;
    }
    parts
}

/// Per-pack context of the host stage pipeline: one task list per pack
/// runs fluxes → flux-correction → combine → boundary sends → receive
/// polls against this context, which owns a disjoint `&mut` slice of every
/// per-block structure (blocks, fluxes, u_new, timings) plus shared
/// read-only views (topology, u0, scratch pool) — the whole context is
/// `Send`, so its list can be swept by any worker while other packs'
/// (host OR device) lists run concurrently.
pub(crate) struct HostPackCtx<'a> {
    /// Global index of the pack's first block (u0 is indexed globally).
    pub start: usize,
    /// Pack index (slot in the regional dt reduction's `minima`).
    pub pi: usize,
    pub blocks: &'a mut [MeshBlock],
    pub flux: &'a mut [FluxArrays],
    pub unew: &'a mut [Vec<Real>],
    pub secs: &'a mut [f64],
    pub u0: &'a [Vec<Real>],
    /// Flux corrections this pack's coarse blocks expect (indices are
    /// global; polled against the pack's flux slice via `start`).
    pub fpending: Vec<super::FluxRecv>,
    /// Per-block speculation flags: `spec[off]` = the block expects NO
    /// fine-neighbor flux correction, so its combine may run before the
    /// correction poll (the poll only ever writes blocks with pending
    /// corrections, so a speculative block's inputs are already final).
    pub spec: Vec<bool>,
    /// Send/receive halves of the pack's ghost exchange; also the single
    /// owner of the shared topology (`PackExchange::topo`).
    pub exch: PackExchange<'a>,
    pub fcomm: &'a Comm,
    pub scratch: &'a ScratchPool,
    pub stats: &'a OverlapStats,
    /// Package view for the fused dt reduction (`estimate_dt` reads
    /// interior cells only, so it can run right after the combine).
    pub pkg: &'a HydroPackage,
    /// Per-pack partial CFL minima of the merged dt reduction (one f64
    /// bit-pattern slot per pack across BOTH spaces; min is exact, so the
    /// cross-list fold is bitwise order-independent).
    pub minima: &'a [AtomicU64],
    /// Result slot written by the cross-list fold.
    pub dt_result: &'a AtomicU64,
    /// The shared dt collective state (post counter + in-flight handle).
    pub coll: &'a DtColl,
    pub shape: IndexShape,
    pub gamma: Real,
    pub co: StageCoeffs,
    pub dt: Real,
    pub error: Option<Error>,
    /// Shared across packs: first error drains every list fast.
    pub abort: &'a AtomicBool,
}

/// Stage-combine the context's blocks whose `spec` flag equals
/// `speculative` (both halves together cover the pack exactly once; the
/// per-block arithmetic is identical either way, so the split is bitwise
/// neutral).
fn combine_blocks(c: &mut HostPackCtx, speculative: bool) {
    let HostPackCtx { blocks, flux, unew, secs, u0, start, spec, shape, co, dt, .. } =
        c;
    for (off, b) in blocks.iter_mut().enumerate() {
        if spec[off] != speculative {
            continue;
        }
        let t0 = Instant::now();
        let dx =
            [b.coords.dx[0] as Real, b.coords.dx[1] as Real, b.coords.dx[2] as Real];
        let arr = b.data.get_mut(CONS).expect("cons");
        native::apply_stage(
            arr.as_slice(),
            &u0[*start + off],
            &flux[off],
            shape,
            *co,
            *dt,
            dx,
            &mut unew[off],
        );
        arr.as_mut_slice().copy_from_slice(&unew[off]);
        secs[off] += t0.elapsed().as_secs_f64();
    }
}

/// Produce the host-space task list for one pack into `list` (part of the
/// driver's merged region). Tasks unwrap [`SpaceCtx::Host`]; the returned
/// id is the final-stage dt task (the regional fold's mark), `None` on
/// non-final stages.
///
/// Task graph: `t_flux` → {`t_fcsend`, `t_fcpoll`}(multilevel) with the
/// combine split into a speculative half (gated on fluxes only — blocks
/// with no pending corrections) and a patch-back half (gated on the
/// correction poll); sends/dt wait for both halves.
pub(crate) fn add_host_pack_list(
    list: &mut TaskList<SpaceCtx<'_>>,
    multilevel: bool,
    final_stage: bool,
) -> Option<TaskId> {
    // 1. prim recovery + fluxes for the pack's blocks
    let t_flux = list.add(NONE, |ctx: &mut SpaceCtx| {
        let SpaceCtx::Host(c) = ctx else { return TaskStatus::Complete };
        if c.abort.load(Ordering::SeqCst) {
            return TaskStatus::Complete;
        }
        let HostPackCtx { blocks, flux, secs, scratch, shape, gamma, .. } = c;
        scratch.with(|scr| {
            for (off, fx) in flux.iter_mut().enumerate() {
                let t0 = Instant::now();
                let arr = blocks[off].data.get(CONS).expect("cons");
                native::compute_fluxes(arr.as_slice(), shape, *gamma, fx, scr);
                secs[off] += t0.elapsed().as_secs_f64();
            }
        });
        TaskStatus::Complete
    });
    // 2. speculative stage combine: blocks that expect no correction read
    // only their own (final) fluxes, so they need not wait for the poll.
    let t_apply_spec = list.add(&[t_flux], |ctx: &mut SpaceCtx| {
        let SpaceCtx::Host(c) = ctx else { return TaskStatus::Complete };
        if c.abort.load(Ordering::SeqCst) {
            return TaskStatus::Complete;
        }
        combine_blocks(c, true);
        TaskStatus::Complete
    });
    // 3. flux correction (multilevel): fine-side sends read the computed
    // fluxes; the coarse-side poll overwrites disjoint face entries of the
    // PENDING blocks only and gates their (patch-back) combine.
    let apply_deps: Vec<TaskId> = if multilevel {
        let _t_fcsend = list.add(&[t_flux], |ctx: &mut SpaceCtx| {
            let SpaceCtx::Host(c) = ctx else { return TaskStatus::Complete };
            if c.abort.load(Ordering::SeqCst) {
                return TaskStatus::Complete;
            }
            let HostPackCtx { blocks, flux, exch, fcomm, .. } = c;
            let topo = exch.topo();
            for (off, b) in blocks.iter().enumerate() {
                super::flux_corr_send_block(&topo, fcomm, &b.loc, &flux[off]);
            }
            TaskStatus::Complete
        });
        let t_fcpoll = list.add(&[t_flux], |ctx: &mut SpaceCtx| {
            let SpaceCtx::Host(c) = ctx else { return TaskStatus::Complete };
            if c.abort.load(Ordering::SeqCst) {
                return TaskStatus::Complete;
            }
            let HostPackCtx { flux, fpending, fcomm, start, exch, error, abort, .. } =
                c;
            match super::flux_corr_poll_pending(
                fcomm,
                exch.topo().dim,
                fpending,
                flux,
                *start,
            ) {
                Ok(true) => TaskStatus::Complete,
                Ok(false) => TaskStatus::Incomplete,
                Err(e) => {
                    *error = Some(e);
                    abort.store(true, Ordering::SeqCst);
                    TaskStatus::Complete
                }
            }
        });
        // patch-back combine for the blocks whose fluxes the poll patched
        let t_apply_rest = list.add(&[t_fcpoll], |ctx: &mut SpaceCtx| {
            let SpaceCtx::Host(c) = ctx else { return TaskStatus::Complete };
            if c.abort.load(Ordering::SeqCst) {
                return TaskStatus::Complete;
            }
            combine_blocks(c, false);
            TaskStatus::Complete
        });
        vec![t_apply_spec, t_apply_rest]
    } else {
        vec![t_apply_spec]
    };
    // 4a. post the pack's boundary sends + register receives
    let t_send = list.add(&apply_deps, |ctx: &mut SpaceCtx| {
        let SpaceCtx::Host(c) = ctx else { return TaskStatus::Complete };
        if c.abort.load(Ordering::SeqCst) {
            return TaskStatus::Complete;
        }
        let HostPackCtx { blocks, exch, stats, error, abort, .. } = c;
        match exch.post_sends(blocks) {
            Ok(()) => {
                exch.register_receives(blocks);
                stats.packs_posted.fetch_add(1, Ordering::Relaxed);
                stats
                    .segments_sent
                    .fetch_add(exch.segments_sent() as u64, Ordering::Relaxed);
            }
            Err(e) => {
                if error.is_none() {
                    *error = Some(e);
                }
                abort.store(true, Ordering::SeqCst);
            }
        }
        TaskStatus::Complete
    });
    // 4b. poll receives; Incomplete hands the worker to other lists —
    // this is where the overlap happens.
    let _t_poll = list.add(&[t_send], |ctx: &mut SpaceCtx| {
        let SpaceCtx::Host(c) = ctx else { return TaskStatus::Complete };
        if c.error.is_some() || c.abort.load(Ordering::SeqCst) {
            return TaskStatus::Complete;
        }
        let HostPackCtx { blocks, exch, stats, error, abort, .. } = c;
        match exch.poll(blocks) {
            Ok(true) => TaskStatus::Complete,
            Ok(false) => {
                stats.incomplete_polls.fetch_add(1, Ordering::Relaxed);
                if !exch.sends_posted() {
                    stats.early_poll_violations.fetch_add(1, Ordering::Relaxed);
                }
                TaskStatus::Incomplete
            }
            Err(e) => {
                *error = Some(e);
                abort.store(true, Ordering::SeqCst);
                TaskStatus::Complete
            }
        }
    });
    // 5. (final stage) per-pack partial CFL min — reads the combined
    // interior state written by the combine halves, so it rides the same
    // list without waiting on the ghost exchange. `estimate_dt` already
    // includes the CFL factor, so the slot holds a finished local dt.
    if final_stage {
        let t_dt = list.add(&apply_deps, |ctx: &mut SpaceCtx| {
            let SpaceCtx::Host(c) = ctx else { return TaskStatus::Complete };
            if c.abort.load(Ordering::SeqCst) {
                return TaskStatus::Complete;
            }
            let mut m = f64::INFINITY;
            for b in c.blocks.iter() {
                m = m.min(c.pkg.estimate_dt(&b.data, &b.coords));
            }
            c.minima[c.pi].store(m.to_bits(), Ordering::SeqCst);
            c.coll.dt_done.fetch_add(1, Ordering::SeqCst);
            TaskStatus::Complete
        });
        Some(t_dt)
    } else {
        None
    }
}
