//! Host execution space: the native Rust solver run pack-parallel.
//!
//! The stage operates per MeshBlockPack ([`crate::mesh_data::MeshData`]):
//! packs are dealt to a scoped-thread worker pool in contiguous,
//! pack-aligned block ranges, so every worker owns disjoint `&mut` chunks
//! of the per-block work arrays (fluxes, u0, u_new) and a private
//! reconstruction scratch. Flux correction stays on the driver thread (it
//! is communication-bound and touches fluxes across packs), and the ghost
//! exchange runs as the per-pack task collection of
//! [`crate::bvals::exchange_tasked`] — the same task-collection shape the
//! Device path uses for its boundary routing.

use super::{run_stage_exchange, StageExecutor};
use crate::error::Result;
use crate::hydro::native::{self, FluxArrays, Scratch, StageCoeffs};
use crate::hydro::CONS;
use crate::mesh::IndexShape;
use crate::vars::Package;
use crate::{Real, NHYDRO};

/// Per-rank host executor state: per-block work arrays (same order as
/// `mesh.blocks`) plus one scratch per worker thread.
pub struct HostExec {
    flux: Vec<FluxArrays>,
    u0: Vec<Vec<Real>>,
    unew: Vec<Vec<Real>>,
    scratch: Vec<Scratch>,
    nworkers: usize,
}

impl HostExec {
    pub fn new(
        shape: &IndexShape,
        nblocks: usize,
        npacks: usize,
        ranks_sharing: usize,
    ) -> HostExec {
        let nelem = NHYDRO * shape.ncells_total();
        let nworkers = crate::util::num_workers(npacks.max(1), ranks_sharing);
        HostExec {
            flux: (0..nblocks).map(|_| FluxArrays::new(shape)).collect(),
            u0: (0..nblocks).map(|_| vec![0.0; nelem]).collect(),
            unew: (0..nblocks).map(|_| vec![0.0; nelem]).collect(),
            scratch: (0..nworkers).map(|_| Scratch::default()).collect(),
            nworkers,
        }
    }

    pub fn nworkers(&self) -> usize {
        self.nworkers
    }

    /// Block `bi`'s flux arrays (flux-correction tests).
    pub fn flux(&self, bi: usize) -> &FluxArrays {
        &self.flux[bi]
    }
}

/// Split a per-block slice into per-worker chunks matching `ranges`
/// (contiguous ascending block ranges covering the slice).
fn split_chunks<'a, T>(
    mut rest: &'a mut [T],
    ranges: &[std::ops::Range<usize>],
) -> Vec<&'a mut [T]> {
    let mut parts = Vec::with_capacity(ranges.len());
    for r in ranges {
        let (head, tail) = rest.split_at_mut(r.len());
        parts.push(head);
        rest = tail;
    }
    parts
}

impl StageExecutor for HostExec {
    fn begin_cycle(&mut self, sim: &mut super::HydroSim) -> Result<()> {
        sim.mesh_data.validate(&sim.mesh)?;
        for (bi, b) in sim.mesh.blocks.iter().enumerate() {
            self.u0[bi].copy_from_slice(b.data.get(CONS)?.as_slice());
        }
        Ok(())
    }

    fn stage(
        &mut self,
        sim: &mut super::HydroSim,
        co: StageCoeffs,
        _si: usize,
        dt: Real,
    ) -> Result<()> {
        sim.mesh_data.validate(&sim.mesh)?;
        let shape = sim.mesh.cfg.index_shape();
        let gamma = sim.pkg.gamma;
        let multilevel = sim.is_multilevel();
        if multilevel {
            sim.flux_corr_post_recvs();
        }
        let ranges = sim.mesh_data.worker_block_ranges(self.nworkers);

        // Phase 1 — fluxes, pack-parallel (reads block state, writes
        // disjoint per-block flux arrays).
        {
            let blocks = &sim.mesh.blocks;
            let flux_parts = split_chunks(&mut self.flux, &ranges);
            let scratch_parts: Vec<&mut Scratch> =
                self.scratch.iter_mut().take(ranges.len()).collect();
            std::thread::scope(|s| {
                for ((r, flux_part), scr) in
                    ranges.iter().zip(flux_parts).zip(scratch_parts)
                {
                    let start = r.start;
                    s.spawn(move || {
                        for (off, fx) in flux_part.iter_mut().enumerate() {
                            let arr = blocks[start + off].data.get(CONS).expect("cons");
                            native::compute_fluxes(
                                arr.as_slice(),
                                &shape,
                                gamma,
                                fx,
                                scr,
                            );
                        }
                    });
                }
            });
        }

        // Phase 2 — flux correction across fine/coarse faces (multilevel
        // only): communication-bound, driver thread, backoff while waiting.
        if multilevel {
            for bi in 0..sim.mesh.blocks.len() {
                sim.flux_corr_send(&self.flux[bi], bi);
            }
            sim.flux_corr_wait(&mut self.flux)?;
        }

        // Phase 3 — stage combine, pack-parallel (disjoint &mut blocks).
        {
            let block_parts = split_chunks(&mut sim.mesh.blocks, &ranges);
            let unew_parts = split_chunks(&mut self.unew, &ranges);
            let mut flux_rest: &[FluxArrays] = &self.flux;
            let mut u0_rest: &[Vec<Real>] = &self.u0;
            let mut flux_parts: Vec<&[FluxArrays]> = Vec::with_capacity(ranges.len());
            let mut u0_parts: Vec<&[Vec<Real>]> = Vec::with_capacity(ranges.len());
            for r in &ranges {
                let (fh, ft) = flux_rest.split_at(r.len());
                flux_parts.push(fh);
                flux_rest = ft;
                let (uh, ut) = u0_rest.split_at(r.len());
                u0_parts.push(uh);
                u0_rest = ut;
            }
            std::thread::scope(|s| {
                for (((blocks_part, unew_part), flux_part), u0_part) in block_parts
                    .into_iter()
                    .zip(unew_parts)
                    .zip(flux_parts)
                    .zip(u0_parts)
                {
                    s.spawn(move || {
                        for (off, b) in blocks_part.iter_mut().enumerate() {
                            let dx = [
                                b.coords.dx[0] as Real,
                                b.coords.dx[1] as Real,
                                b.coords.dx[2] as Real,
                            ];
                            let arr = b.data.get_mut(CONS).expect("cons");
                            native::apply_stage(
                                arr.as_slice(),
                                &u0_part[off],
                                &flux_part[off],
                                &shape,
                                co,
                                dt,
                                dx,
                                &mut unew_part[off],
                            );
                            arr.as_mut_slice().copy_from_slice(&unew_part[off]);
                        }
                    });
                }
            });
        }

        // Phase 4 — ghost exchange as per-pack task lists (shared shape
        // with the Device path's boundary routing).
        run_stage_exchange(sim)
    }

    /// Parallel min-reduction of the per-block CFL estimates over the
    /// worker ranges, folded on the driver thread.
    fn local_dt(&self, sim: &super::HydroSim) -> f64 {
        let blocks = &sim.mesh.blocks;
        if blocks.is_empty() {
            return f64::INFINITY;
        }
        let pkg = &sim.pkg;
        let ranges = if sim.mesh_data.is_current(&sim.mesh) {
            sim.mesh_data.worker_block_ranges(self.nworkers)
        } else {
            vec![0..blocks.len()]
        };
        if ranges.len() <= 1 {
            return blocks
                .iter()
                .map(|b| pkg.estimate_dt(&b.data, &b.coords))
                .fold(f64::INFINITY, f64::min);
        }
        let mut mins = vec![f64::INFINITY; ranges.len()];
        std::thread::scope(|s| {
            for (r, out) in ranges.iter().zip(mins.iter_mut()) {
                let r = r.clone();
                s.spawn(move || {
                    let mut m = f64::INFINITY;
                    for b in &blocks[r] {
                        m = m.min(pkg.estimate_dt(&b.data, &b.coords));
                    }
                    *out = m;
                });
            }
        });
        mins.into_iter().fold(f64::INFINITY, f64::min)
    }
}
