//! Host execution space: the native Rust solver run pack-parallel.
//!
//! The stage operates per MeshBlockPack ([`crate::mesh_data::MeshData`]):
//! packs are the work items of a cost-aware work-stealing pool
//! ([`crate::util::stealing::StealPool`]). Worker deques are seeded by the
//! cost-weighted contiguous partition (per-pack costs = summed
//! `MeshBlock::cost` EWMAs), and a worker whose deque drains steals packs
//! from the heaviest victim — closing the tail that static range-dealing
//! leaves on multilevel meshes with uneven per-block cost. With
//! `parthenon/exec sched = static` the pool never steals and degenerates
//! to the cost-weighted static schedule.
//!
//! Every pack owns a disjoint `&mut` chunk of the per-block work arrays
//! (fluxes, u0, u_new), and each worker keeps a private reconstruction
//! scratch, so no locking happens inside the kernels and results are
//! bitwise independent of worker count and steal order. Per-block kernel
//! seconds are measured here and folded into `MeshBlock::cost` by
//! `HydroSim::update_block_costs` (EWMA) — the measured costs feed both
//! the next cycle's seed partition and the load balancer.
//!
//! Flux correction stays on the driver thread (it is communication-bound
//! and touches fluxes across packs); the ghost exchange runs as the
//! per-pack task collection of [`crate::bvals::exchange_tasked_parallel`],
//! executed on the same worker-pool shape.

use std::time::Instant;

use super::{run_stage_exchange, StageExecutor};
use crate::error::Result;
use crate::hydro::native::{self, FluxArrays, Scratch, StageCoeffs};
use crate::hydro::CONS;
use crate::mesh::IndexShape;
use crate::util::stealing::{run_stealing, StealPolicy, StealPool};
use crate::vars::Package;
use crate::{Real, NHYDRO};

/// Per-rank host executor state: per-block work arrays (same order as
/// `mesh.blocks`) plus one scratch per worker thread.
pub struct HostExec {
    flux: Vec<FluxArrays>,
    u0: Vec<Vec<Real>>,
    unew: Vec<Vec<Real>>,
    scratch: Vec<Scratch>,
    /// Measured kernel seconds per block, accumulated over the cycle's
    /// stages and drained by `HydroSim::update_block_costs`.
    block_secs: Vec<f64>,
    nworkers: usize,
    policy: StealPolicy,
}

impl HostExec {
    pub fn new(
        shape: &IndexShape,
        nblocks: usize,
        npacks: usize,
        ranks_sharing: usize,
        nworkers_req: usize,
        policy: StealPolicy,
    ) -> HostExec {
        let nelem = NHYDRO * shape.ncells_total();
        let cap = npacks.max(1);
        let nworkers = if nworkers_req > 0 {
            nworkers_req.min(cap)
        } else {
            crate::util::num_workers(cap, ranks_sharing)
        };
        HostExec {
            flux: (0..nblocks).map(|_| FluxArrays::new(shape)).collect(),
            u0: (0..nblocks).map(|_| vec![0.0; nelem]).collect(),
            unew: (0..nblocks).map(|_| vec![0.0; nelem]).collect(),
            scratch: (0..nworkers).map(|_| Scratch::default()).collect(),
            block_secs: vec![0.0; nblocks],
            nworkers,
            policy,
        }
    }

    pub fn nworkers(&self) -> usize {
        self.nworkers
    }

    pub fn policy(&self) -> StealPolicy {
        self.policy
    }

    /// Block `bi`'s flux arrays (flux-correction tests).
    pub fn flux(&self, bi: usize) -> &FluxArrays {
        &self.flux[bi]
    }

    /// Take (and zero) the per-block kernel seconds measured since the
    /// last drain.
    pub fn drain_block_secs(&mut self) -> Vec<f64> {
        let out = self.block_secs.clone();
        for s in &mut self.block_secs {
            *s = 0.0;
        }
        out
    }
}

/// Split a per-block slice into per-pack chunks matching `ranges`
/// (contiguous ascending block ranges covering the slice).
fn split_chunks<'a, T>(
    mut rest: &'a mut [T],
    ranges: &[std::ops::Range<usize>],
) -> Vec<&'a mut [T]> {
    let mut parts = Vec::with_capacity(ranges.len());
    for r in ranges {
        let (head, tail) = rest.split_at_mut(r.len());
        parts.push(head);
        rest = tail;
    }
    parts
}

impl StageExecutor for HostExec {
    fn begin_cycle(&mut self, sim: &mut super::HydroSim) -> Result<()> {
        sim.mesh_data.validate(&sim.mesh)?;
        for (bi, b) in sim.mesh.blocks.iter().enumerate() {
            self.u0[bi].copy_from_slice(b.data.get(CONS)?.as_slice());
        }
        Ok(())
    }

    fn stage(
        &mut self,
        sim: &mut super::HydroSim,
        co: StageCoeffs,
        _si: usize,
        dt: Real,
    ) -> Result<()> {
        sim.mesh_data.validate(&sim.mesh)?;
        let shape = sim.mesh.cfg.index_shape();
        let gamma = sim.pkg.gamma;
        let multilevel = sim.is_multilevel();
        if multilevel {
            sim.flux_corr_post_recvs();
        }
        // Packs are the unit of stealing; the seed partition is weighted
        // by the measured per-block costs.
        let pack_ranges = sim.mesh_data.block_ranges();
        let pack_costs = sim.mesh_data.pack_costs(&sim.mesh);

        // Phase 1 — fluxes, pack-stealing (reads block state, writes
        // disjoint per-pack flux chunks; each worker owns a scratch).
        {
            let blocks = &sim.mesh.blocks;
            let flux_parts = split_chunks(&mut self.flux, &pack_ranges);
            let secs_parts = split_chunks(&mut self.block_secs, &pack_ranges);
            let items: Vec<(usize, &mut [FluxArrays], &mut [f64])> = pack_ranges
                .iter()
                .zip(flux_parts.into_iter().zip(secs_parts))
                .map(|(r, (fx, sc))| (r.start, fx, sc))
                .collect();
            let pool = StealPool::seed(&pack_costs, self.nworkers, self.policy);
            run_stealing(
                &pool,
                items,
                &mut self.scratch,
                |scr: &mut Scratch, _pi, (start, flux_part, secs_part)| {
                    for (off, fx) in flux_part.iter_mut().enumerate() {
                        let t0 = Instant::now();
                        let arr = blocks[start + off].data.get(CONS).expect("cons");
                        native::compute_fluxes(arr.as_slice(), &shape, gamma, fx, scr);
                        secs_part[off] += t0.elapsed().as_secs_f64();
                    }
                },
            );
        }

        // Phase 2 — flux correction across fine/coarse faces (multilevel
        // only): communication-bound, driver thread, backoff while waiting.
        if multilevel {
            for bi in 0..sim.mesh.blocks.len() {
                sim.flux_corr_send(&self.flux[bi], bi);
            }
            sim.flux_corr_wait(&mut self.flux)?;
        }

        // Phase 3 — stage combine, pack-stealing (disjoint &mut blocks;
        // fluxes and u0 are read by global block index).
        {
            let flux = &self.flux;
            let u0 = &self.u0;
            let block_parts = split_chunks(&mut sim.mesh.blocks, &pack_ranges);
            let unew_parts = split_chunks(&mut self.unew, &pack_ranges);
            let secs_parts = split_chunks(&mut self.block_secs, &pack_ranges);
            let items: Vec<_> = pack_ranges
                .iter()
                .zip(block_parts)
                .zip(unew_parts.into_iter().zip(secs_parts))
                .map(|((r, bp), (up, sp))| (r.start, bp, up, sp))
                .collect();
            let pool = StealPool::seed(&pack_costs, self.nworkers, self.policy);
            run_stealing(
                &pool,
                items,
                &mut self.scratch,
                |_scr: &mut Scratch, _pi, (start, blocks_part, unew_part, secs_part)| {
                    for (off, b) in blocks_part.iter_mut().enumerate() {
                        let t0 = Instant::now();
                        let dx = [
                            b.coords.dx[0] as Real,
                            b.coords.dx[1] as Real,
                            b.coords.dx[2] as Real,
                        ];
                        let arr = b.data.get_mut(CONS).expect("cons");
                        native::apply_stage(
                            arr.as_slice(),
                            &u0[start + off],
                            &flux[start + off],
                            &shape,
                            co,
                            dt,
                            dx,
                            &mut unew_part[off],
                        );
                        arr.as_mut_slice().copy_from_slice(&unew_part[off]);
                        secs_part[off] += t0.elapsed().as_secs_f64();
                    }
                },
            );
        }

        // Phase 4 — ghost exchange as per-pack task lists, run on the same
        // worker-pool shape (parallel polling; serial under sched=static).
        run_stage_exchange(sim, self.nworkers, self.policy)
    }

    /// Parallel min-reduction of the per-block CFL estimates over the
    /// pack items, folded on the driver thread (f64 min is associative
    /// and commutative, so the result is order-independent).
    fn local_dt(&self, sim: &super::HydroSim) -> f64 {
        let blocks = &sim.mesh.blocks;
        if blocks.is_empty() {
            return f64::INFINITY;
        }
        let pkg = &sim.pkg;
        if !sim.mesh_data.is_current(&sim.mesh) || self.nworkers <= 1 {
            return blocks
                .iter()
                .map(|b| pkg.estimate_dt(&b.data, &b.coords))
                .fold(f64::INFINITY, f64::min);
        }
        let pack_ranges = sim.mesh_data.block_ranges();
        let pack_costs = sim.mesh_data.pack_costs(&sim.mesh);
        let pool = StealPool::seed(&pack_costs, self.nworkers, self.policy);
        let mut mins = vec![f64::INFINITY; pool.nworkers()];
        run_stealing(&pool, pack_ranges, &mut mins, |m, _pi, r| {
            for b in &blocks[r] {
                *m = m.min(pkg.estimate_dt(&b.data, &b.coords));
            }
        });
        mins.into_iter().fold(f64::INFINITY, f64::min)
    }
}
