//! Cost-partitioned hybrid scheduling across execution spaces
//! (`parthenon/exec space=hybrid`).
//!
//! The partitioner keeps TWO per-pack cost models — measured host-seconds
//! and device-seconds, folded as EWMAs — and assigns every pack to one of
//! the two spaces. In automatic mode (`hybrid_split < 0`) the assignment
//! is a greedy two-machine makespan schedule: packs are visited in index
//! order (deterministic) and each goes to the space on which it would
//! *finish* earlier given the load already assigned there. A pack that has
//! not been measured on a space yet uses its nominal scheduler cost
//! ([`crate::mesh_data::MeshData::pack_costs`], mean 1.0) as an optimistic
//! estimate for that space, so both spaces receive work before any
//! measurement exists and the model self-corrects as cycles land.
//!
//! A forced split (`hybrid_split` in `[0, 1]`) bypasses the cost model and
//! assigns the device a prefix of `floor(split * npacks)` packs — `0.0`
//! degenerates to a pure-host run and `1.0` to a pure-device run, which is
//! what pins the hybrid scheduler bitwise against the single-space oracles
//! in `hybrid_equivalence`.
//!
//! Re-partitioning happens at the `parthenon/loadbalance interval`
//! cadence (driven from [`super::HydroSim::step`]); the driver re-stages a
//! migrating pack exactly once per migration and counts it in
//! [`crate::metrics::HybridStats`].

use crate::mesh_data::PackSpace;

/// Weight of the newest per-pack seconds sample.
const EWMA_ALPHA: f64 = 0.3;

/// Per-pack two-space cost model + assignment policy.
#[derive(Debug, Clone)]
pub(crate) struct HybridPartition {
    /// Forced device share (`parthenon/exec hybrid_split`); negative means
    /// automatic cost-based partitioning.
    split: f64,
    /// Measured seconds per pack on the Host space (0.0 = unmeasured).
    host_secs: Vec<f64>,
    /// Measured seconds per pack on the Device space (0.0 = unmeasured).
    dev_secs: Vec<f64>,
}

impl HybridPartition {
    pub fn new(split: f64) -> Self {
        HybridPartition { split, host_secs: Vec::new(), dev_secs: Vec::new() }
    }

    /// Forget every measurement (pack identities changed: regrid,
    /// rebalance, restore) and size the model for `npacks` packs.
    pub fn reset(&mut self, npacks: usize) {
        self.host_secs = vec![0.0; npacks];
        self.dev_secs = vec![0.0; npacks];
    }

    /// Fold one measured cycle (`secs` summed over the pack's blocks) into
    /// the EWMA of the space that executed the pack.
    pub fn observe(&mut self, pi: usize, space: PackSpace, secs: f64) {
        let model = match space {
            PackSpace::Host => &mut self.host_secs,
            PackSpace::Device => &mut self.dev_secs,
        };
        if pi >= model.len() || secs <= 0.0 {
            return;
        }
        model[pi] = if model[pi] > 0.0 {
            EWMA_ALPHA * secs + (1.0 - EWMA_ALPHA) * model[pi]
        } else {
            secs
        };
    }

    /// Compute the pack → space assignment. Deterministic for fixed
    /// inputs. `device_available` is false when no [`super::DeviceState`]
    /// exists (no runtime, or mid-regrid with the engine torn down) —
    /// everything stays on the host. `nworkers` is the *requested* worker count: an automatic
    /// split on a single worker degenerates to a pure-host run (there is
    /// nobody to overlap with), while a forced split is always honored.
    pub fn assign(
        &self,
        pack_costs: &[f64],
        device_available: bool,
        nworkers: usize,
    ) -> Vec<PackSpace> {
        let n = pack_costs.len();
        if !device_available {
            return vec![PackSpace::Host; n];
        }
        if self.split >= 0.0 {
            let ndev = ((self.split.min(1.0) * n as f64).floor() as usize).min(n);
            let mut out = vec![PackSpace::Host; n];
            for s in out.iter_mut().take(ndev) {
                *s = PackSpace::Device;
            }
            return out;
        }
        if nworkers == 1 {
            return vec![PackSpace::Host; n];
        }
        // greedy 2-machine makespan over the per-space cost estimates
        let mut load = [0.0f64; 2]; // [host, device]
        let mut out = Vec::with_capacity(n);
        for (pi, &nominal) in pack_costs.iter().enumerate() {
            let est = |model: &[f64]| {
                let m = model.get(pi).copied().unwrap_or(0.0);
                if m > 0.0 {
                    m
                } else {
                    nominal.max(f64::MIN_POSITIVE)
                }
            };
            let (h, d) = (est(&self.host_secs), est(&self.dev_secs));
            if load[0] + h <= load[1] + d {
                load[0] += h;
                out.push(PackSpace::Host);
            } else {
                load[1] += d;
                out.push(PackSpace::Device);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forced_split_assigns_device_prefix() {
        let hp = HybridPartition::new(0.5);
        let a = hp.assign(&[1.0; 4], true, 8);
        assert_eq!(
            a,
            vec![
                PackSpace::Device,
                PackSpace::Device,
                PackSpace::Host,
                PackSpace::Host
            ]
        );
        let all_dev = HybridPartition::new(1.0).assign(&[1.0; 3], true, 8);
        assert!(all_dev.iter().all(|s| *s == PackSpace::Device));
        let all_host = HybridPartition::new(0.0).assign(&[1.0; 3], true, 8);
        assert!(all_host.iter().all(|s| *s == PackSpace::Host));
        // forced split honored even on one worker
        let forced = HybridPartition::new(1.0).assign(&[1.0; 2], true, 1);
        assert!(forced.iter().all(|s| *s == PackSpace::Device));
    }

    #[test]
    fn no_device_or_single_worker_degenerates_to_host() {
        let hp = HybridPartition::new(-1.0);
        assert!(hp
            .assign(&[1.0; 5], false, 8)
            .iter()
            .all(|s| *s == PackSpace::Host));
        assert!(hp
            .assign(&[1.0; 5], true, 1)
            .iter()
            .all(|s| *s == PackSpace::Host));
    }

    #[test]
    fn auto_mode_gives_both_spaces_work_before_measurement() {
        let hp = HybridPartition::new(-1.0);
        let a = hp.assign(&[1.0; 6], true, 4);
        assert!(a.iter().any(|s| *s == PackSpace::Host));
        assert!(a.iter().any(|s| *s == PackSpace::Device));
        // deterministic
        assert_eq!(a, hp.assign(&[1.0; 6], true, 4));
    }

    #[test]
    fn measurements_steer_the_greedy_schedule() {
        let mut hp = HybridPartition::new(-1.0);
        hp.reset(4);
        // device runs every pack 10x faster than the host
        for pi in 0..4 {
            hp.observe(pi, PackSpace::Host, 1.0);
            hp.observe(pi, PackSpace::Device, 0.1);
        }
        let a = hp.assign(&[1.0; 4], true, 4);
        let ndev = a.iter().filter(|s| **s == PackSpace::Device).count();
        assert!(ndev >= 3, "fast device should take most packs, got {ndev}");
    }

    #[test]
    fn observe_folds_ewma() {
        let mut hp = HybridPartition::new(-1.0);
        hp.reset(1);
        hp.observe(0, PackSpace::Host, 2.0);
        assert_eq!(hp.host_secs[0], 2.0, "first sample taken verbatim");
        hp.observe(0, PackSpace::Host, 4.0);
        let expect = EWMA_ALPHA * 4.0 + (1.0 - EWMA_ALPHA) * 2.0;
        assert!((hp.host_secs[0] - expect).abs() < 1e-12);
        // out-of-range / non-positive samples ignored
        hp.observe(9, PackSpace::Host, 1.0);
        hp.observe(0, PackSpace::Device, 0.0);
        assert_eq!(hp.dev_secs[0], 0.0);
    }
}
