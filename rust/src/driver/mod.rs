//! Drivers (paper Sec. 3.11): the base [`Driver`] trait, the evolution loop,
//! and [`HydroSim`] — the PARTHENON-HYDRO application driver that weaves
//! package tasks into task collections, reduces the timestep, runs AMR and
//! load balancing, and writes outputs.
//!
//! Both execution spaces are TASK-LIST PRODUCERS over the shared
//! [`MeshData`] pack partition (built once, invalidated only on regrid /
//! load balance / restart): [`run_stage`] asks each space for one task
//! list per pack it owns, merges ALL of them — host lists, device lists,
//! and the overlapped dt-reduction list — into ONE
//! [`crate::tasks::TaskRegion`], and executes that region on the shared
//! cost-aware work-stealing pool. An idle worker sweeps any ready task,
//! including across the execution-space boundary (`space=hybrid`).
//!
//! * [`host::add_host_pack_list`] — native Rust solver kernels; supports
//!   everything (AMR, multilevel meshes with flux correction, all BCs).
//! * [`device::add_dev_pack_list`] — artifact launches through the
//!   runtime: uniform periodic meshes take the fast path with the three
//!   buffer packing strategies of Fig. 8, every other mesh (multilevel
//!   SMR/AMR, non-periodic BCs) the general per-block list that mirrors
//!   the Host shape — flux correction, restriction/prolongation and
//!   physical BCs on device launches, bitwise-identical to Host.
//! * `space=hybrid` — both at once: packs are assigned to spaces by the
//!   measured per-pack cost EWMAs of [`hybrid::HybridPartition`],
//!   re-partitioned at the `parthenon/loadbalance interval` cadence with
//!   exactly one staging re-stage per migrating pack.
//!
//! `overlap = phased` executes the very same produced lists serially on
//! one worker — the bitwise oracle over the same task units.

pub mod bench;
mod device;
mod host;
mod hybrid;
pub mod recover;
pub mod regrid;

pub use device::DeviceState;
pub use host::{HostExec, OverlapStats};
pub use recover::{run_recoverable, RecoveryReport};

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::bvals::{self, ExchTopo, PackExchange, PackStrategy};
use crate::comm::{
    tags, CollHandle, CollMode, Comm, FaultConfig, Payload, ReduceOp, World,
};
use crate::config::{Override, ParameterInput};
use crate::error::{Error, Result};
use crate::hydro::native::{self, FluxArrays, StageCoeffs, RK2_STAGES};
use crate::hydro::problems::{self, Problem};
use crate::hydro::{HydroPackage, CONS};
use crate::mesh::{LogicalLocation, Mesh, MeshBlock, MeshConfig, NeighborKind};
use crate::mesh_data::{MeshData, PackDesc, PackSpace, PackStaging};
use crate::metrics::{Ewma, HybridStats, RebalanceStats, Timers, ZoneCycles};
use crate::tasks::{RegionInstr, TaskId, TaskRegion, TaskStatus, NONE};
use crate::util::stealing::StealPolicy;
use crate::vars::{resolve_packages, Package};
use crate::Real;
use hybrid::HybridPartition;

/// EWMA weight for folding measured per-block cycle seconds into
/// [`crate::mesh::MeshBlock::cost`] (fast enough to track AMR-driven cost
/// shifts, smooth enough to ignore one-cycle jitter).
const COST_EWMA_ALPHA: f64 = 0.3;

/// Where the hydro stage executes (`parthenon/exec space`).
///
/// * `Host` — native Rust kernels only.
/// * `Device` — runtime artifact launches only.
/// * `Hybrid` — heterogeneous co-execution: every cycle, both spaces
///   produce task lists into the same region and packs are split between
///   them by measured cost ([`hybrid::HybridPartition`]). The Device
///   space serves every mesh (general mode covers multilevel and
///   non-periodic), so the split never degenerates on capability.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecSpace {
    Host,
    Device,
    Hybrid,
}

/// How the stage's task region is scheduled (`parthenon/exec overlap`).
///
/// * `Fused` (default) — the merged per-pack task lists run on the worker
///   pool: prim-recovery/fluxes → flux-correction → stage combine → post
///   sends, then receives are polled as `Incomplete` tasks, so pack A's
///   boundary exchange overlaps pack B's compute (the paper's
///   comm/compute overlap).
/// * `Phased` — the SAME produced lists executed serially on one worker
///   (`nworkers = 1`, no stealing). Kept as the bitwise-identity oracle
///   over the same task units: both modes must produce identical results
///   (`rust/tests/overlap_fused.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverlapMode {
    Phased,
    Fused,
}

impl OverlapMode {
    /// Parse the `parthenon/exec overlap` input value.
    pub fn parse(s: &str) -> Option<OverlapMode> {
        match s {
            "phased" | "barrier" => Some(OverlapMode::Phased),
            "fused" | "overlap" => Some(OverlapMode::Fused),
            _ => None,
        }
    }
}

/// How a fixed-tree rebalance migrates data (`parthenon/loadbalance mode`).
///
/// * `Incremental` (default) — compute the [`crate::balance::MigrationPlan`]
///   delta, migrate ONLY the blocks that change owner, keep every other
///   container (and resident device staging) in place, refresh ghosts /
///   routing for exactly the affected blocks, and re-gather only the dirty
///   packs.
/// * `Full` — tear down every local container and re-fill from a stash /
///   the migration payloads, then run a whole-mesh ghost exchange. Kept as
///   the bitwise-identity oracle: both modes must produce identical state,
///   dt bits and cost EWMAs (`rust/tests/rebalance_incremental.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RebalanceMode {
    Full,
    Incremental,
}

impl RebalanceMode {
    /// Parse the `parthenon/loadbalance mode` input value.
    pub fn parse(s: &str) -> Option<RebalanceMode> {
        match s {
            "full" | "rebuild" => Some(RebalanceMode::Full),
            "incremental" | "delta" => Some(RebalanceMode::Incremental),
            _ => None,
        }
    }
}

/// Base driver abstraction (paper Sec. 3.11): applications implement
/// `execute`; [`EvolutionDriver`] adds the time loop; [`MultiStageDriver`]
/// is realized by [`HydroSim`]'s per-stage task collections.
pub trait Driver {
    fn execute(&mut self) -> Result<()>;
}

/// Drivers that advance a solution in time.
pub trait EvolutionDriver: Driver {
    fn time(&self) -> f64;
    fn cycle(&self) -> u64;
    /// Advance one timestep.
    fn step(&mut self) -> Result<()>;
}

/// Multi-stage (RK) drivers: one task collection per stage.
pub trait MultiStageDriver: EvolutionDriver {
    fn num_stages(&self) -> usize;
}

/// Shared slot of the overlapped dt collective (final RK stage, tree
/// collectives): the posting task on the extra list folds the per-pack
/// minima, posts the `iallreduce(Min)` on the driver's collective
/// communicator, and parks the handle here; the draining task polls it to
/// completion while other lists' boundary polls keep running on the same
/// worker pool. The per-pack `t_dt` tasks of BOTH spaces publish finished
/// f64 local dts (CFL included), so one fold serves host, device and
/// mixed assignments alike.
pub(crate) struct DtColl {
    /// `Some` only when the overlapped reduction is active this stage
    /// (owned clone of the sim's collective comm, so a multi-sim region
    /// can hold one `DtColl` per tenant without borrowing the sims).
    pub comm: Option<Comm>,
    pub handle: Mutex<Option<CollHandle>>,
    /// How many packs have published their partial min.
    pub dt_done: AtomicUsize,
    /// Global dt bits, stored when the handle completes.
    pub global: AtomicU64,
}

/// Context of the overlapped-dt task list (no pack attached — its tasks
/// only touch the shared reduction slots).
pub(crate) struct CollCtx<'a> {
    pub minima: &'a [AtomicU64],
    pub dt_result: &'a AtomicU64,
    pub coll: &'a DtColl,
    pub error: Option<Error>,
    pub abort: &'a AtomicBool,
}

/// One task list's context in the merged stage region: each task body
/// unwraps the variant its producer owns and completes as a no-op on any
/// other (a list never mixes variants, so this never skips real work).
pub(crate) enum SpaceCtx<'a> {
    Host(host::HostPackCtx<'a>),
    Dev(device::DevPackCtx<'a>),
    Coll(CollCtx<'a>),
}

impl SpaceCtx<'_> {
    fn take_error(&mut self) -> Option<Error> {
        match self {
            SpaceCtx::Host(c) => c.error.take(),
            SpaceCtx::Dev(c) => c.error.take(),
            SpaceCtx::Coll(c) => c.error.take(),
        }
    }
}

/// One tenant's contribution to a (possibly multi-simulation) stage
/// region: the sim, its taken-out space engines, and this cycle's dt.
/// [`run_cycle`] builds one for the classic solo path; the service engine
/// ([`crate::service::Engine`]) builds one per live session and hands the
/// whole slice to [`run_cycle_multi`].
pub(crate) struct SimSlot<'s> {
    pub sim: &'s mut HydroSim,
    pub host: Option<&'s mut HostExec>,
    pub dev: Option<&'s mut DeviceState>,
    pub dt: Real,
}

/// Cross-slot stage configuration — the service engine's knobs.
/// [`StageShared::solo`] reproduces the single-sim behavior exactly:
/// worker shape derived from the slot's own engines, no batching, no
/// service counters.
pub(crate) struct StageShared<'e> {
    /// Worker-pool override (the engine's shared pool); `None` derives
    /// the shape from the FIRST slot's engines, as solo runs always did.
    pub workers: Option<(usize, StealPolicy)>,
    /// Fuse same-[`crate::runtime::ArtifactKey`] device packs of
    /// DIFFERENT slots into one batched launch.
    pub batching: bool,
    /// Harvest target for the cross-sim counters
    /// ([`crate::metrics::ServiceStats`]).
    pub svc: Option<&'e crate::service::ServiceCounters>,
}

impl StageShared<'_> {
    pub(crate) fn solo() -> Self {
        StageShared { workers: None, batching: false, svc: None }
    }
}

/// One full cycle (all RK stages) through the merged task region — the
/// single code path every execution space (and their hybrid) runs. The
/// caller hands in whichever space engines exist; `run_stage` asks each
/// for task lists covering exactly the packs assigned to it.
pub(crate) fn run_cycle(
    sim: &mut HydroSim,
    host: Option<&mut HostExec>,
    dev: Option<&mut DeviceState>,
    dt: Real,
) -> Result<()> {
    let mut slots = [SimSlot { sim, host, dev, dt }];
    run_cycle_multi(&mut slots, &StageShared::solo())
}

/// N tenants' cycles through SHARED per-stage task regions: every slot
/// snapshots its cycle-start state, then each RK stage runs as ONE merged
/// region over every slot's packs ([`run_stage_multi`]) so idle workers
/// drain whichever tenant has work.
pub(crate) fn run_cycle_multi(
    slots: &mut [SimSlot<'_>],
    shared: &StageShared<'_>,
) -> Result<()> {
    for slot in slots.iter_mut() {
        slot.sim.mesh_data.validate(&slot.sim.mesh)?;
        // Cycle-start snapshots. Each present space snapshots ALL blocks /
        // packs — for packs assigned to the other space the copy is of
        // stale data and is never read, which keeps the snapshot
        // independent of the assignment (and of mid-run migrations).
        if let Some(h) = slot.host.as_deref_mut() {
            for (bi, b) in slot.sim.mesh.blocks.iter().enumerate() {
                h.u0[bi].copy_from_slice(b.data.get(CONS)?.as_slice());
            }
        }
        if slot.dev.is_some() {
            let (_descs, staging) = slot.sim.mesh_data.parts_mut();
            for p in staging.iter_mut() {
                p.u0.copy_from_slice(&p.u);
            }
        }
    }
    for (si, co) in RK2_STAGES.iter().enumerate() {
        run_stage_multi(slots, *co, si, shared)?;
    }
    Ok(())
}

/// Per-slot stage state that must outlive the region's borrows: the dt
/// fold slots, the overlapped-collective slot, and the pass-1 computed
/// pack layout that the pass-2 context builder and the epilogue both
/// read.
struct StageAux {
    npacks: usize,
    spaces: Vec<PackSpace>,
    pack_costs: Vec<f64>,
    scal: Option<crate::runtime::ScalArgs>,
    overlap_coll: bool,
    hybrid_mode: bool,
    /// Global index of this slot's first task list in the merged region.
    list_base: usize,
    minima: Vec<AtomicU64>,
    dt_result: AtomicU64,
    coll: DtColl,
}

/// One RK stage as ONE merged task region: every pack contributes the
/// task list its assigned space produces ([`host::add_host_pack_list`] /
/// [`device::add_dev_pack_list`]), the overlapped dt reduction rides an
/// extra list on the final stage (tree collectives), and the whole region
/// runs on the shared cost-weighted work-stealing pool. Under
/// `space=hybrid` the pool is instrumented so cross-space steals land in
/// [`HybridStats`]; under `overlap=phased` the same region executes
/// serially on one worker (the bitwise oracle).
pub(crate) fn run_stage(
    sim: &mut HydroSim,
    host: Option<&mut HostExec>,
    dev: Option<&mut DeviceState>,
    co: StageCoeffs,
    si: usize,
    dt: Real,
) -> Result<()> {
    let mut slots = [SimSlot { sim, host, dev, dt }];
    run_stage_multi(&mut slots, co, si, &StageShared::solo())
}

/// One RK stage of EVERY slot as ONE merged task region. Pass 1 walks the
/// slots sequentially — validation, pack layout, per-slot dt/collective
/// state, and (service engine) batch enlistment of same-key device packs.
/// Pass 2 builds one context + one task list per pack of every slot into
/// the shared region and executes it on the shared pool. The epilogue
/// restores the taken engine state, folds each slot's dt, and applies the
/// physical BCs — all per slot, exactly as the solo stage always did.
pub(crate) fn run_stage_multi(
    slots: &mut [SimSlot<'_>],
    co: StageCoeffs,
    si: usize,
    shared: &StageShared<'_>,
) -> Result<()> {
    if slots.is_empty() {
        return Ok(());
    }
    let final_stage = si + 1 == RK2_STAGES.len();
    let multi = slots.len() > 1;

    // ---- pass 1: per-slot validation, pack layout, batch enlistment ----
    let mut registry = crate::service::BatchRegistry::new();
    let mut auxes: Vec<StageAux> = Vec::with_capacity(slots.len());
    let mut tickets: Vec<Vec<Option<crate::service::BatchTicket>>> =
        Vec::with_capacity(slots.len());
    let mut nlists_total = 0usize;
    let mut stall = std::time::Duration::ZERO;
    let mut any_phased = false;
    let mut hybrid_any = false;
    for (sid, slot) in slots.iter_mut().enumerate() {
        let sim = &mut *slot.sim;
        sim.mesh_data.validate(&sim.mesh)?;
        stall = stall.max(sim.world.stall_limit());
        let hybrid_mode = sim.sp.exec == ExecSpace::Hybrid;
        hybrid_any |= hybrid_mode;
        any_phased |= sim.sp.overlap == OverlapMode::Phased;
        let npacks = sim.mesh_data.npacks();
        let spaces: Vec<PackSpace> = sim.mesh_data.pack_spaces().to_vec();
        let pack_costs = sim.mesh_data.pack_costs(&sim.mesh);
        let any_dev = spaces.iter().any(|s| *s == PackSpace::Device);
        let any_host = spaces.iter().any(|s| *s == PackSpace::Host);
        if any_dev && slot.dev.is_none() {
            return Err(Error::Runtime(
                "packs assigned to the Device space without a DeviceState".into(),
            ));
        }
        if any_host && slot.host.is_none() {
            return Err(Error::Runtime(
                "packs assigned to the Host space without a HostExec".into(),
            ));
        }
        let scal = match slot.dev.as_deref() {
            Some(d) if any_dev => {
                if d.strategy == PackStrategy::Native {
                    return Err(Error::Runtime(
                        "strategy=native is the Host path".into(),
                    ));
                }
                Some(d.scal(co, slot.dt, &sim.mesh))
            }
            _ => None,
        };
        // Cross-sim batching (service engine): fast-path PerPack device
        // packs enlist by artifact key; a group that ends up single-sim
        // is dissolved at seal (solo launch), so every surviving batch is
        // genuinely cross-tenant.
        let mut tks: Vec<Option<crate::service::BatchTicket>> =
            (0..npacks).map(|_| None).collect();
        if shared.batching && multi {
            if let Some(d) = slot.dev.as_deref() {
                if !d.is_general() && d.strategy == PackStrategy::PerPack {
                    let ranges = sim.mesh_data.block_ranges();
                    for (pi, tk) in tks.iter_mut().enumerate() {
                        if spaces[pi] == PackSpace::Device {
                            let key = d.key("fused", ranges[pi].len());
                            *tk = Some(registry.enlist(key, sid as u32));
                        }
                    }
                }
            }
        }
        // The merged dt reduction runs on the final RK stage only:
        // per-pack partial minima (f64 bits — both spaces publish
        // finished local dts) + one cross-list fold. With tree
        // collectives the GLOBAL reduction also runs inside the region
        // (posted/drained by an extra task list, overlapped with the tail
        // packs' boundary polls); flat mode keeps the blocking
        // post-region allreduce as the oracle.
        let overlap_coll = final_stage && sim.sp.coll == CollMode::Tree;
        let minima: Vec<AtomicU64> = if final_stage {
            (0..npacks).map(|_| AtomicU64::new(f64::INFINITY.to_bits())).collect()
        } else {
            Vec::new()
        };
        let coll = DtColl {
            comm: (overlap_coll && npacks > 0).then(|| sim.comm_coll.clone()),
            handle: Mutex::new(None),
            dt_done: AtomicUsize::new(0),
            global: AtomicU64::new(f64::INFINITY.to_bits()),
        };
        let nlists = npacks + usize::from(overlap_coll && npacks > 0);
        auxes.push(StageAux {
            npacks,
            spaces,
            pack_costs,
            scal,
            overlap_coll,
            hybrid_mode,
            list_base: nlists_total,
            minima,
            dt_result: AtomicU64::new(f64::INFINITY.to_bits()),
            coll,
        });
        tickets.push(tks);
        nlists_total += nlists;
    }
    registry.seal();

    // Worker pool shape: an engine override wins; otherwise the FIRST
    // slot derives it exactly as solo runs always did (the host engine
    // governs whenever it exists, a pure device run sizes off the device
    // engine). Any phased slot forces the serial oracle for the whole
    // region.
    let (mut nworkers, mut policy) = match shared.workers {
        Some(w) => w,
        None => {
            let slot0 = &slots[0];
            if let Some(h) = slot0.host.as_deref() {
                (h.nworkers, h.policy)
            } else if let Some(d) = slot0.dev.as_deref() {
                (d.stage_workers(auxes[0].npacks), d.policy)
            } else {
                (1, StealPolicy::NoSteal)
            }
        }
    };
    if any_phased {
        nworkers = 1;
        policy = StealPolicy::NoSteal;
    }

    // Concatenated per-list seed costs + scheduling labels (coll lists
    // ride free: zero cost, wildcard space, their slot's sim id).
    let mut all_costs: Vec<f64> = Vec::with_capacity(nlists_total);
    let mut spaces_u8: Vec<u8> = Vec::with_capacity(nlists_total);
    let mut sims_u32: Vec<u32> = Vec::with_capacity(nlists_total);
    for (sid, aux) in auxes.iter().enumerate() {
        all_costs.extend_from_slice(&aux.pack_costs);
        spaces_u8.extend(aux.spaces.iter().map(|s| match s {
            PackSpace::Host => 0u8,
            PackSpace::Device => 1u8,
        }));
        sims_u32.extend(std::iter::repeat(sid as u32).take(aux.npacks));
        if aux.overlap_coll && aux.npacks > 0 {
            all_costs.push(0.0);
            spaces_u8.push(255);
            sims_u32.push(sid as u32);
        }
    }

    let cross_steals = AtomicU64::new(0);
    let cross_sim_steals = AtomicU64::new(0);
    let mut first_error: Option<Error> = None;

    // Host scratch moves into a bounded pool (≤ nworkers concurrent flux
    // tasks) and is restored below, also on error paths. Device per-pack
    // buffers are taken out so the region's contexts can hold disjoint
    // `&mut` slices while sharing `&DeviceState`.
    let pools: Vec<Option<host::ScratchPool>> = slots
        .iter_mut()
        .map(|s| {
            s.host
                .as_deref_mut()
                .map(|h| host::ScratchPool::new(std::mem::take(&mut h.scratch)))
        })
        .collect();
    type DevTaken = (Vec<Real>, Vec<f64>, Vec<Vec<Real>>, Vec<FluxArrays>);
    let mut dev_takens: Vec<Option<DevTaken>> = Vec::with_capacity(slots.len());
    for (slot, aux) in slots.iter_mut().zip(&auxes) {
        dev_takens.push(slot.dev.as_deref_mut().map(|d| {
            if d.tmps.len() != aux.npacks {
                d.tmps.resize_with(aux.npacks, Vec::new);
            }
            (
                std::mem::take(&mut d.last_dts),
                std::mem::take(&mut d.block_secs),
                std::mem::take(&mut d.tmps),
                std::mem::take(&mut d.gen_flux),
            )
        }));
    }
    // ---- pass 2: one context + one task list per pack of every slot ----
    {
        let mut region: TaskRegion<SpaceCtx> = TaskRegion::new(nlists_total);
        let mut ctxs: Vec<SpaceCtx> = Vec::with_capacity(nlists_total);
        let abort = AtomicBool::new(false);
        let mut pool_it = pools.iter();
        let mut dtk_it = dev_takens.iter_mut();
        let mut tks_it = tickets.iter_mut();
        for (slot, aux) in slots.iter_mut().zip(auxes.iter()) {
            let scratch_pool = pool_it.next().expect("pool slot");
            let dev_taken = dtk_it.next().expect("taken slot");
            let tks = tks_it.next().expect("ticket row");
            let host_present = slot.host.is_some();
            let sim = &mut *slot.sim;
            let shape = sim.mesh.cfg.index_shape();
            let gamma = sim.pkg.gamma;
            let cfl = sim.pkg.cfl;
            let multilevel = sim.is_multilevel();
            let hybrid_mode = aux.hybrid_mode;
            let overlap_coll = aux.overlap_coll;
            let npacks = aux.npacks;
            let spaces = &aux.spaces;
            let pack_ranges = sim.mesh_data.block_ranges();
            let dt = slot.dt;
            let scal = aux.scal;
            let minima: &[AtomicU64] = &aux.minima;
            let dt_result = &aux.dt_result;
            let coll_slot = &aux.coll;
            let HydroSim { mesh, mesh_data, pkg, comm_cons, comm_flux, .. } = sim;

            // -- host-side per-pack parts (exist whenever the engine does)
            let (mut flux_parts, mut unew_parts, mut hsecs_parts, u0_all, stats) =
                match slot.host.as_deref_mut() {
                    Some(h) => {
                        let HostExec { flux, unew, block_secs, u0, overlap_stats, .. } =
                            h;
                        (
                            Some(host::split_chunks(flux, &pack_ranges).into_iter()),
                            Some(host::split_chunks(unew, &pack_ranges).into_iter()),
                            Some(
                                host::split_chunks(block_secs, &pack_ranges)
                                    .into_iter(),
                            ),
                            Some(&u0[..]),
                            Some(&*overlap_stats),
                        )
                    }
                    None => (None, None, None, None, None),
                };
            let topo = ExchTopo {
                shape,
                dim: mesh.cfg.dim,
                tree: &mesh.tree,
                ranks: &mesh.ranks,
            };
            // Flux corrections are registered per pack up front (reads the
            // immutable topology), before the blocks split into disjoint
            // per-pack slices — for every pack, whichever space runs it (the
            // general device list polls the same comm with the same tags).
            let fpend: Vec<Vec<FluxRecv>> = if multilevel {
                pack_ranges
                    .iter()
                    .map(|r| {
                        flux_corr_pending_blocks(
                            &topo,
                            &mesh.blocks[r.clone()],
                            r.start,
                        )
                    })
                    .collect()
            } else {
                (0..npacks).map(|_| Vec::new()).collect()
            };
            let mut block_parts = host_present
                .then(|| host::split_chunks(&mut mesh.blocks, &pack_ranges).into_iter());

            // -- device-side per-pack parts --
            let dev_ref: Option<&DeviceState> = slot.dev.as_deref();
            let (descs, staging): (&[PackDesc], &mut [PackStaging]) =
                if dev_ref.is_some() {
                    mesh_data.parts_mut()
                } else {
                    (&[], &mut [])
                };
            let mut staging_it = staging.iter_mut();
            let dev_present = dev_taken.is_some();
            let dev_general = dev_ref.map_or(false, |d| d.is_general());
            let (mut dts_rest, mut dsecs_rest, mut tmps_it, mut gflux_rest) =
                match dev_taken.as_mut() {
                    Some((dts, secs, tmps, gfx)) => {
                        (&mut dts[..], &mut secs[..], Some(tmps.iter_mut()), &mut gfx[..])
                    }
                    None => (
                        &mut [] as &mut [Real],
                        &mut [] as &mut [f64],
                        None,
                        &mut [] as &mut [FluxArrays],
                    ),
                };
            // Hybrid stage comm: device packs exchange on the shared CONS
            // comm so both spaces interoperate (fast-path route tags match
            // the host exchange tags, and general mode shares the host's spec
            // layer outright); a pure device run keeps the device's own comm
            // — the bitwise oracle channel.
            let dev_comm: Option<&Comm> = if hybrid_mode {
                Some(&*comm_cons)
            } else {
                dev_ref.map(|d| &d.comm)
            };

            // -- build one context + one task list per pack --
            for (pi, (range, fpending)) in
                pack_ranges.iter().zip(fpend.into_iter()).enumerate()
            {
                // advance every per-pack resource iterator in lockstep so the
                // parts stay aligned with the pack index; the side not chosen
                // for this pack just drops its (disjoint) parts.
                let blocks =
                    block_parts.as_mut().map(|it| it.next().expect("pack part"));
                let flux = flux_parts.as_mut().map(|it| it.next().expect("pack part"));
                let unew = unew_parts.as_mut().map(|it| it.next().expect("pack part"));
                let hsecs =
                    hsecs_parts.as_mut().map(|it| it.next().expect("pack part"));
                let stg = staging_it.next();
                let tmp = tmps_it.as_mut().map(|it| it.next().expect("pack tmp"));
                let nb = range.len();
                // the taken device buffers cover every block when the engine
                // exists; without one the placeholder slices stay empty
                let take = if dev_present { nb } else { 0 };
                let (dts, rest) = std::mem::take(&mut dts_rest).split_at_mut(take);
                dts_rest = rest;
                let (dsecs, rest) = std::mem::take(&mut dsecs_rest).split_at_mut(take);
                dsecs_rest = rest;
                let gtake = if dev_general { nb } else { 0 };
                let (gfx, rest) = std::mem::take(&mut gflux_rest).split_at_mut(gtake);
                gflux_rest = rest;
                match spaces[pi] {
                    PackSpace::Host => {
                        let blocks = blocks.expect("host engine present");
                        // speculative-combine flags: a block with no pending
                        // fine-neighbor correction combines right after its
                        // fluxes (uniform meshes: every block qualifies)
                        let spec: Vec<bool> = if multilevel {
                            (0..nb)
                                .map(|off| {
                                    !fpending
                                        .iter()
                                        .any(|f| f.block == range.start + off)
                                })
                                .collect()
                        } else {
                            vec![true; nb]
                        };
                        ctxs.push(SpaceCtx::Host(host::HostPackCtx {
                            start: range.start,
                            pi,
                            blocks,
                            flux: flux.expect("host engine present"),
                            unew: unew.expect("host engine present"),
                            secs: hsecs.expect("host engine present"),
                            u0: u0_all.expect("host engine present"),
                            fpending,
                            spec,
                            exch: PackExchange::new(topo, comm_cons, CONS),
                            fcomm: comm_flux,
                            scratch: scratch_pool.as_ref().expect("host engine present"),
                            stats: stats.expect("host engine present"),
                            pkg,
                            minima,
                            dt_result,
                            coll: coll_slot,
                            shape,
                            gamma,
                            co,
                            dt,
                            error: None,
                            abort: &abort,
                        }));
                        let _ = host::add_host_pack_list(
                            region.list(aux.list_base + pi),
                            multilevel,
                            final_stage,
                        );
                    }
                    PackSpace::Device => {
                        let dev_s = dev_ref.expect("device engine present");
                        let d = &descs[pi];
                        ctxs.push(SpaceCtx::Dev(device::DevPackCtx {
                            dev: dev_s,
                            d,
                            p: stg.expect("device staging present"),
                            dts,
                            secs: dsecs,
                            tmp: tmp.expect("device engine present"),
                            pending: dev_s.pack_pending(d),
                            pi,
                            comm: dev_comm.expect("device engine present"),
                            minima,
                            dt_result,
                            coll: coll_slot,
                            scal: scal.expect("device scal present"),
                            cfl,
                            compute_dt: final_stage,
                            flux: gfx,
                            fpending,
                            fcomm: comm_flux,
                            topo,
                            batch: tks[pi].take(),
                            error: None,
                            abort: &abort,
                        }));
                        let _ = device::add_dev_pack_list(
                            region.list(aux.list_base + pi),
                            dev_general,
                            multilevel,
                            final_stage,
                        );
                    }
                }
            }

            if overlap_coll && npacks > 0 {
                // Extra task list: fold the per-pack minima the moment the
                // last t_dt lands, post the global iallreduce(Min), then poll
                // the tree handle to completion. Both tasks return Incomplete
                // while waiting, so workers sweep back to the packs' boundary
                // polls in between — the global dt reduction rides the same
                // overlap the ghost exchange uses.
                let list = region.list(aux.list_base + npacks);
                let t_post = list.add(NONE, move |ctx: &mut SpaceCtx| {
                    let SpaceCtx::Coll(c) = ctx else { return TaskStatus::Complete };
                    if c.abort.load(Ordering::SeqCst) {
                        return TaskStatus::Complete;
                    }
                    if c.coll.dt_done.load(Ordering::SeqCst) < npacks {
                        return TaskStatus::Incomplete;
                    }
                    let mut m = f64::INFINITY;
                    for a in c.minima {
                        m = m.min(f64::from_bits(a.load(Ordering::SeqCst)));
                    }
                    c.dt_result.store(m.to_bits(), Ordering::SeqCst);
                    let comm = c.coll.comm.as_ref().expect("overlap collective comm");
                    *c.coll.handle.lock().unwrap() =
                        Some(comm.iallreduce(m, ReduceOp::Min));
                    TaskStatus::Complete
                });
                let _t_drain = list.add(&[t_post], |ctx: &mut SpaceCtx| {
                    let SpaceCtx::Coll(c) = ctx else { return TaskStatus::Complete };
                    if c.abort.load(Ordering::SeqCst) {
                        return TaskStatus::Complete;
                    }
                    let mut slot = c.coll.handle.lock().unwrap();
                    match slot.as_mut().map(CollHandle::test) {
                        Some(Ok(true)) => {
                            match slot.take().expect("handle present").into_f64() {
                                Ok(g) => {
                                    c.coll.global.store(g.to_bits(), Ordering::SeqCst);
                                }
                                Err(e) => {
                                    drop(slot);
                                    if c.error.is_none() {
                                        c.error = Some(e);
                                    }
                                    c.abort.store(true, Ordering::SeqCst);
                                }
                            }
                            TaskStatus::Complete
                        }
                        Some(Ok(false)) => TaskStatus::Incomplete,
                        Some(Err(e)) => {
                            *slot = None; // poisoned handle: drop it
                            drop(slot);
                            if c.error.is_none() {
                                c.error = Some(e);
                            }
                            c.abort.store(true, Ordering::SeqCst);
                            TaskStatus::Complete
                        }
                        // aborted before the post ran
                        None => TaskStatus::Complete,
                    }
                });
                ctxs.push(SpaceCtx::Coll(CollCtx {
                    minima,
                    dt_result,
                    coll: coll_slot,
                    error: None,
                    abort: &abort,
                }));
            }
        }

        // Cross-space steal instrumentation runs under hybrid exactly as
        // before; the sim labels + cross-sim counter join only when the
        // region actually multiplexes tenants, so solo runs keep their
        // original instrumentation bit-for-bit.
        let instr = (hybrid_any || multi).then(|| RegionInstr {
            spaces: &spaces_u8,
            cross_steals: &cross_steals,
            sims: multi.then_some(&sims_u32[..]),
            cross_sim_steals: multi.then_some(&cross_sim_steals),
        });
        if nlists_total > 0 {
            match region.execute_parallel_weighted_instr(
                ctxs,
                Some(&all_costs),
                nworkers,
                policy,
                stall,
                instr,
            ) {
                Ok(done) => {
                    for mut c in done {
                        if let Some(e) = c.take_error() {
                            first_error = Some(e);
                            break;
                        }
                    }
                }
                Err(e) => first_error = Some(e),
            }
        }
    }

    // Restore the taken engine state (also on error paths).
    for (slot, (pool, taken)) in
        slots.iter_mut().zip(pools.into_iter().zip(dev_takens))
    {
        if let (Some(h), Some(pool)) = (slot.host.as_deref_mut(), pool) {
            h.scratch = pool.into_inner();
        }
        if let (Some(d), Some((dts, secs, tmps, gfx))) =
            (slot.dev.as_deref_mut(), taken)
        {
            d.last_dts = dts;
            d.block_secs = secs;
            d.tmps = tmps;
            d.gen_flux = gfx;
        }
    }
    if let Some(e) = first_error {
        // A stalled task region is this rank's first sight of the
        // failure: escalate so every peer's waits drain with `Aborted`
        // instead of idling out their own watchdogs one by one (every
        // tenant's world — the shared region took them all down).
        for slot in slots.iter() {
            slot.sim.world.escalate(slot.sim.mesh.my_rank, &e);
        }
        return Err(e);
    }
    for (slot, aux) in slots.iter_mut().zip(auxes.iter()) {
        let sim = &mut *slot.sim;
        if final_stage {
            if !aux.overlap_coll && aux.npacks > 0 {
                // Flat oracle: fold the per-pack minima once the region has
                // drained (the blocking global allreduce stays in
                // `reduce_dt`).
                let mut m = f64::INFINITY;
                for a in &aux.minima {
                    m = m.min(f64::from_bits(a.load(Ordering::SeqCst)));
                }
                aux.dt_result.store(m.to_bits(), Ordering::SeqCst);
            }
            // Local dt for this cycle, produced inside the region — the
            // post-cycle `reduce_dt` consults this instead of re-sweeping.
            sim.fused_dt_local =
                Some(f64::from_bits(aux.dt_result.load(Ordering::SeqCst)));
            if aux.overlap_coll {
                // Every rank posts exactly one dt collective per cycle,
                // so a rank with zero packs (no task region to overlap
                // with) still joins the exchange — here, blocking, with an
                // identity contribution.
                let g = if aux.npacks > 0 {
                    f64::from_bits(aux.coll.global.load(Ordering::SeqCst))
                } else {
                    sim.comm_coll.iallreduce(f64::INFINITY, ReduceOp::Min).into_f64()?
                };
                sim.fused_dt_global = Some(g);
            }
        }
        if aux.hybrid_mode && aux.npacks > 0 {
            let nh =
                aux.spaces.iter().filter(|s| **s == PackSpace::Host).count() as u64;
            sim.hybrid_stats.packs_host += nh;
            sim.hybrid_stats.packs_device += aux.npacks as u64 - nh;
            // The shared counter can't attribute a steal to one tenant's
            // hybrid stats when several share the region — the engine's
            // ServiceStats carries it instead.
            if !multi {
                sim.hybrid_stats.cross_space_steals +=
                    cross_steals.load(Ordering::SeqCst);
            }
        }
        // Physical BCs once every receive has landed — the same point the
        // pure-host path has always applied them. Device packs fill their own
        // physical ghosts in the staged arrays at poll-drain, so this sweep
        // runs only when a host pack (or a packless host rank, which must
        // still flip its ghost parity) participated; its writes into device
        // packs' stale containers are harmless — staging is authoritative
        // there, and the pre-regrid sync rewrites the containers wholesale.
        let any_host = aux.spaces.iter().any(|s| *s == PackSpace::Host);
        if slot.host.is_some() && (any_host || aux.npacks == 0) {
            bvals::apply_block_physical_bcs(
                &mut sim.mesh,
                CONS,
                Some([native::IM1, native::IM2, native::IM3]),
            )?;
        }
    }
    if let Some(svc) = shared.svc {
        let (batched, saved) = registry.harvest();
        svc.batched_launches.fetch_add(batched, Ordering::SeqCst);
        svc.launches_saved.fetch_add(saved, Ordering::SeqCst);
        svc.cross_sim_steals
            .fetch_add(cross_sim_steals.load(Ordering::SeqCst), Ordering::SeqCst);
    }
    Ok(())
}

/// Simulation parameters parsed from the input file + CLI.
#[derive(Debug, Clone)]
pub struct SimParams {
    pub problem: Problem,
    pub tlim: f64,
    pub nlim: i64,
    pub exec: ExecSpace,
    pub strategy: PackStrategy,
    pub pack_size: usize,
    /// Host worker-thread count (0 = auto from hardware parallelism).
    pub nworkers: usize,
    /// Host pack scheduler: work-stealing (default) or static ranges.
    pub sched: StealPolicy,
    /// Forced device share of the hybrid partition (`parthenon/exec
    /// hybrid_split`, default -1.0 = automatic cost-based partitioning).
    /// `0.0` pins every pack to the Host space and `1.0` every pack to
    /// the Device space — the bitwise equivalence anchors of
    /// `rust/tests/hybrid_equivalence.rs`.
    pub hybrid_split: f64,
    /// Stage scheduling: fused per-pack pipeline (default) or the
    /// barrier-phased oracle.
    pub overlap: OverlapMode,
    /// Cycles between cost-driven load-balance checks (0 = off; AMR runs
    /// rebalance inside regrid anyway).
    pub lb_interval: i64,
    /// Fixed-tree migration strategy (`parthenon/loadbalance mode`,
    /// default incremental; `full` is the bitwise-identity oracle).
    pub lb_mode: RebalanceMode,
    /// Collective algorithm (`parthenon/comm coll`, default tree; `flat`
    /// is the bulk-synchronous bitwise oracle). Tree also enables the
    /// overlapped dt reduction inside the fused final stage.
    pub coll: CollMode,
    pub impl_: String,
    pub output_dt: f64,
    pub history_dt: f64,
    pub out_dir: String,
    pub quiet: bool,
    /// Seed-driven fault-injection plan (`parthenon/fault`, default: all
    /// off). Installed on the World before the rank's first communication.
    pub fault: FaultConfig,
    /// Cycles between durable checkpoints (`parthenon/job
    /// checkpoint_interval`, 0 = off). Checkpoints are written atomically
    /// (tmp + rename), so a crash mid-write never loses the previous one.
    pub checkpoint_interval: i64,
    /// Checkpoint target (`parthenon/job checkpoint_path`, default
    /// `<out_dir>/parthenon.chk.pbin`).
    pub checkpoint_path: String,
}

impl SimParams {
    pub fn from_input(pin: &mut ParameterInput) -> Result<SimParams> {
        let problem_s = pin.str_or("parthenon/job", "problem", "uniform");
        let problem = Problem::parse(&problem_s)
            .ok_or_else(|| Error::config(format!("unknown problem {problem_s:?}")))?;
        let exec = match pin.str_or("parthenon/exec", "space", "host").as_str() {
            "host" => ExecSpace::Host,
            "device" => ExecSpace::Device,
            "hybrid" => ExecSpace::Hybrid,
            other => return Err(Error::config(format!("unknown exec space {other:?}"))),
        };
        let strategy_s = pin.str_or(
            "parthenon/exec",
            "strategy",
            if exec == ExecSpace::Host { "native" } else { "perpack" },
        );
        let strategy = PackStrategy::parse(&strategy_s)
            .ok_or_else(|| Error::config(format!("unknown strategy {strategy_s:?}")))?;
        let sched_s = pin.str_or("parthenon/exec", "sched", "stealing");
        let sched = StealPolicy::parse(&sched_s)
            .ok_or_else(|| Error::config(format!("unknown scheduler {sched_s:?}")))?;
        let overlap_s = pin.str_or("parthenon/exec", "overlap", "fused");
        let overlap = OverlapMode::parse(&overlap_s)
            .ok_or_else(|| Error::config(format!("unknown overlap mode {overlap_s:?}")))?;
        let lb_mode_s = pin.str_or("parthenon/loadbalance", "mode", "incremental");
        let lb_mode = RebalanceMode::parse(&lb_mode_s)
            .ok_or_else(|| Error::config(format!("unknown loadbalance mode {lb_mode_s:?}")))?;
        let coll_s = pin.str_or("parthenon/comm", "coll", "tree");
        let coll = CollMode::parse(&coll_s)
            .ok_or_else(|| Error::config(format!("unknown coll mode {coll_s:?}")))?;
        let out_dir = pin.str_or("parthenon/job", "out_dir", ".");
        let default_chk = format!("{out_dir}/parthenon.chk.pbin");
        Ok(SimParams {
            problem,
            tlim: pin.real_or("parthenon/time", "tlim", 1.0),
            nlim: pin.int_or("parthenon/time", "nlim", -1),
            exec,
            strategy,
            pack_size: pin.int_or("parthenon/exec", "pack_size", 16) as usize,
            nworkers: pin.int_or("parthenon/exec", "nworkers", 0).max(0) as usize,
            sched,
            hybrid_split: pin.real_or("parthenon/exec", "hybrid_split", -1.0),
            overlap,
            lb_interval: pin.int_or("parthenon/loadbalance", "interval", 0),
            lb_mode,
            coll,
            impl_: pin.str_or("parthenon/exec", "impl", "jnp"),
            output_dt: pin.real_or("parthenon/output0", "dt", -1.0),
            history_dt: pin.real_or("parthenon/history", "dt", -1.0),
            out_dir,
            quiet: pin.bool_or("parthenon/job", "quiet", false),
            fault: FaultConfig::from_input(pin),
            checkpoint_interval: pin.int_or("parthenon/job", "checkpoint_interval", 0),
            checkpoint_path: pin.str_or("parthenon/job", "checkpoint_path", &default_chk),
        })
    }
}

/// Pending flux-correction receive on a coarse block.
pub(crate) struct FluxRecv {
    block: usize,
    src: usize,
    tag: u64,
    d: usize,
    face_idx: usize,
    t_start: [usize; 3], // tangential coarse start (per axis; normal unused)
    t_len: [usize; 3],
}

/// The PARTHENON-HYDRO application driver for one rank.
pub struct HydroSim {
    pub pin: ParameterInput,
    pub mesh: Mesh,
    /// Cached pack partition + staging, shared by both execution spaces.
    pub mesh_data: MeshData,
    pub pkg: HydroPackage,
    pub sp: SimParams,
    pub world: World,
    comm_cons: Comm,
    comm_flux: Comm,
    comm_coll: Comm,
    pub device: Option<DeviceState>,
    pub host: Option<HostExec>,
    /// The process's compiled-artifact runtime, shared by every engine this
    /// sim ever builds (regrids reuse it — the executable cache and launch
    /// counters persist) and, under [`crate::service::Engine`], by every
    /// OTHER sim in the process. Lazily constructed on the first device
    /// engine unless injected via [`SimBuilder::runtime`].
    rt: Option<Arc<crate::runtime::Runtime>>,
    /// Cost-partitioner of `space=hybrid` (None on single-space runs).
    hybrid: Option<HybridPartition>,
    /// Co-execution counters (`space=hybrid`): packs per space, steals
    /// across the space boundary, staging re-stages, re-partitions.
    pub hybrid_stats: HybridStats,
    /// This rank's CFL dt, produced INSIDE the final stage's task region
    /// (both spaces publish into the same fold). Taken by [`reduce_dt`];
    /// invalidated whenever the mesh/staging changes under it.
    ///
    /// [`reduce_dt`]: HydroSim::reduce_dt
    fused_dt_local: Option<f64>,
    /// The finished GLOBAL dt when the final stage also drained the tree
    /// `iallreduce(Min)` inside its region (overlapped collectives).
    fused_dt_global: Option<f64>,
    pub time: f64,
    pub cycle: u64,
    pub dt: f64,
    pub timers: Timers,
    pub zc: ZoneCycles,
    /// Migration / re-gather accounting of the load balancer — tests and
    /// the regrid bench lane assert the incremental path touches only the
    /// delta (a no-op rebalance leaves every counter untouched).
    pub lb_stats: RebalanceStats,
    output_idx: usize,
    next_output: f64,
    next_history: f64,
}

/// Builder for [`HydroSim`] — the one construction path. Injection points
/// the bare constructor never had: a shared [`crate::runtime::Runtime`]
/// (the service engine passes ONE `Arc` to every session, so exactly one
/// runtime exists per process) and a shared worker-pool shape (overrides
/// the deck's `parthenon/exec nworkers`/`sched` so every tenant seeds the
/// same pool). `HydroSim::new` remains as a thin shim over
/// `SimBuilder::new(pin).rank(r).world(w).build()`.
pub struct SimBuilder {
    pin: ParameterInput,
    rank: usize,
    world: Option<World>,
    runtime: Option<Arc<crate::runtime::Runtime>>,
    pool: Option<(usize, StealPolicy)>,
}

impl SimBuilder {
    pub fn new(pin: ParameterInput) -> SimBuilder {
        SimBuilder { pin, rank: 0, world: None, runtime: None, pool: None }
    }

    /// This rank's index in the world (default 0).
    pub fn rank(mut self, rank: usize) -> SimBuilder {
        self.rank = rank;
        self
    }

    /// The comm world (default: a fresh single-rank world).
    pub fn world(mut self, world: World) -> SimBuilder {
        self.world = Some(world);
        self
    }

    /// Share an existing runtime instead of lazily constructing one on the
    /// first device engine.
    pub fn runtime(mut self, rt: Arc<crate::runtime::Runtime>) -> SimBuilder {
        self.runtime = Some(rt);
        self
    }

    /// Adopt a shared worker-pool shape (overrides the deck's
    /// `parthenon/exec nworkers` / `sched`).
    pub fn pool(mut self, pool: &crate::service::SharedPool) -> SimBuilder {
        self.pool = Some((pool.nworkers, pool.policy));
        self
    }

    pub fn build(self) -> Result<HydroSim> {
        let SimBuilder { mut pin, rank, world, runtime, pool } = self;
        let world = world.unwrap_or_else(|| World::new(1));
        let cfg = MeshConfig::from_params(&mut pin)?;
        let pkg = HydroPackage::initialize(&mut pin);
        let mut sp = SimParams::from_input(&mut pin)?;
        if let Some((nworkers, sched)) = pool {
            sp.nworkers = nworkers;
            sp.sched = sched;
        }
        let fields = resolve_packages(&[pkg.descriptor()])?;
        // Install the fault plan before this rank's first send/recv: the
        // checksum-framing decision must be uniform across every message a
        // rank ever handles (comm::fault's framing invariant).
        world.install_faults(sp.fault.clone());
        let mut mesh = Mesh::build(cfg, fields, rank, world.size());

        // Problem generation on every local block.
        for mb in &mut mesh.blocks {
            problems::generate(sp.problem, mb, &mut pin, pkg.gamma)?;
        }

        let comm_cons = world.comm(rank, tags::COMM_BVALS_BASE);
        let comm_flux = world.comm(rank, tags::COMM_FLUX);
        let comm_coll = world.comm(rank, 0).with_coll(sp.coll);
        let mesh_data = MeshData::build(&mesh, sp.pack_size, None);

        let mut sim = HydroSim {
            pin,
            mesh,
            mesh_data,
            pkg,
            sp,
            world,
            comm_cons,
            comm_flux,
            comm_coll,
            device: None,
            host: None,
            rt: runtime,
            hybrid: None,
            hybrid_stats: HybridStats::default(),
            fused_dt_local: None,
            fused_dt_global: None,
            time: 0.0,
            cycle: 0,
            dt: 0.0,
            timers: Timers::default(),
            zc: ZoneCycles::default(),
            lb_stats: RebalanceStats::default(),
            output_idx: 0,
            next_output: 0.0,
            next_history: 0.0,
        };
        sim.rebuild_work_buffers();

        // Initial ghost fill + derived fill.
        bvals::exchange_blocking(
            &mut sim.mesh,
            &sim.comm_cons,
            CONS,
            Some([native::IM1, native::IM2, native::IM3]),
        )?;
        sim.fill_derived();

        match sim.sp.exec {
            ExecSpace::Host => {}
            ExecSpace::Device => {
                let rt = sim.runtime_handle()?;
                let dev = DeviceState::new(&mut sim, rt)?;
                sim.device = Some(dev);
                let n = sim.mesh_data.npacks();
                sim.mesh_data.set_pack_spaces(vec![PackSpace::Device; n]);
            }
            ExecSpace::Hybrid => sim.init_hybrid()?,
        }

        // Initial timestep.
        sim.dt = sim.reduce_dt();
        Ok(sim)
    }
}

impl HydroSim {
    /// Thin shim over [`SimBuilder`] — the historical constructor shape.
    pub fn new(pin: ParameterInput, rank: usize, world: World) -> Result<HydroSim> {
        SimBuilder::new(pin).rank(rank).world(world).build()
    }

    /// The sim's shared runtime handle, constructing it on first use when
    /// none was injected. The ONLY `Runtime` construction site in the
    /// driver: every engine (re)build clones this `Arc`, so regrids,
    /// restarts and hybrid re-inits reuse the compiled-executable cache,
    /// and a corrupt artifact dir surfaces exactly once.
    pub(crate) fn runtime_handle(&mut self) -> Result<Arc<crate::runtime::Runtime>> {
        if let Some(rt) = &self.rt {
            return Ok(Arc::clone(rt));
        }
        let rt = Arc::new(crate::runtime::Runtime::new(
            crate::runtime::default_artifact_dir(),
        )?);
        self.rt = Some(Arc::clone(&rt));
        Ok(rt)
    }

    /// Restore state from a snapshot (restart; paper Sec. 3.9). The mesh is
    /// rebuilt from the snapshot's leaves and redistributed over the CURRENT
    /// rank count by the load balancer, exactly like Parthenon's restart.
    pub fn restore_snapshot(&mut self, snap: &crate::io::Snapshot) -> Result<()> {
        use crate::balance;
        let tree = crate::mesh::BlockTree::from_leaves(
            self.mesh.cfg.nrb,
            self.mesh.cfg.dim,
            self.mesh.cfg.periodic_flags(),
            snap.leaves.clone(),
        );
        // The restart distribution must be identical on every rank, and a
        // rank only knows its OWN measured costs — so restarts seed from
        // the nominal (uniform) derivation; the EWMA re-measures within a
        // few cycles and the next regrid/rebalance uses the real costs.
        let costs = balance::derive_leaf_costs(
            tree.leaves(),
            &Default::default(),
            self.mesh.cfg.dim,
        );
        self.device = None; // routes/staging are stale; rebuilt below
        self.hybrid = None; // pack identities change; re-partitioned below
        self.fused_dt_local = None;
        self.fused_dt_global = None;
        self.mesh.ranks = balance::assign_blocks(&costs, self.mesh.nranks);
        self.mesh.tree = tree;
        self.mesh.rebuild_local_blocks();
        self.rebuild_work_buffers();
        // The snapshot overwrites the block containers, so any preserved
        // staging no longer reflects them.
        self.mesh_data.mark_all_dirty();
        snap.restore_into(&mut self.mesh)?;
        self.time = snap.time;
        self.cycle = snap.cycle;
        self.dt = snap.dt;
        bvals::exchange_blocking(
            &mut self.mesh,
            &self.comm_cons,
            CONS,
            Some([native::IM1, native::IM2, native::IM3]),
        )?;
        self.fill_derived();
        match self.sp.exec {
            ExecSpace::Host => {}
            ExecSpace::Device => {
                let rt = self.runtime_handle()?;
                let dev = DeviceState::new(self, rt)?;
                self.device = Some(dev);
                let n = self.mesh_data.npacks();
                self.mesh_data.set_pack_spaces(vec![PackSpace::Device; n]);
            }
            ExecSpace::Hybrid => self.init_hybrid()?,
        }
        Ok(())
    }

    /// Write a restart snapshot of the current state.
    pub fn write_restart(&mut self, path: &str) -> Result<()> {
        self.sync_device_to_blocks()?;
        crate::io::write_snapshot(
            &self.mesh,
            &self.comm_coll,
            self.time,
            self.cycle,
            self.dt,
            &[CONS.to_string()],
            path,
        )
    }

    /// Scatter device-RESIDENT staging back into the block containers
    /// (no-op on the Host path, where the containers are authoritative).
    /// Under hybrid, host-assigned packs are dirty — their containers are
    /// already authoritative and must not be clobbered by stale staging.
    pub fn sync_device_to_blocks(&mut self) -> Result<()> {
        if self.device.is_some() {
            self.mesh_data.scatter_resident(&mut self.mesh, CONS)?;
        }
        Ok(())
    }

    /// Scatter a fully-current image of every device-resident pack into
    /// the containers, GHOSTS included: fast-path packs first fold their
    /// resident ghost inbox into the staged arrays (`stage_out_pack`; a
    /// no-op in general mode, whose staged ghosts are always current),
    /// then the resident staging scatters down. Used before a regrid,
    /// whose refinement criteria and restrict/prolong kernels read the
    /// containers.
    fn sync_device_full(&mut self) -> Result<()> {
        let Some(dev) = self.device.as_ref() else { return Ok(()) };
        let spaces = self.mesh_data.pack_spaces().to_vec();
        {
            let (descs, staging) = self.mesh_data.parts_mut();
            for (pi, s) in spaces.iter().enumerate() {
                if *s == PackSpace::Device {
                    dev.stage_out_pack(&descs[pi], &mut staging[pi]);
                }
            }
        }
        self.mesh_data.scatter_resident(&mut self.mesh, CONS)?;
        Ok(())
    }

    /// (Re)create the Device engine after a regrid changed the tree —
    /// `space=device` rebuilds the engine + the all-device assignment,
    /// `space=hybrid` re-runs its bring-up (fresh partition + assignment
    /// against the new packs). The caller must have torn the old engine
    /// down (and synced its staging) first.
    fn rebuild_device_engine(&mut self) -> Result<()> {
        debug_assert!(self.device.is_none());
        match self.sp.exec {
            ExecSpace::Device => {
                let rt = self.runtime_handle()?;
                let dev = DeviceState::new(self, rt)?;
                self.device = Some(dev);
                let n = self.mesh_data.npacks();
                self.mesh_data.set_pack_spaces(vec![PackSpace::Device; n]);
            }
            ExecSpace::Hybrid => self.init_hybrid()?,
            ExecSpace::Host => {}
        }
        Ok(())
    }

    /// Rebuild the pack cache + per-block work buffers after mesh changes
    /// (regrid, load balance, restart). The single invalidation point: the
    /// pack plan is re-planned against the mesh's new version and the host
    /// executor's work arrays are resized.
    ///
    /// Precondition: the DeviceState must be torn down first (set
    /// `self.device = None`, then recreate it) — a rebuild under a live
    /// device would zero its staging without re-gathering and leave its
    /// routing tables sized for the old block set. Every caller honors
    /// this today (init, restart, regrid-is-host-only).
    pub(crate) fn rebuild_work_buffers(&mut self) {
        debug_assert!(
            self.device.is_none(),
            "tear down DeviceState before rebuild_work_buffers; recreate it \
             after so it re-plans the packs and re-gathers staging"
        );
        self.mesh_data.ensure_current(&self.mesh, None);
        self.fused_dt_local = None;
        self.fused_dt_global = None;
        // Host work arrays (fluxes, u0, u_new) are ~5x the conserved-state
        // footprint; pure Device runs never touch them, so only the
        // execution spaces with a host side pay for them (Host, Hybrid).
        let shape = self.mesh.cfg.index_shape();
        self.host = if self.sp.exec != ExecSpace::Device {
            Some(HostExec::new(
                &shape,
                self.mesh.blocks.len(),
                self.mesh_data.npacks(),
                self.mesh.nranks,
                self.sp.nworkers,
                self.sp.sched,
            ))
        } else {
            None
        };
    }

    /// The incremental analog of [`HydroSim::rebuild_work_buffers`]: the
    /// pack plan was already re-drawn (preserving resident staging) by the
    /// caller, so only the host executor's per-block work arrays are
    /// resized in place — allocations for blocks that stayed are reused,
    /// and the worker count is re-resolved against the new pack count
    /// exactly like a fresh build (so full and incremental rebalances
    /// schedule identically). Same precondition as the full hook: on
    /// Device the DeviceState must be taken out first.
    pub(crate) fn resize_work_buffers(&mut self) {
        debug_assert!(
            self.device.is_none(),
            "take the DeviceState out before resize_work_buffers; its \
             routes/dts are refreshed by after_rebalance_incremental"
        );
        self.mesh_data.ensure_current(&self.mesh, None);
        self.fused_dt_local = None;
        self.fused_dt_global = None;
        if self.host.is_none() {
            // Device path (or first build): nothing to resize in place
            self.rebuild_work_buffers();
            return;
        }
        let shape = self.mesh.cfg.index_shape();
        let (nblocks, npacks) = (self.mesh.blocks.len(), self.mesh_data.npacks());
        self.host
            .as_mut()
            .expect("checked above")
            .resize(&shape, nblocks, npacks);
    }

    /// Fold the executor's measured per-block kernel seconds into the
    /// per-block cost EWMA ([`crate::mesh::MeshBlock::cost`]). Samples are
    /// normalized to the GLOBAL mean block seconds (sum-allreduced), never
    /// a rank-local mean — a rank-local mean would rescale every rank to
    /// 1.0 and erase exactly the inter-rank imbalance the load balancer
    /// needs to see. Every rank reaches the collective every cycle (ranks
    /// with no blocks contribute zeros; the exec space is uniform across
    /// ranks, so no rank is left waiting). Host measures per block; Device
    /// times each pack launch and spreads the sample evenly over the
    /// pack's blocks — so `parthenon/loadbalance interval` rebalances on
    /// MEASURED costs in both execution spaces.
    pub(crate) fn update_block_costs(&mut self) {
        // Drain BOTH engines: under hybrid each holds the seconds of the
        // packs its space executed (zeros elsewhere), so the element-wise
        // sum is the complete per-block measurement. Single-space runs
        // drain exactly one engine, as before.
        let hsecs = self.host.as_mut().map(|h| h.drain_block_secs());
        let dsecs = self.device.as_mut().map(|d| d.drain_block_secs());
        let secs = match (hsecs, dsecs) {
            (Some(mut h), Some(d)) => {
                if h.len() == d.len() {
                    for (a, b) in h.iter_mut().zip(&d) {
                        *a += b;
                    }
                }
                h
            }
            (Some(h), None) => h,
            (None, Some(d)) => d,
            (None, None) => return,
        };
        // Feed the per-pack seconds into the hybrid cost model of the
        // space that actually executed each pack this interval.
        if let Some(hp) = self.hybrid.as_mut() {
            if secs.len() == self.mesh.blocks.len() {
                let spaces = self.mesh_data.pack_spaces();
                for (pi, d) in self.mesh_data.packs().iter().enumerate() {
                    let s: f64 = secs[d.block_range()].iter().sum();
                    hp.observe(pi, spaces[pi], s);
                }
            }
        }
        let local = [secs.iter().sum::<f64>(), secs.len() as f64];
        let glob = self.comm_coll.allreduce_vec(&local, ReduceOp::Sum);
        let (gtotal, gcount) = (glob[0], glob[1]);
        if gtotal <= 0.0 || gcount <= 0.0 || secs.len() != self.mesh.blocks.len() {
            return; // nothing measured yet (or stale buffer length)
        }
        let gmean = gtotal / gcount;
        let ew = Ewma { alpha: COST_EWMA_ALPHA };
        for (b, s) in self.mesh.blocks.iter_mut().zip(&secs) {
            b.cost = ew.fold(b.cost, (s / gmean).max(1e-3));
        }
    }

    pub fn fill_derived(&mut self) {
        for mb in &mut self.mesh.blocks {
            self.pkg.fill_derived(&mut mb.data, &mb.coords);
        }
    }

    /// Recompute derived fields only for the given blocks (by gid) — the
    /// incremental rebalance refreshes exactly the migrated blocks; every
    /// other block's derived data is untouched and already consistent with
    /// its (unchanged) conserved state.
    pub(crate) fn fill_derived_for(&mut self, gids: &std::collections::HashSet<usize>) {
        for mb in &mut self.mesh.blocks {
            if gids.contains(&mb.gid) {
                self.pkg.fill_derived(&mut mb.data, &mb.coords);
            }
        }
    }

    /// Global zones (interior cells) across all ranks' blocks.
    pub fn global_zones(&self) -> u64 {
        (self.mesh.tree.nblocks() * self.mesh.cfg.index_shape().ncells_interior()) as u64
    }

    /// CFL timestep: executor-local estimate, min-reduced across ranks.
    /// In fused mode the local value was already produced INSIDE the final
    /// stage's task region (per-pack partial minima + one regional
    /// cross-list fold on both exec spaces), so no separate sweep over the
    /// blocks runs here; the phased oracle still sweeps (Host) or folds
    /// the staged per-block dts (Device). With tree collectives the fused
    /// final stage also posted the global `iallreduce(Min)` from inside
    /// the task region and drained it there (overlapped with the tail
    /// packs' boundary polls), so this just picks up the finished global
    /// value — no rank blocks here at all.
    pub fn reduce_dt(&mut self) -> f64 {
        if let Some(g) = self.fused_dt_global.take() {
            self.fused_dt_local = None;
            return g;
        }
        let local = self
            .fused_dt_local
            .take()
            .unwrap_or_else(|| self.bootstrap_local_dt());
        self.comm_coll.allreduce(local, ReduceOp::Min)
    }

    /// This rank's CFL dt when no stage has produced one yet (startup,
    /// restart, post-regrid): sweep whichever representation is currently
    /// authoritative per pack. Bitwise-matches what the next stage's fused
    /// fold would produce from the same state.
    fn bootstrap_local_dt(&self) -> f64 {
        let container_sweep = |blocks: &[MeshBlock]| {
            blocks
                .iter()
                .map(|b| self.pkg.estimate_dt(&b.data, &b.coords))
                .fold(f64::INFINITY, f64::min)
        };
        let Some(dev) = self.device.as_ref() else {
            return container_sweep(&self.mesh.blocks);
        };
        let spaces = self.mesh_data.pack_spaces();
        if spaces.iter().all(|s| *s == PackSpace::Host) {
            return container_sweep(&self.mesh.blocks);
        }
        // Per pack: device-assigned packs fold the staged per-block dts of
        // the device bootstrap/launch — fast path with the legacy fold
        // (f32 min, then one CFL scale), general mode with the host
        // formula (per-block `(cfl · raw) as f64`, f64 min — exactly
        // `estimate_dt`, so multilevel bootstraps match the host bitwise);
        // host packs sweep their containers.
        let mut m = f64::INFINITY;
        for (pi, d) in self.mesh_data.packs().iter().enumerate() {
            let r = d.block_range();
            let pack_dt = match spaces[pi] {
                PackSpace::Host => container_sweep(&self.mesh.blocks[r]),
                PackSpace::Device if dev.is_general() => dev.last_dts[r]
                    .iter()
                    .fold(f64::INFINITY, |a, &v| a.min((self.pkg.cfl * v) as f64)),
                PackSpace::Device => {
                    let md = dev.last_dts[r]
                        .iter()
                        .fold(f32::INFINITY, |a, &b| a.min(b));
                    self.pkg.cfl as f64 * md as f64
                }
            };
            m = m.min(pack_dt);
        }
        m
    }

    // -- heterogeneous co-execution (space=hybrid) ---------------------------

    /// Bring up `space=hybrid`: build the Device engine (the general mode
    /// covers multilevel and non-periodic meshes, so every mesh is
    /// device-capable now), keep the Host engine, and draw the initial
    /// pack → space assignment. A missing or corrupt artifact runtime
    /// surfaces as a structured error, like `space=device`.
    pub(crate) fn init_hybrid(&mut self) -> Result<()> {
        let rt = self.runtime_handle()?;
        let dev = DeviceState::new(self, rt)?;
        self.device = Some(dev);
        // DeviceState::new re-drew the pack plan (gathering staging);
        // re-size the host work arrays against the final pack count so
        // both engines cover the same partition.
        let shape = self.mesh.cfg.index_shape();
        let (nblocks, npacks) = (self.mesh.blocks.len(), self.mesh_data.npacks());
        self.host
            .as_mut()
            .expect("hybrid keeps the host engine")
            .resize(&shape, nblocks, npacks);
        self.hybrid = Some(HybridPartition::new(self.sp.hybrid_split));
        self.hybrid_assign();
        Ok(())
    }

    /// (Re)draw the pack → space assignment from scratch (startup, regrid,
    /// rebalance, restore — pack identities changed, measurements reset).
    /// Host-assigned packs are marked dirty: their containers are
    /// authoritative; the staging gathered for the device is stale for
    /// them until they migrate back.
    pub(crate) fn hybrid_assign(&mut self) {
        let npacks = self.mesh_data.npacks();
        let costs = self.mesh_data.pack_costs(&self.mesh);
        let nworkers = self.host.as_ref().map_or(1, |h| h.nworkers());
        let Some(hp) = self.hybrid.as_mut() else { return };
        hp.reset(npacks);
        let spaces = hp.assign(&costs, self.device.is_some(), nworkers);
        let to_host: Vec<usize> = spaces
            .iter()
            .enumerate()
            .filter(|(_, s)| **s == PackSpace::Host)
            .map(|(pi, _)| pi)
            .collect();
        self.mesh_data.set_pack_spaces(spaces);
        self.mesh_data.mark_packs_dirty(&to_host);
    }

    /// Re-partition packs between the spaces from the measured cost EWMAs
    /// (at the `parthenon/loadbalance interval` cadence). A migrating pack
    /// is re-staged exactly ONCE, in the direction it moves:
    ///
    /// * host → device: gather its containers into staging, then pre-fill
    ///   the staged ghost inbox from the (just-exchanged) container ghosts
    ///   so the device's first unpack is a bitwise no-op;
    /// * device → host: unpack the staged ghost inbox into the staged
    ///   interior+ghosts, then scatter to the containers and mark dirty.
    pub(crate) fn hybrid_repartition(&mut self) -> Result<()> {
        if self.device.is_none() {
            return Ok(());
        }
        let old = self.mesh_data.pack_spaces().to_vec();
        let costs = self.mesh_data.pack_costs(&self.mesh);
        let nworkers = self.host.as_ref().map_or(1, |h| h.nworkers());
        let new = {
            let Some(hp) = self.hybrid.as_ref() else { return Ok(()) };
            hp.assign(&costs, true, nworkers)
        };
        if new == old {
            return Ok(());
        }
        let to_dev: Vec<usize> = (0..old.len())
            .filter(|&pi| old[pi] == PackSpace::Host && new[pi] == PackSpace::Device)
            .collect();
        let to_host: Vec<usize> = (0..old.len())
            .filter(|&pi| old[pi] == PackSpace::Device && new[pi] == PackSpace::Host)
            .collect();
        let dev = self.device.as_ref().expect("checked above");
        // device → host: staged ghosts land in the staged state first, so
        // the subsequent scatter writes fully-exchanged blocks.
        if !to_host.is_empty() {
            let (descs, staging) = self.mesh_data.parts_mut();
            for &pi in &to_host {
                dev.stage_out_pack(&descs[pi], &mut staging[pi]);
            }
            self.mesh_data.scatter_packs(&mut self.mesh, CONS, &to_host)?;
        }
        // host → device: containers are authoritative; gather them and
        // pre-fill the staged inbox from the container ghosts.
        if !to_dev.is_empty() {
            self.mesh_data.gather_packs(&self.mesh, CONS, &to_dev)?;
            let dev = self.device.as_ref().expect("checked above");
            let (descs, staging) = self.mesh_data.parts_mut();
            for &pi in &to_dev {
                dev.stage_in_pack(&descs[pi], &mut staging[pi]);
            }
        }
        self.mesh_data.set_pack_spaces(new);
        self.mesh_data.mark_packs_dirty(&to_host);
        self.fused_dt_local = None;
        self.fused_dt_global = None;
        self.hybrid_stats.restagings += (to_dev.len() + to_host.len()) as u64;
        self.hybrid_stats.repartitions += 1;
        Ok(())
    }

    pub(crate) fn is_multilevel(&self) -> bool {
        self.mesh.tree.max_level() > 0
    }

    // -- outputs --------------------------------------------------------------

    pub(crate) fn maybe_output(&mut self, force: bool) -> Result<()> {
        let fire_output =
            self.sp.output_dt > 0.0 && (force || self.time + 1e-12 >= self.next_output);
        let fire_history =
            self.sp.history_dt > 0.0 && (force || self.time + 1e-12 >= self.next_history);
        if fire_output || fire_history {
            // Both consumers read the block containers; on the Device path
            // staging is authoritative between outputs, so scatter once.
            self.sync_device_to_blocks()?;
        }
        if fire_output {
            self.fill_derived();
            let path = format!(
                "{}/{}.{:05}.pbin",
                self.sp.out_dir, "parthenon", self.output_idx
            );
            crate::io::write_snapshot(
                &self.mesh,
                &self.comm_coll,
                self.time,
                self.cycle,
                self.dt,
                &[CONS.to_string()],
                &path,
            )?;
            self.output_idx += 1;
            while self.next_output <= self.time {
                self.next_output += self.sp.output_dt;
            }
        }
        if fire_history {
            let sums = self.history_sums();
            let glob = self.comm_coll.allreduce_vec(&sums, ReduceOp::Sum);
            if self.mesh.my_rank == 0 {
                let path = format!("{}/parthenon.hst", self.sp.out_dir);
                crate::io::append_history(&path, self.time, self.cycle, &glob)?;
            }
            while self.next_history <= self.time {
                self.next_history += self.sp.history_dt;
            }
        }
        Ok(())
    }

    /// Volume-integrated (mass, momx, KE, total E) over local blocks.
    pub fn history_sums(&self) -> Vec<f64> {
        let shape = self.mesh.cfg.index_shape();
        let mut out = vec![0.0f64; 4];
        for b in &self.mesh.blocks {
            let vol = b.coords.cell_volume();
            let arr = b.data.get(CONS).expect("cons");
            let u = arr.as_slice();
            let n = shape.ncells_total();
            let (nt0, nt1) = (shape.nt(0), shape.nt(1));
            for k in shape.is_(2)..shape.ie(2) {
                for j in shape.is_(1)..shape.ie(1) {
                    for i in shape.is_(0)..shape.ie(0) {
                        let c = (k * nt1 + j) * nt0 + i;
                        let rho = u[c] as f64;
                        let mx = u[n + c] as f64;
                        let my = u[2 * n + c] as f64;
                        let mz = u[3 * n + c] as f64;
                        let e = u[4 * n + c] as f64;
                        out[0] += rho * vol;
                        out[1] += mx * vol;
                        out[2] += 0.5 * (mx * mx + my * my + mz * mz) / rho.max(1e-30) * vol;
                        out[3] += e * vol;
                    }
                }
            }
        }
        out
    }
}

/// Fine side of the flux correction for ONE block: restrict the boundary
/// face fluxes toward every coarser face neighbor and isend them. Operates
/// on the shared exchange topology so per-pack tasks can send from worker
/// threads (the fused stage pipeline); `HydroSim::flux_corr_send` wraps it
/// for the phased path.
pub(crate) fn flux_corr_send_block(
    t: &bvals::ExchTopo,
    comm_flux: &Comm,
    loc: &LogicalLocation,
    fx: &FluxArrays,
) {
    let shape = t.shape;
    let dim = shape.dim;
    for nb in t.tree.find_neighbors(loc) {
        // faces only
        let nonzero = (0..3).filter(|&d| nb.offset[d] != 0).count();
        if nonzero != 1 {
            continue;
        }
        let NeighborKind::Coarser(cloc) = &nb.kind else { continue };
        let d = (0..3).find(|&d| nb.offset[d] != 0).unwrap();
        let side = if nb.offset[d] < 0 { 0 } else { 1 };
        let face_idx = if side == 0 { 0 } else { shape.n[d] };
        // restrict tangentially: coarse (tj, tk) <- mean of fine 2x2 (or
        // 2 in 2D). Tangential axes = all active axes != d.
        let mut payload = Vec::new();
        let tdims: Vec<usize> = (0..dim).filter(|&a| a != d).collect();
        let tlen: Vec<usize> =
            tdims.iter().map(|&a| shape.n[a] / 2).collect();
        for v in 0..crate::NHYDRO {
            match dim {
                1 => payload.push(fx.f[d][fx.idx(d, v, 0, 0, face_idx)]),
                2 => {
                    let a = tdims[0];
                    for t in 0..tlen[0] {
                        let mut s = 0.0;
                        for dt in 0..2 {
                            let tt = 2 * t + dt;
                            let (k, j, i) = match (d, a) {
                                (0, 1) => (0, tt, face_idx),
                                (1, 0) => (0, face_idx, tt),
                                _ => unreachable!(),
                            };
                            s += fx.f[d][fx.idx(d, v, k, j, i)];
                        }
                        payload.push(s * 0.5);
                    }
                }
                _ => {
                    // 3D: tangential axes in ascending order (a1 < a2)
                    let (a1, a2) = (tdims[0], tdims[1]);
                    for t2 in 0..tlen[1] {
                        for t1 in 0..tlen[0] {
                            let mut s = 0.0;
                            for d2 in 0..2 {
                                for d1 in 0..2 {
                                    let u1 = 2 * t1 + d1;
                                    let u2 = 2 * t2 + d2;
                                    let mut kji = [0usize; 3]; // (i,j,k)
                                    kji[d] = face_idx;
                                    kji[a1] = u1;
                                    kji[a2] = u2;
                                    s += fx.f[d]
                                        [fx.idx(d, v, kji[2], kji[1], kji[0])];
                                }
                            }
                            payload.push(s * 0.25);
                        }
                    }
                }
            }
        }
        let cgid = t.tree.gid_of(cloc).unwrap();
        let face = 2 * d + (1 - side); // coarse block's face (opposite side)
        let child = ((loc.lx[0] & 1)
            | ((loc.lx[1] & 1) << 1)
            | ((loc.lx[2] & 1) << 2)) as usize;
        let tag = tags::flux_tag(cgid, face, child);
        comm_flux.isend(t.ranks[cgid], tag, Payload::F32(payload));
    }
}

/// Coarse side: the flux corrections the given blocks expect this stage.
/// `FluxRecv::block` indices are `base + slice index` (mesh-global when the
/// caller passes the full block list with `base == 0`, pack-global when a
/// fused per-pack task registers its own disjoint slice).
pub(crate) fn flux_corr_pending_blocks(
    t: &bvals::ExchTopo,
    blocks: &[MeshBlock],
    base: usize,
) -> Vec<FluxRecv> {
    let shape = t.shape;
    let dim = shape.dim;
    let mut out = Vec::new();
    for (i, b) in blocks.iter().enumerate() {
        let bi = base + i;
        for nb in t.tree.find_neighbors(&b.loc) {
            let nonzero = (0..3).filter(|&d| nb.offset[d] != 0).count();
            if nonzero != 1 {
                continue;
            }
            let NeighborKind::Finer(fines) = &nb.kind else { continue };
            let d = (0..3).find(|&d| nb.offset[d] != 0).unwrap();
            let side = if nb.offset[d] < 0 { 0 } else { 1 };
            let face_idx = if side == 0 { 0 } else { shape.n[d] };
            let face = 2 * d + side;
            for floc in fines {
                let child = ((floc.lx[0] & 1)
                    | ((floc.lx[1] & 1) << 1)
                    | ((floc.lx[2] & 1) << 2)) as usize;
                let mut t_start = [0usize; 3];
                let mut t_len = [1usize; 3];
                for a in 0..dim {
                    if a == d {
                        continue;
                    }
                    let bit = (floc.lx[a] & 1) as usize;
                    t_start[a] = bit * shape.n[a] / 2;
                    t_len[a] = shape.n[a] / 2;
                }
                let fgid = t.tree.gid_of(floc).unwrap();
                out.push(FluxRecv {
                    block: bi,
                    src: t.ranks[fgid],
                    tag: tags::flux_tag(b.gid, face, child),
                    d,
                    face_idx,
                    t_start,
                    t_len,
                });
            }
        }
    }
    out
}

/// Poll a pending-correction list, applying arrivals into `flux` (indexed
/// by `FluxRecv::block - base`, so a per-pack task polls its own disjoint
/// flux slice). True when the list has drained.
pub(crate) fn flux_corr_poll_pending(
    comm_flux: &Comm,
    dim: usize,
    pending: &mut Vec<FluxRecv>,
    flux: &mut [FluxArrays],
    base: usize,
) -> Result<bool> {
    let mut i = 0;
    while i < pending.len() {
        let p = &pending[i];
        if let Some(payload) = comm_flux.try_recv(p.src, p.tag)? {
            let data = payload.into_f32()?;
            let p = pending.swap_remove(i);
            apply_flux_correction(&mut flux[p.block - base], &p, dim, &data);
        } else {
            i += 1;
        }
    }
    Ok(pending.is_empty())
}

/// Apply one received flux correction to a coarse block's flux array.
fn apply_flux_correction(fx: &mut FluxArrays, p: &FluxRecv, dim: usize, data: &[Real]) {
    let d = p.d;
    let mut r = 0usize;
    let tdims: Vec<usize> = (0..dim).filter(|&a| a != d).collect();
    for v in 0..crate::NHYDRO {
        match dim {
            1 => {
                let idx = fx.idx(d, v, 0, 0, p.face_idx);
                fx.f[d][idx] = data[r];
                r += 1;
            }
            2 => {
                let a = tdims[0];
                for t in 0..p.t_len[a] {
                    let tt = p.t_start[a] + t;
                    let (k, j, i) = match (d, a) {
                        (0, 1) => (0, tt, p.face_idx),
                        (1, 0) => (0, p.face_idx, tt),
                        _ => unreachable!(),
                    };
                    let idx = fx.idx(d, v, k, j, i);
                    fx.f[d][idx] = data[r];
                    r += 1;
                }
            }
            _ => {
                let (a1, a2) = (tdims[0], tdims[1]);
                for t2 in 0..p.t_len[a2] {
                    for t1 in 0..p.t_len[a1] {
                        let mut kji = [0usize; 3]; // (i,j,k)
                        kji[d] = p.face_idx;
                        kji[a1] = p.t_start[a1] + t1;
                        kji[a2] = p.t_start[a2] + t2;
                        let idx = fx.idx(d, v, kji[2], kji[1], kji[0]);
                        fx.f[d][idx] = data[r];
                        r += 1;
                    }
                }
            }
        }
    }
    debug_assert_eq!(r, data.len());
}

impl HydroSim {
    /// Whether the time loop has more cycles to run (the [`Driver::execute`]
    /// loop condition, also polled per session by
    /// [`crate::service::Engine::step`]).
    pub fn running(&self) -> bool {
        self.time < self.sp.tlim
            && (self.sp.nlim < 0 || (self.cycle as i64) < self.sp.nlim)
    }

    /// Top-of-cycle bookkeeping, split out of [`EvolutionDriver::step`] so
    /// the multiplexed [`crate::service::Engine`] can run it per session
    /// before merging every tenant's cycle into one region. Simulated rank
    /// death fires here, BEFORE this cycle's checkpoint could be written —
    /// so recovery must resume from an earlier durable snapshot. Returns
    /// the dt this cycle advances by.
    pub(crate) fn pre_step(&mut self) -> Result<Real> {
        self.world.check_kill(self.mesh.my_rank, self.cycle)?;
        Ok(self.dt as Real)
    }

    /// Everything after the cycle's task region: advance clocks, fold the
    /// dt reduction, cost EWMAs, AMR / balance / hybrid repartition
    /// cadences, durable checkpoints, and throughput accounting. `elapsed`
    /// is the wall time of the cycle (under the service engine: of the
    /// whole merged cycle).
    pub(crate) fn post_step(&mut self, elapsed: f64) -> Result<()> {
        self.time += self.dt;
        self.cycle += 1;
        self.dt = self.reduce_dt();

        // Measured per-block seconds -> cost EWMA (before regrid/rebalance
        // so this cycle's measurements inform this cycle's distribution).
        self.update_block_costs();

        // AMR — in every exec space. With a Device engine up, staging is
        // authoritative: sync it into the containers first (refinement
        // criteria and the regrid restrict/prolong read containers), tear
        // the engine down across the tree change (the rebuild invariant),
        // and bring it back up on the new mesh. An unchanged tree restores
        // the engine untouched — `check_and_regrid` returns before
        // mutating anything in that case.
        if self.mesh.cfg.adaptive
            && self.cycle % self.mesh.cfg.check_interval as u64 == 0
        {
            if self.device.is_some() {
                self.sync_device_full()?;
                let dev = self.device.take();
                let changed = regrid::check_and_regrid(self)?;
                if changed {
                    drop(dev);
                    self.rebuild_device_engine()?;
                } else {
                    self.device = dev;
                }
            } else {
                regrid::check_and_regrid(self)?;
            }
        }

        // Cost-driven load balance on a fixed tree (opt-in; AMR regrids
        // already rebalance). Runs on every rank at the same cycle — the
        // cost allgather is a collective.
        if self.sp.lb_interval > 0
            && self.cycle % self.sp.lb_interval as u64 == 0
            && !self.mesh.cfg.adaptive
        {
            regrid::check_and_rebalance(self)?;
        }

        // Hybrid re-partition between the spaces, at the same cadence as
        // the inter-rank balancer (after it, so the assignment is drawn
        // against the post-migration pack plan).
        if self.sp.exec == ExecSpace::Hybrid
            && self.sp.lb_interval > 0
            && self.cycle % self.sp.lb_interval as u64 == 0
        {
            self.hybrid_repartition()?;
        }

        // Durable checkpoint (atomic tmp+rename) on the configured cadence:
        // the recovery loop restarts from the last one of these.
        if self.sp.checkpoint_interval > 0
            && self.cycle % self.sp.checkpoint_interval as u64 == 0
        {
            let path = self.sp.checkpoint_path.clone();
            self.write_restart(&path)?;
        }

        self.zc.record_cycle(self.global_zones(), elapsed);
        Ok(())
    }
}

impl Driver for HydroSim {
    fn execute(&mut self) -> Result<()> {
        self.maybe_output(true)?;
        while self.running() {
            self.step()?;
            self.maybe_output(false)?;
            if !self.sp.quiet && self.mesh.my_rank == 0 && self.cycle % 50 == 0 {
                eprintln!(
                    "cycle {:6}  time {:.5e}  dt {:.5e}  blocks {}",
                    self.cycle,
                    self.time,
                    self.dt,
                    self.mesh.tree.nblocks()
                );
            }
        }
        self.maybe_output(true)?;
        Ok(())
    }
}

impl EvolutionDriver for HydroSim {
    fn time(&self) -> f64 {
        self.time
    }

    fn cycle(&self) -> u64 {
        self.cycle
    }

    fn step(&mut self) -> Result<()> {
        let t0 = std::time::Instant::now();
        let dt = self.pre_step()?;

        // One cycle through the merged task region (take-dance so the
        // producers can borrow the rest of the sim).
        {
            let mut h = self.host.take();
            let mut d = self.device.take();
            let r = run_cycle(self, h.as_mut(), d.as_mut(), dt);
            self.host = h;
            self.device = d;
            r?;
        }

        self.post_step(t0.elapsed().as_secs_f64())
    }
}

impl MultiStageDriver for HydroSim {
    fn num_stages(&self) -> usize {
        2
    }
}

/// Launch an N-rank simulation of `input`, returning per-rank zone-cycles/s
/// (joined). The standard entry point for the CLI, examples and benches.
/// Overrides arrive already parsed ([`Override`]) — a malformed CLI spec is
/// an [`Error::Config`] at the program edge, before any rank launches.
pub fn run_simulation(
    input: &str,
    overrides: &[Override],
    nranks: usize,
) -> Result<Vec<f64>> {
    use std::sync::Mutex;
    let results: std::sync::Arc<Mutex<Vec<f64>>> =
        std::sync::Arc::new(Mutex::new(vec![0.0; nranks]));
    let input = input.to_string();
    let overrides = overrides.to_vec();
    let res2 = results.clone();
    World::launch(nranks, move |rank, world| {
        let mut pin = ParameterInput::from_str(&input).expect("parse input");
        for ov in &overrides {
            pin.apply(ov);
        }
        let mut sim = HydroSim::new(pin, rank, world).expect("build sim");
        sim.execute().expect("run sim");
        res2.lock().unwrap()[rank] = sim.zc.zcps();
    });
    Ok(std::sync::Arc::try_unwrap(results)
        .map(|m| m.into_inner().unwrap())
        .unwrap_or_default())
}
