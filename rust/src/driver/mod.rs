//! Drivers (paper Sec. 3.11): the base [`Driver`] trait, the evolution loop,
//! and [`HydroSim`] — the PARTHENON-HYDRO application driver that weaves
//! package tasks into task collections, reduces the timestep, runs AMR and
//! load balancing, and writes outputs.
//!
//! Both execution spaces run through the shared pack-centric layer: the
//! cycle loop is generic over [`StageExecutor`], and both executors consume
//! the same cached [`MeshData`] pack partition (built once, invalidated
//! only on regrid / load balance / restart):
//! * [`HostExec`] — native Rust solver on a scoped-thread worker pool over
//!   packs; supports everything (AMR, multilevel meshes with flux
//!   correction, all BCs).
//! * [`DeviceState`] — artifact launches per pack through the runtime, with
//!   the three buffer packing strategies of Fig. 8; uniform periodic meshes
//!   (the configuration of every performance experiment in the paper).

pub mod bench;
mod device;
mod host;
pub mod recover;
pub mod regrid;

pub use device::DeviceState;
pub use host::{HostExec, OverlapStats};
pub use recover::{run_recoverable, RecoveryReport};

use crate::bvals::{self, PackStrategy};
use crate::comm::{tags, CollMode, Comm, FaultConfig, Payload, ReduceOp, World};
use crate::config::ParameterInput;
use crate::error::{Error, Result};
use crate::hydro::native::{self, FluxArrays, StageCoeffs, RK2_STAGES};
use crate::hydro::problems::{self, Problem};
use crate::hydro::{HydroPackage, CONS};
use crate::mesh::{LogicalLocation, Mesh, MeshBlock, MeshConfig, NeighborKind};
use crate::mesh_data::MeshData;
use crate::metrics::{Ewma, RebalanceStats, Timers, ZoneCycles};
use crate::util::backoff::ProgressWait;
use crate::util::stealing::StealPolicy;
use crate::vars::{resolve_packages, Package};
use crate::Real;

/// EWMA weight for folding measured per-block cycle seconds into
/// [`crate::mesh::MeshBlock::cost`] (fast enough to track AMR-driven cost
/// shifts, smooth enough to ignore one-cycle jitter).
const COST_EWMA_ALPHA: f64 = 0.3;

/// Where the hydro stage executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecSpace {
    Host,
    Device,
}

/// How the stage phases are scheduled (`parthenon/exec overlap`).
///
/// * `Fused` (default) — phases 1–4 run as ONE per-pack task list:
///   prim-recovery/fluxes → flux-correction → stage combine → post sends,
///   then receives are polled as `Incomplete` tasks, so pack A's boundary
///   exchange overlaps pack B's compute (the paper's comm/compute overlap).
/// * `Phased` — the barrier-phased loop (all fluxes, then all corrections,
///   then all combines, then the exchange). Kept as the bitwise-identity
///   oracle: both modes must produce identical results
///   (`rust/tests/overlap_fused.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverlapMode {
    Phased,
    Fused,
}

impl OverlapMode {
    /// Parse the `parthenon/exec overlap` input value.
    pub fn parse(s: &str) -> Option<OverlapMode> {
        match s {
            "phased" | "barrier" => Some(OverlapMode::Phased),
            "fused" | "overlap" => Some(OverlapMode::Fused),
            _ => None,
        }
    }
}

/// How a fixed-tree rebalance migrates data (`parthenon/loadbalance mode`).
///
/// * `Incremental` (default) — compute the [`crate::balance::MigrationPlan`]
///   delta, migrate ONLY the blocks that change owner, keep every other
///   container (and resident device staging) in place, refresh ghosts /
///   routing for exactly the affected blocks, and re-gather only the dirty
///   packs.
/// * `Full` — tear down every local container and re-fill from a stash /
///   the migration payloads, then run a whole-mesh ghost exchange. Kept as
///   the bitwise-identity oracle: both modes must produce identical state,
///   dt bits and cost EWMAs (`rust/tests/rebalance_incremental.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RebalanceMode {
    Full,
    Incremental,
}

impl RebalanceMode {
    /// Parse the `parthenon/loadbalance mode` input value.
    pub fn parse(s: &str) -> Option<RebalanceMode> {
        match s {
            "full" | "rebuild" => Some(RebalanceMode::Full),
            "incremental" | "delta" => Some(RebalanceMode::Incremental),
            _ => None,
        }
    }
}

/// Base driver abstraction (paper Sec. 3.11): applications implement
/// `execute`; [`EvolutionDriver`] adds the time loop; [`MultiStageDriver`]
/// is realized by [`HydroSim`]'s per-stage task collections.
pub trait Driver {
    fn execute(&mut self) -> Result<()>;
}

/// Drivers that advance a solution in time.
pub trait EvolutionDriver: Driver {
    fn time(&self) -> f64;
    fn cycle(&self) -> u64;
    /// Advance one timestep.
    fn step(&mut self) -> Result<()>;
}

/// Multi-stage (RK) drivers: one task collection per stage.
pub trait MultiStageDriver: EvolutionDriver {
    fn num_stages(&self) -> usize;
}

/// One execution space's stage engine. Implementations consume the shared
/// [`MeshData`] pack partition; the cycle loop ([`HydroSim::step`]) is
/// generic over this trait, so Host and Device share one driver shape.
pub trait StageExecutor {
    /// Snapshot the cycle-start state u0 (per pack / per block).
    fn begin_cycle(&mut self, sim: &mut HydroSim) -> Result<()>;
    /// Run one RK stage (`si` = stage index) including its boundary
    /// communication.
    fn stage(&mut self, sim: &mut HydroSim, co: StageCoeffs, si: usize, dt: Real)
        -> Result<()>;
    /// This rank's raw CFL dt after the last cycle (already scaled by the
    /// package CFL number).
    fn local_dt(&self, sim: &HydroSim) -> f64;
}

/// One full cycle (all RK stages) through an executor — the single code
/// path both execution spaces run.
pub(crate) fn run_cycle<E: StageExecutor>(
    sim: &mut HydroSim,
    exec: &mut E,
    dt: Real,
) -> Result<()> {
    sim.mesh_data.validate(&sim.mesh)?;
    exec.begin_cycle(sim)?;
    for (si, co) in RK2_STAGES.iter().enumerate() {
        exec.stage(sim, *co, si, dt)?;
    }
    Ok(())
}

/// The end-of-stage ghost exchange of the conserved state, expressed as
/// per-pack task lists (one list per MeshBlockPack). Under a stealing
/// schedule the lists run on the worker pool; under `sched = static` (or a
/// single worker) they are polled serially on the driver thread.
pub(crate) fn run_stage_exchange(
    sim: &mut HydroSim,
    nworkers: usize,
    policy: StealPolicy,
) -> Result<()> {
    let ranges = sim.mesh_data.block_ranges();
    bvals::exchange_tasked_parallel(
        &mut sim.mesh,
        &sim.comm_cons,
        CONS,
        Some([native::IM1, native::IM2, native::IM3]),
        &ranges,
        nworkers,
        policy,
    )
}

/// Simulation parameters parsed from the input file + CLI.
#[derive(Debug, Clone)]
pub struct SimParams {
    pub problem: Problem,
    pub tlim: f64,
    pub nlim: i64,
    pub exec: ExecSpace,
    pub strategy: PackStrategy,
    pub pack_size: usize,
    /// Host worker-thread count (0 = auto from hardware parallelism).
    pub nworkers: usize,
    /// Host pack scheduler: work-stealing (default) or static ranges.
    pub sched: StealPolicy,
    /// Stage scheduling: fused per-pack pipeline (default) or the
    /// barrier-phased oracle.
    pub overlap: OverlapMode,
    /// Cycles between cost-driven load-balance checks (0 = off; AMR runs
    /// rebalance inside regrid anyway).
    pub lb_interval: i64,
    /// Fixed-tree migration strategy (`parthenon/loadbalance mode`,
    /// default incremental; `full` is the bitwise-identity oracle).
    pub lb_mode: RebalanceMode,
    /// Collective algorithm (`parthenon/comm coll`, default tree; `flat`
    /// is the bulk-synchronous bitwise oracle). Tree also enables the
    /// overlapped dt reduction inside the fused final stage.
    pub coll: CollMode,
    pub impl_: String,
    pub output_dt: f64,
    pub history_dt: f64,
    pub out_dir: String,
    pub quiet: bool,
    /// Seed-driven fault-injection plan (`parthenon/fault`, default: all
    /// off). Installed on the World before the rank's first communication.
    pub fault: FaultConfig,
    /// Cycles between durable checkpoints (`parthenon/job
    /// checkpoint_interval`, 0 = off). Checkpoints are written atomically
    /// (tmp + rename), so a crash mid-write never loses the previous one.
    pub checkpoint_interval: i64,
    /// Checkpoint target (`parthenon/job checkpoint_path`, default
    /// `<out_dir>/parthenon.chk.pbin`).
    pub checkpoint_path: String,
}

impl SimParams {
    pub fn from_input(pin: &mut ParameterInput) -> Result<SimParams> {
        let problem_s = pin.str_or("parthenon/job", "problem", "uniform");
        let problem = Problem::parse(&problem_s)
            .ok_or_else(|| Error::config(format!("unknown problem {problem_s:?}")))?;
        let exec = match pin.str_or("parthenon/exec", "space", "host").as_str() {
            "host" => ExecSpace::Host,
            "device" => ExecSpace::Device,
            other => return Err(Error::config(format!("unknown exec space {other:?}"))),
        };
        let strategy_s = pin.str_or(
            "parthenon/exec",
            "strategy",
            if exec == ExecSpace::Device { "perpack" } else { "native" },
        );
        let strategy = PackStrategy::parse(&strategy_s)
            .ok_or_else(|| Error::config(format!("unknown strategy {strategy_s:?}")))?;
        let sched_s = pin.str_or("parthenon/exec", "sched", "stealing");
        let sched = StealPolicy::parse(&sched_s)
            .ok_or_else(|| Error::config(format!("unknown scheduler {sched_s:?}")))?;
        let overlap_s = pin.str_or("parthenon/exec", "overlap", "fused");
        let overlap = OverlapMode::parse(&overlap_s)
            .ok_or_else(|| Error::config(format!("unknown overlap mode {overlap_s:?}")))?;
        let lb_mode_s = pin.str_or("parthenon/loadbalance", "mode", "incremental");
        let lb_mode = RebalanceMode::parse(&lb_mode_s)
            .ok_or_else(|| Error::config(format!("unknown loadbalance mode {lb_mode_s:?}")))?;
        let coll_s = pin.str_or("parthenon/comm", "coll", "tree");
        let coll = CollMode::parse(&coll_s)
            .ok_or_else(|| Error::config(format!("unknown coll mode {coll_s:?}")))?;
        let out_dir = pin.str_or("parthenon/job", "out_dir", ".");
        let default_chk = format!("{out_dir}/parthenon.chk.pbin");
        Ok(SimParams {
            problem,
            tlim: pin.real_or("parthenon/time", "tlim", 1.0),
            nlim: pin.int_or("parthenon/time", "nlim", -1),
            exec,
            strategy,
            pack_size: pin.int_or("parthenon/exec", "pack_size", 16) as usize,
            nworkers: pin.int_or("parthenon/exec", "nworkers", 0).max(0) as usize,
            sched,
            overlap,
            lb_interval: pin.int_or("parthenon/loadbalance", "interval", 0),
            lb_mode,
            coll,
            impl_: pin.str_or("parthenon/exec", "impl", "jnp"),
            output_dt: pin.real_or("parthenon/output0", "dt", -1.0),
            history_dt: pin.real_or("parthenon/history", "dt", -1.0),
            out_dir,
            quiet: pin.bool_or("parthenon/job", "quiet", false),
            fault: FaultConfig::from_input(pin),
            checkpoint_interval: pin.int_or("parthenon/job", "checkpoint_interval", 0),
            checkpoint_path: pin.str_or("parthenon/job", "checkpoint_path", &default_chk),
        })
    }
}

/// Pending flux-correction receive on a coarse block.
struct FluxRecv {
    block: usize,
    src: usize,
    tag: u64,
    d: usize,
    face_idx: usize,
    t_start: [usize; 3], // tangential coarse start (per axis; normal unused)
    t_len: [usize; 3],
}

/// The PARTHENON-HYDRO application driver for one rank.
pub struct HydroSim {
    pub pin: ParameterInput,
    pub mesh: Mesh,
    /// Cached pack partition + staging, shared by both execution spaces.
    pub mesh_data: MeshData,
    pub pkg: HydroPackage,
    pub sp: SimParams,
    pub world: World,
    comm_cons: Comm,
    comm_flux: Comm,
    comm_coll: Comm,
    pub device: Option<DeviceState>,
    pub host: Option<HostExec>,
    flux_pending: Vec<FluxRecv>,
    pub time: f64,
    pub cycle: u64,
    pub dt: f64,
    pub timers: Timers,
    pub zc: ZoneCycles,
    /// Migration / re-gather accounting of the load balancer — tests and
    /// the regrid bench lane assert the incremental path touches only the
    /// delta (a no-op rebalance leaves every counter untouched).
    pub lb_stats: RebalanceStats,
    output_idx: usize,
    next_output: f64,
    next_history: f64,
}

impl HydroSim {
    pub fn new(mut pin: ParameterInput, rank: usize, world: World) -> Result<HydroSim> {
        let cfg = MeshConfig::from_params(&mut pin)?;
        let pkg = HydroPackage::initialize(&mut pin);
        let sp = SimParams::from_input(&mut pin)?;
        let fields = resolve_packages(&[pkg.descriptor()])?;
        // Install the fault plan before this rank's first send/recv: the
        // checksum-framing decision must be uniform across every message a
        // rank ever handles (comm::fault's framing invariant).
        world.install_faults(sp.fault.clone());
        let mut mesh = Mesh::build(cfg, fields, rank, world.size());

        // Problem generation on every local block.
        for mb in &mut mesh.blocks {
            problems::generate(sp.problem, mb, &mut pin, pkg.gamma)?;
        }

        let comm_cons = world.comm(rank, tags::COMM_BVALS_BASE);
        let comm_flux = world.comm(rank, tags::COMM_FLUX);
        let comm_coll = world.comm(rank, 0).with_coll(sp.coll);
        let mesh_data = MeshData::build(&mesh, sp.pack_size, None);

        let mut sim = HydroSim {
            pin,
            mesh,
            mesh_data,
            pkg,
            sp,
            world,
            comm_cons,
            comm_flux,
            comm_coll,
            device: None,
            host: None,
            flux_pending: Vec::new(),
            time: 0.0,
            cycle: 0,
            dt: 0.0,
            timers: Timers::default(),
            zc: ZoneCycles::default(),
            lb_stats: RebalanceStats::default(),
            output_idx: 0,
            next_output: 0.0,
            next_history: 0.0,
        };
        sim.rebuild_work_buffers();

        // Initial ghost fill + derived fill.
        bvals::exchange_blocking(
            &mut sim.mesh,
            &sim.comm_cons,
            CONS,
            Some([native::IM1, native::IM2, native::IM3]),
        )?;
        sim.fill_derived();

        if sim.sp.exec == ExecSpace::Device {
            let dev = DeviceState::new(&mut sim)?;
            sim.device = Some(dev);
        }

        // Initial timestep.
        sim.dt = sim.reduce_dt();
        Ok(sim)
    }

    /// Restore state from a snapshot (restart; paper Sec. 3.9). The mesh is
    /// rebuilt from the snapshot's leaves and redistributed over the CURRENT
    /// rank count by the load balancer, exactly like Parthenon's restart.
    pub fn restore_snapshot(&mut self, snap: &crate::io::Snapshot) -> Result<()> {
        use crate::balance;
        let tree = crate::mesh::BlockTree::from_leaves(
            self.mesh.cfg.nrb,
            self.mesh.cfg.dim,
            self.mesh.cfg.periodic_flags(),
            snap.leaves.clone(),
        );
        // The restart distribution must be identical on every rank, and a
        // rank only knows its OWN measured costs — so restarts seed from
        // the nominal (uniform) derivation; the EWMA re-measures within a
        // few cycles and the next regrid/rebalance uses the real costs.
        let costs = balance::derive_leaf_costs(
            tree.leaves(),
            &Default::default(),
            self.mesh.cfg.dim,
        );
        self.device = None; // routes/staging are stale; rebuilt below
        self.mesh.ranks = balance::assign_blocks(&costs, self.mesh.nranks);
        self.mesh.tree = tree;
        self.mesh.rebuild_local_blocks();
        self.rebuild_work_buffers();
        // The snapshot overwrites the block containers, so any preserved
        // staging no longer reflects them.
        self.mesh_data.mark_all_dirty();
        snap.restore_into(&mut self.mesh)?;
        self.time = snap.time;
        self.cycle = snap.cycle;
        self.dt = snap.dt;
        bvals::exchange_blocking(
            &mut self.mesh,
            &self.comm_cons,
            CONS,
            Some([native::IM1, native::IM2, native::IM3]),
        )?;
        self.fill_derived();
        if self.sp.exec == ExecSpace::Device {
            let dev = DeviceState::new(self)?;
            self.device = Some(dev);
        }
        Ok(())
    }

    /// Write a restart snapshot of the current state.
    pub fn write_restart(&mut self, path: &str) -> Result<()> {
        self.sync_device_to_blocks()?;
        crate::io::write_snapshot(
            &self.mesh,
            &self.comm_coll,
            self.time,
            self.cycle,
            self.dt,
            &[CONS.to_string()],
            path,
        )
    }

    /// Scatter device staging back into the block containers (no-op on the
    /// Host path, where the containers are authoritative).
    pub fn sync_device_to_blocks(&mut self) -> Result<()> {
        if self.device.is_some() {
            self.mesh_data.scatter(&mut self.mesh, CONS)?;
        }
        Ok(())
    }

    /// Rebuild the pack cache + per-block work buffers after mesh changes
    /// (regrid, load balance, restart). The single invalidation point: the
    /// pack plan is re-planned against the mesh's new version and the host
    /// executor's work arrays are resized.
    ///
    /// Precondition: the DeviceState must be torn down first (set
    /// `self.device = None`, then recreate it) — a rebuild under a live
    /// device would zero its staging without re-gathering and leave its
    /// routing tables sized for the old block set. Every caller honors
    /// this today (init, restart, regrid-is-host-only).
    pub(crate) fn rebuild_work_buffers(&mut self) {
        debug_assert!(
            self.device.is_none(),
            "tear down DeviceState before rebuild_work_buffers; recreate it \
             after so it re-plans the packs and re-gathers staging"
        );
        self.mesh_data.ensure_current(&self.mesh, None);
        // Host work arrays (fluxes, u0, u_new) are ~5x the conserved-state
        // footprint; Device runs never touch them, so only the Host
        // execution space pays for them.
        let shape = self.mesh.cfg.index_shape();
        self.host = if self.sp.exec == ExecSpace::Host {
            Some(HostExec::new(
                &shape,
                self.mesh.blocks.len(),
                self.mesh_data.npacks(),
                self.mesh.nranks,
                self.sp.nworkers,
                self.sp.sched,
            ))
        } else {
            None
        };
    }

    /// The incremental analog of [`HydroSim::rebuild_work_buffers`]: the
    /// pack plan was already re-drawn (preserving resident staging) by the
    /// caller, so only the host executor's per-block work arrays are
    /// resized in place — allocations for blocks that stayed are reused,
    /// and the worker count is re-resolved against the new pack count
    /// exactly like a fresh build (so full and incremental rebalances
    /// schedule identically). Same precondition as the full hook: on
    /// Device the DeviceState must be taken out first.
    pub(crate) fn resize_work_buffers(&mut self) {
        debug_assert!(
            self.device.is_none(),
            "take the DeviceState out before resize_work_buffers; its \
             routes/dts are refreshed by after_rebalance_incremental"
        );
        self.mesh_data.ensure_current(&self.mesh, None);
        if self.host.is_none() {
            // Device path (or first build): nothing to resize in place
            self.rebuild_work_buffers();
            return;
        }
        let shape = self.mesh.cfg.index_shape();
        let (nblocks, npacks) = (self.mesh.blocks.len(), self.mesh_data.npacks());
        self.host
            .as_mut()
            .expect("checked above")
            .resize(&shape, nblocks, npacks);
    }

    /// Fold the executor's measured per-block kernel seconds into the
    /// per-block cost EWMA ([`crate::mesh::MeshBlock::cost`]). Samples are
    /// normalized to the GLOBAL mean block seconds (sum-allreduced), never
    /// a rank-local mean — a rank-local mean would rescale every rank to
    /// 1.0 and erase exactly the inter-rank imbalance the load balancer
    /// needs to see. Every rank reaches the collective every cycle (ranks
    /// with no blocks contribute zeros; the exec space is uniform across
    /// ranks, so no rank is left waiting). Host measures per block; Device
    /// times each pack launch and spreads the sample evenly over the
    /// pack's blocks — so `parthenon/loadbalance interval` rebalances on
    /// MEASURED costs in both execution spaces.
    pub(crate) fn update_block_costs(&mut self) {
        let secs = if let Some(h) = self.host.as_mut() {
            h.drain_block_secs()
        } else if let Some(d) = self.device.as_mut() {
            d.drain_block_secs()
        } else {
            return;
        };
        let local = [secs.iter().sum::<f64>(), secs.len() as f64];
        let glob = self.comm_coll.allreduce_vec(&local, ReduceOp::Sum);
        let (gtotal, gcount) = (glob[0], glob[1]);
        if gtotal <= 0.0 || gcount <= 0.0 || secs.len() != self.mesh.blocks.len() {
            return; // nothing measured yet (or stale buffer length)
        }
        let gmean = gtotal / gcount;
        let ew = Ewma { alpha: COST_EWMA_ALPHA };
        for (b, s) in self.mesh.blocks.iter_mut().zip(&secs) {
            b.cost = ew.fold(b.cost, (s / gmean).max(1e-3));
        }
    }

    pub fn fill_derived(&mut self) {
        for mb in &mut self.mesh.blocks {
            self.pkg.fill_derived(&mut mb.data, &mb.coords);
        }
    }

    /// Recompute derived fields only for the given blocks (by gid) — the
    /// incremental rebalance refreshes exactly the migrated blocks; every
    /// other block's derived data is untouched and already consistent with
    /// its (unchanged) conserved state.
    pub(crate) fn fill_derived_for(&mut self, gids: &std::collections::HashSet<usize>) {
        for mb in &mut self.mesh.blocks {
            if gids.contains(&mb.gid) {
                self.pkg.fill_derived(&mut mb.data, &mb.coords);
            }
        }
    }

    /// Global zones (interior cells) across all ranks' blocks.
    pub fn global_zones(&self) -> u64 {
        (self.mesh.tree.nblocks() * self.mesh.cfg.index_shape().ncells_interior()) as u64
    }

    /// CFL timestep: executor-local estimate, min-reduced across ranks.
    /// In fused mode the local value was already produced INSIDE the final
    /// stage's task region (per-pack partial minima + one regional
    /// cross-list fold on both exec spaces), so no separate sweep over the
    /// blocks runs here; the phased oracle still sweeps (Host) or folds
    /// the staged per-block dts (Device). With tree collectives the fused
    /// final stage also posted the global `iallreduce(Min)` from inside
    /// the task region and drained it there (overlapped with the tail
    /// packs' boundary polls), so this just picks up the finished global
    /// value — no rank blocks here at all.
    pub fn reduce_dt(&mut self) -> f64 {
        if let Some(g) = self
            .device
            .as_mut()
            .and_then(|d| d.take_global_dt())
            .or_else(|| self.host.as_mut().and_then(|h| h.take_global_dt()))
        {
            return g;
        }
        let local = if let Some(dev) = &self.device {
            dev.local_dt(self)
        } else if let Some(h) = &self.host {
            h.local_dt(self)
        } else {
            self.mesh
                .blocks
                .iter()
                .map(|b| self.pkg.estimate_dt(&b.data, &b.coords))
                .fold(f64::INFINITY, f64::min)
        };
        self.comm_coll.allreduce(local, ReduceOp::Min)
    }

    // -- flux correction (native, multilevel) --------------------------------

    pub(crate) fn is_multilevel(&self) -> bool {
        self.mesh.tree.max_level() > 0
    }

    /// Fine side: restrict boundary face fluxes and send to the coarse
    /// neighbor (paper Sec. 3.7).
    pub(crate) fn flux_corr_send(&self, fx: &FluxArrays, bi: usize) {
        let t = bvals::ExchTopo::of(&self.mesh);
        flux_corr_send_block(&t, &self.comm_flux, &self.mesh.blocks[bi].loc, fx);
    }

    /// Coarse side: register expected flux corrections for this stage.
    pub(crate) fn flux_corr_post_recvs(&mut self) {
        let t = bvals::ExchTopo::of(&self.mesh);
        self.flux_pending = flux_corr_pending_blocks(&t, &self.mesh.blocks, 0);
    }

    /// Poll flux corrections; apply arrivals into `flux`. True when done.
    pub(crate) fn flux_corr_poll(&mut self, flux: &mut [FluxArrays]) -> Result<bool> {
        let dim = self.mesh.cfg.dim;
        flux_corr_poll_pending(&self.comm_flux, dim, &mut self.flux_pending, flux, 0)
    }

    /// Wait (bounded spin-then-backoff, progress-aware watchdog) until
    /// every registered flux correction has arrived and been applied.
    pub(crate) fn flux_corr_wait(&mut self, flux: &mut [FluxArrays]) -> Result<()> {
        let mut wait = ProgressWait::new(self.world.stall_limit());
        let mut remaining = self.flux_pending.len();
        loop {
            if self.flux_corr_poll(flux)? {
                return Ok(());
            }
            let now = self.flux_pending.len();
            let progressed = now < remaining;
            remaining = now;
            if !wait.step(progressed) {
                let e = Error::Timeout {
                    what: format!(
                        "flux correction ({} receives missing)",
                        self.flux_pending.len()
                    ),
                    rank: Some(self.mesh.my_rank),
                    peer: None,
                    tag: None,
                    elapsed: wait.idle_elapsed(),
                };
                self.world.escalate(self.mesh.my_rank, &e);
                return Err(e);
            }
        }
    }

    // -- outputs --------------------------------------------------------------

    fn maybe_output(&mut self, force: bool) -> Result<()> {
        let fire_output =
            self.sp.output_dt > 0.0 && (force || self.time + 1e-12 >= self.next_output);
        let fire_history =
            self.sp.history_dt > 0.0 && (force || self.time + 1e-12 >= self.next_history);
        if fire_output || fire_history {
            // Both consumers read the block containers; on the Device path
            // staging is authoritative between outputs, so scatter once.
            self.sync_device_to_blocks()?;
        }
        if fire_output {
            self.fill_derived();
            let path = format!(
                "{}/{}.{:05}.pbin",
                self.sp.out_dir, "parthenon", self.output_idx
            );
            crate::io::write_snapshot(
                &self.mesh,
                &self.comm_coll,
                self.time,
                self.cycle,
                self.dt,
                &[CONS.to_string()],
                &path,
            )?;
            self.output_idx += 1;
            while self.next_output <= self.time {
                self.next_output += self.sp.output_dt;
            }
        }
        if fire_history {
            let sums = self.history_sums();
            let glob = self.comm_coll.allreduce_vec(&sums, ReduceOp::Sum);
            if self.mesh.my_rank == 0 {
                let path = format!("{}/parthenon.hst", self.sp.out_dir);
                crate::io::append_history(&path, self.time, self.cycle, &glob)?;
            }
            while self.next_history <= self.time {
                self.next_history += self.sp.history_dt;
            }
        }
        Ok(())
    }

    /// Volume-integrated (mass, momx, KE, total E) over local blocks.
    pub fn history_sums(&self) -> Vec<f64> {
        let shape = self.mesh.cfg.index_shape();
        let mut out = vec![0.0f64; 4];
        for b in &self.mesh.blocks {
            let vol = b.coords.cell_volume();
            let arr = b.data.get(CONS).expect("cons");
            let u = arr.as_slice();
            let n = shape.ncells_total();
            let (nt0, nt1) = (shape.nt(0), shape.nt(1));
            for k in shape.is_(2)..shape.ie(2) {
                for j in shape.is_(1)..shape.ie(1) {
                    for i in shape.is_(0)..shape.ie(0) {
                        let c = (k * nt1 + j) * nt0 + i;
                        let rho = u[c] as f64;
                        let mx = u[n + c] as f64;
                        let my = u[2 * n + c] as f64;
                        let mz = u[3 * n + c] as f64;
                        let e = u[4 * n + c] as f64;
                        out[0] += rho * vol;
                        out[1] += mx * vol;
                        out[2] += 0.5 * (mx * mx + my * my + mz * mz) / rho.max(1e-30) * vol;
                        out[3] += e * vol;
                    }
                }
            }
        }
        out
    }
}

/// Fine side of the flux correction for ONE block: restrict the boundary
/// face fluxes toward every coarser face neighbor and isend them. Operates
/// on the shared exchange topology so per-pack tasks can send from worker
/// threads (the fused stage pipeline); `HydroSim::flux_corr_send` wraps it
/// for the phased path.
pub(crate) fn flux_corr_send_block(
    t: &bvals::ExchTopo,
    comm_flux: &Comm,
    loc: &LogicalLocation,
    fx: &FluxArrays,
) {
    let shape = t.shape;
    let dim = shape.dim;
    for nb in t.tree.find_neighbors(loc) {
        // faces only
        let nonzero = (0..3).filter(|&d| nb.offset[d] != 0).count();
        if nonzero != 1 {
            continue;
        }
        let NeighborKind::Coarser(cloc) = &nb.kind else { continue };
        let d = (0..3).find(|&d| nb.offset[d] != 0).unwrap();
        let side = if nb.offset[d] < 0 { 0 } else { 1 };
        let face_idx = if side == 0 { 0 } else { shape.n[d] };
        // restrict tangentially: coarse (tj, tk) <- mean of fine 2x2 (or
        // 2 in 2D). Tangential axes = all active axes != d.
        let mut payload = Vec::new();
        let tdims: Vec<usize> = (0..dim).filter(|&a| a != d).collect();
        let tlen: Vec<usize> =
            tdims.iter().map(|&a| shape.n[a] / 2).collect();
        for v in 0..crate::NHYDRO {
            match dim {
                1 => payload.push(fx.f[d][fx.idx(d, v, 0, 0, face_idx)]),
                2 => {
                    let a = tdims[0];
                    for t in 0..tlen[0] {
                        let mut s = 0.0;
                        for dt in 0..2 {
                            let tt = 2 * t + dt;
                            let (k, j, i) = match (d, a) {
                                (0, 1) => (0, tt, face_idx),
                                (1, 0) => (0, face_idx, tt),
                                _ => unreachable!(),
                            };
                            s += fx.f[d][fx.idx(d, v, k, j, i)];
                        }
                        payload.push(s * 0.5);
                    }
                }
                _ => {
                    // 3D: tangential axes in ascending order (a1 < a2)
                    let (a1, a2) = (tdims[0], tdims[1]);
                    for t2 in 0..tlen[1] {
                        for t1 in 0..tlen[0] {
                            let mut s = 0.0;
                            for d2 in 0..2 {
                                for d1 in 0..2 {
                                    let u1 = 2 * t1 + d1;
                                    let u2 = 2 * t2 + d2;
                                    let mut kji = [0usize; 3]; // (i,j,k)
                                    kji[d] = face_idx;
                                    kji[a1] = u1;
                                    kji[a2] = u2;
                                    s += fx.f[d]
                                        [fx.idx(d, v, kji[2], kji[1], kji[0])];
                                }
                            }
                            payload.push(s * 0.25);
                        }
                    }
                }
            }
        }
        let cgid = t.tree.gid_of(cloc).unwrap();
        let face = 2 * d + (1 - side); // coarse block's face (opposite side)
        let child = ((loc.lx[0] & 1)
            | ((loc.lx[1] & 1) << 1)
            | ((loc.lx[2] & 1) << 2)) as usize;
        let tag = tags::flux_tag(cgid, face, child);
        comm_flux.isend(t.ranks[cgid], tag, Payload::F32(payload));
    }
}

/// Coarse side: the flux corrections the given blocks expect this stage.
/// `FluxRecv::block` indices are `base + slice index` (mesh-global when the
/// caller passes the full block list with `base == 0`, pack-global when a
/// fused per-pack task registers its own disjoint slice).
pub(crate) fn flux_corr_pending_blocks(
    t: &bvals::ExchTopo,
    blocks: &[MeshBlock],
    base: usize,
) -> Vec<FluxRecv> {
    let shape = t.shape;
    let dim = shape.dim;
    let mut out = Vec::new();
    for (i, b) in blocks.iter().enumerate() {
        let bi = base + i;
        for nb in t.tree.find_neighbors(&b.loc) {
            let nonzero = (0..3).filter(|&d| nb.offset[d] != 0).count();
            if nonzero != 1 {
                continue;
            }
            let NeighborKind::Finer(fines) = &nb.kind else { continue };
            let d = (0..3).find(|&d| nb.offset[d] != 0).unwrap();
            let side = if nb.offset[d] < 0 { 0 } else { 1 };
            let face_idx = if side == 0 { 0 } else { shape.n[d] };
            let face = 2 * d + side;
            for floc in fines {
                let child = ((floc.lx[0] & 1)
                    | ((floc.lx[1] & 1) << 1)
                    | ((floc.lx[2] & 1) << 2)) as usize;
                let mut t_start = [0usize; 3];
                let mut t_len = [1usize; 3];
                for a in 0..dim {
                    if a == d {
                        continue;
                    }
                    let bit = (floc.lx[a] & 1) as usize;
                    t_start[a] = bit * shape.n[a] / 2;
                    t_len[a] = shape.n[a] / 2;
                }
                let fgid = t.tree.gid_of(floc).unwrap();
                out.push(FluxRecv {
                    block: bi,
                    src: t.ranks[fgid],
                    tag: tags::flux_tag(b.gid, face, child),
                    d,
                    face_idx,
                    t_start,
                    t_len,
                });
            }
        }
    }
    out
}

/// Poll a pending-correction list, applying arrivals into `flux` (indexed
/// by `FluxRecv::block - base`, so a per-pack task polls its own disjoint
/// flux slice). True when the list has drained.
pub(crate) fn flux_corr_poll_pending(
    comm_flux: &Comm,
    dim: usize,
    pending: &mut Vec<FluxRecv>,
    flux: &mut [FluxArrays],
    base: usize,
) -> Result<bool> {
    let mut i = 0;
    while i < pending.len() {
        let p = &pending[i];
        if let Some(payload) = comm_flux.try_recv(p.src, p.tag)? {
            let data = payload.into_f32()?;
            let p = pending.swap_remove(i);
            apply_flux_correction(&mut flux[p.block - base], &p, dim, &data);
        } else {
            i += 1;
        }
    }
    Ok(pending.is_empty())
}

/// Apply one received flux correction to a coarse block's flux array.
fn apply_flux_correction(fx: &mut FluxArrays, p: &FluxRecv, dim: usize, data: &[Real]) {
    let d = p.d;
    let mut r = 0usize;
    let tdims: Vec<usize> = (0..dim).filter(|&a| a != d).collect();
    for v in 0..crate::NHYDRO {
        match dim {
            1 => {
                let idx = fx.idx(d, v, 0, 0, p.face_idx);
                fx.f[d][idx] = data[r];
                r += 1;
            }
            2 => {
                let a = tdims[0];
                for t in 0..p.t_len[a] {
                    let tt = p.t_start[a] + t;
                    let (k, j, i) = match (d, a) {
                        (0, 1) => (0, tt, p.face_idx),
                        (1, 0) => (0, p.face_idx, tt),
                        _ => unreachable!(),
                    };
                    let idx = fx.idx(d, v, k, j, i);
                    fx.f[d][idx] = data[r];
                    r += 1;
                }
            }
            _ => {
                let (a1, a2) = (tdims[0], tdims[1]);
                for t2 in 0..p.t_len[a2] {
                    for t1 in 0..p.t_len[a1] {
                        let mut kji = [0usize; 3]; // (i,j,k)
                        kji[d] = p.face_idx;
                        kji[a1] = p.t_start[a1] + t1;
                        kji[a2] = p.t_start[a2] + t2;
                        let idx = fx.idx(d, v, kji[2], kji[1], kji[0]);
                        fx.f[d][idx] = data[r];
                        r += 1;
                    }
                }
            }
        }
    }
    debug_assert_eq!(r, data.len());
}

impl Driver for HydroSim {
    fn execute(&mut self) -> Result<()> {
        self.maybe_output(true)?;
        while self.time < self.sp.tlim
            && (self.sp.nlim < 0 || (self.cycle as i64) < self.sp.nlim)
        {
            self.step()?;
            self.maybe_output(false)?;
            if !self.sp.quiet && self.mesh.my_rank == 0 && self.cycle % 50 == 0 {
                eprintln!(
                    "cycle {:6}  time {:.5e}  dt {:.5e}  blocks {}",
                    self.cycle,
                    self.time,
                    self.dt,
                    self.mesh.tree.nblocks()
                );
            }
        }
        self.maybe_output(true)?;
        Ok(())
    }
}

impl EvolutionDriver for HydroSim {
    fn time(&self) -> f64 {
        self.time
    }

    fn cycle(&self) -> u64 {
        self.cycle
    }

    fn step(&mut self) -> Result<()> {
        let t0 = std::time::Instant::now();
        // Simulated rank death fires at the top of the scheduled cycle,
        // BEFORE this cycle's checkpoint could be written — so recovery
        // must resume from an earlier durable snapshot.
        self.world.check_kill(self.mesh.my_rank, self.cycle)?;
        let dt = self.dt as Real;

        // One cycle through the shared executor layer (take-dance so the
        // executor can borrow the rest of the sim).
        if self.device.is_some() {
            let mut dev = self.device.take().unwrap();
            let r = run_cycle(self, &mut dev, dt);
            self.device = Some(dev);
            r?;
        } else {
            let mut h = self.host.take().expect("host executor");
            let r = run_cycle(self, &mut h, dt);
            self.host = Some(h);
            r?;
        }

        self.time += self.dt;
        self.cycle += 1;
        self.dt = self.reduce_dt();

        // Measured per-block seconds -> cost EWMA (before regrid/rebalance
        // so this cycle's measurements inform this cycle's distribution).
        self.update_block_costs();

        // AMR
        if self.mesh.cfg.adaptive
            && self.device.is_none()
            && self.cycle % self.mesh.cfg.check_interval as u64 == 0
        {
            regrid::check_and_regrid(self)?;
        }

        // Cost-driven load balance on a fixed tree (opt-in; AMR regrids
        // already rebalance). Runs on every rank at the same cycle — the
        // cost allgather is a collective.
        if self.sp.lb_interval > 0
            && self.cycle % self.sp.lb_interval as u64 == 0
            && !(self.mesh.cfg.adaptive && self.device.is_none())
        {
            regrid::check_and_rebalance(self)?;
        }

        // Durable checkpoint (atomic tmp+rename) on the configured cadence:
        // the recovery loop restarts from the last one of these.
        if self.sp.checkpoint_interval > 0
            && self.cycle % self.sp.checkpoint_interval as u64 == 0
        {
            let path = self.sp.checkpoint_path.clone();
            self.write_restart(&path)?;
        }

        self.zc
            .record_cycle(self.global_zones(), t0.elapsed().as_secs_f64());
        Ok(())
    }
}

impl MultiStageDriver for HydroSim {
    fn num_stages(&self) -> usize {
        2
    }
}

/// Launch an N-rank simulation of `input`, returning per-rank zone-cycles/s
/// (joined). The standard entry point for the CLI, examples and benches.
pub fn run_simulation(
    input: &str,
    overrides: &[String],
    nranks: usize,
) -> Result<Vec<f64>> {
    use std::sync::Mutex;
    let results: std::sync::Arc<Mutex<Vec<f64>>> =
        std::sync::Arc::new(Mutex::new(vec![0.0; nranks]));
    let input = input.to_string();
    let overrides = overrides.to_vec();
    let res2 = results.clone();
    World::launch(nranks, move |rank, world| {
        let mut pin = ParameterInput::from_str(&input).expect("parse input");
        for ov in &overrides {
            pin.apply_override(ov).expect("override");
        }
        let mut sim = HydroSim::new(pin, rank, world).expect("build sim");
        sim.execute().expect("run sim");
        res2.lock().unwrap()[rank] = sim.zc.zcps();
    });
    Ok(std::sync::Arc::try_unwrap(results)
        .map(|m| m.into_inner().unwrap())
        .unwrap_or_default())
}
