//! Device execution space: MeshBlockPacks staged through runtime
//! artifacts, with the paper's three buffer-packing strategies (Fig. 8):
//!
//! * `PerBuffer` — one launch per boundary buffer per block (pack1/unpack1
//!   artifacts) + one stage launch per block: the "original" regime.
//! * `PerBlock`  — unpack/stage/pack launches per block (3/block/stage).
//! * `PerPack`   — ONE fused launch (unpack+stage+pack+dt) per MeshBlockPack
//!   per stage: the paper's full packing optimization.
//!
//! The pack partition and its staging buffers live in the shared
//! [`MeshData`] cache (same structure the Host path schedules its workers
//! over); this module owns only the launch plumbing: runtime, routing
//! tables, and the per-pack TASK-LIST PRODUCER. [`add_dev_pack_list`]
//! emits one task list per device pack — launch → send segments → poll
//! receives (+ the per-pack dt partial on the final RK stage) — and the
//! driver's single merged [`crate::tasks::TaskRegion`]
//! ([`super::run_stage`]) executes them on the shared stealing pool next
//! to the Host space's lists. The shared-state [`Runtime`] takes `&self`
//! on every entry point, so pack launches from different workers proceed
//! concurrently and one pack's boundary routing overlaps the interior
//! launches of the others; `parthenon/exec nworkers|sched` govern the
//! Device lists exactly like the Host lists, and `overlap = phased` runs
//! the same lists serially (the bitwise oracle over the same task units).
//!
//! Requires a uniform, fully periodic mesh — the configuration of every
//! performance experiment in the paper. AMR/multilevel runs use the Host
//! path (see DESIGN.md §limitations); `space=hybrid` probes the same
//! capability and degenerates to all-host when it fails.
//!
//! Per-pack launches are timed and spread over the pack's blocks into the
//! cost EWMA (`drain_block_secs`), so the load balancer — and, under
//! hybrid, the per-space cost model of
//! [`super::hybrid::HybridPartition`] — sees measured Device costs.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Instant;

use super::{DtColl, HydroSim, SpaceCtx};
use crate::bvals::{bufspec, PackStrategy};
use crate::comm::{tags, Comm, Payload};
use crate::error::{Error, Result};
use crate::hydro::native::StageCoeffs;
use crate::hydro::CONS;
use crate::mesh::{IndexShape, Mesh, NeighborKind};
use crate::mesh_data::{MeshData, PackDesc, PackStaging};
use crate::runtime::{default_artifact_dir, ArtifactKey, Runtime, ScalArgs};
use crate::tasks::{TaskId, TaskList, TaskStatus, NONE};
use crate::util::backoff::ProgressWait;
use crate::util::stealing::StealPolicy;
use crate::{Real, NHYDRO};

/// Routing entry for one (block, neighbor slot). Crate-visible (opaquely)
/// so the incremental rebalance can carry the gid-keyed route map across
/// the mesh update and hand it back for re-pointing.
#[derive(Debug, Clone)]
pub(crate) struct NbrEntry {
    /// Neighbor block gid — stable across a fixed-tree rebalance, so a
    /// surviving block's entries only need their ranks re-pointed from the
    /// new ownership table (tags are gid-derived and never change).
    ngid: usize,
    dst_rank: usize,
    send_tag: u64,
    recv_src: usize,
    recv_tag: u64,
}

impl NbrEntry {
    /// Neighbor block gid this entry routes to/from.
    pub(crate) fn ngid(&self) -> usize {
        self.ngid
    }
}

/// Per-rank device state: runtime + routing; staging lives in [`MeshData`].
pub struct DeviceState {
    pub rt: Runtime,
    shape: IndexShape,
    pub(crate) strategy: PackStrategy,
    impl_: String,
    /// Pack sizes the plan may use (fused artifact variants, ascending).
    plan_sizes: Vec<usize>,
    /// Per local block (flat order): routing per neighbor slot.
    routes: Vec<Vec<NbrEntry>>,
    seg_offs: Vec<usize>,
    seg_lens: Vec<usize>,
    buflen: usize,
    block_elems: usize,
    pub(crate) last_dts: Vec<Real>,
    /// The device's own boundary comm (`COMM_BVALS_BASE + 1`): bootstrap
    /// and rebalance routing rounds always use it; pure-device stages use
    /// it too (the bitwise oracle channel), while hybrid stages exchange
    /// on the driver's shared CONS comm so host and device packs
    /// interoperate.
    pub(crate) comm: Comm,
    gamma: Real,
    /// Measured launch seconds per block (per-pack launch time spread
    /// evenly over the pack's blocks), drained into the cost EWMA by
    /// `HydroSim::update_block_costs` — so `parthenon/loadbalance
    /// interval` rebalances Device runs on measured, not nominal, costs.
    pub(crate) block_secs: Vec<f64>,
    /// Requested fused-stage workers (`parthenon/exec nworkers`, 0=auto).
    nworkers_req: usize,
    /// Ranks sharing this machine's cores (auto worker sizing).
    nranks: usize,
    /// Pack scheduler for the fused stage (`parthenon/exec sched`).
    pub(crate) policy: StealPolicy,
    /// Per-pack staging scratch of the worker-parallel lists (one per pack
    /// so concurrent launches never share; resized lazily to the current
    /// pack count and reused across stages).
    pub(crate) tmps: Vec<Vec<Real>>,
}

impl DeviceState {
    /// Build the device state and re-plan `sim.mesh_data` onto the artifact
    /// pack sizes (the one pack partition both paths share).
    pub fn new(sim: &mut HydroSim) -> Result<DeviceState> {
        let mesh = &sim.mesh;
        if mesh.tree.max_level() != 0 {
            return Err(Error::Runtime(
                "Device exec space requires a uniform mesh (use Host for AMR)".into(),
            ));
        }
        if mesh.cfg.periodic_flags()[..mesh.cfg.dim].iter().any(|p| !p) {
            return Err(Error::Runtime(
                "Device exec space requires fully periodic boundaries".into(),
            ));
        }
        let shape = mesh.cfg.index_shape();
        let rt = Runtime::new(default_artifact_dir())?;

        let strategy = sim.sp.strategy;
        let dim = mesh.cfg.dim;
        let n = mesh.cfg.block_nx;
        // Pack-size menu: fused variants for PerPack, single blocks
        // otherwise. The MeshData plan is rebuilt from this menu.
        let plan_sizes = match strategy {
            PackStrategy::PerPack => {
                let avail = rt.manifest().pack_sizes("fused", dim, n, &sim.sp.impl_);
                let avail = if avail.is_empty() {
                    rt.manifest().pack_sizes("fused", dim, n, "jnp")
                } else {
                    avail
                };
                if avail.is_empty() {
                    return Err(Error::Artifact(format!(
                        "no fused artifacts for dim={dim} n={n:?}"
                    )));
                }
                avail
            }
            _ => vec![1],
        };

        let block_elems = NHYDRO * shape.ncells_total();
        let buflen = bufspec::buflen(&shape, NHYDRO);
        let (seg_offs, _) = bufspec::segment_offsets(&shape, NHYDRO);
        let seg_lens = bufspec::segment_lengths(&shape, NHYDRO);

        let nlocal = mesh.blocks.len();
        let routes = Self::build_routes(mesh)?;

        let comm = sim.world.comm(mesh.my_rank, tags::COMM_BVALS_BASE + 1);
        let mut dev = DeviceState {
            rt,
            shape,
            strategy,
            impl_: sim.sp.impl_.clone(),
            plan_sizes,
            routes,
            seg_offs,
            seg_lens,
            buflen,
            block_elems,
            last_dts: vec![0.0; nlocal],
            comm,
            gamma: sim.pkg.gamma,
            block_secs: vec![0.0; nlocal],
            nworkers_req: sim.sp.nworkers,
            nranks: mesh.nranks,
            policy: sim.sp.sched,
            tmps: Vec::new(),
        };

        // Shared pack partition: re-plan onto the artifact sizes + staging
        // (preserving any still-clean staging), gather only dirty packs.
        sim.mesh_data
            .rebuild_preserving(&sim.mesh, Some(&dev.plan_sizes));
        sim.mesh_data.gather_dirty(&sim.mesh, CONS)?;
        // Bootstrap: fill bufs_in once (pack + route) and compute dt.
        let scal0 = dev.scal(StageCoeffs { g0: 0.0, g1: 1.0, beta: 1.0 }, 0.0, &sim.mesh);
        let all: Vec<usize> = (0..sim.mesh_data.npacks()).collect();
        dev.bootstrap(&mut sim.mesh_data, scal0, &all)?;
        Ok(dev)
    }

    /// Routing entries of ONE block (a tree walk — the expensive half of
    /// route construction; the incremental rebalance pays it only for
    /// arriving blocks).
    fn block_routes(mesh: &Mesh, b: &crate::mesh::MeshBlock) -> Result<Vec<NbrEntry>> {
        let opp = bufspec::opposite_index(mesh.cfg.dim);
        let mut entries = Vec::new();
        for nb in mesh.tree.find_neighbors(&b.loc) {
            let NeighborKind::SameLevel(nloc) = &nb.kind else {
                return Err(Error::Runtime("device mesh must be uniform".into()));
            };
            let ngid = mesh.tree.gid_of(nloc).unwrap();
            let my_child = child_code_of(&b.loc);
            let nbr_child = child_code_of(nloc);
            entries.push(NbrEntry {
                ngid,
                dst_rank: mesh.rank_of(ngid),
                send_tag: tags::bval_tag(ngid, (opp[nb.nbr_index] << 3) | my_child),
                recv_src: mesh.rank_of(ngid),
                recv_tag: tags::bval_tag(b.gid, (nb.nbr_index << 3) | nbr_child),
            });
        }
        Ok(entries)
    }

    /// Routing tables for the current (uniform) mesh — rebuilt after a
    /// load balance without tearing the runtime/staging down.
    fn build_routes(mesh: &Mesh) -> Result<Vec<Vec<NbrEntry>>> {
        mesh.blocks.iter().map(|b| Self::block_routes(mesh, b)).collect()
    }

    /// The current routing tables keyed by gid — captured BEFORE an
    /// incremental rebalance rewrites the local block order, handed back
    /// to [`DeviceState::after_rebalance_incremental`] for re-pointing.
    pub(crate) fn routes_by_gid(
        &self,
        mesh: &Mesh,
    ) -> std::collections::HashMap<usize, Vec<NbrEntry>> {
        mesh.blocks
            .iter()
            .enumerate()
            .map(|(bi, b)| (b.gid, self.routes[bi].clone()))
            .collect()
    }

    /// Pack sizes the plan may draw from (artifact variants).
    pub(crate) fn plan_sizes(&self) -> &[usize] {
        &self.plan_sizes
    }

    /// The last measured per-block dts keyed by gid (stable across a
    /// fixed-tree rebalance).
    pub(crate) fn dts_by_gid(&self, mesh: &Mesh) -> std::collections::HashMap<usize, Real> {
        mesh.blocks
            .iter()
            .enumerate()
            .map(|(bi, b)| (b.gid, self.last_dts[bi]))
            .collect()
    }

    /// Bring the device back after a fixed-tree load balance: routes are
    /// rebuilt for the new ownership, staging stays resident — only the
    /// packs the rebalance marked dirty are re-gathered, re-packed and
    /// re-timed; every block's boundary buffers are then re-routed once so
    /// bufs_in is consistent with the new neighbors' owners.
    pub(crate) fn after_rebalance(
        &mut self,
        sim: &mut super::HydroSim,
        old_dts: &std::collections::HashMap<usize, Real>,
    ) -> Result<()> {
        self.routes = Self::build_routes(&sim.mesh)?;
        self.last_dts = vec![0.0; sim.mesh.blocks.len()];
        self.block_secs = vec![0.0; sim.mesh.blocks.len()];
        sim.fused_dt_local = None;
        sim.fused_dt_global = None;
        for (bi, b) in sim.mesh.blocks.iter().enumerate() {
            if let Some(v) = old_dts.get(&b.gid) {
                self.last_dts[bi] = *v;
            }
        }
        let dirty = sim.mesh_data.dirty_packs();
        sim.mesh_data.gather_dirty(&sim.mesh, CONS)?;
        let scal0 =
            self.scal(StageCoeffs { g0: 0.0, g1: 1.0, beta: 1.0 }, 0.0, &sim.mesh);
        self.bootstrap(&mut sim.mesh_data, scal0, &dirty)
    }

    /// The incremental counterpart of [`DeviceState::after_rebalance`]:
    /// consumes the migration plan's products instead of rebuilding
    /// wholesale. Surviving blocks' routing entries are re-pointed from
    /// the new ownership table (gid-stable tags; no tree walk) and only
    /// arriving blocks rebuild theirs; only the dirty packs are
    /// re-gathered, re-packed and re-timed; and the `bufs_in` refresh is
    /// limited to the dirty packs via the subset routing round (clean
    /// packs' resident buffers already hold the latest segments). Returns
    /// (blocks whose routes were rebuilt from the tree, segments resent).
    pub(crate) fn after_rebalance_incremental(
        &mut self,
        sim: &mut super::HydroSim,
        old_dts: &std::collections::HashMap<usize, Real>,
        old_routes: std::collections::HashMap<usize, Vec<NbrEntry>>,
    ) -> Result<(u64, u64)> {
        let mut old_routes = old_routes;
        let mut routes = Vec::with_capacity(sim.mesh.blocks.len());
        let mut rebuilt = 0u64;
        for b in &sim.mesh.blocks {
            match old_routes.remove(&b.gid) {
                Some(mut entries) => {
                    for e in &mut entries {
                        let r = sim.mesh.rank_of(e.ngid);
                        e.dst_rank = r;
                        e.recv_src = r;
                    }
                    routes.push(entries);
                }
                None => {
                    routes.push(Self::block_routes(&sim.mesh, b)?);
                    rebuilt += 1;
                }
            }
        }
        self.routes = routes;
        self.last_dts = vec![0.0; sim.mesh.blocks.len()];
        self.block_secs = vec![0.0; sim.mesh.blocks.len()];
        sim.fused_dt_local = None;
        sim.fused_dt_global = None;
        for (bi, b) in sim.mesh.blocks.iter().enumerate() {
            if let Some(v) = old_dts.get(&b.gid) {
                self.last_dts[bi] = *v;
            }
        }
        let dirty = sim.mesh_data.dirty_packs();
        sim.mesh_data.gather_dirty(&sim.mesh, CONS)?;
        let scal0 =
            self.scal(StageCoeffs { g0: 0.0, g1: 1.0, beta: 1.0 }, 0.0, &sim.mesh);
        self.repack_packs(&mut sim.mesh_data, scal0, &dirty)?;
        let nseg = self.refresh_boundary_subset(sim, &dirty)?;
        Ok((rebuilt, nseg))
    }

    /// The subset routing round of an incremental rebalance. Collective:
    /// every rank allgathers the gids of its dirty packs' blocks (their
    /// `bufs_in` were re-allocated empty by the re-plan), then each rank
    /// sends exactly the outbound segments addressed at a refreshing
    /// block — resident `bufs_out` of clean packs still hold the latest
    /// stage's segments, dirty packs were just re-packed — and polls only
    /// its own dirty packs' receives. Returns segments sent.
    fn refresh_boundary_subset(
        &self,
        sim: &mut super::HydroSim,
        dirty: &[usize],
    ) -> Result<u64> {
        use std::collections::HashSet;
        let mut mine = Vec::new();
        for &pi in dirty {
            let d = sim.mesh_data.packs()[pi];
            for b in &sim.mesh.blocks[d.block_range()] {
                mine.push(b.gid as u64);
            }
        }
        let refresh: HashSet<usize> = sim
            .world
            .comm(sim.mesh.my_rank, 0)
            .with_coll(sim.sp.coll)
            .allgather_u64s(&mine)
            .into_iter()
            .flatten()
            .map(|g| g as usize)
            .collect();
        if refresh.is_empty() {
            return Ok(0);
        }
        let mut nsent = 0u64;
        let (descs, staging) = sim.mesh_data.parts_mut();
        for (d, p) in descs.iter().zip(staging.iter()) {
            for bi in 0..d.nb {
                let flat = d.first + bi;
                let base = bi * self.buflen;
                for (slot, e) in self.routes[flat].iter().enumerate() {
                    if !refresh.contains(&e.ngid) {
                        continue;
                    }
                    let seg = &p.bufs_out[base + self.seg_offs[slot]
                        ..base + self.seg_offs[slot] + self.seg_lens[slot]];
                    self.comm.isend(e.dst_rank, e.send_tag, Payload::F32(seg.to_vec()));
                    nsent += 1;
                }
            }
        }
        let mut pending: Vec<(usize, Vec<(usize, usize)>)> = dirty
            .iter()
            .map(|&pi| (pi, self.pack_pending(&descs[pi])))
            .collect();
        let mut wait = ProgressWait::new(self.comm.stall_limit());
        loop {
            let mut progressed = false;
            let mut left = 0usize;
            for (pi, pend) in pending.iter_mut() {
                if pend.is_empty() {
                    continue;
                }
                let before = pend.len();
                self.poll_one(&descs[*pi], &mut staging[*pi], &self.comm, pend)?;
                progressed |= pend.len() < before;
                left += pend.len();
            }
            if left == 0 {
                return Ok(nsent);
            }
            if !wait.step(progressed) {
                let e = Error::Timeout {
                    what: format!(
                        "incremental boundary refresh ({left} segments missing)"
                    ),
                    rank: Some(self.comm.rank()),
                    peer: None,
                    tag: None,
                    elapsed: wait.idle_elapsed(),
                };
                self.comm.world().escalate(self.comm.rank(), &e);
                return Err(e);
            }
        }
    }

    fn key(&self, kind: &str, nb: usize) -> ArtifactKey {
        let mut k = ArtifactKey::new(kind, self.shape.dim, self.shape_n(), nb);
        // pallas impl only exists for some variants; fall back to jnp
        if self.impl_ == "pallas" {
            let kp = k.clone().with_impl("pallas");
            if self.rt.manifest().has(&kp) {
                return kp;
            }
        }
        k.impl_ = "jnp".to_string();
        k
    }

    fn shape_n(&self) -> [usize; 3] {
        self.shape.n
    }

    /// Worker threads for the fused stage, resolved against the current
    /// pack count (packs are the unit of work; more workers than packs
    /// would only idle).
    pub(crate) fn stage_workers(&self, npacks: usize) -> usize {
        if self.nworkers_req > 0 {
            self.nworkers_req.min(npacks.max(1))
        } else {
            crate::util::num_workers(npacks, self.nranks)
        }
    }

    /// Buffer fill + dt for the given packs (nb=1 pack/dt artifacts; not
    /// timed): recompute `bufs_out` and `last_dts` from the staged `u`.
    /// [`DeviceState::bootstrap`] follows this with the whole-rank routing
    /// round; the incremental rebalance with the dirty-pack subset refresh.
    fn repack_packs(&mut self, md: &mut MeshData, scal: ScalArgs, packs: &[usize]) -> Result<()> {
        let kp = self.key("pack", 1);
        let kdt = self.key("dt", 1);
        let ne = self.block_elems;
        let bl = self.buflen;
        let (descs, staging) = md.parts_mut();
        for &pi in packs {
            let d = &descs[pi];
            let p = &mut staging[pi];
            for bi in 0..d.nb {
                let u_slice = p.u[bi * ne..(bi + 1) * ne].to_vec();
                let mut seg = vec![0.0; bl];
                self.rt.pack(&kp, &u_slice, &mut seg)?;
                p.bufs_out[bi * bl..(bi + 1) * bl].copy_from_slice(&seg);
                let dts = self.rt.dt(&kdt, &u_slice, scal)?;
                self.last_dts[d.first + bi] = dts[0];
            }
        }
        Ok(())
    }

    /// Buffer fill + dt for the given packs, then one full boundary-routing
    /// round so every block's bufs_in is consistent. All packs at init;
    /// only the dirty packs after a full-mode load balance (resident
    /// staging keeps the rest).
    fn bootstrap(&mut self, md: &mut MeshData, scal: ScalArgs, packs: &[usize]) -> Result<()> {
        self.repack_packs(md, scal, packs)?;
        self.route_and_receive(md)?;
        Ok(())
    }

    fn scal_from_shape(&self, co: StageCoeffs, dt: Real, dx: [Real; 3]) -> ScalArgs {
        ScalArgs { g0: co.g0, g1: co.g1, beta: co.beta, dt, dx, gamma: self.gamma }
    }

    pub(crate) fn scal(&self, co: StageCoeffs, dt: Real, mesh: &Mesh) -> ScalArgs {
        let dx = match mesh.blocks.first() {
            Some(b) => [
                b.coords.dx[0] as Real,
                b.coords.dx[1] as Real,
                b.coords.dx[2] as Real,
            ],
            // rank owns no blocks: derive from the (uniform) root grid
            None => {
                let mut dx = [1.0 as Real; 3];
                for d in 0..mesh.cfg.dim {
                    dx[d] = (mesh.cfg.domain.width(d) / mesh.cfg.nx[d] as f64) as Real;
                }
                dx
            }
        };
        self.scal_from_shape(co, dt, dx)
    }

    /// The inbound `(block-in-pack, slot)` pairs one pack waits on.
    pub(crate) fn pack_pending(&self, d: &PackDesc) -> Vec<(usize, usize)> {
        let mut v = Vec::new();
        for bi in 0..d.nb {
            for slot in 0..self.routes[d.first + bi].len() {
                v.push((bi, slot));
            }
        }
        v
    }

    /// Send every pack's outbound segments and receive inbound segments
    /// into bufs_in, polling with bounded backoff — the whole-rank barrier
    /// routing of the bootstrap and rebalance paths, built on the same
    /// per-pack `send_one`/`poll_one` primitives the stage lists use
    /// (always on the device's own comm).
    fn route_and_receive(&self, md: &mut MeshData) -> Result<()> {
        let mut pending: Vec<Vec<(usize, usize)>> =
            md.packs().iter().map(|d| self.pack_pending(d)).collect();
        let mut wait = ProgressWait::new(self.comm.stall_limit());
        let (descs, staging) = md.parts_mut();
        for (d, p) in descs.iter().zip(staging.iter()) {
            self.send_one(d, p, &self.comm);
        }
        loop {
            let mut progressed = false;
            let mut left = 0usize;
            for ((d, p), pend) in
                descs.iter().zip(staging.iter_mut()).zip(pending.iter_mut())
            {
                if pend.is_empty() {
                    continue;
                }
                let before = pend.len();
                self.poll_one(d, p, &self.comm, pend)?;
                progressed |= pend.len() < before;
                left += pend.len();
            }
            if left == 0 {
                return Ok(());
            }
            if !wait.step(progressed) {
                let e = Error::Timeout {
                    what: format!(
                        "device boundary routing ({left} segments missing)"
                    ),
                    rank: Some(self.comm.rank()),
                    peer: None,
                    tag: None,
                    elapsed: wait.idle_elapsed(),
                };
                self.comm.world().escalate(self.comm.rank(), &e);
                return Err(e);
            }
        }
    }

    /// Take (and zero) the per-block launch seconds measured since the
    /// last drain (cost model; see `HydroSim::update_block_costs`).
    pub fn drain_block_secs(&mut self) -> Vec<f64> {
        let out = self.block_secs.clone();
        for s in &mut self.block_secs {
            *s = 0.0;
        }
        out
    }

    /// The stage launches of ONE pack under the configured packing
    /// strategy (Fig. 8). `&self`: the shared-state [`Runtime`] lets any
    /// worker thread launch concurrently, so this is the work item of the
    /// per-pack task lists. The caller hands in the pack's disjoint
    /// `last_dts`/`block_secs` slices (`dts_out`/`secs_out`, both `d.nb`
    /// long), a reusable staging scratch `tmp`, and `compute_dt` (true on
    /// the cycle's final RK stage — the ONE place that decision is made is
    /// the caller's `si + 1 == RK2_STAGES.len()`). Launch seconds are
    /// spread evenly over the pack's blocks into `secs_out` (artifact keys
    /// are resolved before the timer starts, so key construction never
    /// pollutes the measured launch seconds).
    fn launch_pack_parts(
        &self,
        d: &PackDesc,
        p: &mut PackStaging,
        dts_out: &mut [Real],
        secs_out: &mut [f64],
        tmp: &mut Vec<Real>,
        scal: ScalArgs,
        compute_dt: bool,
    ) -> Result<()> {
        let elapsed = match self.strategy {
            PackStrategy::PerPack => {
                // one fused unpack+stage+pack+dt launch for the whole pack
                let key = self.key("fused", d.nb);
                let t0 = Instant::now();
                let dts = self.rt.fused(
                    &key,
                    &mut p.u,
                    &p.u0,
                    &p.bufs_in,
                    scal,
                    &mut p.bufs_out,
                )?;
                let el = t0.elapsed();
                if compute_dt {
                    dts_out.copy_from_slice(&dts);
                }
                el
            }
            PackStrategy::PerBlock => {
                // unpack + stage + pack (+ dt at stage 2) per block
                let kun = self.key("unpack", 1);
                let kst = self.key("stage", 1);
                let kpk = self.key("pack", 1);
                let kdt = self.key("dt", 1);
                let ne = self.block_elems;
                let bl = self.buflen;
                if tmp.len() != ne {
                    tmp.resize(ne, 0.0);
                }
                let t0 = Instant::now();
                for bi in 0..d.nb {
                    let u = &mut p.u[bi * ne..(bi + 1) * ne];
                    let u0 = &p.u0[bi * ne..(bi + 1) * ne];
                    let bin = &p.bufs_in[bi * bl..(bi + 1) * bl];
                    self.rt.unpack(&kun, u, bin, tmp)?;
                    u.copy_from_slice(tmp);
                    self.rt.stage(&kst, u, u0, scal, tmp)?;
                    u.copy_from_slice(tmp);
                    self.rt.pack(&kpk, u, &mut p.bufs_out[bi * bl..(bi + 1) * bl])?;
                    if compute_dt {
                        let dts = self.rt.dt(&kdt, u, scal)?;
                        dts_out[bi] = dts[0];
                    }
                }
                t0.elapsed()
            }
            PackStrategy::PerBuffer => {
                // the "original" regime: one launch per boundary buffer
                // (unpack1/pack1) plus the per-block stage launch
                let kst = self.key("stage", 1);
                let kdt = self.key("dt", 1);
                let nslots = self.seg_lens.len();
                let kun1: Vec<ArtifactKey> =
                    (0..nslots).map(|s| self.key("unpack1", 1).with_nbr(s)).collect();
                let kpk1: Vec<ArtifactKey> =
                    (0..nslots).map(|s| self.key("pack1", 1).with_nbr(s)).collect();
                let ne = self.block_elems;
                let bl = self.buflen;
                if tmp.len() != ne {
                    tmp.resize(ne, 0.0);
                }
                let t0 = Instant::now();
                for bi in 0..d.nb {
                    let u = &mut p.u[bi * ne..(bi + 1) * ne];
                    let u0 = &p.u0[bi * ne..(bi + 1) * ne];
                    let base = bi * bl;
                    // apply each inbound buffer with its own launch
                    for slot in 0..nslots {
                        let seg = &p.bufs_in[base + self.seg_offs[slot]
                            ..base + self.seg_offs[slot] + self.seg_lens[slot]];
                        self.rt.unpack1(&kun1[slot], u, seg, tmp)?;
                        u.copy_from_slice(tmp);
                    }
                    self.rt.stage(&kst, u, u0, scal, tmp)?;
                    u.copy_from_slice(tmp);
                    // fill each outbound buffer with its own launch
                    for slot in 0..nslots {
                        let seg = self.rt.pack1(&kpk1[slot], u)?;
                        p.bufs_out[base + self.seg_offs[slot]
                            ..base + self.seg_offs[slot] + self.seg_lens[slot]]
                            .copy_from_slice(&seg);
                    }
                    if compute_dt {
                        let dts = self.rt.dt(&kdt, u, scal)?;
                        dts_out[bi] = dts[0];
                    }
                }
                t0.elapsed()
            }
            PackStrategy::Native => {
                return Err(Error::Runtime("strategy=native is the Host path".into()))
            }
        };
        // Per-pack launch seconds, spread evenly over the pack's blocks
        // (launches are the per-pack measurement unit on Device).
        let per_block = elapsed.as_secs_f64() / d.nb.max(1) as f64;
        for s in secs_out.iter_mut() {
            *s += per_block;
        }
        Ok(())
    }

    /// Send ONE pack's outbound boundary segments on `comm` (stage send
    /// task; the barrier `route_and_receive` loops this over the rank).
    fn send_one(&self, d: &PackDesc, p: &PackStaging, comm: &Comm) {
        for bi in 0..d.nb {
            let flat = d.first + bi;
            let base = bi * self.buflen;
            for (slot, e) in self.routes[flat].iter().enumerate() {
                let seg = &p.bufs_out[base + self.seg_offs[slot]
                    ..base + self.seg_offs[slot] + self.seg_lens[slot]];
                comm.isend(e.dst_rank, e.send_tag, Payload::F32(seg.to_vec()));
            }
        }
    }

    /// Poll ONE pack's pending inbound segments (`(block-in-pack, slot)`
    /// pairs) on `comm` into its `bufs_in`. True when all receives are in.
    fn poll_one(
        &self,
        d: &PackDesc,
        p: &mut PackStaging,
        comm: &Comm,
        pending: &mut Vec<(usize, usize)>,
    ) -> Result<bool> {
        let mut i = 0usize;
        while i < pending.len() {
            let (bi, slot) = pending[i];
            let e = &self.routes[d.first + bi][slot];
            if let Some(payload) = comm.try_recv(e.recv_src, e.recv_tag)? {
                let data = payload.into_f32()?;
                let base = bi * self.buflen;
                p.bufs_in[base + self.seg_offs[slot]
                    ..base + self.seg_offs[slot] + self.seg_lens[slot]]
                    .copy_from_slice(&data);
                pending.swap_remove(i);
            } else {
                i += 1;
            }
        }
        Ok(pending.is_empty())
    }

    /// Host → device restaging of one migrated pack: reconstruct its
    /// `bufs_in` from the freshly gathered `u`'s GHOST zones. For every
    /// neighbor slot the receive slab is copied out of `u` into the slot's
    /// segment — exactly the buffer a routing round would have delivered,
    /// because the containers' ghosts were current when the pack was
    /// gathered. The next launch's unpack then rewrites those ghost zones
    /// with identical values: a bitwise no-op.
    pub(crate) fn stage_in_pack(&self, d: &PackDesc, p: &mut PackStaging) {
        let ne = self.block_elems;
        let bl = self.buflen;
        let offsets = crate::mesh::tree::neighbor_offsets(self.shape.dim);
        for bi in 0..d.nb {
            for (slot, o) in offsets.iter().enumerate() {
                let slab = bufspec::recv_slab(*o, &self.shape);
                let mut w = bi * bl + self.seg_offs[slot];
                for v in 0..NHYDRO {
                    w += bufspec::copy_slab_out(
                        &p.u[bi * ne..(bi + 1) * ne],
                        &self.shape,
                        v,
                        &slab,
                        &mut p.bufs_in[w..],
                    );
                }
            }
        }
    }

    /// Device → host restaging of one migrating pack: apply its resident
    /// `bufs_in` to the GHOST zones of `u` before the scatter. After a
    /// stage, `u`'s interior is current but its ghosts are one exchange
    /// stale (the launch applies `bufs_in` at its start) — this is the
    /// same unpack the next launch would have performed, so the scattered
    /// container is fully current, interior and ghosts.
    pub(crate) fn stage_out_pack(&self, d: &PackDesc, p: &mut PackStaging) {
        let ne = self.block_elems;
        let bl = self.buflen;
        for bi in 0..d.nb {
            bufspec::unpack_all(
                &mut p.u[bi * ne..(bi + 1) * ne],
                &self.shape,
                NHYDRO,
                &p.bufs_in[bi * bl..(bi + 1) * bl],
            );
        }
    }
}

/// One pack's device-stage context: shared read view of the device state
/// + disjoint `&mut` slices of everything the pack writes. `Send`, so its
/// list can be swept by any worker of the merged region.
pub(crate) struct DevPackCtx<'a> {
    pub dev: &'a DeviceState,
    pub d: &'a PackDesc,
    pub p: &'a mut PackStaging,
    pub dts: &'a mut [Real],
    pub secs: &'a mut [f64],
    pub tmp: &'a mut Vec<Real>,
    pub pending: Vec<(usize, usize)>,
    /// Pack index (slot in the merged region's f64 `minima`).
    pub pi: usize,
    /// Stage comm for this pack's sends/polls: the driver's shared CONS
    /// comm under hybrid (host and device packs interoperate — the route
    /// tags are bit-identical to the host's same-level exchange tags on a
    /// uniform mesh), the device's own comm in a pure device run.
    pub comm: &'a Comm,
    pub minima: &'a [AtomicU64],
    pub dt_result: &'a AtomicU64,
    pub coll: &'a DtColl<'a>,
    pub scal: ScalArgs,
    /// Package CFL: the per-pack dt partial is published CFL-scaled in
    /// f64, so the merged fold compares finished local dts across spaces.
    pub cfl: Real,
    pub compute_dt: bool,
    pub error: Option<Error>,
    /// Shared across packs: first error drains every list fast.
    pub abort: &'a AtomicBool,
}

/// Produce the device-space task list for one pack into `list` (part of
/// the driver's merged region): launch → send → poll, plus the per-pack
/// dt partial on the final RK stage. Tasks unwrap [`SpaceCtx::Dev`]; the
/// returned id is the dt task (the regional fold's mark), `None` on
/// non-final stages.
///
/// The published dt partial is `cfl · min(pack dts)` as f64 — f32→f64 is
/// exact and multiplying by a positive CFL commutes with `min` bit-wise,
/// so the merged cross-pack fold equals the legacy fold-then-scale of the
/// pure device executor.
pub(crate) fn add_dev_pack_list(
    list: &mut TaskList<SpaceCtx<'_>>,
    final_stage: bool,
) -> Option<TaskId> {
    let t_launch = list.add(NONE, |ctx: &mut SpaceCtx| {
        let SpaceCtx::Dev(c) = ctx else { return TaskStatus::Complete };
        if c.abort.load(Ordering::SeqCst) {
            return TaskStatus::Complete;
        }
        let DevPackCtx { dev, d, p, dts, secs, tmp, scal, compute_dt, error, abort, .. } =
            c;
        if let Err(e) = dev.launch_pack_parts(d, p, dts, secs, tmp, *scal, *compute_dt)
        {
            *error = Some(e);
            abort.store(true, Ordering::SeqCst);
        }
        TaskStatus::Complete
    });
    let t_send = list.add(&[t_launch], |ctx: &mut SpaceCtx| {
        let SpaceCtx::Dev(c) = ctx else { return TaskStatus::Complete };
        if c.abort.load(Ordering::SeqCst) {
            return TaskStatus::Complete;
        }
        c.dev.send_one(c.d, c.p, c.comm);
        TaskStatus::Complete
    });
    let _t_poll = list.add(&[t_send], |ctx: &mut SpaceCtx| {
        let SpaceCtx::Dev(c) = ctx else { return TaskStatus::Complete };
        if c.abort.load(Ordering::SeqCst) {
            return TaskStatus::Complete;
        }
        let DevPackCtx { dev, d, p, comm, pending, error, abort, .. } = c;
        match dev.poll_one(d, p, comm, pending) {
            Ok(true) => TaskStatus::Complete,
            Ok(false) => TaskStatus::Incomplete,
            Err(e) => {
                *error = Some(e);
                abort.store(true, Ordering::SeqCst);
                TaskStatus::Complete
            }
        }
    });
    if final_stage {
        // partial min of the launch-computed per-block dts — the per-pack
        // half of the merged dt reduction, published CFL-scaled in f64
        let t_dt = list.add(&[t_launch], |ctx: &mut SpaceCtx| {
            let SpaceCtx::Dev(c) = ctx else { return TaskStatus::Complete };
            if c.abort.load(Ordering::SeqCst) {
                return TaskStatus::Complete;
            }
            let m = c.dts.iter().fold(f32::INFINITY, |a, &b| a.min(b));
            let local = c.cfl as f64 * m as f64;
            c.minima[c.pi].store(local.to_bits(), Ordering::SeqCst);
            c.coll.dt_done.fetch_add(1, Ordering::SeqCst);
            TaskStatus::Complete
        });
        Some(t_dt)
    } else {
        None
    }
}

fn child_code_of(loc: &crate::mesh::LogicalLocation) -> usize {
    ((loc.lx[0] & 1) | ((loc.lx[1] & 1) << 1) | ((loc.lx[2] & 1) << 2)) as usize
}
