//! Device execution space: MeshBlockPacks staged through runtime
//! artifacts, with the paper's three buffer-packing strategies (Fig. 8):
//!
//! * `PerBuffer` — one launch per boundary buffer per block (pack1/unpack1
//!   artifacts) + one stage launch per block: the "original" regime.
//! * `PerBlock`  — unpack/stage/pack launches per block (3/block/stage).
//! * `PerPack`   — ONE fused launch (unpack+stage+pack+dt) per MeshBlockPack
//!   per stage: the paper's full packing optimization.
//!
//! The pack partition and its staging buffers live in the shared
//! [`MeshData`] cache (same structure the Host path schedules its workers
//! over); this module owns only the launch plumbing: runtime, routing
//! tables, and the per-pack TASK-LIST PRODUCER. [`add_dev_pack_list`]
//! emits one task list per device pack — launch → send segments → poll
//! receives (+ the per-pack dt partial on the final RK stage) — and the
//! driver's single merged [`crate::tasks::TaskRegion`]
//! ([`super::run_stage`]) executes them on the shared stealing pool next
//! to the Host space's lists. The shared-state [`Runtime`] takes `&self`
//! on every entry point, so pack launches from different workers proceed
//! concurrently and one pack's boundary routing overlaps the interior
//! launches of the others; `parthenon/exec nworkers|sched` govern the
//! Device lists exactly like the Host lists, and `overlap = phased` runs
//! the same lists serially (the bitwise oracle over the same task units).
//!
//! Uniform fully-periodic meshes run the FAST path above (flat per-slot
//! routing tables + the Fig. 8 launch menu). Every other mesh —
//! multilevel SMR/AMR, non-periodic physical boundaries — runs the
//! GENERAL path: per-block `flux`/`combine` launches split at the flux
//! seam so flux corrections from fine neighbors interleave exactly like
//! the Host list, and boundary routing plays back a per-block snapshot of
//! the shared `bvals::exchange` spec layer (same-level copies,
//! fine→coarse restriction, coarse→fine prolongation, physical-BC
//! tables), so every wire payload, tag and ghost fill is byte-identical
//! to the Host exchange by construction. `space=device|hybrid` therefore
//! runs every mesh the Host path runs.
//!
//! Per-pack launches are timed and spread over the pack's blocks into the
//! cost EWMA (`drain_block_secs`), so the load balancer — and, under
//! hybrid, the per-space cost model of
//! [`super::hybrid::HybridPartition`] — sees measured Device costs.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use super::{DtColl, FluxRecv, HydroSim, SpaceCtx};
use crate::bvals::{bufspec, ExchTopo, PackStrategy, RecvOp, SendOp};
use crate::comm::{tags, Comm, Payload};
use crate::error::{Error, Result};
use crate::hydro::native::{FluxArrays, StageCoeffs};
use crate::hydro::CONS;
use crate::mesh::{BoundaryCondition, IndexShape, LogicalLocation, Mesh, NeighborKind};
use crate::mesh_data::{MeshData, PackDesc, PackStaging};
use crate::runtime::{ArtifactKey, Runtime, ScalArgs};
use crate::service::{BatchTicket, FusedParcel};
use crate::tasks::{TaskId, TaskList, TaskStatus, NONE};
use crate::util::backoff::ProgressWait;
use crate::util::stealing::StealPolicy;
use crate::{Real, NHYDRO};

/// Routing entry for one (block, neighbor slot). Crate-visible (opaquely)
/// so the incremental rebalance can carry the gid-keyed route map across
/// the mesh update and hand it back for re-pointing.
#[derive(Debug, Clone)]
pub(crate) struct NbrEntry {
    /// Neighbor block gid — stable across a fixed-tree rebalance, so a
    /// surviving block's entries only need their ranks re-pointed from the
    /// new ownership table (tags are gid-derived and never change).
    ngid: usize,
    dst_rank: usize,
    send_tag: u64,
    recv_src: usize,
    recv_tag: u64,
}

impl NbrEntry {
    /// Neighbor block gid this entry routes to/from.
    pub(crate) fn ngid(&self) -> usize {
        self.ngid
    }
}

/// One outbound boundary segment of the GENERAL routing snapshot
/// (multilevel / non-periodic meshes): destination rank + wire tag + the
/// payload op from the spec layer shared with the host exchange.
#[derive(Debug, Clone)]
struct GenSend {
    rank: usize,
    tag: u64,
    op: SendOp,
}

/// One inbound boundary segment of the general snapshot.
#[derive(Debug, Clone)]
struct GenRecv {
    src: usize,
    tag: u64,
    op: RecvOp,
}

/// Everything the general per-block task bodies need about ONE block,
/// snapshotted at route-build time so stage tasks share `&DeviceState`
/// without borrowing the mesh.
#[derive(Debug, Clone)]
struct GenBlock {
    loc: LogicalLocation,
    dx: [Real; 3],
    /// Physical-BC table (`None` when every face of this block is interior
    /// or periodic — the common case away from domain edges).
    bcs: Option<[[Option<BoundaryCondition>; 2]; 3]>,
    sends: Vec<GenSend>,
    recvs: Vec<GenRecv>,
}

/// General-mode routing snapshot, indexed by flat local block id.
#[derive(Debug, Clone)]
struct GenRoutes {
    blocks: Vec<GenBlock>,
}

/// Snapshot the general routing of every local block: outbound/inbound
/// boundary specs from the shared `bvals::exchange` spec layer (ranks
/// resolved now, so stage tasks never touch the mesh), the physical-BC
/// table, and the block geometry the per-block launches need.
fn build_gen_routes(mesh: &Mesh) -> GenRoutes {
    let topo = ExchTopo::of(mesh);
    let blocks = mesh
        .blocks
        .iter()
        .map(|b| {
            let sends = crate::bvals::send_specs_for(&topo, &b.loc)
                .into_iter()
                .map(|s| GenSend { rank: mesh.rank_of(s.ngid), tag: s.tag, op: s.op })
                .collect();
            let recvs = crate::bvals::recv_specs_for(&topo, b.gid, &b.loc)
                .into_iter()
                .map(|r| GenRecv { src: r.src_rank, tag: r.tag, op: r.op })
                .collect();
            GenBlock {
                loc: b.loc,
                dx: [
                    b.coords.dx[0] as Real,
                    b.coords.dx[1] as Real,
                    b.coords.dx[2] as Real,
                ],
                bcs: crate::bvals::block_bc_table(
                    mesh.cfg.bcs,
                    mesh.cfg.nrb,
                    mesh.cfg.dim,
                    &b.loc,
                ),
                sends,
                recvs,
            }
        })
        .collect();
    GenRoutes { blocks }
}

/// Per-rank device state: runtime + routing; staging lives in [`MeshData`].
/// The runtime is INJECTED (shared `Arc`), never constructed here — one
/// process constructs exactly one [`Runtime`], whether it drives one sim
/// or a whole [`crate::service::Engine`] of them.
pub struct DeviceState {
    pub rt: Arc<Runtime>,
    shape: IndexShape,
    pub(crate) strategy: PackStrategy,
    impl_: String,
    /// Pack sizes the plan may use (fused artifact variants, ascending).
    plan_sizes: Vec<usize>,
    /// Per local block (flat order): routing per neighbor slot. Empty in
    /// general mode, which routes through `gen` instead.
    routes: Vec<Vec<NbrEntry>>,
    /// General-mode routing snapshot (multilevel / non-periodic meshes):
    /// per-block send/recv specs + physical-BC tables + geometry. `Some`
    /// selects the general per-block task list; `None` the fast path.
    gen: Option<GenRoutes>,
    /// General-mode per-block flux arrays (flux corrections from finer
    /// neighbors land here between the flux and combine launches). Empty
    /// on the fast path, whose fused `stage` kernel never exposes fluxes.
    pub(crate) gen_flux: Vec<FluxArrays>,
    seg_offs: Vec<usize>,
    seg_lens: Vec<usize>,
    buflen: usize,
    block_elems: usize,
    pub(crate) last_dts: Vec<Real>,
    /// The device's own boundary comm (`COMM_BVALS_BASE + 1`): bootstrap
    /// and rebalance routing rounds always use it; pure-device stages use
    /// it too (the bitwise oracle channel), while hybrid stages exchange
    /// on the driver's shared CONS comm so host and device packs
    /// interoperate.
    pub(crate) comm: Comm,
    gamma: Real,
    /// Measured launch seconds per block (per-pack launch time spread
    /// evenly over the pack's blocks), drained into the cost EWMA by
    /// `HydroSim::update_block_costs` — so `parthenon/loadbalance
    /// interval` rebalances Device runs on measured, not nominal, costs.
    pub(crate) block_secs: Vec<f64>,
    /// Requested fused-stage workers (`parthenon/exec nworkers`, 0=auto).
    nworkers_req: usize,
    /// Ranks sharing this machine's cores (auto worker sizing).
    nranks: usize,
    /// Pack scheduler for the fused stage (`parthenon/exec sched`).
    pub(crate) policy: StealPolicy,
    /// Per-pack staging scratch of the worker-parallel lists (one per pack
    /// so concurrent launches never share; resized lazily to the current
    /// pack count and reused across stages).
    pub(crate) tmps: Vec<Vec<Real>>,
}

impl DeviceState {
    /// Build the device state against an injected shared runtime and
    /// re-plan `sim.mesh_data` onto the artifact pack sizes (the one pack
    /// partition both paths share).
    pub fn new(sim: &mut HydroSim, rt: Arc<Runtime>) -> Result<DeviceState> {
        let mesh = &sim.mesh;
        // Uniform fully-periodic meshes take the fast path (flat routing
        // tables + fused stage); everything else snapshots the general
        // per-block spec layer shared with the host exchange.
        let general = mesh.tree.max_level() != 0
            || mesh.cfg.periodic_flags()[..mesh.cfg.dim].iter().any(|p| !p);
        let shape = mesh.cfg.index_shape();

        let strategy = sim.sp.strategy;
        let dim = mesh.cfg.dim;
        let n = mesh.cfg.block_nx;
        // Pack-size menu: fused variants for PerPack, single blocks
        // otherwise. The MeshData plan is rebuilt from this menu.
        let plan_sizes = match strategy {
            PackStrategy::PerPack => {
                let avail = rt.manifest().pack_sizes("fused", dim, n, &sim.sp.impl_);
                let avail = if avail.is_empty() {
                    rt.manifest().pack_sizes("fused", dim, n, "jnp")
                } else {
                    avail
                };
                if avail.is_empty() {
                    return Err(Error::Artifact(format!(
                        "no fused artifacts for dim={dim} n={n:?}"
                    )));
                }
                avail
            }
            _ => vec![1],
        };

        let block_elems = NHYDRO * shape.ncells_total();
        let buflen = bufspec::buflen(&shape, NHYDRO);
        let (seg_offs, _) = bufspec::segment_offsets(&shape, NHYDRO);
        let seg_lens = bufspec::segment_lengths(&shape, NHYDRO);

        let nlocal = mesh.blocks.len();
        let (routes, gen) = if general {
            (Vec::new(), Some(build_gen_routes(mesh)))
        } else {
            (Self::build_routes(mesh)?, None)
        };
        let gen_flux = if general {
            vec![FluxArrays::new(&shape); nlocal]
        } else {
            Vec::new()
        };

        let comm = sim.world.comm(mesh.my_rank, tags::COMM_BVALS_BASE + 1);
        let mut dev = DeviceState {
            rt,
            shape,
            strategy,
            impl_: sim.sp.impl_.clone(),
            plan_sizes,
            routes,
            gen,
            gen_flux,
            seg_offs,
            seg_lens,
            buflen,
            block_elems,
            last_dts: vec![0.0; nlocal],
            comm,
            gamma: sim.pkg.gamma,
            block_secs: vec![0.0; nlocal],
            nworkers_req: sim.sp.nworkers,
            nranks: mesh.nranks,
            policy: sim.sp.sched,
            tmps: Vec::new(),
        };

        // Shared pack partition: re-plan onto the artifact sizes + staging
        // (preserving any still-clean staging), gather only dirty packs.
        sim.mesh_data
            .rebuild_preserving(&sim.mesh, Some(&dev.plan_sizes));
        sim.mesh_data.gather_dirty(&sim.mesh, CONS)?;
        let all: Vec<usize> = (0..sim.mesh_data.npacks()).collect();
        if dev.gen.is_some() {
            // General bootstrap: the staged arrays arrive ghost-current
            // from the containers (every creation path runs the blocking
            // exchange + BCs before gather), so no routing round is
            // needed — only the per-block dt launches.
            dev.refresh_dts_general(&mut sim.mesh_data, &all)?;
        } else {
            // Bootstrap: fill bufs_in once (pack + route) and compute dt.
            let scal0 =
                dev.scal(StageCoeffs { g0: 0.0, g1: 1.0, beta: 1.0 }, 0.0, &sim.mesh);
            dev.bootstrap(&mut sim.mesh_data, scal0, &all)?;
        }
        Ok(dev)
    }

    /// Routing entries of ONE block (a tree walk — the expensive half of
    /// route construction; the incremental rebalance pays it only for
    /// arriving blocks).
    fn block_routes(mesh: &Mesh, b: &crate::mesh::MeshBlock) -> Result<Vec<NbrEntry>> {
        let opp = bufspec::opposite_index(mesh.cfg.dim);
        let mut entries = Vec::new();
        for nb in mesh.tree.find_neighbors(&b.loc) {
            let NeighborKind::SameLevel(nloc) = &nb.kind else {
                return Err(Error::Runtime(
                    "device fast path requires a uniform mesh (general mode \
                     routes multilevel meshes)"
                        .into(),
                ));
            };
            let ngid = mesh.tree.gid_of(nloc).unwrap();
            let my_child = child_code_of(&b.loc);
            let nbr_child = child_code_of(nloc);
            entries.push(NbrEntry {
                ngid,
                dst_rank: mesh.rank_of(ngid),
                send_tag: tags::bval_tag(ngid, (opp[nb.nbr_index] << 3) | my_child),
                recv_src: mesh.rank_of(ngid),
                recv_tag: tags::bval_tag(b.gid, (nb.nbr_index << 3) | nbr_child),
            });
        }
        Ok(entries)
    }

    /// Routing tables for the current (uniform) mesh — rebuilt after a
    /// load balance without tearing the runtime/staging down.
    fn build_routes(mesh: &Mesh) -> Result<Vec<Vec<NbrEntry>>> {
        mesh.blocks.iter().map(|b| Self::block_routes(mesh, b)).collect()
    }

    /// True when this engine runs the GENERAL per-block path (multilevel
    /// or non-periodic mesh) instead of the uniform fast path.
    pub(crate) fn is_general(&self) -> bool {
        self.gen.is_some()
    }

    /// Recompute `last_dts` for the given packs with per-block dt launches
    /// (general mode's analog of the fast path's bootstrap/repack rounds;
    /// there are no resident boundary buffers to refill — general ghosts
    /// live in the staged arrays and are current after every stage).
    fn refresh_dts_general(&mut self, md: &mut MeshData, packs: &[usize]) -> Result<()> {
        let kdt = self.key("dt", 1);
        let ne = self.block_elems;
        let co = StageCoeffs { g0: 0.0, g1: 1.0, beta: 1.0 };
        let (descs, staging) = md.parts_mut();
        for &pi in packs {
            let d = &descs[pi];
            let p = &staging[pi];
            for bi in 0..d.nb {
                let flat = d.first + bi;
                let dx = self.gen.as_ref().expect("general routes").blocks[flat].dx;
                let scal = self.scal_from_shape(co, 0.0, dx);
                let dts = self.rt.dt(&kdt, &p.u[bi * ne..(bi + 1) * ne], scal)?;
                self.last_dts[flat] = dts[0];
            }
        }
        Ok(())
    }

    /// The pack-level scal with the BLOCK's own dx patched in (general
    /// mode: blocks at different levels have different cell widths).
    fn scal_for_block(&self, base: ScalArgs, flat: usize) -> ScalArgs {
        let dx = self.gen.as_ref().expect("general routes").blocks[flat].dx;
        ScalArgs { dx, ..base }
    }

    /// The current routing tables keyed by gid — captured BEFORE an
    /// incremental rebalance rewrites the local block order, handed back
    /// to [`DeviceState::after_rebalance_incremental`] for re-pointing.
    pub(crate) fn routes_by_gid(
        &self,
        mesh: &Mesh,
    ) -> std::collections::HashMap<usize, Vec<NbrEntry>> {
        if self.gen.is_some() {
            // General mode has no flat routes to carry across; the
            // incremental rebalance rebuilds the spec snapshot wholesale.
            return std::collections::HashMap::new();
        }
        mesh.blocks
            .iter()
            .enumerate()
            .map(|(bi, b)| (b.gid, self.routes[bi].clone()))
            .collect()
    }

    /// Pack sizes the plan may draw from (artifact variants).
    pub(crate) fn plan_sizes(&self) -> &[usize] {
        &self.plan_sizes
    }

    /// The last measured per-block dts keyed by gid (stable across a
    /// fixed-tree rebalance).
    pub(crate) fn dts_by_gid(&self, mesh: &Mesh) -> std::collections::HashMap<usize, Real> {
        mesh.blocks
            .iter()
            .enumerate()
            .map(|(bi, b)| (b.gid, self.last_dts[bi]))
            .collect()
    }

    /// Bring the device back after a fixed-tree load balance: routes are
    /// rebuilt for the new ownership, staging stays resident — only the
    /// packs the rebalance marked dirty are re-gathered, re-packed and
    /// re-timed; every block's boundary buffers are then re-routed once so
    /// bufs_in is consistent with the new neighbors' owners.
    pub(crate) fn after_rebalance(
        &mut self,
        sim: &mut super::HydroSim,
        old_dts: &std::collections::HashMap<usize, Real>,
    ) -> Result<()> {
        if self.gen.is_some() {
            // General snapshot embeds ranks, so it rebuilds wholesale
            // (cheap next to the migration itself).
            self.gen = Some(build_gen_routes(&sim.mesh));
            self.gen_flux = vec![FluxArrays::new(&self.shape); sim.mesh.blocks.len()];
        } else {
            self.routes = Self::build_routes(&sim.mesh)?;
        }
        self.last_dts = vec![0.0; sim.mesh.blocks.len()];
        self.block_secs = vec![0.0; sim.mesh.blocks.len()];
        sim.fused_dt_local = None;
        sim.fused_dt_global = None;
        for (bi, b) in sim.mesh.blocks.iter().enumerate() {
            if let Some(v) = old_dts.get(&b.gid) {
                self.last_dts[bi] = *v;
            }
        }
        let dirty = sim.mesh_data.dirty_packs();
        sim.mesh_data.gather_dirty(&sim.mesh, CONS)?;
        if self.gen.is_some() {
            // Ghosts ride the staged arrays in general mode — dirty packs
            // only need their dt launches refreshed, no routing round.
            return self.refresh_dts_general(&mut sim.mesh_data, &dirty);
        }
        let scal0 =
            self.scal(StageCoeffs { g0: 0.0, g1: 1.0, beta: 1.0 }, 0.0, &sim.mesh);
        self.bootstrap(&mut sim.mesh_data, scal0, &dirty)
    }

    /// The incremental counterpart of [`DeviceState::after_rebalance`]:
    /// consumes the migration plan's products instead of rebuilding
    /// wholesale. Surviving blocks' routing entries are re-pointed from
    /// the new ownership table (gid-stable tags; no tree walk) and only
    /// arriving blocks rebuild theirs; only the dirty packs are
    /// re-gathered, re-packed and re-timed; and the `bufs_in` refresh is
    /// limited to the dirty packs via the subset routing round (clean
    /// packs' resident buffers already hold the latest segments). Returns
    /// (blocks whose routes were rebuilt from the tree, segments resent).
    pub(crate) fn after_rebalance_incremental(
        &mut self,
        sim: &mut super::HydroSim,
        old_dts: &std::collections::HashMap<usize, Real>,
        old_routes: std::collections::HashMap<usize, Vec<NbrEntry>>,
    ) -> Result<(u64, u64)> {
        if self.gen.is_some() {
            // No flat routing tables to re-point in general mode — the
            // spec snapshot rebuilds wholesale, and ghosts ride the staged
            // arrays across the migration (no bufs_in refresh round).
            self.after_rebalance(sim, old_dts)?;
            return Ok((sim.mesh.blocks.len() as u64, 0));
        }
        let mut old_routes = old_routes;
        let mut routes = Vec::with_capacity(sim.mesh.blocks.len());
        let mut rebuilt = 0u64;
        for b in &sim.mesh.blocks {
            match old_routes.remove(&b.gid) {
                Some(mut entries) => {
                    for e in &mut entries {
                        let r = sim.mesh.rank_of(e.ngid);
                        e.dst_rank = r;
                        e.recv_src = r;
                    }
                    routes.push(entries);
                }
                None => {
                    routes.push(Self::block_routes(&sim.mesh, b)?);
                    rebuilt += 1;
                }
            }
        }
        self.routes = routes;
        self.last_dts = vec![0.0; sim.mesh.blocks.len()];
        self.block_secs = vec![0.0; sim.mesh.blocks.len()];
        sim.fused_dt_local = None;
        sim.fused_dt_global = None;
        for (bi, b) in sim.mesh.blocks.iter().enumerate() {
            if let Some(v) = old_dts.get(&b.gid) {
                self.last_dts[bi] = *v;
            }
        }
        let dirty = sim.mesh_data.dirty_packs();
        sim.mesh_data.gather_dirty(&sim.mesh, CONS)?;
        let scal0 =
            self.scal(StageCoeffs { g0: 0.0, g1: 1.0, beta: 1.0 }, 0.0, &sim.mesh);
        self.repack_packs(&mut sim.mesh_data, scal0, &dirty)?;
        let nseg = self.refresh_boundary_subset(sim, &dirty)?;
        Ok((rebuilt, nseg))
    }

    /// The subset routing round of an incremental rebalance. Collective:
    /// every rank allgathers the gids of its dirty packs' blocks (their
    /// `bufs_in` were re-allocated empty by the re-plan), then each rank
    /// sends exactly the outbound segments addressed at a refreshing
    /// block — resident `bufs_out` of clean packs still hold the latest
    /// stage's segments, dirty packs were just re-packed — and polls only
    /// its own dirty packs' receives. Returns segments sent.
    fn refresh_boundary_subset(
        &self,
        sim: &mut super::HydroSim,
        dirty: &[usize],
    ) -> Result<u64> {
        use std::collections::HashSet;
        let mut mine = Vec::new();
        for &pi in dirty {
            let d = sim.mesh_data.packs()[pi];
            for b in &sim.mesh.blocks[d.block_range()] {
                mine.push(b.gid as u64);
            }
        }
        let refresh: HashSet<usize> = sim
            .world
            .comm(sim.mesh.my_rank, 0)
            .with_coll(sim.sp.coll)
            .allgather_u64s(&mine)
            .into_iter()
            .flatten()
            .map(|g| g as usize)
            .collect();
        if refresh.is_empty() {
            return Ok(0);
        }
        let mut nsent = 0u64;
        let (descs, staging) = sim.mesh_data.parts_mut();
        for (d, p) in descs.iter().zip(staging.iter()) {
            for bi in 0..d.nb {
                let flat = d.first + bi;
                let base = bi * self.buflen;
                for (slot, e) in self.routes[flat].iter().enumerate() {
                    if !refresh.contains(&e.ngid) {
                        continue;
                    }
                    let seg = &p.bufs_out[base + self.seg_offs[slot]
                        ..base + self.seg_offs[slot] + self.seg_lens[slot]];
                    self.comm.isend(e.dst_rank, e.send_tag, Payload::F32(seg.to_vec()));
                    nsent += 1;
                }
            }
        }
        let mut pending: Vec<(usize, Vec<(usize, usize)>)> = dirty
            .iter()
            .map(|&pi| (pi, self.pack_pending(&descs[pi])))
            .collect();
        let mut wait = ProgressWait::new(self.comm.stall_limit());
        loop {
            let mut progressed = false;
            let mut left = 0usize;
            for (pi, pend) in pending.iter_mut() {
                if pend.is_empty() {
                    continue;
                }
                let before = pend.len();
                self.poll_one(&descs[*pi], &mut staging[*pi], &self.comm, pend)?;
                progressed |= pend.len() < before;
                left += pend.len();
            }
            if left == 0 {
                return Ok(nsent);
            }
            if !wait.step(progressed) {
                let e = Error::Timeout {
                    what: format!(
                        "incremental boundary refresh ({left} segments missing)"
                    ),
                    rank: Some(self.comm.rank()),
                    peer: None,
                    tag: None,
                    elapsed: wait.idle_elapsed(),
                };
                self.comm.world().escalate(self.comm.rank(), &e);
                return Err(e);
            }
        }
    }

    pub(crate) fn key(&self, kind: &str, nb: usize) -> ArtifactKey {
        let mut k = ArtifactKey::new(kind, self.shape.dim, self.shape_n(), nb);
        // pallas impl only exists for some variants; fall back to jnp
        if self.impl_ == "pallas" {
            let kp = k.clone().with_impl("pallas");
            if self.rt.manifest().has(&kp) {
                return kp;
            }
        }
        k.impl_ = "jnp".to_string();
        k
    }

    fn shape_n(&self) -> [usize; 3] {
        self.shape.n
    }

    /// Worker threads for the fused stage, resolved against the current
    /// pack count (packs are the unit of work; more workers than packs
    /// would only idle).
    pub(crate) fn stage_workers(&self, npacks: usize) -> usize {
        if self.nworkers_req > 0 {
            self.nworkers_req.min(npacks.max(1))
        } else {
            crate::util::num_workers(npacks, self.nranks)
        }
    }

    /// Buffer fill + dt for the given packs (nb=1 pack/dt artifacts; not
    /// timed): recompute `bufs_out` and `last_dts` from the staged `u`.
    /// [`DeviceState::bootstrap`] follows this with the whole-rank routing
    /// round; the incremental rebalance with the dirty-pack subset refresh.
    fn repack_packs(&mut self, md: &mut MeshData, scal: ScalArgs, packs: &[usize]) -> Result<()> {
        let kp = self.key("pack", 1);
        let kdt = self.key("dt", 1);
        let ne = self.block_elems;
        let bl = self.buflen;
        let (descs, staging) = md.parts_mut();
        for &pi in packs {
            let d = &descs[pi];
            let p = &mut staging[pi];
            for bi in 0..d.nb {
                let u_slice = p.u[bi * ne..(bi + 1) * ne].to_vec();
                let mut seg = vec![0.0; bl];
                self.rt.pack(&kp, &u_slice, &mut seg)?;
                p.bufs_out[bi * bl..(bi + 1) * bl].copy_from_slice(&seg);
                let dts = self.rt.dt(&kdt, &u_slice, scal)?;
                self.last_dts[d.first + bi] = dts[0];
            }
        }
        Ok(())
    }

    /// Buffer fill + dt for the given packs, then one full boundary-routing
    /// round so every block's bufs_in is consistent. All packs at init;
    /// only the dirty packs after a full-mode load balance (resident
    /// staging keeps the rest).
    fn bootstrap(&mut self, md: &mut MeshData, scal: ScalArgs, packs: &[usize]) -> Result<()> {
        self.repack_packs(md, scal, packs)?;
        self.route_and_receive(md)?;
        Ok(())
    }

    fn scal_from_shape(&self, co: StageCoeffs, dt: Real, dx: [Real; 3]) -> ScalArgs {
        ScalArgs { g0: co.g0, g1: co.g1, beta: co.beta, dt, dx, gamma: self.gamma }
    }

    pub(crate) fn scal(&self, co: StageCoeffs, dt: Real, mesh: &Mesh) -> ScalArgs {
        let dx = match mesh.blocks.first() {
            Some(b) => [
                b.coords.dx[0] as Real,
                b.coords.dx[1] as Real,
                b.coords.dx[2] as Real,
            ],
            // rank owns no blocks: derive from the (uniform) root grid
            None => {
                let mut dx = [1.0 as Real; 3];
                for d in 0..mesh.cfg.dim {
                    dx[d] = (mesh.cfg.domain.width(d) / mesh.cfg.nx[d] as f64) as Real;
                }
                dx
            }
        };
        self.scal_from_shape(co, dt, dx)
    }

    /// The inbound `(block-in-pack, slot)` pairs one pack waits on — slot
    /// indexes the fast path's routing table, or the general snapshot's
    /// recv-spec list.
    pub(crate) fn pack_pending(&self, d: &PackDesc) -> Vec<(usize, usize)> {
        let mut v = Vec::new();
        if let Some(gen) = &self.gen {
            for bi in 0..d.nb {
                for ri in 0..gen.blocks[d.first + bi].recvs.len() {
                    v.push((bi, ri));
                }
            }
            return v;
        }
        for bi in 0..d.nb {
            for slot in 0..self.routes[d.first + bi].len() {
                v.push((bi, slot));
            }
        }
        v
    }

    /// Send every pack's outbound segments and receive inbound segments
    /// into bufs_in, polling with bounded backoff — the whole-rank barrier
    /// routing of the bootstrap and rebalance paths, built on the same
    /// per-pack `send_one`/`poll_one` primitives the stage lists use
    /// (always on the device's own comm).
    fn route_and_receive(&self, md: &mut MeshData) -> Result<()> {
        let mut pending: Vec<Vec<(usize, usize)>> =
            md.packs().iter().map(|d| self.pack_pending(d)).collect();
        let mut wait = ProgressWait::new(self.comm.stall_limit());
        let (descs, staging) = md.parts_mut();
        for (d, p) in descs.iter().zip(staging.iter()) {
            self.send_one(d, p, &self.comm);
        }
        loop {
            let mut progressed = false;
            let mut left = 0usize;
            for ((d, p), pend) in
                descs.iter().zip(staging.iter_mut()).zip(pending.iter_mut())
            {
                if pend.is_empty() {
                    continue;
                }
                let before = pend.len();
                self.poll_one(d, p, &self.comm, pend)?;
                progressed |= pend.len() < before;
                left += pend.len();
            }
            if left == 0 {
                return Ok(());
            }
            if !wait.step(progressed) {
                let e = Error::Timeout {
                    what: format!(
                        "device boundary routing ({left} segments missing)"
                    ),
                    rank: Some(self.comm.rank()),
                    peer: None,
                    tag: None,
                    elapsed: wait.idle_elapsed(),
                };
                self.comm.world().escalate(self.comm.rank(), &e);
                return Err(e);
            }
        }
    }

    /// Take (and zero) the per-block launch seconds measured since the
    /// last drain (cost model; see `HydroSim::update_block_costs`).
    pub fn drain_block_secs(&mut self) -> Vec<f64> {
        let out = self.block_secs.clone();
        for s in &mut self.block_secs {
            *s = 0.0;
        }
        out
    }

    /// The stage launches of ONE pack under the configured packing
    /// strategy (Fig. 8). `&self`: the shared-state [`Runtime`] lets any
    /// worker thread launch concurrently, so this is the work item of the
    /// per-pack task lists. The caller hands in the pack's disjoint
    /// `last_dts`/`block_secs` slices (`dts_out`/`secs_out`, both `d.nb`
    /// long), a reusable staging scratch `tmp`, and `compute_dt` (true on
    /// the cycle's final RK stage — the ONE place that decision is made is
    /// the caller's `si + 1 == RK2_STAGES.len()`). Launch seconds are
    /// spread evenly over the pack's blocks into `secs_out` (artifact keys
    /// are resolved before the timer starts, so key construction never
    /// pollutes the measured launch seconds).
    fn launch_pack_parts(
        &self,
        d: &PackDesc,
        p: &mut PackStaging,
        dts_out: &mut [Real],
        secs_out: &mut [f64],
        tmp: &mut Vec<Real>,
        scal: ScalArgs,
        compute_dt: bool,
    ) -> Result<()> {
        let elapsed = match self.strategy {
            PackStrategy::PerPack => {
                // one fused unpack+stage+pack+dt launch for the whole pack
                let key = self.key("fused", d.nb);
                let t0 = Instant::now();
                let dts = self.rt.fused(
                    &key,
                    &mut p.u,
                    &p.u0,
                    &p.bufs_in,
                    scal,
                    &mut p.bufs_out,
                )?;
                let el = t0.elapsed();
                if compute_dt {
                    dts_out.copy_from_slice(&dts);
                }
                el
            }
            PackStrategy::PerBlock => {
                // unpack + stage + pack (+ dt at stage 2) per block
                let kun = self.key("unpack", 1);
                let kst = self.key("stage", 1);
                let kpk = self.key("pack", 1);
                let kdt = self.key("dt", 1);
                let ne = self.block_elems;
                let bl = self.buflen;
                if tmp.len() != ne {
                    tmp.resize(ne, 0.0);
                }
                let t0 = Instant::now();
                for bi in 0..d.nb {
                    let u = &mut p.u[bi * ne..(bi + 1) * ne];
                    let u0 = &p.u0[bi * ne..(bi + 1) * ne];
                    let bin = &p.bufs_in[bi * bl..(bi + 1) * bl];
                    self.rt.unpack(&kun, u, bin, tmp)?;
                    u.copy_from_slice(tmp);
                    self.rt.stage(&kst, u, u0, scal, tmp)?;
                    u.copy_from_slice(tmp);
                    self.rt.pack(&kpk, u, &mut p.bufs_out[bi * bl..(bi + 1) * bl])?;
                    if compute_dt {
                        let dts = self.rt.dt(&kdt, u, scal)?;
                        dts_out[bi] = dts[0];
                    }
                }
                t0.elapsed()
            }
            PackStrategy::PerBuffer => {
                // the "original" regime: one launch per boundary buffer
                // (unpack1/pack1) plus the per-block stage launch
                let kst = self.key("stage", 1);
                let kdt = self.key("dt", 1);
                let nslots = self.seg_lens.len();
                let kun1: Vec<ArtifactKey> =
                    (0..nslots).map(|s| self.key("unpack1", 1).with_nbr(s)).collect();
                let kpk1: Vec<ArtifactKey> =
                    (0..nslots).map(|s| self.key("pack1", 1).with_nbr(s)).collect();
                let ne = self.block_elems;
                let bl = self.buflen;
                if tmp.len() != ne {
                    tmp.resize(ne, 0.0);
                }
                let t0 = Instant::now();
                for bi in 0..d.nb {
                    let u = &mut p.u[bi * ne..(bi + 1) * ne];
                    let u0 = &p.u0[bi * ne..(bi + 1) * ne];
                    let base = bi * bl;
                    // apply each inbound buffer with its own launch
                    for slot in 0..nslots {
                        let seg = &p.bufs_in[base + self.seg_offs[slot]
                            ..base + self.seg_offs[slot] + self.seg_lens[slot]];
                        self.rt.unpack1(&kun1[slot], u, seg, tmp)?;
                        u.copy_from_slice(tmp);
                    }
                    self.rt.stage(&kst, u, u0, scal, tmp)?;
                    u.copy_from_slice(tmp);
                    // fill each outbound buffer with its own launch
                    for slot in 0..nslots {
                        let seg = self.rt.pack1(&kpk1[slot], u)?;
                        p.bufs_out[base + self.seg_offs[slot]
                            ..base + self.seg_offs[slot] + self.seg_lens[slot]]
                            .copy_from_slice(&seg);
                    }
                    if compute_dt {
                        let dts = self.rt.dt(&kdt, u, scal)?;
                        dts_out[bi] = dts[0];
                    }
                }
                t0.elapsed()
            }
            PackStrategy::Native => {
                return Err(Error::Runtime("strategy=native is the Host path".into()))
            }
        };
        // Per-pack launch seconds, spread evenly over the pack's blocks
        // (launches are the per-pack measurement unit on Device).
        let per_block = elapsed.as_secs_f64() / d.nb.max(1) as f64;
        for s in secs_out.iter_mut() {
            *s += per_block;
        }
        Ok(())
    }

    /// Send ONE pack's outbound boundary segments on `comm` (stage send
    /// task; the barrier `route_and_receive` loops this over the rank).
    fn send_one(&self, d: &PackDesc, p: &PackStaging, comm: &Comm) {
        for bi in 0..d.nb {
            let flat = d.first + bi;
            let base = bi * self.buflen;
            for (slot, e) in self.routes[flat].iter().enumerate() {
                let seg = &p.bufs_out[base + self.seg_offs[slot]
                    ..base + self.seg_offs[slot] + self.seg_lens[slot]];
                comm.isend(e.dst_rank, e.send_tag, Payload::F32(seg.to_vec()));
            }
        }
    }

    /// Poll ONE pack's pending inbound segments (`(block-in-pack, slot)`
    /// pairs) on `comm` into its `bufs_in`. True when all receives are in.
    fn poll_one(
        &self,
        d: &PackDesc,
        p: &mut PackStaging,
        comm: &Comm,
        pending: &mut Vec<(usize, usize)>,
    ) -> Result<bool> {
        let mut i = 0usize;
        while i < pending.len() {
            let (bi, slot) = pending[i];
            let e = &self.routes[d.first + bi][slot];
            if let Some(payload) = comm.try_recv(e.recv_src, e.recv_tag)? {
                let data = payload.into_f32()?;
                let base = bi * self.buflen;
                p.bufs_in[base + self.seg_offs[slot]
                    ..base + self.seg_offs[slot] + self.seg_lens[slot]]
                    .copy_from_slice(&data);
                pending.swap_remove(i);
            } else {
                i += 1;
            }
        }
        Ok(pending.is_empty())
    }

    /// Host → device restaging of one migrated pack: reconstruct its
    /// `bufs_in` from the freshly gathered `u`'s GHOST zones. For every
    /// neighbor slot the receive slab is copied out of `u` into the slot's
    /// segment — exactly the buffer a routing round would have delivered,
    /// because the containers' ghosts were current when the pack was
    /// gathered. The next launch's unpack then rewrites those ghost zones
    /// with identical values: a bitwise no-op.
    pub(crate) fn stage_in_pack(&self, d: &PackDesc, p: &mut PackStaging) {
        if self.gen.is_some() {
            // General mode keeps no resident boundary buffers: staged `u`
            // is always fully current (interior + ghosts + physical BCs),
            // so a migrated pack needs no restaging.
            return;
        }
        let ne = self.block_elems;
        let bl = self.buflen;
        let offsets = crate::mesh::tree::neighbor_offsets(self.shape.dim);
        for bi in 0..d.nb {
            for (slot, o) in offsets.iter().enumerate() {
                let slab = bufspec::recv_slab(*o, &self.shape);
                let mut w = bi * bl + self.seg_offs[slot];
                for v in 0..NHYDRO {
                    w += bufspec::copy_slab_out(
                        &p.u[bi * ne..(bi + 1) * ne],
                        &self.shape,
                        v,
                        &slab,
                        &mut p.bufs_in[w..],
                    );
                }
            }
        }
    }

    /// Device → host restaging of one migrating pack: apply its resident
    /// `bufs_in` to the GHOST zones of `u` before the scatter. After a
    /// stage, `u`'s interior is current but its ghosts are one exchange
    /// stale (the launch applies `bufs_in` at its start) — this is the
    /// same unpack the next launch would have performed, so the scattered
    /// container is fully current, interior and ghosts.
    pub(crate) fn stage_out_pack(&self, d: &PackDesc, p: &mut PackStaging) {
        if self.gen.is_some() {
            // General staging is never ghost-stale (receives apply
            // straight into `u` and BCs fill at poll-drain).
            return;
        }
        let ne = self.block_elems;
        let bl = self.buflen;
        for bi in 0..d.nb {
            bufspec::unpack_all(
                &mut p.u[bi * ne..(bi + 1) * ne],
                &self.shape,
                NHYDRO,
                &p.bufs_in[bi * bl..(bi + 1) * bl],
            );
        }
    }

    // ---- general (multilevel / non-periodic) launch bodies ----

    /// Flux launches of ONE pack (general mode): one `flux` launch per
    /// block into the pack's disjoint [`FluxArrays`] slice. Splitting the
    /// fused stage at the flux seam is what lets corrections from finer
    /// neighbors patch the fluxes before the combine — exactly the Host
    /// list's shape. Launch seconds accrue per block (cost EWMA).
    fn flux_pack_general(
        &self,
        d: &PackDesc,
        p: &PackStaging,
        flux: &mut [FluxArrays],
        secs_out: &mut [f64],
        scal: ScalArgs,
    ) -> Result<()> {
        let kfx = self.key("flux", 1);
        let ne = self.block_elems;
        for bi in 0..d.nb {
            let sb = self.scal_for_block(scal, d.first + bi);
            let t0 = Instant::now();
            self.rt.flux(&kfx, &p.u[bi * ne..(bi + 1) * ne], sb, &mut flux[bi])?;
            secs_out[bi] += t0.elapsed().as_secs_f64();
        }
        Ok(())
    }

    /// Combine launches of ONE pack (general mode): per block, apply the
    /// (possibly corrected) fluxes. `flux` then `combine` on uncorrected
    /// fluxes is bitwise the fast path's `stage`.
    fn combine_pack_general(
        &self,
        d: &PackDesc,
        p: &mut PackStaging,
        flux: &[FluxArrays],
        secs_out: &mut [f64],
        scal: ScalArgs,
    ) -> Result<()> {
        let kcb = self.key("combine", 1);
        let ne = self.block_elems;
        for bi in 0..d.nb {
            let sb = self.scal_for_block(scal, d.first + bi);
            let t0 = Instant::now();
            self.rt.combine(
                &kcb,
                &mut p.u[bi * ne..(bi + 1) * ne],
                &p.u0[bi * ne..(bi + 1) * ne],
                &flux[bi],
                sb,
            )?;
            secs_out[bi] += t0.elapsed().as_secs_f64();
        }
        Ok(())
    }

    /// Send ONE pack's outbound boundary segments (general mode): one
    /// `payload` launch per snapshotted [`SendOp`] — same-level slab,
    /// restricted fine→coarse slab, or interior slab bound for a finer
    /// neighbor's prolongation. Bytes and tags are the host exchange's by
    /// construction (shared spec layer).
    fn send_one_general(&self, d: &PackDesc, p: &PackStaging, comm: &Comm) -> Result<()> {
        let gen = self.gen.as_ref().expect("general routes");
        let kbp = self.key("payload", 1);
        let ne = self.block_elems;
        for bi in 0..d.nb {
            let u = &p.u[bi * ne..(bi + 1) * ne];
            for s in &gen.blocks[d.first + bi].sends {
                let payload = self.rt.boundary_payload(&kbp, u, &s.op)?;
                comm.isend(s.rank, s.tag, Payload::F32(payload));
            }
        }
        Ok(())
    }

    /// Poll ONE pack's pending inbound segments (general mode) straight
    /// into the staged arrays — ghost insert or coarse→fine prolongation
    /// per the snapshotted [`RecvOp`] — and, once the pack has drained,
    /// fill its blocks' physical boundary ghosts from the per-block BC
    /// tables. That is the same point the host path applies BCs (after
    /// every receive landed), and BC fills read only the block's own
    /// cells, so per-pack application is bitwise the host's global sweep.
    fn poll_one_general(
        &self,
        d: &PackDesc,
        p: &mut PackStaging,
        comm: &Comm,
        pending: &mut Vec<(usize, usize)>,
    ) -> Result<bool> {
        let gen = self.gen.as_ref().expect("general routes");
        let kab = self.key("apply", 1);
        let ne = self.block_elems;
        let mut i = 0usize;
        while i < pending.len() {
            let (bi, ri) = pending[i];
            let r = &gen.blocks[d.first + bi].recvs[ri];
            if let Some(payload) = comm.try_recv(r.src, r.tag)? {
                let data = payload.into_f32()?;
                self.rt.apply_boundary(
                    &kab,
                    &mut p.u[bi * ne..(bi + 1) * ne],
                    &r.op,
                    &data,
                )?;
                pending.swap_remove(i);
            } else {
                i += 1;
            }
        }
        if !pending.is_empty() {
            return Ok(false);
        }
        for bi in 0..d.nb {
            if let Some(bcs) = &gen.blocks[d.first + bi].bcs {
                crate::bvals::apply_physical_bcs(
                    &mut p.u[bi * ne..(bi + 1) * ne],
                    &self.shape,
                    bcs,
                    NHYDRO,
                    Some([
                        crate::hydro::native::IM1,
                        crate::hydro::native::IM2,
                        crate::hydro::native::IM3,
                    ]),
                );
            }
        }
        Ok(true)
    }

    /// Per-block dt launches of ONE pack (general mode): raw `min_dt` per
    /// block with the block's own level dx (the caller CFL-scales each
    /// block's value with the host formula before folding).
    fn dt_pack_general(
        &self,
        d: &PackDesc,
        p: &PackStaging,
        dts_out: &mut [Real],
    ) -> Result<()> {
        let kdt = self.key("dt", 1);
        let ne = self.block_elems;
        let co = StageCoeffs { g0: 0.0, g1: 1.0, beta: 1.0 };
        for bi in 0..d.nb {
            let dx = self.gen.as_ref().expect("general routes").blocks[d.first + bi].dx;
            let scal = self.scal_from_shape(co, 0.0, dx);
            let dts = self.rt.dt(&kdt, &p.u[bi * ne..(bi + 1) * ne], scal)?;
            dts_out[bi] = dts[0];
        }
        Ok(())
    }
}

/// One pack's device-stage context: shared read view of the device state
/// + disjoint `&mut` slices of everything the pack writes. `Send`, so its
/// list can be swept by any worker of the merged region.
pub(crate) struct DevPackCtx<'a> {
    pub dev: &'a DeviceState,
    pub d: &'a PackDesc,
    pub p: &'a mut PackStaging,
    pub dts: &'a mut [Real],
    pub secs: &'a mut [f64],
    pub tmp: &'a mut Vec<Real>,
    pub pending: Vec<(usize, usize)>,
    /// Pack index (slot in the merged region's f64 `minima`).
    pub pi: usize,
    /// Stage comm for this pack's sends/polls: the driver's shared CONS
    /// comm under hybrid (host and device packs interoperate — fast-path
    /// route tags match the host's same-level exchange tags, and general
    /// mode shares the host's spec layer outright), the device's own comm
    /// in a pure device run.
    pub comm: &'a Comm,
    pub minima: &'a [AtomicU64],
    pub dt_result: &'a AtomicU64,
    pub coll: &'a DtColl,
    pub scal: ScalArgs,
    /// Package CFL: the per-pack dt partial is published CFL-scaled in
    /// f64, so the merged fold compares finished local dts across spaces.
    pub cfl: Real,
    pub compute_dt: bool,
    /// General-mode per-block flux arrays of this pack (disjoint slice of
    /// `DeviceState::gen_flux`); empty on the fast path.
    pub flux: &'a mut [FluxArrays],
    /// Pending flux corrections from finer neighbors (general multilevel
    /// lists; empty otherwise). `FluxRecv::block` is flat-local, rebased
    /// by `d.first` at poll time like the host lists.
    pub fpending: Vec<FluxRecv>,
    /// Flux-correction comm — the driver's shared one, so corrections
    /// cross execution spaces under hybrid.
    pub fcomm: &'a Comm,
    /// Shared exchange topology (general flux-correction sends walk the
    /// tree for coarse face neighbors, exactly like the host list).
    pub topo: ExchTopo<'a>,
    /// Cross-simulation batch membership (service engine, fast path only):
    /// `Some` routes this pack's launch through the batch rendezvous —
    /// post staging, wait for the co-batched packs of OTHER sims, one
    /// fused launch, per-sim scatter. `None` (solo runs, general mode,
    /// dissolved single-sim groups) launches directly.
    pub batch: Option<BatchTicket>,
    pub error: Option<Error>,
    /// Shared across packs: first error drains every list fast.
    pub abort: &'a AtomicBool,
}

impl DevPackCtx<'_> {
    /// One poll of the batched-launch rendezvous (PerPack fast path).
    ///
    /// First poll donates the pack's staging buffers (`mem::take`) to the
    /// group; every poll then asks the group to launch — the poller that
    /// finds all parcels posted runs ONE [`Runtime::fused_batch`] over the
    /// whole group and scatters per-part results; everyone else returns
    /// `Incomplete` until the results land, then reclaims its buffers.
    /// The per-part dts/seconds land exactly where the solo launch puts
    /// them, so cost EWMAs and dt bits stay per-tenant.
    fn launch_batched(&mut self) -> Result<TaskStatus> {
        let ticket = self.batch.as_mut().expect("batched launch has a ticket");
        if !ticket.posted {
            ticket.group.post(
                ticket.slot,
                FusedParcel {
                    u: std::mem::take(&mut self.p.u),
                    u0: std::mem::take(&mut self.p.u0),
                    bufs_in: std::mem::take(&mut self.p.bufs_in),
                    bufs_out: std::mem::take(&mut self.p.bufs_out),
                    scal: self.scal,
                },
            );
            ticket.posted = true;
        }
        let Some((parcel, dts, secs)) = ticket.group.try_collect(&self.dev.rt, ticket.slot)?
        else {
            return Ok(TaskStatus::Incomplete);
        };
        self.p.u = parcel.u;
        self.p.u0 = parcel.u0;
        self.p.bufs_in = parcel.bufs_in;
        self.p.bufs_out = parcel.bufs_out;
        if self.compute_dt {
            self.dts.copy_from_slice(&dts);
        }
        // same spread the solo launch applies (launch seconds per block)
        let per_block = secs / self.d.nb.max(1) as f64;
        for s in self.secs.iter_mut() {
            *s += per_block;
        }
        Ok(TaskStatus::Complete)
    }
}

/// Produce the device-space task list for one pack into `list` (part of
/// the driver's merged region). Fast path (`general=false`): launch →
/// send → poll, plus the per-pack dt partial on the final RK stage.
/// General mode delegates to [`add_dev_pack_list_general`], which mirrors
/// the Host list shape. Tasks unwrap [`SpaceCtx::Dev`]; the returned id
/// is the dt task (the regional fold's mark), `None` on non-final stages.
///
/// The fast path's published dt partial is `cfl · min(pack dts)` as f64 —
/// f32→f64 is exact and multiplying by a positive CFL commutes with `min`
/// bit-wise, so the merged cross-pack fold equals the legacy
/// fold-then-scale of the pure device executor.
pub(crate) fn add_dev_pack_list(
    list: &mut TaskList<SpaceCtx<'_>>,
    general: bool,
    multilevel: bool,
    final_stage: bool,
) -> Option<TaskId> {
    if general {
        return add_dev_pack_list_general(list, multilevel, final_stage);
    }
    let t_launch = list.add(NONE, |ctx: &mut SpaceCtx| {
        let SpaceCtx::Dev(c) = ctx else { return TaskStatus::Complete };
        if c.abort.load(Ordering::SeqCst) {
            return TaskStatus::Complete;
        }
        if c.batch.as_ref().is_some_and(|t| t.group.is_active()) {
            // cross-sim batched launch: rendezvous with the co-batched
            // packs of other sessions instead of launching solo
            return match c.launch_batched() {
                Ok(st) => st,
                Err(e) => {
                    if c.error.is_none() {
                        c.error = Some(e);
                    }
                    c.abort.store(true, Ordering::SeqCst);
                    TaskStatus::Complete
                }
            };
        }
        let DevPackCtx { dev, d, p, dts, secs, tmp, scal, compute_dt, error, abort, .. } =
            c;
        if let Err(e) = dev.launch_pack_parts(d, p, dts, secs, tmp, *scal, *compute_dt)
        {
            *error = Some(e);
            abort.store(true, Ordering::SeqCst);
        }
        TaskStatus::Complete
    });
    let t_send = list.add(&[t_launch], |ctx: &mut SpaceCtx| {
        let SpaceCtx::Dev(c) = ctx else { return TaskStatus::Complete };
        if c.abort.load(Ordering::SeqCst) {
            return TaskStatus::Complete;
        }
        c.dev.send_one(c.d, c.p, c.comm);
        TaskStatus::Complete
    });
    let _t_poll = list.add(&[t_send], |ctx: &mut SpaceCtx| {
        let SpaceCtx::Dev(c) = ctx else { return TaskStatus::Complete };
        if c.abort.load(Ordering::SeqCst) {
            return TaskStatus::Complete;
        }
        let DevPackCtx { dev, d, p, comm, pending, error, abort, .. } = c;
        match dev.poll_one(d, p, comm, pending) {
            Ok(true) => TaskStatus::Complete,
            Ok(false) => TaskStatus::Incomplete,
            Err(e) => {
                *error = Some(e);
                abort.store(true, Ordering::SeqCst);
                TaskStatus::Complete
            }
        }
    });
    if final_stage {
        // partial min of the launch-computed per-block dts — the per-pack
        // half of the merged dt reduction, published CFL-scaled in f64
        let t_dt = list.add(&[t_launch], |ctx: &mut SpaceCtx| {
            let SpaceCtx::Dev(c) = ctx else { return TaskStatus::Complete };
            if c.abort.load(Ordering::SeqCst) {
                return TaskStatus::Complete;
            }
            let m = c.dts.iter().fold(f32::INFINITY, |a, &b| a.min(b));
            let local = c.cfl as f64 * m as f64;
            c.minima[c.pi].store(local.to_bits(), Ordering::SeqCst);
            c.coll.dt_done.fetch_add(1, Ordering::SeqCst);
            TaskStatus::Complete
        });
        Some(t_dt)
    } else {
        None
    }
}

/// The GENERAL device task list for one pack (multilevel / non-periodic
/// meshes): the exact Host list shape on device launches — flux →
/// (flux-corr send ‖ flux-corr poll) → combine → boundary send → poll
/// (+ BC fill at drain), with the per-pack dt partial on the final RK
/// stage. Per-block `flux`/`combine` launches split at the flux seam so
/// corrections from fine neighbors patch the flux arrays before the
/// combine, and the boundary tasks play back the snapshotted spec ops —
/// every payload, tag and ghost fill is byte-identical to the host path
/// by construction.
///
/// The dt partial uses the HOST formula — per block `(cfl · min_dt) as
/// f64`, folded with `f64::min` — so the merged fold is bit-identical to
/// an all-host run of the same mesh (the host widens AFTER the f32
/// multiply; see `HydroPackage::estimate_dt`).
fn add_dev_pack_list_general(
    list: &mut TaskList<SpaceCtx<'_>>,
    multilevel: bool,
    final_stage: bool,
) -> Option<TaskId> {
    let t_flux = list.add(NONE, |ctx: &mut SpaceCtx| {
        let SpaceCtx::Dev(c) = ctx else { return TaskStatus::Complete };
        if c.abort.load(Ordering::SeqCst) {
            return TaskStatus::Complete;
        }
        let DevPackCtx { dev, d, p, flux, secs, scal, error, abort, .. } = c;
        if let Err(e) = dev.flux_pack_general(d, p, flux, secs, *scal) {
            *error = Some(e);
            abort.store(true, Ordering::SeqCst);
        }
        TaskStatus::Complete
    });
    let combine_dep = if multilevel {
        // fine side: restrict + send face fluxes toward coarser neighbors
        let _t_fcsend = list.add(&[t_flux], |ctx: &mut SpaceCtx| {
            let SpaceCtx::Dev(c) = ctx else { return TaskStatus::Complete };
            if c.abort.load(Ordering::SeqCst) {
                return TaskStatus::Complete;
            }
            let gen = c.dev.gen.as_ref().expect("general routes");
            for bi in 0..c.d.nb {
                super::flux_corr_send_block(
                    &c.topo,
                    c.fcomm,
                    &gen.blocks[c.d.first + bi].loc,
                    &c.flux[bi],
                );
            }
            TaskStatus::Complete
        });
        // coarse side: poll pending corrections into the flux slice; the
        // combine must wait for them (not for the sends — those only gate
        // OTHER packs' polls, via message arrival)
        list.add(&[t_flux], |ctx: &mut SpaceCtx| {
            let SpaceCtx::Dev(c) = ctx else { return TaskStatus::Complete };
            if c.abort.load(Ordering::SeqCst) {
                return TaskStatus::Complete;
            }
            let DevPackCtx { d, flux, fpending, fcomm, topo, error, abort, .. } = c;
            match super::flux_corr_poll_pending(fcomm, topo.dim, fpending, flux, d.first)
            {
                Ok(true) => TaskStatus::Complete,
                Ok(false) => TaskStatus::Incomplete,
                Err(e) => {
                    *error = Some(e);
                    abort.store(true, Ordering::SeqCst);
                    TaskStatus::Complete
                }
            }
        })
    } else {
        t_flux
    };
    let t_combine = list.add(&[combine_dep], |ctx: &mut SpaceCtx| {
        let SpaceCtx::Dev(c) = ctx else { return TaskStatus::Complete };
        if c.abort.load(Ordering::SeqCst) {
            return TaskStatus::Complete;
        }
        let DevPackCtx { dev, d, p, flux, secs, scal, error, abort, .. } = c;
        if let Err(e) = dev.combine_pack_general(d, p, flux, secs, *scal) {
            *error = Some(e);
            abort.store(true, Ordering::SeqCst);
        }
        TaskStatus::Complete
    });
    let t_send = list.add(&[t_combine], |ctx: &mut SpaceCtx| {
        let SpaceCtx::Dev(c) = ctx else { return TaskStatus::Complete };
        if c.abort.load(Ordering::SeqCst) {
            return TaskStatus::Complete;
        }
        let DevPackCtx { dev, d, p, comm, error, abort, .. } = c;
        if let Err(e) = dev.send_one_general(d, p, comm) {
            *error = Some(e);
            abort.store(true, Ordering::SeqCst);
        }
        TaskStatus::Complete
    });
    let _t_poll = list.add(&[t_send], |ctx: &mut SpaceCtx| {
        let SpaceCtx::Dev(c) = ctx else { return TaskStatus::Complete };
        if c.abort.load(Ordering::SeqCst) {
            return TaskStatus::Complete;
        }
        let DevPackCtx { dev, d, p, comm, pending, error, abort, .. } = c;
        match dev.poll_one_general(d, p, comm, pending) {
            Ok(true) => TaskStatus::Complete,
            Ok(false) => TaskStatus::Incomplete,
            Err(e) => {
                *error = Some(e);
                abort.store(true, Ordering::SeqCst);
                TaskStatus::Complete
            }
        }
    });
    if final_stage {
        // per-pack half of the merged dt reduction, host formula
        let t_dt = list.add(&[t_combine], |ctx: &mut SpaceCtx| {
            let SpaceCtx::Dev(c) = ctx else { return TaskStatus::Complete };
            if c.abort.load(Ordering::SeqCst) {
                return TaskStatus::Complete;
            }
            let DevPackCtx { dev, d, p, dts, pi, minima, coll, cfl, error, abort, .. } =
                c;
            if let Err(e) = dev.dt_pack_general(d, p, dts) {
                *error = Some(e);
                abort.store(true, Ordering::SeqCst);
                return TaskStatus::Complete;
            }
            let mut m = f64::INFINITY;
            for &v in dts.iter() {
                m = m.min((*cfl * v) as f64);
            }
            minima[*pi].store(m.to_bits(), Ordering::SeqCst);
            coll.dt_done.fetch_add(1, Ordering::SeqCst);
            TaskStatus::Complete
        });
        Some(t_dt)
    } else {
        None
    }
}

fn child_code_of(loc: &crate::mesh::LogicalLocation) -> usize {
    ((loc.lx[0] & 1) | ((loc.lx[1] & 1) << 1) | ((loc.lx[2] & 1) << 2)) as usize
}
