//! Device execution path: MeshBlockPacks staged through PJRT artifacts,
//! with the paper's three buffer-packing strategies (Fig. 8):
//!
//! * `PerBuffer` — one launch per boundary buffer per block (pack1/unpack1
//!   artifacts) + one stage launch per block: the "original" regime.
//! * `PerBlock`  — unpack/stage/pack launches per block (3/block/stage).
//! * `PerPack`   — ONE fused launch (unpack+stage+pack+dt) per MeshBlockPack
//!   per stage: the paper's full packing optimization.
//!
//! Requires a uniform, fully periodic mesh — the configuration of every
//! performance experiment in the paper. AMR/multilevel runs use the Host
//! path (see DESIGN.md §limitations).

use super::HydroSim;
use crate::bvals::{bufspec, PackStrategy};
use crate::comm::{tags, Comm, Payload};
use crate::error::{Error, Result};
use crate::hydro::native::{StageCoeffs, RK2_STAGES};
use crate::hydro::CONS;
use crate::mesh::{IndexShape, Mesh, NeighborKind};
use crate::runtime::{default_artifact_dir, plan_packs, ArtifactKey, Runtime, ScalArgs};
use crate::{Real, NHYDRO};

/// Routing entry for one (block, neighbor slot).
#[derive(Debug, Clone)]
struct NbrEntry {
    dst_rank: usize,
    send_tag: u64,
    recv_src: usize,
    recv_tag: u64,
}

/// One MeshBlockPack's staging storage.
struct DevPack {
    nb: usize,
    /// Index into the flat local-block order (first block).
    first: usize,
    u: Vec<Real>,
    u0: Vec<Real>,
    bufs_in: Vec<Real>,
    bufs_out: Vec<Real>,
}

/// Per-rank device state.
pub struct DeviceState {
    pub rt: Runtime,
    shape: IndexShape,
    strategy: PackStrategy,
    impl_: String,
    packs: Vec<DevPack>,
    /// Per local block (flat order): routing per neighbor slot.
    routes: Vec<Vec<NbrEntry>>,
    seg_offs: Vec<usize>,
    seg_lens: Vec<usize>,
    buflen: usize,
    block_elems: usize,
    last_dts: Vec<Real>,
    comm: Comm,
    tmp: Vec<Real>,
    gamma: Real,
}

impl DeviceState {
    pub fn new(sim: &HydroSim) -> Result<DeviceState> {
        let mesh = &sim.mesh;
        if mesh.tree.max_level() != 0 {
            return Err(Error::Runtime(
                "Device exec space requires a uniform mesh (use Host for AMR)".into(),
            ));
        }
        if mesh.cfg.periodic_flags()[..mesh.cfg.dim].iter().any(|p| !p) {
            return Err(Error::Runtime(
                "Device exec space requires fully periodic boundaries".into(),
            ));
        }
        let shape = mesh.cfg.index_shape();
        let rt = Runtime::new(default_artifact_dir())?;

        let strategy = sim.sp.strategy;
        let dim = mesh.cfg.dim;
        let n = mesh.cfg.block_nx;
        // Pack plan: fused sizes for PerPack, single blocks otherwise.
        let nlocal = mesh.blocks.len();
        let plan = match strategy {
            PackStrategy::PerPack => {
                let avail = rt.manifest().pack_sizes("fused", dim, n, &sim.sp.impl_);
                let avail = if avail.is_empty() {
                    rt.manifest().pack_sizes("fused", dim, n, "jnp")
                } else {
                    avail
                };
                if avail.is_empty() {
                    return Err(Error::Artifact(format!(
                        "no fused artifacts for dim={dim} n={n:?}"
                    )));
                }
                plan_packs(nlocal, &avail, sim.sp.pack_size)
            }
            _ => vec![1; nlocal],
        };

        let block_elems = NHYDRO * shape.ncells_total();
        let buflen = bufspec::buflen(&shape, NHYDRO);
        let (seg_offs, _) = bufspec::segment_offsets(&shape, NHYDRO);
        let seg_lens = bufspec::segment_lengths(&shape, NHYDRO);

        let mut packs = Vec::new();
        let mut first = 0usize;
        for nb in plan {
            packs.push(DevPack {
                nb,
                first,
                u: vec![0.0; nb * block_elems],
                u0: vec![0.0; nb * block_elems],
                bufs_in: vec![0.0; nb * buflen],
                bufs_out: vec![0.0; nb * buflen],
            });
            first += nb;
        }

        // Routing tables.
        let opp = bufspec::opposite_index(dim);
        let mut routes = Vec::with_capacity(nlocal);
        for b in &mesh.blocks {
            let mut entries = Vec::new();
            for nb in mesh.tree.find_neighbors(&b.loc) {
                let NeighborKind::SameLevel(nloc) = &nb.kind else {
                    return Err(Error::Runtime("device mesh must be uniform".into()));
                };
                let ngid = mesh.tree.gid_of(nloc).unwrap();
                let my_child = child_code_of(&b.loc);
                let nbr_child = child_code_of(nloc);
                entries.push(NbrEntry {
                    dst_rank: mesh.rank_of(ngid),
                    send_tag: tags::bval_tag(
                        ngid,
                        (opp[nb.nbr_index] << 3) | my_child,
                    ),
                    recv_src: mesh.rank_of(ngid),
                    recv_tag: tags::bval_tag(b.gid, (nb.nbr_index << 3) | nbr_child),
                });
            }
            routes.push(entries);
        }

        let comm = sim.world.comm(mesh.my_rank, tags::COMM_BVALS_BASE + 1);
        let mut dev = DeviceState {
            rt,
            shape,
            strategy,
            impl_: sim.sp.impl_.clone(),
            packs,
            routes,
            seg_offs,
            seg_lens,
            buflen,
            block_elems,
            last_dts: vec![0.0; nlocal],
            comm,
            tmp: vec![0.0; block_elems],
            gamma: sim.pkg.gamma,
        };

        dev.sync_from_blocks(mesh)?;
        // Bootstrap: fill bufs_in once (pack + route) and compute dt.
        dev.bootstrap(mesh)?;
        Ok(dev)
    }

    fn key(&self, kind: &str, nb: usize) -> ArtifactKey {
        let mut k = ArtifactKey::new(kind, self.shape.dim, self.shape_n(), nb);
        // pallas impl only exists for some variants; fall back to jnp
        if self.impl_ == "pallas" {
            let kp = k.clone().with_impl("pallas");
            if self.rt.manifest().has(&kp) {
                return kp;
            }
        }
        k.impl_ = "jnp".to_string();
        k
    }

    fn shape_n(&self) -> [usize; 3] {
        self.shape.n
    }

    /// Gather authoritative state from MeshBlock containers into staging.
    pub fn sync_from_blocks(&mut self, mesh: &Mesh) -> Result<()> {
        for p in &mut self.packs {
            for bi in 0..p.nb {
                let arr = mesh.blocks[p.first + bi].data.get(CONS)?;
                p.u[bi * self.block_elems..(bi + 1) * self.block_elems]
                    .copy_from_slice(arr.as_slice());
            }
        }
        Ok(())
    }

    /// Scatter staging back into MeshBlock containers (for IO / regrid).
    pub fn sync_to_blocks(&self, mesh: &mut Mesh) -> Result<()> {
        for p in &self.packs {
            for bi in 0..p.nb {
                let arr = mesh.blocks[p.first + bi].data.get_mut(CONS)?;
                arr.as_mut_slice()
                    .copy_from_slice(&p.u[bi * self.block_elems..(bi + 1) * self.block_elems]);
            }
        }
        Ok(())
    }

    /// Initial buffer fill + dt (uses nb=1 pack/dt artifacts; not timed).
    fn bootstrap(&mut self, mesh: &Mesh) -> Result<()> {
        let kp = self.key("pack", 1);
        for pi in 0..self.packs.len() {
            for bi in 0..self.packs[pi].nb {
                let (u_slice, mut seg) = {
                    let p = &self.packs[pi];
                    (
                        p.u[bi * self.block_elems..(bi + 1) * self.block_elems].to_vec(),
                        vec![0.0; self.buflen],
                    )
                };
                self.rt.pack(&kp, &u_slice, &mut seg)?;
                self.packs[pi].bufs_out[bi * self.buflen..(bi + 1) * self.buflen]
                    .copy_from_slice(&seg);
            }
        }
        self.route_and_receive(mesh)?;
        // initial dt
        let kdt = self.key("dt", 1);
        let scal = self.scal(RK2_STAGES[0], 0.0, mesh);
        for pi in 0..self.packs.len() {
            for bi in 0..self.packs[pi].nb {
                let u_slice = self.packs[pi].u
                    [bi * self.block_elems..(bi + 1) * self.block_elems]
                    .to_vec();
                let dts = self.rt.dt(&kdt, &u_slice, scal)?;
                self.last_dts[self.packs[pi].first + bi] = dts[0];
            }
        }
        Ok(())
    }

    fn scal(&self, co: StageCoeffs, dt: Real, mesh: &Mesh) -> ScalArgs {
        let c = &mesh.blocks[0].coords;
        ScalArgs {
            g0: co.g0,
            g1: co.g1,
            beta: co.beta,
            dt,
            dx: [c.dx[0] as Real, c.dx[1] as Real, c.dx[2] as Real],
            gamma: self.gamma,
        }
    }

    /// Raw min CFL dt across local blocks (times the caller's CFL factor).
    pub fn last_dt_local(&self, cfl: f64) -> f64 {
        let m = self
            .last_dts
            .iter()
            .fold(Real::INFINITY, |a, &b| a.min(b));
        cfl * m as f64
    }

    /// Send every block's outbound segments and (blocking) receive inbound
    /// segments into bufs_in.
    fn route_and_receive(&mut self, mesh: &Mesh) -> Result<()> {
        // sends
        for p in &self.packs {
            for bi in 0..p.nb {
                let flat = p.first + bi;
                let base = bi * self.buflen;
                for (slot, e) in self.routes[flat].iter().enumerate() {
                    let seg = &p.bufs_out
                        [base + self.seg_offs[slot]..base + self.seg_offs[slot] + self.seg_lens[slot]];
                    self.comm
                        .isend(e.dst_rank, e.send_tag, Payload::F32(seg.to_vec()));
                }
            }
        }
        let _ = mesh;
        // receives (blocking; messages already in flight)
        for p in &mut self.packs {
            for bi in 0..p.nb {
                let flat = p.first + bi;
                let base = bi * self.buflen;
                for (slot, e) in self.routes[flat].iter().enumerate() {
                    let data = self
                        .comm
                        .recv(e.recv_src, e.recv_tag)
                        .into_f32()?;
                    p.bufs_in
                        [base + self.seg_offs[slot]..base + self.seg_offs[slot] + self.seg_lens[slot]]
                        .copy_from_slice(&data);
                }
            }
        }
        Ok(())
    }

    /// One full cycle (2 RK stages) on the device path.
    pub fn step(&mut self, sim: &mut HydroSim, dt: Real) -> Result<()> {
        // u0 <- u
        for p in &mut self.packs {
            p.u0.copy_from_slice(&p.u);
        }
        for (si, co) in RK2_STAGES.iter().enumerate() {
            let scal = self.scal(*co, dt, &sim.mesh);
            match self.strategy {
                PackStrategy::PerPack => self.stage_perpack(scal, si)?,
                PackStrategy::PerBlock => self.stage_perblock(scal, si)?,
                PackStrategy::PerBuffer => self.stage_perbuffer(scal, si)?,
                PackStrategy::Native => {
                    return Err(Error::Runtime(
                        "strategy=native is the Host path".into(),
                    ))
                }
            }
            self.route_and_receive(&sim.mesh)?;
        }
        Ok(())
    }

    /// One fused launch per pack per stage.
    fn stage_perpack(&mut self, scal: ScalArgs, si: usize) -> Result<()> {
        let keys: Vec<ArtifactKey> =
            self.packs.iter().map(|p| self.key("fused", p.nb)).collect();
        let DeviceState { rt, packs, last_dts, .. } = self;
        for (pi, p) in packs.iter_mut().enumerate() {
            let dts =
                rt.fused(&keys[pi], &mut p.u, &p.u0, &p.bufs_in, scal, &mut p.bufs_out)?;
            if si == 1 {
                for (bi, d) in dts.iter().enumerate() {
                    last_dts[p.first + bi] = *d;
                }
            }
        }
        Ok(())
    }

    /// unpack + stage + pack (+ dt at stage 2) per block.
    fn stage_perblock(&mut self, scal: ScalArgs, si: usize) -> Result<()> {
        let kun = self.key("unpack", 1);
        let kst = self.key("stage", 1);
        let kpk = self.key("pack", 1);
        let kdt = self.key("dt", 1);
        let DeviceState { rt, packs, last_dts, tmp, .. } = self;
        for p in packs.iter_mut() {
            debug_assert_eq!(p.nb, 1);
            rt.unpack(&kun, &p.u, &p.bufs_in, tmp)?;
            p.u.copy_from_slice(tmp);
            rt.stage(&kst, &p.u, &p.u0, scal, tmp)?;
            p.u.copy_from_slice(tmp);
            rt.pack(&kpk, &p.u, &mut p.bufs_out)?;
            if si == 1 {
                let dts = rt.dt(&kdt, &p.u, scal)?;
                last_dts[p.first] = dts[0];
            }
        }
        Ok(())
    }

    /// The "original" regime: one launch per buffer (unpack1/pack1) plus the
    /// per-block stage launch.
    fn stage_perbuffer(&mut self, scal: ScalArgs, si: usize) -> Result<()> {
        let kst = self.key("stage", 1);
        let kdt = self.key("dt", 1);
        let nslots = self.seg_lens.len();
        let kun1: Vec<ArtifactKey> =
            (0..nslots).map(|s| self.key("unpack1", 1).with_nbr(s)).collect();
        let kpk1: Vec<ArtifactKey> =
            (0..nslots).map(|s| self.key("pack1", 1).with_nbr(s)).collect();
        let DeviceState { rt, packs, last_dts, tmp, seg_offs, seg_lens, .. } = self;
        for p in packs.iter_mut() {
            debug_assert_eq!(p.nb, 1);
            // apply each inbound buffer with its own launch
            for slot in 0..nslots {
                let seg = &p.bufs_in[seg_offs[slot]..seg_offs[slot] + seg_lens[slot]];
                rt.unpack1(&kun1[slot], &p.u, seg, tmp)?;
                p.u.copy_from_slice(tmp);
            }
            rt.stage(&kst, &p.u, &p.u0, scal, tmp)?;
            p.u.copy_from_slice(tmp);
            // fill each outbound buffer with its own launch
            for slot in 0..nslots {
                let seg = rt.pack1(&kpk1[slot], &p.u)?;
                p.bufs_out[seg_offs[slot]..seg_offs[slot] + seg_lens[slot]]
                    .copy_from_slice(&seg);
            }
            if si == 1 {
                let dts = rt.dt(&kdt, &p.u, scal)?;
                last_dts[p.first] = dts[0];
            }
        }
        Ok(())
    }
}

fn child_code_of(loc: &crate::mesh::LogicalLocation) -> usize {
    ((loc.lx[0] & 1) | ((loc.lx[1] & 1) << 1) | ((loc.lx[2] & 1) << 2)) as usize
}
