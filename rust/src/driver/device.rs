//! Device execution path: MeshBlockPacks staged through runtime artifacts,
//! with the paper's three buffer-packing strategies (Fig. 8):
//!
//! * `PerBuffer` — one launch per boundary buffer per block (pack1/unpack1
//!   artifacts) + one stage launch per block: the "original" regime.
//! * `PerBlock`  — unpack/stage/pack launches per block (3/block/stage).
//! * `PerPack`   — ONE fused launch (unpack+stage+pack+dt) per MeshBlockPack
//!   per stage: the paper's full packing optimization.
//!
//! The pack partition and its staging buffers live in the shared
//! [`MeshData`] cache (same structure the Host path schedules its workers
//! over); this module owns only the launch plumbing: runtime, routing
//! tables, and per-stage launches. Requires a uniform, fully periodic mesh —
//! the configuration of every performance experiment in the paper.
//! AMR/multilevel runs use the Host path (see DESIGN.md §limitations).
//!
//! With `parthenon/exec overlap = fused` (default) the stage runs as
//! per-pack task lists — launch → send segments → poll receives — so one
//! pack's boundary routing overlaps the interior launches of the others;
//! `overlap = phased` keeps the launch-all-then-route barrier as the
//! bitwise-identity oracle. Per-pack launches are timed and spread over
//! the pack's blocks into the cost EWMA (`drain_block_secs`), so the load
//! balancer sees measured Device costs.

use std::time::Instant;

use super::{HydroSim, OverlapMode, StageExecutor};
use crate::bvals::{bufspec, PackStrategy};
use crate::comm::{tags, Comm, Payload};
use crate::error::{Error, Result};
use crate::hydro::native::StageCoeffs;
use crate::hydro::CONS;
use crate::mesh::{IndexShape, Mesh, NeighborKind};
use crate::mesh_data::{MeshData, PackDesc, PackStaging};
use crate::runtime::{default_artifact_dir, ArtifactKey, Runtime, ScalArgs};
use crate::tasks::{TaskRegion, TaskStatus, NONE};
use crate::util::backoff::{ProgressWait, STALL_LIMIT};
use crate::{Real, NHYDRO};

/// Routing entry for one (block, neighbor slot).
#[derive(Debug, Clone)]
struct NbrEntry {
    dst_rank: usize,
    send_tag: u64,
    recv_src: usize,
    recv_tag: u64,
}

/// Per-rank device state: runtime + routing; staging lives in [`MeshData`].
pub struct DeviceState {
    pub rt: Runtime,
    shape: IndexShape,
    strategy: PackStrategy,
    impl_: String,
    /// Pack sizes the plan may use (fused artifact variants, ascending).
    plan_sizes: Vec<usize>,
    /// Per local block (flat order): routing per neighbor slot.
    routes: Vec<Vec<NbrEntry>>,
    seg_offs: Vec<usize>,
    seg_lens: Vec<usize>,
    buflen: usize,
    block_elems: usize,
    last_dts: Vec<Real>,
    comm: Comm,
    tmp: Vec<Real>,
    gamma: Real,
    /// Measured launch seconds per block (per-pack launch time spread
    /// evenly over the pack's blocks), drained into the cost EWMA by
    /// `HydroSim::update_block_costs` — so `parthenon/loadbalance
    /// interval` rebalances Device runs on measured, not nominal, costs.
    block_secs: Vec<f64>,
}

impl DeviceState {
    /// Build the device state and re-plan `sim.mesh_data` onto the artifact
    /// pack sizes (the one pack partition both paths share).
    pub fn new(sim: &mut HydroSim) -> Result<DeviceState> {
        let mesh = &sim.mesh;
        if mesh.tree.max_level() != 0 {
            return Err(Error::Runtime(
                "Device exec space requires a uniform mesh (use Host for AMR)".into(),
            ));
        }
        if mesh.cfg.periodic_flags()[..mesh.cfg.dim].iter().any(|p| !p) {
            return Err(Error::Runtime(
                "Device exec space requires fully periodic boundaries".into(),
            ));
        }
        let shape = mesh.cfg.index_shape();
        let rt = Runtime::new(default_artifact_dir())?;

        let strategy = sim.sp.strategy;
        let dim = mesh.cfg.dim;
        let n = mesh.cfg.block_nx;
        // Pack-size menu: fused variants for PerPack, single blocks
        // otherwise. The MeshData plan is rebuilt from this menu.
        let plan_sizes = match strategy {
            PackStrategy::PerPack => {
                let avail = rt.manifest().pack_sizes("fused", dim, n, &sim.sp.impl_);
                let avail = if avail.is_empty() {
                    rt.manifest().pack_sizes("fused", dim, n, "jnp")
                } else {
                    avail
                };
                if avail.is_empty() {
                    return Err(Error::Artifact(format!(
                        "no fused artifacts for dim={dim} n={n:?}"
                    )));
                }
                avail
            }
            _ => vec![1],
        };

        let block_elems = NHYDRO * shape.ncells_total();
        let buflen = bufspec::buflen(&shape, NHYDRO);
        let (seg_offs, _) = bufspec::segment_offsets(&shape, NHYDRO);
        let seg_lens = bufspec::segment_lengths(&shape, NHYDRO);

        let nlocal = mesh.blocks.len();
        let routes = Self::build_routes(mesh)?;

        let comm = sim.world.comm(mesh.my_rank, tags::COMM_BVALS_BASE + 1);
        let mut dev = DeviceState {
            rt,
            shape,
            strategy,
            impl_: sim.sp.impl_.clone(),
            plan_sizes,
            routes,
            seg_offs,
            seg_lens,
            buflen,
            block_elems,
            last_dts: vec![0.0; nlocal],
            comm,
            tmp: vec![0.0; block_elems],
            gamma: sim.pkg.gamma,
            block_secs: vec![0.0; nlocal],
        };

        // Shared pack partition: re-plan onto the artifact sizes + staging
        // (preserving any still-clean staging), gather only dirty packs.
        sim.mesh_data
            .rebuild_preserving(&sim.mesh, Some(&dev.plan_sizes));
        sim.mesh_data.gather_dirty(&sim.mesh, CONS)?;
        // Bootstrap: fill bufs_in once (pack + route) and compute dt.
        let scal0 = dev.scal(StageCoeffs { g0: 0.0, g1: 1.0, beta: 1.0 }, 0.0, &sim.mesh);
        let all: Vec<usize> = (0..sim.mesh_data.npacks()).collect();
        dev.bootstrap(&mut sim.mesh_data, scal0, &all)?;
        Ok(dev)
    }

    /// Routing tables for the current (uniform) mesh — rebuilt after a
    /// load balance without tearing the runtime/staging down.
    fn build_routes(mesh: &Mesh) -> Result<Vec<Vec<NbrEntry>>> {
        let opp = bufspec::opposite_index(mesh.cfg.dim);
        let mut routes = Vec::with_capacity(mesh.blocks.len());
        for b in &mesh.blocks {
            let mut entries = Vec::new();
            for nb in mesh.tree.find_neighbors(&b.loc) {
                let NeighborKind::SameLevel(nloc) = &nb.kind else {
                    return Err(Error::Runtime("device mesh must be uniform".into()));
                };
                let ngid = mesh.tree.gid_of(nloc).unwrap();
                let my_child = child_code_of(&b.loc);
                let nbr_child = child_code_of(nloc);
                entries.push(NbrEntry {
                    dst_rank: mesh.rank_of(ngid),
                    send_tag: tags::bval_tag(
                        ngid,
                        (opp[nb.nbr_index] << 3) | my_child,
                    ),
                    recv_src: mesh.rank_of(ngid),
                    recv_tag: tags::bval_tag(b.gid, (nb.nbr_index << 3) | nbr_child),
                });
            }
            routes.push(entries);
        }
        Ok(routes)
    }

    /// Pack sizes the plan may draw from (artifact variants).
    pub(crate) fn plan_sizes(&self) -> &[usize] {
        &self.plan_sizes
    }

    /// The last measured per-block dts keyed by gid (stable across a
    /// fixed-tree rebalance).
    pub(crate) fn dts_by_gid(&self, mesh: &Mesh) -> std::collections::HashMap<usize, Real> {
        mesh.blocks
            .iter()
            .enumerate()
            .map(|(bi, b)| (b.gid, self.last_dts[bi]))
            .collect()
    }

    /// Bring the device back after a fixed-tree load balance: routes are
    /// rebuilt for the new ownership, staging stays resident — only the
    /// packs the rebalance marked dirty are re-gathered, re-packed and
    /// re-timed; every block's boundary buffers are then re-routed once so
    /// bufs_in is consistent with the new neighbors' owners.
    pub(crate) fn after_rebalance(
        &mut self,
        sim: &mut super::HydroSim,
        old_dts: &std::collections::HashMap<usize, Real>,
    ) -> Result<()> {
        self.routes = Self::build_routes(&sim.mesh)?;
        self.last_dts = vec![0.0; sim.mesh.blocks.len()];
        self.block_secs = vec![0.0; sim.mesh.blocks.len()];
        for (bi, b) in sim.mesh.blocks.iter().enumerate() {
            if let Some(v) = old_dts.get(&b.gid) {
                self.last_dts[bi] = *v;
            }
        }
        let dirty = sim.mesh_data.dirty_packs();
        sim.mesh_data.gather_dirty(&sim.mesh, CONS)?;
        let scal0 =
            self.scal(StageCoeffs { g0: 0.0, g1: 1.0, beta: 1.0 }, 0.0, &sim.mesh);
        self.bootstrap(&mut sim.mesh_data, scal0, &dirty)
    }

    fn key(&self, kind: &str, nb: usize) -> ArtifactKey {
        let mut k = ArtifactKey::new(kind, self.shape.dim, self.shape_n(), nb);
        // pallas impl only exists for some variants; fall back to jnp
        if self.impl_ == "pallas" {
            let kp = k.clone().with_impl("pallas");
            if self.rt.manifest().has(&kp) {
                return kp;
            }
        }
        k.impl_ = "jnp".to_string();
        k
    }

    fn shape_n(&self) -> [usize; 3] {
        self.shape.n
    }

    /// Buffer fill + dt for the given packs (nb=1 pack/dt artifacts; not
    /// timed), then one full boundary-routing round so every block's
    /// bufs_in is consistent. All packs at init; only the dirty packs
    /// after a load balance (resident staging keeps the rest).
    fn bootstrap(&mut self, md: &mut MeshData, scal: ScalArgs, packs: &[usize]) -> Result<()> {
        let kp = self.key("pack", 1);
        let kdt = self.key("dt", 1);
        {
            let (descs, staging) = md.parts_mut();
            let DeviceState { rt, last_dts, buflen, block_elems, .. } = self;
            for &pi in packs {
                let d = &descs[pi];
                let p = &mut staging[pi];
                for bi in 0..d.nb {
                    let u_slice =
                        p.u[bi * *block_elems..(bi + 1) * *block_elems].to_vec();
                    let mut seg = vec![0.0; *buflen];
                    rt.pack(&kp, &u_slice, &mut seg)?;
                    p.bufs_out[bi * *buflen..(bi + 1) * *buflen]
                        .copy_from_slice(&seg);
                    let dts = rt.dt(&kdt, &u_slice, scal)?;
                    last_dts[d.first + bi] = dts[0];
                }
            }
        }
        self.route_and_receive(md)?;
        Ok(())
    }

    fn scal_from_shape(&self, co: StageCoeffs, dt: Real, dx: [Real; 3]) -> ScalArgs {
        ScalArgs { g0: co.g0, g1: co.g1, beta: co.beta, dt, dx, gamma: self.gamma }
    }

    fn scal(&self, co: StageCoeffs, dt: Real, mesh: &Mesh) -> ScalArgs {
        let dx = match mesh.blocks.first() {
            Some(b) => [
                b.coords.dx[0] as Real,
                b.coords.dx[1] as Real,
                b.coords.dx[2] as Real,
            ],
            // rank owns no blocks: derive from the (uniform) root grid
            None => {
                let mut dx = [1.0 as Real; 3];
                for d in 0..mesh.cfg.dim {
                    dx[d] = (mesh.cfg.domain.width(d) / mesh.cfg.nx[d] as f64) as Real;
                }
                dx
            }
        };
        self.scal_from_shape(co, dt, dx)
    }

    /// The inbound `(block-in-pack, slot)` pairs one pack waits on.
    fn pack_pending(&self, d: &PackDesc) -> Vec<(usize, usize)> {
        let mut v = Vec::new();
        for bi in 0..d.nb {
            for slot in 0..self.routes[d.first + bi].len() {
                v.push((bi, slot));
            }
        }
        v
    }

    /// Send every pack's outbound segments and receive inbound segments
    /// into bufs_in, polling with bounded backoff — the whole-rank barrier
    /// routing of the phased path and the bootstrap, built on the same
    /// per-pack `send_pack`/`poll_pack` primitives the fused lists use.
    fn route_and_receive(&mut self, md: &mut MeshData) -> Result<()> {
        for pi in 0..md.npacks() {
            self.send_pack(md.packs(), md.staging(), pi);
        }
        let mut pending: Vec<Vec<(usize, usize)>> =
            md.packs().iter().map(|d| self.pack_pending(d)).collect();
        let mut wait = ProgressWait::new(STALL_LIMIT);
        loop {
            let mut progressed = false;
            let mut left = 0usize;
            for (pi, pend) in pending.iter_mut().enumerate() {
                if pend.is_empty() {
                    continue;
                }
                let before = pend.len();
                self.poll_pack(md, pi, pend)?;
                progressed |= pend.len() < before;
                left += pend.len();
            }
            if left == 0 {
                return Ok(());
            }
            if !wait.step(progressed) {
                return Err(Error::Comm(format!(
                    "device boundary routing stalled ({left} segments missing after {:?} idle)",
                    wait.idle_elapsed()
                )));
            }
        }
    }

    /// Take (and zero) the per-block launch seconds measured since the
    /// last drain (cost model; see `HydroSim::update_block_costs`).
    pub fn drain_block_secs(&mut self) -> Vec<f64> {
        let out = self.block_secs.clone();
        for s in &mut self.block_secs {
            *s = 0.0;
        }
        out
    }

    /// The stage launches of ONE pack under the configured packing
    /// strategy (Fig. 8), timed into the per-block cost samples (artifact
    /// keys are resolved before the timer starts, so key construction
    /// never pollutes the measured launch seconds). The per-pack unit of
    /// both stage schedules: the phased path loops over packs; the fused
    /// path orders `launch_pack` → `send_pack` → `poll_pack` per pack
    /// through a task list.
    fn launch_pack(
        &mut self,
        md: &mut MeshData,
        pi: usize,
        scal: ScalArgs,
        si: usize,
    ) -> Result<()> {
        let elapsed = match self.strategy {
            PackStrategy::PerPack => {
                // one fused unpack+stage+pack+dt launch for the whole pack
                let key = self.key("fused", md.packs()[pi].nb);
                let (descs, staging) = md.parts_mut();
                let d = &descs[pi];
                let p = &mut staging[pi];
                let t0 = Instant::now();
                let dts = self.rt.fused(
                    &key,
                    &mut p.u,
                    &p.u0,
                    &p.bufs_in,
                    scal,
                    &mut p.bufs_out,
                )?;
                let el = t0.elapsed();
                if si == 1 {
                    for (bi, v) in dts.iter().enumerate() {
                        self.last_dts[d.first + bi] = *v;
                    }
                }
                el
            }
            PackStrategy::PerBlock => {
                // unpack + stage + pack (+ dt at stage 2) per block
                let kun = self.key("unpack", 1);
                let kst = self.key("stage", 1);
                let kpk = self.key("pack", 1);
                let kdt = self.key("dt", 1);
                let (descs, staging) = md.parts_mut();
                let d = &descs[pi];
                let p = &mut staging[pi];
                let DeviceState { rt, last_dts, tmp, block_elems, buflen, .. } = self;
                let ne = *block_elems;
                let bl = *buflen;
                let t0 = Instant::now();
                for bi in 0..d.nb {
                    let u = &mut p.u[bi * ne..(bi + 1) * ne];
                    let u0 = &p.u0[bi * ne..(bi + 1) * ne];
                    let bin = &p.bufs_in[bi * bl..(bi + 1) * bl];
                    rt.unpack(&kun, u, bin, tmp)?;
                    u.copy_from_slice(tmp);
                    rt.stage(&kst, u, u0, scal, tmp)?;
                    u.copy_from_slice(tmp);
                    rt.pack(&kpk, u, &mut p.bufs_out[bi * bl..(bi + 1) * bl])?;
                    if si == 1 {
                        let dts = rt.dt(&kdt, u, scal)?;
                        last_dts[d.first + bi] = dts[0];
                    }
                }
                t0.elapsed()
            }
            PackStrategy::PerBuffer => {
                // the "original" regime: one launch per boundary buffer
                // (unpack1/pack1) plus the per-block stage launch
                let kst = self.key("stage", 1);
                let kdt = self.key("dt", 1);
                let nslots = self.seg_lens.len();
                let kun1: Vec<ArtifactKey> =
                    (0..nslots).map(|s| self.key("unpack1", 1).with_nbr(s)).collect();
                let kpk1: Vec<ArtifactKey> =
                    (0..nslots).map(|s| self.key("pack1", 1).with_nbr(s)).collect();
                let (descs, staging) = md.parts_mut();
                let d = &descs[pi];
                let p = &mut staging[pi];
                let DeviceState {
                    rt, last_dts, tmp, seg_offs, seg_lens, block_elems, buflen, ..
                } = self;
                let ne = *block_elems;
                let bl = *buflen;
                let t0 = Instant::now();
                for bi in 0..d.nb {
                    let u = &mut p.u[bi * ne..(bi + 1) * ne];
                    let u0 = &p.u0[bi * ne..(bi + 1) * ne];
                    let base = bi * bl;
                    // apply each inbound buffer with its own launch
                    for slot in 0..nslots {
                        let seg = &p.bufs_in[base + seg_offs[slot]
                            ..base + seg_offs[slot] + seg_lens[slot]];
                        rt.unpack1(&kun1[slot], u, seg, tmp)?;
                        u.copy_from_slice(tmp);
                    }
                    rt.stage(&kst, u, u0, scal, tmp)?;
                    u.copy_from_slice(tmp);
                    // fill each outbound buffer with its own launch
                    for slot in 0..nslots {
                        let seg = rt.pack1(&kpk1[slot], u)?;
                        p.bufs_out[base + seg_offs[slot]
                            ..base + seg_offs[slot] + seg_lens[slot]]
                            .copy_from_slice(&seg);
                    }
                    if si == 1 {
                        let dts = rt.dt(&kdt, u, scal)?;
                        last_dts[d.first + bi] = dts[0];
                    }
                }
                t0.elapsed()
            }
            PackStrategy::Native => {
                return Err(Error::Runtime("strategy=native is the Host path".into()))
            }
        };
        // Per-pack launch seconds, spread evenly over the pack's blocks
        // (launches are the per-pack measurement unit on Device).
        let d = &md.packs()[pi];
        let per_block = elapsed.as_secs_f64() / d.nb.max(1) as f64;
        for bi in 0..d.nb {
            self.block_secs[d.first + bi] += per_block;
        }
        Ok(())
    }

    /// Send ONE pack's outbound boundary segments (fused send task; the
    /// phased `route_and_receive` keeps its own whole-rank loop).
    fn send_pack(&self, descs: &[PackDesc], staging: &[PackStaging], pi: usize) {
        let d = &descs[pi];
        let p = &staging[pi];
        for bi in 0..d.nb {
            let flat = d.first + bi;
            let base = bi * self.buflen;
            for (slot, e) in self.routes[flat].iter().enumerate() {
                let seg = &p.bufs_out[base + self.seg_offs[slot]
                    ..base + self.seg_offs[slot] + self.seg_lens[slot]];
                self.comm.isend(e.dst_rank, e.send_tag, Payload::F32(seg.to_vec()));
            }
        }
    }

    /// Poll ONE pack's pending inbound segments (`(block-in-pack, slot)`
    /// pairs) into its `bufs_in`. True when the pack's receives are all in.
    fn poll_pack(
        &self,
        md: &mut MeshData,
        pi: usize,
        pending: &mut Vec<(usize, usize)>,
    ) -> Result<bool> {
        let (descs, staging) = md.parts_mut();
        let d = &descs[pi];
        let p = &mut staging[pi];
        let mut i = 0usize;
        while i < pending.len() {
            let (bi, slot) = pending[i];
            let e = &self.routes[d.first + bi][slot];
            if let Some(payload) = self.comm.try_recv(e.recv_src, e.recv_tag) {
                let data = payload.into_f32()?;
                let base = bi * self.buflen;
                p.bufs_in[base + self.seg_offs[slot]
                    ..base + self.seg_offs[slot] + self.seg_lens[slot]]
                    .copy_from_slice(&data);
                pending.swap_remove(i);
            } else {
                i += 1;
            }
        }
        Ok(pending.is_empty())
    }

    /// The fused Device stage: per-pack task lists order launch → send →
    /// poll, swept round-robin on the driver thread (launches share the
    /// runtime), so one pack's boundary routing overlaps the interior
    /// launches of the others instead of waiting behind a whole-rank
    /// launch barrier. Bitwise identical to the phased path: launches are
    /// per-pack independent and every received segment lands in a disjoint
    /// `bufs_in` slab.
    fn stage_fused(&mut self, md: &mut MeshData, scal: ScalArgs, si: usize) -> Result<()> {
        let npacks = md.npacks();
        let pending: Vec<Vec<(usize, usize)>> =
            md.packs().iter().map(|d| self.pack_pending(d)).collect();

        struct DevStageCtx<'a> {
            dev: &'a mut DeviceState,
            md: &'a mut MeshData,
            pending: Vec<Vec<(usize, usize)>>,
            scal: ScalArgs,
            si: usize,
            error: Option<Error>,
        }

        let mut region: TaskRegion<DevStageCtx> = TaskRegion::new(npacks);
        for pi in 0..npacks {
            let list = region.list(pi);
            let t_launch = list.add(NONE, move |c: &mut DevStageCtx| {
                if c.error.is_some() {
                    return TaskStatus::Complete;
                }
                if let Err(e) = c.dev.launch_pack(c.md, pi, c.scal, c.si) {
                    c.error = Some(e);
                }
                TaskStatus::Complete
            });
            let t_send = list.add(&[t_launch], move |c: &mut DevStageCtx| {
                if c.error.is_some() {
                    return TaskStatus::Complete;
                }
                c.dev.send_pack(c.md.packs(), c.md.staging(), pi);
                TaskStatus::Complete
            });
            let _t_poll = list.add(&[t_send], move |c: &mut DevStageCtx| {
                if c.error.is_some() {
                    return TaskStatus::Complete;
                }
                let DevStageCtx { dev, md, pending, error, .. } = c;
                match dev.poll_pack(md, pi, &mut pending[pi]) {
                    Ok(true) => TaskStatus::Complete,
                    Ok(false) => TaskStatus::Incomplete,
                    Err(e) => {
                        *error = Some(e);
                        TaskStatus::Complete
                    }
                }
            });
        }
        let mut ctx = DevStageCtx { dev: self, md, pending, scal, si, error: None };
        region.execute(&mut ctx, 200_000)?;
        if let Some(e) = ctx.error {
            return Err(e);
        }
        Ok(())
    }
}

impl StageExecutor for DeviceState {
    fn begin_cycle(&mut self, sim: &mut HydroSim) -> Result<()> {
        sim.mesh_data.validate(&sim.mesh)?;
        let (_descs, staging) = sim.mesh_data.parts_mut();
        for p in staging.iter_mut() {
            p.u0.copy_from_slice(&p.u);
        }
        Ok(())
    }

    fn stage(
        &mut self,
        sim: &mut HydroSim,
        co: StageCoeffs,
        si: usize,
        dt: Real,
    ) -> Result<()> {
        sim.mesh_data.validate(&sim.mesh)?;
        if self.strategy == PackStrategy::Native {
            return Err(Error::Runtime("strategy=native is the Host path".into()));
        }
        let scal = self.scal(co, dt, &sim.mesh);
        let overlap = sim.sp.overlap;
        let md = &mut sim.mesh_data;
        if overlap == OverlapMode::Fused {
            // per-pack task lists: launch → send → poll, interleaved
            self.stage_fused(md, scal, si)
        } else {
            // phased oracle: all launches, then the whole-rank routing
            for pi in 0..md.npacks() {
                self.launch_pack(md, pi, scal, si)?;
            }
            self.route_and_receive(md)
        }
    }

    /// Raw min CFL dt across local blocks, scaled by the package CFL.
    fn local_dt(&self, sim: &HydroSim) -> f64 {
        let m = self
            .last_dts
            .iter()
            .fold(Real::INFINITY, |a, &b| a.min(b));
        sim.pkg.cfl as f64 * m as f64
    }
}

fn child_code_of(loc: &crate::mesh::LogicalLocation) -> usize {
    ((loc.lx[0] & 1) | ((loc.lx[1] & 1) << 1) | ((loc.lx[2] & 1) << 2)) as usize
}
