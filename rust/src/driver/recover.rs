//! Crash recovery: run a multi-rank simulation to completion, relaunching
//! from the last durable checkpoint whenever an attempt dies.
//!
//! An attempt dies when any rank's driver returns an error — a simulated
//! rank death from the fault plan's kill schedule, a communication timeout
//! escalated to `Error::Timeout`, a detected `Error::CorruptMessage`, or
//! the cooperative `Error::Aborted` those broadcast to the peers. Unlike
//! [`World::launch`], the relaunch harness joins every rank thread and
//! *collects* failures instead of propagating the first panic, so a dead
//! attempt tears down cleanly and the next one starts from a fresh
//! [`World`] (clean mailboxes, no abort latched).
//!
//! Recovery restores from `parthenon/job checkpoint_path` when a durable
//! checkpoint exists (checkpoints are published atomically via tmp+rename,
//! so a kill mid-write never leaves a torn file — see
//! [`crate::io::write_snapshot`]) and from scratch otherwise. The kill
//! schedule is disarmed on relaunch (`kill_cycle=-1`): the fault it models
//! is a one-shot crash, and re-arming it would kill every attempt at the
//! same cycle forever. Stochastic delay/dup/reorder faults stay armed —
//! they are absorbed transparently and do not perturb the trajectory, so a
//! recovered run finishes bitwise identical to an uninterrupted one
//! (pinned by `rust/tests/chaos.rs`).

use std::path::Path;
use std::sync::Arc;

use crate::comm::World;
use crate::config::{Override, ParameterInput};
use crate::driver::{Driver, SimBuilder};
use crate::error::{Error, Result};
use crate::io::Snapshot;

/// Outcome of [`run_recoverable`].
#[derive(Debug, Clone, Default)]
pub struct RecoveryReport {
    /// Total launch attempts (1 = the run never failed).
    pub attempts: usize,
    /// Attempts that restored state from a durable checkpoint (an attempt
    /// that dies before the first checkpoint restarts from scratch).
    pub restored: usize,
    /// Final simulated time / cycle of the successful attempt.
    pub final_time: f64,
    pub final_cycle: u64,
    /// Errors observed on failed attempts, in order (diagnostics).
    pub failures: Vec<String>,
}

/// Render a rank thread's panic payload for the failure log.
fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        format!("rank panic: {s}")
    } else if let Some(s) = p.downcast_ref::<String>() {
        format!("rank panic: {s}")
    } else {
        "rank panic (non-string payload)".into()
    }
}

/// One launch attempt: every rank builds a sim, optionally restores from
/// `restore_from`, and runs to completion. Per-rank outcomes are collected
/// (never resume_unwind — a dead rank must not take down the harness).
fn attempt(
    input: &str,
    overrides: &[Override],
    nranks: usize,
    restore_from: Option<&str>,
) -> Vec<std::result::Result<(f64, u64), String>> {
    let world = World::new(nranks);
    let input: Arc<str> = input.into();
    let overrides: Arc<[Override]> = overrides.into();
    let restore: Option<Arc<str>> = restore_from.map(Into::into);
    let mut handles = Vec::new();
    for rank in 0..nranks {
        let w = world.clone();
        let input = input.clone();
        let overrides = overrides.clone();
        let restore = restore.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("rank{rank}"))
                .stack_size(32 * 1024 * 1024)
                .spawn(move || -> Result<(f64, u64)> {
                    let mut pin = ParameterInput::from_str(&input)?;
                    for ov in overrides.iter() {
                        pin.apply(ov);
                    }
                    let mut sim =
                        SimBuilder::new(pin).rank(rank).world(w).build()?;
                    if let Some(path) = restore.as_deref() {
                        let snap = Snapshot::read(path)?;
                        sim.restore_snapshot(&snap)?;
                    }
                    sim.execute()?;
                    Ok((sim.time, sim.cycle))
                })
                .expect("spawn rank thread"),
        );
    }
    handles
        .into_iter()
        .map(|h| match h.join() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(e)) => Err(e.to_string()),
            Err(p) => Err(panic_msg(p.as_ref())),
        })
        .collect()
}

/// Run `input` on `nranks` ranks, recovering from rank deaths by
/// relaunching from the last durable checkpoint, at most `max_restarts`
/// times. Returns the recovery report on success; the last attempt's
/// first error once the restart budget is exhausted.
pub fn run_recoverable(
    input: &str,
    overrides: &[Override],
    nranks: usize,
    max_restarts: usize,
) -> Result<RecoveryReport> {
    // Derive the checkpoint path exactly as SimParams::from_input does, so
    // the harness looks where the sim writes.
    let mut pin = ParameterInput::from_str(input)?;
    for ov in overrides {
        pin.apply(ov);
    }
    let out_dir = pin.str_or("parthenon/job", "out_dir", ".");
    let default_chk = format!("{out_dir}/parthenon.chk.pbin");
    let chk_path = pin.str_or("parthenon/job", "checkpoint_path", &default_chk);

    let mut report = RecoveryReport::default();
    let mut ovr = overrides.to_vec();
    let mut relaunch = false;
    loop {
        report.attempts += 1;
        let restore_from = if relaunch && Path::new(&chk_path).exists() {
            report.restored += 1;
            Some(chk_path.as_str())
        } else {
            None
        };
        let outcomes = attempt(input, &ovr, nranks, restore_from);
        match outcomes.iter().find_map(|o| o.as_ref().err().cloned()) {
            None => {
                if let Some(Ok((t, c))) = outcomes.first() {
                    report.final_time = *t;
                    report.final_cycle = *c;
                }
                return Ok(report);
            }
            Some(e) => {
                report.failures.push(e.clone());
                if report.attempts > max_restarts {
                    return Err(Error::Comm(format!(
                        "recovery exhausted after {} attempts: {e}",
                        report.attempts
                    )));
                }
                // Disarm the one-shot kill; leave stochastic faults armed.
                let disarm = Override::new("parthenon/fault", "kill_cycle", -1);
                if !ovr.contains(&disarm) {
                    ovr.push(disarm);
                }
                relaunch = true;
            }
        }
    }
}
