//! AMR regrid + load balancing (paper Sec. 3.8): gather refinement flags,
//! rebuild the tree deterministically on every rank, recompute the Z-order
//! distribution from the *measured* per-block costs (EWMA of cycle
//! seconds), and migrate block data (derefining before sending and
//! refining on the receiving rank, to minimize transfer size).
//!
//! [`rebalance`] is the fixed-tree variant: same-tree re-assignment with
//! point-to-point migration, in one of two modes
//! (`parthenon/loadbalance mode`): the default [`rebalance_incremental`]
//! derives a [`balance::MigrationPlan`] delta and touches ONLY the blocks
//! that change owner (containers stay in place, device staging stays
//! resident, ghosts/routing/bufs_in refresh only for the affected blocks),
//! while [`rebalance_full`] tears every local container down and is kept
//! as the bitwise-identity oracle. Migration and re-gather volumes are
//! recorded in `HydroSim::lb_stats` ([`crate::metrics::RebalanceStats`]).
//!
//! Both rebalance modes carry particle swarms WITH their block: a leaving
//! block's swarms are serialized onto the migration payload (the same
//! per-particle wire format `particles/comm.rs` uses for neighbor
//! transport) and reconstructed on the receiving rank; a staying block's
//! swarms stay in place. Only the AMR regrid ([`apply_new_tree`]) still
//! drops swarms — particle prolongation/restriction is not defined.

use std::collections::HashMap;

use super::HydroSim;
use crate::balance;
use crate::bvals::{self, prolongate_child_from_parent, restrict_block_into_parent};
use crate::comm::{tags, Payload};
use crate::error::Result;
use crate::hydro::native;
use crate::hydro::CONS;
use crate::error::Error;
use crate::mesh::{AmrFlag, LogicalLocation};
use crate::particles::{Swarm, SwarmField};
use crate::vars::Package;
use crate::{Real, NHYDRO};

/// Allgather every rank's (gid, measured cost) pairs and derive per-leaf
/// costs for `new_tree` (which may equal the current tree): unchanged
/// leaves keep their measured EWMA cost, refined children inherit the
/// parent's, derefined parents take the mean of their children. This is a
/// collective — every rank must call it at the same point.
fn gather_global_costs(sim: &HydroSim, new_leaves: &[LogicalLocation]) -> Vec<f64> {
    let mut payload = Vec::new();
    for b in &sim.mesh.blocks {
        payload.extend_from_slice(&(b.gid as u64).to_le_bytes());
        payload.extend_from_slice(&b.cost.to_le_bytes());
    }
    let gathered = sim
        .world
        .comm(sim.mesh.my_rank, 3)
        .with_coll(sim.sp.coll)
        .allgather(payload);
    let mut by_loc: HashMap<LogicalLocation, f64> = HashMap::new();
    for blob in &gathered {
        for chunk in blob.chunks_exact(16) {
            let gid = u64::from_le_bytes(chunk[..8].try_into().unwrap()) as usize;
            let cost = f64::from_le_bytes(chunk[8..16].try_into().unwrap());
            by_loc.insert(sim.mesh.tree.leaves()[gid], cost);
        }
    }
    balance::derive_leaf_costs(new_leaves, &by_loc, sim.mesh.cfg.dim)
}

/// Check refinement criteria, and regrid + rebalance if anything changed.
/// Returns true if the mesh changed.
pub fn check_and_regrid(sim: &mut HydroSim) -> Result<bool> {
    // 1. local flags
    let mut payload = Vec::new();
    for b in &sim.mesh.blocks {
        let flag = sim.pkg.check_refinement(&b.data, &b.coords);
        let f: i8 = match flag {
            AmrFlag::Refine => 1,
            AmrFlag::Derefine => -1,
            AmrFlag::Same => 0,
        };
        payload.extend_from_slice(&(b.gid as u64).to_le_bytes());
        payload.push(f as u8);
    }

    // 2. allgather flags -> identical flag map on every rank
    let gathered = sim
        .world
        .comm(sim.mesh.my_rank, 3)
        .with_coll(sim.sp.coll)
        .allgather(payload);
    let mut flags: HashMap<LogicalLocation, AmrFlag> = HashMap::new();
    for blob in &gathered {
        for chunk in blob.chunks_exact(9) {
            let gid = u64::from_le_bytes(chunk[..8].try_into().unwrap()) as usize;
            let f = chunk[8] as i8;
            let loc = sim.mesh.tree.leaves()[gid];
            let flag = match f {
                1 => AmrFlag::Refine,
                -1 => AmrFlag::Derefine,
                _ => AmrFlag::Same,
            };
            flags.insert(loc, flag);
        }
    }

    // 3. deterministic tree rebuild
    let new_tree = sim.mesh.tree.regrid(&flags, sim.mesh.cfg.max_level);
    if new_tree.leaves() == sim.mesh.tree.leaves() {
        return Ok(false);
    }
    apply_new_tree(sim, new_tree)?;
    Ok(true)
}

/// Swap in a new tree: recompute ranks, migrate data, rebuild local blocks.
pub fn apply_new_tree(sim: &mut HydroSim, new_tree: crate::mesh::BlockTree) -> Result<()> {
    let shape = sim.mesh.cfg.index_shape();
    let nelem = NHYDRO * shape.ncells_total();
    let old_map = sim.mesh.location_map(); // loc -> (old gid, old rank)
    let me = sim.mesh.my_rank;
    let comm = sim.world.comm(me, tags::COMM_MIGRATE);

    let costs = gather_global_costs(sim, new_tree.leaves());
    let new_ranks = balance::assign_blocks(&costs, sim.mesh.nranks);

    // Stash local old block data by location.
    let mut stash: HashMap<LogicalLocation, Vec<Real>> = HashMap::new();
    for b in &sim.mesh.blocks {
        stash.insert(b.loc, b.data.get(CONS)?.as_slice().to_vec());
    }

    // -- send phase -----------------------------------------------------------
    let dim = sim.mesh.cfg.dim;
    for (new_gid, loc) in new_tree.leaves().iter().enumerate() {
        let dst = new_ranks[new_gid];
        // (a) same location existed
        if let Some((_, old_rank)) = old_map.get(loc) {
            if *old_rank == me && dst != me {
                let data = stash.get(loc).unwrap();
                comm.isend(
                    dst,
                    tags::migrate_tag(new_gid, 0),
                    Payload::F32(data.clone()),
                );
            }
            continue;
        }
        // (b) refinement: the parent existed -> parent owner sends its block
        if loc.level > 0 {
            if let Some((_, old_rank)) = old_map.get(&loc.parent()) {
                if *old_rank == me && dst != me {
                    let data = stash.get(&loc.parent()).unwrap();
                    comm.isend(
                        dst,
                        tags::migrate_tag(new_gid, 0),
                        Payload::F32(data.clone()),
                    );
                }
                continue;
            }
        }
        // (c) derefinement: children existed -> each child owner restricts
        //     its quadrant before sending (transfer-size optimization).
        for child in loc.children(dim) {
            if let Some((_, old_rank)) = old_map.get(&child) {
                if *old_rank == me {
                    let bits = child.child_bits();
                    let piece = (bits[0] | (bits[1] << 1) | (bits[2] << 2)) as usize;
                    if dst == me {
                        continue; // local: restricted in the fill phase
                    }
                    let data = stash.get(&child).unwrap();
                    let mut restricted = Vec::new();
                    let interior = crate::bvals::bufspec::Slab {
                        x: (shape.is_(0), shape.ie(0)),
                        y: (shape.is_(1), shape.ie(1)),
                        z: (shape.is_(2), shape.ie(2)),
                    };
                    bvals::restrict_slab(data, &shape, NHYDRO, &interior, &mut restricted);
                    comm.isend(
                        dst,
                        tags::migrate_tag(new_gid, 1 + piece),
                        Payload::F32(restricted),
                    );
                }
            }
        }
    }

    // -- rebuild local blocks --------------------------------------------------
    sim.mesh.tree = new_tree;
    sim.mesh.ranks = new_ranks;
    sim.mesh.rebuild_local_blocks();
    sim.rebuild_work_buffers();
    // carry the derived costs over so the EWMA continues from the
    // inherited weight instead of resetting to nominal
    for b in &mut sim.mesh.blocks {
        b.cost = costs[b.gid];
    }

    // -- fill phase -------------------------------------------------------------
    for bi in 0..sim.mesh.blocks.len() {
        let (loc, gid) = (sim.mesh.blocks[bi].loc, sim.mesh.blocks[bi].gid);
        // (a) direct move / receive
        if let Some((_, old_rank)) = old_map.get(&loc) {
            let data = if *old_rank == me {
                stash.get(&loc).unwrap().clone()
            } else {
                comm.recv(*old_rank, tags::migrate_tag(gid, 0))?.into_f32()?
            };
            sim.mesh.blocks[bi]
                .data
                .get_mut(CONS)?
                .as_mut_slice()
                .copy_from_slice(&data);
            continue;
        }
        // (b) refined from parent
        if loc.level > 0 {
            if let Some((_, old_rank)) = old_map.get(&loc.parent()) {
                let parent_data = if *old_rank == me {
                    stash.get(&loc.parent()).unwrap().clone()
                } else {
                    comm.recv(*old_rank, tags::migrate_tag(gid, 0))?.into_f32()?
                };
                let bits = loc.child_bits();
                let mut child = vec![0.0; nelem];
                prolongate_child_from_parent(&parent_data, &shape, NHYDRO, bits, &mut child);
                sim.mesh.blocks[bi]
                    .data
                    .get_mut(CONS)?
                    .as_mut_slice()
                    .copy_from_slice(&child);
                continue;
            }
        }
        // (c) derefined from children
        let mut parent = vec![0.0; nelem];
        for child in loc.children(dim) {
            let (_, old_rank) = old_map
                .get(&child)
                .expect("new coarse leaf must come from old children");
            let bits = child.child_bits();
            if *old_rank == me {
                let data = stash.get(&child).unwrap();
                restrict_block_into_parent(data, &shape, NHYDRO, bits, &mut parent);
            } else {
                let piece = (bits[0] | (bits[1] << 1) | (bits[2] << 2)) as usize;
                let restricted = comm
                    .recv(*old_rank, tags::migrate_tag(gid, 1 + piece))?
                    .into_f32()?;
                place_restricted_quadrant(&restricted, &shape, bits, &mut parent);
            }
        }
        sim.mesh.blocks[bi]
            .data
            .get_mut(CONS)?
            .as_mut_slice()
            .copy_from_slice(&parent);
    }

    // fresh ghosts + derived everywhere
    let comm_cons = sim.world.comm(me, tags::COMM_BVALS_BASE);
    bvals::exchange_blocking(
        &mut sim.mesh,
        &comm_cons,
        CONS,
        Some([native::IM1, native::IM2, native::IM3]),
    )?;
    sim.fill_derived();
    // Pack identities changed with the tree: re-draw the pack -> space
    // assignment against the new pack count. The regrid runs with the
    // Device engine torn down (see `HydroSim::step`), so this interim
    // draw lands all-host; the caller's `rebuild_device_engine` brings
    // the device back up and re-draws with it available.
    if sim.sp.exec == super::ExecSpace::Hybrid {
        sim.hybrid_assign();
    }
    Ok(())
}

/// Re-derive the cost-balanced assignment for the CURRENT tree and migrate
/// if it changed. Collective: every rank must call this at the same cycle.
/// Returns true if blocks moved.
pub fn check_and_rebalance(sim: &mut HydroSim) -> Result<bool> {
    let costs = gather_global_costs(sim, sim.mesh.tree.leaves());
    let new_ranks = balance::assign_blocks(&costs, sim.mesh.nranks);
    if new_ranks == sim.mesh.ranks {
        return Ok(false);
    }
    rebalance(sim, new_ranks)?;
    Ok(true)
}

/// Fixed-tree load balance: re-assign blocks to ranks and migrate their
/// data point-to-point. Dispatches on `parthenon/loadbalance mode`:
/// [`rebalance_incremental`] (default) migrates ONLY the
/// [`balance::MigrationPlan`] delta; [`rebalance_full`] is the
/// tear-down-everything oracle the incremental path must match bitwise
/// (state, dt bits, cost EWMAs — pinned by
/// `rust/tests/rebalance_incremental.rs`).
///
/// In both modes the measured cost EWMA travels WITH each migrated block —
/// appended to its point-to-point payload (two f32 bit-halves of the f64,
/// exact) — so a migrated-in block continues from the sender's measured
/// weight instead of restarting at the derived nominal value and
/// forgetting the very imbalance that triggered the migration. The block's
/// particle swarms ride the same payload (serialized between the conserved
/// state and the cost words, [`append_swarms`]), so a rebalance moves
/// particles with their block instead of dropping them.
pub fn rebalance(sim: &mut HydroSim, new_ranks: Vec<usize>) -> Result<()> {
    match sim.sp.lb_mode {
        super::RebalanceMode::Full => rebalance_full(sim, new_ranks),
        super::RebalanceMode::Incremental => rebalance_incremental(sim, new_ranks),
    }
}

/// The full-rebuild oracle (`parthenon/loadbalance mode=full`): every local
/// container is torn down and re-filled from a stash or the migration
/// payloads, then a whole-mesh ghost exchange refreshes every boundary.
/// The Device path still keeps its `MeshData` staging resident across the
/// re-plan: only packs whose block set changes are re-gathered afterwards
/// (pinned by the `gathered_packs` instrumentation in
/// `rust/tests/mesh_data_packs.rs`).
pub fn rebalance_full(sim: &mut HydroSim, new_ranks: Vec<usize>) -> Result<()> {
    let me = sim.mesh.my_rank;
    let old_ranks = sim.mesh.ranks.clone();
    assert_eq!(new_ranks.len(), old_ranks.len(), "same-tree rebalance");
    if new_ranks == old_ranks {
        return Ok(());
    }
    let plan = balance::MigrationPlan::between(&old_ranks, &new_ranks);
    sim.lb_stats.rebalances += 1;
    sim.lb_stats.full_rebuilds += 1;
    sim.lb_stats.blocks_moved += plan.len() as u64;
    sim.lb_stats.blocks_sent += plan.leaving(me).count() as u64;
    sim.lb_stats.blocks_received += plan.arriving(me).count() as u64;
    let gathered0 = sim.mesh_data.gathered_packs();
    let comm = sim.world.comm(me, tags::COMM_MIGRATE);
    let mut dev = sim.device.take();

    // Device: every container this oracle is about to stash must be
    // authoritative, and a migration can reshape pack boundaries so that a
    // STAYING block lands in a dirty (re-gathered) pack — scatter every
    // RESIDENT pack, not just the packs holding a leaving block.
    // (Scattering only the leaving packs would re-gather stale containers
    // into any reshaped pack; the incremental path scatters exactly the
    // packs the plan delta marks as not surviving.) Dirty packs are
    // skipped: under hybrid those are the host-assigned packs, whose
    // containers are already authoritative and whose staging is stale.
    if dev.is_some() {
        sim.mesh_data.scatter_resident(&mut sim.mesh, CONS)?;
    }

    // Stash every local block's conserved state AND measured cost by gid
    // (gids are stable: the tree is unchanged), and lift its swarms out of
    // the container before the teardown; send the leaving ones with the
    // swarm blob and the cost appended to the payload.
    let mut stash: HashMap<usize, (Vec<Real>, f64)> = HashMap::new();
    let mut swarm_stash: HashMap<usize, HashMap<String, Swarm>> = HashMap::new();
    for b in &mut sim.mesh.blocks {
        stash.insert(b.gid, (b.data.get(CONS)?.as_slice().to_vec(), b.cost));
        swarm_stash.insert(b.gid, std::mem::take(&mut b.swarms));
    }
    for (gid, (&o, &n)) in old_ranks.iter().zip(new_ranks.iter()).enumerate() {
        if o == me && n != me {
            let (data, cost) = stash.get(&gid).unwrap();
            let mut payload = data.clone();
            let swarms = encode_swarms(swarm_stash.get_mut(&gid).unwrap());
            append_swarms(&mut payload, &swarms);
            append_cost(&mut payload, *cost);
            comm.isend(n, tags::migrate_tag(gid, 0), Payload::F32(payload));
        }
    }
    let old_dts = dev.as_ref().map(|d| d.dts_by_gid(&sim.mesh));

    // Swap the assignment and rebuild local blocks; the pack plan is
    // re-drawn preserving staging of packs whose block set is unchanged.
    sim.mesh.ranks = new_ranks;
    sim.mesh.rebuild_local_blocks();
    let plan_sizes = dev.as_ref().map(|d| d.plan_sizes().to_vec());
    let preserved = sim
        .mesh_data
        .rebuild_preserving(&sim.mesh, plan_sizes.as_deref());
    sim.lb_stats.packs_preserved += preserved as u64;
    sim.rebuild_work_buffers();

    // Fill phase: local restores + receives for migrated-in blocks. The
    // cost EWMA and the particle swarms ride the migration payload (or
    // the local stashes), so both survive the move.
    for bi in 0..sim.mesh.blocks.len() {
        let gid = sim.mesh.blocks[bi].gid;
        let src_rank = old_ranks[gid];
        let (data, cost, swarms) = if src_rank == me {
            let (data, cost) = stash.get(&gid).unwrap().clone();
            (data, cost, swarm_stash.remove(&gid).unwrap_or_default())
        } else {
            let mut payload =
                comm.recv(src_rank, tags::migrate_tag(gid, 0))?.into_f32()?;
            let cost = take_cost(&mut payload);
            let blob = take_swarms(&mut payload);
            (payload, cost, decode_swarms(&blob)?)
        };
        sim.mesh.blocks[bi]
            .data
            .get_mut(CONS)?
            .as_mut_slice()
            .copy_from_slice(&data);
        sim.mesh.blocks[bi].cost = cost;
        sim.mesh.blocks[bi].swarms = swarms;
    }

    // Device: boundary-adjacent slabs of the preserved (clean) packs are
    // scattered so the container-side ghost fill below reads current data;
    // full interiors stay resident in staging.
    if dev.is_some() {
        sim.mesh_data.scatter_boundary(&mut sim.mesh, CONS)?;
    }

    // Fresh ghosts + derived everywhere (containers), then bring the
    // device back: routes rebuilt, only dirty packs re-gathered.
    let comm_cons = sim.world.comm(me, tags::COMM_BVALS_BASE);
    bvals::exchange_blocking(
        &mut sim.mesh,
        &comm_cons,
        CONS,
        Some([native::IM1, native::IM2, native::IM3]),
    )?;
    sim.fill_derived();
    if let Some(ref mut d) = dev {
        d.after_rebalance(sim, old_dts.as_ref().unwrap())?;
    }
    sim.lb_stats.packs_regathered += sim.mesh_data.gathered_packs() - gathered0;
    sim.device = dev;
    // Pack identities changed: re-draw the pack -> space assignment (and
    // reset the per-space cost model to the new pack count).
    if sim.sp.exec == super::ExecSpace::Hybrid {
        sim.hybrid_assign();
    }
    Ok(())
}

/// The incremental rebalance (`parthenon/loadbalance mode=incremental`,
/// the default): touch ONLY what the [`balance::MigrationPlan`] delta
/// says moved.
///
/// * Leaving blocks are sent point-to-point straight from their
///   containers (swarm blob + cost EWMA appended); nothing else is
///   stashed or copied. Staying blocks keep their swarms in place.
/// * [`crate::mesh::Mesh::apply_assignment_incremental`] keeps every
///   staying block's container (data + cost) in place — no teardown, no
///   restore pass.
/// * On Device, [`crate::mesh_data::MeshData::plan_delta`] predicts which
///   packs' staging will not survive the re-plan; exactly those are
///   scattered up front, `rebuild_preserving` keeps the rest resident,
///   and `DeviceState::after_rebalance_incremental` re-points surviving
///   routes by gid, re-gathers/re-packs only the dirty packs and refreshes
///   `bufs_in` via the dirty-subset routing round.
/// * Ghosts are refreshed ONLY for the moved blocks
///   ([`bvals::exchange_blocking_subset`]); every other block's ghost data
///   is already current from the last stage exchange — migration changes
///   owners, never values.
///
/// Every step mirrors a full-rebuild step byte-for-byte (same payloads,
/// same kernels on the same data), which is what makes `mode=full` a
/// usable bitwise oracle.
pub fn rebalance_incremental(sim: &mut HydroSim, new_ranks: Vec<usize>) -> Result<()> {
    use std::collections::HashSet;
    let me = sim.mesh.my_rank;
    let old_ranks = sim.mesh.ranks.clone();
    assert_eq!(new_ranks.len(), old_ranks.len(), "same-tree rebalance");
    let plan = balance::MigrationPlan::between(&old_ranks, &new_ranks);
    if plan.is_empty() {
        return Ok(());
    }
    sim.lb_stats.rebalances += 1;
    sim.lb_stats.blocks_moved += plan.len() as u64;
    let comm = sim.world.comm(me, tags::COMM_MIGRATE);
    let mut dev = sim.device.take();

    // The locations this rank owns AFTER the move (gid order) — the key
    // for predicting which packs' staging survives the re-plan.
    let new_locs: Vec<LogicalLocation> = sim
        .mesh
        .tree
        .leaves()
        .iter()
        .enumerate()
        .filter(|(gid, _)| new_ranks[*gid] == me)
        .map(|(_, l)| *l)
        .collect();

    // Device: scatter exactly the packs whose staging will NOT survive —
    // their blocks' containers must be authoritative before they are sent
    // away or re-gathered into a reshaped pack. Capture the gid-keyed
    // route map while the old block order still exists.
    let mut old_routes = None;
    if let Some(d) = dev.as_ref() {
        let delta = sim.mesh_data.plan_delta(&new_locs, Some(d.plan_sizes()));
        sim.mesh_data.scatter_packs(&mut sim.mesh, CONS, &delta.stale_old)?;
        old_routes = Some(d.routes_by_gid(&sim.mesh));
    }
    let old_dts = dev.as_ref().map(|d| d.dts_by_gid(&sim.mesh));

    // Send ONLY the leaving blocks, straight from their containers
    // (extracting their particles onto the wire as we go).
    for b in &mut sim.mesh.blocks {
        let dst = new_ranks[b.gid];
        if dst != me {
            let mut payload = b.data.get(CONS)?.as_slice().to_vec();
            let swarms = encode_swarms(&mut b.swarms);
            append_swarms(&mut payload, &swarms);
            append_cost(&mut payload, b.cost);
            comm.isend(dst, tags::migrate_tag(b.gid, 0), Payload::F32(payload));
            sim.lb_stats.blocks_sent += 1;
        }
    }

    // Apply the new ownership in place: staying blocks keep their
    // containers and cost EWMA verbatim; arriving blocks get fresh
    // containers filled from the payloads below.
    let kept = sim.mesh.apply_assignment_incremental(new_ranks);
    sim.lb_stats.blocks_kept += kept as u64;
    let plan_sizes = dev.as_ref().map(|d| d.plan_sizes().to_vec());
    let preserved = sim
        .mesh_data
        .rebuild_preserving(&sim.mesh, plan_sizes.as_deref());
    sim.lb_stats.packs_preserved += preserved as u64;
    sim.resize_work_buffers();

    // Fill ONLY the arriving blocks (the cost EWMA rides the payload).
    for bi in 0..sim.mesh.blocks.len() {
        let gid = sim.mesh.blocks[bi].gid;
        let src = old_ranks[gid];
        if src == me {
            continue;
        }
        let mut payload = comm.recv(src, tags::migrate_tag(gid, 0))?.into_f32()?;
        let cost = take_cost(&mut payload);
        let blob = take_swarms(&mut payload);
        sim.mesh.blocks[bi]
            .data
            .get_mut(CONS)?
            .as_mut_slice()
            .copy_from_slice(&payload);
        sim.mesh.blocks[bi].cost = cost;
        sim.mesh.blocks[bi].swarms = decode_swarms(&blob)?;
        sim.lb_stats.blocks_received += 1;
    }

    // Ghost refresh limited to the moved blocks: they receive their full
    // inbound segment set; every rank sends only the segments a moved
    // block needs. Staying blocks' ghosts are already current (the last
    // stage exchange filled them from the very same neighbor data).
    let moved: HashSet<usize> = plan.moved_gids().collect();
    if dev.is_some() {
        // container-side senders next to a moved block may sit in clean
        // packs whose containers are stale — sync just those packs'
        // boundary slabs from the resident staging. One linear pass:
        // block -> pack from the contiguous plan, pack membership as flags.
        let mut block_pack = vec![0usize; sim.mesh_data.nblocks()];
        for d in sim.mesh_data.packs() {
            for bi in d.block_range() {
                block_pack[bi] = d.index;
            }
        }
        let mut is_sender = vec![false; sim.mesh_data.npacks()];
        if let Some(routes) = old_routes.as_ref() {
            for (bi, b) in sim.mesh.blocks.iter().enumerate() {
                let Some(entries) = routes.get(&b.gid) else { continue };
                if entries.iter().any(|e| moved.contains(&e.ngid())) {
                    is_sender[block_pack[bi]] = true;
                }
            }
        }
        let sender_packs: Vec<usize> = is_sender
            .iter()
            .enumerate()
            .filter_map(|(pi, s)| s.then_some(pi))
            .collect();
        sim.mesh_data
            .scatter_boundary_packs(&mut sim.mesh, CONS, &sender_packs)?;
    }
    let comm_cons = sim.world.comm(me, tags::COMM_BVALS_BASE);
    let nseg = bvals::exchange_blocking_subset(
        &mut sim.mesh,
        &comm_cons,
        CONS,
        Some([native::IM1, native::IM2, native::IM3]),
        &moved,
    )?;
    sim.lb_stats.bval_segments_resent += nseg as u64;
    sim.fill_derived_for(&moved);

    // Device bring-back: gid-keyed route re-pointing, dirty-pack-only
    // re-gather/re-pack, and the subset bufs_in refresh.
    let gathered0 = sim.mesh_data.gathered_packs();
    if let Some(ref mut d) = dev {
        let (rebuilt, resent) = d.after_rebalance_incremental(
            sim,
            old_dts.as_ref().unwrap(),
            old_routes.take().unwrap(),
        )?;
        sim.lb_stats.routes_rebuilt += rebuilt;
        sim.lb_stats.bval_segments_resent += resent;
    }
    sim.lb_stats.packs_regathered += sim.mesh_data.gathered_packs() - gathered0;
    sim.device = dev;
    // Pack identities changed: re-draw the pack -> space assignment (and
    // reset the per-space cost model to the new pack count).
    if sim.sp.exec == super::ExecSpace::Hybrid {
        sim.hybrid_assign();
    }
    Ok(())
}

/// Append an f64 cost to an f32 migration payload as two bit-exact halves
/// (hi word first). [`take_cost`] reverses it on the receiving rank.
fn append_cost(payload: &mut Vec<Real>, cost: f64) {
    let bits = cost.to_bits();
    payload.push(Real::from_bits((bits >> 32) as u32));
    payload.push(Real::from_bits(bits as u32));
}

/// Pop the two cost halves appended by [`append_cost`], restoring the f64.
fn take_cost(payload: &mut Vec<Real>) -> f64 {
    let lo = payload.pop().expect("migration payload carries a cost").to_bits() as u64;
    let hi = payload.pop().expect("migration payload carries a cost").to_bits() as u64;
    f64::from_bits((hi << 32) | lo)
}

// -- swarm-carrying migration ----------------------------------------------
//
// A leaving block's swarms are flattened into one byte blob (all integers
// little-endian u32, per-particle records in the `particles/comm.rs` wire
// format, i.e. [`Swarm::extract`] field order = BTreeMap order):
//
//   u32 n_swarms
//   per swarm, in sorted-name order:
//     u32 name_len, name bytes
//     u32 n_extra_fields            (beyond the implicit x/y/z)
//     per extra field: u32 kind (0 = Real, 1 = Int), u32 name_len, bytes
//     u32 particle_bytes_len, particle bytes
//
// The blob rides the f32 migration payload as bit-cast words followed by
// one byte-length word ([`append_swarms`]/[`take_swarms`]), sitting
// between the conserved state and the two cost words.

/// Serialize (and drain the particles of) every swarm on a leaving block.
fn encode_swarms(swarms: &mut HashMap<String, Swarm>) -> Vec<u8> {
    let mut names: Vec<String> = swarms.keys().cloned().collect();
    names.sort();
    let mut out = Vec::new();
    put_u32(&mut out, names.len() as u32);
    for name in &names {
        let sw = swarms.get_mut(name).unwrap();
        put_bytes(&mut out, name.as_bytes());
        let extras: Vec<(u32, String)> = sw
            .field_names()
            .filter(|n| !matches!(*n, "x" | "y" | "z"))
            .map(|n| {
                let kind = if sw.real_field(n).is_ok() { 0u32 } else { 1u32 };
                (kind, n.to_string())
            })
            .collect();
        put_u32(&mut out, extras.len() as u32);
        for (kind, fname) in &extras {
            put_u32(&mut out, *kind);
            put_bytes(&mut out, fname.as_bytes());
        }
        let active = sw.active_indices();
        let particles = sw.extract(&active);
        put_bytes(&mut out, &particles);
    }
    out
}

/// Rebuild a block's swarms from the blob [`encode_swarms`] produced.
fn decode_swarms(blob: &[u8]) -> Result<HashMap<String, Swarm>> {
    let mut pos = 0usize;
    let nsw = get_u32(blob, &mut pos)? as usize;
    let mut out = HashMap::new();
    for _ in 0..nsw {
        let name = get_str(blob, &mut pos)?;
        let nex = get_u32(blob, &mut pos)? as usize;
        let mut extras = Vec::with_capacity(nex);
        for _ in 0..nex {
            let kind = get_u32(blob, &mut pos)?;
            let fname = get_str(blob, &mut pos)?;
            extras.push(match kind {
                0 => SwarmField::Real(fname),
                1 => SwarmField::Int(fname),
                k => {
                    return Err(Error::Comm(format!(
                        "swarm migration: unknown field kind {k}"
                    )))
                }
            });
        }
        let mut sw = Swarm::new(&name, &extras);
        let particles = get_bytes(blob, &mut pos)?;
        sw.insert_bytes(particles)?;
        out.insert(name, sw);
    }
    if pos != blob.len() {
        return Err(Error::Comm(format!(
            "swarm migration: {} trailing bytes in blob",
            blob.len() - pos
        )));
    }
    Ok(out)
}

/// Append the swarm blob to an f32 migration payload: the bytes bit-cast
/// into words (zero-padded tail), then one word holding the byte length.
fn append_swarms(payload: &mut Vec<Real>, blob: &[u8]) {
    let nwords = (blob.len() + 3) / 4;
    for w in 0..nwords {
        let mut buf = [0u8; 4];
        let start = w * 4;
        let end = (start + 4).min(blob.len());
        buf[..end - start].copy_from_slice(&blob[start..end]);
        payload.push(Real::from_bits(u32::from_le_bytes(buf)));
    }
    payload.push(Real::from_bits(blob.len() as u32));
}

/// Pop the swarm blob appended by [`append_swarms`] (call AFTER
/// [`take_cost`] — the cost words sit on top).
fn take_swarms(payload: &mut Vec<Real>) -> Vec<u8> {
    let len = payload
        .pop()
        .expect("migration payload carries a swarm blob")
        .to_bits() as usize;
    let nwords = (len + 3) / 4;
    assert!(payload.len() >= nwords, "migration payload carries a swarm blob");
    let words = payload.split_off(payload.len() - nwords);
    let mut blob = Vec::with_capacity(nwords * 4);
    for w in &words {
        blob.extend_from_slice(&w.to_bits().to_le_bytes());
    }
    blob.truncate(len);
    blob
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    put_u32(out, b.len() as u32);
    out.extend_from_slice(b);
}

fn get_u32(b: &[u8], pos: &mut usize) -> Result<u32> {
    let end = *pos + 4;
    if end > b.len() {
        return Err(Error::Comm("swarm migration: truncated blob".into()));
    }
    let v = u32::from_le_bytes(b[*pos..end].try_into().unwrap());
    *pos = end;
    Ok(v)
}

fn get_bytes<'a>(b: &'a [u8], pos: &mut usize) -> Result<&'a [u8]> {
    let len = get_u32(b, pos)? as usize;
    let end = *pos + len;
    if end > b.len() {
        return Err(Error::Comm("swarm migration: truncated blob".into()));
    }
    let v = &b[*pos..end];
    *pos = end;
    Ok(v)
}

fn get_str(b: &[u8], pos: &mut usize) -> Result<String> {
    String::from_utf8(get_bytes(b, pos)?.to_vec())
        .map_err(|_| Error::Comm("swarm migration: non-utf8 name in blob".into()))
}

/// Place a restricted child interior (dense [nvar, nz/2, ny/2, nx/2] in
/// active dims) into the parent's octant.
fn place_restricted_quadrant(
    data: &[Real],
    shape: &crate::mesh::IndexShape,
    bits: [i64; 3],
    parent: &mut [Real],
) {
    let dim = shape.dim;
    let n = shape.ncells_total();
    let (nt0, nt1) = (shape.nt(0), shape.nt(1));
    let cx = shape.n[0] / 2;
    let cy = if dim >= 2 { shape.n[1] / 2 } else { 1 };
    let cz = if dim >= 3 { shape.n[2] / 2 } else { 1 };
    let ox = shape.is_(0) + bits[0] as usize * cx;
    let oy = shape.is_(1) + if dim >= 2 { bits[1] as usize * cy } else { 0 };
    let oz = shape.is_(2) + if dim >= 3 { bits[2] as usize * cz } else { 0 };
    let mut r = 0usize;
    for v in 0..NHYDRO {
        for k in 0..cz {
            for j in 0..cy {
                for i in 0..cx {
                    parent[v * n + ((oz + k) * nt1 + oy + j) * nt0 + ox + i] = data[r];
                    r += 1;
                }
            }
        }
    }
    debug_assert_eq!(r, data.len());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_rides_payload_bit_exactly() {
        for cost in [0.0f64, 1.0, 0.37519, 1e-300, 1.2345678e13, f64::MIN_POSITIVE] {
            let mut payload = vec![1.5 as Real, -2.25];
            append_cost(&mut payload, cost);
            assert_eq!(payload.len(), 4);
            let got = take_cost(&mut payload);
            assert_eq!(got.to_bits(), cost.to_bits(), "cost must survive bit-exactly");
            assert_eq!(payload, vec![1.5 as Real, -2.25]);
        }
    }

    fn sample_swarms() -> HashMap<String, Swarm> {
        let mut tracers = Swarm::new("tracers", &[SwarmField::Int("id".into())]);
        let slots = tracers.add_particles(3);
        for (i, &s) in slots.iter().enumerate() {
            tracers.real_field_mut("x").unwrap()[s] = 0.125 * i as Real;
            tracers.real_field_mut("y").unwrap()[s] = -1.5 + i as Real;
            tracers.real_field_mut("z").unwrap()[s] = 7.0;
            tracers.int_field_mut("id").unwrap()[s] = 100 + i as i64;
        }
        let mut dust = Swarm::new(
            "dust",
            &[SwarmField::Real("mass".into()), SwarmField::Int("kind".into())],
        );
        let s = dust.add_particles(1)[0];
        dust.real_field_mut("mass").unwrap()[s] = 1e-6;
        dust.int_field_mut("kind").unwrap()[s] = -3;
        let mut out = HashMap::new();
        out.insert("tracers".to_string(), tracers);
        out.insert("dust".to_string(), dust);
        out
    }

    #[test]
    fn swarms_round_trip_through_the_migration_payload() {
        let mut swarms = sample_swarms();
        let mut payload = vec![3.25 as Real, -0.5]; // stand-in conserved state
        let blob = encode_swarms(&mut swarms);
        // extraction drains the sender's particles (the block is leaving)
        assert!(swarms.values().all(|s| s.num_active() == 0));
        append_swarms(&mut payload, &blob);
        append_cost(&mut payload, 0.625);

        // receiver pops in reverse order: cost first, then the blob
        assert_eq!(take_cost(&mut payload).to_bits(), 0.625f64.to_bits());
        let got_blob = take_swarms(&mut payload);
        assert_eq!(got_blob, blob);
        assert_eq!(payload, vec![3.25 as Real, -0.5]);

        let got = decode_swarms(&got_blob).unwrap();
        assert_eq!(got.len(), 2);
        let tracers = &got["tracers"];
        assert_eq!(tracers.num_active(), 3);
        let idx = tracers.active_indices();
        for (i, &s) in idx.iter().enumerate() {
            assert_eq!(tracers.real_field("x").unwrap()[s], 0.125 * i as Real);
            assert_eq!(tracers.real_field("y").unwrap()[s], -1.5 + i as Real);
            assert_eq!(tracers.real_field("z").unwrap()[s], 7.0);
            assert_eq!(tracers.int_field("id").unwrap()[s], 100 + i as i64);
        }
        let dust = &got["dust"];
        assert_eq!(dust.num_active(), 1);
        let s = dust.active_indices()[0];
        assert_eq!(dust.real_field("mass").unwrap()[s], 1e-6);
        assert_eq!(dust.int_field("kind").unwrap()[s], -3);
    }

    #[test]
    fn empty_swarm_map_rides_as_a_tiny_blob() {
        let mut empty = HashMap::new();
        let blob = encode_swarms(&mut empty);
        assert_eq!(blob, vec![0, 0, 0, 0]);
        let mut payload: Vec<Real> = vec![1.0];
        append_swarms(&mut payload, &blob);
        assert_eq!(payload.len(), 3); // state + 1 word + length word
        let got = take_swarms(&mut payload);
        assert!(decode_swarms(&got).unwrap().is_empty());
        assert_eq!(payload, vec![1.0 as Real]);
    }

    #[test]
    fn decode_rejects_corrupt_blobs() {
        assert!(decode_swarms(&[1, 0, 0]).is_err(), "truncated count");
        // n_swarms = 1 but nothing follows
        assert!(decode_swarms(&[1, 0, 0, 0]).is_err());
        let mut swarms = sample_swarms();
        let mut blob = encode_swarms(&mut swarms);
        blob.push(0); // trailing garbage
        assert!(decode_swarms(&blob).is_err());
    }
}
