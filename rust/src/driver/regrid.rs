//! AMR regrid + load balancing (paper Sec. 3.8): gather refinement flags,
//! rebuild the tree deterministically on every rank, recompute the Z-order
//! distribution from the *measured* per-block costs (EWMA of cycle
//! seconds), and migrate block data (derefining before sending and
//! refining on the receiving rank, to minimize transfer size).
//!
//! [`rebalance`] is the fixed-tree variant: same-tree re-assignment with
//! point-to-point migration. On Device runs it preserves the persistent
//! staging of every pack whose block set is unchanged (only migrated packs
//! are scattered/re-gathered — see `MeshData::rebuild_preserving`).

use std::collections::HashMap;

use super::HydroSim;
use crate::balance;
use crate::bvals::{self, prolongate_child_from_parent, restrict_block_into_parent};
use crate::comm::{tags, Payload};
use crate::error::Result;
use crate::hydro::native;
use crate::hydro::CONS;
use crate::mesh::{AmrFlag, LogicalLocation};
use crate::vars::Package;
use crate::{Real, NHYDRO};

/// Allgather every rank's (gid, measured cost) pairs and derive per-leaf
/// costs for `new_tree` (which may equal the current tree): unchanged
/// leaves keep their measured EWMA cost, refined children inherit the
/// parent's, derefined parents take the mean of their children. This is a
/// collective — every rank must call it at the same point.
fn gather_global_costs(sim: &HydroSim, new_leaves: &[LogicalLocation]) -> Vec<f64> {
    let mut payload = Vec::new();
    for b in &sim.mesh.blocks {
        payload.extend_from_slice(&(b.gid as u64).to_le_bytes());
        payload.extend_from_slice(&b.cost.to_le_bytes());
    }
    let gathered = sim.world.comm(sim.mesh.my_rank, 3).allgather(payload);
    let mut by_loc: HashMap<LogicalLocation, f64> = HashMap::new();
    for blob in &gathered {
        for chunk in blob.chunks_exact(16) {
            let gid = u64::from_le_bytes(chunk[..8].try_into().unwrap()) as usize;
            let cost = f64::from_le_bytes(chunk[8..16].try_into().unwrap());
            by_loc.insert(sim.mesh.tree.leaves()[gid], cost);
        }
    }
    balance::derive_leaf_costs(new_leaves, &by_loc, sim.mesh.cfg.dim)
}

/// Check refinement criteria, and regrid + rebalance if anything changed.
/// Returns true if the mesh changed.
pub fn check_and_regrid(sim: &mut HydroSim) -> Result<bool> {
    // 1. local flags
    let mut payload = Vec::new();
    for b in &sim.mesh.blocks {
        let flag = sim.pkg.check_refinement(&b.data, &b.coords);
        let f: i8 = match flag {
            AmrFlag::Refine => 1,
            AmrFlag::Derefine => -1,
            AmrFlag::Same => 0,
        };
        payload.extend_from_slice(&(b.gid as u64).to_le_bytes());
        payload.push(f as u8);
    }

    // 2. allgather flags -> identical flag map on every rank
    let gathered = sim.world.comm(sim.mesh.my_rank, 3).allgather(payload);
    let mut flags: HashMap<LogicalLocation, AmrFlag> = HashMap::new();
    for blob in &gathered {
        for chunk in blob.chunks_exact(9) {
            let gid = u64::from_le_bytes(chunk[..8].try_into().unwrap()) as usize;
            let f = chunk[8] as i8;
            let loc = sim.mesh.tree.leaves()[gid];
            let flag = match f {
                1 => AmrFlag::Refine,
                -1 => AmrFlag::Derefine,
                _ => AmrFlag::Same,
            };
            flags.insert(loc, flag);
        }
    }

    // 3. deterministic tree rebuild
    let new_tree = sim.mesh.tree.regrid(&flags, sim.mesh.cfg.max_level);
    if new_tree.leaves() == sim.mesh.tree.leaves() {
        return Ok(false);
    }
    apply_new_tree(sim, new_tree)?;
    Ok(true)
}

/// Swap in a new tree: recompute ranks, migrate data, rebuild local blocks.
pub fn apply_new_tree(sim: &mut HydroSim, new_tree: crate::mesh::BlockTree) -> Result<()> {
    let shape = sim.mesh.cfg.index_shape();
    let nelem = NHYDRO * shape.ncells_total();
    let old_map = sim.mesh.location_map(); // loc -> (old gid, old rank)
    let me = sim.mesh.my_rank;
    let comm = sim.world.comm(me, tags::COMM_MIGRATE);

    let costs = gather_global_costs(sim, new_tree.leaves());
    let new_ranks = balance::assign_blocks(&costs, sim.mesh.nranks);

    // Stash local old block data by location.
    let mut stash: HashMap<LogicalLocation, Vec<Real>> = HashMap::new();
    for b in &sim.mesh.blocks {
        stash.insert(b.loc, b.data.get(CONS)?.as_slice().to_vec());
    }

    // -- send phase -----------------------------------------------------------
    let dim = sim.mesh.cfg.dim;
    for (new_gid, loc) in new_tree.leaves().iter().enumerate() {
        let dst = new_ranks[new_gid];
        // (a) same location existed
        if let Some((_, old_rank)) = old_map.get(loc) {
            if *old_rank == me && dst != me {
                let data = stash.get(loc).unwrap();
                comm.isend(
                    dst,
                    tags::migrate_tag(new_gid, 0),
                    Payload::F32(data.clone()),
                );
            }
            continue;
        }
        // (b) refinement: the parent existed -> parent owner sends its block
        if loc.level > 0 {
            if let Some((_, old_rank)) = old_map.get(&loc.parent()) {
                if *old_rank == me && dst != me {
                    let data = stash.get(&loc.parent()).unwrap();
                    comm.isend(
                        dst,
                        tags::migrate_tag(new_gid, 0),
                        Payload::F32(data.clone()),
                    );
                }
                continue;
            }
        }
        // (c) derefinement: children existed -> each child owner restricts
        //     its quadrant before sending (transfer-size optimization).
        for child in loc.children(dim) {
            if let Some((_, old_rank)) = old_map.get(&child) {
                if *old_rank == me {
                    let bits = child.child_bits();
                    let piece = (bits[0] | (bits[1] << 1) | (bits[2] << 2)) as usize;
                    if dst == me {
                        continue; // local: restricted in the fill phase
                    }
                    let data = stash.get(&child).unwrap();
                    let mut restricted = Vec::new();
                    let interior = crate::bvals::bufspec::Slab {
                        x: (shape.is_(0), shape.ie(0)),
                        y: (shape.is_(1), shape.ie(1)),
                        z: (shape.is_(2), shape.ie(2)),
                    };
                    bvals::restrict_slab(data, &shape, NHYDRO, &interior, &mut restricted);
                    comm.isend(
                        dst,
                        tags::migrate_tag(new_gid, 1 + piece),
                        Payload::F32(restricted),
                    );
                }
            }
        }
    }

    // -- rebuild local blocks --------------------------------------------------
    sim.mesh.tree = new_tree;
    sim.mesh.ranks = new_ranks;
    sim.mesh.rebuild_local_blocks();
    sim.rebuild_work_buffers();
    // carry the derived costs over so the EWMA continues from the
    // inherited weight instead of resetting to nominal
    for b in &mut sim.mesh.blocks {
        b.cost = costs[b.gid];
    }

    // -- fill phase -------------------------------------------------------------
    for bi in 0..sim.mesh.blocks.len() {
        let (loc, gid) = (sim.mesh.blocks[bi].loc, sim.mesh.blocks[bi].gid);
        // (a) direct move / receive
        if let Some((_, old_rank)) = old_map.get(&loc) {
            let data = if *old_rank == me {
                stash.get(&loc).unwrap().clone()
            } else {
                comm.recv(*old_rank, tags::migrate_tag(gid, 0)).into_f32()?
            };
            sim.mesh.blocks[bi]
                .data
                .get_mut(CONS)?
                .as_mut_slice()
                .copy_from_slice(&data);
            continue;
        }
        // (b) refined from parent
        if loc.level > 0 {
            if let Some((_, old_rank)) = old_map.get(&loc.parent()) {
                let parent_data = if *old_rank == me {
                    stash.get(&loc.parent()).unwrap().clone()
                } else {
                    comm.recv(*old_rank, tags::migrate_tag(gid, 0)).into_f32()?
                };
                let bits = loc.child_bits();
                let mut child = vec![0.0; nelem];
                prolongate_child_from_parent(&parent_data, &shape, NHYDRO, bits, &mut child);
                sim.mesh.blocks[bi]
                    .data
                    .get_mut(CONS)?
                    .as_mut_slice()
                    .copy_from_slice(&child);
                continue;
            }
        }
        // (c) derefined from children
        let mut parent = vec![0.0; nelem];
        for child in loc.children(dim) {
            let (_, old_rank) = old_map
                .get(&child)
                .expect("new coarse leaf must come from old children");
            let bits = child.child_bits();
            if *old_rank == me {
                let data = stash.get(&child).unwrap();
                restrict_block_into_parent(data, &shape, NHYDRO, bits, &mut parent);
            } else {
                let piece = (bits[0] | (bits[1] << 1) | (bits[2] << 2)) as usize;
                let restricted = comm
                    .recv(*old_rank, tags::migrate_tag(gid, 1 + piece))
                    .into_f32()?;
                place_restricted_quadrant(&restricted, &shape, bits, &mut parent);
            }
        }
        sim.mesh.blocks[bi]
            .data
            .get_mut(CONS)?
            .as_mut_slice()
            .copy_from_slice(&parent);
    }

    // fresh ghosts + derived everywhere
    let comm_cons = sim.world.comm(me, tags::COMM_BVALS_BASE);
    bvals::exchange_blocking(
        &mut sim.mesh,
        &comm_cons,
        CONS,
        Some([native::IM1, native::IM2, native::IM3]),
    )?;
    sim.fill_derived();
    Ok(())
}

/// Re-derive the cost-balanced assignment for the CURRENT tree and migrate
/// if it changed. Collective: every rank must call this at the same cycle.
/// Returns true if blocks moved.
pub fn check_and_rebalance(sim: &mut HydroSim) -> Result<bool> {
    let costs = gather_global_costs(sim, sim.mesh.tree.leaves());
    let new_ranks = balance::assign_blocks(&costs, sim.mesh.nranks);
    if new_ranks == sim.mesh.ranks {
        return Ok(false);
    }
    rebalance(sim, new_ranks)?;
    Ok(true)
}

/// Fixed-tree load balance: re-assign blocks to ranks and migrate their
/// data point-to-point. The Device path keeps its `MeshData` staging
/// resident: only packs whose block set changes are scattered (to make the
/// leaving blocks' containers authoritative) and re-gathered afterwards;
/// untouched packs keep their staging verbatim (pinned by the
/// `gathered_packs` instrumentation in `rust/tests/mesh_data_packs.rs`).
///
/// The measured cost EWMA travels WITH each migrated block — appended to
/// its point-to-point payload (two f32 bit-halves of the f64, exact) — so
/// a migrated-in block continues from the sender's measured weight instead
/// of restarting at the derived nominal value and forgetting the very
/// imbalance that triggered the migration. Blocks that stay put restore
/// their cost from a local stash (rebuild_local_blocks resets containers).
/// No extra collective is needed (the old implementation re-allgathered
/// every rank's costs here).
pub fn rebalance(sim: &mut HydroSim, new_ranks: Vec<usize>) -> Result<()> {
    let me = sim.mesh.my_rank;
    let old_ranks = sim.mesh.ranks.clone();
    assert_eq!(new_ranks.len(), old_ranks.len(), "same-tree rebalance");
    if new_ranks == old_ranks {
        return Ok(());
    }
    let comm = sim.world.comm(me, tags::COMM_MIGRATE);
    let mut dev = sim.device.take();

    // Device: containers of blocks that LEAVE this rank must be made
    // authoritative before they are stashed/sent — scatter only the packs
    // that hold a leaving block, not the whole rank.
    if dev.is_some() {
        let leaving: Vec<usize> = sim
            .mesh_data
            .packs()
            .iter()
            .enumerate()
            .filter(|(_, d)| {
                sim.mesh.blocks[d.block_range()]
                    .iter()
                    .any(|b| new_ranks[b.gid] != me)
            })
            .map(|(pi, _)| pi)
            .collect();
        sim.mesh_data.scatter_packs(&mut sim.mesh, CONS, &leaving)?;
    }

    // Stash every local block's conserved state AND measured cost by gid
    // (gids are stable: the tree is unchanged); send the leaving ones with
    // the cost appended to the payload.
    let mut stash: HashMap<usize, (Vec<Real>, f64)> = HashMap::new();
    for b in &sim.mesh.blocks {
        stash.insert(b.gid, (b.data.get(CONS)?.as_slice().to_vec(), b.cost));
    }
    for (gid, (&o, &n)) in old_ranks.iter().zip(new_ranks.iter()).enumerate() {
        if o == me && n != me {
            let (data, cost) = stash.get(&gid).unwrap();
            let mut payload = data.clone();
            append_cost(&mut payload, *cost);
            comm.isend(n, tags::migrate_tag(gid, 0), Payload::F32(payload));
        }
    }
    let old_dts = dev.as_ref().map(|d| d.dts_by_gid(&sim.mesh));

    // Swap the assignment and rebuild local blocks; the pack plan is
    // re-drawn preserving staging of packs whose block set is unchanged.
    sim.mesh.ranks = new_ranks;
    sim.mesh.rebuild_local_blocks();
    let plan_sizes = dev.as_ref().map(|d| d.plan_sizes().to_vec());
    sim.mesh_data
        .rebuild_preserving(&sim.mesh, plan_sizes.as_deref());
    sim.rebuild_work_buffers();

    // Fill phase: local restores + receives for migrated-in blocks. The
    // cost EWMA rides the migration payload (or the local stash), so the
    // measured weight survives the move.
    for bi in 0..sim.mesh.blocks.len() {
        let gid = sim.mesh.blocks[bi].gid;
        let src_rank = old_ranks[gid];
        let (data, cost) = if src_rank == me {
            stash.get(&gid).unwrap().clone()
        } else {
            let mut payload =
                comm.recv(src_rank, tags::migrate_tag(gid, 0)).into_f32()?;
            let cost = take_cost(&mut payload);
            (payload, cost)
        };
        sim.mesh.blocks[bi]
            .data
            .get_mut(CONS)?
            .as_mut_slice()
            .copy_from_slice(&data);
        sim.mesh.blocks[bi].cost = cost;
    }

    // Device: boundary-adjacent slabs of the preserved (clean) packs are
    // scattered so the container-side ghost fill below reads current data;
    // full interiors stay resident in staging.
    if dev.is_some() {
        sim.mesh_data.scatter_boundary(&mut sim.mesh, CONS)?;
    }

    // Fresh ghosts + derived everywhere (containers), then bring the
    // device back: routes rebuilt, only dirty packs re-gathered.
    let comm_cons = sim.world.comm(me, tags::COMM_BVALS_BASE);
    bvals::exchange_blocking(
        &mut sim.mesh,
        &comm_cons,
        CONS,
        Some([native::IM1, native::IM2, native::IM3]),
    )?;
    sim.fill_derived();
    if let Some(ref mut d) = dev {
        d.after_rebalance(sim, old_dts.as_ref().unwrap())?;
    }
    sim.device = dev;
    Ok(())
}

/// Append an f64 cost to an f32 migration payload as two bit-exact halves
/// (hi word first). [`take_cost`] reverses it on the receiving rank.
fn append_cost(payload: &mut Vec<Real>, cost: f64) {
    let bits = cost.to_bits();
    payload.push(Real::from_bits((bits >> 32) as u32));
    payload.push(Real::from_bits(bits as u32));
}

/// Pop the two cost halves appended by [`append_cost`], restoring the f64.
fn take_cost(payload: &mut Vec<Real>) -> f64 {
    let lo = payload.pop().expect("migration payload carries a cost").to_bits() as u64;
    let hi = payload.pop().expect("migration payload carries a cost").to_bits() as u64;
    f64::from_bits((hi << 32) | lo)
}

/// Place a restricted child interior (dense [nvar, nz/2, ny/2, nx/2] in
/// active dims) into the parent's octant.
fn place_restricted_quadrant(
    data: &[Real],
    shape: &crate::mesh::IndexShape,
    bits: [i64; 3],
    parent: &mut [Real],
) {
    let dim = shape.dim;
    let n = shape.ncells_total();
    let (nt0, nt1) = (shape.nt(0), shape.nt(1));
    let cx = shape.n[0] / 2;
    let cy = if dim >= 2 { shape.n[1] / 2 } else { 1 };
    let cz = if dim >= 3 { shape.n[2] / 2 } else { 1 };
    let ox = shape.is_(0) + bits[0] as usize * cx;
    let oy = shape.is_(1) + if dim >= 2 { bits[1] as usize * cy } else { 0 };
    let oz = shape.is_(2) + if dim >= 3 { bits[2] as usize * cz } else { 0 };
    let mut r = 0usize;
    for v in 0..NHYDRO {
        for k in 0..cz {
            for j in 0..cy {
                for i in 0..cx {
                    parent[v * n + ((oz + k) * nt1 + oy + j) * nt0 + ox + i] = data[r];
                    r += 1;
                }
            }
        }
    }
    debug_assert_eq!(r, data.len());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_rides_payload_bit_exactly() {
        for cost in [0.0f64, 1.0, 0.37519, 1e-300, 1.2345678e13, f64::MIN_POSITIVE] {
            let mut payload = vec![1.5 as Real, -2.25];
            append_cost(&mut payload, cost);
            assert_eq!(payload.len(), 4);
            let got = take_cost(&mut payload);
            assert_eq!(got.to_bits(), cost.to_bits(), "cost must survive bit-exactly");
            assert_eq!(payload, vec![1.5 as Real, -2.25]);
        }
    }
}
