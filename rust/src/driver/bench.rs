//! Bench support: timed multi-rank simulation runs with warmup, used by the
//! `cargo bench` harnesses that regenerate the paper's tables and figures.

use std::sync::{Arc, Mutex};

use super::SimBuilder;
use crate::comm::World;
use crate::config::{Override, ParameterInput};
use crate::driver::EvolutionDriver;
use crate::metrics::HybridStats;


/// Result of one measured configuration.
#[derive(Debug, Clone)]
pub struct BenchRun {
    /// Global zone-cycles/s (mean of the per-rank measurements, each of
    /// which counts all global zones).
    pub zcps: f64,
    /// Total executable launches across ranks (device path only).
    pub launches: u64,
    /// Cycles measured (after warmup).
    pub cycles: u64,
    /// Total blocks in the mesh.
    pub nblocks: usize,
    /// Wall seconds of the measured window (max across ranks).
    pub wall: f64,
    /// Co-execution counters summed across ranks (`space=hybrid` only;
    /// untouched on single-space runs).
    pub hybrid: HybridStats,
}

/// Run `deck` on `nranks` rank-threads: `warm` untimed cycles, then `meas`
/// timed cycles. Panics on simulation errors (benches should be loud).
pub fn measure(deck: &str, overrides: &[&str], nranks: usize, warm: u64, meas: u64) -> BenchRun {
    let out: Arc<Mutex<Vec<(f64, u64, usize, f64, HybridStats)>>> =
        Arc::new(Mutex::new(vec![(0.0, 0, 0, 0.0, HybridStats::default()); nranks]));
    let o2 = out.clone();
    let deck = deck.to_string();
    // parse once at the edge; rank closures apply the typed overrides
    let overrides: Vec<Override> = overrides
        .iter()
        .map(|s| s.parse().expect("bench override"))
        .collect();
    World::launch(nranks, move |rank, world| {
        let mut pin = ParameterInput::from_str(&deck).expect("bench deck parses");
        for ov in &overrides {
            pin.apply(ov);
        }
        // never stop early
        pin.set("parthenon/time", "tlim", 1e30);
        pin.set("parthenon/time", "nlim", -1);
        let mut sim = SimBuilder::new(pin)
            .rank(rank)
            .world(world)
            .build()
            .expect("bench sim");
        for _ in 0..warm {
            sim.step().expect("warm step");
        }
        sim.zc.reset();
        let launches0 = sim.device.as_ref().map(|d| d.rt.launches()).unwrap_or(0);
        for _ in 0..meas {
            sim.step().expect("meas step");
        }
        let launches = sim.device.as_ref().map(|d| d.rt.launches()).unwrap_or(0) - launches0;
        o2.lock().unwrap()[rank] = (
            sim.zc.zcps(),
            launches,
            sim.mesh.tree.nblocks(),
            sim.zc.wall_secs,
            sim.hybrid_stats.clone(),
        );
    });
    let v = out.lock().unwrap();
    let mut hybrid = HybridStats::default();
    for x in v.iter() {
        hybrid.merge(&x.4);
    }
    BenchRun {
        zcps: v.iter().map(|x| x.0).sum::<f64>() / nranks as f64,
        launches: v.iter().map(|x| x.1).sum(),
        cycles: meas,
        nblocks: v[0].2,
        wall: v.iter().map(|x| x.3).fold(0.0, f64::max),
        hybrid,
    }
}

/// Standard 3D benchmark deck: periodic uniform flow on an nx^3 mesh split
/// into bx^3 blocks.
pub fn deck_3d(nx: usize, bx: usize) -> String {
    format!(
        "<parthenon/job>\nproblem = uniform\nquiet = true\n\
         <parthenon/mesh>\nnx1 = {nx}\nnx2 = {nx}\nnx3 = {nx}\n\
         <parthenon/meshblock>\nnx1 = {bx}\nnx2 = {bx}\nnx3 = {bx}\n\
         <parthenon/time>\ntlim = 1e30\n\
         <hydro>\ngamma = 1.4\ncfl = 0.3\n\
         <problem>\nvx = 0.1\nvy = 0.05\n"
    )
}

/// 3D deck with independent per-axis mesh extents.
pub fn deck_3d_xyz(nx: [usize; 3], bx: usize) -> String {
    format!(
        "<parthenon/job>\nproblem = uniform\nquiet = true\n\
         <parthenon/mesh>\nnx1 = {}\nnx2 = {}\nnx3 = {}\n\
         <parthenon/meshblock>\nnx1 = {bx}\nnx2 = {bx}\nnx3 = {bx}\n\
         <parthenon/time>\ntlim = 1e30\n\
         <hydro>\ngamma = 1.4\ncfl = 0.3\n\
         <problem>\nvx = 0.1\nvy = 0.05\n",
        nx[0], nx[1], nx[2]
    )
}

/// Multilevel deck: root grid nx^3 in bx^3 blocks with a statically refined
/// central cube (the paper's Table 1 / Fig 11 mesh shape, scaled down).
pub fn deck_multilevel(nx: usize, bx: usize, levels: u8) -> String {
    let mut s = format!(
        "<parthenon/job>\nproblem = blast\nquiet = true\n\
         <parthenon/mesh>\nnx1 = {nx}\nnx2 = {nx}\nnx3 = {nx}\nrefinement = static\n\
         <parthenon/meshblock>\nnx1 = {bx}\nnx2 = {bx}\nnx3 = {bx}\n\
         <parthenon/time>\ntlim = 1e30\n\
         <hydro>\ngamma = 1.4\ncfl = 0.3\n\
         <problem>\nradius = 0.2\np_in = 2.0\np_out = 1.0\n"
    );
    s.push_str(&format!(
        "<parthenon/static_refinement0>\nlevel = {levels}\n\
         x1min = 0.3\nx1max = 0.7\nx2min = 0.3\nx2max = 0.7\nx3min = 0.3\nx3max = 0.7\n"
    ));
    s
}
