//! Variable metadata: flags and shape (paper Sec. 3.4).

/// Metadata flags. A variable carries a set of these (bitmask).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u32)]
pub enum MetadataFlag {
    // -- topology ------------------------------------------------------------
    /// Cell-centered field.
    Cell = 1 << 0,
    /// Face-centered field (allocation/indexing only; comm not yet wired,
    /// matching the paper's Sec. 7 status).
    Face = 1 << 1,
    /// Edge-centered field (reserved).
    Edge = 1 << 2,
    /// Not tied to the mesh.
    None = 1 << 3,

    // -- role ----------------------------------------------------------------
    /// Evolved state: included in restarts and prolong/restrict on regrid.
    Independent = 1 << 4,
    /// Recomputed from independent data (not communicated or restarted).
    Derived = 1 << 5,

    // -- dependency resolution (paper Sec. 3.3) -------------------------------
    /// Package owns and provides this variable.
    Provides = 1 << 6,
    /// Package needs this variable but does not create it.
    Requires = 1 << 7,
    /// Package can provide it but defers to another provider.
    Overridable = 1 << 8,
    /// Private to the registering package (name is namespaced).
    Private = 1 << 9,

    // -- behavior ------------------------------------------------------------
    /// Ghost zones are filled by boundary communication.
    FillGhost = 1 << 10,
    /// Flux storage is allocated; participates in flux correction.
    WithFluxes = 1 << 11,
    /// Advected by the hydro package.
    Advected = 1 << 12,
    /// Force inclusion in restart outputs.
    Restart = 1 << 13,
    /// Sparse: allocated per-block on demand.
    Sparse = 1 << 14,
    /// Vector: components transform like a vector under reflection.
    Vector = 1 << 15,
    /// Tensor (flattened components).
    Tensor = 1 << 16,
}

/// Metadata for one variable: flag set plus component shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Metadata {
    flags: u32,
    /// Component shape (empty = scalar). E.g. [3] = vector, [3,3] = tensor.
    pub shape: Vec<usize>,
    /// Sparse id when the variable belongs to a sparse pool.
    pub sparse_id: Option<usize>,
}

impl Metadata {
    pub fn new(flags: &[MetadataFlag]) -> Self {
        let mut m = Metadata { flags: 0, shape: Vec::new(), sparse_id: None };
        for f in flags {
            m.flags |= *f as u32;
        }
        m
    }

    pub fn with_shape(mut self, shape: Vec<usize>) -> Self {
        self.shape = shape;
        self
    }

    pub fn with_sparse_id(mut self, id: usize) -> Self {
        self.sparse_id = Some(id);
        self.set(MetadataFlag::Sparse);
        self
    }

    #[inline]
    pub fn has(&self, f: MetadataFlag) -> bool {
        self.flags & (f as u32) != 0
    }

    pub fn set(&mut self, f: MetadataFlag) {
        self.flags |= f as u32;
    }

    pub fn unset(&mut self, f: MetadataFlag) {
        self.flags &= !(f as u32);
    }

    /// Flattened number of components.
    pub fn ncomp(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    /// Exactly one of Provides / Requires / Overridable / Private (defaults
    /// to Provides when none set).
    pub fn role(&self) -> MetadataFlag {
        for f in [
            MetadataFlag::Requires,
            MetadataFlag::Overridable,
            MetadataFlag::Private,
            MetadataFlag::Provides,
        ] {
            if self.has(f) {
                return f;
            }
        }
        MetadataFlag::Provides
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_roundtrip() {
        let mut m = Metadata::new(&[MetadataFlag::Cell, MetadataFlag::Independent]);
        assert!(m.has(MetadataFlag::Cell));
        assert!(!m.has(MetadataFlag::FillGhost));
        m.set(MetadataFlag::FillGhost);
        assert!(m.has(MetadataFlag::FillGhost));
        m.unset(MetadataFlag::FillGhost);
        assert!(!m.has(MetadataFlag::FillGhost));
    }

    #[test]
    fn ncomp() {
        assert_eq!(Metadata::new(&[]).ncomp(), 1);
        assert_eq!(Metadata::new(&[]).with_shape(vec![3]).ncomp(), 3);
        assert_eq!(Metadata::new(&[]).with_shape(vec![3, 3]).ncomp(), 9);
    }

    #[test]
    fn role_defaults_to_provides() {
        assert_eq!(Metadata::new(&[MetadataFlag::Cell]).role(), MetadataFlag::Provides);
        assert_eq!(
            Metadata::new(&[MetadataFlag::Requires]).role(),
            MetadataFlag::Requires
        );
    }

    #[test]
    fn sparse_builder() {
        let m = Metadata::new(&[MetadataFlag::Cell]).with_sparse_id(4);
        assert!(m.has(MetadataFlag::Sparse));
        assert_eq!(m.sparse_id, Some(4));
    }
}
