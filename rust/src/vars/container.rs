//! `MeshBlockData` — the per-block container holding every resolved variable
//! (paper Sec. 3.6).

use std::collections::HashMap;

use super::array::Array4;
use super::metadata::MetadataFlag;
use super::package::FieldDef;
use super::Variable;
use crate::error::{Error, Result};
use crate::mesh::IndexShape;

/// All variables of one MeshBlock.
#[derive(Debug, Clone, Default)]
pub struct MeshBlockData {
    pub shape: Option<IndexShape>,
    vars: Vec<Variable>,
    by_name: HashMap<String, usize>,
}

impl MeshBlockData {
    /// Build from the resolved field list. Dense variables are allocated
    /// immediately; sparse ones stay empty until
    /// [`MeshBlockData::allocate_sparse`].
    pub fn from_fields(fields: &[FieldDef], shape: IndexShape) -> Self {
        let mut c = MeshBlockData { shape: Some(shape), ..Default::default() };
        let (zt, yt, xt) = shape.total_zyx();
        for f in fields {
            let sparse = f.metadata.has(MetadataFlag::Sparse);
            let dims = [f.metadata.ncomp(), zt, yt, xt];
            let data = if sparse { Array4::empty() } else { Array4::zeros(dims) };
            let idx = c.vars.len();
            c.by_name.insert(f.name.clone(), idx);
            c.vars.push(Variable {
                name: f.name.clone(),
                metadata: f.metadata.clone(),
                data,
                allocated: !sparse,
            });
        }
        c
    }

    pub fn nvars(&self) -> usize {
        self.vars.len()
    }

    pub fn var_names(&self) -> impl Iterator<Item = &str> {
        self.vars.iter().map(|v| v.name.as_str())
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.by_name.get(name).copied()
    }

    pub fn var(&self, name: &str) -> Result<&Variable> {
        self.by_name
            .get(name)
            .map(|&i| &self.vars[i])
            .ok_or_else(|| Error::Variable(format!("no variable {name:?}")))
    }

    pub fn var_mut(&mut self, name: &str) -> Result<&mut Variable> {
        match self.by_name.get(name) {
            Some(&i) => Ok(&mut self.vars[i]),
            None => Err(Error::Variable(format!("no variable {name:?}"))),
        }
    }

    pub fn var_by_index(&self, idx: usize) -> &Variable {
        &self.vars[idx]
    }

    pub fn var_by_index_mut(&mut self, idx: usize) -> &mut Variable {
        &mut self.vars[idx]
    }

    /// Data array of a variable (must exist and be allocated).
    pub fn get(&self, name: &str) -> Result<&Array4> {
        let v = self.var(name)?;
        if !v.allocated {
            return Err(Error::Variable(format!("variable {name:?} not allocated")));
        }
        Ok(&v.data)
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Array4> {
        let shape = self.shape;
        let v = self.var_mut(name)?;
        if !v.allocated {
            let _ = shape;
            return Err(Error::Variable(format!("variable {name:?} not allocated")));
        }
        Ok(&mut v.data)
    }

    /// Two distinct variables mutably at once (for update kernels).
    pub fn get2_mut(&mut self, a: &str, b: &str) -> Result<(&mut Array4, &mut Array4)> {
        let ia = self
            .index_of(a)
            .ok_or_else(|| Error::Variable(format!("no variable {a:?}")))?;
        let ib = self
            .index_of(b)
            .ok_or_else(|| Error::Variable(format!("no variable {b:?}")))?;
        if ia == ib {
            return Err(Error::Variable(format!("get2_mut of same variable {a:?}")));
        }
        let (lo, hi, swap) = if ia < ib { (ia, ib, false) } else { (ib, ia, true) };
        let (left, right) = self.vars.split_at_mut(hi);
        let (x, y) = (&mut left[lo].data, &mut right[0].data);
        Ok(if swap { (y, x) } else { (x, y) })
    }

    /// Allocate a sparse variable on this block.
    pub fn allocate_sparse(&mut self, name: &str) -> Result<()> {
        let shape = self
            .shape
            .ok_or_else(|| Error::Variable("container has no shape".into()))?;
        let (zt, yt, xt) = shape.total_zyx();
        let v = self.var_mut(name)?;
        if !v.metadata.has(MetadataFlag::Sparse) {
            return Err(Error::Variable(format!("{name:?} is not sparse")));
        }
        if !v.allocated {
            v.data = Array4::zeros([v.metadata.ncomp(), zt, yt, xt]);
            v.allocated = true;
        }
        Ok(())
    }

    /// Deallocate a sparse variable (frees storage).
    pub fn deallocate_sparse(&mut self, name: &str) -> Result<()> {
        let v = self.var_mut(name)?;
        if !v.metadata.has(MetadataFlag::Sparse) {
            return Err(Error::Variable(format!("{name:?} is not sparse")));
        }
        v.data = Array4::empty();
        v.allocated = false;
        Ok(())
    }

    /// Names of variables whose metadata matches every given flag
    /// (allocated ones only).
    pub fn names_by_flags(&self, flags: &[MetadataFlag]) -> Vec<String> {
        self.vars
            .iter()
            .filter(|v| v.allocated && flags.iter().all(|f| v.metadata.has(*f)))
            .map(|v| v.name.clone())
            .collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Variable> {
        self.vars.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vars::{Metadata, MetadataFlag};

    fn fields() -> Vec<FieldDef> {
        vec![
            FieldDef {
                name: "cons".into(),
                metadata: Metadata::new(&[
                    MetadataFlag::Cell,
                    MetadataFlag::Independent,
                    MetadataFlag::FillGhost,
                ])
                .with_shape(vec![5]),
            },
            FieldDef {
                name: "prim".into(),
                metadata: Metadata::new(&[MetadataFlag::Cell, MetadataFlag::Derived])
                    .with_shape(vec![5]),
            },
            FieldDef {
                name: "vf_3".into(),
                metadata: Metadata::new(&[MetadataFlag::Cell]).with_sparse_id(3),
            },
        ]
    }

    fn shape() -> IndexShape {
        IndexShape::new(2, [8, 8, 1])
    }

    #[test]
    fn dense_allocated_sparse_not() {
        let c = MeshBlockData::from_fields(&fields(), shape());
        assert_eq!(c.nvars(), 3);
        assert!(c.get("cons").is_ok());
        assert!(c.get("vf_3").is_err());
    }

    #[test]
    fn sparse_allocate_deallocate() {
        let mut c = MeshBlockData::from_fields(&fields(), shape());
        c.allocate_sparse("vf_3").unwrap();
        assert!(c.get("vf_3").is_ok());
        assert_eq!(c.get("vf_3").unwrap().dims()[0], 1);
        c.deallocate_sparse("vf_3").unwrap();
        assert!(c.get("vf_3").is_err());
        assert!(c.allocate_sparse("cons").is_err(), "dense is not sparse");
    }

    #[test]
    fn flag_queries() {
        let mut c = MeshBlockData::from_fields(&fields(), shape());
        assert_eq!(c.names_by_flags(&[MetadataFlag::FillGhost]), vec!["cons"]);
        assert!(c.names_by_flags(&[MetadataFlag::Sparse]).is_empty(), "unallocated hidden");
        c.allocate_sparse("vf_3").unwrap();
        assert_eq!(c.names_by_flags(&[MetadataFlag::Sparse]), vec!["vf_3"]);
    }

    #[test]
    fn get2_mut_disjoint() {
        let mut c = MeshBlockData::from_fields(&fields(), shape());
        let (a, b) = c.get2_mut("cons", "prim").unwrap();
        a.fill(1.0);
        b.fill(2.0);
        assert_eq!(c.get("cons").unwrap().as_slice()[0], 1.0);
        assert_eq!(c.get("prim").unwrap().as_slice()[0], 2.0);
        assert!(c.get2_mut("cons", "cons").is_err());
    }

    #[test]
    fn dims_include_ghosts() {
        let c = MeshBlockData::from_fields(&fields(), shape());
        assert_eq!(c.get("cons").unwrap().dims(), [5, 1, 12, 12]);
    }
}
