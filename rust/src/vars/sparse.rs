//! Sparse variable pools (paper Sec. 3.4): variables that exist on only
//! some blocks, allocated on demand and deallocated when they leave a block.

use super::container::MeshBlockData;
use crate::mesh::IndexShape;
use crate::Real;

/// Descriptor of a sparse pool: base name + ids (fields are `base_<id>`).
#[derive(Debug, Clone)]
pub struct SparsePool {
    pub base: String,
    pub ids: Vec<usize>,
    /// Deallocate when max |interior| falls below this.
    pub dealloc_threshold: Real,
    /// Deallocate only after this many consecutive below-threshold cycles.
    pub dealloc_count: usize,
}

impl SparsePool {
    pub fn new(base: &str, ids: &[usize]) -> Self {
        SparsePool {
            base: base.to_string(),
            ids: ids.to_vec(),
            dealloc_threshold: 1.0e-12,
            dealloc_count: 1,
        }
    }

    pub fn field_name(&self, id: usize) -> String {
        format!("{}_{id}", self.base)
    }

    /// Max |value| over the interior of a sparse field (0 if unallocated).
    pub fn interior_max_abs(
        &self,
        data: &MeshBlockData,
        id: usize,
        shape: &IndexShape,
    ) -> Real {
        let name = self.field_name(id);
        let Ok(arr) = data.get(&name) else { return 0.0 };
        let mut m: Real = 0.0;
        for v in 0..arr.dims()[0] {
            for k in shape.is_(2)..shape.ie(2) {
                for j in shape.is_(1)..shape.ie(1) {
                    for i in shape.is_(0)..shape.ie(0) {
                        m = m.max(arr.get(v, k, j, i).abs());
                    }
                }
            }
        }
        m
    }

    /// Deallocate ids whose interior is (numerically) empty. Returns the
    /// list of deallocated field names.
    pub fn sweep_deallocate(
        &self,
        data: &mut MeshBlockData,
        shape: &IndexShape,
    ) -> Vec<String> {
        let mut dropped = Vec::new();
        for &id in &self.ids {
            let name = self.field_name(id);
            if data.get(&name).is_err() {
                continue;
            }
            if self.interior_max_abs(data, id, shape) < self.dealloc_threshold {
                let _ = data.deallocate_sparse(&name);
                dropped.push(name);
            }
        }
        dropped
    }

    /// Ensure a sparse id is allocated on this block (e.g. when advected in).
    pub fn ensure_allocated(&self, data: &mut MeshBlockData, id: usize) {
        let _ = data.allocate_sparse(&self.field_name(id));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vars::package::FieldDef;
    use crate::vars::{Metadata, MetadataFlag};

    fn setup() -> (MeshBlockData, SparsePool, IndexShape) {
        let pool = SparsePool::new("vf", &[1, 2]);
        let fields: Vec<FieldDef> = pool
            .ids
            .iter()
            .map(|&id| FieldDef {
                name: pool.field_name(id),
                metadata: Metadata::new(&[MetadataFlag::Cell]).with_sparse_id(id),
            })
            .collect();
        let shape = IndexShape::new(2, [4, 4, 1]);
        (MeshBlockData::from_fields(&fields, shape), pool, shape)
    }

    #[test]
    fn allocate_on_demand() {
        let (mut data, pool, _) = setup();
        assert!(data.get("vf_1").is_err());
        pool.ensure_allocated(&mut data, 1);
        assert!(data.get("vf_1").is_ok());
        assert!(data.get("vf_2").is_err(), "other id untouched");
    }

    #[test]
    fn sweep_deallocates_empty_only() {
        let (mut data, pool, shape) = setup();
        pool.ensure_allocated(&mut data, 1);
        pool.ensure_allocated(&mut data, 2);
        // put real material into vf_2's interior
        let g = shape.is_(0);
        data.get_mut("vf_2").unwrap().set(0, 0, g, g, 0.5);
        let dropped = pool.sweep_deallocate(&mut data, &shape);
        assert_eq!(dropped, vec!["vf_1"]);
        assert!(data.get("vf_2").is_ok());
    }

    #[test]
    fn ghost_data_does_not_keep_alive() {
        let (mut data, pool, shape) = setup();
        pool.ensure_allocated(&mut data, 1);
        data.get_mut("vf_1").unwrap().set(0, 0, 0, 0, 9.0); // ghost corner
        let dropped = pool.sweep_deallocate(&mut data, &shape);
        assert_eq!(dropped, vec!["vf_1"], "ghost-only data is 'empty'");
    }
}
