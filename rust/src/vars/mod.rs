//! Variables, metadata, packages (StateDescriptors), containers and packs —
//! the paper's Sec. 3.3-3.6 abstractions.

mod array;
mod container;
mod metadata;
mod pack;
mod package;
mod sparse;

pub use array::Array4;
pub use container::MeshBlockData;
pub use metadata::{Metadata, MetadataFlag};
pub use pack::{PackDescriptor, VariablePack};
pub use package::{
    resolve_packages, FieldDef, Package, ParamValue, Params, StateDescriptor,
};
pub use sparse::SparsePool;

/// A variable: metadata plus per-block data (and optional flux storage).
#[derive(Debug, Clone)]
pub struct Variable {
    pub name: String,
    pub metadata: Metadata,
    /// [ncomp, Z, Y, X] data, ghosts included. Empty if unallocated (sparse).
    pub data: Array4,
    /// True once storage is allocated (always true for dense variables).
    pub allocated: bool,
}

impl Variable {
    pub fn ncomp(&self) -> usize {
        self.metadata.ncomp()
    }
}
