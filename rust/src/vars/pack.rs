//! Variable packs: bundling many variables (and, at the MeshData level,
//! many blocks) into contiguous staging storage so the device path can
//! touch them in a single kernel launch (paper Sec. 3.6).
//!
//! On the Rust side a pack is (a) a selection of variables resolved to
//! component planes and (b) gather/scatter into a caller-owned contiguous
//! buffer laid out `[v, z, y, x]` per block — the exact input layout of the
//! AOT artifacts. MeshBlockPacks add the leading `b` index by stacking
//! per-block gathers at fixed strides.

use super::container::MeshBlockData;
use super::metadata::MetadataFlag;
use crate::error::{Error, Result};
use crate::Real;

/// What to pack: resolved variable names, in pack order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackDescriptor {
    pub var_names: Vec<String>,
}

impl PackDescriptor {
    pub fn by_names(names: &[&str]) -> Self {
        PackDescriptor { var_names: names.iter().map(|s| s.to_string()).collect() }
    }

    /// Select every allocated variable matching all `flags`.
    pub fn by_flags(data: &MeshBlockData, flags: &[MetadataFlag]) -> Self {
        PackDescriptor { var_names: data.names_by_flags(flags) }
    }
}

/// A pack bound to one container: flattened (var, comp) list.
#[derive(Debug, Clone)]
pub struct VariablePack {
    entries: Vec<(usize, usize)>, // (var index, component)
    plane_len: usize,
}

impl VariablePack {
    pub fn new(data: &MeshBlockData, desc: &PackDescriptor) -> Result<Self> {
        let shape = data
            .shape
            .ok_or_else(|| Error::Variable("container has no shape".into()))?;
        let plane_len = shape.ncells_total();
        let mut entries = Vec::new();
        for name in &desc.var_names {
            let idx = data
                .index_of(name)
                .ok_or_else(|| Error::Variable(format!("no variable {name:?}")))?;
            let v = data.var_by_index(idx);
            if !v.allocated {
                continue; // sparse & unallocated: skipped, like Parthenon
            }
            for c in 0..v.ncomp() {
                entries.push((idx, c));
            }
        }
        Ok(VariablePack { entries, plane_len })
    }

    /// Total flattened components (the pack's `v` extent).
    pub fn ncomp(&self) -> usize {
        self.entries.len()
    }

    /// Elements required in the staging buffer.
    pub fn staging_len(&self) -> usize {
        self.ncomp() * self.plane_len
    }

    /// Copy pack data into `out` (layout [v, z, y, x]).
    pub fn gather(&self, data: &MeshBlockData, out: &mut [Real]) {
        debug_assert_eq!(out.len(), self.staging_len());
        for (slot, (vi, c)) in self.entries.iter().enumerate() {
            let src = data.var_by_index(*vi).data.comp(*c);
            out[slot * self.plane_len..(slot + 1) * self.plane_len].copy_from_slice(src);
        }
    }

    /// Copy staging data back into the variables.
    pub fn scatter(&self, data: &mut MeshBlockData, src: &[Real]) {
        debug_assert_eq!(src.len(), self.staging_len());
        for (slot, (vi, c)) in self.entries.iter().enumerate() {
            let dst = data.var_by_index_mut(*vi).data.comp_mut(*c);
            dst.copy_from_slice(&src[slot * self.plane_len..(slot + 1) * self.plane_len]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::IndexShape;
    use crate::vars::package::FieldDef;
    use crate::vars::Metadata;

    fn container() -> MeshBlockData {
        let fields = vec![
            FieldDef {
                name: "a".into(),
                metadata: Metadata::new(&[MetadataFlag::Cell]).with_shape(vec![2]),
            },
            FieldDef {
                name: "b".into(),
                metadata: Metadata::new(&[MetadataFlag::Cell, MetadataFlag::FillGhost]),
            },
            FieldDef {
                name: "s_1".into(),
                metadata: Metadata::new(&[MetadataFlag::Cell]).with_sparse_id(1),
            },
        ];
        MeshBlockData::from_fields(&fields, IndexShape::new(1, [4, 1, 1]))
    }

    #[test]
    fn pack_flattens_components() {
        let data = container();
        let pack = VariablePack::new(&data, &PackDescriptor::by_names(&["a", "b"])).unwrap();
        assert_eq!(pack.ncomp(), 3);
        assert_eq!(pack.staging_len(), 3 * 8);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut data = container();
        data.get_mut("a").unwrap().comp_mut(1).fill(5.0);
        data.get_mut("b").unwrap().fill(-2.0);
        let pack = VariablePack::new(&data, &PackDescriptor::by_names(&["a", "b"])).unwrap();
        let mut buf = vec![0.0; pack.staging_len()];
        pack.gather(&data, &mut buf);
        assert!(buf[8..16].iter().all(|&x| x == 5.0));
        assert!(buf[16..24].iter().all(|&x| x == -2.0));
        for x in buf.iter_mut() {
            *x += 1.0;
        }
        pack.scatter(&mut data, &buf);
        assert!(data.get("a").unwrap().comp(1).iter().all(|&x| x == 6.0));
        assert!(data.get("b").unwrap().comp(0).iter().all(|&x| x == -1.0));
    }

    #[test]
    fn unallocated_sparse_skipped() {
        let data = container();
        let pack =
            VariablePack::new(&data, &PackDescriptor::by_names(&["b", "s_1"])).unwrap();
        assert_eq!(pack.ncomp(), 1, "sparse var not allocated -> skipped");
    }

    #[test]
    fn by_flags_selection() {
        let data = container();
        let desc = PackDescriptor::by_flags(&data, &[MetadataFlag::FillGhost]);
        assert_eq!(desc.var_names, vec!["b"]);
    }

    #[test]
    fn missing_var_is_error() {
        let data = container();
        assert!(VariablePack::new(&data, &PackDescriptor::by_names(&["zz"])).is_err());
    }
}
