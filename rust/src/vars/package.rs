//! Packages (StateDescriptors): independent components that register
//! variables, params, and physics hooks (paper Sec. 3.3).

use std::collections::BTreeMap;

use super::container::MeshBlockData;
use super::metadata::{Metadata, MetadataFlag};
use crate::error::{Error, Result};
use crate::mesh::{AmrFlag, Coords};

/// A typed parameter value stored in a package's params.
#[derive(Debug, Clone, PartialEq)]
pub enum ParamValue {
    Int(i64),
    Real(f64),
    Bool(bool),
    Str(String),
    VecReal(Vec<f64>),
    VecInt(Vec<i64>),
}

/// Per-package constants ("params" in the paper).
#[derive(Debug, Clone, Default)]
pub struct Params {
    map: BTreeMap<String, ParamValue>,
}

impl Params {
    pub fn add(&mut self, key: &str, value: ParamValue) {
        self.map.insert(key.to_string(), value);
    }

    pub fn get(&self, key: &str) -> Option<&ParamValue> {
        self.map.get(key)
    }

    pub fn real(&self, key: &str) -> Result<f64> {
        match self.map.get(key) {
            Some(ParamValue::Real(v)) => Ok(*v),
            Some(ParamValue::Int(v)) => Ok(*v as f64),
            other => Err(Error::Package(format!("param {key:?}: not a real ({other:?})"))),
        }
    }

    pub fn int(&self, key: &str) -> Result<i64> {
        match self.map.get(key) {
            Some(ParamValue::Int(v)) => Ok(*v),
            other => Err(Error::Package(format!("param {key:?}: not an int ({other:?})"))),
        }
    }

    pub fn bool(&self, key: &str) -> Result<bool> {
        match self.map.get(key) {
            Some(ParamValue::Bool(v)) => Ok(*v),
            other => Err(Error::Package(format!("param {key:?}: not a bool ({other:?})"))),
        }
    }

    pub fn str(&self, key: &str) -> Result<&str> {
        match self.map.get(key) {
            Some(ParamValue::Str(v)) => Ok(v),
            other => Err(Error::Package(format!("param {key:?}: not a str ({other:?})"))),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }
}

/// Field registration record.
#[derive(Debug, Clone)]
pub struct FieldDef {
    pub name: String,
    pub metadata: Metadata,
}

/// What a package registers: fields, sparse pools, params.
#[derive(Debug, Clone, Default)]
pub struct StateDescriptor {
    pub name: String,
    pub fields: Vec<FieldDef>,
    pub params: Params,
}

impl StateDescriptor {
    pub fn new(name: &str) -> Self {
        StateDescriptor { name: name.to_string(), ..Default::default() }
    }

    /// Register a field. Private fields are namespaced as `pkg::name`.
    pub fn add_field(&mut self, name: &str, metadata: Metadata) {
        let name = if metadata.has(MetadataFlag::Private) {
            format!("{}::{}", self.name, name)
        } else {
            name.to_string()
        };
        self.fields.push(FieldDef { name, metadata });
    }

    /// Register a sparse pool: one field per sparse id, named `base_<id>`.
    pub fn add_sparse_pool(&mut self, base: &str, ids: &[usize], metadata: Metadata) {
        for &id in ids {
            let m = metadata.clone().with_sparse_id(id);
            self.add_field(&format!("{base}_{id}"), m);
        }
    }
}

/// Physics hooks a package may implement; dispatched by drivers.
/// (The paper's task functions are woven by the application driver; these
/// are the package-level callbacks Parthenon exposes.)
pub trait Package: Send + Sync {
    fn descriptor(&self) -> &StateDescriptor;

    fn name(&self) -> &str {
        &self.descriptor().name
    }

    /// Tag this block for (de)refinement.
    fn check_refinement(&self, _data: &MeshBlockData, _coords: &Coords) -> AmrFlag {
        AmrFlag::Same
    }

    /// Package CFL limit for this block (f64::INFINITY if none).
    fn estimate_dt(&self, _data: &MeshBlockData, _coords: &Coords) -> f64 {
        f64::INFINITY
    }

    /// Recompute derived quantities after the state changed.
    fn fill_derived(&self, _data: &mut MeshBlockData, _coords: &Coords) {}
}

/// Resolve Provides/Requires/Overridable/Private across packages into the
/// final field list for containers (paper Sec. 3.3 semantics).
pub fn resolve_packages(pkgs: &[&StateDescriptor]) -> Result<Vec<FieldDef>> {
    let mut provided: BTreeMap<String, FieldDef> = BTreeMap::new();
    let mut overridable: BTreeMap<String, FieldDef> = BTreeMap::new();
    let mut required: Vec<(String, String)> = Vec::new(); // (pkg, field)
    let mut out: Vec<FieldDef> = Vec::new();

    for pkg in pkgs {
        for f in &pkg.fields {
            match f.metadata.role() {
                MetadataFlag::Provides => {
                    if let Some(prev) = provided.get(&f.name) {
                        let _ = prev;
                        return Err(Error::Package(format!(
                            "field {:?} provided by two packages (second: {})",
                            f.name, pkg.name
                        )));
                    }
                    provided.insert(f.name.clone(), f.clone());
                }
                MetadataFlag::Overridable => {
                    overridable.entry(f.name.clone()).or_insert_with(|| f.clone());
                }
                MetadataFlag::Requires => {
                    required.push((pkg.name.clone(), f.name.clone()));
                }
                MetadataFlag::Private => {
                    if out.iter().any(|g| g.name == f.name) {
                        return Err(Error::Package(format!(
                            "duplicate private field {:?}",
                            f.name
                        )));
                    }
                    out.push(f.clone());
                }
                _ => unreachable!(),
            }
        }
    }

    // overridables defer to providers
    for (name, f) in overridable {
        provided.entry(name).or_insert(f);
    }

    for (pkg, name) in &required {
        if !provided.contains_key(name) {
            return Err(Error::Package(format!(
                "package {pkg:?} requires field {name:?} but nothing provides it"
            )));
        }
    }

    out.extend(provided.into_values());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell() -> Metadata {
        Metadata::new(&[MetadataFlag::Cell])
    }

    #[test]
    fn provides_conflict_is_error() {
        let mut a = StateDescriptor::new("a");
        a.add_field("x", cell());
        let mut b = StateDescriptor::new("b");
        b.add_field("x", cell());
        assert!(resolve_packages(&[&a, &b]).is_err());
    }

    #[test]
    fn requires_satisfied_by_provider() {
        let mut a = StateDescriptor::new("a");
        a.add_field("x", cell());
        let mut b = StateDescriptor::new("b");
        let mut m = cell();
        m.set(MetadataFlag::Requires);
        b.add_field("x", m);
        let fields = resolve_packages(&[&a, &b]).unwrap();
        assert_eq!(fields.len(), 1);
    }

    #[test]
    fn requires_unsatisfied_is_error() {
        let mut b = StateDescriptor::new("b");
        let mut m = cell();
        m.set(MetadataFlag::Requires);
        b.add_field("ghost", m);
        assert!(resolve_packages(&[&b]).is_err());
    }

    #[test]
    fn overridable_defers_to_provider() {
        let mut a = StateDescriptor::new("a");
        let mut m = cell().with_shape(vec![3]);
        m.set(MetadataFlag::Overridable);
        a.add_field("x", m);
        let mut b = StateDescriptor::new("b");
        b.add_field("x", cell()); // provider, scalar
        let fields = resolve_packages(&[&a, &b]).unwrap();
        assert_eq!(fields.len(), 1);
        assert_eq!(fields[0].metadata.ncomp(), 1, "provider wins");
    }

    #[test]
    fn overridable_used_when_no_provider() {
        let mut a = StateDescriptor::new("a");
        let mut m = cell();
        m.set(MetadataFlag::Overridable);
        a.add_field("x", m);
        let fields = resolve_packages(&[&a]).unwrap();
        assert_eq!(fields.len(), 1);
    }

    #[test]
    fn private_is_namespaced() {
        let mut a = StateDescriptor::new("a");
        let mut m = cell();
        m.set(MetadataFlag::Private);
        a.add_field("x", m);
        assert_eq!(a.fields[0].name, "a::x");
        let mut b = StateDescriptor::new("b");
        let mut m2 = cell();
        m2.set(MetadataFlag::Private);
        b.add_field("x", m2);
        let fields = resolve_packages(&[&a, &b]).unwrap();
        assert_eq!(fields.len(), 2, "same leaf name, different namespaces");
    }

    #[test]
    fn sparse_pool_registers_per_id() {
        let mut a = StateDescriptor::new("mat");
        a.add_sparse_pool("vf", &[1, 4, 10], cell());
        assert_eq!(a.fields.len(), 3);
        assert_eq!(a.fields[1].name, "vf_4");
        assert_eq!(a.fields[1].metadata.sparse_id, Some(4));
    }

    #[test]
    fn params_typed_access() {
        let mut p = Params::default();
        p.add("gamma", ParamValue::Real(1.4));
        p.add("n", ParamValue::Int(3));
        p.add("on", ParamValue::Bool(true));
        assert_eq!(p.real("gamma").unwrap(), 1.4);
        assert_eq!(p.real("n").unwrap(), 3.0);
        assert_eq!(p.int("n").unwrap(), 3);
        assert!(p.bool("on").unwrap());
        assert!(p.int("gamma").is_err());
        assert!(p.real("missing").is_err());
    }
}
